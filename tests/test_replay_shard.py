"""Sharded replay runtime: the whole step loop inside one ``shard_map``.

The load-bearing guarantees:

  * ``run_series_sharded`` is **bit-for-bit** the single-device scanned
    ``run_series`` — per-step metrics, trigger fire steps, migration
    fractions/loads and the final assignment — on any mesh size
    (in-process tests degrade to a 1-device mesh; the subprocess test
    forces an 8-virtual-device mesh so the genuinely distributed case is
    asserted in every CI run);
  * the sharded PIC driver (``PICConfig(sharded_replay=True)``) executes
    its particle exchanges *inside the scan* via the masked ``ppermute``
    ring all-to-all and still reproduces the single-device scanned
    ``PICResult`` bit-for-bit, including ``final_x/final_y`` restored to
    particle-id order (wall-derived fields — ``step_seconds``,
    ``lb_seconds`` — embed measured plan wall time and are excluded:
    they differ between any two runs of *either* path);
  * repeated in-scan exchanges conserve the particle population exactly
    (the slab prefixes always hold a permutation of the particle ids);
  * the measured predictive gate (``TriggerState.last_moved``) amortizes
    against the last executed exchange and falls back to the modeled
    estimate only before one exists.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.pic import driver
from repro.runtime import cost as rt_cost
from repro.runtime import migrate as rt_migrate
from repro.runtime import triggers as rt
from repro.sim import scenarios, simulator

SERIES_FIELDS = ("max_avg", "ext_int", "migrations", "lb_fired",
                 "max_load", "migrated_load", "final_assignment")
PIC_FIELDS = ("max_avg", "ext_bytes", "int_bytes", "migrations",
              "migrated_bytes", "lb_steps", "final_x", "final_y")


def _assert_parity(ref, got, fields):
    for f in fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, f)), np.asarray(getattr(got, f)),
            err_msg=f"sharded replay diverged on {f}")


# ------------------------------------------------------- series replay --


def test_series_sharded_matches_scanned():
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    kw = dict(steps=14, lb_every=4, strategy="diff-comm",
              strategy_kwargs=dict(k=2))
    ref = simulator.run_series(prob, evolve, scan=True, **kw)
    sh = simulator.run_series_sharded(prob, evolve, **kw)
    assert sh.scanned and sh.lb_fired.sum() > 0
    _assert_parity(ref, sh, SERIES_FIELDS)


@pytest.mark.parametrize("trigger", ["threshold", "predictive"])
def test_series_sharded_adaptive_trigger_parity(trigger):
    prob, evolve = scenarios.get("bimodal-churn").instantiate(
        grid=8, num_nodes=4)
    kw = dict(steps=20, lb_every=5, strategy="diff-comm",
              strategy_kwargs=dict(k=2), trigger=trigger)
    ref = simulator.run_series(prob, evolve, scan=True, **kw)
    sh = simulator.run_series_sharded(prob, evolve, **kw)
    assert ref.lb_fired.sum() > 0         # the policy does act
    _assert_parity(ref, sh, SERIES_FIELDS)


def test_series_sharded_threads_per_node_parity():
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    kw = dict(steps=10, lb_every=3, strategy="diff-comm",
              strategy_kwargs=dict(k=2), threads_per_node=2)
    ref = simulator.run_series(prob, evolve, scan=True, **kw)
    sh = simulator.run_series_sharded(prob, evolve, **kw)
    np.testing.assert_array_equal(ref.thread_max_avg, sh.thread_max_avg)


def test_series_sharded_runner_cache_keyed_on_node_count():
    # regression: the runner cache must not hand a trace compiled for a
    # different P to an otherwise-identical call (same evolve identity,
    # same array shapes, same steps/strategy) — the node count is baked
    # into the compiled shard_map body, unlike the single-device runner
    # whose jit retraces on the problem's static num_nodes field
    from repro.sim import stencil

    def evolve(p, t):
        ramp = jnp.arange(1.0, p.loads.shape[0] + 1.0, dtype=jnp.float32)
        return dataclasses.replace(
            p, loads=(1.5 + jnp.cos(0.3 * t)) * ramp)

    evolve.jittable = True
    kw = dict(steps=8, lb_every=3, strategy="diff-comm",
              strategy_kwargs=dict(k=2))
    for nodes in (4, 8):               # same (N, E) shapes, different P
        prob = stencil.stencil_2d(8, 8, nodes)
        ref = simulator.run_series(prob, evolve, scan=True, **kw)
        sh = simulator.run_series_sharded(prob, evolve, num_shards=1,
                                          **kw)
        _assert_parity(ref, sh, SERIES_FIELDS)


def test_series_sharded_validates_inputs():
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    kw = dict(steps=4, lb_every=2)
    with pytest.raises(ValueError, match="not jittable"):
        simulator.run_series_sharded(prob, evolve, strategy="greedy", **kw)
    with pytest.raises(ValueError, match="scan-safe"):
        simulator.run_series_sharded(prob, lambda p, t: p, **kw)
    with pytest.raises(ValueError, match="cannot honor"):
        simulator.run_series_sharded(
            prob, evolve, strategy="diff-comm",
            strategy_kwargs=dict(step_fn=None), **kw)
    with pytest.raises(ValueError, match="not both"):
        from jax.sharding import Mesh
        simulator.run_series_sharded(
            prob, evolve, mesh=Mesh(np.asarray(jax.devices()[:1]),
                                    ("lb",)),
            num_shards=1, **kw)


# ---------------------------------------------------------- PIC replay --


def _pic_cfg(**kw):
    base = dict(L=100, n_particles=2000, steps=20, k=1, rho=0.9, cx=10,
                cy=10, num_pes=4, mapping="striped", lb_every=5,
                strategy="diff-comm", strategy_kwargs=dict(k=2), seed=0)
    base.update(kw)
    return driver.PICConfig(**base)


def test_pic_sharded_matches_scanned():
    ref = driver.run(_pic_cfg(scan=True))
    sh = driver.run(_pic_cfg(sharded_replay=True))
    assert sh.scanned and sh.migrated_bytes.sum() > 0
    _assert_parity(ref, sh, PIC_FIELDS)


def test_pic_sharded_adaptive_trigger_parity():
    ref = driver.run(_pic_cfg(scan=True, trigger="threshold"))
    sh = driver.run(_pic_cfg(sharded_replay=True, trigger="threshold"))
    assert ref.lb_steps.sum() > 0
    _assert_parity(ref, sh, PIC_FIELDS)


def test_pic_sharded_conservation_under_repeated_migrations():
    # lb_every=2 → many executed in-scan exchanges; the slab prefixes
    # must remain a permutation of the particle population throughout,
    # and per-particle trajectories must be untouched by the exchanges
    cfg = _pic_cfg(sharded_replay=True, lb_every=2, steps=16)
    r = driver.run(cfg)
    assert (r.lb_steps > 0).sum() >= 5
    assert r.migrated_bytes.sum() > 0
    assert r.final_x.shape == (cfg.n_particles,)
    assert np.isfinite(r.final_x).all() and np.isfinite(r.final_y).all()
    never = driver.run(_pic_cfg(strategy="none", steps=16))
    np.testing.assert_array_equal(r.final_x, never.final_x)
    np.testing.assert_array_equal(r.final_y, never.final_y)


def test_pic_sharded_capacity_overflow_raises():
    with pytest.raises(ValueError, match="replay_capacity"):
        driver.run(_pic_cfg(sharded_replay=True,
                            replay_capacity=100))
    # a sufficient explicit budget is honored
    r = driver.run(_pic_cfg(sharded_replay=True, replay_capacity=2000))
    ref = driver.run(_pic_cfg(scan=True))
    np.testing.assert_array_equal(r.final_x, ref.final_x)


def test_pic_sharded_rejects_scan_false_and_host_strategies():
    with pytest.raises(ValueError, match="scan"):
        driver.run(_pic_cfg(sharded_replay=True, scan=False))
    with pytest.raises(ValueError, match="not jittable"):
        driver.run(_pic_cfg(sharded_replay=True, strategy="greedy"))


# ----------------------------------------- capacity-planned sharded apply --


def test_migrate_sharded_plans_capacity_from_the_plan():
    D = len(jax.devices())
    P, n = 4 * D, 32 * D
    rng = np.random.default_rng(3)
    on = rng.integers(0, P, n).astype(np.int32)
    x = rng.normal(size=n).astype(np.float32)
    ids = np.arange(n, dtype=np.int32)
    planned = rt_migrate.planned_capacity(on, num_nodes=P, num_shards=D)
    counts = np.bincount(on, minlength=P).reshape(D, P // D).sum(1)
    assert planned == counts.max()
    owner_out, (xo, ido), got_counts = rt_migrate.migrate_sharded(
        on, (x, ids), num_nodes=P)          # capacity planned, not passed
    assert xo.shape[0] == D * planned
    (ref_x, ref_ids), _ = rt_migrate.migrate(on, on, (x, ids), num_nodes=P)
    got_counts = np.asarray(got_counts)
    got = np.concatenate(
        [np.asarray(ido)[d * planned:d * planned + got_counts[d]]
         for d in range(D)])
    np.testing.assert_array_equal(got, np.asarray(ref_ids))


# ------------------------------------------------ measured predictive gate --


def _decide_series(trig, ml_fn, observe_moved=None, steps=24, avg=10.0,
                   total=80.0):
    """Fire pattern; optionally feed ``observe_moved`` after each step."""
    def step(s, t):
        do, s = trig.decide(s, t, jnp.float32(ml_fn(t)), jnp.float32(avg),
                            jnp.float32(total))
        if observe_moved is not None:
            s = trig.observe(s, jnp.float32(observe_moved), do)
        return s, do
    _, dos = jax.lax.scan(step, trig.init_state(), jnp.arange(steps))
    return np.asarray(dos)


def test_predictive_cold_start_uses_estimate():
    # without any observed exchange, the measured gate is the legacy
    # estimate gate — identical firing pattern
    model = rt_cost.RuntimeCostModel(t_byte=0.5, lb_overhead=1.0)
    measured = rt.PredictiveTrigger(cost=model)
    legacy = rt.PredictiveTrigger(cost=model, measured_gate=False)
    rising = lambda t: 10.0 + 2.0 * t            # noqa: E731
    np.testing.assert_array_equal(_decide_series(measured, rising),
                                  _decide_series(legacy, rising))


def test_predictive_measured_gate_amortizes_observed_volume():
    rising = lambda t: 10.0 + 2.0 * t            # noqa: E731
    model = rt_cost.RuntimeCostModel(t_byte=0.5, lb_overhead=1.0)
    # estimate gate: 0.15 * 80 * 0.5 + 1 = 7.0.  A measured *cheap*
    # exchange (gate 1.0) fires at least as often; a measured expensive
    # one (gate > any projected loss) silences the trigger after its
    # first cold-start firing.
    trig = rt.PredictiveTrigger(cost=model)
    base = _decide_series(trig, rising).sum()
    cheap = _decide_series(trig, rising, observe_moved=0.0).sum()
    dear = _decide_series(trig, rising, observe_moved=1e9).sum()
    assert cheap >= base > 0
    assert dear == 1                 # cold-start fire, then priced out
    # estimate-only trigger ignores the observations entirely
    legacy = rt.PredictiveTrigger(cost=model, measured_gate=False)
    assert _decide_series(legacy, rising, observe_moved=1e9).sum() == \
        _decide_series(legacy, rising).sum()


def test_observe_records_only_fired_steps():
    trig = rt.PredictiveTrigger()
    s = trig.init_state()
    assert float(s.last_moved) < 0
    s = trig.observe(s, 5.0, jnp.asarray(False))
    assert float(s.last_moved) < 0               # not fired: no sample
    s = trig.observe(s, 5.0, jnp.asarray(True))
    assert float(s.last_moved) == 5.0
    s = trig.observe(s, 7.0, jnp.asarray(False))
    assert float(s.last_moved) == 5.0            # kept until next fire
    # simple triggers ignore the feedback
    for simple in (rt.EveryTrigger(5), rt.ThresholdTrigger()):
        st = simple.init_state()
        assert simple.observe(st, 9.0, jnp.asarray(True)) is st


def test_run_series_observe_plumbing_host_scan_parity():
    # a predictive policy whose gate flips from fire-often (estimate) to
    # fire-rarely (measured, expensive) only if the replay layers
    # actually feed the executed volume back — parity across paths
    # proves all three plumb it identically
    model = rt_cost.RuntimeCostModel(t_load=1.0, t_byte=50.0,
                                     bytes_per_load=1.0,
                                     moved_frac_est=0.001)
    trig = rt.PredictiveTrigger(cost=model)
    prob, evolve = scenarios.get("adversarial-hotspot").instantiate(
        grid=8, num_nodes=4)
    kw = dict(steps=20, lb_every=5, strategy="diff-comm",
              strategy_kwargs=dict(k=2), trigger=trig)
    host = simulator.run_series(prob, evolve, scan=False, **kw)
    scan = simulator.run_series(prob, evolve, scan=True, **kw)
    np.testing.assert_array_equal(host.lb_fired, scan.lb_fired)
    # the measured gate did bite: with the cheap estimate it would fire
    # on (nearly) every eligible step; the observed volume prices most
    # of them out
    legacy = simulator.run_series(
        prob, evolve, scan=True, **{**kw, "trigger": dataclasses.replace(
            trig, measured_gate=False)})
    assert scan.lb_fired.sum() < legacy.lb_fired.sum()


# ------------------------------------------- subprocess: 8-device mesh --

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np

from repro.pic import driver
from repro.sim import scenarios, simulator

assert len(jax.devices()) == 8, jax.devices()

SERIES_FIELDS = ("max_avg", "ext_int", "migrations", "lb_fired",
                 "max_load", "migrated_load", "final_assignment")
PIC_FIELDS = ("max_avg", "ext_bytes", "int_bytes", "migrations",
              "migrated_bytes", "lb_steps", "final_x", "final_y")

# -- 1. series replay: 8-way sharded plan loop, fixed + adaptive -------
for name, trig in (("stencil-wave", None), ("bimodal-churn", "threshold"),
                   ("adversarial-hotspot", "predictive")):
    prob, evolve = scenarios.get(name).instantiate(grid=8, num_nodes=8)
    kw = dict(steps=18, lb_every=4, strategy="diff-comm",
              strategy_kwargs=dict(k=3), trigger=trig)
    ref = simulator.run_series(prob, evolve, scan=True, **kw)
    sh = simulator.run_series_sharded(prob, evolve, **kw)
    for f in SERIES_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, f)), np.asarray(getattr(sh, f)),
            err_msg=f"{name}/{f}")
    print(name, "series 8-way parity OK (fires:", int(ref.lb_fired.sum()),
          ")")

# -- 2. PIC replay: particle slabs 8-way, in-scan ring exchange --------
base = dict(L=100, n_particles=2000, steps=18, k=1, rho=0.9, cx=10,
            cy=10, num_pes=8, mapping="striped", lb_every=4,
            strategy="diff-comm", strategy_kwargs=dict(k=3), seed=0)
for trig in (None, "threshold"):
    ref = driver.run(driver.PICConfig(scan=True, trigger=trig, **base))
    sh = driver.run(driver.PICConfig(sharded_replay=True, trigger=trig,
                                     **base))
    assert ref.migrated_bytes.sum() > 0
    for f in PIC_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, f)), np.asarray(getattr(sh, f)),
            err_msg=f"pic/{trig}/{f}")
    print("pic 8-way parity OK, trigger =", trig,
          "(exchanged bytes:", int(ref.migrated_bytes.sum()), ")")

# -- 3. runtime capacity overflow: never drop payload silently ---------
try:
    driver.run(driver.PICConfig(sharded_replay=True,
                                replay_capacity=2000 // 8, **base))
    raise SystemExit("undersized replay_capacity must raise")
except ValueError as e:
    assert "replay_capacity" in str(e), e
print("runtime capacity overflow raises OK")

# -- 4. conservation under repeated 8-way exchanges --------------------
r = driver.run(driver.PICConfig(sharded_replay=True,
                                **{**base, "lb_every": 2}))
never = driver.run(driver.PICConfig(strategy="none",
                                    **{k: v for k, v in base.items()
                                       if k not in ("strategy",
                                                    "strategy_kwargs")}))
assert (r.lb_steps > 0).sum() >= 5
np.testing.assert_array_equal(r.final_x, never.final_x)
np.testing.assert_array_equal(r.final_y, never.final_y)
print("repeated-exchange conservation OK")
print("ALL OK")
"""


@pytest.mark.slow
def test_sharded_replay_on_8_virtual_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "ALL OK" in out.stdout
