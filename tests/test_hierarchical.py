"""Within-node LPT (paper §III.D): the jittable device implementation.

Covers: LPT exactness on small hand-checkable cases, the classic LPT
approximation bound against brute-force optima, empty-node and
threads>objects edge cases, bit-for-bit parity between the vectorized
device LPT and the host NumPy oracle, and the two-level wiring through
``LBEngine`` / ``run_series`` / the PIC driver.
"""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, hierarchical
from repro.pic import driver
from repro.sim import scenarios, simulator, stencil, synthetic


def _makespans(loads, assignment, thread, P, T):
    pe = np.asarray(assignment) * T + np.asarray(thread)
    return np.bincount(pe, weights=np.asarray(loads), minlength=P * T)


def _lpt(loads, assignment, P, T):
    return np.asarray(hierarchical.lpt_threads(
        np.asarray(loads, np.float32), np.asarray(assignment, np.int32),
        num_nodes=P, threads_per_node=T))


# ------------------------------------------------------------ exactness --


def test_lpt_balances_hand_checked_case_exactly():
    # [5,4,3,2,1] over 3 threads: LPT reaches the perfect 5/5/5 split
    loads = np.array([5, 4, 3, 2, 1], np.float32)
    thread = _lpt(loads, np.zeros(5, np.int32), 1, 3)
    tl = _makespans(loads, np.zeros(5, np.int32), thread, 1, 3)
    np.testing.assert_array_equal(tl, [5.0, 5.0, 5.0])


def test_lpt_descending_order_and_tie_breaks():
    # equal loads: rank r object goes to thread r (argmin lowest index),
    # and equal-load objects keep index order (stable sort)
    loads = np.ones(7, np.float32)
    thread = _lpt(loads, np.zeros(7, np.int32), 1, 3)
    np.testing.assert_array_equal(thread, [0, 1, 2, 0, 1, 2, 0])


def _brute_force_makespan(loads, T):
    best = np.inf
    for assign in itertools.product(range(T), repeat=len(loads)):
        tl = np.zeros(T)
        for load, t in zip(loads, assign):
            tl[t] += load
        best = min(best, tl.max())
    return best


def test_lpt_within_classic_bound_of_bruteforce_optimum():
    rng = np.random.default_rng(7)
    for trial in range(6):
        n, T = int(rng.integers(4, 9)), int(rng.integers(2, 4))
        loads = rng.integers(1, 20, n).astype(np.float32)
        thread = _lpt(loads, np.zeros(n, np.int32), 1, T)
        got = _makespans(loads, np.zeros(n, np.int32), thread, 1, T).max()
        opt = _brute_force_makespan(loads, T)
        # Graham's LPT bound: makespan <= (4/3 - 1/(3T)) * OPT
        assert got <= (4.0 / 3.0 - 1.0 / (3 * T)) * opt + 1e-5, (
            trial, loads, got, opt)


# ----------------------------------------------------------- edge cases --


def test_lpt_empty_node_and_uneven_nodes():
    # node 1 has no objects at all
    loads = np.array([3, 1, 2, 5], np.float32)
    assignment = np.array([0, 0, 2, 2], np.int32)
    thread = _lpt(loads, assignment, 3, 2)
    assert (thread >= 0).all() and (thread < 2).all()
    tl = _makespans(loads, assignment, thread, 3, 2)
    np.testing.assert_array_equal(tl, [3, 1, 0, 0, 5, 2])


def test_lpt_more_threads_than_objects():
    loads = np.array([2.0, 1.0], np.float32)
    thread = _lpt(loads, np.zeros(2, np.int32), 1, 8)
    # each object gets its own thread, heaviest first
    np.testing.assert_array_equal(thread, [0, 1])


def test_lpt_single_thread_is_all_zero():
    rng = np.random.default_rng(0)
    loads = rng.random(50).astype(np.float32)
    assignment = rng.integers(0, 5, 50).astype(np.int32)
    np.testing.assert_array_equal(_lpt(loads, assignment, 5, 1),
                                  np.zeros(50, np.int32))


# -------------------------------------------------- new vs old parity --


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_device_lpt_matches_host_oracle_bit_for_bit(seed):
    rng = np.random.default_rng(seed)
    N, P, T = 300, 9, 4
    loads = (rng.random(N) * 10).astype(np.float32)
    assignment = rng.integers(0, P, N).astype(np.int32)
    dev = _lpt(loads, assignment, P, T)
    host = hierarchical.within_node_lpt(loads, assignment, P, T)
    np.testing.assert_array_equal(dev, host)


def test_device_lpt_matches_host_with_ties():
    # heavy tie pressure: few distinct load values
    rng = np.random.default_rng(3)
    loads = rng.integers(1, 4, 120).astype(np.float32)
    assignment = rng.integers(0, 4, 120).astype(np.int32)
    np.testing.assert_array_equal(
        _lpt(loads, assignment, 4, 3),
        hierarchical.within_node_lpt(loads, assignment, 4, 3))


def test_flatten_hierarchy_and_thread_loads():
    loads = np.array([1, 2, 3, 4], np.float32)
    assignment = np.array([0, 1, 0, 1], np.int32)
    thread = np.array([1, 0, 0, 1], np.int32)
    pe = hierarchical.flatten_hierarchy(assignment, thread, 2)
    np.testing.assert_array_equal(pe, [1, 2, 0, 3])
    tl = np.asarray(hierarchical.thread_loads(
        loads, assignment, thread, num_nodes=2, threads_per_node=2))
    np.testing.assert_array_equal(tl, [3, 1, 2, 4])


# ------------------------------------------------------- engine wiring --


def _fixture_problem():
    prob = stencil.stencil_2d(12, 12, 9, mapping="tiled")
    return synthetic.hotspot(prob, node=0, factor=6.0)


def test_engine_plan_hier_fn_is_plan_fn_plus_lpt():
    prob = _fixture_problem()
    eng = engine.get_engine(k=4, threads_per_node=4)
    a, thread, stats = jax.jit(eng.plan_hier_fn)(prob)
    a_ref, stats_ref = jax.jit(engine.get_engine(k=4).plan_fn)(prob)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    assert int(stats.diffusion_iters) == int(stats_ref.diffusion_iters)
    thr_ref = hierarchical.lpt_threads(
        prob.loads, a, num_nodes=9, threads_per_node=4)
    np.testing.assert_array_equal(np.asarray(thread), np.asarray(thr_ref))


def test_engine_plan_emits_thread_placement_in_info():
    prob = _fixture_problem()
    plan = engine.get_engine(k=4, threads_per_node=3).plan(prob)
    assert plan.info["threads_per_node"] == 3
    thread = plan.info["thread"]
    assert thread.shape == plan.assignment.shape
    assert (thread >= 0).all() and (thread < 3).all()


def test_engine_without_threads_rejects_hier_plan():
    with pytest.raises(ValueError, match="threads_per_node"):
        engine.get_engine(k=4).plan_hier_fn(_fixture_problem())


def test_plan_hier_batch_fn_matches_per_problem():
    from repro.core import comm_graph

    probs = [synthetic.hotspot(stencil.stencil_2d(10, 10, 4), node=n,
                               factor=f)
             for n, f in [(0, 5.0), (2, 3.0)]]
    eng = engine.get_engine(k=2, threads_per_node=2)
    stacked = comm_graph.stack_problems(probs)
    a_b, t_b, _ = jax.jit(eng.plan_hier_batch_fn)(stacked)
    for b, p in enumerate(probs):
        a1, t1, _ = eng.plan_hier_fn(p)
        np.testing.assert_array_equal(np.asarray(a_b)[b], np.asarray(a1))
        np.testing.assert_array_equal(np.asarray(t_b)[b], np.asarray(t1))


# -------------------------------------------------- replay-layer wiring --


def test_run_series_thread_metrics_host_vs_scan_parity():
    problem, evolve = scenarios.get("stencil-wave").instantiate(
        grid=12, num_nodes=4)
    kw = dict(steps=12, lb_every=4, strategy="diff-comm",
              strategy_kwargs=dict(k=2), threads_per_node=4)
    host = simulator.run_series(problem, evolve, scan=False, **kw)
    scan = simulator.run_series(problem, evolve, scan=True, **kw)
    assert host.thread_max_avg is not None
    assert scan.thread_max_avg is not None
    assert scan.thread_max_avg.shape == (12,)
    np.testing.assert_allclose(host.thread_max_avg, scan.thread_max_avg,
                               rtol=1e-5)
    # thread-level imbalance can't beat perfect balance
    assert (scan.thread_max_avg >= 1.0 - 1e-5).all()


def test_run_series_without_threads_has_no_thread_series():
    problem, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    res = simulator.run_series(problem, evolve, steps=6, lb_every=3,
                               strategy="none")
    assert res.thread_max_avg is None


def test_pic_driver_thread_metrics_host_vs_scan_parity():
    base = dict(L=100, n_particles=2000, steps=12, k=1, rho=0.9, cx=8,
                cy=8, num_pes=4, mapping="striped", lb_every=5, seed=0,
                strategy="diff-comm", strategy_kwargs=dict(k=2),
                threads_per_node=2)
    host = driver.run(driver.PICConfig(scan=False, **base))
    scan = driver.run(driver.PICConfig(scan=True, **base))
    assert host.thread_max_avg is not None
    assert scan.thread_max_avg is not None
    np.testing.assert_allclose(host.thread_max_avg, scan.thread_max_avg,
                               rtol=1e-5)
    assert (scan.thread_max_avg >= 1.0 - 1e-5).all()
