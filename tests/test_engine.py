"""LBEngine / Strategy protocol / scenario registry / scanned replay.

The load-bearing regression here: the scan-compiled planning pipeline must
produce the *same plan* as the eager ``diffusion_lb`` path bit-for-bit on
a fixed seed — the device-resident engine is a compilation strategy, not a
different algorithm.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import api, comm_graph, engine, metrics
from repro.pic import driver
from repro.sim import scenarios, simulator, stencil, synthetic

LEGACY_NAMES = {"none", "diff-comm", "diff-coord", "greedy",
                "greedy-refine", "metis", "parmetis"}


def _fixture_problem():
    prob = stencil.stencil_2d(12, 12, 9, mapping="tiled")
    return synthetic.hotspot(prob, node=0, factor=6.0)


# ------------------------------------------------------------- registry --


def test_registry_keeps_all_legacy_strategies():
    assert LEGACY_NAMES <= set(engine.available())
    assert LEGACY_NAMES <= set(api.STRATEGIES)          # back-compat view
    for name in LEGACY_NAMES:
        s = engine.get_strategy(name)
        assert s.name == name
        assert isinstance(s.jittable, bool)
    assert engine.get_strategy("diff-comm").jittable
    assert not engine.get_strategy("greedy").jittable


def test_unknown_strategy_raises_with_listing():
    with pytest.raises(KeyError, match="diff-comm"):
        engine.get_strategy("nope")


def test_strategy_run_matches_run_strategy():
    prob = _fixture_problem()
    a1 = engine.get_strategy("diff-comm").run(prob, k=4).assignment
    a2 = api.run_strategy("diff-comm", prob, k=4).assignment
    np.testing.assert_array_equal(a1, a2)


def test_host_baseline_through_protocol():
    prob = _fixture_problem()
    plan = engine.get_strategy("greedy").run(prob)
    assert plan.assignment.shape == (144,)
    after = metrics.evaluate(prob, jnp.asarray(plan.assignment))
    before = metrics.evaluate(prob)
    assert after["max_avg_load"] <= before["max_avg_load"]


# ------------------------------------------------------ engine vs eager --


def test_scanned_plan_matches_eager_diffusion_bit_for_bit():
    prob = _fixture_problem()
    eager = api.diffusion_lb(prob, k=4, variant="comm").assignment

    plan = engine.get_strategy("diff-comm").bind(k=4)

    def scanned(p):
        def body(carry, _):
            a, stats = plan(carry)
            return carry, a
        _, ys = jax.lax.scan(body, p, None, length=3)
        return ys

    ys = np.asarray(jax.jit(scanned)(prob))
    for row in ys:                       # same input => same plan, each step
        np.testing.assert_array_equal(row, eager)


def test_engine_plan_stats_match_eager_info():
    prob = _fixture_problem()
    info = api.diffusion_lb(prob, k=4).info
    _, stats = jax.jit(engine.get_engine(k=4).plan_fn)(prob)
    assert int(stats.protocol_rounds) == info["protocol_rounds"]
    assert int(stats.diffusion_iters) == info["diffusion_iters"]
    assert float(stats.unrealized_flow) == pytest.approx(
        info["unrealized_flow"], rel=1e-6)


def test_zero_stats_dtypes_match_plan_stats():
    prob = _fixture_problem()
    _, stats = engine.get_engine(k=2).plan_fn(prob)
    zero = engine.zero_stats()
    for a, b in zip(stats, zero):
        assert jnp.asarray(a).dtype == jnp.asarray(b).dtype


# --------------------------------------------------------- batched path --


def test_stack_problems_pads_edges_and_stacks_leaves():
    probs = [p for _, p, _ in scenarios.batch_instances(4)]
    stacked = comm_graph.stack_problems(probs)
    E = max(p.num_edges for p in probs)
    assert stacked.loads.shape == (4,) + probs[0].loads.shape
    assert stacked.edges_src.shape == (4, E)
    assert stacked.num_nodes == probs[0].num_nodes
    # padding slots carry the standard (-1, -1, 0.0) convention
    for b, p in enumerate(probs):
        pad = np.asarray(stacked.edges_src[b, p.num_edges:])
        assert (pad == -1).all()
        assert (np.asarray(stacked.edges_bytes[b, p.num_edges:]) == 0).all()


def test_stack_problems_rejects_mixed_shapes():
    a = stencil.stencil_2d(8, 8, 4)
    b = stencil.stencil_2d(12, 12, 4)
    with pytest.raises(ValueError, match="common"):
        comm_graph.stack_problems([a, b])


def test_plan_batch_matches_per_problem_plans():
    probs = [synthetic.hotspot(stencil.stencil_2d(12, 12, 9), node=n,
                               factor=f)
             for n, f in [(0, 6.0), (3, 2.0), (5, 9.0)]]
    eng = engine.get_engine(k=4)
    plans = eng.plan_batch(probs)
    assert len(plans) == 3
    for p, plan in zip(probs, plans):
        single = api.diffusion_lb(p, k=4).assignment
        np.testing.assert_array_equal(plan.assignment, single)
        assert plan.info["batch_size"] == 3


def test_run_series_batch_matches_single_lane_replays():
    inst = scenarios.batch_instances(4, grid=8, num_nodes=4)
    kw = dict(steps=12, lb_every=4, strategy="diff-comm",
              strategy_kwargs=dict(k=2))
    bres = simulator.run_series_batch(inst, **kw)
    assert bres.batch == 4 and bres.steps == 12
    for (_, p, ev), lane in zip(inst, bres.series):
        single = simulator.run_series(p, ev, scan=True, **kw)
        np.testing.assert_allclose(single.max_avg, lane.max_avg, rtol=1e-4)
        np.testing.assert_allclose(single.ext_int, lane.ext_int, rtol=1e-4)
        np.testing.assert_allclose(single.migrations, lane.migrations,
                                   atol=1e-6)


def test_run_series_batch_rejects_host_strategy():
    inst = scenarios.batch_instances(2, grid=8, num_nodes=4)
    with pytest.raises(ValueError, match="jittable"):
        simulator.run_series_batch(inst, steps=4, lb_every=2,
                                   strategy="greedy")


# -------------------------------------------------------- scanned replay --


def test_run_series_scanned_matches_host_loop():
    problem, evolve = scenarios.get("stencil-wave").instantiate(
        grid=12, num_nodes=4)
    kw = dict(steps=24, lb_every=6, strategy="diff-comm",
              strategy_kwargs=dict(k=3))
    host = simulator.run_series(problem, evolve, scan=False, **kw)
    scan = simulator.run_series(problem, evolve, scan=True, **kw)
    assert scan.scanned and not host.scanned
    np.testing.assert_allclose(host.max_avg, scan.max_avg, rtol=1e-4)
    np.testing.assert_allclose(host.ext_int, scan.ext_int, rtol=1e-4)
    np.testing.assert_allclose(host.migrations, scan.migrations, atol=1e-6)


def test_run_series_none_strategy_scans():
    problem, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    res = simulator.run_series(problem, evolve, steps=10, lb_every=3,
                               strategy="none")
    assert res.scanned
    assert (res.migrations == 0).all()


def test_run_series_host_fallback_for_numpy_baseline():
    problem, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    res = simulator.run_series(problem, evolve, steps=10, lb_every=3,
                               strategy="greedy-refine")
    assert not res.scanned
    assert np.isfinite(res.max_avg).all()


# ------------------------------------------------------ scenario registry --

SMALL = {
    "stencil-wave": dict(grid=8, num_nodes=4),
    "pic-geometric": dict(cx=6, cy=6, num_pes=4, n_particles=1000.0),
    "adversarial-hotspot": dict(grid=8, num_nodes=4),
    "bimodal-churn": dict(grid=8, num_nodes=4),
}


def test_scenario_registry_has_required_workloads():
    assert {"stencil-wave", "pic-geometric", "adversarial-hotspot",
            "bimodal-churn"} <= set(scenarios.available())


@pytest.mark.parametrize("name", sorted(SMALL))
def test_scenario_evolve_is_scan_safe_and_shape_stable(name):
    problem, evolve = scenarios.get(name).instantiate(**SMALL[name])
    assert getattr(evolve, "jittable", False)
    p1 = jax.jit(lambda p, t: evolve(p, t))(problem, jnp.int32(3))
    assert p1.loads.shape == problem.loads.shape
    assert p1.loads.dtype == jnp.float32
    assert np.isfinite(np.asarray(p1.loads)).all()
    res = simulator.run_series(problem, evolve, steps=12, lb_every=4,
                               strategy="diff-comm",
                               strategy_kwargs=dict(k=2))
    assert res.scanned
    assert np.isfinite(res.max_avg).all()


def test_scenario_evolve_is_deterministic_in_t():
    problem, evolve = scenarios.get("bimodal-churn").instantiate(
        **SMALL["bimodal-churn"])
    a = np.asarray(evolve(problem, 7).loads)
    b = np.asarray(evolve(problem, 7).loads)
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------ PIC driver --


def test_pic_scanned_matches_host_loop():
    base = dict(L=100, n_particles=2000, steps=20, k=1, rho=0.9, cx=8,
                cy=8, num_pes=4, mapping="striped", lb_every=6, seed=0,
                strategy="diff-comm", strategy_kwargs=dict(k=2))
    host = driver.run(driver.PICConfig(scan=False, **base))
    scan = driver.run(driver.PICConfig(scan=True, **base))
    assert scan.scanned and not host.scanned
    np.testing.assert_allclose(host.max_avg, scan.max_avg, rtol=1e-5)
    np.testing.assert_allclose(host.ext_bytes, scan.ext_bytes, rtol=1e-5)
    np.testing.assert_allclose(host.migrations, scan.migrations, atol=1e-6)
    np.testing.assert_allclose(host.migrated_bytes, scan.migrated_bytes,
                               rtol=1e-5)
    np.testing.assert_allclose(host.final_x, scan.final_x, atol=1e-3)


def test_pic_sweep_chunk_config_is_result_invariant():
    """PICConfig.sweep_chunk reaches the planner through strategy_kwargs
    and must not change any trajectory (chunking is bit-for-bit)."""
    base = dict(L=100, n_particles=2000, steps=16, k=1, rho=0.9, cx=8,
                cy=8, num_pes=4, mapping="striped", lb_every=5, seed=0,
                strategy="diff-comm", strategy_kwargs=dict(k=2), scan=True)
    r_def = driver.run(driver.PICConfig(**base))
    r_chk = driver.run(driver.PICConfig(sweep_chunk=32, **base))
    np.testing.assert_array_equal(r_def.max_avg, r_chk.max_avg)
    np.testing.assert_array_equal(r_def.migrations, r_chk.migrations)
    np.testing.assert_array_equal(r_def.final_x, r_chk.final_x)


def test_pic_scan_chunking_invariant():
    base = dict(L=100, n_particles=2000, steps=17, k=1, rho=0.9, cx=8,
                cy=8, num_pes=4, mapping="striped", lb_every=5, seed=0,
                strategy="diff-comm", strategy_kwargs=dict(k=2), scan=True)
    r1 = driver.run(driver.PICConfig(scan_chunk=5, **base))
    r2 = driver.run(driver.PICConfig(scan_chunk=50, **base))
    np.testing.assert_allclose(r1.max_avg, r2.max_avg, rtol=1e-6)
    np.testing.assert_allclose(r1.migrations, r2.migrations, atol=1e-7)
    np.testing.assert_allclose(r1.final_x, r2.final_x, atol=1e-4)
