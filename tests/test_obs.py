"""Observability contracts (obs/telemetry, obs/metrics, obs/trace_export).

The load-bearing guarantees:

  * **Off is free** — on every replay path, passing ``telemetry="off"``
    (or no config at all) leaves the replay **bit-for-bit** identical to
    the pre-telemetry path and attaches no snapshot.  The off/absent
    configs normalize to the same runner-cache key, so the compiled
    program is literally the same executable.
  * **Recording is passive** — ``level="full"`` changes no replay output
    either; it only adds the scan-carried StepRecord ring.
  * The ring wraps correctly (keeps the *last* ``ring`` records in
    chronological order, counts drops), counters are monotone, and the
    exported Chrome trace passes the shared format validator that the
    CI observability step runs.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.obs import metrics as obs_metrics
from repro.obs import telemetry as obs
from repro.obs import trace_export
from repro.pic import driver
from repro.serve import replay as serve_replay
from repro.sim import scenarios, simulator
from repro.train import ep_runtime

SERIES_FIELDS = ("max_avg", "ext_int", "migrations", "lb_fired",
                 "max_load", "migrated_load", "final_assignment")
PIC_FIELDS = ("max_avg", "ext_bytes", "int_bytes", "migrations",
              "migrated_bytes", "lb_steps", "final_x", "final_y")
SERVE_FIELDS = ("max_avg", "lb_fired", "moved_sessions", "moved_kv_bytes",
                "prefix_local", "deferred", "occ_max", "final_uid",
                "final_replica", "final_kv")
EP_FIELDS = ("max_avg", "lb_fired", "moved_experts", "moved_bytes",
             "final_placement", "final_slot_expert", "final_wsig")


def _assert_bitwise(ref, got, fields):
    for f in fields:
        a, b = getattr(ref, f), getattr(got, f)
        if a is None and b is None:
            continue
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"telemetry changed replay output {f}")


def _sim_case():
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    kw = dict(steps=14, lb_every=4, strategy="diff-comm",
              strategy_kwargs=dict(k=2))
    return prob, evolve, kw


# --------------------------------------- off-parity: every replay path --


@pytest.mark.parametrize("scan", [True, False])
def test_sim_off_parity(scan):
    prob, evolve, kw = _sim_case()
    base = simulator.run_series(prob, evolve, scan=scan, **kw)
    off = simulator.run_series(prob, evolve, scan=scan, telemetry="off",
                               **kw)
    absent = simulator.run_series(prob, evolve, scan=scan, telemetry=None,
                                  **kw)
    assert off.telemetry is None and absent.telemetry is None
    _assert_bitwise(base, off, SERIES_FIELDS)
    _assert_bitwise(base, absent, SERIES_FIELDS)


def test_sim_sharded_off_parity():
    prob, evolve, kw = _sim_case()
    base = simulator.run_series_sharded(prob, evolve, **kw)
    off = simulator.run_series_sharded(prob, evolve, telemetry="off", **kw)
    assert off.telemetry is None
    _assert_bitwise(base, off, SERIES_FIELDS)


def _pic_cfg(**kw):
    base = dict(L=100, n_particles=2000, steps=12, k=1, rho=0.9, cx=10,
                cy=10, num_pes=4, mapping="striped", lb_every=4,
                strategy="diff-comm", strategy_kwargs=dict(k=2), seed=0)
    base.update(kw)
    return driver.PICConfig(**base)


@pytest.mark.parametrize("path_kw", [dict(scan=True),
                                     dict(sharded_replay=True)])
def test_pic_off_parity(path_kw):
    base = driver.run(_pic_cfg(**path_kw))
    off = driver.run(_pic_cfg(telemetry="off", **path_kw))
    assert off.telemetry is None
    _assert_bitwise(base, off, PIC_FIELDS)


def test_serve_off_parity():
    w = serve_replay.ServeWorkload(num_sessions=32, num_replicas=4)
    kw = dict(steps=16, lb_every=4)
    base = serve_replay.run_serve_replay(w, **kw)
    off = serve_replay.run_serve_replay(w, telemetry="off", **kw)
    assert off.telemetry is None
    _assert_bitwise(base, off, SERVE_FIELDS)


def test_ep_off_parity():
    w = ep_runtime.RoutingWorkload(num_experts=16, num_ranks=4)
    kw = dict(steps=12, lb_every=4)
    base = ep_runtime.run_ep_replay(w, **kw)
    off = ep_runtime.run_ep_replay(w, telemetry="off", **kw)
    assert off.telemetry is None
    _assert_bitwise(base, off, EP_FIELDS)


# ------------------------------------ recording is passive + complete --


@pytest.mark.parametrize("level", ["counters", "full"])
def test_sim_full_recording_is_passive(level):
    prob, evolve, kw = _sim_case()
    base = simulator.run_series(prob, evolve, scan=True, **kw)
    rec = simulator.run_series(prob, evolve, scan=True, telemetry=level,
                               **kw)
    _assert_bitwise(base, rec, SERIES_FIELDS)
    snap = rec.telemetry
    assert snap is not None and snap.config.level == level
    assert snap.steps_total == kw["steps"] and snap.dropped == 0
    assert snap.records.shape == (kw["steps"], len(obs.FIELDS))
    np.testing.assert_array_equal(snap.column("t"),
                                  np.arange(kw["steps"]))
    np.testing.assert_array_equal(snap.column("fired"),
                                  np.asarray(base.lb_fired, np.float32))
    if level == "full":
        assert snap.node_loads.shape == (kw["steps"], prob.num_nodes)
        # per-node lanes sum to the workload the aggregates describe
        avg = snap.node_loads.mean(axis=1)
        np.testing.assert_allclose(avg, snap.column("avg_load"),
                                   rtol=1e-5)
    else:
        assert snap.node_loads is None


def test_sharded_full_matches_scanned_records():
    prob, evolve, kw = _sim_case()
    ref = simulator.run_series(prob, evolve, scan=True, telemetry="full",
                               **kw)
    sh = simulator.run_series_sharded(prob, evolve, telemetry="full", **kw)
    np.testing.assert_array_equal(ref.telemetry.records,
                                  sh.telemetry.records)
    np.testing.assert_array_equal(ref.telemetry.node_loads,
                                  sh.telemetry.node_loads)


@pytest.mark.parametrize("make", [
    lambda: serve_replay.run_serve_replay(
        serve_replay.ServeWorkload(num_sessions=32, num_replicas=4),
        steps=16, lb_every=4, telemetry="full"),
    lambda: ep_runtime.run_ep_replay(
        ep_runtime.RoutingWorkload(num_experts=16, num_ranks=4,
                                   hot_amp=6.0, drift_period=4,
                                   alpha=1.5),
        steps=20, lb_every=3, telemetry="full"),
    lambda: driver.run(_pic_cfg(scan=True, telemetry="full")),
])
def test_full_snapshot_on_other_paths(make):
    res = make()
    snap = res.telemetry
    assert snap is not None and snap.dropped == 0
    fired = (res.lb_fired if hasattr(res, "lb_fired")
             else res.lb_steps)
    assert snap.column("fired").sum() == np.asarray(fired).sum() > 0
    assert (snap.column("moved_items") > 0).any()


# ------------------------------------------------- config resolution --


def test_resolve_levels():
    assert not obs.resolve(None).enabled
    assert not obs.resolve("off").enabled
    c = obs.resolve("counters")
    assert c.enabled and not c.full
    f = obs.resolve("full")
    assert f.enabled and f.full
    cfg = obs.TelemetryConfig(level="full", ring=7)
    assert obs.resolve(cfg) is cfg
    with pytest.raises(ValueError):
        obs.resolve("verbose")
    with pytest.raises(ValueError):
        obs.TelemetryConfig(level="full", ring=0)


# --------------------------------------------- ring wraparound (prop) --


@settings(max_examples=30, deadline=None)
@given(steps=st.integers(min_value=1, max_value=40),
       ring=st.integers(min_value=1, max_value=17))
def test_ring_keeps_last_records_chronologically(steps, ring):
    cfg = obs.TelemetryConfig(level="full", ring=ring)
    P = 3
    state = obs.init_state(cfg, P)
    for t in range(steps):
        state = obs.record(
            state, cfg, t=jnp.int32(t),
            node_loads=jnp.arange(P, dtype=jnp.float32) + t,
            fired=jnp.float32(t % 2), sweeps=jnp.float32(t))
    snap = obs.snapshot(state, cfg)
    kept = min(steps, ring)
    assert snap.steps_total == steps
    assert snap.dropped == max(0, steps - ring)
    assert snap.records.shape == (kept, len(obs.FIELDS))
    expect_t = np.arange(steps)[-kept:]
    np.testing.assert_array_equal(snap.column("t"), expect_t)
    np.testing.assert_array_equal(snap.column("sweeps"), expect_t)
    # node-load lanes wrap with the same chronology
    np.testing.assert_array_equal(
        snap.node_loads[:, 0], expect_t.astype(np.float32))


# ------------------------------------------------- metrics registry --


@settings(max_examples=30, deadline=None)
@given(n=st.integers(min_value=1, max_value=50),
       inc=st.integers(min_value=0, max_value=9))
def test_counter_monotone(n, inc):
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("x")
    prev = c.value
    assert prev == 0
    for _ in range(n):
        c.inc(inc)
        assert c.value >= prev        # monotone under any inc sequence
        prev = c.value
    assert c.value == n * inc


def test_counter_rejects_negative_and_gauge_does_not():
    reg = obs_metrics.MetricsRegistry()
    with pytest.raises(ValueError):
        reg.counter("x").inc(-1)
    reg.gauge("g").set(-5.0)
    assert reg.snapshot()["g"] == -5.0


def test_registry_snapshot_and_reset():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("a").inc()
    reg.counter("a").inc(2)           # same name → same counter
    reg.gauge("b").set(1.5)
    assert reg.snapshot() == {"a": 3, "b": 1.5}
    reg.reset()
    assert reg.snapshot() == {}


def test_default_registry_helpers():
    obs_metrics.reset()
    obs_metrics.counter("t/c").inc(4)
    obs_metrics.gauge("t/g").set(2.0)
    snap = obs_metrics.snapshot()
    assert snap["t/c"] == 4 and snap["t/g"] == 2.0
    obs_metrics.reset()
    assert "t/c" not in obs_metrics.snapshot()


# ----------------------------------------------------- trace export --


def _full_snapshot():
    prob, evolve, kw = _sim_case()
    res = simulator.run_series(prob, evolve, scan=True, telemetry="full",
                               **kw)
    assert res.lb_fired.sum() > 0
    return res


def test_chrome_trace_valid_and_complete(tmp_path):
    res = _full_snapshot()
    path = tmp_path / "trace.json"
    trace = trace_export.export_chrome_trace(res.telemetry,
                                             path=str(path),
                                             label="test-replay")
    assert trace_export.validate_chrome_trace(trace) == []
    reread = json.loads(path.read_text())
    assert trace_export.validate_chrome_trace(reread) == []

    ev = trace["traceEvents"]
    names = [e["name"] for e in ev]
    # per-node load lanes (full level), fire instants, step slices
    assert "node/000 load" in names and "node/003 load" in names
    fires = [e for e in ev if e["name"] == "lb-fire"]
    assert len(fires) == int(res.lb_fired.sum())
    slices = [e for e in ev if e["ph"] == "X" and
              e["name"].startswith("step ")]
    assert len(slices) == len(res.telemetry.records)
    # migrations exported as matched flow pairs
    starts = [e for e in ev if e["ph"] == "s"]
    finishes = [e for e in ev if e["ph"] == "f"]
    assert len(starts) == len(finishes) > 0
    assert trace["otherData"]["telemetry_level"] == "full"
    assert trace["otherData"]["dropped"] == 0


def test_counters_level_trace_uses_aggregate_lanes():
    prob, evolve, kw = _sim_case()
    res = simulator.run_series(prob, evolve, scan=True,
                               telemetry="counters", **kw)
    trace = trace_export.export_chrome_trace(res.telemetry)
    assert trace_export.validate_chrome_trace(trace) == []
    names = {e["name"] for e in trace["traceEvents"]}
    assert "max_load" in names and "p95_load" in names
    assert not any(n.startswith("node/") for n in names)


def test_validator_flags_corruption():
    res = _full_snapshot()
    trace = trace_export.export_chrome_trace(res.telemetry)

    bad = json.loads(json.dumps(trace))
    del [e for e in bad["traceEvents"] if e["ph"] != "M"][0]["ts"]
    assert any("missing 'ts'" in e for e in
               trace_export.validate_chrome_trace(bad))

    bad = json.loads(json.dumps(trace))
    bad["traceEvents"].append({"name": "migration", "ph": "s",
                               "id": 999_999, "pid": 0, "tid": 1,
                               "ts": bad["traceEvents"][-1]["ts"]})
    assert any("flow id 999999" in e for e in
               trace_export.validate_chrome_trace(bad))

    assert trace_export.validate_chrome_trace({}) != []
    assert trace_export.validate_chrome_trace(
        {"traceEvents": []}) != []
