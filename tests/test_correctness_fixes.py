"""Correctness sweep regressions: engine cache keying, the ext/int comm
sentinel, and seeded "random" stencil mappings (ISSUE 3 satellites)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, metrics
from repro.core.comm_graph import make_problem
from repro.sim import scenarios, stencil


# ------------------------------------------------------- engine cache --


def test_get_engine_positional_and_keyword_share_one_entry():
    e1 = engine.get_engine("comm", 6)
    e2 = engine.get_engine(variant="comm", k=6)
    e3 = engine.get_engine(k=6)            # variant defaults to "comm"
    assert e1 is e2 is e3


def test_get_engine_numeric_spelling_shares_one_entry():
    # int/float spellings of the same config must not compile twice
    assert engine.get_engine(k=7, tol=0.02) is engine.get_engine(
        k=7.0, tol=0.02)


class _UnhashableStep:
    """A callable planner step that cannot be hashed (regression: the old
    ``lru_cache`` raised TypeError for such step_fns)."""
    __hash__ = None

    def __call__(self, *args):
        from repro.core import virtual_lb
        return virtual_lb.reference_sweep(*args)


def test_get_engine_accepts_unhashable_step_fn():
    step = _UnhashableStep()
    with pytest.raises(TypeError):
        hash(step)
    e1 = engine.get_engine(k=3, step_fn=step)
    assert e1 is engine.get_engine(k=3, step_fn=step)   # keyed by identity
    assert e1.step_fn is step


def test_get_engine_rejects_bad_arguments():
    with pytest.raises(TypeError, match="unexpected"):
        engine.get_engine(bogus=1)
    with pytest.raises(TypeError, match="multiple values"):
        engine.get_engine("comm", variant="comm")


# --------------------------------------------------- ext/int sentinel --


def _two_node_problem(edges, edge_bytes):
    return make_problem(
        loads=[1.0, 1.0], assignment=[0, 1], edges=edges,
        edge_bytes=edge_bytes, num_nodes=2)


def test_ext_int_all_external_returns_finite_sentinel():
    # the only edge crosses the node boundary: internal bytes == 0 — the
    # old epsilon division produced ~1e30 garbage
    prob = _two_node_problem([[0, 1]], [8.0])
    m = metrics.evaluate(prob)
    assert m["ext_int_comm"] == metrics.EXT_INT_ALL_EXTERNAL
    assert all(np.isfinite(v) for v in m.values())
    d = metrics.evaluate_device(prob)
    assert float(d.ext_int_comm) == metrics.EXT_INT_ALL_EXTERNAL


def test_ext_int_no_comm_at_all_is_zero():
    prob = _two_node_problem(np.zeros((0, 2), np.int32), np.zeros(0))
    m = metrics.evaluate(prob)
    assert m["ext_int_comm"] == 0.0


def test_ext_int_normal_ratio_unchanged():
    # one internal (node 0) + one external edge: ratio = 4/2
    prob = make_problem(
        loads=[1.0, 1.0, 1.0], assignment=[0, 0, 1],
        edges=[[0, 1], [1, 2]], edge_bytes=[2.0, 4.0], num_nodes=2)
    m = metrics.evaluate(prob)
    assert m["ext_int_comm"] == pytest.approx(2.0)


# ----------------------------------------------- seeded random mapping --


def test_stencil_2d_random_mapping_seed_varies():
    a0 = np.asarray(stencil.stencil_2d(8, 8, 4, mapping="random").assignment)
    a0b = np.asarray(
        stencil.stencil_2d(8, 8, 4, mapping="random", seed=0).assignment)
    a1 = np.asarray(
        stencil.stencil_2d(8, 8, 4, mapping="random", seed=1).assignment)
    np.testing.assert_array_equal(a0, a0b)   # default seed=0 == legacy
    assert (a0 != a1).any()                  # different seed, new instance
    legacy = np.random.default_rng(0).integers(0, 4, 64).astype(np.int32)
    np.testing.assert_array_equal(a0, legacy)


def test_stencil_3d_random_mapping_seed_varies():
    a0 = np.asarray(
        stencil.stencil_3d(4, 4, 4, 4, mapping="random").assignment)
    a2 = np.asarray(
        stencil.stencil_3d(4, 4, 4, 4, mapping="random", seed=2).assignment)
    legacy = np.random.default_rng(0).integers(0, 4, 64).astype(np.int32)
    np.testing.assert_array_equal(a0, legacy)
    assert (a0 != a2).any()


def test_scenario_registry_threads_seed_to_random_mapping():
    for name in ("stencil-wave", "adversarial-hotspot", "bimodal-churn"):
        p1, _ = scenarios.get(name).instantiate(
            grid=8, num_nodes=4, mapping="random", seed=1)
        p2, _ = scenarios.get(name).instantiate(
            grid=8, num_nodes=4, mapping="random", seed=2)
        assert (np.asarray(p1.assignment) != np.asarray(p2.assignment)).any(), \
            name


def test_scenario_default_seed_keeps_legacy_instances():
    # default parameters are unchanged: the memoized instance for the
    # registry defaults must still be the legacy deterministic one
    p, _ = scenarios.get("stencil-wave").instantiate(grid=8, num_nodes=4)
    q = stencil.stencil_2d(8, 8, 4, mapping="tiled")
    np.testing.assert_array_equal(np.asarray(p.assignment),
                                  np.asarray(q.assignment))
