"""Serving replay: scan-compiled continuous batching with executed KV moves.

The load-bearing guarantees:

  * the scanned serving replay (``lax.scan`` whole loop) is **bit-for-bit**
    the eager host loop — trigger fire steps, per-tick moved KV bytes,
    imbalance metrics and the final per-session placement — for every
    jittable strategy and trigger policy;
  * the sharded path (fired exchanges as ``ppermute`` ring all-to-alls
    under ``shard_map``, strict layout contract) reproduces the same
    trajectory (in-process tests degrade to a 1-device mesh; the
    subprocess test forces an 8-virtual-device mesh);
  * every exchange conserves the session population and their KV payload
    exactly — identity lives in ``uid``, not the slot index;
  * ``slot_capacity`` degrades gracefully: per-replica occupancy stays
    within budget and overflow moves defer in place (never dropped);
  * the ``serving-trace`` scenario adapts a recorded trace to the
    simulator registry with the same host/scan/sharded parity contract.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.runtime.cost import RuntimeCostModel
from repro.runtime.triggers import PredictiveTrigger
from repro.serve import replay as sr

PARITY_FIELDS = ("max_avg", "lb_fired", "moved_sessions", "moved_kv_bytes",
                 "prefix_local", "deferred", "occ_max")


def _wl(**kw):
    base = dict(num_sessions=48, num_replicas=4, group_size=4,
                turn_period=6, turn_len=3, burst_period=7, seed=0)
    base.update(kw)
    return sr.ServeWorkload(**base)


def _assert_parity(ref, got):
    for f in PARITY_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, f)), np.asarray(getattr(got, f)),
            err_msg=f"serving replay diverged on {f}")
    np.testing.assert_array_equal(ref.final_replica_by_uid,
                                  got.final_replica_by_uid)
    np.testing.assert_array_equal(np.sort(ref.final_uid),
                                  np.sort(got.final_uid))


# ------------------------------------------------------ host/scan parity --


@pytest.mark.parametrize("trigger", [None, "every", "threshold"])
def test_scan_matches_host(trigger):
    w = _wl()
    kw = dict(steps=24, lb_every=6, strategy="diff-comm", trigger=trigger)
    scan = sr.run_serve_replay(w, scan=True, **kw)
    host = sr.run_serve_replay(w, scan=False, **kw)
    assert scan.scanned and not host.scanned
    assert scan.lb_fired.sum() > 0
    _assert_parity(scan, host)


def test_scan_matches_host_predictive_measured_gate():
    w = _wl()
    trig = PredictiveTrigger(cost=RuntimeCostModel(bytes_per_load=8.0))
    kw = dict(steps=30, lb_every=5, strategy="diff-comm+predictive",
              trigger=trig)
    scan = sr.run_serve_replay(w, scan=True, **kw)
    host = sr.run_serve_replay(w, scan=False, **kw)
    assert scan.lb_fired.sum() > 0
    _assert_parity(scan, host)


def test_every_trigger_fires_on_legacy_cadence():
    w = _wl()
    r = sr.run_serve_replay(w, steps=25, lb_every=10, strategy="diff-comm",
                            trigger="every")
    assert list(np.flatnonzero(r.lb_fired)) == [10, 20]


# ----------------------------------------------------------- conservation --


def test_exchanges_conserve_sessions_and_kv():
    w = _wl(num_sessions=64)
    r = sr.run_serve_replay(w, steps=20, lb_every=4, strategy="diff-comm",
                            trigger="every")
    assert r.lb_fired.sum() >= 4 and r.total_moved_kv > 0
    S = w.num_sessions
    # the slots always hold a permutation of the session population
    np.testing.assert_array_equal(np.sort(r.final_uid), np.arange(S))
    # KV payload is conserved by the exchange: final total == initial
    # total + the deterministic per-tick decode growth (moves add zero)
    import jax.numpy as jnp
    uid0 = jnp.arange(S)
    expect = float(np.asarray(w.kv0_of(uid0)).sum()) + sum(
        w.kv_per_token * float(np.asarray(w.loads_at(t, uid0)).sum())
        for t in range(20))
    assert float(r.final_kv.sum()) == pytest.approx(expect, rel=1e-5)


def test_no_lb_keeps_initial_block_placement():
    w = _wl()
    r = sr.run_serve_replay(w, steps=8, strategy="none")
    assert r.lb_fired.sum() == 0 and r.total_moved_kv == 0
    S, R = w.num_sessions, w.num_replicas
    np.testing.assert_array_equal(
        r.final_replica_by_uid, (np.arange(S) * R) // S)


# -------------------------------------------------------------- capacity --


def test_slot_capacity_bounds_occupancy_and_defers():
    w = _wl(num_sessions=64, num_replicas=4)
    cap = 18                      # tight: 64 sessions / 4 replicas = 16
    kw = dict(steps=24, lb_every=4, strategy="diff-comm", trigger="every",
              slot_capacity=cap)
    r = sr.run_serve_replay(w, scan=True, **kw)
    assert r.occ_max.max() <= cap
    assert np.sort(r.final_uid).tolist() == list(range(64))  # none dropped
    host = sr.run_serve_replay(w, scan=False, **kw)
    _assert_parity(r, host)
    # an unconstrained run of the same workload does exceed the budget at
    # some tick — i.e. the clamp above actually bit
    free = sr.run_serve_replay(
        w, steps=24, lb_every=4, strategy="diff-comm", trigger="every")
    assert free.occ_max.max() > cap or free.deferred.sum() == 0


# ------------------------------------------------------------ trace replay --


def test_trace_workload_reproduces_its_source():
    w = _wl()
    tw = sr.record_trace(w, steps=20)
    kw = dict(steps=20, lb_every=5, strategy="diff-comm", trigger="every")
    ref = sr.run_serve_replay(w, scan=True, **kw)
    got = sr.run_serve_replay(tw, scan=True, **kw)
    _assert_parity(ref, got)
    # trace host path agrees too
    host = sr.run_serve_replay(tw, scan=False, **kw)
    _assert_parity(got, host)


def test_trace_loops_past_its_length():
    w = _wl()
    tw = sr.record_trace(w, steps=6)   # shorter than the replay
    r = sr.run_serve_replay(tw, steps=15, lb_every=5, strategy="diff-comm")
    assert np.isfinite(r.max_avg).all()


# ------------------------------------------------------- host baselines --


def test_greedy_baseline_executes_real_exchanges():
    w = _wl()
    r = sr.run_serve_replay(w, steps=18, lb_every=6, strategy="greedy",
                            trigger="every")
    assert not r.scanned               # host-only strategy
    assert r.lb_fired.sum() > 0 and r.total_moved_kv > 0
    np.testing.assert_array_equal(np.sort(r.final_uid),
                                  np.arange(w.num_sessions))


def test_scan_rejects_host_only_strategy():
    with pytest.raises(ValueError, match="not jittable"):
        sr.run_serve_replay(_wl(), steps=4, strategy="greedy", scan=True)


def test_sharded_rejects_scan_true():
    with pytest.raises(ValueError, match="host-driven"):
        sr.run_serve_replay(_wl(), steps=4, strategy="diff-comm",
                            scan=True, num_shards=2)


# ------------------------------------------------------------ sharded path --


def test_sharded_matches_scanned_single_shard():
    w = _wl()
    kw = dict(steps=20, lb_every=5, strategy="diff-comm", trigger="every")
    ref = sr.run_serve_replay(w, scan=True, **kw)
    sh = sr.run_serve_replay(w, num_shards=1, **kw)
    assert sh.sharded and not sh.scanned
    assert ref.lb_fired.sum() > 0
    _assert_parity(ref, sh)


# -------------------------------------------------- serving-trace scenario --


def test_serving_trace_scenario_parity():
    from repro.sim import scenarios, simulator

    prob, evolve = scenarios.get("serving-trace").instantiate(
        num_sessions=64, num_replicas=4, trace_len=24)
    prob.validate()
    kw = dict(steps=18, lb_every=6, strategy="diff-comm",
              strategy_kwargs=dict(k=2))
    scan = simulator.run_series(prob, evolve, scan=True, **kw)
    host = simulator.run_series(prob, evolve, scan=False, **kw)
    for f in ("max_avg", "lb_fired", "migrations", "migrated_load",
              "final_assignment"):
        np.testing.assert_array_equal(
            np.asarray(getattr(scan, f)), np.asarray(getattr(host, f)),
            err_msg=f"serving-trace scenario diverged on {f}")
    sh = simulator.run_series_sharded(prob, evolve, **kw)
    np.testing.assert_array_equal(scan.max_avg, sh.max_avg)
    np.testing.assert_array_equal(scan.final_assignment,
                                  sh.final_assignment)


# ------------------------------------------- subprocess: 8-device mesh --

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np

from repro.serve import replay as sr

assert len(jax.devices()) == 8, jax.devices()

FIELDS = ("max_avg", "lb_fired", "moved_sessions", "moved_kv_bytes",
          "prefix_local", "deferred", "occ_max")

w = sr.ServeWorkload(num_sessions=256, num_replicas=16, group_size=4,
                     turn_period=6, turn_len=3, burst_period=7, seed=0)
for trig in ("every", "threshold"):
    kw = dict(steps=20, lb_every=5, strategy="diff-comm", trigger=trig)
    ref = sr.run_serve_replay(w, scan=True, **kw)
    sh = sr.run_serve_replay(w, num_shards=8, **kw)
    assert sh.sharded and ref.lb_fired.sum() > 0
    for f in FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, f)), np.asarray(getattr(sh, f)),
            err_msg=f"{trig}/{f}")
    np.testing.assert_array_equal(ref.final_replica_by_uid,
                                  sh.final_replica_by_uid)
    np.testing.assert_array_equal(np.sort(sh.final_uid), np.arange(256))
    print("serve 8-way parity OK, trigger =", trig,
          "(moved KV:", int(ref.total_moved_kv), ")")
print("ALL OK")
"""


@pytest.mark.slow
def test_serve_sharded_replay_on_8_virtual_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "ALL OK" in out.stdout
