"""Serving: engine continuous batching + diffusion request scheduling."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer
from repro.models.params import init_params
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.scheduler import (DiffusionScheduler, Session, fleet_loads,
                                   fleet_problem, prefix_locality)


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_arch("smollm-135m").reduced
    params = init_params(transformer.model_specs(cfg), 0)
    return cfg, params


def test_engine_drains_all_requests(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, ServeConfig(num_slots=2, max_len=64))
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(1, cfg.vocab_size, 4 + i),
                           max_new_tokens=6))
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out) == 6 for r in done)


def test_engine_continuous_batching_joins_mid_flight(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, ServeConfig(num_slots=2, max_len=64))
    rng = np.random.default_rng(1)
    eng.submit(Request(uid=0, prompt=rng.integers(1, cfg.vocab_size, 4),
                       max_new_tokens=10))
    eng.tick()
    eng.tick()
    # join while request 0 is mid-decode
    eng.submit(Request(uid=1, prompt=rng.integers(1, cfg.vocab_size, 4),
                       max_new_tokens=3))
    done = eng.run_until_drained()
    assert {r.uid for r in done} == {0, 1}


def test_engine_decode_matches_dedicated_decode(engine_setup):
    """Engine output for a single request == plain prefill+decode_step."""
    import jax.numpy as jnp
    cfg, params = engine_setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab_size, 6)

    eng = ServeEngine(cfg, params, ServeConfig(num_slots=1, max_len=32))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    out_engine = eng.run_until_drained()[0].out

    cache = transformer.init_cache(cfg, 1, 32, jnp.float32)
    batch = dict(tokens=jnp.asarray(prompt[None], jnp.int32),
                 positions=jnp.arange(len(prompt), dtype=jnp.int32)[None])
    logits, cache = transformer.prefill(params, cfg, batch, cache)
    toks = [int(np.argmax(np.asarray(logits[0, -1])))]
    for i in range(4):
        l, cache = transformer.decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.int32(len(prompt) + i), cache)
        toks.append(int(np.argmax(np.asarray(l[0, 0]))))
    assert out_engine == toks


def test_engine_eos_at_admission_frees_slot_for_next_request(engine_setup):
    # the prefill-produced first token can already be terminal
    # (max_new_tokens=1): the request must finish at admission and the
    # slot must be reused for the next queued request in the same pass
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, ServeConfig(num_slots=1, max_len=64))
    rng = np.random.default_rng(3)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=rng.integers(1, cfg.vocab_size, 4),
                           max_new_tokens=1))
    eng.submit(Request(uid=3, prompt=rng.integers(1, cfg.vocab_size, 4),
                       max_new_tokens=4))
    eng._admit()
    # the three one-token requests completed without occupying the slot;
    # the fourth holds it with its prefill token pending decode
    assert {r.uid for r in eng.done} == {0, 1, 2}
    assert all(len(r.out) == 1 for r in eng.done)
    assert eng.slot_req[0] is not None and eng.slot_req[0].uid == 3
    done = eng.run_until_drained()
    assert {r.uid for r in done} == {0, 1, 2, 3}
    assert len([r for r in done if r.uid == 3][0].out) == 4


def test_engine_eos_token_at_prefill_terminates(engine_setup):
    # eos_id == the argmax first token ⇒ done at admission, no decode tick
    cfg, params = engine_setup
    rng = np.random.default_rng(4)
    prompt = rng.integers(1, cfg.vocab_size, 5)
    probe = ServeEngine(cfg, params, ServeConfig(num_slots=1, max_len=64))
    probe.submit(Request(uid=0, prompt=prompt, max_new_tokens=1))
    first = probe.run_until_drained()[0].out[0]

    eng = ServeEngine(cfg, params, ServeConfig(num_slots=1, max_len=64))
    eng.submit(Request(uid=1, prompt=prompt, max_new_tokens=16,
                       eos_id=first))
    done = eng.run_until_drained()
    assert done[0].out == [first]
    assert eng.ticks == 0


def test_scheduler_prefix_affinity():
    s = DiffusionScheduler(4)
    for i in range(8):
        sess = Session(uid=i, replica=0, tokens_per_s=1.0, prefix_group=i % 2)
        s.place_new(sess)
    # all sessions of one prefix group land on one replica at admission
    by_group = {}
    for sess in s.sessions.values():
        by_group.setdefault(sess.prefix_group, set()).add(sess.replica)
    assert all(len(v) == 1 for v in by_group.values())


def test_scheduler_rebalance_balances_load():
    s = DiffusionScheduler(4, k=3)
    rng = np.random.default_rng(0)
    # adversarial: everything on replica 0
    for i in range(24):
        s.add(Session(uid=i, replica=0, tokens_per_s=float(rng.integers(1, 4)),
                      prefix_group=i // 3))
    before = s.replica_loads()
    info = s.rebalance()
    after = s.replica_loads()
    assert after.max() / after.mean() < before.max() / before.mean()


def test_scheduler_diffusion_preserves_prefix_groups_better_than_greedy():
    def build():
        s = DiffusionScheduler(4, k=3)
        rng = np.random.default_rng(1)
        for i in range(32):
            s.add(Session(uid=i, replica=i % 2, tokens_per_s=1.0 + (i % 5),
                          prefix_group=i // 4))
        return s

    def split_groups(s):
        by_group = {}
        for sess in s.sessions.values():
            by_group.setdefault(sess.prefix_group, set()).add(sess.replica)
        return sum(len(v) > 1 for v in by_group.values())

    sd = build()
    sd.rebalance(strategy="diff-comm")
    sg = build()
    sg.rebalance(strategy="greedy")
    assert split_groups(sd) <= split_groups(sg)


def test_place_new_picks_least_loaded_prefix_peer():
    # group 7 lives on replicas 0 (hot) and 2 (cool): a new group-7
    # session must join the *cool* peer, not the first one found
    s = DiffusionScheduler(4)
    s.add(Session(uid=0, replica=0, tokens_per_s=9.0, prefix_group=7))
    s.add(Session(uid=1, replica=2, tokens_per_s=1.0, prefix_group=7))
    s.add(Session(uid=2, replica=3, tokens_per_s=0.1, prefix_group=5))
    r = s.place_new(Session(uid=3, replica=-1, tokens_per_s=1.0,
                            prefix_group=7))
    assert r == 2
    # no peers anywhere ⇒ least-loaded replica overall (1 is empty)
    r = s.place_new(Session(uid=4, replica=-1, tokens_per_s=1.0,
                            prefix_group=99))
    assert r == 1


def test_rebalance_conserves_sessions_and_kv_bytes():
    s = DiffusionScheduler(4, k=3)
    rng = np.random.default_rng(7)
    ref = {}
    for i in range(40):
        sess = Session(uid=100 + i, replica=int(rng.integers(0, 2)),
                       tokens_per_s=float(rng.uniform(0.1, 5.0)),
                       prefix_group=i // 5,
                       kv_bytes=float(rng.uniform(10.0, 200.0)))
        ref[sess.uid] = sess
        s.add(sess)
    kv_before = sum(x.kv_bytes for x in ref.values())
    info = s.rebalance(strategy="diff-comm")
    after = s.sessions
    # identity: same uid set, and every per-session field except the
    # replica owner survives the slab exchange exactly
    assert set(after) == set(ref)
    for uid, sess in after.items():
        assert sess.tokens_per_s == pytest.approx(ref[uid].tokens_per_s)
        assert sess.prefix_group == ref[uid].prefix_group
        assert sess.kv_bytes == pytest.approx(ref[uid].kv_bytes)
    assert sum(x.kv_bytes for x in after.values()) == \
        pytest.approx(kv_before)
    # the executed exchange priced real per-session KV volume
    moved = [uid for uid in ref if after[uid].replica != ref[uid].replica]
    assert info["moved_sessions"] == len(moved)
    assert info["moved_kv_bytes"] == pytest.approx(
        sum(ref[u].kv_bytes for u in moved))


def test_rebalance_slot_capacity_defers_overflow():
    s = DiffusionScheduler(2)
    for i in range(12):
        s.add(Session(uid=i, replica=0, tokens_per_s=1.0))
    info = s.rebalance(strategy="diff-comm", slot_capacity=8)
    occ = np.bincount([x.replica for x in s.sessions.values()], minlength=2)
    assert occ.max() <= 8
    assert len(s.sessions) == 12          # deferred, never dropped
    assert info["deferred_sessions"] >= 0
    assert info["moved_sessions"] + info["deferred_sessions"] >= 2


def test_edge_weights_share_the_node_load_floor():
    # a zero-load session still contributes a (floored) edge weight: the
    # problem's edge bytes come from the same clamped loads as its node
    # loads, so planning never sees a 0-weight prefix tie
    s = DiffusionScheduler(4)
    s.add(Session(uid=0, replica=0, tokens_per_s=0.0, prefix_group=1))
    s.add(Session(uid=1, replica=1, tokens_per_s=0.0, prefix_group=1))
    prob = s.problem()
    loads = np.asarray(prob.loads)
    ew = np.asarray(prob.edges_bytes)
    es = np.asarray(prob.edges_src)
    assert loads.min() >= 1e-3
    star = ew[(es >= 0)]
    assert star.size and (star >= 1e-3).all()


def test_prefix_locality_metric():
    import jax.numpy as jnp
    s = DiffusionScheduler(4)
    for i in range(8):
        s.add(Session(uid=i, replica=i % 4, tokens_per_s=1.0,
                      prefix_group=i // 4))
    fleet = s.fleet()
    split = float(prefix_locality(fleet))
    # perfect placement: group 0 (uids 0..3) on replica 0, group 1 on 1
    colocated = float(prefix_locality(
        fleet, assignment=jnp.where(fleet.uid < 4, 0, 1)))
    assert colocated == pytest.approx(1.0)
    assert split < colocated


def test_maybe_rebalance_predictive_amortizes_executed_kv():
    from repro.runtime.cost import RuntimeCostModel
    from repro.runtime.triggers import PredictiveTrigger

    def build(cost):
        s = DiffusionScheduler(4, k=3)
        rng = np.random.default_rng(11)
        for i in range(24):
            s.add(Session(uid=i, replica=0,
                          tokens_per_s=float(rng.uniform(0.5, 4.0)),
                          prefix_group=i // 3,
                          kv_bytes=float(rng.uniform(50.0, 100.0))))
        return s

    def drive(cost, measured):
        s = build(cost)
        trig = PredictiveTrigger(cost=cost, measured_gate=measured)
        fires = 0
        for _ in range(12):
            info = s.maybe_rebalance(trigger=trig, lb_every=2, cost=cost)
            fires += int(info["fired"])
            # keep the imbalance pressure on so the estimate gate would
            # keep firing: pile fresh load onto replica 0
            for uid, sess in s.sessions.items():
                if sess.replica == 0:
                    s.add(Session(uid=uid, replica=0,
                                  tokens_per_s=sess.tokens_per_s + 2.0,
                                  prefix_group=sess.prefix_group,
                                  kv_bytes=sess.kv_bytes))
        return fires

    # KV bytes are made astronomically expensive in load units: the
    # measured gate must fire less often than the estimate-only gate once
    # it has seen what one executed exchange actually moved
    cost = RuntimeCostModel(t_load=1.0, t_byte=50.0, bytes_per_load=1e-4,
                            moved_frac_est=1e-6)
    measured, legacy = drive(cost, True), drive(cost, False)
    assert legacy > 0
    assert measured < legacy
    assert measured >= 1                 # cold start still fires once


def test_scheduler_fleet_roundtrip_via_sessions_facade():
    # legacy dict-of-sessions view stays faithful to the slab store
    s = DiffusionScheduler(3, capacity=4)   # forces a _grow
    for i in range(9):
        s.add(Session(uid=i * 10, replica=i % 3, tokens_per_s=float(i),
                      prefix_group=i % 2, kv_bytes=2.0 * i))
    s.remove(30)
    assert len(s) == 8 and 30 not in s.sessions
    sess = s.sessions[70]
    assert sess.tokens_per_s == 7.0 and sess.kv_bytes == 14.0
    loads = s.replica_loads()
    assert loads.sum() == pytest.approx(sum(
        x.tokens_per_s for x in s.sessions.values()))
    assert np.asarray(fleet_loads(s.fleet())).min() >= 1e-3
    prob = fleet_problem(s.fleet(), 3)
    prob.validate()
