"""Serving: engine continuous batching + diffusion request scheduling."""
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer
from repro.models.params import init_params
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.scheduler import DiffusionScheduler, Session


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_arch("smollm-135m").reduced
    params = init_params(transformer.model_specs(cfg), 0)
    return cfg, params


def test_engine_drains_all_requests(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, ServeConfig(num_slots=2, max_len=64))
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(uid=i,
                           prompt=rng.integers(1, cfg.vocab_size, 4 + i),
                           max_new_tokens=6))
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out) == 6 for r in done)


def test_engine_continuous_batching_joins_mid_flight(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, ServeConfig(num_slots=2, max_len=64))
    rng = np.random.default_rng(1)
    eng.submit(Request(uid=0, prompt=rng.integers(1, cfg.vocab_size, 4),
                       max_new_tokens=10))
    eng.tick()
    eng.tick()
    # join while request 0 is mid-decode
    eng.submit(Request(uid=1, prompt=rng.integers(1, cfg.vocab_size, 4),
                       max_new_tokens=3))
    done = eng.run_until_drained()
    assert {r.uid for r in done} == {0, 1}


def test_engine_decode_matches_dedicated_decode(engine_setup):
    """Engine output for a single request == plain prefill+decode_step."""
    import jax.numpy as jnp
    cfg, params = engine_setup
    rng = np.random.default_rng(2)
    prompt = rng.integers(1, cfg.vocab_size, 6)

    eng = ServeEngine(cfg, params, ServeConfig(num_slots=1, max_len=32))
    eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=5))
    out_engine = eng.run_until_drained()[0].out

    cache = transformer.init_cache(cfg, 1, 32, jnp.float32)
    batch = dict(tokens=jnp.asarray(prompt[None], jnp.int32),
                 positions=jnp.arange(len(prompt), dtype=jnp.int32)[None])
    logits, cache = transformer.prefill(params, cfg, batch, cache)
    toks = [int(np.argmax(np.asarray(logits[0, -1])))]
    for i in range(4):
        l, cache = transformer.decode_step(
            params, cfg, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.int32(len(prompt) + i), cache)
        toks.append(int(np.argmax(np.asarray(l[0, 0]))))
    assert out_engine == toks


def test_scheduler_prefix_affinity():
    s = DiffusionScheduler(4)
    for i in range(8):
        sess = Session(uid=i, replica=0, tokens_per_s=1.0, prefix_group=i % 2)
        s.place_new(sess)
    # all sessions of one prefix group land on one replica at admission
    by_group = {}
    for sess in s.sessions.values():
        by_group.setdefault(sess.prefix_group, set()).add(sess.replica)
    assert all(len(v) == 1 for v in by_group.values())


def test_scheduler_rebalance_balances_load():
    s = DiffusionScheduler(4, k=3)
    rng = np.random.default_rng(0)
    # adversarial: everything on replica 0
    for i in range(24):
        s.add(Session(uid=i, replica=0, tokens_per_s=float(rng.integers(1, 4)),
                      prefix_group=i // 3))
    before = s.replica_loads()
    info = s.rebalance()
    after = s.replica_loads()
    assert after.max() / after.mean() < before.max() / before.mean()


def test_scheduler_diffusion_preserves_prefix_groups_better_than_greedy():
    def build():
        s = DiffusionScheduler(4, k=3)
        rng = np.random.default_rng(1)
        for i in range(32):
            s.add(Session(uid=i, replica=i % 2, tokens_per_s=1.0 + (i % 5),
                          prefix_group=i // 4))
        return s

    def split_groups(s):
        by_group = {}
        for sess in s.sessions.values():
            by_group.setdefault(sess.prefix_group, set()).add(sess.replica)
        return sum(len(v) > 1 for v in by_group.values())

    sd = build()
    sd.rebalance(strategy="diff-comm")
    sg = build()
    sg.rebalance(strategy="greedy")
    assert split_groups(sd) <= split_groups(sg)
