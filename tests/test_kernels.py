"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core.virtual_lb import (
    neighborhood_residual,
    reference_sweep,
    reverse_slots,
)
from repro.kernels.diffusion import ops as diffusion_ops
from repro.kernels.diffusion.kernel import (
    diffusion_nsweeps_pallas,
    diffusion_sweep_pallas,
)
from repro.kernels.diffusion.ref import diffusion_nsweeps_ref
from repro.kernels.histogram.kernel import histogram_pallas
from repro.kernels.histogram.ref import histogram_ref
from repro.kernels.pic_push.kernel import pic_push_pallas
from repro.kernels.pic_push.ref import pic_push_ref
from repro.pic.grid import alternating_grid
from repro.pic.particles import initialize
from tests.conftest import random_symmetric_graph


# --------------------------------------------------------------- diffusion --


def _graph(P, K, seed):
    """Random symmetric K-regular-ish neighbor table (device arrays)."""
    nbr, mask = random_symmetric_graph(P, K, seed)
    return jnp.asarray(nbr), jnp.asarray(mask)


@pytest.mark.parametrize("P,K,block_p", [
    (16, 2, 8), (64, 4, 32), (100, 4, 64), (257, 8, 128), (512, 3, 512),
])
@pytest.mark.parametrize("single_hop", [True, False])
def test_diffusion_kernel_matches_ref(P, K, block_p, single_hop):
    nbr, mask = _graph(P, K, seed=P + K)
    rev = reverse_slots(nbr, mask)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random(P).astype(np.float32) * 10)
    own = x * 0.7
    out_k = diffusion_sweep_pallas(x, own, nbr, mask, rev, 0.2, single_hop,
                                   block_p=block_p, interpret=True)
    out_r = reference_sweep(x, own, nbr, mask, rev, jnp.float32(0.2),
                            single_hop)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(P=st.integers(8, 80), K=st.integers(1, 6), seed=st.integers(0, 99))
def test_property_diffusion_kernel_conserves(P, K, seed):
    nbr, mask = _graph(P, K, seed)
    rev = reverse_slots(nbr, mask)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random(P).astype(np.float32) * 5)
    xn, own, flow = diffusion_sweep_pallas(
        x, x, nbr, mask, rev, 1.0 / (K + 1), True, interpret=True)
    np.testing.assert_allclose(float(jnp.sum(xn)), float(jnp.sum(x)),
                               rtol=1e-4)
    assert (np.asarray(xn) >= -1e-4).all()


# ------------------------------------------------------- fused multi-sweep --


def _nsweeps_args(P, K, seed=0):
    nbr, mask = _graph(P, K, seed=P + K)
    rev = reverse_slots(nbr, mask)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random(P).astype(np.float32) * 10)
    own = x * 0.7
    flow = jnp.zeros((P, K), jnp.float32)
    res0 = neighborhood_residual(x, nbr, mask)
    return x, own, flow, res0, nbr, mask, rev


@pytest.mark.parametrize("P,K,S", [
    (16, 2, 1), (16, 2, 4), (64, 4, 8), (100, 4, 3), (257, 8, 6),
])
@pytest.mark.parametrize("single_hop", [True, False])
def test_nsweeps_kernel_bit_for_bit_vs_iterated_reference(P, K, S,
                                                          single_hop):
    """The fused S-sweep kernel must equal S iterated reference sweeps
    *bit-for-bit* (interpret mode): same values, not just close ones —
    the chunked loop is a compilation strategy, not a different scheme.
    tol=-1 keeps every sweep active so all S sweeps actually run."""
    x, own, flow, res0, nbr, mask, rev = _nsweeps_args(P, K)
    alpha = 1.0 / (K + 1.0)
    got = diffusion_nsweeps_pallas(
        x, own, flow, jnp.int32(0), res0, jnp.int32(0), nbr, mask, rev,
        alpha, n_sweeps=S, single_hop=single_hop, tol=-1.0,
        max_iters=10 ** 6, interpret=True)
    xs, os_, fl = x, own, flow
    for _ in range(S):
        xs, os_, df = reference_sweep(xs, os_, nbr, mask, rev,
                                      jnp.float32(alpha), single_hop)
        fl = fl + df
    for a, b in zip(got[:3], (xs, os_, fl)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(got[3]) == S


@pytest.mark.parametrize("P,K,S,tol", [
    (32, 3, 4, 0.02), (64, 4, 8, 0.1), (100, 4, 16, 0.02),
])
@pytest.mark.parametrize("single_hop", [True, False])
def test_nsweeps_kernel_early_exit_parity(P, K, S, tol, single_hop):
    """With a realistic tol the kernel's device-side early exit must make
    the same per-sweep decisions as the reference chunk: identical carry
    (x/own/flow) *and* identical iteration/stall/residual bookkeeping."""
    x, own, flow, res0, nbr, mask, rev = _nsweeps_args(P, K)
    alpha = jnp.float32(1.0 / (K + 1.0))
    args = (x, own, flow, jnp.int32(0), res0, jnp.int32(0), nbr, mask, rev,
            alpha)
    kw = dict(n_sweeps=S, single_hop=single_hop, tol=tol, max_iters=512)
    got = diffusion_nsweeps_pallas(*args, interpret=True, **kw)
    want = diffusion_nsweeps_ref(*args, **kw)
    for a, b in zip(got, want):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_nsweeps_kernel_resumes_mid_convergence():
    """Chunk boundaries carry (it, res, stall) through: two chained 4-sweep
    kernel calls equal one 8-sweep call."""
    P, K = 48, 3
    x, own, flow, res0, nbr, mask, rev = _nsweeps_args(P, K)
    alpha = jnp.float32(1.0 / (K + 1.0))
    kw = dict(single_hop=True, tol=0.02, max_iters=512, interpret=True)
    one = diffusion_nsweeps_pallas(
        x, own, flow, jnp.int32(0), res0, jnp.int32(0), nbr, mask, rev,
        alpha, n_sweeps=8, **kw)
    half = diffusion_nsweeps_pallas(
        x, own, flow, jnp.int32(0), res0, jnp.int32(0), nbr, mask, rev,
        alpha, n_sweeps=4, **kw)
    two = diffusion_nsweeps_pallas(
        *half, nbr, mask, rev, alpha, n_sweeps=4, **kw)
    for a, b in zip(one, two):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sweep_impl_selection_rule():
    """Non-TPU backends take the compiled reference chunk; the documented
    VMEM budget splits fused vs streaming on TPU."""
    from repro.kernels import on_tpu

    small, huge = (4096, 8), (1_000_000, 8)
    if on_tpu():
        assert diffusion_ops.sweep_impl(*small) == "fused"
        assert diffusion_ops.sweep_impl(*huge) == "streaming"
    else:
        assert diffusion_ops.sweep_impl(*small) == "reference"
        assert diffusion_ops.sweep_impl(*huge) == "reference"
    assert (diffusion_ops.fused_vmem_bytes(*small)
            <= diffusion_ops.FUSED_VMEM_BUDGET
            < diffusion_ops.fused_vmem_bytes(*huge))


# --------------------------------------------------------------- histogram --


@pytest.mark.parametrize("N,C,block_n", [
    (100, 7, 32), (4096, 144, 2048), (5000, 333, 1024), (64, 4, 64),
])
def test_histogram_matches_ref(N, C, block_n):
    rng = np.random.default_rng(N)
    ids = jnp.asarray(rng.integers(-1, C, N), jnp.int32)   # incl. padding ids
    w = jnp.asarray(rng.random(N), jnp.float32)
    got = histogram_pallas(ids, w, C=C, block_n=block_n, interpret=True)
    want = histogram_ref(ids, w, C=C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


def test_histogram_weighted_vs_counts():
    ids = jnp.asarray([0, 0, 1, 2, 2, 2], jnp.int32)
    ones = jnp.ones(6, jnp.float32)
    got = histogram_pallas(ids, ones, C=3, interpret=True)
    np.testing.assert_allclose(np.asarray(got), [2, 1, 3])


# ---------------------------------------------------------------- pic_push --


@pytest.mark.parametrize("L,N,block_n", [(32, 100, 64), (64, 1000, 256),
                                         (128, 333, 512)])
def test_pic_push_matches_ref(L, N, block_n):
    p = initialize("GEOMETRIC", L, N, k=1, seed=L)
    g = jnp.asarray(alternating_grid(L))
    args = tuple(map(jnp.asarray, (p.x, p.y, p.vx, p.vy, p.q)))
    got = pic_push_pallas(g, *args, L=L, block_n=block_n, interpret=True)
    want = pic_push_ref(g, *args, L=L)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", ["GEOMETRIC", "SINUSOIDAL", "LINEAR",
                                  "PATCH"])
def test_pic_push_positions_stay_in_bounds(mode):
    L = 48
    p = initialize(mode, L, 500, k=2, seed=1)
    g = jnp.asarray(alternating_grid(L))
    x, y, vx, vy = map(jnp.asarray, (p.x, p.y, p.vx, p.vy))
    q = jnp.asarray(p.q)
    for _ in range(5):
        x, y, vx, vy = pic_push_ref(g, x, y, vx, vy, q, L=L)
    assert (np.asarray(x) >= 0).all() and (np.asarray(x) < L).all()
    assert (np.asarray(y) >= 0).all() and (np.asarray(y) < L).all()


def test_prk_determinism_displacement():
    """The PRK construction: exactly (2k+1) cells/step horizontally after
    every even step, vy cells vertically."""
    L, k = 64, 3
    p = initialize("GEOMETRIC", L, 400, k=k, seed=5)
    g = jnp.asarray(alternating_grid(L))
    s = tuple(map(jnp.asarray, (p.x, p.y, p.vx, p.vy)))
    q = jnp.asarray(p.q)
    for _ in range(4):
        out = pic_push_ref(g, *s, q, L=L)
        s = out
    dx = (np.asarray(s[0]) - p.x) % L
    dy = (np.asarray(s[1]) - p.y) % L
    np.testing.assert_allclose(dx, (4 * (2 * k + 1)) % L, atol=1e-3)
    np.testing.assert_allclose(dy, 4.0, atol=1e-3)


# --------------------------------------------------------- flash attention --


from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@pytest.mark.parametrize("B,Sq,T,KV,G,hd,window,prefix,dtype", [
    (2, 64, 64, 2, 3, 16, 0, 0, jnp.float32),
    (1, 128, 128, 1, 4, 32, 0, 0, jnp.float32),
    (2, 64, 64, 2, 2, 16, 24, 0, jnp.float32),
    (1, 48, 48, 2, 2, 16, 0, 16, jnp.float32),
    (2, 96, 96, 3, 1, 16, 0, 0, jnp.bfloat16),
    (1, 40, 72, 2, 2, 8, 0, 0, jnp.float32),   # Sq != T, non-multiple blocks
])
def test_flash_attention_matches_ref(B, Sq, T, KV, G, hd, window, prefix,
                                     dtype):
    rng = np.random.default_rng(Sq + T)
    q = jnp.asarray(rng.normal(size=(B, Sq, KV, G, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), dtype)
    qpos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32) + (T - Sq),
                            (B, Sq))
    kpos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    got = flash_attention_pallas(q, k, v, qpos, kpos, window=window,
                                 prefix_len=prefix, q_block=32, kv_block=32,
                                 interpret=True)
    want = flash_attention_ref(q, k, v, qpos, kpos, window=window,
                               prefix_len=prefix)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_cache_sentinels():
    """Unwritten cache slots (sentinel positions) must not contribute."""
    B, Sq, T, KV, G, hd = 1, 16, 64, 1, 2, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Sq, KV, G, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)).astype(np.float32))
    qpos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    kpos = jnp.where(jnp.arange(T) < Sq, jnp.arange(T), 2 ** 30)[None, :]
    kpos = jnp.broadcast_to(kpos.astype(jnp.int32), (B, T))
    got = flash_attention_pallas(q, k, v, qpos, kpos, q_block=16,
                                 kv_block=16, interpret=True)
    want = flash_attention_ref(q[:, :Sq], k[:, :Sq], v[:, :Sq], qpos,
                               kpos[:, :Sq])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)


# ---------------------------------------------------- migrate (sort-free) --


from repro.kernels.migrate import ops as migrate_ops
from repro.kernels.migrate.kernel import scatter_dest_pallas
from repro.kernels.migrate.ref import bucket_ranks_ref, scatter_dest_ref


def _stable_dest(ids, C):
    """Oracle: item → slot of the stable argsort bucketed layout."""
    ids = np.asarray(ids)
    n = ids.shape[0]
    valid = (ids >= 0) & (ids < C)
    order = np.argsort(np.where(valid, ids, C), kind="stable")
    dest = np.full(n, n, np.int64)
    for slot, i in enumerate(order[: valid.sum()]):
        dest[i] = slot
    return dest


@pytest.mark.parametrize("n,C,block_n", [
    (100, 8, 32), (257, 16, 64), (1024, 8, 256), (96, 1, 32),
    (50, 3, 64), (512, 40, 128),
])
def test_migrate_scatter_kernel_matches_ref(n, C, block_n):
    rng = np.random.default_rng(n * 31 + C)
    ids = rng.integers(0, C, size=n).astype(np.int32)
    ids[::13] = -1                       # padding slots
    ids[::29] = C                        # out-of-range sentinel slots
    dest_k, counts_k = scatter_dest_pallas(
        jnp.asarray(ids), C=C, block_n=block_n, interpret=True)
    dest_r, counts_r = scatter_dest_ref(jnp.asarray(ids), C=C)
    np.testing.assert_array_equal(np.asarray(dest_k), np.asarray(dest_r))
    np.testing.assert_array_equal(np.asarray(counts_k), np.asarray(counts_r))
    np.testing.assert_array_equal(np.asarray(dest_r), _stable_dest(ids, C))


@pytest.mark.parametrize("case", ["duplicate_heavy", "empty_node",
                                  "single_node", "empty_input"])
def test_migrate_scatter_edge_cases_both_paths(case):
    n, C = {"duplicate_heavy": (300, 4), "empty_node": (128, 16),
            "single_node": (64, 1), "empty_input": (0, 8)}[case]
    rng = np.random.default_rng(7)
    if case == "duplicate_heavy":
        ids = np.repeat(rng.integers(0, C, 3), 100).astype(np.int32)
    elif case == "empty_node":
        ids = rng.choice([0, 3, 15], size=n).astype(np.int32)  # 13 empty
    elif case == "single_node":
        ids = np.zeros(n, np.int32)
    else:
        ids = np.zeros(0, np.int32)
    want = _stable_dest(ids, C)
    for use_kernel in (False, True):
        dest, counts, offsets = migrate_ops.scatter_dest(
            jnp.asarray(ids), C=C, use_kernel=use_kernel)
        np.testing.assert_array_equal(np.asarray(dest), want)
        np.testing.assert_array_equal(
            np.asarray(counts), np.bincount(ids, minlength=C))
        assert offsets.shape == (C + 1,) and int(offsets[-1]) == n


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 600), C=st.integers(1, 48), seed=st.integers(0, 99))
def test_property_migrate_scatter_equals_stable_argsort(n, C, seed):
    """Sort-free permutation == jnp.argsort(owner, stable=True), both
    implementations, random owner vectors (duplicates guaranteed for
    n > C)."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, C, size=n).astype(np.int32)
    want_order = np.asarray(jnp.argsort(jnp.asarray(ids), stable=True))
    for use_kernel in (False, True):
        dest, _, _ = migrate_ops.scatter_dest(
            jnp.asarray(ids), C=C, use_kernel=use_kernel)
        order = np.empty(n, np.int64)
        order[np.asarray(dest)] = np.arange(n)
        np.testing.assert_array_equal(order, want_order)


@settings(max_examples=8, deadline=None)
@given(n=st.integers(1, 400), C=st.integers(2, 32), seed=st.integers(0, 50))
def test_property_migrate_bucket_ranks(n, C, seed):
    """rank[i] counts earlier same-owner items; padding ranks are -1; both
    dispatch paths agree bit-for-bit."""
    rng = np.random.default_rng(seed + 1000)
    ids = rng.integers(-1, C, size=n).astype(np.int32)
    want = np.full(n, -1, np.int64)
    seen = {}
    for i, v in enumerate(ids):
        if 0 <= v < C:
            want[i] = seen.get(v, 0)
            seen[v] = want[i] + 1
    for use_kernel in (False, True):
        rank, counts = migrate_ops.bucket_ranks(
            jnp.asarray(ids), C=C, use_kernel=use_kernel)
        np.testing.assert_array_equal(np.asarray(rank), want)
        np.testing.assert_array_equal(
            np.asarray(counts), np.bincount(ids[ids >= 0], minlength=C))


def test_migrate_blocked_ref_matches_single_block():
    """The blocked lax.scan reference is exact int arithmetic: forcing
    many small blocks reproduces the one-shot result bit-for-bit."""
    rng = np.random.default_rng(3)
    ids = jnp.asarray(rng.integers(0, 6, size=1000), jnp.int32)
    r1, c1 = bucket_ranks_ref(ids, C=6)
    import repro.kernels.migrate.ref as mref
    orig = mref.BLOCK_ELEMS
    try:
        mref.BLOCK_ELEMS = 6 * 64      # force ~16 blocks
        bucket_ranks_ref.clear_cache()
        r2, c2 = bucket_ranks_ref(ids, C=6)
    finally:
        mref.BLOCK_ELEMS = orig
        bucket_ranks_ref.clear_cache()
    np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_migrate_impl_selection_rule():
    """Non-TPU backends take the compiled reference; the kernel needs a
    block size within the VMEM budget and the f32-exact n bound; the
    sort-vs-scatter crossover tracks the bucket count on CPU."""
    from repro.kernels import on_tpu

    assert migrate_ops.kernel_block_n(8) is not None
    assert migrate_ops.kernel_block_n(100_000) is None
    if on_tpu():
        assert migrate_ops.scatter_impl(1 << 20, 8) == "kernel"
        assert migrate_ops.preferred_method(1 << 20, 1024) == "scatter"
    else:
        assert migrate_ops.scatter_impl(1 << 20, 8) == "reference"
        assert migrate_ops.preferred_method(1 << 20, 8) == "scatter"
        assert migrate_ops.preferred_method(
            1 << 20, migrate_ops.SORT_CROSSOVER_C + 1) == "sort"
