"""Pallas kernels vs pure-jnp oracles, interpret mode, shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core.virtual_lb import reference_sweep, reverse_slots
from repro.kernels.diffusion.kernel import diffusion_sweep_pallas
from repro.kernels.histogram.kernel import histogram_pallas
from repro.kernels.histogram.ref import histogram_ref
from repro.kernels.pic_push.kernel import pic_push_pallas
from repro.kernels.pic_push.ref import pic_push_ref
from repro.pic.grid import alternating_grid
from repro.pic.particles import initialize


# --------------------------------------------------------------- diffusion --


def _graph(P, K, seed):
    """Random symmetric K-regular-ish neighbor table."""
    rng = np.random.default_rng(seed)
    nbr = np.full((P, K), -1, np.int32)
    mask = np.zeros((P, K), bool)
    deg = np.zeros(P, np.int64)
    order = rng.permutation(P * P)
    for idx in order:
        i, j = divmod(int(idx), P)
        if i >= j or deg[i] >= K or deg[j] >= K:
            continue
        nbr[i, deg[i]] = j
        nbr[j, deg[j]] = i
        mask[i, deg[i]] = mask[j, deg[j]] = True
        deg[i] += 1
        deg[j] += 1
    return jnp.asarray(nbr), jnp.asarray(mask)


@pytest.mark.parametrize("P,K,block_p", [
    (16, 2, 8), (64, 4, 32), (100, 4, 64), (257, 8, 128), (512, 3, 512),
])
@pytest.mark.parametrize("single_hop", [True, False])
def test_diffusion_kernel_matches_ref(P, K, block_p, single_hop):
    nbr, mask = _graph(P, K, seed=P + K)
    rev = reverse_slots(nbr, mask)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.random(P).astype(np.float32) * 10)
    own = x * 0.7
    out_k = diffusion_sweep_pallas(x, own, nbr, mask, rev, 0.2, single_hop,
                                   block_p=block_p, interpret=True)
    out_r = reference_sweep(x, own, nbr, mask, rev, jnp.float32(0.2),
                            single_hop)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(P=st.integers(8, 80), K=st.integers(1, 6), seed=st.integers(0, 99))
def test_property_diffusion_kernel_conserves(P, K, seed):
    nbr, mask = _graph(P, K, seed)
    rev = reverse_slots(nbr, mask)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.random(P).astype(np.float32) * 5)
    xn, own, flow = diffusion_sweep_pallas(
        x, x, nbr, mask, rev, 1.0 / (K + 1), True, interpret=True)
    np.testing.assert_allclose(float(jnp.sum(xn)), float(jnp.sum(x)),
                               rtol=1e-4)
    assert (np.asarray(xn) >= -1e-4).all()


# --------------------------------------------------------------- histogram --


@pytest.mark.parametrize("N,C,block_n", [
    (100, 7, 32), (4096, 144, 2048), (5000, 333, 1024), (64, 4, 64),
])
def test_histogram_matches_ref(N, C, block_n):
    rng = np.random.default_rng(N)
    ids = jnp.asarray(rng.integers(-1, C, N), jnp.int32)   # incl. padding ids
    w = jnp.asarray(rng.random(N), jnp.float32)
    got = histogram_pallas(ids, w, C=C, block_n=block_n, interpret=True)
    want = histogram_ref(ids, w, C=C)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4)


def test_histogram_weighted_vs_counts():
    ids = jnp.asarray([0, 0, 1, 2, 2, 2], jnp.int32)
    ones = jnp.ones(6, jnp.float32)
    got = histogram_pallas(ids, ones, C=3, interpret=True)
    np.testing.assert_allclose(np.asarray(got), [2, 1, 3])


# ---------------------------------------------------------------- pic_push --


@pytest.mark.parametrize("L,N,block_n", [(32, 100, 64), (64, 1000, 256),
                                         (128, 333, 512)])
def test_pic_push_matches_ref(L, N, block_n):
    p = initialize("GEOMETRIC", L, N, k=1, seed=L)
    g = jnp.asarray(alternating_grid(L))
    args = tuple(map(jnp.asarray, (p.x, p.y, p.vx, p.vy, p.q)))
    got = pic_push_pallas(g, *args, L=L, block_n=block_n, interpret=True)
    want = pic_push_ref(g, *args, L=L)
    for a, b in zip(got, want):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("mode", ["GEOMETRIC", "SINUSOIDAL", "LINEAR",
                                  "PATCH"])
def test_pic_push_positions_stay_in_bounds(mode):
    L = 48
    p = initialize(mode, L, 500, k=2, seed=1)
    g = jnp.asarray(alternating_grid(L))
    x, y, vx, vy = map(jnp.asarray, (p.x, p.y, p.vx, p.vy))
    q = jnp.asarray(p.q)
    for _ in range(5):
        x, y, vx, vy = pic_push_ref(g, x, y, vx, vy, q, L=L)
    assert (np.asarray(x) >= 0).all() and (np.asarray(x) < L).all()
    assert (np.asarray(y) >= 0).all() and (np.asarray(y) < L).all()


def test_prk_determinism_displacement():
    """The PRK construction: exactly (2k+1) cells/step horizontally after
    every even step, vy cells vertically."""
    L, k = 64, 3
    p = initialize("GEOMETRIC", L, 400, k=k, seed=5)
    g = jnp.asarray(alternating_grid(L))
    s = tuple(map(jnp.asarray, (p.x, p.y, p.vx, p.vy)))
    q = jnp.asarray(p.q)
    for _ in range(4):
        out = pic_push_ref(g, *s, q, L=L)
        s = out
    dx = (np.asarray(s[0]) - p.x) % L
    dy = (np.asarray(s[1]) - p.y) % L
    np.testing.assert_allclose(dx, (4 * (2 * k + 1)) % L, atol=1e-3)
    np.testing.assert_allclose(dy, 4.0, atol=1e-3)


# --------------------------------------------------------- flash attention --


from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@pytest.mark.parametrize("B,Sq,T,KV,G,hd,window,prefix,dtype", [
    (2, 64, 64, 2, 3, 16, 0, 0, jnp.float32),
    (1, 128, 128, 1, 4, 32, 0, 0, jnp.float32),
    (2, 64, 64, 2, 2, 16, 24, 0, jnp.float32),
    (1, 48, 48, 2, 2, 16, 0, 16, jnp.float32),
    (2, 96, 96, 3, 1, 16, 0, 0, jnp.bfloat16),
    (1, 40, 72, 2, 2, 8, 0, 0, jnp.float32),   # Sq != T, non-multiple blocks
])
def test_flash_attention_matches_ref(B, Sq, T, KV, G, hd, window, prefix,
                                     dtype):
    rng = np.random.default_rng(Sq + T)
    q = jnp.asarray(rng.normal(size=(B, Sq, KV, G, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)), dtype)
    qpos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32) + (T - Sq),
                            (B, Sq))
    kpos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    got = flash_attention_pallas(q, k, v, qpos, kpos, window=window,
                                 prefix_len=prefix, q_block=32, kv_block=32,
                                 interpret=True)
    want = flash_attention_ref(q, k, v, qpos, kpos, window=window,
                               prefix_len=prefix)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_attention_cache_sentinels():
    """Unwritten cache slots (sentinel positions) must not contribute."""
    B, Sq, T, KV, G, hd = 1, 16, 64, 1, 2, 16
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, Sq, KV, G, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, T, KV, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, T, KV, hd)).astype(np.float32))
    qpos = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
    kpos = jnp.where(jnp.arange(T) < Sq, jnp.arange(T), 2 ** 30)[None, :]
    kpos = jnp.broadcast_to(kpos.astype(jnp.int32), (B, T))
    got = flash_attention_pallas(q, k, v, qpos, kpos, q_block=16,
                                 kv_block=16, interpret=True)
    want = flash_attention_ref(q[:, :Sq], k[:, :Sq], v[:, :Sq], qpos,
                               kpos[:, :Sq])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-3)
