"""The paper's technique as a framework feature: MoE expert placement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import ep_balance as eb


def _skewed_stats(E=16, k=2, seed=0, steps=5):
    stats = eb.ExpertStats(E, ema=0.5)
    rng = np.random.default_rng(seed)
    p = np.r_[np.full(4, 0.6 / 4), np.full(E - 4, 0.4 / (E - 4))]
    for _ in range(steps):
        ids = rng.choice(E, size=(512, k), p=p)
        stats.update(ids)
    return stats


def test_stats_update_counts_and_coactivation():
    stats = eb.ExpertStats(4, ema=0.0)
    ids = np.array([[0, 1], [0, 1], [2, 3]])
    stats.update(ids)
    assert stats.tokens[0] == 2 and stats.tokens[3] == 1
    assert stats.coact[0, 1] == 2 and stats.coact[1, 0] == 2
    assert stats.coact[2, 3] == 1
    assert stats.coact[0, 2] == 0


def test_plan_is_capacity_exact():
    stats = _skewed_stats()
    placement = (np.arange(16) // 4).astype(np.int32)
    new, info = eb.plan_placement(stats, placement, 4)
    counts = np.bincount(new, minlength=4)
    assert (counts == 4).all()


def test_plan_reduces_imbalance():
    stats = _skewed_stats()
    # adversarial initial: the 4 hot experts all on rank 0
    placement = (np.arange(16) // 4).astype(np.int32)
    before = stats.imbalance(placement, 4)
    new, info = eb.plan_placement(stats, placement, 4)
    after = stats.imbalance(new, 4)
    assert after < before
    assert info["moved_experts"] < 16, "diffusion must not move everything"


def test_diffusion_moves_fewer_experts_than_greedy():
    stats = _skewed_stats(seed=3)
    placement = (np.arange(16) // 4).astype(np.int32)
    d, di = eb.plan_placement(stats, placement, 4, strategy="diff-comm")
    g, gi = eb.plan_placement(stats, placement, 4, strategy="greedy")
    assert di["moved_experts"] <= gi["moved_experts"]


def test_perm_roundtrip():
    placement = np.array([1, 0, 0, 1, 2, 3, 3, 2], np.int32)
    perm = eb.placement_to_perm(placement, 4)
    # slot r*2+i holds a logical expert that placement maps to rank r
    for s, e in enumerate(perm):
        assert placement[e] == s // 2


def test_apply_perm_preserves_moe_semantics():
    """Permuted weights + permuted router columns == identical MoE output."""
    from repro.configs import get_arch
    from repro.models import moe as moe_mod
    from repro.models import transformer
    from repro.models.params import init_params

    cfg = get_arch("deepseek-v3-671b").reduced       # 8 experts, dense impl
    specs = transformer.model_specs(cfg)
    params = init_params(specs, 0)
    moe_params = jax.tree.map(lambda x: x[0], params["unit"][0]["moe"])

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))
    y0, _ = moe_mod.moe_dense(moe_params, cfg, x)

    perm = np.array([3, 1, 0, 2, 7, 6, 5, 4])
    permuted = eb.apply_perm_to_params(moe_params, perm)
    y1, _ = moe_mod.moe_dense(permuted, cfg, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)


def test_migration_bytes_counts_cross_rank_moves():
    old = np.arange(8)
    new = np.array([1, 0, 2, 3, 4, 5, 6, 7])      # swap within rank 0: free
    assert eb.migration_bytes(old, new, 100.0, 4) == 0.0
    new2 = np.array([2, 1, 0, 3, 4, 5, 6, 7])     # 0<->2 crosses ranks 0/1
    assert eb.migration_bytes(old, new2, 100.0, 4) == 200.0


def test_colocation_of_coactivated_experts():
    """Experts that always fire together should end colocated (ext/int)."""
    E, R = 8, 4
    stats = eb.ExpertStats(E, ema=0.0)
    # pairs (0,4), (1,5), (2,6), (3,7) co-activate; start split across ranks
    ids = np.array([[0, 4], [1, 5], [2, 6], [3, 7]] * 64)
    stats.update(ids)
    stats.tokens = stats.tokens + np.linspace(0, 1, E)  # break ties
    placement = np.array([0, 1, 2, 3, 0, 1, 2, 3], np.int32)  # already cheap
    new, info = eb.plan_placement(stats, placement, R)
    # already-colocated pairs with balanced load: nothing should move
    assert info["moved_experts"] == 0


# --------------------------------------------------- vectorized statistics --


@pytest.mark.parametrize("E,k,T,seed", [(8, 2, 64, 0), (16, 4, 256, 1),
                                        (32, 3, 128, 2), (4, 4, 512, 3)])
def test_pair_stats_vectorized_matches_loop(E, k, T, seed):
    """Property test: the one-shot CᵀC−diag update equals the historical
    O(k²) pair loop — including rows with duplicate expert ids (top-k
    samplers with replacement produce them)."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, E, size=(T, k))
    c_vec, co_vec = eb.pair_stats_np(ids, E)
    c_loop, co_loop = eb.pair_stats_loop(ids, E)
    np.testing.assert_array_equal(c_vec, c_loop)
    np.testing.assert_array_equal(co_vec, co_loop)
    # structural invariants: symmetric, zero diagonal contribution rule
    np.testing.assert_array_equal(co_vec, co_vec.T)


def test_pair_stats_device_matches_host():
    """``models.moe.pair_stats`` (the in-scan op) computes the identical
    statistics as the host numpy twin the EMA collector uses."""
    from repro.models import moe as moe_mod

    rng = np.random.default_rng(7)
    ids = rng.integers(0, 16, size=(128, 4))
    st = moe_mod.pair_stats(jnp.asarray(ids), 16)
    c_np, co_np = eb.pair_stats_np(ids, 16)
    np.testing.assert_array_equal(np.asarray(st.counts), c_np)
    np.testing.assert_array_equal(np.asarray(st.coact), co_np)


def test_update_from_counts_matches_update():
    """The device-stats EMA path and the raw-ids EMA path agree."""
    rng = np.random.default_rng(5)
    a = eb.ExpertStats(8, ema=0.7)
    b = eb.ExpertStats(8, ema=0.7)
    for _ in range(4):
        ids = rng.integers(0, 8, size=(64, 2))
        a.update(ids)
        c, co = eb.pair_stats_np(ids, 8)
        b.update_from_counts(c, co)
    np.testing.assert_allclose(a.tokens, b.tokens)
    np.testing.assert_allclose(a.coact, b.coact)


# ------------------------------------------------------- capacity repair --


@pytest.mark.parametrize("seed", range(4))
def test_repair_capacity_is_exact(seed):
    E, R = 24, 4
    rng = np.random.default_rng(seed)
    a = rng.integers(0, R, size=E).astype(np.int32)
    loads = rng.uniform(0.1, 5.0, size=E).astype(np.float32)
    out = np.asarray(eb.repair_capacity(a, loads, num_ranks=R, cap=E // R))
    assert (np.bincount(out, minlength=R) == E // R).all()
    # experts on non-overfull ranks never move
    counts = np.bincount(a, minlength=R)
    for e in range(E):
        if counts[a[e]] <= E // R:
            assert out[e] == a[e]


def test_repair_capacity_evicts_lightest_first():
    # rank 0 holds 5 experts (cap 2); the three lightest must leave
    a = np.array([0, 0, 0, 0, 0, 1, 2, 3], np.int32)
    loads = np.array([5.0, 1.0, 4.0, 2.0, 3.0, 1.0, 1.0, 1.0], np.float32)
    out = np.asarray(eb.repair_capacity(a, loads, num_ranks=4, cap=2))
    assert (np.bincount(out, minlength=4) == 2).all()
    assert out[0] == 0 and out[2] == 0          # heaviest two stay
    assert set(np.nonzero(out != a)[0]) == {1, 3, 4}


def test_repair_capacity_traceable_in_scan():
    """The repair pass must run inside lax.scan (the in-scan runtime
    plans under a traced cond) and match the eager result bit-for-bit."""
    E, R = 16, 4
    rng = np.random.default_rng(9)
    a = jnp.asarray(rng.integers(0, R, size=E), jnp.int32)
    loads = jnp.asarray(rng.uniform(0.1, 2.0, size=E), jnp.float32)

    def body(carry, _):
        return eb.repair_capacity(carry, loads, num_ranks=R, cap=E // R), 0

    scanned, _ = jax.lax.scan(body, a, jnp.arange(1))
    eager = eb.repair_capacity(a, loads, num_ranks=R, cap=E // R)
    np.testing.assert_array_equal(np.asarray(scanned), np.asarray(eager))


# ------------------------------------------------------ strategy registry --


def test_plan_placement_accepts_registered_strategies():
    """plan_placement routes through the Strategy registry: the historic
    "greedy" alias, the registered "ep-greedy", and any diff-* name."""
    from repro.core import engine

    assert "ep-greedy" in engine.available()
    stats = _skewed_stats(seed=11)
    placement = (np.arange(16) // 4).astype(np.int32)
    # (diff-coord is registered too but needs coords, which expert
    # comm graphs don't carry)
    for name in ("greedy", "ep-greedy", "diff-comm",
                 "diff-comm+predictive"):
        new, info = eb.plan_placement(stats, placement, 4, strategy=name)
        assert (np.bincount(new, minlength=4) == 4).all(), name


def test_greedy_alias_matches_registered_greedy():
    stats = _skewed_stats(seed=13)
    placement = (np.arange(16) // 4).astype(np.int32)
    a, _ = eb.plan_placement(stats, placement, 4, strategy="greedy")
    b, _ = eb.plan_placement(stats, placement, 4, strategy="ep-greedy")
    np.testing.assert_array_equal(a, b)
