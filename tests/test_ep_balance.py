"""The paper's technique as a framework feature: MoE expert placement."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import ep_balance as eb


def _skewed_stats(E=16, k=2, seed=0, steps=5):
    stats = eb.ExpertStats(E, ema=0.5)
    rng = np.random.default_rng(seed)
    p = np.r_[np.full(4, 0.6 / 4), np.full(E - 4, 0.4 / (E - 4))]
    for _ in range(steps):
        ids = rng.choice(E, size=(512, k), p=p)
        stats.update(ids)
    return stats


def test_stats_update_counts_and_coactivation():
    stats = eb.ExpertStats(4, ema=0.0)
    ids = np.array([[0, 1], [0, 1], [2, 3]])
    stats.update(ids)
    assert stats.tokens[0] == 2 and stats.tokens[3] == 1
    assert stats.coact[0, 1] == 2 and stats.coact[1, 0] == 2
    assert stats.coact[2, 3] == 1
    assert stats.coact[0, 2] == 0


def test_plan_is_capacity_exact():
    stats = _skewed_stats()
    placement = (np.arange(16) // 4).astype(np.int32)
    new, info = eb.plan_placement(stats, placement, 4)
    counts = np.bincount(new, minlength=4)
    assert (counts == 4).all()


def test_plan_reduces_imbalance():
    stats = _skewed_stats()
    # adversarial initial: the 4 hot experts all on rank 0
    placement = (np.arange(16) // 4).astype(np.int32)
    before = stats.imbalance(placement, 4)
    new, info = eb.plan_placement(stats, placement, 4)
    after = stats.imbalance(new, 4)
    assert after < before
    assert info["moved_experts"] < 16, "diffusion must not move everything"


def test_diffusion_moves_fewer_experts_than_greedy():
    stats = _skewed_stats(seed=3)
    placement = (np.arange(16) // 4).astype(np.int32)
    d, di = eb.plan_placement(stats, placement, 4, strategy="diff-comm")
    g, gi = eb.plan_placement(stats, placement, 4, strategy="greedy")
    assert di["moved_experts"] <= gi["moved_experts"]


def test_perm_roundtrip():
    placement = np.array([1, 0, 0, 1, 2, 3, 3, 2], np.int32)
    perm = eb.placement_to_perm(placement, 4)
    # slot r*2+i holds a logical expert that placement maps to rank r
    for s, e in enumerate(perm):
        assert placement[e] == s // 2


def test_apply_perm_preserves_moe_semantics():
    """Permuted weights + permuted router columns == identical MoE output."""
    from repro.configs import get_arch
    from repro.models import moe as moe_mod
    from repro.models import transformer
    from repro.models.params import init_params

    cfg = get_arch("deepseek-v3-671b").reduced       # 8 experts, dense impl
    specs = transformer.model_specs(cfg)
    params = init_params(specs, 0)
    moe_params = jax.tree.map(lambda x: x[0], params["unit"][0]["moe"])

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))
    y0, _ = moe_mod.moe_dense(moe_params, cfg, x)

    perm = np.array([3, 1, 0, 2, 7, 6, 5, 4])
    permuted = eb.apply_perm_to_params(moe_params, perm)
    y1, _ = moe_mod.moe_dense(permuted, cfg, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)


def test_migration_bytes_counts_cross_rank_moves():
    old = np.arange(8)
    new = np.array([1, 0, 2, 3, 4, 5, 6, 7])      # swap within rank 0: free
    assert eb.migration_bytes(old, new, 100.0, 4) == 0.0
    new2 = np.array([2, 1, 0, 3, 4, 5, 6, 7])     # 0<->2 crosses ranks 0/1
    assert eb.migration_bytes(old, new2, 100.0, 4) == 200.0


def test_colocation_of_coactivated_experts():
    """Experts that always fire together should end colocated (ext/int)."""
    E, R = 8, 4
    stats = eb.ExpertStats(E, ema=0.0)
    # pairs (0,4), (1,5), (2,6), (3,7) co-activate; start split across ranks
    ids = np.array([[0, 4], [1, 5], [2, 6], [3, 7]] * 64)
    stats.update(ids)
    stats.tokens = stats.tokens + np.linspace(0, 1, E)  # break ties
    placement = np.array([0, 1, 2, 3, 0, 1, 2, 3], np.int32)  # already cheap
    new, info = eb.plan_placement(stats, placement, R)
    # already-colocated pairs with balanced load: nothing should move
    assert info["moved_experts"] == 0
