"""PIC PRK benchmark (paper §VI): driver-level behavior."""
import numpy as np
import pytest

from repro.pic import chares, driver
from repro.pic.particles import initialize


def _cfg(**kw):
    base = dict(L=100, n_particles=2000, steps=25, k=1, rho=0.9, cx=10,
                cy=10, num_pes=4, mapping="striped", lb_every=8,
                strategy="none", seed=0)
    base.update(kw)
    return driver.PICConfig(**base)


def test_particle_count_conserved():
    r = driver.run(_cfg())
    assert r.final_x.shape == (2000,)
    assert np.isfinite(r.final_x).all()


def test_geometric_distribution_skews_left():
    p = initialize("GEOMETRIC", 100, 20_000, rho=0.8, seed=0)
    left = (p.x < 25).mean()
    right = (p.x > 75).mean()
    assert left > 0.9 and right < 0.01


def test_initial_mapping_modes():
    a = chares.initial_mapping(12, 12, 4, "striped")
    b = chares.initial_mapping(12, 12, 4, "quad")
    assert a.shape == b.shape == (144,)
    assert set(a) == set(b) == {0, 1, 2, 3}
    # striped: contiguous thirds of chare columns; quad: 2x2 tiles
    assert (np.sort(np.bincount(a)) == 36).all()
    assert (np.sort(np.bincount(b)) == 36).all()


def test_chare_of_periodic_and_in_range():
    c = chares.chare_of(np.array([0.1, 99.9]), np.array([0.1, 99.9]),
                        100, 12, 12)
    assert (c >= 0).all() and (c < 144).all()


def test_lb_improves_particle_balance():
    r0 = driver.run(_cfg(strategy="none", steps=40, lb_every=8))
    r1 = driver.run(_cfg(strategy="diff-comm", steps=40, lb_every=8,
                         strategy_kwargs=dict(k=2)))
    assert r1.max_avg.mean() < r0.max_avg.mean()
    assert r1.migrations.max() > 0


def test_diffusion_lower_migration_than_greedy_global():
    r_d = driver.run(_cfg(strategy="diff-comm", steps=30,
                          strategy_kwargs=dict(k=2)))
    r_g = driver.run(_cfg(strategy="greedy", steps=30))
    assert (r_d.migrated_bytes.sum() <= r_g.migrated_bytes.sum())


def test_summary_reports_wall_and_comm_ratio():
    r = driver.run(_cfg(strategy="diff-comm", steps=20, lb_every=8,
                        strategy_kwargs=dict(k=2)))
    s = r.summary()
    # schema-stable additions: wall seconds + mean ext/int ratio
    for key in ("mean_max_avg", "mean_ext_bytes", "mean_ext_int",
                "total_migrated_bytes", "lb_seconds", "modeled_time",
                "wall_seconds"):
        assert key in s and np.isfinite(s[key]), key
    assert s["wall_seconds"] > 0
    assert s["mean_ext_int"] >= 0
    # hand-check the ratio definition on the recorded series
    with_int = r.int_bytes > 0
    expect = np.where(with_int, r.ext_bytes / np.where(with_int,
                                                       r.int_bytes, 1.0),
                      np.where(r.ext_bytes > 0, 1.0e6, 0.0)).mean()
    assert s["mean_ext_int"] == float(expect)


def test_build_problem_edges_follow_motion():
    loads = np.ones(16, np.float32)
    assign = chares.initial_mapping(4, 4, 2, "striped")
    prob = chares.build_problem(loads, assign, L=40, cx=4, cy=4, num_pes=2,
                                k=1, vy0=1.0, lb_period=5)
    prob.validate()
    assert prob.num_edges == 32            # east + north per chare
