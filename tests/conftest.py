"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device by design (the 512-device mesh lives only in launch/dryrun.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def ring_neighbors(P: int, hops: int = 1):
    """(P, 2*hops) ring neighbor table + mask (test helper)."""
    import numpy as np
    cols = []
    for h in range(1, hops + 1):
        cols += [(np.arange(P) - h) % P, (np.arange(P) + h) % P]
    nbr = np.stack(cols, axis=1).astype(np.int32)
    mask = np.ones_like(nbr, bool)
    return nbr, mask


def random_symmetric_graph(P: int, K: int, seed: int):
    """Random symmetric K-regular-ish padded neighbor table (test helper).

    Greedily pairs nodes until slots fill: whenever i lists j, j lists i,
    so the reverse-slot identity holds on every masked entry.  Returns
    (nbr (P, K) i32 -1-padded, mask (P, K) bool) as numpy arrays."""
    rng = np.random.default_rng(seed)
    nbr = np.full((P, K), -1, np.int32)
    mask = np.zeros((P, K), bool)
    deg = np.zeros(P, np.int64)
    for idx in rng.permutation(P * P):
        i, j = divmod(int(idx), P)
        if i >= j or deg[i] >= K or deg[j] >= K:
            continue
        nbr[i, deg[i]] = j
        nbr[j, deg[j]] = i
        mask[i, deg[i]] = mask[j, deg[j]] = True
        deg[i] += 1
        deg[j] += 1
    return nbr, mask
