"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests run on the single real
CPU device by design (the 512-device mesh lives only in launch/dryrun.py)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def ring_neighbors(P: int, hops: int = 1):
    """(P, 2*hops) ring neighbor table + mask (test helper)."""
    import numpy as np
    cols = []
    for h in range(1, hops + 1):
        cols += [(np.arange(P) - h) % P, (np.arange(P) + h) % P]
    nbr = np.stack(cols, axis=1).astype(np.int32)
    mask = np.ones_like(nbr, bool)
    return nbr, mask
