"""Mesh-sharded distributed planner vs the single-device engine.

The load-bearing guarantee: ``ShardedLBEngine.plan_fn`` — ppermute halo
exchanges in stage 2, psum-completed stage-1/3 reductions — must produce
the *same plan* as ``LBEngine.plan_fn``.  All data movement in the
sharded path is exact copies and the loop control is shared
(``virtual_lb.sweep_chunk_body``), so the only divergence source is fp
reassociation of the psum'd sums; on the integer-valued stencil
workloads the match is required bit-for-bit, and we assert exact
assignment equality on the float-loads PIC workload too (deterministic
on the pinned CPU jax).

In-process tests run on the default mesh (1 device under plain tier-1;
all 8 when the process is launched with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the CI
multi-device job).  The subprocess test *always* exercises the 8-virtual-
device mesh, so the 8-way parity is asserted in every CI run.
"""
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.core import api, comm_graph, engine
from repro.distributed import lb_shard
from repro.sim import stencil, synthetic


def _problem(P=16, grid=16):
    return synthetic.hotspot(stencil.stencil_2d(grid, grid, P), node=3,
                             factor=7.0)


# ------------------------------------------------- in-process (any D) --


def test_sharded_plan_matches_engine_bit_for_bit():
    prob = _problem()
    ref_a, ref_s = jax.jit(engine.get_engine(k=4).plan_fn)(prob)
    sh = lb_shard.get_sharded_engine(k=4)
    a, s = sh._jitted(prob)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ref_a))
    assert int(s.protocol_rounds) == int(ref_s.protocol_rounds)
    assert int(s.diffusion_iters) == int(ref_s.diffusion_iters)
    np.testing.assert_allclose(float(s.diffusion_residual),
                               float(ref_s.diffusion_residual), rtol=1e-5)
    np.testing.assert_allclose(float(s.unrealized_flow),
                               float(ref_s.unrealized_flow), rtol=1e-5)


def test_sharded_coord_variant_matches_engine():
    prob = _problem()
    ref_a, _ = jax.jit(engine.get_engine(variant="coord", k=4).plan_fn)(prob)
    a, _ = lb_shard.get_sharded_engine(variant="coord", k=4)._jitted(prob)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ref_a))


def test_sharded_strategy_registered_and_runs():
    assert "diff-comm-sharded" in engine.available()
    assert "diff-coord-sharded" in engine.available()
    prob = _problem()
    plan = api.run_strategy("diff-comm-sharded", prob, k=4)
    ref = api.run_strategy("diff-comm", prob, k=4)
    np.testing.assert_array_equal(plan.assignment, ref.assignment)
    assert plan.info["diffusion_iters"] == ref.info["diffusion_iters"]
    # the eager engine view reports its shard count
    eplan = lb_shard.get_sharded_engine(
        k=4, num_shards=lb_shard.best_shards(16)).plan(prob)
    assert eplan.info["num_shards"] == lb_shard.best_shards(16)
    np.testing.assert_array_equal(eplan.assignment, ref.assignment)


def test_sharded_hier_plan_two_level_placement():
    prob = _problem()
    sh = lb_shard.get_sharded_engine(k=4, threads_per_node=4)
    a, thread, _ = sh._jitted_hier(prob)
    eng = engine.get_engine(k=4, threads_per_node=4)
    a_ref, thr_ref, _ = jax.jit(eng.plan_hier_fn)(prob)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    np.testing.assert_array_equal(np.asarray(thread), np.asarray(thr_ref))


def test_best_shards_divides():
    for P in (4, 8, 12, 16, 20, 33):
        D = lb_shard.best_shards(P)
        assert 1 <= D <= len(jax.devices())
        assert P % D == 0


def test_sharded_engine_cache_hits_on_equivalent_config():
    e1 = lb_shard.get_sharded_engine(k=4, tol=0.02)
    e2 = lb_shard.get_sharded_engine(tol=0.02, k=4)
    assert e1 is e2
    assert lb_shard.get_sharded_engine(k=5) is not e1


def test_edge_and_object_padding_is_inert():
    # a problem whose N (70) and E (123) do not divide the shard count:
    # the zero-load object pad and (-1, -1, 0.0) edge pad must not
    # perturb the plan (compare against the engine on the same data)
    prob = synthetic.hotspot(stencil.stencil_2d(10, 7, 4, periodic=False),
                             node=1, factor=4.0)
    ref_a, _ = jax.jit(engine.get_engine(k=2).plan_fn)(prob)
    a, _ = lb_shard.get_sharded_engine(
        k=2, num_shards=lb_shard.best_shards(4))._jitted(prob)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(ref_a))


# ------------------------------------------- subprocess: 8-device mesh --

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np

from repro.core import engine
from repro.distributed import lb_shard
from repro.sim import scenarios, stencil, synthetic

assert len(jax.devices()) == 8, jax.devices()

# -- 1. stencil (integer loads/bytes): bit-for-bit over 8 shards ----------
prob = synthetic.hotspot(stencil.stencil_2d(16, 16, 16), node=3, factor=7.0)
ref_a, ref_s = jax.jit(engine.get_engine(k=4).plan_fn)(prob)
sh = lb_shard.get_sharded_engine(k=4)
assert sh.num_shards == 8, sh.num_shards
a, s = sh._jitted(prob)
np.testing.assert_array_equal(np.asarray(a), np.asarray(ref_a))
assert int(s.diffusion_iters) == int(ref_s.diffusion_iters)
np.testing.assert_allclose(float(s.diffusion_residual),
                           float(ref_s.diffusion_residual), rtol=1e-5)
print("stencil 8-way parity OK")

# -- 2. float-loads PIC chare problem: psum reassociation tolerance -------
p2, _ = scenarios.get("pic-geometric").instantiate(
    cx=8, cy=8, num_pes=8, n_particles=5000.0)
ra, rs = jax.jit(engine.get_engine(k=3).plan_fn)(p2)
sa, ss = lb_shard.get_sharded_engine(k=3)._jitted(p2)
np.testing.assert_array_equal(np.asarray(sa), np.asarray(ra))
assert int(ss.diffusion_iters) == int(rs.diffusion_iters)
print("pic 8-way parity OK")

# -- 3. coord variant ------------------------------------------------------
ca, _ = jax.jit(engine.get_engine(variant="coord", k=4).plan_fn)(prob)
sca, _ = lb_shard.get_sharded_engine(variant="coord", k=4)._jitted(prob)
np.testing.assert_array_equal(np.asarray(sca), np.asarray(ca))
print("coord 8-way parity OK")

# -- 4. P smaller than the mesh: best_shards drops to a divisor ----------
assert lb_shard.best_shards(4) == 4
p4 = synthetic.hotspot(stencil.stencil_2d(8, 8, 4), node=0, factor=5.0)
from repro.core import api
plan4 = api.run_strategy("diff-comm-sharded", p4, k=2)
ref4 = api.run_strategy("diff-comm", p4, k=2)
np.testing.assert_array_equal(plan4.assignment, ref4.assignment)
sub = lb_shard.get_sharded_engine(k=2, num_shards=4)
assert sub.num_shards == 4
np.testing.assert_array_equal(
    np.asarray(sub._jitted(p4)[0]), ref4.assignment)
print("submesh parity OK")

# -- 5. indivisible P raises -----------------------------------------------
try:
    lb_shard.get_sharded_engine(k=2)._jitted(
        synthetic.hotspot(stencil.stencil_2d(6, 6, 12), 0, 2.0))
    raise SystemExit("expected ValueError for P=12 on 8 shards")
except ValueError as e:
    assert "divide" in str(e)
print("divisibility check OK")
print("ALL OK")
"""


@pytest.mark.slow
def test_sharded_parity_on_8_virtual_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "ALL OK" in out.stdout
