"""Optional-hypothesis shim for the property-test modules.

When ``hypothesis`` is installed, re-exports the real ``given`` /
``settings`` / ``strategies``.  When it is missing (the pinned container
does not ship it), provides a deterministic fallback: each strategy yields
a small fixed set of representative samples (bounds, midpoints, a few
pseudo-random interior points) and ``@given`` runs the test once per
sample tuple.  This keeps every module importable and the property tests
meaningful as deterministic example sweeps rather than skipping the whole
file at collection.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class _strategies:
        @staticmethod
        def integers(min_value, max_value):
            span = max_value - min_value
            vals = {min_value, max_value, min_value + span // 2,
                    min_value + span // 3, min_value + (2 * span) // 3,
                    min_value + span // 7}
            return _Strategy(sorted(vals))

        @staticmethod
        def booleans():
            return _Strategy([False, True])

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy([min_value, max_value,
                              0.5 * (min_value + max_value)])

        @staticmethod
        def sampled_from(seq):
            return _Strategy(list(seq))

    st = _strategies()

    def settings(**_kw):
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        names = sorted(strategies)

        def deco(fn):
            def run():
                # zip-cycle rather than full product: len == max #samples,
                # every sample of every strategy appears at least once.
                n = max(len(strategies[k].samples) for k in names)
                for i in range(n):
                    ex = {k: strategies[k].samples[i % len(strategies[k].samples)]
                          for k in names}
                    fn(**ex)
            # plain attribute copy (functools.wraps would expose fn's
            # parameters via __wrapped__ and pytest would demand fixtures)
            run.__name__ = fn.__name__
            run.__doc__ = fn.__doc__
            run.__module__ = fn.__module__
            return run
        return deco
