"""Training substrate: optimizer, memorization, checkpoint resume."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import transformer
from repro.models.params import init_params
from repro.train import checkpoint as ckpt
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("smollm-135m").reduced
    specs = transformer.model_specs(cfg)
    params = init_params(specs, 0)
    ocfg = opt_mod.OptConfig(lr=3e-3, warmup_steps=5, total_steps=200,
                             weight_decay=0.0)
    step = jax.jit(ts_mod.make_train_step(cfg, ocfg))
    return cfg, params, ocfg, step


def _const_batch(cfg, B=4, S=24, seed=0):
    rng = np.random.default_rng(seed)
    toks = rng.integers(1, cfg.vocab_size, (B, S)).astype(np.int32)
    labels = np.concatenate([toks[:, 1:], np.full((B, 1), -1, np.int32)], 1)
    pos = np.broadcast_to(np.arange(S, dtype=np.int32)[None], (B, S))
    return dict(tokens=jnp.asarray(toks), labels=jnp.asarray(labels),
                positions=jnp.asarray(np.ascontiguousarray(pos)))


def test_memorizes_fixed_batch(setup):
    cfg, params, ocfg, step = setup
    opt = opt_mod.init(params)
    batch = _const_batch(cfg)
    losses = []
    for _ in range(60):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.5, f"no memorization: {losses[::10]}"


def test_lr_schedule_shape():
    ocfg = opt_mod.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                             min_lr_frac=0.1)
    lrs = [float(opt_mod.schedule(ocfg, jnp.int32(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1.0) < 1e-6
    assert lrs[3] < 1.0
    assert abs(lrs[4] - 0.1) < 1e-2


def test_grad_clipping_bounds_update():
    """Adam normalizes update magnitude to ~lr regardless of grad scale;
    clipping bounds the *reported* grad norm and protects the moments.
    Assert both invariants (a huge spike must not produce a step > lr)."""
    ocfg = opt_mod.OptConfig(lr=1e-2, clip_norm=1.0, warmup_steps=0,
                             total_steps=10, weight_decay=0.0)
    p = dict(w=jnp.ones((4, 4)))
    g = dict(w=jnp.full((4, 4), 1e6))
    st = opt_mod.init(p)
    p2, st2, m = opt_mod.apply(ocfg, p, g, st)
    assert float(m["grad_norm"]) == pytest.approx(4e6, rel=1e-3)
    assert float(jnp.abs(p2["w"] - p["w"]).max()) <= ocfg.lr * 1.01
    # clipped moments: v is bounded by the clipped grad square
    assert float(st2.nu["w"].max()) <= (1 - ocfg.b2) * (1.0 / 4) ** 2 * 1.01


def test_weight_decay_mask_skips_1d():
    ocfg = opt_mod.OptConfig(lr=1e-2, weight_decay=10.0, warmup_steps=0,
                             total_steps=10)
    p = dict(w=jnp.ones((4, 4)), b=jnp.ones((4,)))
    g = jax.tree.map(jnp.zeros_like, p)
    st = opt_mod.init(p)
    p2, *_ = opt_mod.apply(ocfg, p, g, st)
    assert float(jnp.abs(p2["b"] - 1.0).max()) < 1e-9, "1D: no decay"
    assert float(jnp.abs(p2["w"] - 1.0).max()) > 1e-4, "2D: decayed"


def test_checkpoint_resume_bit_exact(setup):
    cfg, params, ocfg, step = setup
    opt = opt_mod.init(params)
    batch = _const_batch(cfg, seed=1)

    # path A: 6 continuous steps
    pa, oa = params, opt
    for _ in range(6):
        pa, oa, _ = step(pa, oa, batch)

    # path B: 3 steps, save, restore, 3 more
    pb, ob = params, opt
    for _ in range(3):
        pb, ob, _ = step(pb, ob, batch)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, pb, ob)
        pb2, ob2, s, _ = ckpt.restore(d, pb, ob)
        assert s == 3
    for _ in range(3):
        pb2, ob2, _ = step(pb2, ob2, batch)

    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest():
    with tempfile.TemporaryDirectory() as d:
        p = dict(w=jnp.ones((2,)))
        for s in [1, 2, 3, 4, 5]:
            ckpt.save(d, s, p, keep=2)
        names = sorted(x for x in os.listdir(d) if x.startswith("ckpt_"))
        assert names == ["ckpt_00000004", "ckpt_00000005"]
        assert ckpt.latest_step(d) == 5


def test_data_pipeline_deterministic_and_rebalances():
    dcfg = data_mod.DataConfig(vocab_size=100, seq_len=16, global_batch=4,
                               num_shards=16, seed=7)
    p1 = data_mod.DataPipeline(dcfg, num_ranks=4)
    p2 = data_mod.DataPipeline(dcfg, num_ranks=4)
    b1, b2 = p1.next_batch(), p2.next_batch()
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    info = p1.maybe_rebalance(threshold=1.01)
    if info is not None:
        loads = p1.rank_loads()
        assert loads.max() / loads.mean() < 2.0


def test_grad_compress_error_feedback():
    from repro.distributed import grad_compress as gc
    rng = np.random.default_rng(0)
    g = dict(w=jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32)))
    res = gc.init_residual(g)
    # accumulate over steps: error feedback keeps the running sum faithful
    acc_true = np.zeros((64, 64))
    acc_comp = np.zeros((64, 64))
    for s in range(10):
        gs = dict(w=jnp.asarray(
            rng.normal(size=(64, 64)).astype(np.float32)))
        deq, res = gc.compress(gs, res)
        acc_true += np.asarray(gs["w"])
        acc_comp += np.asarray(deq["w"])
    rel = np.linalg.norm(acc_true - acc_comp) / np.linalg.norm(acc_true)
    assert rel < 0.05, f"error feedback diverged: {rel}"
    single = float(gc.compression_error(g, gc.init_residual(g)))
    assert single < 0.05
