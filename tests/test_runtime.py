"""Online rebalancing runtime: triggers, cost model, payload migration.

The load-bearing guarantees:

  * ``trigger="every"`` reproduces the legacy fixed-cadence replay
    **bit-for-bit** on both the host and scanned paths (the trigger
    emits the literal legacy predicate);
  * adaptive triggers fire on the same steps on both paths (shared
    ``load_stats`` expression graph);
  * executed migration conserves item count, bytes and per-item payload
    exactly — it is a permutation — on the single-device bucketed-gather
    path and the ``shard_map`` ``ppermute`` ring path, and the two
    layouts match bit-for-bit (subprocess-forced 8-virtual-device mesh,
    so the parity is asserted in every CI run);
  * PIC particle trajectories are invariant under executed migration
    (the push kernel is per-particle), so the rebalanced driver's
    restored ``final_x/final_y`` equal the never-balanced run's exactly.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine
from repro.pic import driver
from repro.runtime import cost as rt_cost
from repro.runtime import migrate as rt_migrate
from repro.runtime import triggers as rt
from repro.sim import scenarios, simulator


# ------------------------------------------------------------- triggers --


def _scan_decides(trig, ml_fn, steps=24, avg=10.0, total=80.0):
    """Fire pattern of ``trig`` over a scan with max_load = ml_fn(t)."""
    def step(s, t):
        do, s = trig.decide(s, t, jnp.float32(ml_fn(t)), jnp.float32(avg),
                            jnp.float32(total))
        return s, do
    _, dos = jax.lax.scan(step, trig.init_state(), jnp.arange(steps))
    return np.asarray(dos)


def test_every_trigger_matches_legacy_predicate():
    trig = rt.EveryTrigger(every=6)
    dos = _scan_decides(trig, lambda t: 10.0, steps=25)
    expect = np.array([t > 0 and t % 6 == 0 for t in range(25)])
    np.testing.assert_array_equal(dos, expect)


def test_every_trigger_disabled_cadence_is_never():
    assert rt.EveryTrigger(every=0).never
    assert rt.EveryTrigger(every=-3).never
    assert not rt.EveryTrigger(every=1).never
    assert rt.resolve(None, lb_every=0).never


def test_threshold_trigger_hysteresis_and_refractory():
    trig = rt.ThresholdTrigger(hi=1.2, lo=1.05, min_interval=2,
                               rearm_after=100)
    # imbalance permanently above hi and never below lo: fires once,
    # then stays disarmed (rearm_after out of reach)
    dos = _scan_decides(trig, lambda t: 15.0)
    assert dos.sum() == 1 and dos[1]
    # dropping below lo re-arms: fires on each new excursion above hi
    ml = lambda t: jnp.where(t % 8 < 2, 15.0, 10.0)   # noqa: E731
    dos = _scan_decides(trig, ml)
    assert dos.sum() >= 2
    fired_at = np.nonzero(dos)[0]
    assert (np.diff(fired_at) >= 2).all()        # min_interval respected


def test_threshold_trigger_rearm_after_retries():
    trig = rt.ThresholdTrigger(hi=1.2, lo=1.05, min_interval=1,
                               rearm_after=5)
    dos = _scan_decides(trig, lambda t: 15.0)
    fired_at = np.nonzero(dos)[0]
    assert len(fired_at) >= 3                    # keeps retrying
    assert (np.diff(fired_at) >= 5).all()


def test_predictive_trigger_amortizes_migration_cost():
    cheap = rt.PredictiveTrigger(
        cost=rt_cost.RuntimeCostModel(lb_overhead=1.0))
    dear = rt.PredictiveTrigger(
        cost=rt_cost.RuntimeCostModel(lb_overhead=1e9))
    rising = lambda t: 10.0 + 2.0 * t            # noqa: E731
    assert _scan_decides(cheap, rising).sum() > 0
    # same trend, but modeled migration cost can never amortize
    assert _scan_decides(dear, rising).sum() == 0
    # balanced workload (no excess): nothing to anticipate
    assert _scan_decides(cheap, lambda t: 10.0).sum() == 0


def test_triggers_are_hashable_cache_keys():
    assert hash(rt.ThresholdTrigger()) == hash(rt.ThresholdTrigger())
    assert rt.resolve("threshold", lb_every=5) is rt.resolve(
        "threshold", lb_every=5)
    assert rt.resolve("every", lb_every=7) == rt.EveryTrigger(every=7)


def test_resolve_rejects_unknown_specs():
    with pytest.raises(KeyError, match="unknown trigger"):
        rt.resolve("sometimes", lb_every=5)
    with pytest.raises(TypeError, match="Trigger instance"):
        rt.resolve(42, lb_every=5)


def test_resolve_prefers_strategy_registered_trigger():
    t = rt.resolve(None, lb_every=5, strategy_trigger="threshold")
    assert isinstance(t, rt.ThresholdTrigger)
    # explicit spec wins over the strategy's registration
    t = rt.resolve("every", lb_every=5, strategy_trigger="threshold")
    assert t == rt.EveryTrigger(every=5)


# ------------------------------------------------------------ cost model --


def test_cost_model_prices_and_bridges():
    m = rt_cost.RuntimeCostModel(t_load=2.0, t_byte=0.5, bytes_per_load=4.0,
                                 lb_overhead=7.0)
    assert float(m.imbalance_seconds(13.0, 10.0)) == pytest.approx(6.0)
    assert float(m.migration_seconds(10.0)) == pytest.approx(27.0)
    assert float(m.step_seconds(10.0, 5.0, 1.0)) == pytest.approx(37.0)
    pic = driver.CostModel()
    b = rt_cost.RuntimeCostModel.from_pic(
        pic, strategy="diff-comm", num_pes=8, bytes_per_particle=48.0,
        plan_seconds=0.8)
    assert b.t_load == pic.t_particle and b.bytes_per_load == 48.0
    assert b.lb_overhead == pytest.approx(0.1)   # diffusion: wall / P
    c = rt_cost.RuntimeCostModel.from_pic(
        pic, strategy="greedy", num_pes=8, bytes_per_particle=48.0,
        plan_seconds=0.8)
    assert c.lb_overhead == pytest.approx(0.8)   # centralized: full wall


def test_series_modeled_seconds_needs_runtime_records():
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    res = simulator.run_series(prob, evolve, steps=10, lb_every=3,
                               strategy="diff-comm",
                               strategy_kwargs=dict(k=2))
    s = rt_cost.series_modeled_seconds(res, rt_cost.RuntimeCostModel())
    assert s.shape == (10,) and np.isfinite(s).all()
    import dataclasses
    bare = dataclasses.replace(res, max_load=None)
    with pytest.raises(ValueError, match="max_load"):
        rt_cost.series_modeled_seconds(bare, rt_cost.RuntimeCostModel())


# ------------------------------------------------------ run_series wiring --


def test_run_series_every_trigger_is_bit_for_bit_legacy():
    prob, evolve = scenarios.get("bimodal-churn").instantiate(
        grid=8, num_nodes=4)
    kw = dict(steps=18, lb_every=5, strategy="diff-comm",
              strategy_kwargs=dict(k=2))
    for scan in (False, True):
        default = simulator.run_series(prob, evolve, scan=scan, **kw)
        explicit = simulator.run_series(prob, evolve, scan=scan,
                                        trigger="every", **kw)
        np.testing.assert_array_equal(default.max_avg, explicit.max_avg)
        np.testing.assert_array_equal(default.migrations,
                                      explicit.migrations)
        expect = np.array([float(t > 0 and t % 5 == 0)
                           for t in range(18)])
        np.testing.assert_array_equal(default.lb_fired, expect)


@pytest.mark.parametrize("trigger", ["threshold", "predictive"])
def test_run_series_adaptive_trigger_host_scan_parity(trigger):
    prob, evolve = scenarios.get("adversarial-hotspot").instantiate(
        grid=8, num_nodes=4)
    kw = dict(steps=20, lb_every=5, strategy="diff-comm",
              strategy_kwargs=dict(k=2), trigger=trigger)
    host = simulator.run_series(prob, evolve, scan=False, **kw)
    scan = simulator.run_series(prob, evolve, scan=True, **kw)
    np.testing.assert_array_equal(host.lb_fired, scan.lb_fired)
    np.testing.assert_allclose(host.max_avg, scan.max_avg, rtol=1e-4)
    np.testing.assert_allclose(host.migrated_load, scan.migrated_load,
                               rtol=1e-5)
    assert host.lb_fired.sum() > 0               # the policy does act


def test_trigger_wrapped_strategy_registration():
    for name in ("diff-comm+threshold", "diff-comm+predictive",
                 "diff-coord+threshold", "diff-coord+predictive"):
        strat = engine.get_strategy(name)
        assert strat.jittable and strat.trigger in ("threshold",
                                                    "predictive")
    prob, evolve = scenarios.get("bimodal-churn").instantiate(
        grid=8, num_nodes=4)
    kw = dict(steps=16, lb_every=4, strategy_kwargs=dict(k=2))
    wrapped = simulator.run_series(prob, evolve, strategy="diff-comm+threshold",
                                   **kw)
    explicit = simulator.run_series(prob, evolve, strategy="diff-comm",
                                    trigger="threshold", **kw)
    np.testing.assert_array_equal(wrapped.lb_fired, explicit.lb_fired)
    np.testing.assert_array_equal(wrapped.max_avg, explicit.max_avg)


def test_run_series_batch_refuses_trigger_wrapped_strategies():
    # the batched path has no per-lane trigger state: refuse rather than
    # silently downgrade the adaptive policy to the fixed cadence
    inst = scenarios.batch_instances(2, grid=8, num_nodes=4)
    with pytest.raises(ValueError, match="adaptive trigger"):
        simulator.run_series_batch(inst, steps=4, lb_every=2,
                                   strategy="diff-comm+threshold")


# ---------------------------------------------------- payload migration --


def _random_exchange(n=257, P=8, seed=0):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, P, n).astype(np.int32),
            rng.integers(0, P, n).astype(np.int32),
            rng.normal(size=n).astype(np.float32),
            np.arange(n, dtype=np.int32))


def test_migrate_conserves_count_bytes_and_payload():
    oo, on, x, ids = _random_exchange()
    (xr, idr), man = rt_migrate.migrate(oo, on, (x, ids), num_nodes=8)
    xr, idr = np.asarray(xr), np.asarray(idr)
    # count + payload identity: the relocation is a permutation
    np.testing.assert_array_equal(np.sort(idr), ids)
    np.testing.assert_array_equal(xr, x[idr])
    # bytes conservation: per-node recv totals sum to the item count
    send = np.asarray(man.send_counts)
    assert send.sum() == len(ids)
    np.testing.assert_array_equal(send.sum(axis=0), np.bincount(on, minlength=8))
    np.testing.assert_array_equal(send.sum(axis=1), np.bincount(oo, minlength=8))
    np.testing.assert_array_equal(np.asarray(man.moved), oo != on)
    assert int(man.moved_count) == int((oo != on).sum())
    assert int(man.moved_count) == int(send.sum() - np.trace(send))
    assert float(man.moved_bytes(48.0)) == 48.0 * (oo != on).sum()


def test_migrate_layout_is_bucketed_and_stable():
    oo, on, x, ids = _random_exchange(seed=3)
    (xr, idr), man = rt_migrate.migrate(oo, on, (x, ids), num_nodes=8)
    idr = np.asarray(idr)
    off = np.asarray(man.offsets)
    owner_sorted = on[idr]
    for p in range(8):
        seg = owner_sorted[off[p]:off[p + 1]]
        assert (seg == p).all()                  # contiguous slot regions
        # stable: original order preserved within each region
        assert (np.diff(idr[off[p]:off[p + 1]]) > 0).all()


def test_migrate_is_identity_for_settled_layout():
    on = np.repeat(np.arange(4, dtype=np.int32), 16)   # already bucketed
    x = np.arange(64, dtype=np.float32)
    (xr,), man = rt_migrate.migrate(on, on, (x,), num_nodes=4)
    np.testing.assert_array_equal(np.asarray(xr), x)
    assert int(man.moved_count) == 0
    assert float(man.moved_bytes(48.0)) == 0.0


def test_build_manifest_is_scan_and_cond_safe():
    oo, on, x, _ = _random_exchange(n=64)

    def gated(do, oo, on, x):
        return jax.lax.cond(
            do,
            lambda a: rt_migrate.apply_manifest(
                rt_migrate.build_manifest(oo, on, 8), a)[0],
            lambda a: a, x)

    moved = jax.jit(gated, static_argnums=())(jnp.asarray(True), oo, on, x)
    same = jax.jit(gated)(jnp.asarray(False), oo, on, x)
    np.testing.assert_array_equal(np.sort(np.asarray(moved)), np.sort(x))
    np.testing.assert_array_equal(np.asarray(same), x)


def test_inverse_permutation_roundtrip():
    order = np.asarray(rt_migrate.build_manifest(
        *_random_exchange(n=100)[:2], 8).order)
    inv = np.asarray(rt_migrate.inverse_permutation(order))
    np.testing.assert_array_equal(order[inv], np.arange(100))


def test_build_manifest_methods_bit_for_bit():
    """The sort and sort-free scatter builds (and whatever auto picks)
    produce the identical Manifest — the documented layout contract."""
    oo, on, _, _ = _random_exchange(n=321, seed=11)
    sort_m = rt_migrate.build_manifest(oo, on, 8, method="sort")
    scat_m = rt_migrate.build_manifest(oo, on, 8, method="scatter")
    auto_m = rt_migrate.build_manifest(oo, on, 8, method="auto")
    for got in (scat_m, auto_m):
        np.testing.assert_array_equal(np.asarray(got.order),
                                      np.asarray(sort_m.order))
        np.testing.assert_array_equal(np.asarray(got.offsets),
                                      np.asarray(sort_m.offsets))
        np.testing.assert_array_equal(np.asarray(got.send_counts),
                                      np.asarray(sort_m.send_counts))
        np.testing.assert_array_equal(np.asarray(got.moved),
                                      np.asarray(sort_m.moved))
    # the scatter build also exposes the inverse permutation for free
    assert sort_m.dest is None
    np.testing.assert_array_equal(
        np.asarray(scat_m.dest),
        np.asarray(rt_migrate.inverse_permutation(sort_m.order)))
    with pytest.raises(ValueError, match="unknown manifest method"):
        rt_migrate.build_manifest(oo, on, 8, method="bogus")


def test_build_and_apply_matches_two_step():
    oo, on, x, ids = _random_exchange(n=200, seed=5)
    man2 = rt_migrate.build_manifest(oo, on, 8, method="sort")
    want = rt_migrate.apply_manifest(man2, x, ids)
    for method in ("sort", "scatter", "auto"):
        (xr, idr), man = rt_migrate.build_and_apply(
            oo, on, (x, ids), num_nodes=8, method=method)
        np.testing.assert_array_equal(np.asarray(xr), np.asarray(want[0]))
        np.testing.assert_array_equal(np.asarray(idr), np.asarray(want[1]))
        np.testing.assert_array_equal(np.asarray(man.order),
                                      np.asarray(man2.order))


def test_repeated_migration_conserves_through_fused_apply():
    """Chained fused exchanges: payload multiset is preserved exactly at
    every round and the composition tracks a host-side oracle."""
    rng = np.random.default_rng(42)
    n, P = 180, 6
    owner = rng.integers(0, P, n).astype(np.int32)
    x0 = rng.normal(size=n).astype(np.float32)
    x = x0.copy()
    ids = np.arange(n, dtype=np.int32)
    oracle = ids.copy()
    for _round in range(5):
        owner_new = rng.integers(0, P, n).astype(np.int32)
        (x, ids, owner), man = rt_migrate.build_and_apply(
            owner, owner_new, (x, ids, owner_new), num_nodes=P,
            method="scatter")
        x, ids, owner = (np.asarray(a) for a in (x, ids, owner))
        oracle = oracle[np.argsort(owner_new, kind="stable")]
        np.testing.assert_array_equal(ids, oracle)
        np.testing.assert_array_equal(np.sort(ids), np.arange(n))
        # relocated payload still rides with its original item
        np.testing.assert_array_equal(x, x0[ids])
        off = np.asarray(man.offsets)
        assert off[-1] == n and (np.diff(off) >= 0).all()


def test_sharded_scatter_parity_with_masked_slabs():
    """ring_exchange's per-shard placement (now the shared sort-free
    counting-scatter op) with live-prefix masking reproduces the
    single-device manifest layout bit-for-bit on the default mesh — the
    multidevice CI job re-runs this at D=8."""
    from jax.sharding import Mesh, PartitionSpec as P_

    D = len(jax.devices())
    P = 4 * D
    cap = 32
    rng = np.random.default_rng(13)
    counts = rng.integers(1, cap + 1, D).astype(np.int32)
    owner = np.full((D, cap), P, np.int32)     # stale padding owners
    x = np.zeros((D, cap), np.float32)
    for d in range(D):
        owner[d, :counts[d]] = rng.integers(0, P, counts[d])
        x[d, :counts[d]] = rng.normal(size=counts[d])
    live_owner = np.concatenate([owner[d, :counts[d]] for d in range(D)])
    live_x = np.concatenate([x[d, :counts[d]] for d in range(D)])

    mesh = Mesh(np.asarray(jax.devices()), ("mg",))

    def body(cnt_loc, owner_loc, x_loc):
        oo, outs, cnt = rt_migrate.ring_exchange(
            owner_loc, (x_loc,), num_nodes=P, D=D, capacity=cap,
            axis="mg", count_loc=cnt_loc[0])
        return oo, outs[0], cnt[None]

    fn = jax.shard_map(
        body, mesh=mesh, in_specs=(P_("mg"),) * 3,
        out_specs=(P_("mg"),) * 3, check_vma=False)
    oo, xo, co = fn(counts, owner.reshape(-1), x.reshape(-1))
    co = np.asarray(co)
    oo, xo = np.asarray(oo), np.asarray(xo)
    got_owner = np.concatenate(
        [oo[d * cap:d * cap + co[d]] for d in range(D)])
    got_x = np.concatenate([xo[d * cap:d * cap + co[d]] for d in range(D)])
    (ref_x,), man = rt_migrate.migrate(
        live_owner, live_owner, (live_x,), num_nodes=P)
    np.testing.assert_array_equal(got_owner,
                                  live_owner[np.asarray(man.order)])
    np.testing.assert_array_equal(got_x, np.asarray(ref_x))


def test_migrate_sharded_matches_single_device_on_default_mesh():
    # any device count: D=1 degenerates to the plain bucketed gather; the
    # 8-way case is exercised in-process by the multidevice CI job and
    # always by the subprocess test below
    D = len(jax.devices())
    P = 8 * D
    n = 64 * D
    rng = np.random.default_rng(7)
    on = rng.integers(0, P, n).astype(np.int32)
    x = rng.normal(size=n).astype(np.float32)
    ids = np.arange(n, dtype=np.int32)
    (ref_x, ref_ids), _ = rt_migrate.migrate(on, on, (x, ids), num_nodes=P)
    owner_out, (xo, ido), counts = rt_migrate.migrate_sharded(
        on, (x, ids), num_nodes=P, capacity=n)
    counts = np.asarray(counts)
    assert counts.sum() == n                     # conservation
    xo, ido, oo_ = (np.asarray(a) for a in (xo, ido, owner_out))
    got_ids = np.concatenate(
        [ido[d * n:d * n + counts[d]] for d in range(D)])
    got_x = np.concatenate([xo[d * n:d * n + counts[d]] for d in range(D)])
    np.testing.assert_array_equal(got_ids, np.asarray(ref_ids))
    np.testing.assert_array_equal(got_x, np.asarray(ref_x))


def test_migrate_sharded_raises_on_capacity_overflow():
    D = len(jax.devices())
    n = 16 * D
    on = np.zeros(n, np.int32)            # every item lands on shard 0
    with pytest.raises(ValueError, match="capacity"):
        rt_migrate.migrate_sharded(
            on, (np.arange(n, dtype=np.float32),), num_nodes=D,
            capacity=8)


def test_migrate_sharded_validates_mesh_and_divisibility():
    from jax.sharding import Mesh
    with pytest.raises(ValueError, match="1-D mesh"):
        rt_migrate.migrate_sharded(
            np.zeros(8, np.int32), (np.zeros(8, np.float32),),
            num_nodes=8, capacity=8,
            mesh=Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                      ("a", "b")))
    if len(jax.devices()) > 1:       # indivisible n needs a real mesh
        with pytest.raises(ValueError, match="divide"):
            rt_migrate.migrate_sharded(
                np.zeros(7, np.int32), (np.zeros(7, np.float32),),
                num_nodes=len(jax.devices()), capacity=8)


# ------------------------------------------------- PIC executed migration --


def _pic_cfg(**kw):
    base = dict(L=100, n_particles=2000, steps=24, k=1, rho=0.9, cx=10,
                cy=10, num_pes=4, mapping="striped", lb_every=6,
                strategy="diff-comm", strategy_kwargs=dict(k=2), seed=0)
    base.update(kw)
    return driver.PICConfig(**base)


def test_pic_migration_preserves_trajectories_exactly():
    # the push kernel is per-particle, so executed migration (+ the
    # restore to id order) must leave every trajectory bit-identical to
    # a run that never rebalances
    ref = driver.run(_pic_cfg(strategy="none"))
    for scan in (True, False):
        r = driver.run(_pic_cfg(scan=scan))
        assert r.migrated_bytes.sum() > 0        # exchanges executed
        np.testing.assert_array_equal(r.final_x, ref.final_x)
        np.testing.assert_array_equal(r.final_y, ref.final_y)


def test_pic_migrated_bytes_measured_only_at_lb_steps():
    r = driver.run(_pic_cfg(scan=True))
    assert r.lb_steps is not None
    fired = r.lb_steps > 0
    assert (r.migrated_bytes[~fired] == 0).all()
    assert (r.migrations[~fired] == 0).all()
    expect = np.array([float(t > 0 and t % 6 == 0) for t in range(24)])
    np.testing.assert_array_equal(r.lb_steps, expect)


def test_pic_adaptive_trigger_host_scan_parity():
    rh = driver.run(_pic_cfg(scan=False, trigger="threshold"))
    rs = driver.run(_pic_cfg(scan=True, trigger="threshold"))
    np.testing.assert_array_equal(rh.lb_steps, rs.lb_steps)
    np.testing.assert_array_equal(rh.migrated_bytes, rs.migrated_bytes)
    np.testing.assert_allclose(rh.max_avg, rs.max_avg, rtol=1e-5)
    np.testing.assert_array_equal(rh.final_x, rs.final_x)


# ------------------------------------------- subprocess: 8-device mesh --

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np

from repro.distributed import lb_shard
from repro.runtime import migrate as rt_migrate
from repro.sim import stencil, synthetic

assert len(jax.devices()) == 8, jax.devices()

# -- 1. ring all-to-all vs single-device bucketed gather: bit-for-bit ----
rng = np.random.default_rng(11)
for P, n in ((8, 512), (16, 1024)):      # rpd = 1 and rpd = 2
    on = rng.integers(0, P, n).astype(np.int32)
    x = rng.normal(size=n).astype(np.float32)
    ids = np.arange(n, dtype=np.int32)
    (ref_x, ref_ids), _ = rt_migrate.migrate(on, on, (x, ids), num_nodes=P)
    owner_out, (xo, ido), counts = rt_migrate.migrate_sharded(
        on, (x, ids), num_nodes=P, capacity=n)
    counts = np.asarray(counts)
    assert counts.sum() == n, (counts, n)
    cap = n
    xo, ido = np.asarray(xo), np.asarray(ido)
    got_ids = np.concatenate(
        [ido[d * cap:d * cap + counts[d]] for d in range(8)])
    got_x = np.concatenate(
        [xo[d * cap:d * cap + counts[d]] for d in range(8)])
    np.testing.assert_array_equal(got_ids, np.asarray(ref_ids))
    np.testing.assert_array_equal(got_x, np.asarray(ref_x))
    # per-item payload identity under the exchange
    np.testing.assert_array_equal(got_x, x[got_ids])
print("ring all-to-all 8-way parity OK")

# -- 2. plan -> sharded apply through ShardedLBEngine ---------------------
prob = synthetic.hotspot(stencil.stencil_2d(16, 16, 8), node=3, factor=7.0)
sh = lb_shard.get_sharded_engine(k=4)
assignment, _ = sh._jitted(prob)
owner = np.asarray(assignment)[np.arange(prob.num_objects) % prob.num_objects]
payload = np.arange(prob.num_objects, dtype=np.float32)
owner_out, (po,), counts = sh.apply(
    np.asarray(assignment), (payload,), num_nodes=8,
    capacity=prob.num_objects)
counts = np.asarray(counts)
assert counts.sum() == prob.num_objects
(ref_p,), _ = rt_migrate.migrate(
    np.asarray(prob.assignment), np.asarray(assignment), (payload,),
    num_nodes=8)
cap = prob.num_objects
got = np.concatenate([np.asarray(po)[d * cap:d * cap + counts[d]]
                      for d in range(8)])
np.testing.assert_array_equal(got, np.asarray(ref_p))
print("sharded apply OK")
print("ALL OK")
"""


@pytest.mark.slow
def test_sharded_migration_on_8_virtual_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "ALL OK" in out.stdout
