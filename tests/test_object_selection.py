"""Stage 3 (paper §III.C): object selection invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core import comm_graph, object_selection as osel
from repro.sim import stencil, synthetic


def _toy_problem(P=4, per_node=8, seed=0):
    rng = np.random.default_rng(seed)
    N = P * per_node
    assignment = np.repeat(np.arange(P), per_node).astype(np.int32)
    loads = (rng.random(N) + 0.5).astype(np.float32)
    # chain edges between consecutive objects
    edges = np.stack([np.arange(N - 1), np.arange(1, N)], 1)
    ebytes = (rng.random(N - 1) * 10).astype(np.float32)
    coords = np.arange(N, dtype=np.float32)[:, None]
    return comm_graph.make_problem(loads, assignment, edges, ebytes, P,
                                   coords=coords)


def _ring_tables(P, k=2):
    nbr = np.stack([(np.arange(P) - 1) % P, (np.arange(P) + 1) % P], 1)
    return (jnp.asarray(nbr.astype(np.int32)),
            jnp.asarray(np.ones((P, 2), bool)))


def test_moves_only_to_confirmed_neighbors():
    prob = _toy_problem()
    nbr, mask = _ring_tables(4)
    flows = jnp.asarray(np.array([[3.0, 0], [0, 0], [0, 0], [0, 0]],
                                 np.float32))
    res = osel.select_objects(prob, nbr, mask, flows)
    a0 = np.asarray(prob.assignment)
    a1 = np.asarray(res.assignment)
    moved = a0 != a1
    # all moved objects were on node 0 and went to node 3 (slot 0 neighbor)
    assert set(a0[moved]) <= {0}
    assert set(a1[moved]) <= {3}


def test_budget_respected_within_one_object():
    prob = _toy_problem(seed=3)
    nbr, mask = _ring_tables(4)
    budget = 2.5
    flows = jnp.asarray(np.array([[budget, 0], [0, 0], [0, 0], [0, 0]],
                                 np.float32))
    res = osel.select_objects(prob, nbr, mask, flows)
    shipped = float(res.realized[0].sum())
    max_load = float(np.asarray(prob.loads).max())
    assert shipped <= budget + 0.5 * max_load + 1e-5, (
        "midpoint rule: overshoot bounded by half the largest object")


def test_object_single_hop():
    """An object moves at most once per LB round."""
    prob = _toy_problem(seed=4)
    nbr, mask = _ring_tables(4)
    flows = jnp.asarray(np.full((4, 2), 2.0, np.float32))
    res = osel.select_objects(prob, nbr, mask, flows)
    a0 = np.asarray(prob.assignment)
    a1 = np.asarray(res.assignment)
    moved = a0 != a1
    # every moved object landed on a direct neighbor of its source
    nbrs = np.asarray(nbr)
    for o in np.nonzero(moved)[0]:
        assert a1[o] in nbrs[a0[o]]


def test_comm_metric_prioritizes_communicating_objects():
    """Objects with heavy edges to the destination leave first (§III.C)."""
    P, per = 2, 6
    N = P * per
    assignment = np.repeat(np.arange(P), per).astype(np.int32)
    loads = np.ones(N, np.float32)
    # objects 0..5 on node 0; object 2 talks heavily to node 1's objects
    edges = np.array([[2, 6], [0, 1], [3, 4]], np.int32)
    ebytes = np.array([100.0, 1.0, 1.0], np.float32)
    prob = comm_graph.make_problem(loads, assignment, edges, ebytes, P)
    nbr = jnp.asarray(np.array([[1], [0]], np.int32))
    mask = jnp.ones((2, 1), bool)
    flows = jnp.asarray(np.array([[1.0], [0.0]], np.float32))
    res = osel.select_objects(prob, nbr, mask, flows, metric="comm")
    a1 = np.asarray(res.assignment)
    assert a1[2] == 1, "the heavy communicator must migrate first"


def test_coordinate_metric_moves_closest_objects():
    prob = _toy_problem(seed=5)
    nbr, mask = _ring_tables(4)
    flows = jnp.asarray(np.array([[0, 2.0], [0, 0], [0, 0], [0, 0]],
                                 np.float32))
    # node 0 sends to its slot-1 neighbor (node 1); coords are the line
    res = osel.select_objects(prob, nbr, mask, flows, metric="coord")
    a1 = np.asarray(res.assignment)
    moved = np.nonzero(a1 != np.asarray(prob.assignment))[0]
    if moved.size:
        # moved objects are those nearest node 1's centroid: the tail
        assert moved.min() >= 4, f"closest objects move first, got {moved}"


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), P=st.sampled_from([2, 4, 8]))
def test_property_realized_never_exceeds_flows_much(seed, P):
    prob = _toy_problem(P=P, per_node=6, seed=seed)
    nbr, mask = _ring_tables(P)
    rng = np.random.default_rng(seed)
    flows = jnp.asarray((rng.random((P, 2)) * 3).astype(np.float32))
    res = osel.select_objects(prob, nbr, mask, flows)
    realized = np.asarray(res.realized)
    want = np.maximum(np.asarray(flows), 0)
    max_load = float(np.asarray(prob.loads).max())
    assert (realized <= want + 0.5 * max_load + 1e-4).all()
    # load conservation at object level
    nl0 = np.bincount(np.asarray(prob.assignment),
                      weights=np.asarray(prob.loads), minlength=P)
    nl1 = np.bincount(np.asarray(res.assignment),
                      weights=np.asarray(prob.loads), minlength=P)
    np.testing.assert_allclose(nl0.sum(), nl1.sum(), rtol=1e-5)


def test_full_pipeline_reduces_imbalance_stencil():
    """A hotspot (strong *local* imbalance) must be diffused away.  Mild
    i.i.d. noise averages out per node and legitimately converges with no
    movement (neighborhood variance below tol — the paper's criterion), so
    the hotspot is the discriminating case."""
    from repro.core import api, metrics
    prob = stencil.stencil_2d(16, 16, 8, mapping="tiled")
    prob = synthetic.hotspot(prob, node=0, factor=4.0)
    before = metrics.evaluate(prob)
    plan = api.run_strategy("diff-comm", prob, k=4)
    assert plan.info["max_avg_load"] < before["max_avg_load"] * 0.8
