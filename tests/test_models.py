"""Per-arch smoke tests (assignment deliverable f): every reduced config
runs one forward/train step on CPU with shape + finiteness asserts, plus
decode-vs-forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_arch, list_archs, materialize_batch
from repro.models import transformer
from repro.models.params import count_params, init_params

ARCHS = list_archs()


def _batch(cfg, B=2, S=16, seed=0):
    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=S, global_batch=B)
    return materialize_batch(cfg, shape, seed=seed)["batch"]


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_arch(arch).reduced
    params = init_params(transformer.model_specs(cfg), 0)
    batch = _batch(cfg)
    h, _, aux = transformer.forward(params, cfg, batch)
    B = batch["positions"].shape[0]
    S = batch["positions"].shape[1]
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(h).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_loss_finite(arch):
    from repro.train import optimizer as opt_mod
    from repro.train import train_step as ts_mod
    cfg = get_arch(arch).reduced
    params = init_params(transformer.model_specs(cfg), 0)
    opt = opt_mod.init(params)
    step = jax.jit(ts_mod.make_train_step(
        cfg, opt_mod.OptConfig(warmup_steps=1, total_steps=10)))
    batch = _batch(cfg)
    p2, o2, m = step(params, opt, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert float(m["grad_norm"]) > 0
    # params actually changed
    diffs = [float(jnp.abs(a - b).max())
             for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2))]
    assert max(diffs) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Greedy decode logits == full-forward logits at the same positions."""
    cfg = get_arch(arch).reduced
    params = init_params(transformer.model_specs(cfg), 0)
    B, S = 2, 12
    batch = _batch(cfg, B=B, S=S, seed=3)
    h, _, _ = transformer.forward(params, cfg, batch)
    full_logits = transformer.logits_head(params, cfg, h)

    cache = transformer.init_cache(cfg, B, S + 4, jnp.float32)
    plen = S - 4
    if cfg.frontend == "vision_stub":
        pv = cfg.vision_prefix
        pre = dict(embeds=batch["embeds"], tokens=batch["tokens"][:, :plen - pv],
                   positions=batch["positions"][:, :plen])
    elif cfg.frontend == "audio_stub":
        pre = dict(embeds=batch["embeds"][:, :plen], tokens=None,
                   positions=batch["positions"][:, :plen])
    else:
        pre = dict(tokens=batch["tokens"][:, :plen],
                   positions=batch["positions"][:, :plen])
    logits_p, cache = transformer.prefill(params, cfg, pre, cache)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1]), np.asarray(full_logits[:, plen - 1]),
        rtol=2e-2, atol=2e-2)

    # step the remaining tokens one by one; compare to the full forward
    if cfg.frontend == "audio_stub":
        pytest.skip("audio stub decodes from embeds; covered by prefill check")
    toks = batch["tokens"]
    off = cfg.vision_prefix if cfg.frontend == "vision_stub" else 0
    for i in range(plen, S):
        tok = toks[:, i - off: i - off + 1]
        logits_d, cache = transformer.decode_step(
            params, cfg, tok, jnp.int32(i), cache)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, i]),
            rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_spec_counts(arch):
    """Full (non-reduced) configs: spec tree builds, params count in the
    right ballpark, and every layer kind is known.  No allocation."""
    spec = get_arch(arch)
    cfg = spec.config
    cfg.validate()
    specs = transformer.model_specs(cfg)
    n = count_params(specs)
    expected = {
        "smollm-135m": (0.09e9, 0.25e9),
        "gemma3-1b": (0.5e9, 1.6e9),
        "xlstm-125m": (0.06e9, 0.3e9),
        "hymba-1.5b": (1.0e9, 2.5e9),
        "paligemma-3b": (1.5e9, 3.5e9),
        "musicgen-medium": (1.0e9, 2.2e9),
        "gemma3-27b": (20e9, 32e9),
        "qwen1.5-110b": (90e9, 130e9),
        "llama4-scout-17b-a16e": (60e9, 120e9),
        "deepseek-v3-671b": (600e9, 720e9),
    }[arch]
    assert expected[0] < n < expected[1], f"{arch}: {n/1e9:.2f}B params"


def test_sliding_window_masks_far_tokens():
    cfg = get_arch("gemma3-1b").reduced
    params = init_params(transformer.model_specs(cfg), 0)
    B, S = 1, 24
    b1 = _batch(cfg, B=B, S=S, seed=0)
    t2 = np.asarray(b1["tokens"]).copy()
    t2[:, 0] = (t2[:, 0] + 1) % cfg.vocab_size   # perturb a far-away token
    b2 = dict(b1, tokens=jnp.asarray(t2))
    h1, _, _ = transformer.forward(params, cfg, b1)
    h2, _, _ = transformer.forward(params, cfg, b2)
    # token 0 is outside every sliding window of the last position only if
    # S - 1 - 0 >= window for all-local stacks; gemma has global layers, so
    # just assert *some* effect exists near and none is NaN
    assert bool(jnp.isfinite(h1).all() and jnp.isfinite(h2).all())


def test_moe_dense_vs_a2a_path_flagging():
    """Without a mesh, moe auto falls back to the dense path and matches
    the explicitly-dense result."""
    from repro.models import moe as moe_mod
    cfg = get_arch("llama4-scout-17b-a16e").reduced
    params = init_params(transformer.model_specs(cfg), 0)
    batch = _batch(cfg, B=2, S=8)
    h1, _, _ = transformer.forward(params, cfg, batch)
    assert bool(jnp.isfinite(h1).all())
