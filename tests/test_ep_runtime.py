"""Live expert rebalancing runtime (train/ep_runtime.py).

The load-bearing guarantees:

  * the scanned replay and the host-loop replay agree **bit-for-bit** —
    fire steps, imbalance records, placements, slot layouts and moved
    weight bytes (they execute the same jnp expression graphs);
  * every executed exchange conserves the expert population exactly
    (``slot_expert`` stays a permutation, payload rows are preserved as
    a set) and keeps the placement capacity-exact;
  * the predictive trigger's gate amortizes against the **measured**
    moved bytes of the previous exchange, not a model;
  * :func:`ep_runtime.execute_placement` relocates real MoE parameters
    (expert weights + router columns) without changing the layer's
    function, single-device and — in the subprocess-forced 8-device
    test — through the ``shard_map`` ring exchange bit-for-bit;
  * the :class:`ep_runtime.EPRebalancer` drives all of it from the
    train-step metrics (``launch/train.py``'s integration point).
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import ep_balance
from repro.runtime import cost as rt_cost
from repro.runtime import triggers as rt_triggers
from repro.train import ep_runtime as epr

W = epr.RoutingWorkload(num_experts=32, num_ranks=4, tokens_per_step=256,
                        trace_len=24, seed=1)


# ------------------------------------------------------------ replay core --


def test_scan_host_parity_bitforbit():
    a = epr.run_ep_replay(W, steps=24, strategy="diff-comm", lb_every=6)
    b = epr.run_ep_replay(W, steps=24, strategy="diff-comm", lb_every=6,
                          scan=False)
    assert a.scanned and not b.scanned
    np.testing.assert_array_equal(a.lb_fired, b.lb_fired)
    np.testing.assert_array_equal(a.max_avg, b.max_avg)
    np.testing.assert_array_equal(a.moved_experts, b.moved_experts)
    np.testing.assert_array_equal(a.moved_bytes, b.moved_bytes)
    np.testing.assert_array_equal(a.final_placement, b.final_placement)
    np.testing.assert_array_equal(a.final_slot_expert, b.final_slot_expert)
    np.testing.assert_array_equal(a.final_wsig, b.final_wsig)


def test_exchange_conserves_experts_and_capacity():
    r = epr.run_ep_replay(W, steps=24, strategy="diff-comm", lb_every=6)
    assert r.lb_fired.sum() > 0, "cadence trigger must fire"
    E, R = W.num_experts, W.num_ranks
    # slot_expert stays a permutation of the expert ids
    assert sorted(r.final_slot_expert) == list(range(E))
    # placement stays capacity-exact
    assert (np.bincount(r.final_placement, minlength=R) == E // R).all()
    # payload rows survive every exchange as an exact set
    np.testing.assert_allclose(
        np.sort(r.final_wsig, axis=0), np.sort(np.asarray(epr._sig0(E)), 0))
    # slot layout consistent with the placement: slot s sits on rank
    # s // cap and holds an expert the placement maps there
    cap = E // R
    rank_of = r.final_placement[r.final_slot_expert]
    np.testing.assert_array_equal(rank_of, np.arange(E) // cap)


def test_moved_bytes_are_executed_volume():
    r = epr.run_ep_replay(W, steps=24, strategy="diff-comm", lb_every=6)
    np.testing.assert_allclose(r.moved_bytes,
                               r.moved_experts * W.weight_bytes)
    fired = r.lb_fired.astype(bool)
    assert (r.moved_experts[~fired] == 0).all()


def test_rebalancing_reduces_skew():
    """With a drifting hotspot, the cadence-triggered diffusion replay
    must end less imbalanced than never rebalancing."""
    w = epr.RoutingWorkload(num_experts=32, num_ranks=4, hot_amp=8.0,
                            tokens_per_step=512, trace_len=32, seed=3)
    never = epr.run_ep_replay(w, steps=32, strategy="none")
    lb = epr.run_ep_replay(w, steps=32, strategy="diff-comm", lb_every=4)
    assert lb.max_avg[-8:].mean() < never.max_avg[-8:].mean()


def test_predictive_gate_uses_measured_bytes():
    """Pricing weight bytes up must make the predictive trigger fire
    less: the gate reads the measured volume of the last exchange."""
    kw = dict(steps=32, strategy="diff-comm")
    cheap = epr.run_ep_replay(W, trigger=rt_triggers.PredictiveTrigger(
        cost=rt_cost.RuntimeCostModel(t_byte=1e-6)), **kw)
    dear = epr.run_ep_replay(W, trigger=rt_triggers.PredictiveTrigger(
        cost=rt_cost.RuntimeCostModel(t_byte=0.5, lb_overhead=50.0)), **kw)
    assert dear.lb_fired.sum() < cheap.lb_fired.sum()
    assert cheap.lb_fired.sum() > 0


def test_greedy_baseline_moves_more():
    """The registered capacity-capped greedy rebalances from scratch
    every fire; diffusion moves incrementally."""
    d = epr.run_ep_replay(W, steps=24, strategy="diff-comm", lb_every=6)
    g = epr.run_ep_replay(W, steps=24, strategy="greedy", lb_every=6)
    assert not g.scanned                     # host baseline path
    assert d.total_moved_bytes <= g.total_moved_bytes


def test_trace_workload_replays_like_source():
    trace = epr.record_routing(W, steps=24)
    a = epr.run_ep_replay(W, steps=24, strategy="diff-comm", lb_every=6)
    b = epr.run_ep_replay(trace, steps=24, strategy="diff-comm",
                          lb_every=6)
    np.testing.assert_array_equal(a.lb_fired, b.lb_fired)
    np.testing.assert_array_equal(a.final_placement, b.final_placement)


# --------------------------------------------------- real-weight exchange --


def _tiny_moe():
    from repro.configs import get_arch
    from repro.models import transformer
    from repro.models.params import init_params

    cfg = get_arch("deepseek-v3-671b").reduced     # 8 experts, dense impl
    specs = transformer.model_specs(cfg)
    params = init_params(specs, 0)
    moe_params = jax.tree.map(lambda x: x[0], params["unit"][0]["moe"])
    return cfg, moe_params


def test_execute_placement_preserves_moe_semantics():
    """Relocating expert weights + router columns through the executed
    manifest keeps the MoE layer's function identical."""
    from repro.models import moe as moe_mod

    cfg, moe_params = _tiny_moe()
    E, R = cfg.moe.num_experts, 4
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))
    y0, _ = moe_mod.moe_dense(moe_params, cfg, x)

    se = np.arange(E, dtype=np.int32)
    newp = np.asarray([2, 0, 1, 0, 3, 1, 2, 3], np.int32)
    layers, se2, moved, moved_b = epr.execute_placement(
        [moe_params], se, newp, num_ranks=R)
    assert moved > 0
    assert moved_b == moved * epr.expert_param_bytes([moe_params])
    # the physical layout changed but the function didn't
    y1, _ = moe_mod.moe_dense(layers[0], cfg, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)
    # layout contract: slot s now holds an expert newp maps to rank s//2
    np.testing.assert_array_equal(newp[np.asarray(se2)],
                                  np.arange(E) // (E // R))
    # shared-expert tensors are not per-slot payload and must not move
    np.testing.assert_array_equal(np.asarray(layers[0]["shared_wi"]),
                                  np.asarray(moe_params["shared_wi"]))


def test_execute_placement_stacked_layout():
    """The launcher path relocates stacked (G-leading) unit params."""
    from repro.configs import get_arch
    from repro.models import transformer
    from repro.models.params import init_params

    cfg = get_arch("deepseek-v3-671b").reduced
    params = init_params(transformer.model_specs(cfg), 0)
    stacked = params["unit"][0]["moe"]             # leaves lead with G
    E = cfg.moe.num_experts
    se = np.arange(E, dtype=np.int32)
    newp = np.asarray([1, 0, 3, 2, 1, 0, 3, 2], np.int32)
    layers, se2, moved, _ = epr.execute_placement(
        [stacked], se, newp, num_ranks=4)
    for k in ("wi", "wg", "wo", "router"):
        assert layers[0][k].shape == stacked[k].shape, k
    # per-group slices relocated exactly like the unstacked layer
    g0 = jax.tree.map(lambda x: x[0], stacked)
    l0, se2b, _, _ = epr.execute_placement([g0], se, newp, num_ranks=4)
    np.testing.assert_array_equal(np.asarray(se2), np.asarray(se2b))
    for k in ("wi", "wg", "wo", "router"):
        np.testing.assert_array_equal(np.asarray(layers[0][k][0]),
                                      np.asarray(l0[0][k]), err_msg=k)


def test_rebalancer_consumes_train_metrics():
    """EPRebalancer: device-collected router stats in, executed
    relocation + measured-byte observe out."""
    from repro.models import moe as moe_mod

    cfg, moe_params = _tiny_moe()
    # R=2: with 8 experts and rank capacity 2 the diffusion flow per
    # edge is below any hot expert's load and nothing can move — the
    # object-granularity limit, not what this test is about
    E, R = cfg.moe.num_experts, 2
    reb = epr.EPRebalancer(E, R, strategy="diff-comm", trigger="every",
                           lb_every=2, ema=0.0)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))
    y0, _ = moe_mod.moe_dense(moe_params, cfg, x)
    layers = [moe_params]
    bpe = epr.expert_param_bytes(layers)
    fired_bytes = []
    for t in range(6):
        # synthetic skew: experts 0..2 hot, co-activation flat (so the
        # load term, not colocation affinity, drives the plan)
        counts = np.full(E, 10.0)
        counts[:3] += 500.0
        coact = np.ones((E, E)) - np.eye(E)
        # stats arrive keyed by *physical slot* — permute accordingly
        layers, info = reb.step(t, counts[reb.slot_expert],
                                coact[np.ix_(reb.slot_expert,
                                             reb.slot_expert)], layers)
        if info["fired"]:
            fired_bytes.append(info["moved_bytes"])
            assert info["moved_bytes"] == info["moved_experts"] * bpe
    assert fired_bytes, "the cadence trigger must fire"
    assert any(b > 0 for b in fired_bytes), "the hot experts must move"
    # function preserved through every executed relocation
    y1, _ = moe_mod.moe_dense(layers[0], cfg, x)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-4, atol=2e-4)
    # logical placement stayed capacity-exact
    assert (np.bincount(reb.placement, minlength=R) == E // R).all()


def test_rebalancer_feeds_trigger_measured_bytes():
    """A predictive rebalancer's trigger state carries the measured
    volume of the last *executed* exchange, in load units."""
    cfg, moe_params = _tiny_moe()
    E, R = cfg.moe.num_experts, 2
    trig = rt_triggers.PredictiveTrigger(
        cost=rt_cost.RuntimeCostModel(t_byte=1e-9), min_interval=1)
    reb = epr.EPRebalancer(E, R, strategy="diff-comm", trigger=trig,
                           ema=0.0)
    assert float(reb.tstate.last_moved) < 0          # cold start
    layers = [moe_params]
    last_fired = None
    for t in range(8):
        counts = np.full(E, 1.0)
        hot = (np.arange(3) + t // 3) % E            # drifting hot block
        counts[hot] += 500.0
        coact = np.ones((E, E)) - np.eye(E)
        layers, info = reb.step(t, counts[reb.slot_expert],
                                coact[np.ix_(reb.slot_expert,
                                             reb.slot_expert)], layers)
        if info["fired"]:
            last_fired = info
    assert last_fired is not None, "predictive trigger must fire"
    assert float(reb.tstate.last_moved) >= 0
    assert float(reb.tstate.last_moved) * reb.bytes_per_load == \
        pytest.approx(last_fired["moved_bytes"])


def test_routing_skew_scenario_registered():
    from repro.sim import scenarios

    prob, evolve = scenarios.get("routing-skew").instantiate(
        num_experts=32, num_ranks=4, tokens_per_step=256, trace_len=12)
    assert int(prob.loads.shape[0]) == 32 and prob.num_nodes == 4
    p1 = evolve(prob, jnp.int32(3))
    assert p1.loads.shape == prob.loads.shape
    assert p1.edges_bytes.shape == prob.edges_bytes.shape
    assert bool(jnp.all(p1.loads > 0))


# ------------------------------------------- subprocess: 8-device mesh --

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.train import ep_runtime as epr

assert len(jax.devices()) == 8, jax.devices()

w = epr.RoutingWorkload(num_experts=32, num_ranks=8, tokens_per_step=256,
                        trace_len=16, seed=2)
r1 = epr.run_ep_replay(w, steps=8, strategy="diff-comm", lb_every=3,
                       scan=False)
r8 = epr.run_ep_replay(w, steps=8, strategy="diff-comm", lb_every=3,
                       num_shards=8)
assert r8.sharded and r1.lb_fired.sum() > 0
np.testing.assert_array_equal(r1.lb_fired, r8.lb_fired)
np.testing.assert_array_equal(r1.moved_bytes, r8.moved_bytes)
np.testing.assert_array_equal(r1.final_placement, r8.final_placement)
np.testing.assert_array_equal(r1.final_slot_expert, r8.final_slot_expert)
np.testing.assert_array_equal(r1.final_wsig, r8.final_wsig)
print("sharded replay parity OK")

# real-weight ring exchange on the model axis == single-device manifest
rng = np.random.default_rng(0)
E, D_, F = 16, 6, 10
moe = dict(wi=rng.normal(size=(E, D_, F)).astype(np.float32),
           wg=rng.normal(size=(E, D_, F)).astype(np.float32),
           wo=rng.normal(size=(E, F, D_)).astype(np.float32),
           router=rng.normal(size=(D_, E)).astype(np.float32),
           shared_wi=rng.normal(size=(D_, F)).astype(np.float32))
se = np.arange(E, dtype=np.int32)
newp = np.repeat(np.arange(4), 4)[
    np.argsort(rng.normal(size=E), kind="stable")].astype(np.int32)
l1, se1, m1, b1 = epr.execute_placement([moe], se, newp, num_ranks=4)
mesh = Mesh(np.array(jax.devices()[:4]), ("mig",))
l2, se2, m2, b2 = epr.execute_placement([moe], se, newp, num_ranks=4,
                                        mesh=mesh)
np.testing.assert_array_equal(np.asarray(se1), np.asarray(se2))
for k in moe:
    np.testing.assert_array_equal(np.asarray(l1[0][k]),
                                  np.asarray(l2[0][k]), err_msg=k)
assert m1 == m2 and b1 == b2 and m1 > 0
print("ring weight exchange parity OK")
print("ALL OK")
"""


@pytest.mark.slow
def test_ep_runtime_on_8_virtual_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "ALL OK" in out.stdout
