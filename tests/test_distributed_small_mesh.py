"""Multi-device correctness on an 8-device host mesh (subprocess — the
main pytest process must keep the single real CPU device).

Validates the production sharding paths numerically:
  * moe a2a (shard_map + all_to_all) == dense one-hot oracle
  * sharded train step == single-device train step (same loss)
  * dp sharding profile compiles and matches 2d numerically
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding

from repro.configs import get_arch
from repro.distributed import sharding as shard_rules
from repro.models import moe as moe_mod
from repro.models import transformer
from repro.models.params import init_params
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod

mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)

# ---- 1. moe a2a vs dense oracle ------------------------------------------
cfg = get_arch("deepseek-v3-671b").reduced
cfg = dataclasses.replace(
    cfg, moe=dataclasses.replace(cfg.moe, num_experts=8, top_k=2,
                                 capacity_factor=8.0, impl="a2a"))
specs = transformer.model_specs(cfg)
params = init_params(specs, 0)
moe_params = jax.tree.map(lambda x: x[0], params["unit"][0]["moe"])
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)).astype(np.float32))

y_dense, aux_d = moe_mod.moe_dense(moe_params, cfg, x)
with jax.sharding.set_mesh(mesh):
    y_a2a, aux_a = jax.jit(
        lambda p, x: moe_mod.moe_a2a(p, cfg, x))(moe_params, x)
err = float(jnp.max(jnp.abs(y_dense - y_a2a)))
scale = float(jnp.max(jnp.abs(y_dense))) + 1e-9
assert err / scale < 2e-2, f"a2a vs dense mismatch: {err} vs {scale}"
print("moe a2a == dense OK", err / scale)

# ---- 2. sharded train step == unsharded ----------------------------------
cfg2 = get_arch("smollm-135m").reduced
specs2 = transformer.model_specs(cfg2)
params2 = init_params(specs2, 0)
ocfg = opt_mod.OptConfig(warmup_steps=1, total_steps=10)
opt2 = opt_mod.init(params2)
B, S = 4, 16
toks = rng.integers(1, cfg2.vocab_size, (B, S)).astype(np.int32)
batch = dict(tokens=jnp.asarray(toks),
             labels=jnp.asarray(np.concatenate(
                 [toks[:, 1:], np.full((B, 1), -1, np.int32)], 1)),
             positions=jnp.asarray(np.ascontiguousarray(np.broadcast_to(
                 np.arange(S, dtype=np.int32)[None], (B, S)))))
step = ts_mod.make_train_step(cfg2, ocfg)
_, _, m_ref = jax.jit(step)(params2, opt2, batch)

pshard = shard_rules.param_shardings(specs2, mesh)
oshard = shard_rules.opt_shardings(pshard, mesh)
bshard = shard_rules.data_shardings(
    jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch),
    mesh)
with jax.sharding.set_mesh(mesh):
    fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                 out_shardings=(pshard, oshard, None))
    p_s = jax.device_put(params2, pshard)
    o_s = jax.device_put(opt2, oshard)
    b_s = jax.device_put(batch, bshard)
    _, _, m_shard = fn(p_s, o_s, b_s)
d = abs(float(m_ref["loss"]) - float(m_shard["loss"]))
assert d < 5e-2, f"sharded loss differs: {m_ref['loss']} vs {m_shard['loss']}"
print("sharded train step OK", d)

# ---- 3. dp profile --------------------------------------------------------
cfg3 = dataclasses.replace(cfg2, sharding_profile="dp")
pshard3 = shard_rules.param_shardings(specs2, mesh, "dp")
bshard3 = shard_rules.data_shardings(
    jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch),
    mesh, "dp")
step3 = ts_mod.make_train_step(cfg3, ocfg)
with jax.sharding.set_mesh(mesh):
    fn3 = jax.jit(step3, in_shardings=(pshard3, oshard, bshard3),
                  out_shardings=(pshard3, oshard, None))
    _, _, m_dp = fn3(jax.device_put(params2, pshard3), o_s,
                     jax.device_put(batch, bshard3))
d3 = abs(float(m_ref["loss"]) - float(m_dp["loss"]))
assert d3 < 5e-2, f"dp loss differs: {m_dp['loss']}"
print("dp profile OK", d3)

# ---- 4. bf16 params + fp32 master ----------------------------------------
cfg4 = dataclasses.replace(cfg2, param_dtype="bfloat16")
specs4 = transformer.model_specs(cfg4)
params4 = init_params(specs4, 0)
opt4 = opt_mod.init(params4, master_fp32=True)
ocfg4 = opt_mod.OptConfig(warmup_steps=1, total_steps=10, master_fp32=True)
step4 = jax.jit(ts_mod.make_train_step(cfg4, ocfg4))
l0 = None
p4, o4 = params4, opt4
for i in range(8):
    p4, o4, m4 = step4(p4, o4, batch)
    if l0 is None:
        l0 = float(m4["loss"])
assert float(m4["loss"]) < l0 + 0.1, "bf16-param training must not diverge"
assert o4.master is not None
print("bf16 params + master OK", l0, float(m4["loss"]))
print("ALL OK")
"""


@pytest.mark.slow
def test_small_mesh_distributed_paths():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "ALL OK" in out.stdout
