"""Fault tolerance: supervised restarts, heartbeats, straggler balancing."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt
from repro.train import fault_tolerance as ft


def test_run_resilient_recovers_and_completes():
    state = dict(x=0.0, saved=(0, 0.0))
    fail_at = {7, 13}          # injected worker deaths

    def step_fn(step):
        if step in fail_at:
            fail_at.discard(step)
            raise ft.WorkerFailure(f"injected at {step}")
        state["x"] += 1.0

    def save_fn(step):
        state["saved"] = (step, state["x"])

    def restore_fn():
        step, x = state["saved"]
        state["x"] = x
        return step

    out = ft.run_resilient(step_fn, start_step=0, num_steps=20,
                           save_every=5, save_fn=save_fn,
                           restore_fn=restore_fn)
    assert out["final_step"] == 20
    assert out["restarts"] == 2
    assert state["x"] == 20.0, "recovered run must be exactly-once in effect"


def test_run_resilient_gives_up_after_max_restarts():
    def step_fn(step):
        raise ft.WorkerFailure("always")

    with pytest.raises(ft.WorkerFailure):
        ft.run_resilient(step_fn, start_step=0, num_steps=5, save_every=5,
                         save_fn=lambda s: None, restore_fn=lambda: 0,
                         max_restarts=3)


def test_resilient_training_bit_exact_after_crash():
    """End-to-end: crash mid-training, restore from disk, identical result."""
    from repro.configs import get_arch
    from repro.models import transformer
    from repro.models.params import init_params
    from repro.train import optimizer as opt_mod
    from repro.train import train_step as ts_mod

    cfg = get_arch("smollm-135m").reduced
    params0 = init_params(transformer.model_specs(cfg), 0)
    opt0 = opt_mod.init(params0)
    step = jax.jit(ts_mod.make_train_step(
        cfg, opt_mod.OptConfig(warmup_steps=2, total_steps=50)))
    rngb = np.random.default_rng(0)
    B, S = 2, 16
    batches = []
    for _ in range(10):
        t = rngb.integers(1, cfg.vocab_size, (B, S)).astype(np.int32)
        lbl = np.concatenate([t[:, 1:], np.full((B, 1), -1, np.int32)], 1)
        pos = np.ascontiguousarray(
            np.broadcast_to(np.arange(S, dtype=np.int32)[None], (B, S)))
        batches.append(dict(tokens=jnp.asarray(t), labels=jnp.asarray(lbl),
                            positions=jnp.asarray(pos)))

    # ground truth: 10 clean steps
    p, o = params0, opt0
    for b in batches:
        p, o, _ = step(p, o, b)
    truth = jax.tree.leaves(p)

    with tempfile.TemporaryDirectory() as d:
        run = dict(p=params0, o=opt0)
        crashed = dict(left=1)

        def step_fn(s):
            if s == 6 and crashed["left"]:
                crashed["left"] -= 1
                raise ft.WorkerFailure("boom")
            run["p"], run["o"], _ = step(run["p"], run["o"], batches[s])

        def save_fn(s):
            ckpt.save(d, s, run["p"], run["o"])

        def restore_fn():
            run["p"], run["o"], s, _ = ckpt.restore(d, run["p"], run["o"])
            return s

        save_fn(0)
        out = ft.run_resilient(step_fn, start_step=0, num_steps=10,
                               save_every=2, save_fn=save_fn,
                               restore_fn=restore_fn)
        assert out["restarts"] == 1
        for a, b in zip(truth, jax.tree.leaves(run["p"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_heartbeat_detects_dead_hosts():
    mon = ft.HeartbeatMonitor(num_hosts=8, timeout_steps=2)
    for step in range(6):
        for h in range(8):
            if h == 3 and step >= 2:
                continue               # host 3 dies at step 2
            mon.beat(h, step)
    assert mon.dead_hosts(current_step=5) == [3]
    assert mon.healthy_mesh_size(5) == 4   # largest pow2 <= 7


def test_straggler_balancer_sheds_from_slow_host():
    bal = ft.StragglerBalancer(num_hosts=4, shards_per_host=8)
    times = np.array([1.0, 1.0, 1.0, 2.0])
    info = None
    for _ in range(30):
        info = bal.observe(times) or info
    assert info is not None, "persistent straggler must trigger"
    share = bal.host_share()
    assert share[3] < 0.25, f"slow host keeps {share[3]:.2f} of the data"
    assert abs(share.sum() - 1.0) < 1e-9


def test_straggler_balancer_ignores_noise():
    bal = ft.StragglerBalancer(num_hosts=4, shards_per_host=8, ema=0.9)
    rng = np.random.default_rng(0)
    fired = False
    for _ in range(20):
        times = np.ones(4) + rng.normal(0, 0.02, 4)
        fired = fired or (bal.observe(times) is not None)
    assert not fired, "2% noise must not trigger data movement"
