"""Comparison strategies (paper §V.C): behavioral contracts per strategy."""
import numpy as np
import pytest

from repro.core import api, baselines, comm_graph, metrics
from repro.sim import stencil, synthetic


@pytest.fixture(scope="module")
def prob3d():
    p = stencil.stencil_3d(8, 8, 8, 8, mapping="striped")
    return synthetic.mod7(p)


def test_greedy_balances_but_migrates_everything(prob3d):
    a = baselines.greedy(prob3d)
    m = metrics.evaluate(prob3d, a)
    assert m["max_avg_load"] < 1.05
    assert m["pct_migrations"] > 0.5


def test_greedy_refine_balances_with_few_migrations(prob3d):
    a = baselines.greedy_refine(prob3d)
    m = metrics.evaluate(prob3d, a)
    assert m["max_avg_load"] < 1.1
    assert m["pct_migrations"] < 0.3


def test_metis_like_balanced_partition(prob3d):
    a = baselines.metis_like(prob3d)
    m = metrics.evaluate(prob3d, a)
    assert m["max_avg_load"] < 1.15
    # every node non-empty
    assert len(np.unique(a)) == prob3d.num_nodes


def test_metis_migrates_heavily_but_cuts_well(prob3d):
    """The paper's METIS signature: near-total migration, good locality."""
    a = baselines.metis_like(prob3d)
    m = metrics.evaluate(prob3d, a)
    init = metrics.evaluate(prob3d)
    assert m["pct_migrations"] > 0.5
    assert m["ext_int_comm"] < init["ext_int_comm"] * 1.2


def test_parmetis_fewer_migrations_than_metis(prob3d):
    am = baselines.metis_like(prob3d)
    ap = baselines.parmetis_like(prob3d)
    mm = metrics.evaluate(prob3d, am)
    mp = metrics.evaluate(prob3d, ap)
    assert mp["pct_migrations"] < mm["pct_migrations"]
    assert mp["max_avg_load"] < 1.15


def test_parmetis_itr_knob_controls_migration(prob3d):
    lo = baselines.parmetis_like(prob3d, itr=10_000.0)   # migration expensive
    hi = baselines.parmetis_like(prob3d, itr=1.0)        # migration cheap
    m_lo = metrics.evaluate(prob3d, lo)["pct_migrations"]
    m_hi = metrics.evaluate(prob3d, hi)["pct_migrations"]
    assert m_lo <= m_hi + 1e-9


def test_strategy_registry_runs_everything():
    prob = stencil.stencil_2d(12, 12, 4)
    prob = synthetic.random_pm(prob, 0.4)
    for name in api.STRATEGIES:
        kw = dict(k=2) if name.startswith("diff") else {}
        plan = api.run_strategy(name, prob, **kw)
        assert plan.assignment.shape == (prob.num_objects,)
        assert (plan.assignment >= 0).all()
        assert (plan.assignment < prob.num_nodes).all()


def test_rcb_partition_balanced():
    rng = np.random.default_rng(0)
    coords = rng.random((256, 2))
    w = np.ones(256)
    part = baselines._rcb(coords, w, 8)
    counts = np.bincount(part, minlength=8)
    assert counts.max() - counts.min() <= 2
