"""Stage 1 (paper §III.A): protocol invariants, incl. hypothesis sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core import neighbor_selection as ns


def _run(pref, k, **kw):
    res = ns.select_neighbors(jnp.asarray(pref, jnp.float32), k=k, **kw)
    nbr = np.asarray(res.nbr_idx)
    mask = np.asarray(res.nbr_mask)
    deg = np.asarray(res.degree)
    return nbr, mask, deg, res


def _edges(nbr, mask):
    P = nbr.shape[0]
    out = set()
    for i in range(P):
        for k in range(nbr.shape[1]):
            if mask[i, k]:
                out.add((i, int(nbr[i, k])))
    return out


def dense_pref(P, rng, symmetric=True):
    m = rng.random((P, P)) + 0.1
    if symmetric:
        m = (m + m.T) / 2
    np.fill_diagonal(m, 0.0)
    return m


def test_degree_bound_and_symmetry():
    rng = np.random.default_rng(1)
    pref = dense_pref(12, rng)
    nbr, mask, deg, _ = _run(pref, k=4)
    assert (deg <= 4).all()
    e = _edges(nbr, mask)
    assert all((j, i) in e for (i, j) in e), "confirmed pairs must be mutual"
    assert all(i != j for (i, j) in e)


def test_full_degree_mostly_reached_with_enough_candidates():
    """The paper's protocol terminates at a bounded iteration count and
    does NOT guarantee degree K (handshake parity can strand a node at
    K-1); assert the paper's actual contract: ≤K always, ≥K-1 with a full
    candidate set, and the large majority saturated."""
    rng = np.random.default_rng(2)
    pref = dense_pref(16, rng)
    _, _, deg, res = _run(pref, k=4)
    assert (deg <= 4).all()
    assert (deg >= 3).all(), f"complete candidates: deg ≥ K-1, got {deg}"
    assert (deg == 4).mean() >= 0.75


def test_fewer_candidates_than_k():
    # ring comm graph: only 2 candidates each, ask for K=4
    P = 8
    pref = np.zeros((P, P))
    for i in range(P):
        pref[i, (i + 1) % P] = pref[i, (i - 1) % P] = 1.0
    _, _, deg, _ = _run(pref, k=4)
    assert (deg == 2).all(), "degree is capped by candidate count"


def test_prefers_high_comm_neighbors():
    # star weights: node 0 communicates hugely with 1, 2; K=2
    P = 6
    rng = np.random.default_rng(3)
    pref = dense_pref(P, rng) * 0.01
    pref[0, 1] = pref[1, 0] = 100.0
    pref[0, 2] = pref[2, 0] = 90.0
    nbr, mask, _, _ = _run(pref, k=2)
    chosen = {int(n) for n, m in zip(nbr[0], mask[0]) if m}
    assert chosen == {1, 2}


def test_comm_preference_keeps_zero_comm_as_last_resort():
    node_comm = np.zeros((4, 4), np.float32)
    node_comm[0, 1] = node_comm[1, 0] = 5.0
    pref = np.asarray(ns.comm_preference(jnp.asarray(node_comm)))
    assert pref[0, 1] > pref[0, 2] > 0, "zero-comm stays positive (epsilon)"
    assert pref[0, 0] == 0


def test_coordinate_preference_orders_by_distance():
    cent = jnp.asarray([[0.0, 0.0], [1.0, 0.0], [5.0, 0.0]])
    pref = np.asarray(ns.coordinate_preference(cent))
    assert pref[0, 1] > pref[0, 2]


@settings(max_examples=25, deadline=None)
@given(
    P=st.integers(4, 24),
    k=st.integers(1, 6),
    seed=st.integers(0, 10_000),
)
def test_property_degree_bound(P, k, seed):
    rng = np.random.default_rng(seed)
    pref = dense_pref(P, rng)
    # random sparsity: drop ~half the candidate pairs
    drop = rng.random((P, P)) < 0.5
    drop = drop | drop.T
    pref[drop] = 0.0
    nbr, mask, deg, _ = _run(pref, k=k)
    assert (deg <= k).all()
    e = _edges(nbr, mask)
    assert all((j, i) in e for (i, j) in e)
    # degree equals mask count
    assert (mask.sum(1) == deg).all()


@settings(max_examples=10, deadline=None)
@given(P=st.integers(4, 16), seed=st.integers(0, 100))
def test_property_protocol_terminates(P, seed):
    rng = np.random.default_rng(seed)
    pref = dense_pref(P, rng)
    *_, res = _run(pref, k=3, max_rounds=64)
    assert int(res.rounds) < 64, "protocol must converge well before the cap"
