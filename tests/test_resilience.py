"""Resilient sharded replay: faults, guardrails, spill, checkpointing.

The load-bearing guarantees:

  * an **empty / never-active ``FaultSchedule`` changes nothing** — the
    replay entries normalize an empty schedule to the exact
    pre-resilience code path, and a schedule whose first event lies
    beyond the horizon exercises the full resilient trace with all-alive
    masks yet stays bit-for-bit the plain trajectory;
  * a **dead shard is evacuated with zero payload loss**: the sim replay
    ends with no object owned by a dead node, and the PIC replay keeps
    every particle exactly once (final positions equal the LB-free run —
    the push physics never depended on the assignment);
  * ``validate_plan`` **accepts every plan the engine produces** and
    rejects structurally broken ones (out-of-range or dead owners,
    non-finite loads, capacity violations) — property-tested through
    the ``tests._hyp`` shim;
  * the **spill exchange never drops payload**: admissions respect the
    capacity fixed point, deferred items keep their desired owner and
    drain on later fires;
  * the **checkpointed driver is bit-exact** with the one-shot scan,
    with and without injected supervisor failures, composed with fault
    schedules or not.

In-process tests degrade to a 1-device mesh; the subprocess test forces
an 8-virtual-device mesh so the genuinely distributed failure modes
(dead shard among live peers, sharded spill) are asserted in CI.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.core import comm_graph
from repro.core import engine as core_engine
from repro.pic import driver as pic_driver
from repro.runtime import migrate as rt_migrate
from repro.runtime import resilience as rz
from repro.runtime import triggers as rt_triggers
from repro.sim import scenarios, simulator


# --------------------------------------------------------- FaultSchedule --


def test_fault_schedule_validates_events():
    with pytest.raises(ValueError, match="unknown fault kind"):
        rz.FaultSchedule(events=((1, 0, "explode"),))
    with pytest.raises(ValueError, match="non-negative"):
        rz.FaultSchedule(events=((-1, 0, "die"),))
    with pytest.raises(ValueError, match="duplicate"):
        rz.FaultSchedule(events=((3, 1, "die"), (3, 1, "recover")))
    with pytest.raises(ValueError, match="slow_factor"):
        rz.FaultSchedule(events=((1, 0, "slow"),), slow_factor=0.0)
    assert rz.FaultSchedule().empty
    assert rz.FaultSchedule().max_shard() == -1
    assert rz.FaultSchedule(events=((2, 3, "die"),)).max_shard() == 3


def test_fault_schedule_health_projection():
    fs = rz.FaultSchedule(
        events=((5, 1, "die"), (9, 1, "recover"), (3, 0, "slow")),
        slow_factor=0.25)
    alive, speed = (np.asarray(v) for v in fs.shard_health(2, 2))
    assert alive.tolist() == [True, True] and speed.tolist() == [1.0, 1.0]
    alive, speed = (np.asarray(v) for v in fs.shard_health(6, 2))
    assert alive.tolist() == [True, False]
    assert speed.tolist() == [0.25, 1.0]
    alive, speed = (np.asarray(v) for v in fs.shard_health(9, 2))
    assert alive.tolist() == [True, True]      # recovered at its step
    # transitions fire exactly at event steps
    assert bool(fs.changed_at(5, 2)) and bool(fs.changed_at(3, 2))
    assert bool(fs.changed_at(9, 2))
    assert not bool(fs.changed_at(6, 2)) and not bool(fs.changed_at(0, 2))
    # node-level broadcast follows the contiguous shard→node ownership
    alive_n, speed_n = (np.asarray(v) for v in fs.node_health(6, 4, 2))
    assert alive_n.tolist() == [True, True, False, False]
    assert speed_n.tolist() == [0.25, 0.25, 1.0, 1.0]


def test_fault_schedule_is_scan_safe_pure_function():
    # same (t, D) → same health whether called eagerly or under jit
    fs = rz.FaultSchedule(events=((4, 0, "die"), (7, 0, "recover")))
    eager = [np.asarray(fs.shard_health(t, 2)[0]) for t in range(10)]
    jitted = jax.jit(lambda t: fs.shard_health(t, 2)[0])
    traced = [np.asarray(jitted(t)) for t in range(10)]
    np.testing.assert_array_equal(np.stack(eager), np.stack(traced))


# ------------------------------------------------- health-masked planning --


def _tiny_problem(num_nodes=4):
    return comm_graph.make_problem(
        loads=np.array([1.0, 2.0, 3.0, 4.0], np.float32),
        assignment=np.array([0, 1, 2, 3], np.int32),
        edges=np.array([[0, 1], [2, 3]]),
        edge_bytes=np.array([5.0, 1.0], np.float32),
        num_nodes=num_nodes)


def test_rehome_dead_prefers_comm_partner():
    prob = _tiny_problem()
    # node 1 dies; object 1 talks to object 0 (owner 0) → goes to node 0
    out = np.asarray(rz.rehome_dead(prob, jnp.array([1, 0, 1, 1], bool)))
    assert out.tolist() == [0, 0, 2, 3]


def test_rehome_dead_falls_back_to_least_loaded():
    loads = np.array([9.0, 1.0, 1.0, 1.0], np.float32)
    prob = comm_graph.make_problem(
        loads=loads, assignment=np.array([0, 0, 1, 2], np.int32),
        edges=np.array([[0, 1]]), edge_bytes=np.array([1.0], np.float32),
        num_nodes=4)
    # node 2's object has no alive comm partner → least-loaded alive node
    out = np.asarray(rz.rehome_dead(prob, jnp.array([1, 1, 0, 1], bool)))
    assert out[3] == 3      # node loads: 10, 1, dead, 0 → node 3
    assert out[:3].tolist() == [0, 0, 1]


def test_rehome_dead_all_dead_is_noop():
    prob = _tiny_problem()
    out = np.asarray(rz.rehome_dead(prob, jnp.zeros(4, bool)))
    assert out.tolist() == [0, 1, 2, 3]


def test_mask_preference_identity_when_all_alive():
    pref = jnp.arange(16.0).reshape(4, 4)
    np.testing.assert_array_equal(
        np.asarray(rz.mask_preference(pref, jnp.ones(4, bool))),
        np.asarray(pref))
    masked = np.asarray(rz.mask_preference(pref, jnp.array([1, 0, 1, 1],
                                                           bool)))
    assert (masked[1, :] == 0).all() and (masked[:, 1] == 0).all()


def test_load_stats_masked_matches_unmasked_when_healthy():
    loads = jnp.array([1.0, 2.0, 3.0, 4.0])
    assignment = jnp.array([0, 1, 2, 3], jnp.int32)
    mx, av, tot = rt_triggers.load_stats(loads, assignment, 4)
    mxm, avm, totm = rt_triggers.load_stats_masked(
        loads, assignment, 4, jnp.ones(4, bool))
    assert float(mx) == float(mxm)
    assert float(av) == pytest.approx(float(avm))
    assert float(tot) == float(totm)


def test_load_stats_masked_excludes_dead_and_scales_slow():
    loads = jnp.array([1.0, 2.0, 3.0, 10.0])
    assignment = jnp.array([0, 1, 2, 3], jnp.int32)
    alive = jnp.array([1, 1, 1, 0], bool)
    mx, av, tot = rt_triggers.load_stats_masked(loads, assignment, 4,
                                                alive)
    assert float(mx) == 3.0                      # dead node 3 excluded
    assert float(av) == pytest.approx(6.0 / 3.0)  # averaged over alive
    assert float(tot) == 16.0                    # true total kept
    _, _, _ = rt_triggers.load_stats_masked(
        loads, assignment, 4, jnp.ones(4, bool),
        speed=jnp.array([1.0, 1.0, 1.0, 0.5]))
    mx2, _, _ = rt_triggers.load_stats_masked(
        loads, assignment, 4, jnp.ones(4, bool),
        speed=jnp.array([1.0, 1.0, 1.0, 0.5]))
    assert float(mx2) == 20.0                    # slow node looks heavier


def test_engine_plan_health_fn_avoids_dead_nodes():
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    prob = evolve(prob, 3)
    eng = core_engine.get_engine(variant="comm", k=2)
    alive = jnp.array([1, 0, 1, 1], bool)
    a, _stats = eng.plan_health_fn(prob, alive)
    a = np.asarray(a)
    assert not np.isin(a, [1]).any()
    assert bool(rz.validate_plan(a, prob.loads, num_nodes=4, alive=alive))
    # alive=None is exactly plan_fn
    a0, _ = eng.plan_health_fn(prob, None)
    a1, _ = eng.plan_fn(prob)
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))


# ----------------------------------------------------------- validate_plan --


@settings(max_examples=25, deadline=None)
@given(num_nodes=st.integers(min_value=1, max_value=12),
       n=st.integers(min_value=1, max_value=64),
       seed=st.integers(min_value=0, max_value=999))
def test_validate_plan_accepts_valid_assignments(num_nodes, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, num_nodes, size=n).astype(np.int32)
    loads = rng.uniform(0.1, 5.0, size=n).astype(np.float32)
    assert bool(rz.validate_plan(a, loads, num_nodes=num_nodes))
    assert bool(rz.validate_plan(a, loads, num_nodes=num_nodes,
                                 alive=np.ones(num_nodes, bool),
                                 node_capacity=n))


@settings(max_examples=25, deadline=None)
@given(num_nodes=st.integers(min_value=2, max_value=12),
       n=st.integers(min_value=2, max_value=64),
       seed=st.integers(min_value=0, max_value=999),
       mode=st.sampled_from(["range_low", "range_high", "dead", "nan",
                             "capacity"]))
def test_validate_plan_rejects_broken_assignments(num_nodes, n, seed,
                                                  mode):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, num_nodes, size=n).astype(np.int32)
    loads = rng.uniform(0.1, 5.0, size=n).astype(np.float32)
    alive = None
    cap = None
    if mode == "range_low":
        a[rng.integers(n)] = -1
    elif mode == "range_high":
        a[rng.integers(n)] = num_nodes
    elif mode == "dead":
        dead = int(rng.integers(num_nodes))
        alive = np.ones(num_nodes, bool)
        alive[dead] = False
        a[rng.integers(n)] = dead
    elif mode == "nan":
        loads[rng.integers(n)] = np.nan
    elif mode == "capacity":
        a[:] = 0                      # all n objects on node 0
        cap = n - 1
    assert not bool(rz.validate_plan(a, loads, num_nodes=num_nodes,
                                     alive=alive, node_capacity=cap))


def test_validate_plan_rejects_non_vector_assignment_at_trace_time():
    with pytest.raises(ValueError, match="dense"):
        rz.validate_plan(jnp.zeros((2, 2), jnp.int32), jnp.ones(4),
                         num_nodes=2)


def test_finite_or_and_finite_loads():
    v = jnp.array([1.0, np.nan, np.inf, -2.0])
    out = np.asarray(rz.finite_or(v, 7.0))
    assert out.tolist() == [1.0, 7.0, 7.0, -2.0]
    guarded = np.asarray(scenarios.finite_loads(
        jnp.array([2.0, np.nan, np.inf, 0.0])))
    assert guarded[0] == 2.0 and guarded[1] == guarded[2] == 1e-3
    assert guarded[3] == np.float32(1e-3)
    # bitwise identity for finite in-range loads
    clean = jnp.array([1.0, 5.5, 20.0], jnp.float32)
    np.testing.assert_array_equal(np.asarray(scenarios.finite_loads(clean)),
                                  np.asarray(clean))


# ------------------------------------------------------------------ spill --


@settings(max_examples=25, deadline=None)
@given(P=st.integers(min_value=2, max_value=6),
       cap=st.integers(min_value=4, max_value=24),
       seed=st.integers(min_value=0, max_value=999))
def test_spill_admissions_fixed_point(P, cap, seed):
    rng = np.random.default_rng(seed)
    occ = rng.integers(0, cap + 1, size=P).astype(np.int32)
    flow = np.zeros((P, P), np.int32)
    for s in range(P):
        out_total = int(rng.integers(0, occ[s] + 1))
        dsts = rng.integers(0, P, size=out_total)
        for d in dsts:
            if d != s:
                flow[s, d] += 1
    A = np.asarray(rt_migrate.spill_admissions(flow, occ, cap))
    F = flow * (1 - np.eye(P, dtype=np.int32))
    assert (A >= 0).all() and (A <= F).all()        # admits subset of flow
    post = occ - A.sum(1) + A.sum(0)
    assert (post <= cap).all()                      # capacity respected
    # feasible flows are admitted unchanged
    if (occ - F.sum(1) + F.sum(0) <= cap).all():
        np.testing.assert_array_equal(A, F)


def test_spill_owner_conserves_and_drains():
    # 6 of node0's 8 items want node1 (occupancy 8, capacity 8): only the
    # 2 outgoing slots freed by node1's leavers are admissible
    oo = jnp.asarray(np.array([0] * 8 + [1] * 8, np.int32))
    want = np.array([1] * 6 + [0] * 2 + [0] * 2 + [1] * 6, np.int32)
    eff, dfr = rt_migrate.spill_owner(oo, jnp.asarray(want), num_nodes=2,
                                      capacity=8)
    eff, dfr = np.asarray(eff), np.asarray(dfr)
    counts = np.bincount(eff, minlength=2)
    assert counts.sum() == 16                       # nothing dropped
    assert (counts <= 8).all()
    assert dfr.sum() == 4                           # 6 wanted, 2 slots
    # deferred items keep their desired owner and drain at the next fire
    # once capacity allows
    eff2, dfr2 = rt_migrate.spill_owner(jnp.asarray(eff),
                                        jnp.asarray(want), num_nodes=2,
                                        capacity=12)
    eff2, dfr2 = np.asarray(eff2), np.asarray(dfr2)
    assert dfr2.sum() == 0
    np.testing.assert_array_equal(eff2, want)


def test_migrate_eager_capacity_error_is_structured():
    oo = np.zeros(8, np.int32)
    on = np.array([0, 0, 0, 1, 1, 1, 1, 1], np.int32)
    arrays = [np.arange(8, dtype=np.float32)]
    out, man = rt_migrate.migrate(oo, on, arrays, num_nodes=2, capacity=5)
    assert np.diff(np.asarray(man.offsets)).tolist() == [3, 5]
    with pytest.raises(rt_migrate.CapacityOverflowError,
                       match="capacity") as ei:
        rt_migrate.migrate(oo, on, arrays, num_nodes=2, capacity=4)
    err = ei.value
    assert err.capacity == 4 and err.unit == "node"
    assert err.counts == [3, 5] and err.offending == [1]
    assert "node ids [1]" in str(err)


def test_migrate_sharded_spill_single_device():
    # 1-device mesh: spill degenerates to per-node spill_owner semantics
    on = np.array([1] * 7 + [0], np.int32)
    arrays = [np.arange(8, dtype=np.float32)]
    with pytest.raises(ValueError, match="occupancy"):
        rt_migrate.migrate_sharded(on, arrays, num_nodes=2, capacity=4,
                                   on_overflow="spill")
    owner, outs, counts, deferred = rt_migrate.migrate_sharded(
        on, arrays, num_nodes=2, capacity=8, on_overflow="spill")
    assert deferred == 0                # one shard: everything stays local
    assert int(np.asarray(counts).sum()) == 8


def test_ring_exchange_rejects_unknown_mode():
    with pytest.raises(ValueError, match="on_overflow"):
        rt_migrate.migrate_sharded(np.zeros(4, np.int32), [np.zeros(4)],
                                   num_nodes=2, on_overflow="drop")


# --------------------------------------------------- replay integration --


def _series_kw(**over):
    kw = dict(steps=16, lb_every=4, strategy="diff-comm",
              strategy_kwargs=dict(k=2))
    kw.update(over)
    return kw


SERIES_FIELDS = ("max_avg", "ext_int", "migrations", "lb_fired",
                 "max_load", "migrated_load", "final_assignment")


def _assert_series_equal(ref, got):
    for f in SERIES_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, f)), np.asarray(getattr(got, f)),
            err_msg=f"resilient replay diverged on {f}")


def test_empty_schedule_is_bit_identical():
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    base = simulator.run_series_sharded(prob, evolve, **_series_kw())
    empty = simulator.run_series_sharded(
        prob, evolve, faults=rz.FaultSchedule(), **_series_kw())
    _assert_series_equal(base, empty)
    assert empty.plan_rejected is None  # normalized away entirely


def test_never_active_schedule_keeps_parity():
    # the resilient trace (masked stats, forced-fire logic, guardrail)
    # with all-alive health must reproduce the plain path bit-for-bit
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    base = simulator.run_series_sharded(prob, evolve, **_series_kw())
    never = rz.FaultSchedule(events=((10_000, 0, "die"),))
    resil = simulator.run_series_sharded(prob, evolve, faults=never,
                                         **_series_kw())
    _assert_series_equal(base, resil)
    assert resil.plan_rejected is not None
    assert resil.plan_rejected.sum() == 0


def test_guard_only_mode_records_and_keeps_parity():
    prob, evolve = scenarios.get("bimodal-churn").instantiate(
        grid=8, num_nodes=4)
    base = simulator.run_series_sharded(prob, evolve, **_series_kw())
    guarded = simulator.run_series_sharded(prob, evolve, guard=True,
                                           **_series_kw())
    _assert_series_equal(base, guarded)
    assert guarded.plan_rejected.sum() == 0  # engine plans all validate


def test_faults_validation_errors():
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    fs = rz.FaultSchedule(events=((2, 99, "die"),))
    with pytest.raises(ValueError, match="shard"):
        simulator.run_series_sharded(prob, evolve, faults=fs,
                                     **_series_kw())
    with pytest.raises(ValueError, match="active LB"):
        simulator.run_series_sharded(
            prob, evolve, faults=rz.FaultSchedule(events=((2, 0, "die"),)),
            **_series_kw(strategy="none", strategy_kwargs=None))
    with pytest.raises(TypeError, match="FaultSchedule"):
        simulator.run_series_sharded(prob, evolve, faults=object(),
                                     **_series_kw())


def test_pic_driver_rejects_resilience_without_sharded_replay():
    cfg = pic_driver.PICConfig(
        n_particles=512, steps=2, faults=rz.FaultSchedule(
            events=((1, 0, "die"),)))
    with pytest.raises(ValueError, match="sharded_replay"):
        pic_driver.run(cfg)
    cfg = pic_driver.PICConfig(n_particles=512, steps=2,
                               on_overflow="spill")
    with pytest.raises(ValueError, match="sharded_replay"):
        pic_driver.run(cfg)


# ------------------------------------------------- checkpointed replay --


def test_checkpointed_is_bit_exact_without_failures():
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    base = simulator.run_series_sharded(prob, evolve, **_series_kw())
    ck = rz.run_series_checkpointed(prob, evolve, checkpoint_every=5,
                                    **_series_kw())
    _assert_series_equal(base, ck)


def test_checkpointed_restarts_bit_exact():
    prob, evolve = scenarios.get("bimodal-churn").instantiate(
        grid=8, num_nodes=4)
    base = simulator.run_series_sharded(prob, evolve, **_series_kw())
    ck = rz.run_series_checkpointed(prob, evolve, checkpoint_every=3,
                                    fail_at=(1, 3, 3), **_series_kw())
    _assert_series_equal(base, ck)


def test_checkpointed_composes_with_guard_and_faults():
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    never = rz.FaultSchedule(events=((10_000, 0, "die"),))
    one = simulator.run_series_sharded(prob, evolve, faults=never,
                                       **_series_kw())
    ck = rz.run_series_checkpointed(prob, evolve, checkpoint_every=4,
                                    faults=never, fail_at=(2,),
                                    **_series_kw())
    _assert_series_equal(one, ck)
    np.testing.assert_array_equal(one.plan_rejected, ck.plan_rejected)


def test_checkpointed_validates_cadence():
    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    with pytest.raises(ValueError, match="checkpoint_every"):
        rz.run_series_checkpointed(prob, evolve, checkpoint_every=0,
                                   **_series_kw())


def test_checkpointed_exhausts_restarts():
    from repro.train import fault_tolerance as ft

    prob, evolve = scenarios.get("stencil-wave").instantiate(
        grid=8, num_nodes=4)
    # three distinct injected failures against a budget of two restarts
    with pytest.raises(ft.WorkerFailure):
        rz.run_series_checkpointed(prob, evolve, checkpoint_every=4,
                                   fail_at=(1, 2, 3), max_restarts=2,
                                   **_series_kw())


# ------------------------------------------- subprocess: 8-device mesh --

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
import numpy as np

from repro.pic import driver
from repro.runtime import resilience as rz
from repro.sim import scenarios, simulator

assert len(jax.devices()) == 8, jax.devices()

SERIES_FIELDS = ("max_avg", "ext_int", "migrations", "lb_fired",
                 "max_load", "migrated_load", "final_assignment")

prob, evolve = scenarios.get("stencil-wave").instantiate(grid=8,
                                                         num_nodes=16)
kw = dict(steps=24, lb_every=4, strategy="diff-comm",
          strategy_kwargs=dict(k=3))
base = simulator.run_series_sharded(prob, evolve, **kw)

# -- 1. never-active schedule: resilient trace, bit parity on 8 shards --
never = rz.FaultSchedule(events=((10_000, 0, "die"),))
resil = simulator.run_series_sharded(prob, evolve, faults=never, **kw)
for f in SERIES_FIELDS:
    np.testing.assert_array_equal(
        np.asarray(getattr(base, f)), np.asarray(getattr(resil, f)),
        err_msg=f"never-active/{f}")
assert resil.plan_rejected.sum() == 0
print("never-active 8-way parity OK")

# -- 2. dead shard: evacuation completes, owners stay alive -------------
fs = rz.FaultSchedule(events=((9, 2, "die"),))
dead = simulator.run_series_sharded(prob, evolve, faults=fs, **kw)
fa = dead.final_assignment
assert fa.shape == base.final_assignment.shape          # every object owned
dead_nodes = [4, 5]                                     # shard 2 of 8, rpd=2
assert not np.isin(fa, dead_nodes).any(), fa
assert np.isfinite(dead.max_avg).all()
assert dead.lb_fired[9] == 1.0                          # forced evacuation
print("dead-shard evacuation OK (fires:", int(dead.lb_fired.sum()), ")")

# -- 3. rollback determinism: identical runs are bit-identical ----------
dead2 = simulator.run_series_sharded(prob, evolve, faults=fs, **kw)
for f in SERIES_FIELDS:
    np.testing.assert_array_equal(
        np.asarray(getattr(dead, f)), np.asarray(getattr(dead2, f)),
        err_msg=f"determinism/{f}")
np.testing.assert_array_equal(dead.plan_rejected, dead2.plan_rejected)
print("fault-replay determinism OK")

# -- 4. die + recover: shard rejoins and can host objects again ---------
fs2 = rz.FaultSchedule(events=((6, 1, "die"), (14, 1, "recover")))
rec = simulator.run_series_sharded(prob, evolve, faults=fs2, **kw)
mid = None  # owners at the end must be allowed back on shard 1
assert np.isfinite(rec.max_avg).all()
print("die/recover completes OK")

# -- 5. checkpointed + faults + supervisor restart, 8-way, bit-exact ----
ck = rz.run_series_checkpointed(prob, evolve, checkpoint_every=7,
                                faults=fs, fail_at=(1, 2), **kw)
for f in SERIES_FIELDS:
    np.testing.assert_array_equal(
        np.asarray(getattr(dead, f)), np.asarray(getattr(ck, f)),
        err_msg=f"checkpointed/{f}")
print("checkpointed 8-way bit-exact OK")

# -- 6. PIC: dead shard completes with zero particle loss ---------------
pic = dict(L=100, n_particles=2000, steps=18, k=1, rho=0.9, cx=10, cy=10,
           num_pes=8, mapping="striped", lb_every=4, strategy="diff-comm",
           strategy_kwargs=dict(k=3), seed=0, sharded_replay=True)
ref_none = driver.run(driver.PICConfig(
    strategy="none",
    **{k: v for k, v in pic.items()
       if k not in ("strategy", "strategy_kwargs")}))
pfs = rz.FaultSchedule(events=((8, 3, "die"),))
pr = driver.run(driver.PICConfig(faults=pfs, **pic))
# the push physics never depended on the assignment: positions restored
# to particle-id order must match the LB-free run exactly → every
# particle survived the evacuation exchanges
np.testing.assert_array_equal(pr.final_x, ref_none.final_x)
np.testing.assert_array_equal(pr.final_y, ref_none.final_y)
assert pr.lb_steps[8] == 1.0
print("PIC dead-shard zero-loss OK (rejected:",
      int(pr.plan_rejected.sum()), ")")

# -- 7. PIC spill: tight capacity defers, drains, loses nothing ---------
cap = 2000 // 8 + 60
sp = driver.run(driver.PICConfig(replay_capacity=cap, on_overflow="spill",
                                 **{**pic, "lb_every": 2}))
np.testing.assert_array_equal(sp.final_x, ref_none.final_x)
np.testing.assert_array_equal(sp.final_y, ref_none.final_y)
assert sp.deferred.max() > 0            # the clamp did bite
assert sp.deferred[-1] == 0             # and the backlog drained
print("PIC spill-then-drain OK (peak deferred:",
      int(sp.deferred.max()), ")")

# -- 8. sharded spill entry: admissible exchange, structured strict error
from repro.runtime import migrate as rt_migrate
n = 1600
owner = np.zeros(n, np.int32)           # everything wants shard 0's node
owner[: n // 2] = 8                     # half to shard 4 (rpd=2 → node 8)
arrays = [np.arange(n, dtype=np.float32)]
try:
    rt_migrate.migrate_sharded(owner, arrays, num_nodes=16,
                               capacity=n // 8)
    raise SystemExit("strict overflow must raise")
except rt_migrate.CapacityOverflowError as e:
    assert e.unit == "shard" and 0 in e.offending and 4 in e.offending
o2, outs, counts, deferred = rt_migrate.migrate_sharded(
    owner, arrays, num_nodes=16, capacity=n // 8, on_overflow="spill")
counts = np.asarray(counts)
assert (counts <= n // 8).all()
assert counts.sum() + 0 == n            # conservation across shards
assert deferred > 0
print("sharded spill + structured strict error OK (deferred:",
      int(deferred), ")")
print("ALL OK")
"""


@pytest.mark.slow
def test_resilience_on_8_virtual_devices():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, \
        f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "ALL OK" in out.stdout
