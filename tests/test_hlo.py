"""HLO analyzer: validated against XLA cost analysis where XLA is correct
(scan-free modules) and against ground truth where XLA is not (scans)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def _cost(c):
    """XLA cost analysis dict (older jax returns a per-computation list)."""
    ca = c.cost_analysis()
    return ca[0] if isinstance(ca, (list, tuple)) else ca


def test_dot_flops_match_xla_on_scanfree():
    def f(x, w):
        return jnp.tanh(x @ w) @ w

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    c = _compile(f, x, w)
    got = hlo.analyze(c.as_text())
    xla = _cost(c)
    assert got["flops"] == pytest.approx(float(xla["flops"]), rel=1e-6)


def test_scan_flops_weighted_by_trip_count():
    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), None
        return jax.lax.scan(body, x, ws)[0]

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((12, 128, 128), jnp.float32)
    c = _compile(f, x, ws)
    got = hlo.analyze(c.as_text())
    assert got["flops"] == pytest.approx(12 * 2 * 128 ** 3, rel=1e-6)
    assert hlo.while_trip_counts(c.as_text()) == [12]


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            return jax.lax.scan(inner, c, None, length=3)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = _compile(f, x, ws)
    got = hlo.analyze(c.as_text())
    assert got["flops"] == pytest.approx(5 * 3 * 2 * 64 ** 3, rel=1e-6)


def test_traffic_close_to_xla_bytes_on_scanfree():
    def f(x, w):
        return x @ w

    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    c = _compile(f, x, x)
    got = hlo.analyze(c.as_text())
    xla = float(_cost(c)["bytes accessed"])
    assert got["traffic"] == pytest.approx(xla, rel=0.5)


def test_shape_bytes_parsing():
    comps, _ = hlo.split_computations("")
    assert comps == {}
    assert hlo._shape_bytes_of(hlo._shapes_in("bf16[2,3]{1,0} f32[4]")) == \
        2 * 3 * 2 + 4 * 4
    assert hlo._shapes_in("pred[]") == [("pred", [])]
