"""Stage 2 (paper §III.B): diffusion invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core import virtual_lb as vlb
from tests.conftest import random_symmetric_graph, ring_neighbors


def _balance(loads, nbr, mask, **kw):
    return vlb.virtual_balance(
        jnp.asarray(loads, jnp.float32), jnp.asarray(nbr),
        jnp.asarray(mask), **kw)


def test_conserves_total_load():
    P = 32
    nbr, mask = ring_neighbors(P, hops=2)
    rng = np.random.default_rng(0)
    loads = rng.random(P).astype(np.float32) * 10
    res = _balance(loads, nbr, mask)
    np.testing.assert_allclose(
        float(jnp.sum(res.target_loads)), float(loads.sum()), rtol=1e-4)


def test_flows_realize_targets():
    """x_final == x_0 - net outgoing flows (flow bookkeeping consistency)."""
    P = 16
    nbr, mask = ring_neighbors(P, hops=1)
    rng = np.random.default_rng(1)
    loads = rng.random(P).astype(np.float32) * 5
    res = _balance(loads, nbr, mask)
    net_out = np.asarray(res.flows).sum(axis=1)
    np.testing.assert_allclose(
        np.asarray(res.target_loads), loads - net_out, rtol=1e-3, atol=1e-3)


def test_flows_antisymmetric():
    P = 12
    nbr, mask = ring_neighbors(P, hops=2)
    res = _balance(np.arange(P, dtype=np.float32) + 1, nbr, mask)
    flows = np.asarray(res.flows)
    rev = np.asarray(vlb.reverse_slots(jnp.asarray(nbr), jnp.asarray(mask)))
    for i in range(P):
        for k in range(nbr.shape[1]):
            j, r = nbr[i, k], rev[i, k]
            np.testing.assert_allclose(flows[i, k], -flows[j, r], atol=1e-4)


def test_single_hop_limits_outflow_to_own_load():
    """No node ships more than its original load (paper's single-hop)."""
    P = 16
    nbr, mask = ring_neighbors(P, hops=2)
    loads = np.full(P, 1.0, np.float32)
    loads[0] = 50.0
    res = _balance(loads, nbr, mask, single_hop=True)
    out = np.asarray(res.flows).clip(min=0).sum(axis=1)
    assert (out <= loads + 1e-3).all()


def test_multi_hop_beats_single_hop_on_hotspot():
    P = 32
    nbr, mask = ring_neighbors(P, hops=1)
    loads = np.full(P, 1.0, np.float32)
    loads[0] = 100.0
    r1 = _balance(loads, nbr, mask, single_hop=True, max_iters=2000)
    r2 = _balance(loads, nbr, mask, single_hop=False, max_iters=2000)
    m1 = float(np.asarray(r1.target_loads).max())
    m2 = float(np.asarray(r2.target_loads).max())
    assert m2 <= m1 + 1e-3, "unconstrained diffusion spreads further"


def test_converges_on_complete_graph():
    P = 8
    nbr = np.stack([np.roll(np.arange(P), -h)[:P] for h in range(1, P)], 1)
    nbr = np.stack([(np.arange(P) + h) % P for h in range(1, P)], 1).astype(np.int32)
    mask = np.ones_like(nbr, bool)
    loads = np.zeros(P, np.float32)
    loads[0] = 8.0
    res = _balance(loads, nbr, mask, single_hop=False, tol=0.01)
    x = np.asarray(res.target_loads)
    assert x.max() / x.mean() < 1.1


def test_reverse_slots_ring_matches_bruteforce():
    P = 10
    nbr, mask = ring_neighbors(P, hops=2)
    rev = np.asarray(vlb.reverse_slots(jnp.asarray(nbr), jnp.asarray(mask)))
    for i in range(P):
        for k in range(nbr.shape[1]):
            j = nbr[i, k]
            assert nbr[j, rev[i, k]] == i


def test_reverse_slots_padded_rows_and_degree_one():
    """Degree-1 nodes with padded slots: defined entries invert the table,
    padded entries are 0 (masked out by every caller)."""
    # nodes 0 and 1 are each other's only neighbor; node 2 is isolated
    nbr = jnp.asarray(np.array([[1, -1], [0, -1], [-1, -1]], np.int32))
    mask = jnp.asarray(np.array([[True, False], [True, False],
                                 [False, False]]))
    rev = np.asarray(vlb.reverse_slots(nbr, mask))
    assert rev[0, 0] == 0 and rev[1, 0] == 0       # mutual slot 0
    assert (rev[[0, 1], 1] == 0).all()             # padded slots -> 0
    assert (rev[2] == 0).all()                     # fully padded row -> 0
    assert rev.dtype == np.int32


def test_reverse_slots_asymmetric_table_stays_in_range():
    """A deliberately asymmetric table (i lists j, j does not list i):
    reverse_slots must not crash and must return in-range slot indices;
    symmetric pairs elsewhere in the table stay correct."""
    # 0 lists [1, 2]; 1 lists [0] (symmetric with 0); 2 lists [1] only —
    # so 0->2 and 2->1 have no reverse entry.
    nbr = jnp.asarray(np.array([[1, 2], [0, -1], [1, -1]], np.int32))
    mask = jnp.asarray(np.array([[True, True], [True, False],
                                 [True, False]]))
    rev = np.asarray(vlb.reverse_slots(nbr, mask))
    K = nbr.shape[1]
    assert ((rev >= 0) & (rev < K)).all()
    # the symmetric pair 0<->1 is still correctly inverted
    assert rev[0, 0] == 0 and rev[1, 0] == 0


@pytest.mark.parametrize("chunks", [(1, 8), (1, 64), (3, 8)])
def test_virtual_balance_chunk_size_invariant(chunks):
    """The chunked fixed-point loop is a compilation strategy: results —
    loads, flows, iteration count, residual — are bit-for-bit independent
    of sweep_chunk (the per-sweep activity mask replicates the per-sweep
    while_loop decisions exactly)."""
    a, b = chunks
    P = 32
    nbr, mask = ring_neighbors(P, hops=2)
    rng = np.random.default_rng(7)
    loads = rng.random(P).astype(np.float32) * 10
    ra = _balance(loads, nbr, mask, sweep_chunk=a)
    rb = _balance(loads, nbr, mask, sweep_chunk=b)
    for x, y in zip(ra, rb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_virtual_balance_chunk_fn_matches_default():
    """The kernels-layer chunk_fn (auto-dispatching) must reproduce the
    pure-core default exactly on this backend."""
    from repro.kernels.diffusion import ops as dops

    P = 24
    nbr, mask = ring_neighbors(P, hops=1)
    loads = np.random.default_rng(3).random(P).astype(np.float32) * 5
    base = _balance(loads, nbr, mask)
    fused = _balance(loads, nbr, mask, chunk_fn=dops.diffusion_nsweeps)
    for x, y in zip(base, fused):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@settings(max_examples=15, deadline=None)
@given(P=st.integers(6, 60), K=st.integers(1, 6), seed=st.integers(0, 500))
def test_property_reverse_slots_inverts_random_symmetric_graphs(P, K, seed):
    """On any symmetric padded table: masked entries invert the table
    (nbr[nbr[i,k], rev[i,k]] == i), every slot index is in range, and
    padded entries are exactly 0."""
    nbr, mask = random_symmetric_graph(P, K, seed)
    rev = np.asarray(vlb.reverse_slots(jnp.asarray(nbr), jnp.asarray(mask)))
    assert rev.dtype == np.int32
    assert ((rev >= 0) & (rev < K)).all()
    assert (rev[~mask] == 0).all()
    ii, kk = np.nonzero(mask)
    assert (nbr[nbr[ii, kk], rev[ii, kk]] == ii).all()


def test_stall_exit_fires():
    """Single-hop freeze must not burn max_iters."""
    P = 16
    nbr, mask = ring_neighbors(P, hops=1)
    loads = np.full(P, 1.0, np.float32)
    loads[0] = 1000.0
    res = _balance(loads, nbr, mask, single_hop=True, max_iters=512)
    assert int(res.iters) < 512


@settings(max_examples=20, deadline=None)
@given(
    P=st.integers(4, 40),
    hops=st.integers(1, 3),
    seed=st.integers(0, 1000),
    single_hop=st.booleans(),
)
def test_property_conservation_and_no_negative(P, hops, seed, single_hop):
    hops = min(hops, (P - 1) // 2)
    nbr, mask = ring_neighbors(P, hops=hops)
    rng = np.random.default_rng(seed)
    loads = (rng.random(P) * 10).astype(np.float32)
    res = _balance(loads, nbr, mask, single_hop=single_hop)
    x = np.asarray(res.target_loads)
    np.testing.assert_allclose(x.sum(), loads.sum(), rtol=1e-3)
    assert (x >= -1e-3).all(), "virtual loads must stay non-negative"
    # balance never gets worse
    assert x.max() <= loads.max() + 1e-3
