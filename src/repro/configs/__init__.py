"""Assigned-architecture configs (``--arch <id>``) + shape registry."""
from repro.configs.base import (
    ARCHS, SHAPES, ArchSpec, Shape, get_arch, input_specs, list_archs,
    materialize_batch, reduced_config, shape_applicable,
)

__all__ = [
    "ARCHS", "SHAPES", "ArchSpec", "Shape", "get_arch", "input_specs",
    "list_archs", "materialize_batch", "reduced_config", "shape_applicable",
]
