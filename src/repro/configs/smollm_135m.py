"""SmolLM-135M — llama-architecture small dense model.

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
[hf:HuggingFaceTB/SmolLM-135M; hf]
"""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-135m",
    num_layers=30,
    d_model=576,
    num_heads=9,
    num_kv_heads=3,
    d_ff=1536,
    vocab_size=49_152,
    layer_unit=("attn",),
    tie_embeddings=True,
    # too small to fill a 16-wide TP axis: pure-DP layout
    sharding_profile="dp",
)

REDUCED = ModelConfig(
    name="smollm-reduced",
    num_layers=3,
    d_model=48,
    num_heads=3,
    num_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    layer_unit=("attn",),
    tie_embeddings=True,
)

SPEC = ArchSpec(
    name="smollm-135m",
    config=CONFIG,
    reduced=REDUCED,
    family="dense",
    long_context=False,
    source="hf:HuggingFaceTB/SmolLM-135M",
    notes="dense; LB technique attaches at the data level only "
          "(distributed/data_balance.py)",
)
