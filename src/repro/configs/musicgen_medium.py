"""MusicGen-medium — decoder-only transformer over EnCodec tokens.

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.
[arXiv:2306.05284; hf:facebook/musicgen-medium]

Backbone only per the assignment: the EnCodec frontend is a stub —
``input_specs()`` supplies precomputed frame embeddings (B, S, d_model);
decode consumes codebook token ids.  Text-conditioning cross-attention is
out of scope (backbone spec).
"""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    layer_unit=("attn",),
    frontend="audio_stub",
)

REDUCED = ModelConfig(
    name="musicgen-reduced",
    num_layers=3,
    d_model=48,
    num_heads=4,
    num_kv_heads=4,
    d_ff=96,
    vocab_size=64,
    layer_unit=("attn",),
    frontend="audio_stub",
)

SPEC = ArchSpec(
    name="musicgen-medium",
    config=CONFIG,
    reduced=REDUCED,
    family="audio",
    long_context=False,
    source="arXiv:2306.05284",
    notes="EnCodec frontend stubbed: frame embeddings in, token ids out",
)
