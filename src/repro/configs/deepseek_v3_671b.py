"""DeepSeek-V3 671B (37B active).

61L d_model=7168 128H MLA d_ff(expert)=2048 vocab=129280, MoE 1 shared +
256 routed top-8, MTP head.  First 3 layers use a dense 18432-wide MLP
(arXiv:2412.19437 Table 1); the rest are MoE.  [arXiv:2412.19437; hf]

This is the primary EP-balance target for the paper's technique: 256
experts over a 16-wide EP axis = 16 experts/rank, with persistent top-8
co-activation statistics forming the object communication graph.
"""
from repro.configs.base import ArchSpec
from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=2048,
    d_ff_dense=18432,
    vocab_size=129_280,
    prefix_layers=("attn", "attn", "attn"),
    layer_unit=("moe",),
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                  qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1),
    mtp=True,
)

REDUCED = ModelConfig(
    name="deepseek-v3-reduced",
    num_layers=3,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=96,
    d_ff_dense=160,
    vocab_size=512,
    prefix_layers=("attn",),
    layer_unit=("moe",),
    attention="mla",
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16,
                  qk_rope_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=96, num_shared=1,
                  impl="dense"),
    mtp=True,
)

SPEC = ArchSpec(
    name="deepseek-v3-671b",
    config=CONFIG,
    reduced=REDUCED,
    family="moe",
    long_context=False,
    source="arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3",
    notes="MLA (absorbed form), 1 shared + 256 routed top-8, MTP",
)
