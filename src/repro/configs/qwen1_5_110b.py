"""Qwen1.5-110B — large dense model with QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=49152 vocab=152064.
[hf:Qwen/Qwen1.5-110B (dims per assignment); hf]
"""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49_152,
    vocab_size=152_064,
    layer_unit=("attn",),
    qkv_bias=True,
)

REDUCED = ModelConfig(
    name="qwen1.5-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    layer_unit=("attn",),
    qkv_bias=True,
)

SPEC = ArchSpec(
    name="qwen1.5-110b",
    config=CONFIG,
    reduced=REDUCED,
    family="dense",
    long_context=False,
    source="hf:Qwen/Qwen1.5-110B",
    notes="QKV bias; dense ⇒ data-level LB only",
)
