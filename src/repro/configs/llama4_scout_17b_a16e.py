"""Llama-4 Scout 17B-active / 16-expert.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16 experts
top-1 + 1 shared expert (every layer MoE — Scout's interleave step is 1).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Early-fusion multimodality is out of scope per the assignment (text
backbone only).  The paper's diffusion balancer attaches via EP placement
(distributed/ep_balance.py): with 16 experts on a 16-wide EP axis, balancing
migrates replica shares (see DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202_048,
    layer_unit=("moe",),
    moe=MoEConfig(num_experts=16, top_k=1, d_expert=8192, num_shared=1),
    rope_theta=500_000.0,
)

REDUCED = ModelConfig(
    name="llama4-scout-reduced",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    layer_unit=("moe",),
    moe=MoEConfig(num_experts=4, top_k=1, d_expert=128, num_shared=1,
                  impl="dense"),
)

SPEC = ArchSpec(
    name="llama4-scout-17b-a16e",
    config=CONFIG,
    reduced=REDUCED,
    family="moe",
    long_context=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
    notes="MoE 16e top-1 + shared; text backbone only (early fusion skipped)",
)
