"""PaliGemma-3B — SigLIP vision encoder + Gemma decoder (VLM).

Backbone: 18L d_model=2048 8H (GQA kv=1) d_ff=16384 vocab=257216.
[arXiv:2407.07726; hf:google/paligemma-3b-pt-224]

The SigLIP frontend is a stub per the assignment: ``input_specs()``
supplies 256 precomputed patch embeddings that are prepended to the text
tokens; attention is prefix-LM (bidirectional over the image prefix,
causal over text).
"""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16_384,
    vocab_size=257_216,
    layer_unit=("attn",),
    prefix_lm=True,
    vision_prefix=256,
    frontend="vision_stub",
    tie_embeddings=True,
    embed_scale=True,
)

REDUCED = ModelConfig(
    name="paligemma-reduced",
    num_layers=2,
    d_model=48,
    num_heads=2,
    num_kv_heads=1,
    d_ff=96,
    vocab_size=512,
    layer_unit=("attn",),
    prefix_lm=True,
    vision_prefix=8,
    frontend="vision_stub",
    tie_embeddings=True,
    embed_scale=True,
)

SPEC = ArchSpec(
    name="paligemma-3b",
    config=CONFIG,
    reduced=REDUCED,
    family="vlm",
    long_context=False,
    source="arXiv:2407.07726",
    notes="SigLIP frontend stubbed: patch embeddings in; prefix-LM mask",
)
