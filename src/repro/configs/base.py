"""Architecture registry and per-shape input specs.

Every assigned architecture registers an ``ArchSpec`` with its exact
published config, a reduced same-family smoke config, and the set of
applicable input shapes.  ``input_specs`` returns ShapeDtypeStruct stand-ins
(no allocation — the dry-run path), including stacked-cache structs for the
decode shapes via ``jax.eval_shape``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    config: ModelConfig
    reduced: ModelConfig
    family: str                          # dense | moe | hybrid | ssm | audio | vlm
    long_context: bool                   # sub-quadratic ⇒ long_500k applies
    source: str
    notes: str = ""


_MODULES = [
    "llama4_scout_17b_a16e",
    "deepseek_v3_671b",
    "smollm_135m",
    "qwen1_5_110b",
    "gemma3_1b",
    "gemma3_27b",
    "hymba_1_5b",
    "musicgen_medium",
    "xlstm_125m",
    "paligemma_3b",
]

ARCHS: Dict[str, ArchSpec] = {}


def _load():
    if ARCHS:
        return
    for m in _MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        spec: ArchSpec = mod.SPEC
        ARCHS[spec.name] = spec


def list_archs():
    _load()
    return sorted(ARCHS)


def get_arch(name: str) -> ArchSpec:
    _load()
    return ARCHS[name]


def reduced_config(name: str) -> ModelConfig:
    return get_arch(name).config and get_arch(name).reduced


def shape_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    """(applicable?, reason-if-not) — per the assignment's skip rules."""
    a = get_arch(arch)
    s = SHAPES[shape]
    if s.name == "long_500k" and not a.long_context:
        return False, ("pure full-attention arch: 500k decode needs "
                       "sub-quadratic state (see DESIGN.md shape skips)")
    return True, ""


# ------------------------------------------------------------ input specs --


def input_specs(cfg: ModelConfig, shape: Shape,
                compute_dtype=jnp.bfloat16) -> Dict:
    """ShapeDtypeStruct stand-ins for one (arch, shape) cell.

    train:   {batch: {tokens/embeds, positions, labels}}
    prefill: {batch: {tokens/embeds, positions}}
    decode:  {tokens, index, cache}  (cache structs via eval_shape)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def tok(shape_):
        return jax.ShapeDtypeStruct(shape_, i32)

    if shape.kind in ("train", "prefill"):
        batch: Dict = dict(positions=tok((B, S)))
        if cfg.frontend == "audio_stub":
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   compute_dtype)
            batch["tokens"] = None
        elif cfg.frontend == "vision_stub":
            p = cfg.vision_prefix
            batch["embeds"] = jax.ShapeDtypeStruct((B, p, cfg.d_model),
                                                   compute_dtype)
            batch["tokens"] = tok((B, S - p))
        else:
            batch["tokens"] = tok((B, S))
        if shape.kind == "train":
            batch["labels"] = tok((B, S))
            return dict(batch=batch)
        return dict(batch=batch)

    # decode: one new token against a seq_len-deep cache
    from repro.models import transformer

    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, S, compute_dtype))
    return dict(
        tokens=tok((B, 1)),
        index=jax.ShapeDtypeStruct((), i32),
        cache=cache,
    )


def materialize_batch(cfg: ModelConfig, shape: Shape, seed: int = 0,
                      compute_dtype=jnp.bfloat16) -> Dict:
    """Small-scale concrete inputs (smoke tests / examples) matching
    ``input_specs`` structure."""
    specs = input_specs(cfg, shape, compute_dtype)
    key = jax.random.PRNGKey(seed)

    def fill(sds, k):
        if sds.dtype == jnp.int32:
            return jax.random.randint(k, sds.shape, 0,
                                      max(2, min(cfg.vocab_size, 1000)), jnp.int32)
        return jax.random.normal(k, sds.shape, jnp.float32).astype(sds.dtype)

    leaves, treedef = jax.tree.flatten(specs, is_leaf=lambda x: x is None)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = [None if l is None else fill(l, k) for l, k in zip(leaves, keys)]
    mat = jax.tree.unflatten(treedef, out)
    if "batch" in mat:
        B, S = shape.global_batch, shape.seq_len
        mat["batch"]["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None], (B, S)).copy()
    if "index" in mat:
        mat["index"] = jnp.int32(shape.seq_len - 1)
    return mat
