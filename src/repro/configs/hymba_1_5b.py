"""Hymba-1.5B — hybrid parallel attention + mamba heads.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
[arXiv:2411.13676; hf:nvidia/Hymba-1.5B-Base]

Every block runs attention and a selective-SSM (mamba) head bank in
parallel on the same normed input, combined with learned per-block scalars
(the paper's mean-combination with β gates).  Most blocks use sliding-
window attention; the first and last are global (the paper keeps 3 global
layers incl. the middle one — the middle global layer is folded into the
scanned window pattern here, recorded in DESIGN.md).  Sub-quadratic state
⇒ runs the 500k decode cell.
"""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    prefix_layers=("hymba_g",),
    layer_unit=("hymba",),
    suffix_layers=("hymba_g",),
    sliding_window=1024,
    ssm_state=16,
)

REDUCED = ModelConfig(
    name="hymba-reduced",
    num_layers=4,
    d_model=50,
    num_heads=5,
    num_kv_heads=1,
    d_ff=96,
    vocab_size=512,
    prefix_layers=("hymba_g",),
    layer_unit=("hymba",),
    suffix_layers=("hymba_g",),
    sliding_window=16,
    ssm_state=4,
)

SPEC = ArchSpec(
    name="hymba-1.5b",
    config=CONFIG,
    reduced=REDUCED,
    family="hybrid",
    long_context=True,
    source="arXiv:2411.13676",
    notes="parallel attn+mamba heads; SWA + SSM state bounds 500k decode",
)
