"""xLSTM-125M — alternating mLSTM (matrix memory) and sLSTM (scalar
memory) blocks.

12L d_model=768 4H d_ff=0 vocab=50304.  [arXiv:2405.04517; unverified]

d_ff=0 ⇒ no separate FFN sub-blocks (the cells carry their own
projections).  Recurrent state is O(heads·hd²) ⇒ the 500k decode cell is
trivially bounded.
"""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    num_layers=12,
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    layer_unit=("mlstm", "slstm"),
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="xlstm-reduced",
    num_layers=2,
    d_model=48,
    num_heads=2,
    num_kv_heads=2,
    d_ff=0,
    vocab_size=512,
    layer_unit=("mlstm", "slstm"),
    tie_embeddings=True,
)

SPEC = ArchSpec(
    name="xlstm-125m",
    config=CONFIG,
    reduced=REDUCED,
    family="ssm",
    long_context=True,
    source="arXiv:2405.04517 (unverified)",
    notes="sLSTM steps sequentially (recurrent gates); mLSTM chunkwise",
)
