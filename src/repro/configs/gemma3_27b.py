"""Gemma-3 27B — 5:1 local:global sliding-window attention, 128k context.

62L d_model=5376 32H (GQA kv=16) d_ff=21504 vocab=262144.
[hf:google/gemma-3-1b-pt family; unverified]

62 = 10×6 + 2: ten scanned (5 local + 1 global) groups plus two unrolled
local layers.
"""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

_UNIT = ("attn_local",) * 5 + ("attn",)

CONFIG = ModelConfig(
    name="gemma3-27b",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21_504,
    vocab_size=262_144,
    layer_unit=_UNIT,
    suffix_layers=("attn_local", "attn_local"),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    embed_scale=True,
)

REDUCED = ModelConfig(
    name="gemma3-27b-reduced",
    num_layers=8,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    layer_unit=("attn_local",) * 2 + ("attn",),
    suffix_layers=("attn_local", "attn_local"),
    sliding_window=16,
    tie_embeddings=True,
    embed_scale=True,
)

SPEC = ArchSpec(
    name="gemma3-27b",
    config=CONFIG,
    reduced=REDUCED,
    family="dense",
    long_context=True,
    source="hf:google/gemma-3-27b-pt (unverified)",
    notes="5:1 local:global SWA",
)
