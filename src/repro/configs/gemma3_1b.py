"""Gemma-3 1B — 5:1 local:global sliding-window attention, 128k context.

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
[hf:google/gemma-3-1b-pt; unverified]

Layer pattern: repeating (5 × local SWA, 1 × global); 26 = 4×6 + 2, the
two remainder layers are unrolled local blocks (suffix).  kv=1 means head
sharding is impossible — the KV cache length dim is sharded instead
(models/transformer.shard_cache), which is what makes the 500k decode cell
feasible; window layers keep O(window) ring-buffer caches.
"""
from repro.configs.base import ArchSpec
from repro.models.config import ModelConfig

_UNIT = ("attn_local",) * 5 + ("attn",)

CONFIG = ModelConfig(
    name="gemma3-1b",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    d_ff=6912,
    vocab_size=262_144,
    layer_unit=_UNIT,
    suffix_layers=("attn_local", "attn_local"),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    embed_scale=True,
    # too small to fill a 16-wide TP axis: pure-DP layout
    sharding_profile="dp",
)

REDUCED = ModelConfig(
    name="gemma3-1b-reduced",
    num_layers=8,
    d_model=48,
    num_heads=2,
    num_kv_heads=1,
    d_ff=96,
    vocab_size=512,
    layer_unit=("attn_local",) * 2 + ("attn",),
    suffix_layers=("attn_local", "attn_local"),
    sliding_window=16,
    tie_embeddings=True,
    embed_scale=True,
)

SPEC = ArchSpec(
    name="gemma3-1b",
    config=CONFIG,
    reduced=REDUCED,
    family="dense",
    long_context=True,
    source="hf:google/gemma-3-1b-pt (unverified)",
    notes="5:1 local:global SWA; window caches bound 5/6 of KV state",
)
