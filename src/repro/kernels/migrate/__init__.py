"""Fused sort-free counting-scatter kernel (migration manifest build).

histogram → exclusive-scan offsets → stable counting scatter, bit-for-bit
the stable-argsort bucketed layout without a sort.  See ops.py for the
dispatch rules and ref.py / kernel.py for the two implementations.
"""
from repro.kernels.migrate.ops import (  # noqa: F401
    bucket_ranks,
    preferred_method,
    scatter_dest,
    scatter_impl,
)
