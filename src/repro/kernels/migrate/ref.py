"""Pure-jnp sort-free counting-scatter reference (XLA-compiled oracle).

Produces the bucketed destination slot of every item **without a sort**:
per-owner counts (histogram) → exclusive-scan slot offsets → stable
within-owner rank from a blocked prefix over the one-hot matrix, so

    dest[i] = offsets[owner[i]] + rank_within_owner[i]

and ties keep previous-position order — the layout is bit-for-bit
``jnp.argsort(owner, stable=True)``'s bucketed permutation (dest is its
inverse).  The one-hot block prefix is O(n·C) elementwise work instead of
the O(n log n) sort network, which is the win whenever the node count C
is small next to n (the replay loops run C ≤ 64 over n up to 2^20).

Blocking: items are processed in (block, C) one-hot tiles under a
``lax.scan`` whose carry is the running per-owner count, keeping the
transient working set ~``BLOCK_ELEMS`` regardless of n.  All arithmetic
is exact int32, so the blocked and single-block results are identical.

Invalid ids (negative or ≥ C — padding slots) match no one-hot column:
their rank comes out -1 and their dest the out-of-range sentinel ``n``,
so a ``mode="drop"`` scatter ignores them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Transient one-hot tile budget (elements per (block, C) tile).  4 MiB of
# i32 — small enough to stay cache-resident on CPU and comfortably inside
# accelerator memory, large enough that the scan has O(n·C / 2^22) steps.
BLOCK_ELEMS = 1 << 22


def _block_n(n: int, C: int) -> int:
    """Rows per one-hot tile: fill BLOCK_ELEMS, at least 128 rows."""
    return max(128, min(max(n, 1), BLOCK_ELEMS // max(C, 1)))


@functools.partial(jax.jit, static_argnames=("C",))
def bucket_ranks_ref(ids: jax.Array, *, C: int):
    """Stable within-bucket rank of every item, sort-free.

    ``ids`` is (n,) i32; entries outside [0, C) are padding.  Returns
    ``(rank, counts)``: ``rank[i]`` is the number of earlier items with
    the same id (-1 for padding), ``counts`` the (C,) per-id totals.
    """
    ids = jnp.asarray(ids, jnp.int32)
    n = ids.shape[0]
    bn = _block_n(n, C)
    npad = -(-n // bn) * bn if n else 0
    blocks = jnp.pad(ids, (0, npad - n), constant_values=-1).reshape(-1, bn)
    cols = jax.lax.iota(jnp.int32, C)[None, :]

    def blk(acc, ids_b):
        onehot = (ids_b[:, None] == cols).astype(jnp.int32)   # (bn, C)
        incl = jnp.cumsum(onehot, axis=0)                     # inclusive prefix
        # within-block rank (inclusive − 1) + carry of earlier blocks;
        # invalid ids hit no column → both sums are 0 → rank −1
        rank = (incl * onehot).sum(1) - 1 + (onehot * acc[None, :]).sum(1)
        return acc + incl[-1], rank

    acc0 = jnp.zeros((C,), jnp.int32)
    counts, ranks = jax.lax.scan(blk, acc0, blocks)
    return ranks.reshape(-1)[:n], counts


@functools.partial(jax.jit, static_argnames=("C",))
def scatter_dest_ref(ids: jax.Array, *, C: int):
    """Bucketed destination slot of every item, sort-free.

    Returns ``(dest, counts)``: ``dest[i] = offsets[ids[i]] + rank[i]``
    (the inverse of the stable-argsort permutation); padding items get
    the sentinel ``n`` (out of range, dropped by ``mode="drop"``).
    """
    ids = jnp.asarray(ids, jnp.int32)
    n = ids.shape[0]
    rank, counts = bucket_ranks_ref(ids, C=C)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)])
    base = jnp.take(offsets, jnp.clip(ids, 0, C - 1))
    dest = jnp.where(rank >= 0, base + rank, n).astype(jnp.int32)
    return dest, counts
