"""Jitted wrappers selecting the counting-scatter implementation.

:func:`scatter_dest` / :func:`bucket_ranks` are the sort-free primitives
behind ``runtime.migrate``'s manifest build, the PIC re-bucketing paths
and ``ring_exchange``'s per-shard placement.  All implementations honor
the same bit-for-bit contract: the destinations reproduce the
stable-argsort bucketed layout exactly (ties keep previous position).

Implementation selection (:func:`scatter_impl`):

  * ``"kernel"``    — TPU, (block, C) working set within
                      :data:`MIGRATE_VMEM_BUDGET` and ``n`` below the
                      f32-exact bound 2^24: the fused two-phase Pallas
                      kernel (histogram → exclusive-scan → scatter on the
                      MXU, see kernel.py).
  * ``"reference"`` — CPU/GPU, or TPU fallbacks: the blocked-scan jnp
                      reference (XLA-compiled; Pallas interpret mode is
                      Python-slow and numerically identical, so it is
                      reserved for the kernel tests).

Whether the *sort-free* pipeline beats a stable argsort at all is a
separate question answered by :func:`preferred_method` — both paths are
O(n·C) in total work, so the counting scatter wins while C is small:
~3× at the replay loops' C = 8, n = 2^20 on CPU XLA, crossing over to
the sort around C ≈ 64 (measured on the bench host; see
benchmarks/kernel_bench.py → BENCH_kernels.json).  The TPU kernel keeps
winning to much larger C because the one-hot work rides the MXU while
the sort network does not.
"""
from __future__ import annotations

from repro.distributed import compat
from repro.kernels import on_tpu
from repro.kernels.migrate.kernel import scatter_dest_pallas
from repro.kernels.migrate.ref import bucket_ranks_ref, scatter_dest_ref

import jax.numpy as jnp

# VMEM working-set budget for the fused kernel (bytes); same headroom
# convention as diffusion's FUSED_VMEM_BUDGET.
MIGRATE_VMEM_BUDGET = 8 * 1024 * 1024

# f32-exact slot arithmetic on the MXU bounds n (destinations are
# integers carried as f32).
KERNEL_MAX_N = 1 << 24

# CPU crossover: the O(n·C) counting scatter beats XLA's stable sort up
# to about this many buckets (measured at n = 2^20 on the bench host).
SORT_CROSSOVER_C = 64


def kernel_vmem_bytes(block_n: int, C: int) -> int:
    """Fused-kernel VMEM working set for a (block_n, C) phase-1 tile.

    Dominant terms: the (bn, bn) strict-lower-tri rank matrix and two
    (bn, C) one-hot/prefix tiles, all f32; the (C, C) exclusive-scan tri
    lives only at the phase boundary but peaks the same buffer; plus the
    i32 id/dest blocks and three (C,) vectors.
    """
    return 4 * (block_n * block_n + 2 * block_n * C
                + max(C * C, block_n * C) + 2 * block_n + 3 * C)


def kernel_block_n(C: int):
    """Largest supported block size fitting the VMEM budget, else None."""
    for bn in (1024, 512, 256, 128):
        if kernel_vmem_bytes(bn, C) <= MIGRATE_VMEM_BUDGET:
            return bn
    return None


def scatter_impl(n: int, C: int) -> str:
    """Which implementation :func:`scatter_dest` selects for (n, C)."""
    if on_tpu() and n < KERNEL_MAX_N and kernel_block_n(C) is not None:
        return "kernel"
    return "reference"


def preferred_method(n: int, C: int) -> str:
    """``"scatter"`` or ``"sort"`` — what ``method="auto"`` resolves to.

    The TPU kernel always prefers the counting scatter (sort networks
    are MXU-hostile); on CPU/GPU the O(n·C) reference wins only below
    the :data:`SORT_CROSSOVER_C` bucket-count crossover.
    """
    del n
    if on_tpu():
        return "scatter"
    return "scatter" if C <= SORT_CROSSOVER_C else "sort"


def scatter_dest(ids, *, C: int, use_kernel=None):
    """Sort-free bucketed destinations: ``(dest, counts, offsets)``.

    ``dest[i] = offsets[ids[i]] + stable-rank-within-bucket`` — the
    inverse of ``jnp.argsort(ids, stable=True)``'s permutation; padding
    ids (outside [0, C)) get the sentinel ``n``.  ``offsets`` is the
    (C+1,) exclusive scan of ``counts``.  ``use_kernel=None`` dispatches
    per :func:`scatter_impl`.
    """
    n = ids.shape[0]
    if use_kernel is None:
        use_kernel = scatter_impl(n, C) == "kernel"
    with compat.named_scope("kernel/scatter-dest"):
        if use_kernel:
            dest, counts = scatter_dest_pallas(
                ids, C=C, block_n=kernel_block_n(C) or 128,
                interpret=not on_tpu())
        else:
            dest, counts = scatter_dest_ref(ids, C=C)
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32),
             jnp.cumsum(counts).astype(jnp.int32)])
        return dest, counts, offsets


def bucket_ranks(ids, *, C: int, use_kernel=None):
    """Stable within-bucket ranks: ``(rank, counts)``; padding rank −1.

    Kernel path derives the rank from the fused destinations
    (``rank = dest − offsets[id]``) — exact int arithmetic, identical to
    the reference.
    """
    n = ids.shape[0]
    if use_kernel is None:
        use_kernel = scatter_impl(n, C) == "kernel"
    if not use_kernel:
        return bucket_ranks_ref(ids, C=C)
    ids = jnp.asarray(ids, jnp.int32)
    dest, counts, offsets = scatter_dest(ids, C=C, use_kernel=True)
    base = jnp.take(offsets, jnp.clip(ids, 0, C - 1))
    valid = (ids >= 0) & (ids < C)
    rank = jnp.where(valid, dest - base, -1).astype(jnp.int32)
    return rank, counts
