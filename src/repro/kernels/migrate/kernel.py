"""Pallas TPU kernel: fused sort-free counting scatter (migration layout).

One ``pallas_call`` fuses the whole manifest-build pipeline — histogram →
exclusive-scan offsets → stable counting scatter — over a grid of
``(2, n_blocks)``:

  * **phase 0** streams the item blocks once, binning each block's owner
    ids with the histogram kernel's MXU one-hot trick (a ``(1, bn) ×
    (bn, C)`` matmul) into a VMEM-resident (C,) accumulator that persists
    across the sequential grid.
  * at the **phase boundary** (phase 1, block 0) the accumulated totals
    are exclusive-scanned into slot offsets with a strict-lower-triangular
    (C, C) matvec — again MXU work, no serial loop — and the accumulator
    resets to re-count as the running per-owner base.
  * **phase 1** streams the blocks a second time and emits each item's
    destination ``offsets[owner] + rank-within-owner``; the within-block
    rank is a strict-lower-triangular ``(bn, bn) × (bn, C)`` matmul over
    the one-hot matrix, so ties keep previous-position order and the
    result is bit-for-bit the stable-argsort bucketed layout.

Scatter-add serializes on TPU, which is exactly why this kernel exists:
it computes *destinations* with matmuls and leaves the actual data
movement to a single XLA scatter/gather outside (see ops.py).  All counts
and slots ride the MXU as f32 with HIGHEST precision — exact for
integers below 2^24, enforced by the wrapper.

Invalid ids (negative or ≥ C — padding) match no one-hot column and get
the out-of-range sentinel ``n`` as destination (dropped downstream).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_HI = jax.lax.Precision.HIGHEST


def _scatter_kernel(ids_ref, dest_ref, counts_ref, acc_ref, offs_ref, *,
                    C: int, n_total: int):
    ph = pl.program_id(0)              # 0 = count, 1 = scatter
    ids = ids_ref[...]                 # (bn,) i32; invalid = padding
    bn = ids.shape[0]
    colsC = jax.lax.broadcasted_iota(jnp.int32, (bn, C), 1)
    onehot = (ids[:, None] == colsC).astype(jnp.float32)        # (bn, C)
    blk_counts = jnp.dot(jnp.ones((1, bn), jnp.float32), onehot,
                         preferred_element_type=jnp.float32,
                         precision=_HI)[0]                      # (C,) f32

    @pl.when(jnp.logical_and(ph == 0, pl.program_id(1) == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(ph == 0)
    def _count():
        acc_ref[...] += blk_counts.astype(jnp.int32)
        # defined placeholder; phase 1 revisits this block and its write
        # is the one flushed last
        dest_ref[...] = jnp.full((bn,), n_total, jnp.int32)

    @pl.when(jnp.logical_and(ph == 1, pl.program_id(1) == 0))
    def _exclusive_scan():
        # offsets = strict-lower-tri (C, C) matvec over the totals
        ri = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
        ci = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
        tri = (ci < ri).astype(jnp.float32)
        tot = acc_ref[...].astype(jnp.float32)
        offs = jnp.dot(tri, tot[:, None], preferred_element_type=jnp.float32,
                       precision=_HI)[:, 0]
        offs_ref[...] = offs.astype(jnp.int32)
        acc_ref[...] = jnp.zeros_like(acc_ref)   # re-count as running base

    @pl.when(ph == 1)
    def _scatter():
        # strict-lower-tri (bn, bn) × (bn, C): exclusive within-block
        # prefix of the one-hot matrix → stable rank
        ri = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
        ci = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
        tril = (ci < ri).astype(jnp.float32)
        prefix = jnp.dot(tril, onehot, preferred_element_type=jnp.float32,
                         precision=_HI)                          # (bn, C)
        base = (offs_ref[...] + acc_ref[...]).astype(jnp.float32)  # (C,)
        rank = (prefix * onehot).sum(1)
        item_base = (onehot * base[None, :]).sum(1)
        valid = onehot.sum(1) > 0.0
        dest_ref[...] = jnp.where(
            valid, item_base + rank, float(n_total)).astype(jnp.int32)
        acc_ref[...] += blk_counts.astype(jnp.int32)

    # final grid step leaves acc == totals again; constant index map keeps
    # this block VMEM-resident, last write wins
    counts_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("C", "block_n", "interpret"))
def scatter_dest_pallas(
    ids: jax.Array,           # (n,) i32 owner ids in [0, C); others = padding
    *,
    C: int,
    block_n: int = 512,
    interpret: bool = False,
):
    """Fused sort-free ``(dest, counts)`` — see module docstring.

    ``dest`` is (n,) i32 bucketed destinations (sentinel ``n`` for
    padding ids), ``counts`` the (C,) per-owner totals.  Requires
    ``n < 2^24`` (f32-exact slot arithmetic on the MXU); ops.py enforces
    this and falls back to the reference otherwise.
    """
    n = ids.shape[0]
    if n >= 1 << 24:
        raise ValueError(f"n={n} exceeds the kernel's f32-exact bound 2^24")
    if n == 0:
        return (jnp.zeros((0,), jnp.int32), jnp.zeros((C,), jnp.int32))
    Np = -(-n // block_n) * block_n
    ids_p = jnp.pad(ids.astype(jnp.int32), (0, Np - n), constant_values=-1)
    dest_p, counts = pl.pallas_call(
        functools.partial(_scatter_kernel, C=C, n_total=n),
        grid=(2, Np // block_n),
        in_specs=[pl.BlockSpec((block_n,), lambda p, b: (b,))],
        out_specs=[
            pl.BlockSpec((block_n,), lambda p, b: (b,)),
            pl.BlockSpec((C,), lambda p, b: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Np,), jnp.int32),
            jax.ShapeDtypeStruct((C,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((C,), jnp.int32),   # running per-owner counts
            pltpu.VMEM((C,), jnp.int32),   # exclusive-scan slot offsets
        ],
        interpret=interpret,
    )(ids_p)
    return dest_p[:n], counts
