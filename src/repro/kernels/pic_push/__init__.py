from repro.kernels.pic_push.kernel import pic_push_pallas
from repro.kernels.pic_push.ops import pic_push
from repro.kernels.pic_push.ref import pic_push_ref

__all__ = ["pic_push", "pic_push_pallas", "pic_push_ref"]
