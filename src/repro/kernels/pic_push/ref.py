"""Pure-jnp oracle for the PIC particle push."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("L", "dt", "mass"))
def pic_push_ref(grid_q, x, y, vx, vy, q, *, L: int, dt: float = 1.0,
                 mass: float = 1.0):
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    i0 = jnp.floor(x).astype(jnp.int32)
    j0 = jnp.floor(y).astype(jnp.int32)
    fx = jnp.zeros_like(x)
    fy = jnp.zeros_like(y)
    gf = grid_q.astype(jnp.float32).reshape(-1)
    for di in (0, 1):
        for dj in (0, 1):
            ci = jnp.mod(i0 + di, L)
            cj = jnp.mod(j0 + dj, L)
            qc = gf[ci * L + cj]
            dx = x - (i0 + di)
            dy = y - (j0 + dj)
            r2 = dx * dx + dy * dy
            r = jnp.sqrt(r2)
            f = q * qc / jnp.maximum(r2, 1e-12)
            fx += f * dx / jnp.maximum(r, 1e-6)
            fy += f * dy / jnp.maximum(r, 1e-6)
    ax, ay = fx / mass, fy / mass
    xn = jnp.mod(x + vx * dt + 0.5 * ax * dt * dt, L)
    yn = jnp.mod(y + vy * dt + 0.5 * ay * dt * dt, L)
    return xn, yn, vx + ax * dt, vy + ay * dt
