"""Pallas TPU kernel for the PIC PRK particle push (paper §VI).

Per particle and time step (PRK semantics, Georganas et al. IPDPS'16):
  * locate the containing cell (floor of position, periodic grid);
  * Coulomb force from the four cell-corner charges:
      F = Σ_corners q_p·q_c/r² · d̂       (pic.c computeCoulomb);
  * leapfrog update:  x += v·dt + ½·(F/m)·dt²;  v += (F/m)·dt;
  * periodic wrap into [0, L).

TPU adaptation: the fixed charge grid (L×L f32, 4 MB at L=1000) is
VMEM-resident across all grid steps; particle state streams through VMEM in
blocks (``block_n``).  Corner lookups are four gathers from the flattened
grid; everything else is VPU element-wise math.  No scatter anywhere —
PIC PRK has no charge deposition (charges are fixed), which is what makes
it a pure load-balancing benchmark.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _push_kernel(grid_ref, x_ref, y_ref, vx_ref, vy_ref, q_ref,
                 xo_ref, yo_ref, vxo_ref, vyo_ref,
                 *, L: int, dt: float, mass: float):
    g = grid_ref[...]                    # (L, L) VMEM-resident charges
    x, y = x_ref[...], y_ref[...]        # (bn,)
    vx, vy = vx_ref[...], vy_ref[...]
    q = q_ref[...]

    i0 = jnp.floor(x).astype(jnp.int32)
    j0 = jnp.floor(y).astype(jnp.int32)
    fx = jnp.zeros_like(x)
    fy = jnp.zeros_like(y)
    gf = g.reshape(-1)
    for di in (0, 1):
        for dj in (0, 1):
            ci = jnp.mod(i0 + di, L)
            cj = jnp.mod(j0 + dj, L)
            qc = jnp.take(gf, ci * L + cj, mode="clip")
            dx = x - (i0 + di).astype(x.dtype)   # corner at unwrapped coord
            dy = y - (j0 + dj).astype(y.dtype)
            r2 = dx * dx + dy * dy
            r = jnp.sqrt(r2)
            f = q * qc / jnp.maximum(r2, 1e-12)
            fx = fx + f * dx / jnp.maximum(r, 1e-6)
            fy = fy + f * dy / jnp.maximum(r, 1e-6)
    ax = fx / mass
    ay = fy / mass
    xn = x + vx * dt + 0.5 * ax * dt * dt
    yn = y + vy * dt + 0.5 * ay * dt * dt
    xo_ref[...] = jnp.mod(xn, jnp.float32(L))
    yo_ref[...] = jnp.mod(yn, jnp.float32(L))
    vxo_ref[...] = vx + ax * dt
    vyo_ref[...] = vy + ay * dt


@functools.partial(
    jax.jit, static_argnames=("L", "dt", "mass", "block_n", "interpret")
)
def pic_push_pallas(
    grid_q: jax.Array,   # (L, L) f32 fixed grid-point charges
    x: jax.Array, y: jax.Array, vx: jax.Array, vy: jax.Array,
    q: jax.Array,        # (N,) particle charges
    *,
    L: int,
    dt: float = 1.0,
    mass: float = 1.0,
    block_n: int = 1024,
    interpret: bool = False,
):
    N = x.shape[0]
    Np = -(-N // block_n) * block_n

    def pad(a):
        return jnp.pad(a.astype(jnp.float32), (0, Np - N))

    grid = (Np // block_n,)
    blk = pl.BlockSpec((block_n,), lambda i: (i,))
    full = pl.BlockSpec((L, L), lambda i: (0, 0))
    outs = pl.pallas_call(
        functools.partial(_push_kernel, L=L, dt=dt, mass=mass),
        grid=grid,
        in_specs=[full, blk, blk, blk, blk, blk],
        out_specs=[blk, blk, blk, blk],
        out_shape=[jax.ShapeDtypeStruct((Np,), jnp.float32)] * 4,
        interpret=interpret,
    )(grid_q.astype(jnp.float32), pad(x), pad(y), pad(vx), pad(vy), pad(q))
    return tuple(o[:N] for o in outs)
