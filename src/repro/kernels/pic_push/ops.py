"""Jitted wrapper: Pallas on TPU, interpret-mode Pallas or oracle on CPU."""
from __future__ import annotations

from repro.distributed import compat
from repro.kernels import on_tpu
from repro.kernels.pic_push.kernel import pic_push_pallas
from repro.kernels.pic_push.ref import pic_push_ref


def pic_push(grid_q, x, y, vx, vy, q, *, L, dt=1.0, mass=1.0,
             use_kernel: bool = None):
    """Advance particles one step.  Returns (x, y, vx, vy).

    ``use_kernel=None`` auto-selects: native Pallas on TPU; the jnp oracle on
    CPU (interpret mode is Python-slow for large N — the oracle is
    numerically identical, see tests/test_kernels.py).
    """
    if use_kernel is None:
        use_kernel = on_tpu()
    with compat.named_scope("kernel/pic-push"):
        if use_kernel:
            return pic_push_pallas(grid_q, x, y, vx, vy, q, L=L, dt=dt,
                                   mass=mass, interpret=not on_tpu())
        return pic_push_ref(grid_q, x, y, vx, vy, q, L=L, dt=dt,
                            mass=mass)
