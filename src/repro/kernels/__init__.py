"""Pallas TPU kernels for the perf-critical compute hot spots.

  diffusion/  — virtual-LB diffusion sweep (paper §III.B inner loop)
  pic_push/   — PIC PRK particle push (paper §VI hot loop)
  histogram/  — per-chare load measurement (segment histogram)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with backend dispatch) and ref.py (pure-jnp oracle); tests sweep
shapes/dtypes and assert_allclose kernel-vs-oracle in interpret mode.
"""
