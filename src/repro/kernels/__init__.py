"""Pallas TPU kernels for the perf-critical compute hot spots.

  diffusion/  — virtual-LB diffusion sweep (paper §III.B inner loop)
  pic_push/   — PIC PRK particle push (paper §VI hot loop)
  histogram/  — per-chare load measurement (segment histogram)
  migrate/    — sort-free counting-scatter manifest build (§II exchange)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with backend dispatch) and ref.py (pure-jnp oracle); tests sweep
shapes/dtypes and assert_allclose kernel-vs-oracle in interpret mode.

Backend dispatch goes through :func:`on_tpu`, probed once per process —
the default backend cannot change after JAX initializes, so the per-call
``jax.default_backend()`` probe every ops.py used to run was pure
overhead on eager hot paths.
"""
from __future__ import annotations

import functools


@functools.lru_cache(maxsize=1)
def on_tpu() -> bool:
    """True iff the default JAX backend is TPU (cached at first call)."""
    import jax

    return jax.default_backend() == "tpu"
