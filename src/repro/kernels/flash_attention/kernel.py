"""Pallas TPU flash attention (GQA, causal, sliding-window).

Why a kernel: the dry-run HLO shows attention probability tensors
(B, KV, G, qc, kc) round-tripping HBM between the QK-softmax fusion and the
PV dot — ~70% of the memory-roofline term for the 32k-prefill cells
(EXPERIMENTS.md §Perf).  A fused flash kernel keeps the score block in VMEM
for its whole lifetime; HBM attention traffic drops from O(S²) to O(S·d).

TPU adaptation (HBM→VMEM→VREG, MXU):
  * grid = (batch·kv_head, q_blocks): each program owns one (b, kv-head)
    slice and one q block — q/o blocks are VMEM-resident across the inner
    loop; K/V stream in kv-blocks via manual dynamic slices so the causal
    upper triangle is never read (the index_map trick doesn't allow a
    data-dependent number of blocks; we bound the loop with
    ``lax.fori_loop`` over ceil((q_hi+1)/kb) blocks).
  * block shapes: q (qb, G·hd), kv (kb, hd) with qb, kb multiples of 128 —
    MXU-aligned on the contraction dims; fp32 accumulators for m/l/o
    (online softmax), bf16 streams.
  * no transposes: scores = q·kᵀ via dot_general on the last dims.

The pure-jnp oracle is models/attention.py::chunked_attention (re-exported
in ref.py) — the exact module the model calls when the kernel is off, so
kernel == model semantics by construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, qpos_ref, kpos_ref, o_ref,
                  *, kb: int, window: int, prefix_len: int, scale: float):
    """One (batch·kv-head, q-block) program.

    q_ref:   (1, qb, G, hd) — this q block, all query groups of the kv head
    k_ref:   (1, T, hd)     — full K for this (b, kv head) (streamed blocks)
    v_ref:   (1, T, hd)
    qpos/kpos: (1, qb), (1, T) i32 positions (sentinel = unwritten slot)
    o_ref:   (1, qb, G, hd)
    """
    _, qb, G, hd = q_ref.shape
    T = k_ref.shape[1]
    q = q_ref[0].astype(jnp.float32) * scale             # (qb, G, hd)
    qpos = qpos_ref[0]

    m0 = jnp.full((qb, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((qb, G), jnp.float32)
    o0 = jnp.zeros((qb, G, hd), jnp.float32)

    # causal bound: kv blocks beyond max(qpos) are all masked.  qpos is a
    # runtime value, so bound the loop count dynamically with fori_loop.
    hi = jnp.max(jnp.where(qpos < 2 ** 29, qpos, -1))
    n_blocks = jnp.minimum((hi + kb) // kb + 1, (T + kb - 1) // kb)

    def body(i, carry):
        m, l, o = carry
        k = jax.lax.dynamic_slice(k_ref[0], (i * kb, 0), (kb, hd))
        v = jax.lax.dynamic_slice(v_ref[0], (i * kb, 0), (kb, hd))
        kpos = jax.lax.dynamic_slice(kpos_ref[0], (i * kb,), (kb,))
        s = jax.lax.dot_general(
            q, k.astype(jnp.float32),
            dimension_numbers=(((2,), (1,)), ((), ())),
        )                                                # (qb, G, kb)
        ok = kpos[None, :] <= qpos[:, None]              # causal+valid
        if window:
            ok &= (qpos[:, None] - kpos[None, :]) < window
        if prefix_len:
            ok |= (kpos[None, :] < prefix_len) & (kpos[None, :] < 2 ** 29)
        s = jnp.where(ok[:, None, :], s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v,
            dimension_numbers=(((2,), (0,)), ((), ())),
        ).astype(jnp.float32)                            # (qb, G, hd)
        o_new = o * corr[..., None] + pv
        return m_new, l_new, o_new

    m, l, o = jax.lax.fori_loop(0, n_blocks, body, (m0, l0, o0))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "prefix_len", "q_block", "kv_block",
                     "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,       # (B, Sq, KV, G, hd)
    k: jax.Array,       # (B, T, KV, hd)
    v: jax.Array,       # (B, T, KV, hd)
    q_pos: jax.Array,   # (B, Sq)
    kv_pos: jax.Array,  # (B, T)
    *,
    window: int = 0,
    prefix_len: int = 0,
    q_block: int = 128,
    kv_block: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, KV, G, hd = q.shape
    T = k.shape[1]
    qb = min(q_block, Sq)
    kb = min(kv_block, T)
    Sp = -(-Sq // qb) * qb
    Tp = -(-T // kb) * kb
    if Sp != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sp - Sq)) + ((0, 0),) * 3)
        q_pos = jnp.pad(q_pos, ((0, 0), (0, Sp - Sq)),
                        constant_values=2 ** 30)
    if Tp != T:
        k = jnp.pad(k, ((0, 0), (0, Tp - T)) + ((0, 0),) * 2)
        v = jnp.pad(v, ((0, 0), (0, Tp - T)) + ((0, 0),) * 2)
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, Tp - T)),
                         constant_values=2 ** 30)

    # layout: merge (B, KV) into the grid's first axis
    qr = q.transpose(0, 2, 1, 3, 4).reshape(B * KV, Sp, G, hd)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, Tp, hd)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, Tp, hd)
    qpr = jnp.repeat(q_pos, KV, axis=0)                  # (B·KV, Sp)
    kpr = jnp.repeat(kv_pos, KV, axis=0)

    grid = (B * KV, Sp // qb)
    scale = 1.0 / float(hd) ** 0.5
    out = pl.pallas_call(
        functools.partial(_flash_kernel, kb=kb, window=window,
                          prefix_len=prefix_len, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qb, G, hd), lambda b, i: (b, i, 0, 0)),
            pl.BlockSpec((1, Tp, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Tp, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, qb), lambda b, i: (b, i)),
            pl.BlockSpec((1, Tp), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, G, hd), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, Sp, G, hd), q.dtype),
        interpret=interpret,
    )(qr, kr, vr, qpr, kpr)

    out = out.reshape(B, KV, Sp, G, hd).transpose(0, 2, 1, 3, 4)
    return out[:, :Sq]
