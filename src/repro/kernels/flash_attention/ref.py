"""Pure-jnp oracle: the exact chunked-attention path the model uses when
the kernel is off (models/attention.py) — kernel == model semantics."""
from repro.models.attention import chunked_attention


def flash_attention_ref(q, k, v, q_pos, kv_pos, *, window=0, prefix_len=0):
    return chunked_attention(q, k, v, q_pos, kv_pos, window=window,
                             prefix_len=prefix_len)
