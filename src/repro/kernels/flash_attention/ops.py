"""Jitted wrapper: Pallas flash attention on TPU, oracle on CPU."""
from __future__ import annotations

from repro.kernels import on_tpu
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, q_pos, kv_pos, *, window=0, prefix_len=0,
                    use_kernel=None):
    if use_kernel is None:
        use_kernel = on_tpu()
    if use_kernel:
        return flash_attention_pallas(q, k, v, q_pos, kv_pos, window=window,
                                      prefix_len=prefix_len,
                                      interpret=not on_tpu())
    return flash_attention_ref(q, k, v, q_pos, kv_pos, window=window,
                               prefix_len=prefix_len)
