"""Jitted wrapper: Pallas on TPU, oracle on CPU (numerically identical)."""
from __future__ import annotations

import jax

from repro.kernels.histogram.kernel import histogram_pallas
from repro.kernels.histogram.ref import histogram_ref


def histogram(ids, weights, *, C: int, use_kernel: bool = None):
    on_tpu = jax.default_backend() == "tpu"
    if use_kernel is None:
        use_kernel = on_tpu
    if use_kernel:
        return histogram_pallas(ids, weights, C=C, interpret=not on_tpu)
    return histogram_ref(ids, weights, C=C)
