"""Jitted wrapper: Pallas on TPU, oracle on CPU (numerically identical)."""
from __future__ import annotations

from repro.distributed import compat
from repro.kernels import on_tpu
from repro.kernels.histogram.kernel import histogram_pallas
from repro.kernels.histogram.ref import histogram_ref


def histogram(ids, weights, *, C: int, use_kernel: bool = None):
    if use_kernel is None:
        use_kernel = on_tpu()
    with compat.named_scope("kernel/histogram"):
        if use_kernel:
            return histogram_pallas(ids, weights, C=C,
                                    interpret=not on_tpu())
        return histogram_ref(ids, weights, C=C)
