"""Pallas TPU kernel: segment histogram (per-chare load measurement).

Counts (or load-weighted sums) of particles per chare — the measurement the
PIC driver feeds the balancer every LB period.  TPU adaptation: scatter-add
serializes on TPU, so each particle block is binned with a compare-matmul
(one-hot (block_n × C) mask contracted against the weights on the
MXU-friendly path) and accumulated into a VMEM-resident (C,) accumulator
across sequential grid steps (standard revisited-output pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(ids_ref, w_ref, out_ref, *, C: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                     # (bn,) i32, -1 = padding
    w = w_ref[...]                         # (bn,) f32
    onehot = (ids[:, None] == jax.lax.iota(jnp.int32, C)[None, :])
    # contract weights against the one-hot mask on the MXU: a (1, bn) ×
    # (bn, C) matmul replaces the (bn, C) masked where-sum the VPU would
    # otherwise reduce serially.  Padding ids (-1) match no bin → zero
    # columns, so no separate mask is needed.
    # HIGHEST keeps the f32 weights exact on the MXU (default precision
    # would round them through bf16, breaking the ops.py "numerically
    # identical to the oracle" contract)
    contrib = jnp.dot(w[None, :], onehot.astype(jnp.float32),
                      preferred_element_type=jnp.float32,
                      precision=jax.lax.Precision.HIGHEST)[0]
    out_ref[...] += contrib


@functools.partial(jax.jit, static_argnames=("C", "block_n", "interpret"))
def histogram_pallas(
    ids: jax.Array,           # (N,) i32 bin ids in [0, C); negatives ignored
    weights: jax.Array,       # (N,) f32
    *,
    C: int,
    block_n: int = 2048,
    interpret: bool = False,
) -> jax.Array:
    N = ids.shape[0]
    Np = -(-N // block_n) * block_n
    ids_p = jnp.pad(ids.astype(jnp.int32), (0, Np - N), constant_values=-1)
    w_p = jnp.pad(weights.astype(jnp.float32), (0, Np - N))
    return pl.pallas_call(
        functools.partial(_hist_kernel, C=C),
        grid=(Np // block_n,),
        in_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((C,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((C,), jnp.float32),
        interpret=interpret,
    )(ids_p, w_p)
