"""Pure-jnp oracle for the segment histogram."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("C",))
def histogram_ref(ids, weights, *, C: int):
    valid = ids >= 0
    return jax.ops.segment_sum(
        jnp.where(valid, weights.astype(jnp.float32), 0.0),
        jnp.where(valid, ids, 0),
        num_segments=C,
    )
