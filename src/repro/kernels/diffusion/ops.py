"""Jitted wrappers selecting the diffusion-sweep implementation.

``diffusion_sweep`` matches the per-sweep ``step_fn`` signature expected by
``core.virtual_lb.virtual_balance``; ``diffusion_nsweeps`` matches the
fused S-sweep ``chunk_fn`` signature (the production planning path).

Implementation selection (``sweep_impl``):

  * ``"fused"``     — TPU, working set within :data:`FUSED_VMEM_BUDGET`:
                      the fused multi-sweep kernel (tables loaded to VMEM
                      once per S-sweep block, push/recv fused, flow +
                      residual on-chip).
  * ``"streaming"`` — TPU, tables too large for VMEM: the two-pass
                      streaming kernel per sweep, wrapped in the shared
                      masked chunk loop.
  * ``"reference"`` — CPU/GPU: the pure-jnp chunk (XLA-compiled; Pallas
                      interpret mode is Python-slow and numerically
                      identical, so it is reserved for the kernel tests).
"""
from __future__ import annotations

from repro.distributed import compat
from repro.kernels import on_tpu
from repro.kernels.diffusion.kernel import (
    diffusion_nsweeps_pallas,
    diffusion_sweep_pallas,
)
from repro.kernels.diffusion.ref import diffusion_sweep_ref
from repro.core.virtual_lb import reference_nsweeps

# VMEM working-set budget for the fused kernel (bytes).  ~16 MB per core;
# half is left for double-buffered pipelining headroom and the compiler.
FUSED_VMEM_BUDGET = 8 * 1024 * 1024


def fused_vmem_bytes(P: int, K: int) -> int:
    """Fused-kernel VMEM working set for a (P, K) problem.

    Tables: nbr + rev (i32) and mask (i8) — (4+4+1)·P·K; carried state:
    x/own vectors and the flow accumulator — 4·P·(K+2); per-sweep
    intermediates: push, recv, and the (P, K+1) residual scratch —
    ≈ 4·P·(3K+2).
    """
    return P * K * 9 + 4 * P * (K + 2) + 4 * P * (3 * K + 2)


def sweep_impl(P: int, K: int) -> str:
    """Which implementation ``diffusion_nsweeps`` selects for (P, K)."""
    if not on_tpu():
        return "reference"
    if fused_vmem_bytes(P, K) <= FUSED_VMEM_BUDGET:
        return "fused"
    return "streaming"


def diffusion_sweep(x, own, nbr_idx, nbr_mask, rev, alpha, single_hop=True):
    return diffusion_sweep_pallas(
        x, own, nbr_idx, nbr_mask, rev, alpha, single_hop,
        interpret=not on_tpu(),
    )


def diffusion_sweep_reference(x, own, nbr_idx, nbr_mask, rev, alpha,
                              single_hop=True):
    return diffusion_sweep_ref(x, own, nbr_idx, nbr_mask, rev, alpha,
                               single_hop)


def diffusion_nsweeps(x, own, flow, it, res, stall, nbr_idx, nbr_mask, rev,
                      alpha, *, n_sweeps: int, single_hop: bool, tol,
                      max_iters):
    """Fused S-sweep block (``chunk_fn`` for ``virtual_balance``).

    Auto-selects per :func:`sweep_impl`; all three paths are bit-for-bit
    identical (shared ``core.virtual_lb.sweep_chunk_body``).
    """
    impl = sweep_impl(*nbr_idx.shape)
    with compat.named_scope(f"kernel/diffusion-nsweeps-{impl}"):
        if impl == "fused":
            return diffusion_nsweeps_pallas(
                x, own, flow, it, res, stall, nbr_idx, nbr_mask, rev,
                alpha, n_sweeps=n_sweeps, single_hop=single_hop, tol=tol,
                max_iters=max_iters)
        step_fn = diffusion_sweep if impl == "streaming" else None
        return reference_nsweeps(
            x, own, flow, it, res, stall, nbr_idx, nbr_mask, rev, alpha,
            n_sweeps=n_sweeps, single_hop=single_hop, tol=tol,
            max_iters=max_iters, step_fn=step_fn)
