"""Jitted wrapper selecting the diffusion-sweep implementation.

``diffusion_sweep`` matches the ``step_fn`` signature expected by
``core.virtual_lb.virtual_balance``.  On CPU (this container) the Pallas
kernel runs in interpret mode; on TPU it compiles natively.
"""
from __future__ import annotations

import jax

from repro.kernels.diffusion.kernel import diffusion_sweep_pallas
from repro.kernels.diffusion.ref import diffusion_sweep_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def diffusion_sweep(x, own, nbr_idx, nbr_mask, rev, alpha, single_hop=True):
    return diffusion_sweep_pallas(
        x, own, nbr_idx, nbr_mask, rev, alpha, single_hop,
        interpret=not _on_tpu(),
    )


def diffusion_sweep_reference(x, own, nbr_idx, nbr_mask, rev, alpha,
                              single_hop=True):
    return diffusion_sweep_ref(x, own, nbr_idx, nbr_mask, rev, alpha,
                               single_hop)
