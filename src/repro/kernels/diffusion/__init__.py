from repro.kernels.diffusion.ops import (
    diffusion_sweep,
    diffusion_sweep_reference,
)
from repro.kernels.diffusion.kernel import diffusion_sweep_pallas

__all__ = [
    "diffusion_sweep",
    "diffusion_sweep_pallas",
    "diffusion_sweep_reference",
]
