"""Pure-jnp oracle for the diffusion sweep kernel.

This is exactly the reference implementation the balancer uses by default
(core/virtual_lb.py); re-exported here so the kernel test sweep has a single
canonical oracle path.
"""
from repro.core.virtual_lb import reference_sweep


def diffusion_sweep_ref(x, own, nbr_idx, nbr_mask, rev, alpha,
                        single_hop: bool = True):
    return reference_sweep(x, own, nbr_idx, nbr_mask, rev, alpha, single_hop)
