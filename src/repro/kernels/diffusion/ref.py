"""Pure-jnp oracle for the diffusion sweep kernel.

This is exactly the reference implementation the balancer uses by default
(core/virtual_lb.py); re-exported here so the kernel test sweep has a single
canonical oracle path.
"""
from repro.core.virtual_lb import reference_nsweeps, reference_sweep


def diffusion_sweep_ref(x, own, nbr_idx, nbr_mask, rev, alpha,
                        single_hop: bool = True):
    return reference_sweep(x, own, nbr_idx, nbr_mask, rev, alpha, single_hop)


def diffusion_nsweeps_ref(x, own, flow, it, res, stall, nbr_idx, nbr_mask,
                          rev, alpha, *, n_sweeps: int, single_hop: bool,
                          tol, max_iters):
    """S-sweep chunk oracle for ``diffusion_nsweeps_pallas``."""
    return reference_nsweeps(
        x, own, flow, it, res, stall, nbr_idx, nbr_mask, rev, alpha,
        n_sweeps=n_sweeps, single_hop=single_hop, tol=tol,
        max_iters=max_iters)
