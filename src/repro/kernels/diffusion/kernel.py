"""Pallas TPU kernel for the virtual-LB diffusion sweep (paper §III.B).

The sweep is the iterated hot loop of the balancer: at simulator scale
(P ~ 10^5-10^6 nodes, K ≤ 16 neighbors) hundreds of sweeps run per LB round.

TPU adaptation (HBM→VMEM→VREG):
  * the load vector ``x`` (P f32 ≤ 4 MB at P = 10^6) and ``own`` stay fully
    VMEM-resident for every grid step — they are the gather targets;
  * the per-node neighbor tables (P×K idx/mask/rev) stream through VMEM in
    node blocks (``block_p`` rows per grid step) — they are touched once;
  * all compute is VPU element-wise math over (block_p, K) tiles; there is
    deliberately no scatter: the symmetric-graph identity
        recv[i, k] = push[nbr[i, k], rev[i, k]]
    turns "receive" into a second gather, so each sweep is gather-only
    (scatters serialize on TPU; gathers vectorize).

The kernel computes *one* sweep; the fixed-point loop lives in
``core/virtual_lb.py`` (jax.lax.while_loop) and passes
``kernels.diffusion.ops.diffusion_sweep`` as ``step_fn``.

Two-pass structure within a sweep (both passes tile over node blocks):
  pass A computes the scaled ``push`` matrix (needs the single-hop row scale);
  pass B gathers ``recv`` from the completed push matrix and forms outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _push_kernel(x_ref, own_ref, nbr_ref, mask_ref, alpha_ref,
                 push_ref, *, single_hop: bool):
    """Pass A: push[i, k] = alpha * (x_i - x_nbr) clamped ≥ 0, row-rescaled
    so a node never ships more than its remaining own load (single-hop)."""
    x = x_ref[...]                       # (P,) — whole vector in VMEM
    nbr = nbr_ref[...]                   # (bp, K) node block
    mask = mask_ref[...]
    alpha = alpha_ref[0]
    i0 = pl.program_id(0) * nbr.shape[0]
    xi = jax.lax.dynamic_slice(x, (i0,), (nbr.shape[0],))      # (bp,)
    xn = jnp.where(mask, jnp.take(x, jnp.where(mask, nbr, 0), axis=0,
                                  mode="clip"), xi[:, None])
    push = jnp.maximum(alpha * (xi[:, None] - xn), 0.0)
    push = jnp.where(mask, push, 0.0)
    if single_hop:
        own = jax.lax.dynamic_slice(own_ref[...], (i0,), (nbr.shape[0],))
        tot = push.sum(axis=1)
        scale = jnp.where(tot > 0.0,
                          jnp.minimum(1.0, own / (tot + 1e-30)), 1.0)
        push = push * scale[:, None]
    push_ref[...] = push


def _recv_kernel(x_ref, own_ref, push_ref, nbr_ref, mask_ref, rev_ref,
                 x_out_ref, own_out_ref, flow_ref):
    """Pass B: recv[i,k] = push[nbr[i,k], rev[i,k]]; form outputs."""
    nbr = nbr_ref[...]                   # (bp, K)
    mask = mask_ref[...]
    rev = rev_ref[...]
    K = nbr.shape[1]
    i0 = pl.program_id(0) * nbr.shape[0]
    push_all = push_ref[...]             # (P, K) VMEM-resident
    my_push = jax.lax.dynamic_slice(
        push_all, (i0, 0), (nbr.shape[0], K))
    flat = jnp.where(mask, nbr, 0) * K + jnp.where(mask, rev, 0)
    recv = jnp.where(
        mask, jnp.take(push_all.reshape(-1), flat, axis=0, mode="clip"), 0.0)
    sent = my_push.sum(axis=1)
    xi = jax.lax.dynamic_slice(x_ref[...], (i0,), (nbr.shape[0],))
    own = jax.lax.dynamic_slice(own_ref[...], (i0,), (nbr.shape[0],))
    x_out_ref[...] = xi - sent + recv.sum(axis=1)
    own_out_ref[...] = own - sent
    flow_ref[...] = my_push - recv


def _pad_to(a, n, axis=0):
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, n - a.shape[axis])
    return jnp.pad(a, pad)


@functools.partial(
    jax.jit,
    static_argnames=("single_hop", "block_p", "interpret"),
)
def diffusion_sweep_pallas(
    x: jax.Array,          # (P,) f32 current virtual loads
    own: jax.Array,        # (P,) f32 remaining own (originating) load
    nbr_idx: jax.Array,    # (P, K) i32, -1 padded
    nbr_mask: jax.Array,   # (P, K) bool
    rev: jax.Array,        # (P, K) i32 reverse slots
    alpha,
    single_hop: bool = True,
    *,
    block_p: int = 512,
    interpret: bool = False,
):
    """One diffusion sweep. Returns (x_new, own_new, net_flow (P,K))."""
    P, K = nbr_idx.shape
    Pp = -(-P // block_p) * block_p
    xp = _pad_to(x.astype(jnp.float32), Pp)
    ownp = _pad_to(own.astype(jnp.float32), Pp)
    nbrp = _pad_to(nbr_idx, Pp)
    maskp = _pad_to(nbr_mask, Pp)
    revp = _pad_to(rev, Pp)
    alpha_arr = jnp.full((1,), alpha, jnp.float32)
    grid = (Pp // block_p,)

    vec_full = pl.BlockSpec((Pp,), lambda i: (0,))          # VMEM-resident
    tab_full = pl.BlockSpec((Pp, K), lambda i: (0, 0))
    tab_blk = pl.BlockSpec((block_p, K), lambda i: (i, 0))
    vec_blk = pl.BlockSpec((block_p,), lambda i: (i,))

    push = pl.pallas_call(
        functools.partial(_push_kernel, single_hop=single_hop),
        grid=grid,
        in_specs=[vec_full, vec_full, tab_blk, tab_blk,
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=tab_blk,
        out_shape=jax.ShapeDtypeStruct((Pp, K), jnp.float32),
        interpret=interpret,
    )(xp, ownp, nbrp, maskp, alpha_arr)

    x_new, own_new, flow = pl.pallas_call(
        _recv_kernel,
        grid=grid,
        in_specs=[vec_full, vec_full, tab_full, tab_blk, tab_blk, tab_blk],
        out_specs=[vec_blk, vec_blk, tab_blk],
        out_shape=[
            jax.ShapeDtypeStruct((Pp,), jnp.float32),
            jax.ShapeDtypeStruct((Pp,), jnp.float32),
            jax.ShapeDtypeStruct((Pp, K), jnp.float32),
        ],
        interpret=interpret,
    )(xp, ownp, push, nbrp, maskp, revp)

    return x_new[:P], own_new[:P], flow[:P]
