"""Pallas TPU kernels for the virtual-LB diffusion sweep (paper §III.B).

The sweep is the iterated hot loop of the balancer: at simulator scale
(P ~ 10^5-10^6 nodes, K ≤ 16 neighbors) hundreds of sweeps run per LB round.
Two kernels cover the P spectrum (``ops.py`` selects automatically):

**Fused multi-sweep kernel** (``diffusion_nsweeps_pallas``) — the default
when the working set fits the VMEM budget.  One ``pallas_call`` runs S
sweeps back-to-back: the neighbor/mask/reverse tables are loaded into VMEM
*once per S-sweep block* (instead of twice per sweep), push+recv fuse into
a single gather-only pass per sweep via the symmetric-graph identity
    recv[i, k] = push[nbr[i, k], rev[i, k]]
(the push matrix never round-trips HBM), and the (P, K) flow accumulator
plus the neighborhood residual stay on-chip across the whole block.  Each
sweep is gated by the same early-exit predicate the outer fixed-point loop
checks (convergence / iteration cap / stall), so the block is bit-for-bit
equal to S steps of the per-sweep loop — the sweep body is the *shared*
``core.virtual_lb.sweep_chunk_body``, identical by construction.

**Streaming two-pass kernel** (``diffusion_sweep_pallas``) — the large-P
fallback.  Computes one sweep with the tables streamed through VMEM in
``block_p`` node blocks (touched once per pass):
  * the load vector ``x`` (P f32 ≤ 4 MB at P = 10^6) and ``own`` stay fully
    VMEM-resident for every grid step — they are the gather targets;
  * pass A computes the scaled ``push`` matrix (single-hop row scale);
  * pass B gathers ``recv`` from the completed push matrix and forms
    outputs — gather-only, no scatters (scatters serialize on TPU).

The fixed-point loop lives in ``core/virtual_lb.py`` (a
``jax.lax.while_loop`` over S-sweep chunks); ``ops.diffusion_nsweeps`` is
the production ``chunk_fn`` and ``ops.diffusion_sweep`` the per-sweep
``step_fn``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.virtual_lb import sweep_chunk_body, reference_sweep


def _push_kernel(x_ref, own_ref, nbr_ref, mask_ref, alpha_ref,
                 push_ref, *, single_hop: bool):
    """Pass A: push[i, k] = alpha * (x_i - x_nbr) clamped ≥ 0, row-rescaled
    so a node never ships more than its remaining own load (single-hop)."""
    x = x_ref[...]                       # (P,) — whole vector in VMEM
    nbr = nbr_ref[...]                   # (bp, K) node block
    mask = mask_ref[...]
    alpha = alpha_ref[0]
    i0 = pl.program_id(0) * nbr.shape[0]
    xi = jax.lax.dynamic_slice(x, (i0,), (nbr.shape[0],))      # (bp,)
    xn = jnp.where(mask, jnp.take(x, jnp.where(mask, nbr, 0), axis=0,
                                  mode="clip"), xi[:, None])
    push = jnp.maximum(alpha * (xi[:, None] - xn), 0.0)
    push = jnp.where(mask, push, 0.0)
    if single_hop:
        own = jax.lax.dynamic_slice(own_ref[...], (i0,), (nbr.shape[0],))
        tot = push.sum(axis=1)
        scale = jnp.where(tot > 0.0,
                          jnp.minimum(1.0, own / (tot + 1e-30)), 1.0)
        push = push * scale[:, None]
    push_ref[...] = push


def _recv_kernel(x_ref, own_ref, push_ref, nbr_ref, mask_ref, rev_ref,
                 x_out_ref, own_out_ref, flow_ref):
    """Pass B: recv[i,k] = push[nbr[i,k], rev[i,k]]; form outputs."""
    nbr = nbr_ref[...]                   # (bp, K)
    mask = mask_ref[...]
    rev = rev_ref[...]
    K = nbr.shape[1]
    i0 = pl.program_id(0) * nbr.shape[0]
    push_all = push_ref[...]             # (P, K) VMEM-resident
    my_push = jax.lax.dynamic_slice(
        push_all, (i0, 0), (nbr.shape[0], K))
    flat = jnp.where(mask, nbr, 0) * K + jnp.where(mask, rev, 0)
    recv = jnp.where(
        mask, jnp.take(push_all.reshape(-1), flat, axis=0, mode="clip"), 0.0)
    sent = my_push.sum(axis=1)
    xi = jax.lax.dynamic_slice(x_ref[...], (i0,), (nbr.shape[0],))
    own = jax.lax.dynamic_slice(own_ref[...], (i0,), (nbr.shape[0],))
    x_out_ref[...] = xi - sent + recv.sum(axis=1)
    own_out_ref[...] = own - sent
    flow_ref[...] = my_push - recv


def _pad_to(a, n, axis=0):
    pad = [(0, 0)] * a.ndim
    pad[axis] = (0, n - a.shape[axis])
    return jnp.pad(a, pad)


@functools.partial(
    jax.jit,
    static_argnames=("single_hop", "block_p", "interpret"),
)
def diffusion_sweep_pallas(
    x: jax.Array,          # (P,) f32 current virtual loads
    own: jax.Array,        # (P,) f32 remaining own (originating) load
    nbr_idx: jax.Array,    # (P, K) i32, -1 padded
    nbr_mask: jax.Array,   # (P, K) bool
    rev: jax.Array,        # (P, K) i32 reverse slots
    alpha,
    single_hop: bool = True,
    *,
    block_p: int = 512,
    interpret: bool = False,
):
    """One diffusion sweep. Returns (x_new, own_new, net_flow (P,K))."""
    P, K = nbr_idx.shape
    Pp = -(-P // block_p) * block_p
    xp = _pad_to(x.astype(jnp.float32), Pp)
    ownp = _pad_to(own.astype(jnp.float32), Pp)
    nbrp = _pad_to(nbr_idx, Pp)
    maskp = _pad_to(nbr_mask, Pp)
    revp = _pad_to(rev, Pp)
    alpha_arr = jnp.full((1,), alpha, jnp.float32)
    grid = (Pp // block_p,)

    vec_full = pl.BlockSpec((Pp,), lambda i: (0,))          # VMEM-resident
    tab_full = pl.BlockSpec((Pp, K), lambda i: (0, 0))
    tab_blk = pl.BlockSpec((block_p, K), lambda i: (i, 0))
    vec_blk = pl.BlockSpec((block_p,), lambda i: (i,))

    push = pl.pallas_call(
        functools.partial(_push_kernel, single_hop=single_hop),
        grid=grid,
        in_specs=[vec_full, vec_full, tab_blk, tab_blk,
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=tab_blk,
        out_shape=jax.ShapeDtypeStruct((Pp, K), jnp.float32),
        interpret=interpret,
    )(xp, ownp, nbrp, maskp, alpha_arr)

    x_new, own_new, flow = pl.pallas_call(
        _recv_kernel,
        grid=grid,
        in_specs=[vec_full, vec_full, tab_full, tab_blk, tab_blk, tab_blk],
        out_specs=[vec_blk, vec_blk, tab_blk],
        out_shape=[
            jax.ShapeDtypeStruct((Pp,), jnp.float32),
            jax.ShapeDtypeStruct((Pp,), jnp.float32),
            jax.ShapeDtypeStruct((Pp, K), jnp.float32),
        ],
        interpret=interpret,
    )(xp, ownp, push, nbrp, maskp, revp)

    return x_new[:P], own_new[:P], flow[:P]


# ------------------------------------------------------ fused multi-sweep --


def _nsweeps_kernel(x_ref, own_ref, flow_ref, nbr_ref, mask_ref, rev_ref,
                    fscal_ref, iscal_ref,
                    x_out_ref, own_out_ref, flow_out_ref, fstat_ref,
                    istat_ref, *, n_sweeps: int, single_hop: bool, P: int):
    """S early-exit-gated sweeps over fully VMEM-resident state.

    The whole working set — ``x``/``own`` vectors, the (P, K) tables, the
    flow accumulator and the per-sweep push/recv intermediates — lives in
    VMEM for the entire block; HBM is touched exactly once on the way in
    and once on the way out.  The sweep body is the shared
    ``core.virtual_lb.sweep_chunk_body`` (gather-only, one pass per sweep),
    so the block is bit-for-bit the per-sweep reference loop.  Padding rows
    (layout alignment) are sliced off before compute: reductions (residual
    mean, stall detection) see exactly the (P,) problem the reference sees.
    """
    pad = x_ref.shape[0] - P
    x = x_ref[...][:P]
    own = own_ref[...][:P]
    flow = flow_ref[...][:P]
    nbr = nbr_ref[...][:P]
    mask = mask_ref[...][:P]
    rev = rev_ref[...][:P]
    alpha, tol, res0 = fscal_ref[0], fscal_ref[1], fscal_ref[2]
    it0, max_iters, stall0 = iscal_ref[0], iscal_ref[1], iscal_ref[2]

    body = sweep_chunk_body(reference_sweep, nbr, mask, rev, alpha,
                            single_hop, tol, max_iters)
    x, own, flow, it, res, stall = jax.lax.fori_loop(
        0, n_sweeps, body, (x, own, flow, it0, res0, stall0))

    x_out_ref[...] = jnp.pad(x, (0, pad))
    own_out_ref[...] = jnp.pad(own, (0, pad))
    flow_out_ref[...] = jnp.pad(flow, ((0, pad), (0, 0)))
    fstat_ref[...] = res[None]
    istat_ref[...] = jnp.stack([it, stall])


@functools.partial(
    jax.jit,
    static_argnames=("n_sweeps", "single_hop", "interpret"),
)
def diffusion_nsweeps_pallas(
    x: jax.Array,          # (P,) f32 current virtual loads
    own: jax.Array,        # (P,) f32 remaining own (originating) load
    flow: jax.Array,       # (P, K) f32 accumulated net flow (carried)
    it: jax.Array,         # scalar i32 sweeps executed so far
    res: jax.Array,        # scalar f32 current neighborhood residual
    stall: jax.Array,      # scalar i32 consecutive stalled sweeps
    nbr_idx: jax.Array,    # (P, K) i32, -1 padded
    nbr_mask: jax.Array,   # (P, K) bool
    rev: jax.Array,        # (P, K) i32 reverse slots
    alpha,
    *,
    n_sweeps: int,
    single_hop: bool = True,
    tol=0.02,
    max_iters=512,
    interpret: bool = False,
):
    """Fused S-sweep block.  Returns the updated
    ``(x, own, flow, it, res, stall)`` carry — the ``chunk_fn`` contract of
    ``core.virtual_lb.virtual_balance`` (see :func:`reference_nsweeps`)."""
    P, K = nbr_idx.shape
    Pp = -(-P // 8) * 8                       # f32 sublane alignment
    xp = _pad_to(x.astype(jnp.float32), Pp)
    ownp = _pad_to(own.astype(jnp.float32), Pp)
    flowp = _pad_to(flow.astype(jnp.float32), Pp)
    nbrp = _pad_to(nbr_idx, Pp)
    maskp = _pad_to(nbr_mask, Pp)
    revp = _pad_to(rev, Pp)
    fscal = jnp.stack([jnp.float32(alpha), jnp.float32(tol),
                       jnp.float32(res)])
    iscal = jnp.stack([jnp.int32(it), jnp.int32(max_iters),
                       jnp.int32(stall)])

    # no grid: one program, every operand fully VMEM-resident for the block
    x_new, own_new, flow_new, fstat, istat = pl.pallas_call(
        functools.partial(_nsweeps_kernel, n_sweeps=n_sweeps,
                          single_hop=single_hop, P=P),
        out_shape=[
            jax.ShapeDtypeStruct((Pp,), jnp.float32),
            jax.ShapeDtypeStruct((Pp,), jnp.float32),
            jax.ShapeDtypeStruct((Pp, K), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((2,), jnp.int32),
        ],
        interpret=interpret,
    )(xp, ownp, flowp, nbrp, maskp, revp, fscal, iscal)

    return (x_new[:P], own_new[:P], flow_new[:P],
            istat[0], fstat[0], istat[1])
