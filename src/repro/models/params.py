"""Parameter declaration machinery.

Architectures declare parameters as ``ParamSpec`` trees (shape + sharding
PartitionSpec + initializer).  From one declaration we derive:

  * ``init_params``      — materialized fp32 weights (smoke tests, examples);
  * ``shape_dtype_tree`` — jax.ShapeDtypeStruct stand-ins (the dry-run path:
    no allocation ever happens for the full-size configs);
  * ``sharding_tree``    — NamedSharding per leaf for a given mesh.

Stacked (scan-over-layers) parameters carry a leading group dimension that
is always replicated (PartitionSpec prefix None).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    spec: Tuple[Optional[str | Tuple[str, ...]], ...]  # PartitionSpec axes
    init: str = "normal"        # normal | zeros | ones
    scale: float = 0.02
    dtype: Any = jnp.float32

    def pspec(self) -> P:
        return P(*self.spec)

    def sds(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def shape_dtype_tree(tree):
    return tree_map_specs(lambda s: s.sds(), tree)


def sharding_tree(tree, mesh: Mesh):
    return tree_map_specs(lambda s: NamedSharding(mesh, s.pspec()), tree)


def pspec_tree(tree):
    return tree_map_specs(lambda s: s.pspec(), tree)


def init_params(tree, seed: int = 0):
    """Materialize weights.  Deterministic per-leaf fold-in of the path."""
    leaves, treedef = jax.tree_util.tree_flatten(tree, is_leaf=is_spec)
    root = jax.random.PRNGKey(seed)
    out = []
    for i, spec in enumerate(leaves):
        key = jax.random.fold_in(root, i)
        if spec.init == "zeros":
            out.append(jnp.zeros(spec.shape, spec.dtype))
        elif spec.init == "ones":
            out.append(jnp.ones(spec.shape, spec.dtype))
        elif spec.init == "normal":
            out.append(
                (jax.random.normal(key, spec.shape, jnp.float32)
                 * spec.scale).astype(spec.dtype)
            )
        else:
            raise ValueError(spec.init)
    return jax.tree_util.tree_unflatten(treedef, out)


def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree, is_leaf=is_spec)
    return int(sum(int(np.prod(l.shape)) for l in leaves))
