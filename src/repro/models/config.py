"""Unified model configuration covering all assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int
    num_shared: int = 0          # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    impl: str = "auto"           # auto | dense | a2a


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 ⇒ d_model // num_heads
    d_ff_dense: int = 0               # dense-MLP width when it differs from
                                      # d_ff (deepseek: d_ff is the expert dim)
    # block stack: repeating unit of block kinds, scanned over groups.
    # kinds: "attn" | "moe" | "attn_local" | "moe_local" | "hymba"
    #        | "mlstm" | "slstm"
    layer_unit: Tuple[str, ...] = ("attn",)
    prefix_layers: Tuple[str, ...] = ()   # unrolled before the scanned groups
    suffix_layers: Tuple[str, ...] = ()   # unrolled after
    # attention
    attention: str = "gqa"            # gqa | mla
    qkv_bias: bool = False
    sliding_window: int = 0           # window for *_local blocks
    rope_theta: float = 10000.0
    prefix_lm: bool = False           # bidirectional prefix (paligemma)
    # extras
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm_state: int = 16
    ssm_expand: int = 1
    mtp: bool = False                 # deepseek multi-token-prediction head
    tie_embeddings: bool = False
    embed_scale: bool = False         # gemma sqrt(d_model) embedding scale
    norm_eps: float = 1e-6
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"      # "bfloat16" ⇒ fp32 master in optimizer
    # sharding profile (EXPERIMENTS.md §Perf):
    #   "2d" — batch→(pod,data), heads/ffn/vocab/experts→model (default)
    #   "dp" — batch→(pod,data,model), params replicated over model; the
    #          right layout for models too small to fill a 16-wide TP axis
    sharding_profile: str = "2d"
    # expert-parallel axes for MoE ("model" = within-TP EP; ("data","model")
    # = EP-wide: one expert group per chip, no ZeRO-3 expert gathers)
    ep_axes: Tuple[str, ...] = ("model",)
    # modality frontend: "none" | "audio_stub" | "vision_stub"
    frontend: str = "none"
    vision_prefix: int = 256          # stub patch-token count (paligemma)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def num_groups(self) -> int:
        n = self.num_layers - len(self.prefix_layers) - len(self.suffix_layers)
        assert n % len(self.layer_unit) == 0, (
            f"{self.name}: {n} scanned layers not divisible by unit "
            f"{len(self.layer_unit)}"
        )
        return n // len(self.layer_unit)

    def all_layers(self) -> Tuple[str, ...]:
        return (self.prefix_layers
                + self.layer_unit * self.num_groups
                + self.suffix_layers)

    def validate(self) -> None:
        assert self.num_heads % max(self.num_kv_heads, 1) == 0
        _ = self.num_groups
        if any(k.startswith("moe") for k in self.all_layers()):
            assert self.moe is not None
        if self.attention == "mla":
            assert self.mla is not None
