"""State-space / recurrent blocks: Mamba (Hymba's SSM heads), mLSTM and
sLSTM (xLSTM).

All three expose the same two entry styles the transformer stack needs:

  * full-sequence form for training/prefill — chunkwise scan (mLSTM, mamba)
    or stepwise scan (sLSTM) over the sequence with O(1) HLO size;
  * single-step form for decode — the recurrent update on a carried state.

States are small per-head matrices/vectors (this is what makes the
``long_500k`` decode shape feasible for hymba/xlstm: memory is O(state), not
O(sequence)).

Sharding: heads are sharded over the "model" axis; states inherit
(batch→data, heads→model).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import BATCH, MODEL, ParamSpec, shard


# ------------------------------------------------------------------ mamba --


def mamba_specs(cfg: ModelConfig) -> Dict:
    """Selective SSM (Mamba-style, diagonal A) with H heads of size hd."""
    D, H, hd, N = cfg.d_model, cfg.num_heads, cfg.hd, cfg.ssm_state
    inner = H * hd
    return dict(
        wx=ParamSpec((D, inner), ("data", MODEL)),       # value path
        wz=ParamSpec((D, inner), ("data", MODEL)),       # gate path
        wB=ParamSpec((D, H * N), ("data", MODEL)),
        wC=ParamSpec((D, H * N), ("data", MODEL)),
        wdt=ParamSpec((D, H), ("data", MODEL)),
        dt_bias=ParamSpec((H,), (MODEL,), init="zeros"),
        A_log=ParamSpec((H, N), (MODEL, None), init="zeros"),
        Ddiag=ParamSpec((H,), (MODEL,), init="ones"),
        wo=ParamSpec((inner, D), (MODEL, "data")),
    )


def mamba_init_state(cfg: ModelConfig, batch: int, dtype) -> jax.Array:
    H, hd, N = cfg.num_heads, cfg.hd, cfg.ssm_state
    return jnp.zeros((batch, H, N, hd), jnp.float32)


def _mamba_inputs(params, cfg, x):
    dt_ = x.dtype
    B_, S, D = x.shape
    H, hd, N = cfg.num_heads, cfg.hd, cfg.ssm_state
    xv = jnp.einsum("bsd,di->bsi", x, params["wx"].astype(dt_))
    z = jnp.einsum("bsd,di->bsi", x, params["wz"].astype(dt_))
    Bm = jnp.einsum("bsd,dn->bsn", x, params["wB"].astype(dt_))
    Cm = jnp.einsum("bsd,dn->bsn", x, params["wC"].astype(dt_))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, params["wdt"].astype(dt_))
        .astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    xv = shard(xv.reshape(B_, S, H, hd), BATCH, None, MODEL, None)
    z = shard(z.reshape(B_, S, H, hd), BATCH, None, MODEL, None)
    Bm = Bm.reshape(B_, S, H, N).astype(jnp.float32)
    Cm = Cm.reshape(B_, S, H, N).astype(jnp.float32)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))           # (H, N) < 0
    return xv, z, Bm, Cm, dt, A


def mamba_forward(
    params: Dict, cfg: ModelConfig, x: jax.Array,
    state: Optional[jax.Array] = None, *, chunk: int = 256,
) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence selective scan.  x: (B, S, D) → (y, final_state).

    Chunkwise: scan over S/chunk chunks; within a chunk the recurrence
    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t unrolls via cumulative decay
    products in log space (numerically safe: A < 0 so decays ≤ 1).
    """
    B_, S, D = x.shape
    H, hd, N = cfg.num_heads, cfg.hd, cfg.ssm_state
    dt_ = x.dtype
    xv, z, Bm, Cm, dt, A = _mamba_inputs(params, cfg, x)
    if state is None:
        state = mamba_init_state(cfg, B_, dt_)

    c = min(chunk, S)
    Sp = -(-S // c) * c
    pad = Sp - S

    def padt(a):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))

    xv_, z_, Bm_, Cm_, dt_c = map(padt, (xv, z, Bm, Cm, dt))

    def chunk_body(h, inp):
        xc, Bc, Cc, dtc = inp        # (B, c, H, hd), (B, c, H, N), .., (B, c, H)
        # log-decay within the chunk: L[t] = sum_{u<=t} dt_u * A   (B,c,H,N)
        la = dtc[..., None] * A                                  # (B,c,H,N)
        cum = jnp.cumsum(la, axis=1)                             # (B,c,H,N)
        # state contribution at each t: exp(cum_t) * h0
        h_part = jnp.einsum("bchn,bhnd->bchnd", jnp.exp(cum), h)
        # input contributions: x_u injected at u decays by exp(cum_t - cum_u)
        inj = (dtc[..., None] * Bc)[..., None] * xc[..., None, :]  # (B,c,H,N,hd)
        w = jnp.exp(cum)[..., None]
        inj_scaled = inj / jnp.maximum(w, 1e-30)
        csum = jnp.cumsum(inj_scaled, axis=1)
        h_all = h_part + w * csum                                # (B,c,H,N,hd)
        y = jnp.einsum("bchn,bchnd->bchd", Cc, h_all)
        h_new = h_all[:, -1]
        return h_new, y.astype(xc.dtype)

    xs = tuple(
        jnp.moveaxis(a.reshape(B_, Sp // c, c, *a.shape[2:]), 1, 0)
        for a in (xv_.astype(jnp.float32), Bm_, Cm_, dt_c)
    )
    h_fin, ys = jax.lax.scan(chunk_body, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, Sp, H, hd)[:, :S]
    y = y.astype(dt_) + params["Ddiag"].astype(dt_)[None, None, :, None] * xv
    y = y * jax.nn.silu(z)
    y = shard(y.reshape(B_, S, H * hd), BATCH, None, MODEL)
    out = jnp.einsum("bsi,id->bsd", y, params["wo"].astype(dt_))
    return out, h_fin


def mamba_step(
    params: Dict, cfg: ModelConfig, x: jax.Array, state: jax.Array,
) -> Tuple[jax.Array, jax.Array]:
    """Single-token decode.  x: (B, 1, D), state: (B, H, N, hd)."""
    B_, S, D = x.shape
    H, hd, N = cfg.num_heads, cfg.hd, cfg.ssm_state
    dt_ = x.dtype
    xv, z, Bm, Cm, dt, A = _mamba_inputs(params, cfg, x)
    decay = jnp.exp(dt[:, 0, :, None] * A)                       # (B, H, N)
    inj = (dt[:, 0, :, None] * Bm[:, 0])[..., None] * \
        xv[:, 0].astype(jnp.float32)[..., None, :]               # (B,H,N,hd)
    h = decay[..., None] * state + inj
    y = jnp.einsum("bhn,bhnd->bhd", Cm[:, 0], h).astype(dt_)
    y = y + params["Ddiag"].astype(dt_)[None, :, None] * xv[:, 0]
    y = (y * jax.nn.silu(z[:, 0])).reshape(B_, 1, H * hd)
    out = jnp.einsum("bsi,id->bsd", y, params["wo"].astype(dt_))
    return out, h


# ------------------------------------------------------------------ mLSTM --


def mlstm_specs(cfg: ModelConfig) -> Dict:
    """mLSTM (xLSTM matrix-memory cell), H heads of size hd."""
    D, H, hd = cfg.d_model, cfg.num_heads, cfg.hd
    inner = H * hd
    return dict(
        wq=ParamSpec((D, inner), ("data", MODEL)),
        wk=ParamSpec((D, inner), ("data", MODEL)),
        wv=ParamSpec((D, inner), ("data", MODEL)),
        wi=ParamSpec((D, H), ("data", MODEL)),       # input gate (pre-exp)
        wf=ParamSpec((D, H), ("data", MODEL)),       # forget gate
        bi=ParamSpec((H,), (MODEL,), init="zeros"),
        bf=ParamSpec((H,), (MODEL,), init="ones"),
        ogate=ParamSpec((D, inner), ("data", MODEL)),
        norm=ParamSpec((hd,), (None,), init="ones"),
        wo=ParamSpec((inner, D), (MODEL, "data")),
    )


def mlstm_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    H, hd = cfg.num_heads, cfg.hd
    return dict(
        C=jnp.zeros((batch, H, hd, hd), jnp.float32),   # matrix memory
        n=jnp.zeros((batch, H, hd), jnp.float32),       # normalizer
        m=jnp.full((batch, H), -1e30, jnp.float32),     # log-stabilizer
    )


def _mlstm_inputs(params, cfg, x):
    dt_ = x.dtype
    B_, S, D = x.shape
    H, hd = cfg.num_heads, cfg.hd
    q = jnp.einsum("bsd,di->bsi", x, params["wq"].astype(dt_))
    k = jnp.einsum("bsd,di->bsi", x, params["wk"].astype(dt_))
    v = jnp.einsum("bsd,di->bsi", x, params["wv"].astype(dt_))
    o = jax.nn.sigmoid(jnp.einsum("bsd,di->bsi", x, params["ogate"].astype(dt_)))
    q = shard(q.reshape(B_, S, H, hd), BATCH, None, MODEL, None)
    k = shard(k.reshape(B_, S, H, hd), BATCH, None, MODEL, None) / jnp.sqrt(
        jnp.float32(hd)).astype(dt_)
    v = shard(v.reshape(B_, S, H, hd), BATCH, None, MODEL, None)
    ig = (jnp.einsum("bsd,dh->bsh", x, params["wi"].astype(dt_))
          .astype(jnp.float32) + params["bi"])
    fg = (jnp.einsum("bsd,dh->bsh", x, params["wf"].astype(dt_))
          .astype(jnp.float32) + params["bf"])
    return q, k, v, o, ig, fg


def _headwise_rmsnorm(y, w, eps=1e-6):
    var = jnp.mean(y.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
            * w.astype(jnp.float32)).astype(y.dtype)


def mlstm_forward(
    params: Dict, cfg: ModelConfig, x: jax.Array,
    state: Optional[Dict] = None, *, chunk: int = 256,
) -> Tuple[jax.Array, Dict]:
    """Chunkwise-parallel mLSTM (xLSTM paper, stabilized log-space gates)."""
    B_, S, D = x.shape
    H, hd = cfg.num_heads, cfg.hd
    dt_ = x.dtype
    q, k, v, o, ig, fg = _mlstm_inputs(params, cfg, x)
    if state is None:
        state = mlstm_init_state(cfg, B_, dt_)

    c = min(chunk, S)
    Sp = -(-S // c) * c
    pad = Sp - S

    def padt(a, fill=0.0):
        return jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
                       constant_values=fill)

    q_, k_, v_ = padt(q), padt(k), padt(v)
    ig_, fg_ = padt(ig, -1e30), padt(fg, 30.0)  # pads: no input, full forget

    def chunk_body(carry, inp):
        C0, n0, m0 = carry["C"], carry["n"], carry["m"]
        qc, kc, vc, ic, fc = inp       # (B,c,H,hd) / (B,c,H)
        logf = jax.nn.log_sigmoid(fc)                       # (B,c,H)
        F = jnp.cumsum(logf, axis=1)                        # Π f up to t
        # per-position log weights for: carried state (b_t = F_t + m0)
        # and intra-chunk source u→t (a_ut = F_t - F_u + i_u)
        b = F + m0[:, None, :]
        src = F[:, None, :, :] * 0 + ic[:, None, :, :] - F[:, None, :, :] + \
            F[:, :, None, :]                                # (B,t,u,H)
        causal = jnp.tril(jnp.ones((c, c), bool))
        src = jnp.where(causal[None, :, :, None], src, -jnp.inf)
        m_new = jnp.maximum(b, src.max(axis=2))             # (B,c,H)
        # intra-chunk attention-like term
        w_intra = jnp.exp(src - m_new[:, :, None, :])       # (B,t,u,H)
        s = jnp.einsum("bthd,buhd->btuh", qc.astype(jnp.float32),
                       kc.astype(jnp.float32))
        y_intra = jnp.einsum("btuh,btuh,buhd->bthd", s, w_intra,
                             vc.astype(jnp.float32))
        n_intra = jnp.einsum("btuh,btuh,buhd->bthd", s * 0 + 1.0, w_intra,
                             kc.astype(jnp.float32))
        n_intra = jnp.einsum("bthd,bthd->bth", qc.astype(jnp.float32), n_intra)
        # carried-state term
        w_c = jnp.exp(b - m_new)                            # (B,c,H)
        y_c = jnp.einsum("bthd,bhde->bthe", qc.astype(jnp.float32), C0)
        n_c = jnp.einsum("bthd,bhd->bth", qc.astype(jnp.float32), n0)
        y = y_intra + w_c[..., None] * y_c
        nrm = n_intra + w_c * n_c
        denom = jnp.maximum(jnp.abs(nrm), jnp.exp(-m_new))[..., None]
        y = y / denom
        # chunk-final state
        mT = m_new[:, -1]                                    # (B,H)
        decay_all = jnp.exp(F[:, -1:, :] - F + ic - mT[:, None, :])  # (B,c,H)
        C1 = jnp.exp(F[:, -1] + m0 - mT)[..., None, None] * C0 + \
            jnp.einsum("buh,buhd,buhe->bhde", decay_all, kc.astype(jnp.float32),
                       vc.astype(jnp.float32))
        n1 = jnp.exp(F[:, -1] + m0 - mT)[..., None] * n0 + \
            jnp.einsum("buh,buhd->bhd", decay_all, kc.astype(jnp.float32))
        return dict(C=C1, n=n1, m=mT), y.astype(dt_)

    xs = tuple(
        jnp.moveaxis(a.reshape(B_, Sp // c, c, *a.shape[2:]), 1, 0)
        for a in (q_, k_, v_, ig_, fg_)
    )
    fin, ys = jax.lax.scan(chunk_body, state, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, Sp, H, hd)[:, :S]
    y = _headwise_rmsnorm(y, params["norm"])
    y = (y.reshape(B_, S, H * hd) * o.reshape(B_, S, H * hd))
    y = shard(y, BATCH, None, MODEL)
    return jnp.einsum("bsi,id->bsd", y, params["wo"].astype(dt_)), fin


def mlstm_step(
    params: Dict, cfg: ModelConfig, x: jax.Array, state: Dict,
) -> Tuple[jax.Array, Dict]:
    """Single-token recurrent mLSTM update."""
    B_, S, D = x.shape
    H, hd = cfg.num_heads, cfg.hd
    dt_ = x.dtype
    q, k, v, o, ig, fg = _mlstm_inputs(params, cfg, x)
    q1, k1, v1 = (a[:, 0].astype(jnp.float32) for a in (q, k, v))
    i1, f1 = ig[:, 0], fg[:, 0]
    logf = jax.nn.log_sigmoid(f1)
    m_new = jnp.maximum(logf + state["m"], i1)
    fw = jnp.exp(logf + state["m"] - m_new)[..., None]
    iw = jnp.exp(i1 - m_new)[..., None]
    C = fw[..., None] * state["C"] + (iw * k1)[..., None] * v1[:, :, None, :]
    n = fw * state["n"] + iw * k1
    y = jnp.einsum("bhd,bhde->bhe", q1, C)
    nrm = jnp.einsum("bhd,bhd->bh", q1, n)
    denom = jnp.maximum(jnp.abs(nrm), jnp.exp(-m_new))[..., None]
    y = (y / denom).astype(dt_)
    y = _headwise_rmsnorm(y, params["norm"])
    y = (y * o[:, 0].reshape(B_, H, hd)).reshape(B_, 1, H * hd)
    out = jnp.einsum("bsi,id->bsd", y, params["wo"].astype(dt_))
    return out, dict(C=C, n=n, m=m_new)


# ------------------------------------------------------------------ sLSTM --


def slstm_specs(cfg: ModelConfig) -> Dict:
    """sLSTM: scalar memory, exponential gating, head-blocked recurrence."""
    D, H, hd = cfg.d_model, cfg.num_heads, cfg.hd
    inner = H * hd
    gates = dict()
    for g in ("i", "f", "z", "o"):
        gates[f"w{g}"] = ParamSpec((D, inner), ("data", MODEL))
        gates[f"r{g}"] = ParamSpec((H, hd, hd), (MODEL, None, None), scale=0.01)
        gates[f"b{g}"] = ParamSpec((inner,), (MODEL,),
                                   init="ones" if g == "f" else "zeros")
    gates["norm"] = ParamSpec((hd,), (None,), init="ones")
    gates["wo"] = ParamSpec((inner, D), (MODEL, "data"))
    return gates


def slstm_init_state(cfg: ModelConfig, batch: int, dtype) -> Dict:
    H, hd = cfg.num_heads, cfg.hd
    z = jnp.zeros((batch, H, hd), jnp.float32)
    return dict(c=z, n=z, h=z, m=jnp.full((batch, H, hd), -1e30, jnp.float32))


def slstm_forward(
    params: Dict, cfg: ModelConfig, x: jax.Array,
    state: Optional[Dict] = None,
) -> Tuple[jax.Array, Dict]:
    """Step scan over the sequence (sLSTM is inherently sequential: the
    hidden state feeds back into the gates through R)."""
    B_, S, D = x.shape
    H, hd = cfg.num_heads, cfg.hd
    dt_ = x.dtype
    if state is None:
        state = slstm_init_state(cfg, B_, dt_)

    pre = {}
    for g in ("i", "f", "z", "o"):
        pre[g] = (jnp.einsum("bsd,di->bsi", x, params[f"w{g}"].astype(dt_))
                  .astype(jnp.float32) + params[f"b{g}"]).reshape(B_, S, H, hd)

    R = {g: params[f"r{g}"].astype(jnp.float32) for g in ("i", "f", "z", "o")}

    def step(carry, t_in):
        c0, n0, h0, m0 = carry["c"], carry["n"], carry["h"], carry["m"]
        xi, xf, xz, xo = t_in

        def rec(g):
            return jnp.einsum("bhd,hde->bhe", h0, R[g])

        it = xi + rec("i")
        ft = xf + rec("f")
        zt = jnp.tanh(xz + rec("z"))
        ot = jax.nn.sigmoid(xo + rec("o"))
        logf = jax.nn.log_sigmoid(ft)
        m_new = jnp.maximum(logf + m0, it)
        iw = jnp.exp(it - m_new)
        fw = jnp.exp(logf + m0 - m_new)
        c = fw * c0 + iw * zt
        n = jnp.maximum(fw * n0 + iw, jnp.exp(-m_new))
        h = ot * (c / n)
        return dict(c=c, n=n, h=h, m=m_new), h.astype(dt_)

    xs = tuple(jnp.moveaxis(pre[g], 1, 0) for g in ("i", "f", "z", "o"))
    fin, hs = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(hs, 0, 1)                                  # (B,S,H,hd)
    y = _headwise_rmsnorm(y, params["norm"]).reshape(B_, S, H * hd)
    y = shard(y, BATCH, None, MODEL)
    return jnp.einsum("bsi,id->bsd", y, params["wo"].astype(dt_)), fin


def slstm_step(
    params: Dict, cfg: ModelConfig, x: jax.Array, state: Dict,
) -> Tuple[jax.Array, Dict]:
    y, fin = slstm_forward(params, cfg, x, state)
    return y, fin
