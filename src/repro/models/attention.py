"""Attention: GQA (full / sliding-window / prefix-LM) and MLA (DeepSeek).

All softmax attention goes through one chunked online-softmax implementation
(`chunked_attention`) — a pure-JAX flash-attention equivalent.  Nested
``lax.scan`` over query/key chunks keeps HLO size O(1) in sequence length and
peak memory O(q_chunk × kv_chunk), which is what makes the 32k-prefill and
500k-decode dry-run cells fit (see DESIGN.md §6).

KV caches carry an explicit per-slot position array (``pos``, initialized to
a huge sentinel): masking derives entirely from positions, so full caches,
ring-buffer sliding-window caches, and prefix-LM bidirectional reads share
one code path.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.config import ModelConfig
from repro.models.layers import BATCH, MODEL, ParamSpec, apply_rope, shard
from repro.models.layers import rms_norm, rms_norm_spec

POS_SENTINEL = jnp.int32(2**30)


def _tp_size() -> int:
    """Size of the (profile-translated) tensor-parallel axis, 1 if none."""
    env = jax.sharding.get_abstract_mesh()
    if env is None or env.empty:
        return 1
    ax = layers.translate(MODEL)
    sizes = dict(zip(env.axis_names, env.axis_sizes))
    return sizes.get(ax, 1)


# ------------------------------------------------------------ GQA params ----


def gqa_specs(cfg: ModelConfig) -> Dict:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.hd
    p = dict(
        wq=ParamSpec((D, H * hd), ("data", MODEL)),
        wk=ParamSpec((D, KV * hd), ("data", MODEL)),
        wv=ParamSpec((D, KV * hd), ("data", MODEL)),
        wo=ParamSpec((H * hd, D), (MODEL, "data")),
    )
    if cfg.qkv_bias:
        p.update(
            bq=ParamSpec((H * hd,), (MODEL,), init="zeros"),
            bk=ParamSpec((KV * hd,), (MODEL,), init="zeros"),
            bv=ParamSpec((KV * hd,), (MODEL,), init="zeros"),
        )
    return p


def mla_specs(cfg: ModelConfig) -> Dict:
    m = cfg.mla
    D, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_dim + m.qk_rope_dim
    return dict(
        wq_a=ParamSpec((D, m.q_lora_rank), ("data", None)),
        q_norm=rms_norm_spec(m.q_lora_rank),
        wq_b=ParamSpec((m.q_lora_rank, H * qk), (None, MODEL)),
        wkv_a=ParamSpec((D, m.kv_lora_rank + m.qk_rope_dim), ("data", None)),
        kv_norm=rms_norm_spec(m.kv_lora_rank),
        wk_b=ParamSpec((m.kv_lora_rank, H * m.qk_nope_dim), (None, MODEL)),
        wv_b=ParamSpec((m.kv_lora_rank, H * m.v_head_dim), (None, MODEL)),
        wo=ParamSpec((H * m.v_head_dim, D), (MODEL, "data")),
    )


# ----------------------------------------------------- chunked attention ----


def _chunk(x, n):
    """(B, S, ...) -> (S//n, B, n, ...) scan-major chunks."""
    B, S = x.shape[:2]
    x = x.reshape(B, S // n, n, *x.shape[2:])
    return jnp.moveaxis(x, 1, 0)


def _mask(q_pos, kv_pos, window, prefix_len):
    """(..., Sq, Tk) allowed mask from positions (sentinel pos ⇒ masked)."""
    qp = q_pos[..., :, None]
    kp = kv_pos[..., None, :]
    ok = kp <= qp                                   # causal + validity
    if window:
        ok &= (qp - kp) < window
    if prefix_len:
        ok |= (kp < prefix_len) & (kp < POS_SENTINEL // 2)
    return ok


def direct_attention(q, k, v, q_pos, kv_pos, *, window=0, prefix_len=0):
    """Un-chunked attention for short query blocks (decode: Sq == 1).

    No scan over the KV length ⇒ a length-sharded cache stays sharded: the
    score tensor is sharded over Tk, the softmax reductions and the PV
    contraction become GSPMD all-reduces over the "model" axis.
    """
    B, Sq, KV, G, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    s = jnp.einsum("bqkgh,btkh->bkgqt", q, k,
                   preferred_element_type=jnp.float32) * scale
    allowed = _mask(q_pos, kv_pos, window, prefix_len)         # (B, Sq, Tk)
    s = jnp.where(allowed[:, None, None, :, :], s, -1e30)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = p.sum(axis=-1, keepdims=True)
    o = jnp.einsum("bkgqt,btkh->bqkgh", (p / jnp.maximum(l, 1e-30)
                                         ).astype(v.dtype), v)
    return o.astype(q.dtype)


def chunked_attention(
    q: jax.Array,        # (B, Sq, KV, G, hd)
    k: jax.Array,        # (B, Tk, KV, hd)
    v: jax.Array,        # (B, Tk, KV, hd)
    q_pos: jax.Array,    # (B, Sq) i32
    kv_pos: jax.Array,   # (B, Tk) i32 (POS_SENTINEL for unwritten slots)
    *,
    window: int = 0,
    prefix_len: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention; returns (B, Sq, KV, G, hd)."""
    B, Sq, KV, G, hd = q.shape
    Tk = k.shape[1]
    if Sq <= 8:  # decode path
        return direct_attention(q, k, v, q_pos, kv_pos, window=window,
                                prefix_len=prefix_len)
    qc = min(q_chunk, Sq)
    kc = min(kv_chunk, Tk)
    # pad S/T to chunk multiples
    Sp = -(-Sq // qc) * qc
    Tp = -(-Tk // kc) * kc
    if Sp != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sp - Sq)) + ((0, 0),) * 3)
        q_pos = jnp.pad(q_pos, ((0, 0), (0, Sp - Sq)))
    if Tp != Tk:
        k = jnp.pad(k, ((0, 0), (0, Tp - Tk)) + ((0, 0),) * 2)
        v = jnp.pad(v, ((0, 0), (0, Tp - Tk)) + ((0, 0),) * 2)
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, Tp - Tk)),
                         constant_values=POS_SENTINEL)

    scale = 1.0 / jnp.sqrt(jnp.float32(hd))
    qs = _chunk(q, qc)            # (nq, B, qc, KV, G, hd)
    qps = _chunk(q_pos, qc)       # (nq, B, qc)
    ks = _chunk(k, kc)            # (nk, B, kc, KV, hd)
    vs = _chunk(v, kc)
    kps = _chunk(kv_pos, kc)

    def q_body(_, qx):
        qi, qp = qx               # (B, qc, KV, G, hd), (B, qc)

        def kv_body(carry, kx):
            o, m, l = carry
            ki, vi, kp = kx
            s = jnp.einsum("bqkgh,btkh->bkgqt", qi, ki,
                           preferred_element_type=jnp.float32) * scale
            allowed = _mask(qp, kp, window, prefix_len)  # (B, qc, kc)
            s = jnp.where(allowed[:, None, None, :, :], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bkgqt,btkh->bqkgh", p.astype(vi.dtype), vi,
                            preferred_element_type=jnp.float32)
            o_new = o * corr.transpose(0, 3, 1, 2)[..., None] + pv
            return (o_new, m_new, l_new), None

        o0 = jnp.zeros((B, qc, KV, G, hd), jnp.float32)
        m0 = jnp.full((B, KV, G, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, qc), jnp.float32)
        (o, m, l), _ = jax.lax.scan(kv_body, (o0, m0, l0), (ks, vs, kps))
        l = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return None, (o / l).astype(q.dtype)

    # flash-attention memory behavior: recompute per-chunk scores in the
    # backward instead of saving the (nq, nk, B, KV, G, qc, kc) probability
    # stacks — composes with (and is required under) the outer layer remat.
    q_body = jax.checkpoint(
        q_body, policy=jax.checkpoint_policies.nothing_saveable)
    _, out = jax.lax.scan(q_body, None, (qs, qps))
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sp, KV, G, hd)
    return out[:, :Sq]


# ----------------------------------------------------------- GQA forward ----


def init_gqa_cache(cfg: ModelConfig, batch: int, max_len: int, window: int,
                   dtype) -> Dict:
    KV, hd = cfg.num_kv_heads, cfg.hd
    T = min(window, max_len) if window else max_len
    return dict(
        k=jnp.zeros((batch, T, KV, hd), dtype),
        v=jnp.zeros((batch, T, KV, hd), dtype),
        pos=jnp.full((batch, T), POS_SENTINEL, jnp.int32),
    )


def gqa_attention(
    params: Dict,
    cfg: ModelConfig,
    x: jax.Array,                     # (B, S, D)
    positions: jax.Array,             # (B, S)
    *,
    window: int = 0,
    prefix_len: int = 0,
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,  # scalar: #tokens already cached
) -> Tuple[jax.Array, Optional[Dict]]:
    B, S, D = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    G = H // KV
    dt = x.dtype

    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = shard(q.reshape(B, S, KV, G, hd), BATCH, None, MODEL, None, None)
    k = shard(k.reshape(B, S, KV, hd), BATCH, None, MODEL, None)
    v = shard(v.reshape(B, S, KV, hd), BATCH, None, MODEL, None)

    q = apply_rope(q.reshape(B, S, H, hd), positions, cfg.rope_theta)
    q = q.reshape(B, S, KV, G, hd)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None:
        T = cache["k"].shape[1]
        slot = jnp.mod(positions, T) if window else positions  # (B, S)
        bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
        ck = cache["k"].at[bidx, slot].set(k)
        cv = cache["v"].at[bidx, slot].set(v)
        cp = cache["pos"].at[bidx, slot].set(positions)
        new_cache = dict(k=ck, v=cv, pos=cp)
        k, v, kv_pos = ck, cv, cp
    else:
        kv_pos = positions

    # Head-repeat sharding: when KV doesn't divide the TP axis but H does
    # (qwen 8kv/64h vs 16), materialize per-query-head K/V and shard the
    # full head dim — the repeated-but-sharded tensors are *smaller* per
    # device than replicated KV, and every attention einsum becomes local
    # (kills the per-chunk all-reduces; EXPERIMENTS.md §Perf qwen).
    # Gated on KV length: at long T the G×-repeated K/V HBM traffic costs
    # more than the all-reduces it saves (measured: qwen prefill_32k tm
    # 69→102s with repeat vs tx 56→33s — net loss; §Perf).
    tp = _tp_size()
    T_kv = k.shape[1]
    if (tp > 1 and KV % tp != 0 and H % tp == 0 and layers.translate(MODEL)
            and T_kv <= 16384):
        k = shard(jnp.repeat(k, G, axis=2), BATCH, None, MODEL, None)
        v = shard(jnp.repeat(v, G, axis=2), BATCH, None, MODEL, None)
        q = shard(q.reshape(B, S, H, 1, hd), BATCH, None, MODEL, None, None)

    out = chunked_attention(q, k, v, positions, kv_pos,
                            window=window, prefix_len=prefix_len)
    out = out.reshape(B, S, H * hd)
    out = shard(out, BATCH, None, MODEL)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(dt))
    return y, new_cache


# ----------------------------------------------------------- MLA forward ----


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> Dict:
    m = cfg.mla
    return dict(
        ckv=jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        krope=jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
        pos=jnp.full((batch, max_len), POS_SENTINEL, jnp.int32),
    )


def mla_attention(
    params: Dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions: jax.Array,
    *,
    cache: Optional[Dict] = None,
    cache_index: Optional[jax.Array] = None,
    window: int = 0,
    prefix_len: int = 0,
) -> Tuple[jax.Array, Optional[Dict]]:
    """Multi-head Latent Attention in the *absorbed* form.

    Queries are absorbed into latent space (q_abs = q_nope · W_kb per head),
    so attention runs against the (kv_lora + rope) latent cache directly —
    per-head K/V are never materialized (DeepSeek-V3 inference form; also
    used for training here, where it is flop-equivalent).
    """
    m = cfg.mla
    B, S, D = x.shape
    H = cfg.num_heads
    dt = x.dtype

    qa = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(dt)),
                  params["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rh->bsh", qa, params["wq_b"].astype(dt))
    q = shard(q.reshape(B, S, H, m.qk_nope_dim + m.qk_rope_dim),
              BATCH, None, MODEL, None)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(dt))
    ckv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]

    new_cache = None
    if cache is not None:
        bidx = jnp.arange(B, dtype=jnp.int32)[:, None]
        cc = cache["ckv"].at[bidx, positions].set(ckv)
        cr = cache["krope"].at[bidx, positions].set(k_rope)
        cp = cache["pos"].at[bidx, positions].set(positions)
        new_cache = dict(ckv=cc, krope=cr, pos=cp)
        ckv_all, krope_all, kv_pos = cc, cr, cp
    else:
        ckv_all, krope_all, kv_pos = ckv, k_rope, positions

    # absorb: q_abs[h] = q_nope[h] @ wk_b[h]^T  → latent-space queries
    wk_b = params["wk_b"].astype(dt).reshape(m.kv_lora_rank, H, m.qk_nope_dim)
    q_abs = jnp.einsum("bshn,rhn->bshr", q_nope, wk_b)
    # latent "keys": [ckv | k_rope]; queries: [q_abs | q_rope]
    q_full = jnp.concatenate([q_abs, q_rope], axis=-1)[:, :, :, None, :]
    k_full = jnp.concatenate([ckv_all, krope_all], axis=-1)[:, :, None, :]
    # scale by the *nominal* head dim (qk_nope + rope), not the latent dim
    nominal = m.qk_nope_dim + m.qk_rope_dim
    latent = m.kv_lora_rank + m.qk_rope_dim
    q_full = q_full * jnp.sqrt(jnp.float32(latent) / nominal).astype(dt)

    # attention over latents: heads act as KV=1, G=H.  Shard the *group*
    # (head) dim — the latent K/V are per-token (headless) and replicate
    # cheaply, so every score/PV einsum is head-local (no per-chunk
    # collectives; EXPERIMENTS.md §Perf deepseek).
    q_r = q_full.transpose(0, 1, 3, 2, 4)                # (B,S,1,H,latent)
    q_r = shard(q_r, BATCH, None, None, MODEL, None)
    v_lat = jnp.concatenate(
        [ckv_all, jnp.zeros_like(krope_all)], -1)[:, :, None, :]
    if cache is None:
        # train/prefill: replicate the small per-token latents so every
        # score/PV einsum is head-local (EXPERIMENTS.md §Perf deepseek)
        k_full = shard(k_full, BATCH, None, None, None)
        v_lat = shard(v_lat, BATCH, None, None, None)
    # decode: leave the latent cache's length sharding untouched —
    # replicating a 32k-deep cache per step costs more than it saves
    o = chunked_attention(
        q_r, k_full, v_lat,
        positions, kv_pos, window=window, prefix_len=prefix_len,
    )                                                    # (B,S,1,H,latent)
    o_latent = o[:, :, 0, :, : m.kv_lora_rank]           # (B,S,H,kv_lora)
    o_latent = shard(o_latent, BATCH, None, MODEL, None)
    wv_b = params["wv_b"].astype(dt).reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bshr,rhv->bshv", o_latent, wv_b)
    out = out.reshape(B, S, H * m.v_head_dim)
    out = shard(out, BATCH, None, MODEL)
    y = jnp.einsum("bsh,hd->bsd", out, params["wo"].astype(dt))
    return y, new_cache
