"""Mixture-of-Experts FFN with two execution paths.

  * ``dense`` — one-hot dispatch/combine einsum computing every selected
    expert exactly (no token dropping).  Used for smoke-scale configs and as
    the oracle the a2a path is property-tested against.
  * ``a2a``   — GShard-style expert parallelism under ``shard_map``: tokens
    are bucketed per expert with a fixed capacity, exchanged with
    ``all_to_all`` over the "model" mesh axis (the EP axis), processed with
    one batched einsum per device, and combined on the way back.  This is the
    production / dry-run path; capacity overflow drops tokens (weight-0
    combine), the standard GShard behavior — divergence from DeepSeek's
    dropless dispatch is recorded in DESIGN.md.

The expert→EP-rank placement is *itself* a load-balancing problem with
persistently interacting objects (experts co-activated by top-k routing keep
being co-activated); ``distributed/ep_balance.py`` runs the paper's diffusion
balancer on it and feeds the resulting permutation back in via
``expert_perm``.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.layers import BATCH, MODEL, ParamSpec, shard


# ---------------------------------------------------------- routing stats --


class RouterStats(NamedTuple):
    """Per-step routing statistics — the live expert-placement inputs.

    ``counts[e]`` is the number of (token, k) selections of expert ``e``
    this step; ``coact[i, j]`` counts ordered selections of experts i and
    j by the same token (symmetric, zero diagonal-free convention of
    ``distributed/ep_balance.ExpertStats`` — see :func:`pair_stats`).
    Both are f32 device arrays with fixed shapes, so they ride scan
    carries and training-step metrics without host trips."""

    counts: jax.Array   # (E,) f32
    coact: jax.Array    # (E, E) f32


def zero_router_stats(num_experts: int) -> RouterStats:
    return RouterStats(jnp.zeros((num_experts,), jnp.float32),
                       jnp.zeros((num_experts, num_experts), jnp.float32))


def pair_stats(ids, num_experts: int) -> RouterStats:
    """Token counts + co-activation matrix from top-k ids, in one batch.

    ``ids`` is (T, k) i32.  With ``c_t`` the per-token selection-count
    vector (sum of one-hots over the k columns), the ordered-pair
    co-activation identity is

        coact = Σ_t (c_t c_tᵀ − diag(c_t)) = CᵀC − diag(counts)

    — exactly the symmetrized O(k²) ``np.add.at`` pair loop this replaces
    (``ep_balance.ExpertStats`` property-tests the equality), computed as
    one one-hot matmul.  Traceable with fixed shapes: this is the
    device-side hook the training scan and the expert-placement runtime
    (``train/ep_runtime.py``) share."""
    ids = jnp.asarray(ids, jnp.int32)
    E = int(num_experts)
    sel = jax.nn.one_hot(ids, E, dtype=jnp.float32).sum(axis=-2)   # (T, E)
    counts = sel.sum(axis=0)
    coact = jnp.einsum("te,tf->ef", sel, sel) - jnp.diag(counts)
    return RouterStats(counts=counts, coact=coact)


def moe_specs(cfg: ModelConfig) -> Dict:
    m = cfg.moe
    D, F, E = cfg.d_model, m.d_expert, m.num_experts
    ep = tuple(cfg.ep_axes)
    # experts stacked on a leading E dim, sharded over the EP axes.  With
    # ep_axes=("data","model") (EP-wide) every chip owns E/chips experts
    # outright — no FSDP dim left, and no ZeRO-3 gather of expert weights.
    fsdp = "data" if ep == ("model",) else None
    p = dict(
        router=ParamSpec((D, E), ((None,), None), scale=0.006),
        wi=ParamSpec((E, D, F), (ep, fsdp, None)),
        wg=ParamSpec((E, D, F), (ep, fsdp, None)),
        wo=ParamSpec((E, F, D), (ep, None, fsdp)),
    )
    if m.num_shared:
        p.update(
            shared_wi=ParamSpec((D, m.num_shared * F), ("data", MODEL)),
            shared_wg=ParamSpec((D, m.num_shared * F), ("data", MODEL)),
            shared_wo=ParamSpec((m.num_shared * F, D), (MODEL, "data")),
        )
    return p


def _router(params, cfg: ModelConfig, x2d: jax.Array):
    """Top-k routing.  Returns (weights (T,k), ids (T,k), aux_loss scalar)."""
    m = cfg.moe
    logits = jnp.einsum(
        "td,de->te", x2d.astype(jnp.float32), params["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, m.top_k)                     # (T, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux + router z-loss.
    E = m.num_experts
    me = probs.mean(axis=0)                                    # (E,)
    ce = jnp.zeros(E).at[ids.reshape(-1)].add(1.0) / ids.size
    aux = E * jnp.sum(me * ce)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return w.astype(x2d.dtype), ids, aux + 1e-3 * zloss


def _shared(params, cfg, x, dt):
    h = jax.nn.silu(jnp.einsum("tsd,df->tsf", x, params["shared_wg"].astype(dt)))
    h = h * jnp.einsum("tsd,df->tsf", x, params["shared_wi"].astype(dt))
    h = shard(h, BATCH, None, MODEL)
    return jnp.einsum("tsf,fd->tsd", h, params["shared_wo"].astype(dt))


# ------------------------------------------------------------- dense path --


def moe_dense(params, cfg: ModelConfig, x: jax.Array,
              collect_stats: bool = False):
    """One-hot dispatch/combine.  x: (B, S, D) → (y, aux[, RouterStats])."""
    m = cfg.moe
    B, S, D = x.shape
    dt = x.dtype
    x2d = x.reshape(B * S, D)
    w, ids, aux = _router(params, cfg, x2d)
    stats = pair_stats(ids, m.num_experts) if collect_stats else None
    onehot = jax.nn.one_hot(ids, m.num_experts, dtype=dt)       # (T, k, E)
    comb = jnp.einsum("tk,tke->te", w, onehot)                  # (T, E)
    hg = jnp.einsum("td,edf->tef", x2d, params["wg"].astype(dt))
    hi = jnp.einsum("td,edf->tef", x2d, params["wi"].astype(dt))
    h = jax.nn.silu(hg) * hi
    ye = jnp.einsum("tef,efd->ted", h, params["wo"].astype(dt))
    y = jnp.einsum("ted,te->td", ye, comb)
    y = y.reshape(B, S, D)
    if m.num_shared:
        y = y + _shared(params, cfg, x, dt)
    if collect_stats:
        return y, aux, stats
    return y, aux


# --------------------------------------------------------------- a2a path --


def _a2a_local(x_loc, router, wi, wg, wo, *, cfg: ModelConfig, ep: int,
               ep_axis: str, tok_axes: Tuple[str, ...],
               collect_stats: bool = False):
    """shard_map body: x_loc (B_loc, S_loc, D) tokens local to this EP rank."""
    m = cfg.moe
    E = m.num_experts
    E_loc = E // ep
    B_loc, S_loc, D = x_loc.shape
    x_loc = x_loc.reshape(B_loc * S_loc, D)      # local reshape — free
    T_loc = B_loc * S_loc
    dt = x_loc.dtype
    k = m.top_k
    # per-(expert, source) capacity
    cap = max(1, int(m.capacity_factor * k * T_loc) // E)

    logits = jnp.einsum("td,de->te", x_loc.astype(jnp.float32),
                        router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, k)                            # (T_loc, k)
    w = (w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)).astype(dt)
    me = probs.mean(axis=0)
    ce = jnp.zeros(E).at[ids.reshape(-1)].add(1.0) / ids.size
    aux = E * jnp.sum(me * ce) + 1e-3 * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)

    # slot position of each (token, k) pair within its expert bucket
    flat_e = ids.reshape(-1)                                    # (T_loc*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (Tk, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot                   # 1-based
    slot = (pos.sum(axis=1) - 1).astype(jnp.int32)              # (Tk,)
    keep = slot < cap
    # dispatch buffer (E, cap, D); dropped slots write to a scratch row
    buf_idx = jnp.where(keep, flat_e * cap + slot, E * cap)
    disp = jnp.zeros((E * cap + 1, D), dt).at[buf_idx].set(
        jnp.repeat(x_loc, k, axis=0))[: E * cap]
    disp = disp.reshape(E, cap, D)

    # exchange: (E, cap, D) → (ep, E_loc, cap, D) → a2a over EP axis
    disp = disp.reshape(ep, E_loc, cap, D)
    recv = jax.lax.all_to_all(disp, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False)                      # (ep, E_loc, cap, D)
    recv = recv.transpose(1, 0, 2, 3).reshape(E_loc, ep * cap, D)

    # expert FFN, batched over local experts
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", recv, wg.astype(dt)))
    h = h * jnp.einsum("ecd,edf->ecf", recv, wi.astype(dt))
    out = jnp.einsum("ecf,efd->ecd", h, wo.astype(dt))          # (E_loc, ep*cap, D)

    # return trip
    out = out.reshape(E_loc, ep, cap, D).transpose(1, 0, 2, 3)  # (ep, E_loc, cap, D)
    back = jax.lax.all_to_all(out, ep_axis, split_axis=0, concat_axis=0,
                              tiled=False)
    back = back.reshape(E * cap, D)

    # combine: gather each kept slot's result, weight, and sum over k
    gathered = jnp.where(keep[:, None],
                         back[jnp.where(keep, flat_e * cap + slot, 0)], 0.0)
    y = jnp.sum(gathered.reshape(T_loc, k, D) * w[:, :, None], axis=1)
    aux = jax.lax.pmean(jnp.asarray(aux, jnp.float32), tok_axes)
    if collect_stats:
        # global routing stats: every rank routes its own tokens, so the
        # psum over the token axes is the full-batch count/co-activation
        st = pair_stats(ids, E)
        st = RouterStats(*(jax.lax.psum(s, tok_axes) for s in st))
        return y.reshape(B_loc, S_loc, D), aux, st
    return y.reshape(B_loc, S_loc, D), aux


def moe_a2a(params, cfg: ModelConfig, x: jax.Array,
            collect_stats: bool = False):
    """Expert-parallel MoE over the ambient mesh's "model" axis.

    Boundary layout: the (B, S, D) activation keeps its factored form —
    batch over ("pod","data"), *sequence* over "model" (sequence parallelism
    for the MoE segment).  Entering costs nothing (a slice of the
    batch-sharded input); leaving costs one S-dim all-gather per layer —
    the standard GShard SP↔EP transition.  Flattening to (B·S, D) at the
    boundary instead provokes GSPMD's replicate-and-repartition fallback
    (full activation rematerialization) — measured in EXPERIMENTS.md §Perf.
    """
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or mesh.empty or MODEL not in mesh.axis_names:
        return moe_dense(params, cfg, x, collect_stats)
    ep_axes = tuple(a for a in cfg.ep_axes if a in mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    ep = 1
    for a in ep_axes:
        ep *= sizes[a]
    if not ep_axes or cfg.moe.num_experts % ep != 0 or x.shape[1] % sizes[MODEL] != 0:
        return moe_dense(params, cfg, x, collect_stats)

    B, S, D = x.shape
    dt = x.dtype
    tok_axes = tuple(a for a in ("pod", "data", MODEL) if a in mesh.axis_names)
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    x = shard(x, BATCH, MODEL, None)              # seq-shard into the block

    # Expert weights enter EP-sharded; with ep_axes=("model",) GSPMD
    # all-gathers the FSDP ("data") shards at the boundary (ZeRO-3
    # gather-before-use).  With ep_axes=("data","model") the weights are
    # fully resident per chip and nothing is gathered (EP-wide).
    espec = P(ep_axes if len(ep_axes) > 1 else ep_axes[0], None, None)
    out_specs = (P(ba, MODEL, None), P())
    if collect_stats:
        out_specs = out_specs + (moe_pkg_stats_spec(),)
    out = jax.shard_map(
        lambda xl, r, wi, wg, wo: _a2a_local(
            xl, r, wi, wg, wo, cfg=cfg, ep=ep, ep_axis=ep_axes,
            tok_axes=tok_axes, collect_stats=collect_stats),
        mesh=mesh,
        in_specs=(P(ba, MODEL, None), P(None, None), espec, espec, espec),
        out_specs=out_specs,
        check_vma=False,
    )(x, params["router"], params["wi"], params["wg"], params["wo"])
    y, aux = out[0], out[1]

    y = shard(y, BATCH, None, None)               # S all-gather out
    if cfg.moe.num_shared:
        y = y + _shared(params, cfg, x, dt)
    if collect_stats:
        return y, aux, out[2]
    return y, aux


def moe_pkg_stats_spec() -> RouterStats:
    """Replicated out_spec pytree for the stats leg of the a2a body."""
    return RouterStats(P(), P())


def moe_ffn(params, cfg: ModelConfig, x: jax.Array,
            impl: Optional[str] = None, collect_stats: bool = False):
    impl = impl or cfg.moe.impl
    if impl == "dense":
        return moe_dense(params, cfg, x, collect_stats)
    if impl == "a2a":
        return moe_a2a(params, cfg, x, collect_stats)
    # auto: a2a whenever a model-axis mesh is ambient
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is not None and not mesh.empty and MODEL in mesh.axis_names:
        return moe_a2a(params, cfg, x, collect_stats)
    return moe_dense(params, cfg, x, collect_stats)
