"""Shared layer primitives: norms, RoPE, MLP, sharding helpers.

Sharding convention (see DESIGN.md §5): activations are annotated with
logical axes — batch → ("pod","data"), heads/ffn/vocab → "model", everything
else replicated.  ``shard`` is a no-op when no mesh is active so the same
code runs single-device smoke tests and the 512-device dry-run.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.params import ParamSpec

BATCH = ("pod", "data")
MODEL = "model"

# Ambient sharding profile (set by the jitted entry points from
# ModelConfig.sharding_profile; see config.py for the semantics).
import contextvars

_PROFILE = contextvars.ContextVar("sharding_profile", default="2d")


def set_profile(name: str):
    return _PROFILE.set(name)


def profile() -> str:
    return _PROFILE.get()


def translate(axis):
    """Map a logical axis (BATCH tuple / MODEL / mesh-axis name) through the
    active profile.  Under "dp" the model axis joins the batch axes and
    tensor parallelism is disabled — the right layout for models too small
    to fill a 16-wide TP axis (EXPERIMENTS.md §Perf)."""
    if _PROFILE.get() == "dp":
        if isinstance(axis, (tuple, list)) and "data" in axis:
            return ("pod", "data", "model")      # batch over everything
        if axis == MODEL:
            return None                          # no tensor parallelism
    return axis


def _mesh_axes() -> Sequence[str]:
    env = jax.sharding.get_abstract_mesh()
    if env is None or env.empty:
        return ()
    return tuple(env.axis_names)


def shard(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint against the ambient mesh, filtering axis
    names the mesh doesn't have (so single-pod and multi-pod share code)."""
    env = jax.sharding.get_abstract_mesh()
    if env is None or env.empty:
        return x
    names = tuple(env.axis_names)

    def keep(a):
        a = translate(a)
        if a is None:
            return None
        ax = tuple(n for n in (a if isinstance(a, tuple) else (a,))
                   if n in names)
        if not ax:
            return None
        return ax if len(ax) > 1 else ax[0]

    # NOTE: non-divisible dims are deliberately allowed here — GSPMD's
    # padded layout for e.g. 5 kv-heads on a 16-wide axis measurably beats
    # replication (hymba train: 5× in the memory term; EXPERIMENTS.md
    # §Perf).  Divisibility is enforced only at jit argument boundaries
    # (distributed/sharding.py), where NamedSharding requires it.
    spec = P(*(keep(a) for a in axes))
    return jax.lax.with_sharding_constraint(x, spec)


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (w.astype(jnp.float32))).astype(dt)


def rms_norm_spec(d: int) -> ParamSpec:
    return ParamSpec((d,), (None,), init="ones")


# ----------------------------------------------------------------- RoPE ----


def rope_freqs(positions: jax.Array, dim: int, theta: float) -> jax.Array:
    """(..., dim/2) angles for the given positions."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    return positions[..., None].astype(jnp.float32) * inv


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) — rotate pairs (split-half convention)."""
    B, S, H, D = x.shape
    ang = rope_freqs(positions, D, theta)            # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)


# ------------------------------------------------------------------ MLP ----


def mlp_specs(d_model: int, d_ff: int) -> dict:
    return dict(
        wi=ParamSpec((d_model, d_ff), ((None,), MODEL)),
        wg=ParamSpec((d_model, d_ff), ((None,), MODEL)),
        wo=ParamSpec((d_ff, d_model), (MODEL, (None,))),
    )


def mlp(params: dict, x: jax.Array, dtype) -> jax.Array:
    """Gated SiLU MLP (llama family)."""
    h = jnp.einsum("bsd,df->bsf", x, params["wg"].astype(dtype))
    u = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(dtype))
    h = jax.nn.silu(h) * u
    h = shard(h, BATCH, None, MODEL)
    return jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(dtype))


def embed_specs(vocab: int, d_model: int) -> ParamSpec:
    return ParamSpec((vocab, d_model), (MODEL, "data"), scale=0.02)
