"""The unified decoder stack covering all assigned architectures.

Layer stacks are declared as a repeating ``layer_unit`` of block kinds
scanned over ``num_groups`` groups (O(1) HLO size in depth — DESIGN.md §6),
plus optional unrolled prefix/suffix layers for remainders and special
layers (deepseek's 3 dense layers, hymba's global-attention ends, gemma's
5:1 remainder).

Block kinds:
  attn        — softmax attention (GQA or MLA per cfg) + dense MLP
  attn_local  — sliding-window attention + dense MLP
  moe         — attention + mixture-of-experts FFN
  hymba       — parallel attention + mamba heads (windowed attn) + MLP
  hymba_g     — hymba with global attention
  mlstm/slstm — xLSTM blocks (no separate FFN when d_ff == 0)

Modality frontends are stubs per the assignment: ``audio_stub`` consumes
precomputed frame embeddings, ``vision_stub`` consumes precomputed patch
embeddings prepended as a bidirectional prefix (prefix-LM).

Three entry points (all pure functions of (params, batch)):
  ``forward``     — hidden states (training / prefill, optional cache build)
  ``decode_step`` — single-token step with stacked caches
  ``loss_fn``     — next-token CE with sequence-chunked, vocab-sharded
                    logits (the full (B,S,V) fp32 logits never materialize)
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.layers import (
    BATCH, MODEL, ParamSpec, embed_specs, mlp, mlp_specs, rms_norm,
    rms_norm_spec, shard,
)
from repro.models.params import tree_map_specs


# ------------------------------------------------------------------ specs --


def _block_specs(cfg: ModelConfig, kind: str) -> Dict:
    D = cfg.d_model
    p: Dict[str, Any] = dict(norm1=rms_norm_spec(D))
    if kind in ("attn", "attn_local", "moe", "moe_local"):
        p["attn"] = (attn.mla_specs(cfg) if cfg.attention == "mla"
                     else attn.gqa_specs(cfg))
        p["norm2"] = rms_norm_spec(D)
        if kind.startswith("moe"):
            p["moe"] = moe_specs_cached(cfg)
        else:
            p["mlp"] = mlp_specs(D, cfg.d_ff_dense or cfg.d_ff)
    elif kind in ("hymba", "hymba_g"):
        p["attn"] = attn.gqa_specs(cfg)
        p["mamba"] = ssm.mamba_specs(cfg)
        p["beta"] = ParamSpec((2,), (None,), init="ones")
        p["norm2"] = rms_norm_spec(D)
        p["mlp"] = mlp_specs(D, cfg.d_ff)
    elif kind == "mlstm":
        p["cell"] = ssm.mlstm_specs(cfg)
    elif kind == "slstm":
        p["cell"] = ssm.slstm_specs(cfg)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if cfg.d_ff and kind in ("mlstm", "slstm"):
        p["norm2"] = rms_norm_spec(D)
        p["mlp"] = mlp_specs(D, cfg.d_ff)
    return p


def moe_specs_cached(cfg):
    return moe_mod.moe_specs(cfg)


def _stack(tree, g: int):
    """Prepend a replicated group dimension to every ParamSpec."""
    return tree_map_specs(
        lambda s: dataclasses.replace(
            s, shape=(g,) + s.shape, spec=(None,) + tuple(s.spec)),
        tree,
    )


def model_specs(cfg: ModelConfig) -> Dict:
    cfg.validate()
    G = cfg.num_groups
    p: Dict[str, Any] = dict(
        embed=embed_specs(cfg.vocab_size, cfg.d_model),
        final_norm=rms_norm_spec(cfg.d_model),
    )
    p["unit"] = [_stack(_block_specs(cfg, k), G) for k in cfg.layer_unit]
    p["prefix"] = [_block_specs(cfg, k) for k in cfg.prefix_layers]
    p["suffix"] = [_block_specs(cfg, k) for k in cfg.suffix_layers]
    if not cfg.tie_embeddings:
        p["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                 ("data", MODEL))
    if cfg.mtp:
        p["mtp"] = dict(
            block=_block_specs(cfg, "attn"),
            proj=ParamSpec((2 * cfg.d_model, cfg.d_model), ("data", None)),
            norm=rms_norm_spec(cfg.d_model),
        )
    if cfg.param_dtype != "float32":
        # low-precision resident params (fp32 master lives in the optimizer
        # when training — train/optimizer.py): halves FSDP gather bytes.
        import jax.numpy as jnp
        dt = jnp.dtype(cfg.param_dtype)
        p = tree_map_specs(lambda s: dataclasses.replace(s, dtype=dt), p)
    return p


# ------------------------------------------------------------------ cache --


def init_block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                     dtype) -> Optional[Dict]:
    window = cfg.sliding_window if kind in ("attn_local", "hymba") else 0
    if kind in ("attn", "attn_local", "moe", "moe_local"):
        if cfg.attention == "mla":
            return dict(kv=attn.init_mla_cache(cfg, batch, max_len, dtype))
        return dict(kv=attn.init_gqa_cache(cfg, batch, max_len, window, dtype))
    if kind in ("hymba", "hymba_g"):
        return dict(
            kv=attn.init_gqa_cache(cfg, batch, max_len, window, dtype),
            ssm=ssm.mamba_init_state(cfg, batch, dtype),
        )
    if kind == "mlstm":
        return dict(state=ssm.mlstm_init_state(cfg, batch, dtype))
    if kind == "slstm":
        return dict(state=ssm.slstm_init_state(cfg, batch, dtype))
    return None


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    """Stacked cache pytree matching the model structure."""
    G = cfg.num_groups

    def stack_cache(kind):
        one = init_block_cache(cfg, kind, batch, max_len, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (G,) + a.shape).copy(), one)

    return dict(
        unit=[stack_cache(k) for k in cfg.layer_unit],
        prefix=[init_block_cache(cfg, k, batch, max_len, dtype)
                for k in cfg.prefix_layers],
        suffix=[init_block_cache(cfg, k, batch, max_len, dtype)
                for k in cfg.suffix_layers],
    )


def shard_cache(cache):
    """Sharding constraint for caches: batch→(pod,data); KV length→model.

    Length-sharding (sequence parallelism for the KV cache) is what lets
    kv_heads=1 architectures (gemma3) hold 32k-500k caches: heads cannot be
    split, positions can.  Softmax over the sharded length dim partitions
    cleanly (GSPMD inserts the max/sum all-reduces).
    """
    def f(a):
        if a.ndim >= 2:
            return shard(a, BATCH, MODEL, *([None] * (a.ndim - 2)))
        return a

    def g(sub):
        if sub is None:
            return None
        out = dict(sub)
        if "kv" in sub:
            out["kv"] = {k: f(v) for k, v in sub["kv"].items()}
        # recurrent states are O(heads·state): batch→data, heads→model
        for key in ("ssm", "state"):
            if key in sub:
                out[key] = jax.tree.map(
                    lambda a: shard(a, BATCH, MODEL,
                                    *([None] * (a.ndim - 2)))
                    if a.ndim >= 2 else a, sub[key])
        return out

    def g_stacked(sub):
        if sub is None:
            return None
        out = dict(sub)
        if "kv" in sub:
            out["kv"] = {k: (shard(v, None, BATCH, MODEL,
                                   *([None] * (v.ndim - 3)))
                             if v.ndim >= 3 else v)
                         for k, v in sub["kv"].items()}
        for key in ("ssm", "state"):
            if key in sub:
                out[key] = jax.tree.map(
                    lambda a: shard(a, None, BATCH, MODEL,
                                    *([None] * (a.ndim - 3)))
                    if a.ndim >= 3 else a, sub[key])
        return out

    return dict(
        unit=[g_stacked(s) for s in cache["unit"]],
        prefix=[g(s) for s in cache["prefix"]],
        suffix=[g(s) for s in cache["suffix"]],
    )


# ------------------------------------------------------------------ block --


def zero_aux(cfg: ModelConfig, collect_router_stats: bool = False):
    """The aux channel's zero: a scalar, or (scalar, RouterStats) when the
    training scan is accumulating device-resident routing statistics."""
    if collect_router_stats:
        if cfg.moe is None:
            raise ValueError("collect_router_stats needs a MoE config")
        return (jnp.float32(0.0),
                moe_mod.zero_router_stats(cfg.moe.num_experts))
    return jnp.float32(0.0)


def apply_block(
    params: Dict,
    cfg: ModelConfig,
    kind: str,
    x: jax.Array,
    positions: jax.Array,
    cache: Optional[Dict],
    *,
    prefix_len: int = 0,
    decode: bool = False,
    collect_router_stats: bool = False,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Returns (x_out, new_cache, aux_loss).

    With ``collect_router_stats`` the aux leg is the fixed-shape pytree
    ``(aux_scalar, RouterStats)`` for *every* block kind (zeros outside
    MoE blocks), so the layer-unit scan carries per-expert token counts
    and the (E, E) co-activation matrix on device — the live
    expert-placement runtime's input (``train/ep_runtime.py``)."""
    dt = x.dtype
    aux = zero_aux(cfg, collect_router_stats)
    window = cfg.sliding_window if kind in ("attn_local", "moe_local",
                                            "hymba") else 0

    if kind in ("attn", "attn_local", "moe", "moe_local"):
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        fn = attn.mla_attention if cfg.attention == "mla" else attn.gqa_attention
        a, kv = fn(params["attn"], cfg, h, positions, window=window,
                   prefix_len=prefix_len,
                   cache=None if cache is None else cache["kv"])
        x = x + a
        h = rms_norm(x, params["norm2"], cfg.norm_eps)
        if kind.startswith("moe"):
            if collect_router_stats:
                f, a_s, stats = moe_mod.moe_ffn(params["moe"], cfg, h,
                                                collect_stats=True)
                aux = (a_s, stats)
            else:
                f, aux = moe_mod.moe_ffn(params["moe"], cfg, h)
        else:
            f = mlp(params["mlp"], h, dt)
        x = x + f
        new_cache = None if cache is None else dict(kv=kv)
        return x, new_cache, aux

    if kind in ("hymba", "hymba_g"):
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        a, kv = attn.gqa_attention(
            params["attn"], cfg, h, positions, window=window,
            prefix_len=prefix_len,
            cache=None if cache is None else cache["kv"])
        ssm_state = None if cache is None else cache["ssm"]
        if decode:
            m, s_new = ssm.mamba_step(params["mamba"], cfg, h, ssm_state)
        else:
            m, s_new = ssm.mamba_forward(params["mamba"], cfg, h, ssm_state)
        beta = params["beta"].astype(dt)
        x = x + 0.5 * (beta[0] * a + beta[1] * m)
        h = rms_norm(x, params["norm2"], cfg.norm_eps)
        x = x + mlp(params["mlp"], h, dt)
        new_cache = None if cache is None else dict(kv=kv, ssm=s_new)
        return x, new_cache, aux

    if kind in ("mlstm", "slstm"):
        h = rms_norm(x, params["norm1"], cfg.norm_eps)
        state = None if cache is None else cache["state"]
        cell = ssm.mlstm_forward if kind == "mlstm" else ssm.slstm_forward
        step = ssm.mlstm_step if kind == "mlstm" else ssm.slstm_step
        y, s_new = (step if decode else cell)(params["cell"], cfg, h, state)
        x = x + y
        if cfg.d_ff:
            h = rms_norm(x, params["norm2"], cfg.norm_eps)
            x = x + mlp(params["mlp"], h, dt)
        new_cache = None if cache is None else dict(state=s_new)
        return x, new_cache, aux

    raise ValueError(kind)


# ---------------------------------------------------------------- forward --


def _embed_inputs(params, cfg: ModelConfig, batch: Dict) -> jax.Array:
    dt = jnp.dtype(cfg.compute_dtype)
    parts = []
    if "embeds" in batch and batch["embeds"] is not None:
        parts.append(batch["embeds"].astype(dt))
    if "tokens" in batch and batch["tokens"] is not None:
        e = params["embed"].astype(dt)[batch["tokens"]]
        parts.append(e)
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if cfg.embed_scale:
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), dt)
    return shard(x, BATCH, None, None)


def _aux_add(a, b):
    """Pytree add for the aux channel (scalar or (scalar, RouterStats))."""
    return jax.tree.map(jnp.add, a, b)


def forward(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict,
    *,
    cache: Optional[Dict] = None,
    decode: bool = False,
    remat: str = "none",
    collect_router_stats: bool = False,
) -> Tuple[jax.Array, Optional[Dict], jax.Array]:
    """Run the stack.  Returns (hidden (B,S,D), new_cache, aux_loss).

    ``collect_router_stats`` widens the aux return to
    ``(aux_scalar, moe.RouterStats)`` — per-expert token counts and the
    co-activation matrix summed over every MoE layer, accumulated inside
    the layer-unit scan with fixed shapes (no host round-trip)."""
    from repro.models.layers import set_profile
    # dp (batch-over-everything) pays off for training small models; cache
    # paths (prefill/decode) need the 2d layout's KV-length sharding —
    # measured both ways in EXPERIMENTS.md §Perf.
    prof = cfg.sharding_profile
    if prof == "dp" and (decode or cache is not None):
        prof = "2d"
    set_profile(prof)
    x = _embed_inputs(params, cfg, batch)
    positions = batch["positions"]
    prefix_len = cfg.vision_prefix if cfg.prefix_lm else 0
    aux_total = zero_aux(cfg, collect_router_stats)

    new_prefix = []
    for i, kind in enumerate(cfg.prefix_layers):
        c = None if cache is None else cache["prefix"][i]
        x, c_new, aux = apply_block(params["prefix"][i], cfg, kind, x,
                                    positions, c, prefix_len=prefix_len,
                                    decode=decode,
                                    collect_router_stats=collect_router_stats)
        new_prefix.append(c_new)
        aux_total = _aux_add(aux_total, aux)

    # scanned groups
    def group_body(carry, xs):
        x, aux_acc = carry
        unit_params, unit_cache = xs
        new_unit_cache = []
        for i, kind in enumerate(cfg.layer_unit):
            c = None if unit_cache is None else unit_cache[i]
            x, c_new, aux = apply_block(unit_params[i], cfg, kind, x,
                                        positions, c, prefix_len=prefix_len,
                                        decode=decode,
                                        collect_router_stats=collect_router_stats)
            new_unit_cache.append(c_new)
            aux_acc = _aux_add(aux_acc, aux)
        ys = tuple(new_unit_cache) if unit_cache is not None else None
        return (x, aux_acc), ys

    body = group_body
    if remat == "full":
        body = jax.checkpoint(group_body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    elif remat == "dots":
        body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    unit_cache = None if cache is None else tuple(cache["unit"])
    xs = (tuple(params["unit"]), unit_cache)
    if cfg.num_groups > 0:
        (x, aux_total), new_unit = jax.lax.scan(body, (x, aux_total), xs)
    else:
        new_unit = unit_cache

    new_suffix = []
    for i, kind in enumerate(cfg.suffix_layers):
        c = None if cache is None else cache["suffix"][i]
        x, c_new, aux = apply_block(params["suffix"][i], cfg, kind, x,
                                    positions, c, prefix_len=prefix_len,
                                    decode=decode,
                                    collect_router_stats=collect_router_stats)
        new_suffix.append(c_new)
        aux_total = _aux_add(aux_total, aux)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    new_cache = None
    if cache is not None:
        new_cache = dict(unit=list(new_unit), prefix=new_prefix,
                         suffix=new_suffix)
        new_cache = shard_cache(new_cache)
    return x, new_cache, aux_total


def logits_head(params, cfg: ModelConfig, h: jax.Array) -> jax.Array:
    dt = h.dtype
    if cfg.tie_embeddings:
        w = params["embed"].astype(dt).T
    else:
        w = params["lm_head"].astype(dt)
    return jnp.einsum("bsd,dv->bsv", h, w)


# ------------------------------------------------------------------- loss --


def loss_fn(
    params: Dict,
    cfg: ModelConfig,
    batch: Dict,
    *,
    remat: str = "none",
    seq_chunk: int = 512,
    z_weight: float = 1e-4,
    collect_router_stats: bool = False,
) -> Tuple[jax.Array, Dict]:
    """Next-token CE.  ``batch["labels"]`` is (B, S) with -1 = masked.

    The head is applied in sequence chunks under ``lax.scan`` with the vocab
    dim sharded over "model": per-chunk logits are (B, c, V/shards) locally
    and the full (B, S, V) tensor never exists.

    ``collect_router_stats`` adds ``router_counts`` (E,) and
    ``router_coact`` (E, E) to the metrics dict — the device-resident
    routing statistics the expert-placement runtime consumes.  They ride
    the aux channel as non-differentiated metrics (``stop_gradient``), so
    the loss value and gradients are unchanged.
    """
    h, _, aux = forward(params, cfg, batch, remat=remat,
                        collect_router_stats=collect_router_stats)
    rstats = None
    if collect_router_stats:
        aux, rstats = aux
        rstats = jax.lax.stop_gradient(rstats)
    labels = batch["labels"]
    B, S = labels.shape
    dt = h.dtype
    w = (params["embed"].astype(dt).T if cfg.tie_embeddings
         else params["lm_head"].astype(dt))

    c = min(seq_chunk, S)
    Sp = -(-S // c) * c
    if Sp != S:
        h = jnp.pad(h, ((0, 0), (0, Sp - S), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, Sp - S)), constant_values=-1)
    hc = jnp.moveaxis(h.reshape(B, Sp // c, c, -1), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, Sp // c, c), 1, 0)

    def chunk_ce(carry, xs):
        hx, lx = xs                                   # (B,c,D), (B,c)
        logits = jnp.einsum("bcd,dv->bcv", hx, w).astype(jnp.float32)
        logits = shard(logits, BATCH, None, MODEL)
        m = logits.max(axis=-1)
        lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), axis=-1))
        onehot = jax.nn.one_hot(jnp.maximum(lx, 0), cfg.vocab_size, dtype=dt)
        label_logit = jnp.einsum("bcv,bcv->bc", logits.astype(dt), onehot)
        valid = lx >= 0
        nll = jnp.where(valid, lse - label_logit.astype(jnp.float32), 0.0)
        zl = jnp.where(valid, lse ** 2, 0.0)
        tot, ztot, cnt = carry
        return (tot + nll.sum(), ztot + zl.sum(), cnt + valid.sum()), None

    # checkpoint: backward recomputes each chunk's logits instead of saving
    # (B, c, V)-sized residuals per chunk — peak memory drops from
    # O(S/c · B·c·V / shards) to O(B·c·V / shards) at one extra head matmul
    # per chunk.
    chunk_ce = jax.checkpoint(
        chunk_ce, policy=jax.checkpoint_policies.nothing_saveable)
    (tot, ztot, cnt), _ = jax.lax.scan(
        chunk_ce, (jnp.float32(0), jnp.float32(0), jnp.int32(0)), (hc, lc))
    denom = jnp.maximum(cnt, 1).astype(jnp.float32)
    ce = tot / denom
    loss = ce + z_weight * ztot / denom

    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux

    mtp_loss = jnp.float32(0.0)
    if cfg.mtp and "tokens" in batch and batch["tokens"] is not None:
        mtp_loss = _mtp_loss(params, cfg, batch, h[:, :S])
        loss = loss + 0.3 * mtp_loss

    metrics = dict(ce=ce, aux=aux, tokens=cnt, mtp=mtp_loss)
    if rstats is not None:
        metrics["router_counts"] = rstats.counts
        metrics["router_coact"] = rstats.coact
    return loss, metrics


def _mtp_loss(params, cfg: ModelConfig, batch, h):
    """DeepSeek-V3 multi-token prediction: one extra block predicting t+2
    from [norm(h_t) ; emb(token_{t+1})], sharing embed + lm head."""
    dt = h.dtype
    tokens, labels = batch["tokens"], batch["labels"]
    B, S = tokens.shape
    nxt = jnp.concatenate([tokens[:, 1:], tokens[:, -1:]], axis=1)
    lbl2 = jnp.concatenate(
        [labels[:, 1:], jnp.full_like(labels[:, -1:], -1)], axis=1)
    e = params["embed"].astype(dt)[nxt]
    hm = rms_norm(h, params["mtp"]["norm"], cfg.norm_eps)
    x = jnp.einsum("bsf,fd->bsd", jnp.concatenate([hm, e], -1),
                   params["mtp"]["proj"].astype(dt))
    x, _, _ = (lambda p: apply_block(p, cfg, "attn", x, batch["positions"],
                                     None))(params["mtp"]["block"])
    logits = logits_head(params, cfg, x).astype(jnp.float32)
    logits = shard(logits, BATCH, None, MODEL)
    m = logits.max(-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(logits - m[..., None]), -1))
    oh = jax.nn.one_hot(jnp.maximum(lbl2, 0), cfg.vocab_size, dtype=dt)
    ll = jnp.einsum("bsv,bsv->bs", logits.astype(dt), oh).astype(jnp.float32)
    valid = lbl2 >= 0
    return (jnp.where(valid, lse - ll, 0.0).sum()
            / jnp.maximum(valid.sum(), 1))


# ------------------------------------------------------------ decode step --


def prefill(params, cfg: ModelConfig, batch: Dict, cache: Dict):
    """Full-sequence forward writing the cache; returns last-pos logits."""
    h, cache, _ = forward(params, cfg, batch, cache=cache, decode=False)
    logits = logits_head(params, cfg, h[:, -1:])
    return logits, cache


def decode_step(params, cfg: ModelConfig, tokens, index, cache: Dict,
                embeds=None):
    """One decode step.  tokens (B, 1), index scalar current position."""
    B = tokens.shape[0] if tokens is not None else embeds.shape[0]
    positions = jnp.full((B, 1), index, jnp.int32)
    batch = dict(tokens=tokens, embeds=embeds, positions=positions)
    h, cache, _ = forward(params, cfg, batch, cache=cache, decode=True)
    logits = logits_head(params, cfg, h)
    return logits, cache
