"""Error-feedback int8 gradient compression for the DP all-reduce.

Standard EF-SGD construction (Karimireddy et al. 2019): each step compresses
``grad + residual`` to per-tensor-scaled int8, all-reduces the compressed
representation (8× less DP traffic), and carries the quantization error into
the next step's residual — unbiased in the long run, convergence-safe.

Under ``jax.jit`` + GSPMD the all-reduce is implicit (grads of sharded
params); we therefore expose the compression as a *gradient transform*
``(grads, residual) -> (decompressed, residual)`` inserted between backward
and the optimizer (train_step.make_train_step(grad_transform=...)).  The
collective then moves int8: XLA all-reduces the values we hand it, and the
dry-run HLO shows the 4× byte reduction on the DP collectives (validated in
EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_residual(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_leaf(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """fp32 → (int8, scale).  Symmetric per-tensor scaling."""
    amax = jnp.max(jnp.abs(g))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _decompress_leaf(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress(grads, residual) -> Tuple[Any, Any]:
    """Returns (decompressed grads to feed the optimizer, new residual)."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, s = _compress_leaf(g32)
        deq = _decompress_leaf(q, s)
        return deq, g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(tdef, [o[0] for o in out]),
            jax.tree.unflatten(tdef, [o[1] for o in out]))


def compression_error(grads, residual) -> jax.Array:
    """Relative L2 error of one compress round (diagnostics)."""
    deq, _ = compress(grads, residual)
    num = jnp.sqrt(sum(jnp.sum((a.astype(jnp.float32) - b) ** 2)
                       for a, b in zip(jax.tree.leaves(grads),
                                       jax.tree.leaves(deq))))
    den = jnp.sqrt(sum(jnp.sum(a.astype(jnp.float32) ** 2)
                       for a in jax.tree.leaves(grads)))
    return num / jnp.maximum(den, 1e-30)
