"""Sharding-tree builders for every jitted entry point.

One convention (DESIGN.md §5): batch → ("pod","data"); heads / d_ff / vocab
/ experts → "model"; params FSDP over "data" where the ParamSpec says so;
KV-cache length and recurrent-state heads → "model".  These builders turn
that convention into NamedSharding trees for jit in/out_shardings — the
model code re-asserts the same layout internally with
``with_sharding_constraint`` so both sides agree and GSPMD has no freedom
to resharde at the boundary.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compat import get_abstract_mesh  # noqa: F401  (re-export)
from repro.models import params as params_lib

BATCH = ("pod", "data")


def _translate(a, profile: str):
    """Profile translation mirroring models.layers.translate (the in-model
    constraints and the jit-boundary shardings must agree)."""
    if profile == "dp":
        if isinstance(a, (tuple, list)) and "data" in a:
            return ("pod", "data", "model")
        if a == "model":
            return None
    return a


def _filter(spec_axes, mesh: Mesh, shape=None, profile: str = "2d"):
    """PartitionSpec with (a) axis names the mesh lacks dropped, and (b)
    axes dropped on dims they don't divide (vocab 32001, 4 heads or batch 1
    against a 16-wide axis, ... — GSPMD cannot lay those out as jit
    argument shardings; they stay replicated on that dim)."""
    names = set(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def keep(i, a):
        a = _translate(a, profile)
        if a is None:
            return None
        ax = tuple(x for x in (a if isinstance(a, (tuple, list)) else (a,))
                   if x in names)
        # drop trailing axes until the dim divides (batch 128 against a
        # 512-wide ("pod","data","model") product falls back to 16-way
        # rather than replicating outright)
        while ax and shape is not None:
            n = 1
            for x in ax:
                n *= sizes[x]
            if i < len(shape) and shape[i] % n == 0:
                break
            ax = ax[:-1]
        if not ax:
            return None
        return ax if len(ax) > 1 else ax[0]

    return P(*(keep(i, a) for i, a in enumerate(spec_axes)))


def batch_axes(mesh: Mesh):
    return tuple(a for a in BATCH if a in mesh.axis_names)


def param_shardings(spec_tree, mesh: Mesh, profile: str = "2d"):
    return params_lib.tree_map_specs(
        lambda s: NamedSharding(mesh, _filter(s.spec, mesh, s.shape,
                                              profile)),
        spec_tree)


def opt_shardings(pshard, mesh: Mesh, *, master: bool = False):
    """OptState(step, mu, nu[, master]) sharded like the params (ZeRO via
    FSDP spec)."""
    from repro.train.optimizer import OptState
    return OptState(
        step=NamedSharding(mesh, P()),
        mu=jax.tree.map(lambda s: s, pshard),
        nu=jax.tree.map(lambda s: s, pshard),
        master=jax.tree.map(lambda s: s, pshard) if master else None,
    )


def data_shardings(batch_sds: Dict, mesh: Mesh, profile: str = "2d"):
    """Token batches: leading (global) batch dim over ("pod","data")
    (plus "model" under the dp profile)."""
    ba = batch_axes(mesh)

    def f(sds):
        if sds is None:
            return None
        spec = [None] * len(sds.shape)
        if len(sds.shape) >= 1:
            spec[0] = ba if ba else None
        return NamedSharding(mesh, _filter(spec, mesh, sds.shape, profile))

    return jax.tree.map(f, batch_sds, is_leaf=lambda x: x is None)


def cache_shardings(cache_sds: Dict, mesh: Mesh, profile: str = "2d"):
    """KV caches / recurrent states, mirroring transformer.shard_cache:
    batch → ("pod","data"); axis 1 (length or heads) → "model".  Stacked
    ("unit") subtrees carry a leading scan-group dim (replicated)."""
    ba = batch_axes(mesh)
    model = "model" if "model" in mesh.axis_names else None

    def leaf(sds, stacked: bool):
        nd = len(sds.shape)
        off = 1 if stacked else 0
        spec = [None] * nd
        if stacked:
            spec[0] = None
        if nd - off >= 1:
            spec[off] = ba if ba else None
        if nd - off >= 2:
            spec[off + 1] = model
        return NamedSharding(mesh, _filter(spec, mesh, sds.shape, profile))

    def walk(sub, stacked):
        return jax.tree.map(lambda s: leaf(s, stacked), sub,
                            is_leaf=lambda x: x is None or hasattr(x, "shape"))

    out = dict(cache_sds)
    out["unit"] = [walk(s, True) for s in cache_sds["unit"]]
    out["prefix"] = [None if s is None else walk(s, False)
                     for s in cache_sds["prefix"]]
    out["suffix"] = [None if s is None else walk(s, False)
                     for s in cache_sds["suffix"]]
    return out


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
