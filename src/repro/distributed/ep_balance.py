"""MoE expert placement via communication-aware diffusion (DESIGN.md §3.1).

Experts are the canonical "persistently interacting objects" of an LM
system: top-k routing keeps co-activating the same expert groups for a
given data distribution, expert loads (tokens/expert) drift slowly, and
migrating an expert between EP ranks costs real weight traffic
(E × (3·D·F) bytes).  This module runs the paper's three-stage balancer on
the expert→rank placement:

  * objects   = experts;  object load = EMA tokens routed per expert
  * comm edge (i, j) = co-activation count: tokens selecting experts i and
    j together under top-k.  Colocating co-activated experts means one
    dispatched token copy serves both — exactly the "external bytes" the
    paper's metric minimizes (a token sent to a rank is sent once
    regardless of how many local experts consume it);
  * nodes     = EP ranks (the "model" mesh axis)
  * migration = expert weight transfer (minimized by the diffusion design)

Output is a **placement permutation**: physical slot s on rank r holds
logical expert ``perm[r·E_loc + s]``.  The MoE layer applies it as a gather
over the stacked expert weights plus an index remap in the router — no
resharding of anything else.  A post-pass repairs diffusion's approximate
counts to exactly E/R experts per rank (slot capacity is rigid), moving the
lightest experts first along neighbor edges only.

Baseline for comparison: ``greedy_placement`` (sorted load → least-loaded
rank, ignores co-activation — the GreedyLB analogue).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import api as core_api
from repro.core import comm_graph, metrics


@dataclasses.dataclass
class ExpertStats:
    """EMA routing statistics collected from the router over train steps."""

    num_experts: int
    ema: float = 0.9
    tokens: Optional[np.ndarray] = None        # (E,) EMA tokens per expert
    coact: Optional[np.ndarray] = None         # (E, E) EMA co-activations

    def __post_init__(self):
        E = self.num_experts
        if self.tokens is None:
            self.tokens = np.zeros(E)
        if self.coact is None:
            self.coact = np.zeros((E, E))

    def update(self, expert_ids: np.ndarray) -> None:
        """``expert_ids``: (T, k) routed expert ids for one step's tokens."""
        E = self.num_experts
        ids = np.asarray(expert_ids)
        counts = np.bincount(ids.reshape(-1), minlength=E).astype(np.float64)
        co = np.zeros((E, E))
        k = ids.shape[1]
        for a in range(k):
            for b in range(a + 1, k):
                np.add.at(co, (ids[:, a], ids[:, b]), 1.0)
        co = co + co.T
        self.tokens = self.ema * self.tokens + (1 - self.ema) * counts
        self.coact = self.ema * self.coact + (1 - self.ema) * co

    def imbalance(self, placement: np.ndarray, num_ranks: int) -> float:
        rank_load = np.bincount(placement, weights=self.tokens,
                                minlength=num_ranks)
        return float(rank_load.max() / (rank_load.mean() + 1e-30))


def build_problem(stats: ExpertStats, placement: np.ndarray,
                  num_ranks: int) -> comm_graph.LBProblem:
    E = stats.num_experts
    iu, ju = np.triu_indices(E, k=1)
    w = stats.coact[iu, ju]
    keep = w > 0
    edges = np.stack([iu[keep], ju[keep]], axis=1)
    if edges.size == 0:                        # no co-activation yet: ring
        edges = np.stack([np.arange(E), (np.arange(E) + 1) % E], axis=1)
        w = np.full(E, 1e-3)
        keep = slice(None)
    return comm_graph.make_problem(
        loads=np.maximum(stats.tokens, 1e-3),
        assignment=np.asarray(placement, np.int32),
        edges=edges,
        edge_bytes=np.asarray(w[keep], np.float32),
        num_nodes=num_ranks,
    )


def _repair_counts(assignment: np.ndarray, loads: np.ndarray,
                   num_ranks: int, cap: int) -> np.ndarray:
    """Enforce exactly ``cap`` experts per rank, moving light experts from
    over-full to under-full ranks."""
    a = assignment.copy()
    counts = np.bincount(a, minlength=num_ranks)
    over = [r for r in range(num_ranks) if counts[r] > cap]
    under = [r for r in range(num_ranks) if counts[r] < cap]
    for r in over:
        movable = np.nonzero(a == r)[0]
        movable = movable[np.argsort(loads[movable])]      # lightest first
        i = 0
        while counts[r] > cap and i < len(movable):
            dst = min(under, key=lambda q: counts[q])
            a[movable[i]] = dst
            counts[r] -= 1
            counts[dst] += 1
            if counts[dst] >= cap:
                under.remove(dst)
            i += 1
    return a


def plan_placement(
    stats: ExpertStats,
    placement: np.ndarray,
    num_ranks: int,
    *,
    k: int = 4,
    strategy: str = "diff-comm",
) -> Tuple[np.ndarray, Dict]:
    """New expert→rank placement (exactly E/R per rank) + plan info."""
    E = stats.num_experts
    assert E % num_ranks == 0
    cap = E // num_ranks
    prob = build_problem(stats, placement, num_ranks)
    if strategy == "greedy":
        new = greedy_placement(stats, num_ranks)
        info: Dict = dict(strategy="greedy")
    else:
        plan = core_api.diffusion_lb(
            prob, k=min(k, num_ranks - 1),
            variant="comm", tol=0.05)
        new, info = plan.assignment, plan.info
    new = _repair_counts(np.asarray(new), stats.tokens, num_ranks, cap)
    info.update(metrics.evaluate(prob, jnp.asarray(new)))
    info["moved_experts"] = int((new != placement).sum())
    return new.astype(np.int32), info


def greedy_placement(stats: ExpertStats, num_ranks: int) -> np.ndarray:
    """Load-only greedy (ignores co-activation) — the comparison baseline."""
    E = stats.num_experts
    cap = E // num_ranks
    order = np.argsort(-stats.tokens)
    rank_load = np.zeros(num_ranks)
    rank_cnt = np.zeros(num_ranks, np.int64)
    out = np.zeros(E, np.int32)
    for e in order:
        open_ = np.nonzero(rank_cnt < cap)[0]
        r = open_[np.argmin(rank_load[open_])]
        out[e] = r
        rank_load[r] += stats.tokens[e]
        rank_cnt[r] += 1
    return out


# ----------------------------------------------------------- permutation --


def placement_to_perm(placement: np.ndarray, num_ranks: int) -> np.ndarray:
    """(E,) physical-slot → logical-expert permutation.

    Slot ``r·cap + i`` (the i-th expert slice held by EP rank r in the
    stacked weight layout) receives logical expert ``perm[r·cap + i]``."""
    E = len(placement)
    cap = E // num_ranks
    perm = np.zeros(E, np.int64)
    for r in range(num_ranks):
        mine = np.sort(np.nonzero(placement == r)[0])
        assert len(mine) == cap, "placement must be capacity-exact"
        perm[r * cap:(r + 1) * cap] = mine
    return perm


def apply_perm_to_params(moe_params: Dict, perm: np.ndarray) -> Dict:
    """Gather stacked expert weights into the new physical layout, and remap
    the router's output columns so logical expert ids keep working."""
    perm = jnp.asarray(perm)
    inv = jnp.argsort(perm)
    out = dict(moe_params)
    for key in ("wi", "wg", "wo"):
        out[key] = jnp.take(moe_params[key], perm, axis=0)
    # router produces logits over *logical* experts; routing to physical
    # slot s must pick logical perm[s] ⇒ permute logit columns by perm.
    out["router"] = jnp.take(moe_params["router"], perm, axis=1)
    return out


def migration_bytes(perm_old: np.ndarray, perm_new: np.ndarray,
                    bytes_per_expert: float, num_ranks: int) -> float:
    """Weight bytes that cross rank boundaries realizing the new layout."""
    E = len(perm_old)
    cap = E // num_ranks
    rank_of_slot = np.arange(E) // cap
    old_rank = np.zeros(E, np.int64)
    new_rank = np.zeros(E, np.int64)
    old_rank[np.asarray(perm_old)] = rank_of_slot
    new_rank[np.asarray(perm_new)] = rank_of_slot
    return float((old_rank != new_rank).sum() * bytes_per_expert)
