"""MoE expert placement via communication-aware diffusion (DESIGN.md §3.1).

Experts are the canonical "persistently interacting objects" of an LM
system: top-k routing keeps co-activating the same expert groups for a
given data distribution, expert loads (tokens/expert) drift slowly, and
migrating an expert between EP ranks costs real weight traffic
(E × (3·D·F) bytes).  This module runs the paper's three-stage balancer on
the expert→rank placement:

  * objects   = experts;  object load = EMA tokens routed per expert
  * comm edge (i, j) = co-activation count: tokens selecting experts i and
    j together under top-k.  Colocating co-activated experts means one
    dispatched token copy serves both — exactly the "external bytes" the
    paper's metric minimizes (a token sent to a rank is sent once
    regardless of how many local experts consume it);
  * nodes     = EP ranks (the "model" mesh axis)
  * migration = expert weight transfer (minimized by the diffusion design)

Output is a **placement permutation**: physical slot s on rank r holds
logical expert ``perm[r·E_loc + s]``.  The MoE layer applies it as a gather
over the stacked expert weights plus an index remap in the router — no
resharding of anything else.  A post-pass repairs diffusion's approximate
counts to exactly E/R experts per rank (slot capacity is rigid), moving the
lightest experts first along neighbor edges only.

Baseline for comparison: ``greedy_placement`` (sorted load → least-loaded
rank, ignores co-activation — the GreedyLB analogue).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import comm_graph, engine, metrics


@dataclasses.dataclass
class ExpertStats:
    """EMA routing statistics collected from the router over train steps."""

    num_experts: int
    ema: float = 0.9
    tokens: Optional[np.ndarray] = None        # (E,) EMA tokens per expert
    coact: Optional[np.ndarray] = None         # (E, E) EMA co-activations

    def __post_init__(self):
        E = self.num_experts
        if self.tokens is None:
            self.tokens = np.zeros(E)
        if self.coact is None:
            self.coact = np.zeros((E, E))

    def update(self, expert_ids: np.ndarray) -> None:
        """``expert_ids``: (T, k) routed expert ids for one step's tokens.

        One batched bincount + outer-product update: with ``C`` the
        (T, E) per-token selection-count matrix, the symmetrized
        ordered-pair co-activation is ``CᵀC − diag(counts)`` — exactly
        the historical O(k²) ``np.add.at`` pair loop (kept as
        :func:`pair_stats_loop` and property-tested equal), in two BLAS
        calls instead of k(k−1)/2 scatter passes."""
        counts, co = pair_stats_np(expert_ids, self.num_experts)
        self.tokens = self.ema * self.tokens + (1 - self.ema) * counts
        self.coact = self.ema * self.coact + (1 - self.ema) * co

    def update_from_counts(self, counts, coact) -> None:
        """EMA update from precomputed stats (the device-resident path:
        ``models.moe.pair_stats`` sums ride the train step's metrics)."""
        self.tokens = (self.ema * self.tokens
                       + (1 - self.ema) * np.asarray(counts, np.float64))
        self.coact = (self.ema * self.coact
                      + (1 - self.ema) * np.asarray(coact, np.float64))

    def imbalance(self, placement: np.ndarray, num_ranks: int) -> float:
        rank_load = np.bincount(placement, weights=self.tokens,
                                minlength=num_ranks)
        return float(rank_load.max() / (rank_load.mean() + 1e-30))


def pair_stats_np(expert_ids, num_experts: int):
    """(counts (E,), coact (E, E)) from (T, k) routed ids — host twin of
    the device op ``models.moe.pair_stats`` (same identity, numpy)."""
    E = int(num_experts)
    ids = np.asarray(expert_ids)
    T = ids.shape[0]
    counts = np.bincount(ids.reshape(-1), minlength=E).astype(np.float64)
    C = np.zeros((T, E))
    np.add.at(C, (np.repeat(np.arange(T), ids.shape[1]), ids.reshape(-1)),
              1.0)
    co = C.T @ C - np.diag(counts)
    return counts, co


def pair_stats_loop(expert_ids, num_experts: int):
    """The historical O(k²) pair loop, kept as the property-test oracle
    for :meth:`ExpertStats.update` / :func:`pair_stats_np`."""
    E = int(num_experts)
    ids = np.asarray(expert_ids)
    counts = np.bincount(ids.reshape(-1), minlength=E).astype(np.float64)
    co = np.zeros((E, E))
    k = ids.shape[1]
    for a in range(k):
        for b in range(a + 1, k):
            np.add.at(co, (ids[:, a], ids[:, b]), 1.0)
    return counts, co + co.T


def build_problem(stats: ExpertStats, placement: np.ndarray,
                  num_ranks: int) -> comm_graph.LBProblem:
    E = stats.num_experts
    iu, ju = np.triu_indices(E, k=1)
    w = stats.coact[iu, ju]
    keep = w > 0
    edges = np.stack([iu[keep], ju[keep]], axis=1)
    if edges.size == 0:                        # no co-activation yet: ring
        edges = np.stack([np.arange(E), (np.arange(E) + 1) % E], axis=1)
        w = np.full(E, 1e-3)
        keep = slice(None)
    return comm_graph.make_problem(
        loads=np.maximum(stats.tokens, 1e-3),
        assignment=np.asarray(placement, np.int32),
        edges=edges,
        edge_bytes=np.asarray(w[keep], np.float32),
        num_nodes=num_ranks,
    )


@functools.partial(jax.jit, static_argnames=("num_ranks", "cap"))
def repair_capacity(assignment, loads, *, num_ranks: int,
                    cap: int) -> jax.Array:
    """Enforce exactly ``cap`` experts per rank — as a jittable pass.

    Replaces the historical host repair loop with fixed-shape segment
    ops, so the in-scan expert-placement runtime
    (``train/ep_runtime.py``) runs it inside ``lax.scan`` / ``lax.cond``
    and the eager callers execute the *same expression graph* (bit-for-
    bit identical repairs on both paths).  Semantics: each over-full
    rank evicts its lightest excess experts; evicted experts — globally
    ordered by ascending load, ties by index (stable) — fill the
    under-full ranks in rank order.  Deterministic, O(E·R) one-hot
    cumsums, no data-dependent shapes."""
    a = jnp.asarray(assignment, jnp.int32)
    loads = jnp.asarray(loads, jnp.float32)
    E = a.shape[0]
    R = int(num_ranks)
    counts = jax.ops.segment_sum(jnp.ones((E,), jnp.int32), a,
                                 num_segments=R)
    # within-rank position in ascending-load order (stable)
    ordl = jnp.argsort(loads, stable=True).astype(jnp.int32)
    onehot = jax.nn.one_hot(jnp.take(a, ordl), R, dtype=jnp.int32)
    pos_s = (jnp.cumsum(onehot, axis=0) * onehot).sum(axis=1) - 1
    pos = jnp.zeros((E,), jnp.int32).at[ordl].set(pos_s)
    excess = jnp.maximum(counts - cap, 0)
    evict = pos < jnp.take(excess, a)                  # lightest first
    # destinations: the j-th evicted expert (ascending load, stable)
    # takes the j-th open slot in cumulative-deficit order
    deficit = jnp.maximum(cap - counts, 0)
    cd = jnp.cumsum(deficit)
    key = jnp.where(evict, loads, jnp.inf)
    orde = jnp.argsort(key, stable=True).astype(jnp.int32)
    slot = jnp.zeros((E,), jnp.int32).at[orde].set(
        jnp.arange(E, dtype=jnp.int32))
    dst = jnp.searchsorted(cd, slot, side="right").astype(jnp.int32)
    return jnp.where(evict, jnp.clip(dst, 0, R - 1), a)


#: strategy-name aliases: the legacy ``strategy="greedy"`` spelling maps
#: to the registered capacity-capped greedy (``core.baselines
#: .greedy_capped``) — plain ``greedy`` has no slot budget and would
#: leave the capacity repair to do all the work
_ALIASES = {"greedy": "ep-greedy"}


def plan_placement(
    stats: ExpertStats,
    placement: np.ndarray,
    num_ranks: int,
    *,
    k: int = 4,
    strategy: str = "diff-comm",
) -> Tuple[np.ndarray, Dict]:
    """New expert→rank placement (exactly E/R per rank) + plan info.

    Planning goes through the Strategy registry (``core.engine``) — the
    same jitted ``LBEngine`` plan the replay layers trace — followed by
    the jittable :func:`repair_capacity` pass.  The legacy
    ``core_api.diffusion_lb`` path is gone; ``strategy`` accepts any
    registered name (``diff-comm``, ``diff-comm+predictive``,
    ``ep-greedy``, ...) plus the historical ``"greedy"`` alias."""
    E = stats.num_experts
    assert E % num_ranks == 0
    cap = E // num_ranks
    prob = build_problem(stats, placement, num_ranks)
    strat = engine.get_strategy(_ALIASES.get(strategy, strategy))
    kw: Dict = {}
    if strat.variant is not None:
        kw = dict(k=min(k, num_ranks - 1), tol=0.05)
    plan = strat.run(prob, **kw)
    new, info = np.asarray(plan.assignment), dict(plan.info)
    new = np.asarray(repair_capacity(
        new, np.asarray(stats.tokens, np.float32),
        num_ranks=num_ranks, cap=cap))
    info.update(metrics.evaluate(prob, jnp.asarray(new)))
    info["moved_experts"] = int((new != placement).sum())
    return new.astype(np.int32), info


def greedy_placement(stats: ExpertStats, num_ranks: int) -> np.ndarray:
    """Load-only greedy (ignores co-activation) — the comparison baseline."""
    E = stats.num_experts
    cap = E // num_ranks
    order = np.argsort(-stats.tokens)
    rank_load = np.zeros(num_ranks)
    rank_cnt = np.zeros(num_ranks, np.int64)
    out = np.zeros(E, np.int32)
    for e in order:
        open_ = np.nonzero(rank_cnt < cap)[0]
        r = open_[np.argmin(rank_load[open_])]
        out[e] = r
        rank_load[r] += stats.tokens[e]
        rank_cnt[r] += 1
    return out


# ----------------------------------------------------------- permutation --


def placement_to_perm(placement: np.ndarray, num_ranks: int) -> np.ndarray:
    """(E,) physical-slot → logical-expert permutation.

    Slot ``r·cap + i`` (the i-th expert slice held by EP rank r in the
    stacked weight layout) receives logical expert ``perm[r·cap + i]``."""
    E = len(placement)
    cap = E // num_ranks
    perm = np.zeros(E, np.int64)
    for r in range(num_ranks):
        mine = np.sort(np.nonzero(placement == r)[0])
        assert len(mine) == cap, "placement must be capacity-exact"
        perm[r * cap:(r + 1) * cap] = mine
    return perm


def apply_perm_to_params(moe_params: Dict, perm: np.ndarray) -> Dict:
    """Gather stacked expert weights into the new physical layout, and remap
    the router's output columns so logical expert ids keep working."""
    perm = jnp.asarray(perm)
    inv = jnp.argsort(perm)
    out = dict(moe_params)
    for key in ("wi", "wg", "wo"):
        out[key] = jnp.take(moe_params[key], perm, axis=0)
    # router produces logits over *logical* experts; routing to physical
    # slot s must pick logical perm[s] ⇒ permute logit columns by perm.
    out["router"] = jnp.take(moe_params["router"], perm, axis=1)
    return out


def migration_bytes(perm_old: np.ndarray, perm_new: np.ndarray,
                    bytes_per_expert: float, num_ranks: int) -> float:
    """Weight bytes that cross rank boundaries realizing the new layout."""
    E = len(perm_old)
    cap = E // num_ranks
    rank_of_slot = np.arange(E) // cap
    old_rank = np.zeros(E, np.int64)
    new_rank = np.zeros(E, np.int64)
    old_rank[np.asarray(perm_old)] = rank_of_slot
    new_rank[np.asarray(perm_new)] = rank_of_slot
    return float((old_rank != new_rank).sum() * bytes_per_expert)
