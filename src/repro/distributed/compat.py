"""Version-guarded shims for jax.sharding APIs newer than the pinned jax.

The pinned jax (0.4.37) predates ``jax.sharding.get_abstract_mesh``,
``jax.sharding.set_mesh``, ``jax.sharding.AxisType`` and the top-level
``jax.shard_map``.  The code base is written against the newer spelling;
``install()`` (called from ``repro/__init__.py``) backfills the missing
names so both old and new jax work unchanged.  On a new-enough jax every
shim is a no-op and the native implementation is used.
"""
from __future__ import annotations

import contextlib
import enum

import jax


def get_abstract_mesh():
    """Ambient mesh: native ``jax.sharding.get_abstract_mesh`` when present,
    else the thread-local physical mesh set by ``with mesh:`` / ``set_mesh``.

    Both return an object with ``.empty``, ``.axis_names`` and
    ``.axis_sizes`` — the only attributes our call sites touch."""
    native = getattr(jax.sharding, "get_abstract_mesh", None)
    if native is not None and native is not get_abstract_mesh:
        return native()  # pragma: no cover - new-jax path
    from jax._src import mesh as mesh_lib

    return mesh_lib.thread_resources.env.physical_mesh


@contextlib.contextmanager
def _set_mesh_compat(mesh):
    """Old-jax stand-in for ``jax.sharding.set_mesh``: a Mesh is already a
    context manager that installs itself as the ambient mesh."""
    with mesh:
        yield mesh


def _shard_map_compat(f, mesh=None, in_specs=None, out_specs=None,
                      check_vma=None, **kw):
    """Old-jax stand-in for ``jax.shard_map`` (``check_vma`` → ``check_rep``)."""
    from jax.experimental.shard_map import shard_map as _sm

    if check_vma is not None:
        kw["check_rep"] = check_vma
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


class _AxisType(enum.Enum):
    """Placeholder for ``jax.sharding.AxisType`` (auto is the 0.4.x default)."""
    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


def _make_mesh_compat(axis_shapes, axis_names, *, axis_types=None, **kw):
    del axis_types  # 0.4.37 meshes have no axis types (all Auto)
    return _make_mesh_compat.native(axis_shapes, axis_names, **kw)


# ------------------------------------------------------------- profiler --
#
# Thin wrappers so instrumented code never has to care whether the pinned
# jax ships the profiler API (CPU-only wheels and very old jax may not):
# every helper degrades to a no-op context manager.


def named_scope(name: str):
    """Profiler scope usable inside traced code (``jax.named_scope``).

    Names the enclosed ops in XLA HLO metadata, so ``jax.profiler`` traces
    and HLO dumps attribute time to the planner stage / kernel dispatch /
    exchange that spent it.  Free when no profiler is attached."""
    try:
        return jax.named_scope(name)
    except Exception:  # pragma: no cover - ancient jax
        return contextlib.nullcontext()


def trace_annotation(name: str):
    """Host-side profiler region (``jax.profiler.TraceAnnotation``)."""
    try:
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # pragma: no cover - profiler-less build
        return contextlib.nullcontext()


def profiler_trace(log_dir):
    """``jax.profiler.trace(log_dir)`` — no-op when ``log_dir`` is falsy
    or the runtime has no profiler (the launchers' ``--profile-dir``)."""
    if not log_dir:
        return contextlib.nullcontext()
    try:
        return jax.profiler.trace(log_dir)
    except Exception:  # pragma: no cover - profiler-less build
        return contextlib.nullcontext()


def install() -> None:
    """Backfill missing jax.sharding / jax names (idempotent)."""
    if not hasattr(jax.sharding, "get_abstract_mesh"):
        jax.sharding.get_abstract_mesh = get_abstract_mesh
    if not hasattr(jax.sharding, "set_mesh"):
        jax.sharding.set_mesh = _set_mesh_compat
    if not hasattr(jax.sharding, "AxisType"):
        jax.sharding.AxisType = _AxisType
    if not hasattr(jax, "shard_map"):
        jax.shard_map = _shard_map_compat
    import inspect

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh_compat.native = jax.make_mesh
        jax.make_mesh = _make_mesh_compat


install()
