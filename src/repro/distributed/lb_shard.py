"""Mesh-sharded distributed LB planner — the balancer as it actually runs.

The paper's balancer is distributed by construction (§III, §V–VI): each
of the P nodes exchanges load only with its stage-1 graph neighbors.  The
single-device ``LBEngine`` realizes the same fixed point with dense
arrays on one chip; this module executes it **across a JAX device mesh**
(``shard_map`` over a 1-D ``"lb"`` axis), with the P balancer nodes
row-sharded over the mesh:

  * **stage 2 (virtual diffusion)** — the hot loop.  Per-node state
    (loads ``x``, frozen ``own`` budget, ``(P, K)`` flow accumulators)
    lives sharded; each sweep's neighbor reads are **ring halo
    exchanges**: the local block rotates around the mesh via
    ``lax.ppermute`` (D-1 hops) and every shard takes exactly the entries
    its neighbor table points at as they pass — O(P/D) working set per
    hop, no global all-gather of the load vector.  Gathers copy values
    exactly, so each sharded sweep is bit-for-bit the reference sweep.
    The loop-control scalars (residual, movement, stall) are completed
    with ``psum``/``pmax``, through the *same* masked chunk body as the
    single-device path (``virtual_lb.sweep_chunk_body`` with collective
    reduction hooks), so the iteration counts agree by construction.
  * **stage 1 (neighbor selection)** — the O(E) reduction that builds the
    node-communication matrix runs on the **edge shards** and is
    completed with a ``psum``; likewise the (N,)-object load reduction
    feeding stage 2.  The handshake protocol itself then runs replicated
    on every shard (its state is O(P²) *bits* and P protocol rounds are
    cheap — the paper's asynchronous protocol is not the scaling
    bottleneck; the per-edge preference assembly is).
  * **stage 3 (object selection)** — the per-phase object↔target comm
    scores (an O(E) segment reduction per direction) run on the edge
    shards and are ``psum``-completed inside
    ``object_selection.select_objects`` (``score_psum_axis``); the
    take-while selection over the scored objects is replicated.

Numerical parity: all data movement (gathers, ppermute, all_gather) is
exact, and control flow is shared with the single-device engine, so the
only divergence source is **floating-point reassociation of psum'd
reductions** (a psum of per-shard partial sums orders additions
differently from one flat segment-sum).  With integer-valued edge bytes
and loads (every stencil workload) the sums are exact in f32 and the
sharded plan matches ``LBEngine.plan_fn`` **bit-for-bit**; otherwise it
is within a few ulps on the flows and virtually always identical in the
final assignments (tests/test_lb_shard.py asserts exact assignment
equality on an 8-virtual-device CPU mesh).

Run on a CPU mesh of 8 virtual devices with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python ...

``diff-comm-sharded`` / ``diff-coord-sharded`` are registered as
strategies (host-eager: they carry their own mesh), so the PIC driver
and the benchmarks can plan with genuinely distributed execution.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P_

from repro.distributed import compat  # noqa: F401  (installs jax.shard_map)
from repro.core import comm_graph, hierarchical
from repro.core import engine as core_engine
from repro.core import neighbor_selection as ns
from repro.core import object_selection as osel
from repro.core import virtual_lb as vlb

AXIS = "lb"


# ------------------------------------------------------- halo primitives --


def _ring_gather_values(vec_local, owner, idx_local, axis: str, D: int):
    """Gather ``vec[global]`` from a row-sharded flat vector via a
    ``ppermute`` ring.

    ``vec_local`` is this shard's (m,) block of the global vector;
    ``owner``/``idx_local`` (any shape, i32) name the shard and in-shard
    position of every wanted entry.  The block rotates D-1 hops around
    the ring; each shard takes the entries it needs as the owning block
    passes.  Pure data movement — every output element is an exact copy.
    """
    me = jax.lax.axis_index(axis)
    out = jnp.zeros(owner.shape, vec_local.dtype)
    buf = vec_local
    safe = jnp.clip(idx_local, 0, vec_local.shape[0] - 1)
    for s in range(D):
        vals = jnp.take(buf, safe, mode="clip")
        out = jnp.where(owner == (me + s) % D, vals, out)
        if s + 1 < D:
            # buf becomes the block of the next shard around the ring
            buf = jax.lax.ppermute(
                buf, axis, [(d, (d - 1) % D) for d in range(D)])
    return out


def _sharded_sweep_fn(axis: str, D: int, rpd: int):
    """One diffusion sweep over the local row block — the sharded twin of
    ``virtual_lb.reference_sweep`` (same math per row; neighbor loads and
    push-back values arrive via the ppermute ring instead of a local
    gather).  Signature matches the ``sweep`` slot of
    ``virtual_lb.sweep_chunk_body``."""

    def sweep(x, own, nbr_idx, nbr_mask, rev, alpha, single_hop):
        safe_nbr = jnp.where(nbr_mask, nbr_idx, 0)
        owner = safe_nbr // rpd
        xn = jnp.where(
            nbr_mask,
            _ring_gather_values(x, owner, safe_nbr % rpd, axis, D),
            x[:, None])
        push = jnp.maximum(alpha * (x[:, None] - xn), 0.0) * nbr_mask
        if single_hop:
            tot = push.sum(axis=1)
            scale = jnp.where(
                tot > 0, jnp.minimum(1.0, own / (tot + 1e-30)), 1.0)
            push = push * scale[:, None]
        # recv[i, k]: what neighbor j pushed toward i — entry
        # [j % rpd, rev] of j's shard of the (P, K) push table
        K = nbr_idx.shape[1]
        flat_local = (safe_nbr % rpd) * K + jnp.where(nbr_mask, rev, 0)
        recv = jnp.where(
            nbr_mask,
            _ring_gather_values(push.reshape(-1), owner, flat_local,
                                axis, D),
            0.0)
        x_new = x - push.sum(axis=1) + recv.sum(axis=1)
        own_new = own - push.sum(axis=1)
        return x_new, own_new, push - recv

    return sweep


def _sharded_residual_fn(nbr_loc, mask_loc, axis: str, D: int, rpd: int,
                         P: int):
    """Sharded twin of ``virtual_lb.neighborhood_residual``: per-row
    deviations are local once the halo ring delivers the neighbor loads;
    the global mean and max complete with psum/pmax."""

    def residual(x):
        safe_nbr = jnp.where(mask_loc, nbr_loc, 0)
        owner = safe_nbr // rpd
        xn = jnp.where(
            mask_loc,
            _ring_gather_values(x, owner, safe_nbr % rpd, axis, D),
            x[:, None])
        dev = vlb.neighborhood_deviation(x, xn, mask_loc)
        gmean = jax.lax.psum(x.sum(), axis) / P + 1e-30
        return jax.lax.pmax((dev / gmean).max(), axis)

    return residual


# ----------------------------------------------------------- plan body --


def _plan_body(loads_sh, assign_sh, loads, assignment, coords,
               e_src, e_dst, e_bytes, *, variant: str, k: int, tol: float,
               max_iters: int, max_rounds: int, single_hop: bool,
               sweep_chunk: int, P: int, D: int, axis: str):
    """Per-shard planning body (runs under ``shard_map``).

    ``loads_sh``/``assign_sh`` are object shards (padded with zero-load
    objects), ``e_*`` are edge shards (padded with the standard
    ``(-1, -1, 0.0)``), ``loads``/``assignment``/``coords`` replicated.
    Returns a replicated ``(assignment, PlanStats)``.
    """
    rpd = P // D

    # -- stage 1: preference assembly on the edge shards (psum) ---------
    valid = e_src >= 0
    src_n = jnp.where(valid, assignment[jnp.where(valid, e_src, 0)], 0)
    dst_n = jnp.where(valid, assignment[jnp.where(valid, e_dst, 0)], 0)
    w = jnp.where(valid, e_bytes, 0.0)
    m_part = jax.ops.segment_sum(
        w, src_n * P + dst_n, num_segments=P * P).reshape(P, P)
    node_comm = jax.lax.psum(m_part, axis)
    node_comm = node_comm + node_comm.T
    if variant == "comm":
        pref = ns.comm_preference(node_comm)
    else:
        cent = osel.centroids(coords, assignment, P)
        pref = ns.coordinate_preference(cent)
    # the handshake itself is replicated compute: O(P^2) bits of protocol
    # state, identical on every shard (deterministic), sliced per shard
    # below for the sharded diffusion loop
    nres = ns.select_neighbors(pref, k=k, max_rounds=max_rounds)
    rev = vlb.reverse_slots(nres.nbr_idx, nres.nbr_mask)

    # -- stage 2: sharded virtual diffusion -----------------------------
    nl_part = jax.ops.segment_sum(loads_sh, assign_sh, num_segments=P)
    nloads = jax.lax.psum(nl_part, axis)                    # (P,)
    me = jax.lax.axis_index(axis)
    sl = me * rpd
    x0 = jax.lax.dynamic_slice(nloads.astype(jnp.float32), (sl,), (rpd,))
    nbr_loc = jax.lax.dynamic_slice(nres.nbr_idx, (sl, 0),
                                    (rpd, nres.nbr_idx.shape[1]))
    mask_loc = jax.lax.dynamic_slice(nres.nbr_mask, (sl, 0),
                                     (rpd, nres.nbr_mask.shape[1]))
    rev_loc = jax.lax.dynamic_slice(rev, (sl, 0), (rpd, rev.shape[1]))

    K = nres.nbr_idx.shape[1]
    alpha = jnp.float32(1.0 / (K + 1.0))        # virtual_balance default
    n_sweeps = max(1, min(int(sweep_chunk), int(max_iters)))
    residual = _sharded_residual_fn(nbr_loc, mask_loc, axis, D, rpd, P)
    chunk_body = vlb.sweep_chunk_body(
        _sharded_sweep_fn(axis, D, rpd), nbr_loc, mask_loc, rev_loc,
        alpha, single_hop, tol, max_iters,
        residual_fn=residual,
        sum_fn=lambda v: jax.lax.psum(v.sum(), axis),
        mean_abs_fn=lambda x2: jax.lax.psum(jnp.abs(x2).sum(), axis) / P)

    def cond(s):
        _, _, _, it, res, stall = s
        return (it < max_iters) & (res > tol) & (stall < 3)

    def body(s):
        return jax.lax.fori_loop(0, n_sweeps, chunk_body, s)

    init = (x0, x0, jnp.zeros((rpd, K), jnp.float32), jnp.int32(0),
            residual(x0), jnp.int32(0))
    x_fin, _own, flows_loc, iters, res_fin, _stall = jax.lax.while_loop(
        cond, body, init)

    # -- stage 3: selection with edge-sharded scores --------------------
    flows = jax.lax.all_gather(flows_loc, axis, tiled=True)   # (P, K)
    problem_loc = comm_graph.LBProblem(
        loads=loads, assignment=assignment, edges_src=e_src,
        edges_dst=e_dst, edges_bytes=e_bytes, num_nodes=P,
        coords=None if variant == "comm" else coords)
    sres = osel.select_objects(
        problem_loc, nres.nbr_idx, nres.nbr_mask, flows,
        metric="comm" if variant == "comm" else "coord",
        score_psum_axis=axis)

    stats = core_engine.PlanStats(
        protocol_rounds=nres.rounds.astype(jnp.int32),
        mean_degree=jnp.mean(nres.degree.astype(jnp.float32)),
        diffusion_iters=iters.astype(jnp.int32),
        diffusion_residual=res_fin.astype(jnp.float32),
        unrealized_flow=jnp.abs(sres.residual).sum().astype(jnp.float32),
    )
    return sres.assignment.astype(jnp.int32), stats


# -------------------------------------------------------------- engine --


def _pad_to(a, n, fill):
    return jnp.pad(a, [(0, n - a.shape[0])] + [(0, 0)] * (a.ndim - 1),
                   constant_values=fill)


class ShardedLBEngine:
    """The three-stage planner executed across a device mesh.

    Mirrors :class:`repro.core.engine.LBEngine`'s interface (``plan_fn``
    traceable, ``plan`` eager, optional ``threads_per_node`` fourth
    stage) with the P balancer nodes sharded over a 1-D mesh.  Requires
    ``P % num_shards == 0``; edge and object arrays are padded to the
    shard multiple internally (standard padding conventions, masked
    everywhere).
    """

    def __init__(
        self,
        *,
        mesh: Optional[Mesh] = None,
        num_shards: Optional[int] = None,
        variant: str = "comm",
        k: int = 4,
        tol: float = 0.02,
        max_iters: int = 512,
        max_rounds: int = 64,
        single_hop: bool = True,
        sweep_chunk: int = 8,
        threads_per_node: Optional[int] = None,
    ):
        if variant not in ("comm", "coord"):
            raise ValueError(f"unknown variant {variant!r}")
        if mesh is None:
            devs = jax.devices()
            if num_shards is not None:
                if not 1 <= num_shards <= len(devs):
                    raise ValueError(
                        f"num_shards={num_shards} outside "
                        f"[1, {len(devs)}] available devices")
                devs = devs[:num_shards]
            mesh = Mesh(np.asarray(devs), (AXIS,))
        elif num_shards is not None:
            raise ValueError("pass either mesh or num_shards, not both")
        if len(mesh.axis_names) != 1:
            raise ValueError("ShardedLBEngine needs a 1-D mesh")
        self.mesh = mesh
        self.axis_name = mesh.axis_names[0]
        self.num_shards = int(np.prod(mesh.devices.shape))
        self.variant = variant
        self.k = int(k)
        self.tol = float(tol)
        self.max_iters = int(max_iters)
        self.max_rounds = int(max_rounds)
        self.single_hop = bool(single_hop)
        self.sweep_chunk = int(sweep_chunk)
        self.threads_per_node = (None if threads_per_node is None
                                 else int(threads_per_node))
        self._jitted = jax.jit(self.plan_fn)
        self._jitted_hier = (jax.jit(self.plan_hier_fn)
                             if self.threads_per_node else None)

    # ------------------------------------------------------ traced path --

    def plan_fn(
        self, problem: comm_graph.LBProblem
    ) -> Tuple[jax.Array, core_engine.PlanStats]:
        """Sharded neighbor selection → diffusion → selection.

        Traceable; one ``shard_map`` call over the engine's mesh.  Output
        matches ``LBEngine.plan_fn`` (see module docstring for the fp
        parity contract)."""
        P = problem.num_nodes
        D = self.num_shards
        ax = self.axis_name
        if P % D:
            raise ValueError(
                f"num_nodes={P} must divide over the {D}-device mesh")
        if self.variant == "coord" and problem.coords is None:
            raise ValueError("coordinate variant needs coords")

        loads = jnp.asarray(problem.loads, jnp.float32)
        assignment = jnp.asarray(problem.assignment, jnp.int32)
        e_src = jnp.asarray(problem.edges_src, jnp.int32)
        e_dst = jnp.asarray(problem.edges_dst, jnp.int32)
        e_bytes = jnp.asarray(problem.edges_bytes, jnp.float32)
        N, E = loads.shape[0], e_src.shape[0]
        Np, Ep = -(-N // D) * D, -(-E // D) * D
        # object pad: zero-load objects on node 0 contribute nothing to
        # the psum'd load reduction; edge pad is the standard convention
        loads_sh = _pad_to(loads, Np, 0.0)
        assign_sh = _pad_to(assignment, Np, 0)
        coords = (jnp.zeros((1, 1), jnp.float32) if problem.coords is None
                  else jnp.asarray(problem.coords, jnp.float32))

        body = functools.partial(
            _plan_body, variant=self.variant, k=self.k, tol=self.tol,
            max_iters=self.max_iters, max_rounds=self.max_rounds,
            single_hop=self.single_hop, sweep_chunk=self.sweep_chunk,
            P=P, D=D, axis=ax)
        fn = jax.shard_map(
            body, mesh=self.mesh,
            in_specs=(P_(ax), P_(ax), P_(), P_(), P_(),
                      P_(ax), P_(ax), P_(ax)),
            out_specs=(P_(), P_()),
            check_vma=False)
        return fn(loads_sh, assign_sh, loads, assignment, coords,
                  _pad_to(e_src, Ep, -1), _pad_to(e_dst, Ep, -1),
                  _pad_to(e_bytes, Ep, 0.0))

    def plan_hier_fn(
        self, problem: comm_graph.LBProblem
    ) -> Tuple[jax.Array, jax.Array, core_engine.PlanStats]:
        """Sharded plan + within-node LPT (replicated — §III.D is
        thread-local refinement).  Same contract as
        ``LBEngine.plan_hier_fn``."""
        if not self.threads_per_node:
            raise ValueError(
                "plan_hier_fn needs threads_per_node configured")
        assignment, stats = self.plan_fn(problem)
        thread = hierarchical.lpt_threads(
            problem.loads, assignment, num_nodes=problem.num_nodes,
            threads_per_node=self.threads_per_node)
        return assignment, thread, stats

    # ---------------------------------------------------- sharded apply --

    def apply(self, owner_new, arrays, *, num_nodes: int,
              capacity: Optional[int] = None,
              on_overflow: str = "strict"):
        """Execute a plan across this engine's mesh: relocate per-item
        payload between the shard-owned slot regions.

        ``owner_new`` is the (n,) post-plan node id per item (e.g.
        ``assignment[chare_id]`` per particle); ``arrays`` are the
        row-sharded payload buffers; ``num_nodes`` is the planner's P
        (must divide the mesh, like :meth:`plan_fn`).  Delegates to
        ``runtime.migrate.migrate_sharded`` — a ``ppermute`` ring
        all-to-all whose concatenated valid prefixes reproduce the
        single-device bucketed layout bit-for-bit.  ``capacity`` is the
        static per-shard slot budget; the ``None`` default sizes it
        from the plan's own max per-shard inflow
        (``runtime.migrate.planned_capacity``).  ``on_overflow`` picks
        the degradation mode for an undersized budget: ``"strict"``
        raises the structured ``CapacityOverflowError``; ``"spill"``
        clamps per-shard inflow, keeps overflow items on their source
        shard and additionally returns the deferred count (see
        ``runtime.migrate.migrate_sharded``)."""
        from repro.runtime import migrate as rt_migrate

        return rt_migrate.migrate_sharded(
            owner_new, arrays, num_nodes=num_nodes, mesh=self.mesh,
            capacity=capacity, on_overflow=on_overflow)

    # -------------------------------------------------------- host path --

    def plan(self, problem: comm_graph.LBProblem):
        """Eager plan with wall-clock timing and the legacy info dict."""
        return core_engine.eager_plan(
            self, problem, f"diff-{self.variant}-sharded",
            extra_info=dict(num_shards=self.num_shards))


# --------------------------------------------------------------- cache --


_SHARDED_CACHE: Dict[tuple, ShardedLBEngine] = {}
_SHARDED_CACHE_MAX = 16   # each entry pins a Mesh + compiled executables


def get_sharded_engine(*, mesh: Optional[Mesh] = None,
                       **cfg) -> ShardedLBEngine:
    """Sharded-engine cache (canonical key, like ``engine.get_engine``).

    Only default-mesh engines are cached — the key includes the current
    device count, so a re-run under different ``XLA_FLAGS`` rebuilds.  An
    explicit ``mesh`` constructs uncached."""
    if mesh is not None:
        return ShardedLBEngine(mesh=mesh, **cfg)
    defaults = dict(num_shards=None, variant="comm", k=4, tol=0.02,
                    max_iters=512, max_rounds=64, single_hop=True,
                    sweep_chunk=8, threads_per_node=None)
    unknown = set(cfg) - set(defaults)
    if unknown:
        raise TypeError(
            f"get_sharded_engine() got unexpected keyword arguments "
            f"{sorted(unknown)}")
    c = {**defaults, **cfg}
    key = (len(jax.devices()),
           None if c["num_shards"] is None else int(c["num_shards"]),
           str(c["variant"]), int(c["k"]),
           float(c["tol"]), int(c["max_iters"]), int(c["max_rounds"]),
           bool(c["single_hop"]), int(c["sweep_chunk"]),
           None if c["threads_per_node"] is None
           else int(c["threads_per_node"]))
    eng = _SHARDED_CACHE.get(key)
    if eng is None:
        eng = _SHARDED_CACHE[key] = ShardedLBEngine(**c)
        while len(_SHARDED_CACHE) > _SHARDED_CACHE_MAX:  # drop oldest
            _SHARDED_CACHE.pop(next(iter(_SHARDED_CACHE)))
    return eng


# ---------------------------------------------------------- strategies --


def best_shards(num_nodes: int) -> int:
    """Largest device count ≤ the available devices dividing ``P`` (the
    row sharding needs ``P % D == 0``; e.g. P=4 on an 8-device mesh runs
    4-way)."""
    D = min(len(jax.devices()), int(num_nodes))
    while num_nodes % D:
        D -= 1
    return D


def _sharded_plan_fn(variant: str):
    def plan_fn(problem, **params):
        params.setdefault("num_shards", best_shards(problem.num_nodes))
        return get_sharded_engine(variant=variant, **params)._jitted(problem)
    return plan_fn


# jittable=False: the sharded planner carries its own mesh and is meant
# to be dispatched eagerly (the replay layers' scanned paths keep using
# the single-device engine; the two agree — that is the parity test)
core_engine.register(core_engine.Strategy(
    "diff-comm-sharded", _sharded_plan_fn("comm"), jittable=False,
    variant="comm"))
core_engine.register(core_engine.Strategy(
    "diff-coord-sharded", _sharded_plan_fn("coord"), jittable=False,
    variant="coord"))
