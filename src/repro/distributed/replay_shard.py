"""Sharded replay runtime: the whole step loop in one ``shard_map``.

The paper's headline results are end-to-end distributed: diffusion
planning *and* object exchange run on-node across the machine, and the
cost that matters is the coupled step loop, not the planner in isolation
(Demiralp et al., PAPERS.md).  The planner (``ShardedLBEngine``) and the
exchange (``migrate_sharded``) were already mesh-resident — but only as
standalone calls; every replayed trajectory still planned and migrated
single-device.  This module closes that gap: the **entire** simulation
step — workload evolve / PIC particle push, trigger evaluation,
three-stage diffusion planning, and the executed payload exchange — runs
inside a single ``shard_map`` over the 1-D ``"lb"`` mesh, with one
``jax.lax.scan`` carrying the per-shard state (payload slabs, owner
slabs, trigger state) across steps.  Nothing round-trips through the
host or through replicated staging between steps: plan → manifest →
apply compose on the same mesh and axis.

Two entries:

  * :func:`run_series_sharded` — the mesh twin of
    ``sim.simulator.run_series``'s scanned path.  The P balancer nodes
    are row-sharded; each step's stage-2 diffusion runs as ``ppermute``
    ring halo exchanges over O(P/D) rows per shard (the planner's hot
    loop — same sweeps as ``distributed.lb_shard``).
  * :func:`run_pic_sharded` — the mesh twin of the scanned PIC driver
    (``PICConfig(sharded_replay=True)``).  The particle slabs are
    row-sharded: push, handoff counting and the per-chare histogram run
    on the local slab (partial counts completed with exact integer
    ``psum``), and every fired rebalance executes
    ``runtime.migrate.ring_exchange`` — the ``ppermute`` ring
    all-to-all, whose per-shard placement is the shared sort-free
    counting-scatter op (``kernels.migrate.bucket_ranks``) — to
    re-bucket the slabs into PE-owned slot regions *inside the scan*.

Parity contract (the reason this file exists as a *replay* subsystem and
not just a loop around the standalone pieces): both entries are
**bit-for-bit** equal to the single-device scanned paths — identical
per-step metrics, trigger fire steps, migration counts, final
assignments and (PIC) final particle order.  The mechanism:

  * all data movement (``ppermute`` rings, ``all_gather``) copies values
    exactly;
  * every *reduction that feeds a decision or a metric* is evaluated
    with the **same expression graph on the same full-size operands** as
    the single-device path — either on replicated values, or on locally
    exact per-shard values gathered back to full size first.  Float
    ``psum`` of partial sums reassociates additions and is a few-ulp
    hazard (the documented contract of ``lb_shard``'s planner-only
    entry), so the replay's loop-control scalars gather-then-reduce
    instead; the PIC histogram / handoff partial sums are
    integer-valued, where ``psum`` is exact.

Trigger completion: the trigger's ``load_stats`` are computed on the
replicated (C,)/(N,) loads on every shard — identical inputs, identical
expression graph — so all shards fire on identical steps by
construction; the PIC loads themselves are ``psum``-completed exact
integer counts.

Capacity rule (PIC): the scan's payload slabs are static at
``capacity`` slots per shard.  The default is the worst case
``n_particles`` (always safe); production runs size it down with
``PICConfig.replay_capacity`` — the post-hoc overflow check raises
``ValueError`` (payload is never dropped silently), and the eager
``migrate_sharded`` entry can plan the tight per-plan bound via
``runtime.migrate.planned_capacity``.

Run on a CPU mesh of 8 virtual devices with::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 python ...
"""
from __future__ import annotations

import functools
import time
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P_

from repro.distributed import compat  # noqa: F401  (installs jax.shard_map)
from repro.distributed import lb_shard
from repro.core import comm_graph, metrics
from repro.core import engine as core_engine
from repro.core import neighbor_selection as ns
from repro.core import object_selection as osel
from repro.core import virtual_lb as vlb
from repro.obs import telemetry as obs_telemetry
from repro.runtime import migrate as rt_migrate
from repro.runtime import resilience as rt_resilience
from repro.runtime import triggers as rt_triggers

#: one mesh axis shared by planning halo rings and the payload exchange —
#: the composition contract of the issue ("plan → manifest → apply
#: composes without re-gathering")
AXIS = lb_shard.AXIS

#: static planner configuration a diff-* strategy can carry into the
#: sharded replay (mirrors ``core.engine.LBEngine`` defaults)
_ENGINE_DEFAULTS = dict(k=4, tol=0.02, max_iters=512, max_rounds=64,
                        single_hop=True, sweep_chunk=8)


def _engine_params(strat: core_engine.Strategy,
                   strategy_kwargs: Optional[Dict]) -> Dict:
    """Planner configuration for the sharded twin of ``strat``.

    Merges the strategy's registered defaults under the caller kwargs
    exactly as ``Strategy.bind`` would, then validates against the
    static knobs the sharded planner supports."""
    merged = strat.params(**(strategy_kwargs or {}))
    unknown = sorted(set(merged) - set(_ENGINE_DEFAULTS))
    if unknown:
        raise ValueError(
            f"sharded replay cannot honor strategy kwargs {unknown}; "
            f"supported: {sorted(_ENGINE_DEFAULTS)}")
    out = {**_ENGINE_DEFAULTS, **merged}
    return {k: (bool(v) if k == "single_hop" else
                float(v) if k == "tol" else int(v))
            for k, v in out.items()}


def _resolve_resilience(faults, guard, D: int, strategy: str, trig):
    """Normalize the resilience knobs of a replay entry.

    Empty schedules vanish (``faults=None`` downstream selects the exact
    pre-resilience trace — the bit-parity contract's static elision) and
    ``guard`` defaults to on exactly when a schedule is active.  An
    active schedule needs a live LB strategy (a dead shard's objects can
    only be evacuated by a fired plan) and may only reference shards
    that exist on the mesh."""
    if faults is not None:
        if not isinstance(faults, rt_resilience.FaultSchedule):
            raise TypeError(
                "faults must be a runtime.resilience.FaultSchedule")
        if faults.empty:
            faults = None
    guard = (faults is not None) if guard is None else bool(guard)
    if faults is not None:
        if strategy == "none" or trig.never:
            raise ValueError(
                "fault injection needs an active LB strategy/trigger — "
                "with planning disabled a dead shard's objects can never "
                "be evacuated")
        if faults.max_shard() >= D:
            raise ValueError(
                f"fault schedule references shard {faults.max_shard()} "
                f"but the mesh has only {D} shards")
    return faults, guard


def _series_setup(initial, evolve, strategy: str,
                  strategy_kwargs: Optional[Dict], trigger, lb_every: int,
                  mesh: Optional[Mesh], num_shards: Optional[int],
                  faults, guard):
    """Shared validation/configuration of the series replay entries."""
    strategy_kwargs = strategy_kwargs or {}
    strat = core_engine.get_strategy(strategy)
    if not strat.jittable:
        raise ValueError(
            f"strategy {strategy!r} is not jittable; the sharded replay "
            "needs a traceable plan_fn (diff-* / none)")
    if strategy != "none" and strat.variant is None:
        raise ValueError(
            f"strategy {strategy!r} has no diffusion variant; the "
            "sharded replay can only distribute diff-* strategies")
    if not getattr(evolve, "jittable", False):
        raise ValueError(
            "the sharded replay needs a scan-safe evolve (scenarios from "
            "sim/scenarios.py are)")
    trig = rt_triggers.resolve_for_strategy(trigger, lb_every=lb_every,
                                            strategy=strategy)
    P = initial.num_nodes
    mesh = _resolve_mesh(mesh, num_shards, (P,))
    D = int(np.prod(mesh.devices.shape))
    faults, guard = _resolve_resilience(faults, guard, D, strategy, trig)
    eng = None
    if strategy != "none":
        eng = dict(_engine_params(strat, strategy_kwargs),
                   variant=strat.variant)
    return strategy_kwargs, trig, P, mesh, faults, guard, eng


def _resolve_mesh(mesh: Optional[Mesh], num_shards: Optional[int],
                  must_divide: Tuple[int, ...]) -> Mesh:
    """A 1-D ``"lb"`` mesh whose size divides every extent in
    ``must_divide`` (auto-shrinks to the largest viable device count)."""
    if mesh is not None:
        if num_shards is not None:
            raise ValueError("pass either mesh or num_shards, not both")
        if len(mesh.axis_names) != 1:
            raise ValueError("sharded replay needs a 1-D mesh")
        D = int(np.prod(mesh.devices.shape))
        bad = [m for m in must_divide if m % D]
        if bad:
            raise ValueError(
                f"extents {bad} do not divide over the {D}-device mesh")
        return mesh
    devs = jax.devices()
    if num_shards is not None:
        if not 1 <= num_shards <= len(devs):
            raise ValueError(
                f"num_shards={num_shards} outside [1, {len(devs)}] "
                "available devices")
        bad = [m for m in must_divide if m % num_shards]
        if bad:
            raise ValueError(
                f"extents {bad} do not divide over num_shards="
                f"{num_shards}")
        D = num_shards
    else:
        D = min(len(devs), min(must_divide))
        while any(m % D for m in must_divide):
            D -= 1
    return Mesh(np.asarray(devs[:D]), (AXIS,))


def resolve_mesh(mesh: Optional[Mesh], num_shards: Optional[int],
                 must_divide: Tuple[int, ...]) -> Mesh:
    """Public :func:`_resolve_mesh`: the one place a 1-D ``"lb"`` replay
    mesh is derived from a ``mesh``/``num_shards`` spec.  The serving
    replay (``serve/replay.py``) shares it so its sharded KV exchanges
    ride the same mesh-selection rules as the sim/PIC replays."""
    return _resolve_mesh(mesh, num_shards, must_divide)


# ------------------------------------------------- sharded planning step --


def _plan_step_sharded(problem: comm_graph.LBProblem, *, variant: str,
                       k: int, tol: float, max_iters: int, max_rounds: int,
                       single_hop: bool, sweep_chunk: int, P: int, D: int,
                       axis: str, alive=None, speed=None):
    """One three-stage plan inside the replay's ``shard_map`` body.

    The mesh twin of ``LBEngine.plan_fn`` under the replay's parity
    contract: stage 2 — the hot loop — runs genuinely sharded (O(P/D)
    rows per shard, neighbor loads and push-backs via the ``ppermute``
    halo ring of ``lb_shard``), while the O(E) stage-1/3 reductions and
    the handshake run replicated so every reduction keeps the
    single-device expression graph.  The loop-control scalars
    (residual, movement, stall) **gather-then-reduce** — the ring moved
    exact copies, so evaluating the single-device reduction on the
    gathered (P,) vector keeps every early-exit decision bitwise equal
    to ``LBEngine.plan_fn`` (unlike the planner-only
    ``ShardedLBEngine``, whose ``psum`` completion is documented as a
    few-ulp contract).  Traceable; called under ``lax.cond`` inside the
    replay scan.

    ``alive`` / ``speed`` are the optional (P,) node health mask and
    speed vector of the resilient replay paths (the mesh twin of
    ``LBEngine.plan_health_fn``): dead nodes' objects are re-homed onto
    alive communication partners before planning, slowed nodes' loads
    are scaled by the reciprocal speed, and the stage-1 preference
    rows/columns of dead nodes are zeroed so no flow or object ever
    targets them.  ``alive=None`` (the default) adds nothing to the
    trace."""
    if alive is not None:
        problem = rt_resilience.degrade_problem(problem, alive, speed)
    # -- stage 1: preference assembly + handshake (replicated) ----------
    with compat.named_scope("lb-plan/stage1-neighbors"):
        if variant == "comm":
            node_comm = comm_graph.node_comm_matrix(problem)
            pref = ns.comm_preference(node_comm)
        else:
            cent = osel.centroids(problem.coords, problem.assignment, P)
            pref = ns.coordinate_preference(cent)
        if alive is not None:
            pref = rt_resilience.mask_preference(pref, alive)
        nres = ns.select_neighbors(pref, k=k, max_rounds=max_rounds)
        rev = vlb.reverse_slots(nres.nbr_idx, nres.nbr_mask)

    # -- stage 2: sharded virtual diffusion (the hot loop) --------------
    nloads = comm_graph.node_loads(problem)
    rpd = P // D
    me = jax.lax.axis_index(axis)
    sl = me * rpd
    K = nres.nbr_idx.shape[1]
    x0 = jax.lax.dynamic_slice(nloads.astype(jnp.float32), (sl,), (rpd,))
    nbr_loc = jax.lax.dynamic_slice(nres.nbr_idx, (sl, 0), (rpd, K))
    mask_loc = jax.lax.dynamic_slice(nres.nbr_mask, (sl, 0), (rpd, K))
    rev_loc = jax.lax.dynamic_slice(rev, (sl, 0), (rpd, K))
    alpha = jnp.float32(1.0 / (K + 1.0))        # virtual_balance default
    n_sweeps = max(1, min(int(sweep_chunk), int(max_iters)))

    def gather(v):
        return jax.lax.all_gather(v, axis, tiled=True)

    # exact loop control: per-row sweep state is bitwise the reference
    # sweep (gathers copy exactly), so reducing the *gathered* full
    # vector with the single-device expressions reproduces
    # virtual_balance's early-exit/stall decisions bit-for-bit
    def residual_fn(x_loc):
        return vlb.neighborhood_residual(gather(x_loc), nres.nbr_idx,
                                         nres.nbr_mask)

    chunk_body = vlb.sweep_chunk_body(
        lb_shard._sharded_sweep_fn(axis, D, rpd), nbr_loc, mask_loc,
        rev_loc, alpha, single_hop, tol, max_iters,
        residual_fn=residual_fn,
        sum_fn=lambda v: gather(v).sum(),
        mean_abs_fn=lambda x2: jnp.abs(gather(x2)).mean())

    def cond(s):
        _, _, _, it, res, stall = s
        return (it < max_iters) & (res > tol) & (stall < 3)

    def body(s):
        return jax.lax.fori_loop(0, n_sweeps, chunk_body, s)

    init = (x0, x0, jnp.zeros((rpd, K), jnp.float32), jnp.int32(0),
            residual_fn(x0), jnp.int32(0))
    with compat.named_scope("lb-plan/stage2-diffusion"):
        _x_fin, _own, flows_loc, iters, res_fin, _stall = \
            jax.lax.while_loop(cond, body, init)

    # -- stage 3: selection on the gathered flows (replicated) ----------
    with compat.named_scope("lb-plan/stage3-objects"):
        flows = gather(flows_loc)                            # (P, K) exact
        sres = osel.select_objects(
            problem, nres.nbr_idx, nres.nbr_mask, flows,
            metric="comm" if variant == "comm" else "coord")

    stats = core_engine.PlanStats(
        protocol_rounds=nres.rounds.astype(jnp.int32),
        mean_degree=jnp.mean(nres.degree.astype(jnp.float32)),
        diffusion_iters=iters.astype(jnp.int32),
        diffusion_residual=res_fin.astype(jnp.float32),
        unrealized_flow=jnp.abs(sres.residual).sum().astype(jnp.float32),
    )
    return sres.assignment.astype(jnp.int32), stats


# ----------------------------------------------------- series replay ----


_SERIES_CACHE: Dict[tuple, object] = {}
_PIC_CACHE: Dict[tuple, object] = {}
_CACHE_MAX = 16   # each entry pins a Mesh + a compiled whole-replay scan


def _mesh_key(mesh: Mesh) -> tuple:
    return tuple(d.id for d in mesh.devices.flat)


def _cached(cache: Dict, key: tuple, build):
    fn = cache.get(key)
    if fn is None:
        fn = cache[key] = build()
        while len(cache) > _CACHE_MAX:          # drop oldest entry
            cache.pop(next(iter(cache)))
    return fn


def _make_series_step(mesh: Mesh, evolve, strategy: str,
                      eng_params: Optional[Dict], trig,
                      threads_per_node: Optional[int], P: int,
                      faults, guard: bool, tel=None):
    """Shared per-step body of the series replay scans.

    Returns ``(step, track)`` where ``track`` says whether the step
    emits the extra ``plan_rejected`` output.  With ``faults is None``
    and ``guard`` off the emitted trace is **exactly** the
    pre-resilience step (every ``if`` below is static), preserving the
    bit-for-bit parity contract; the resilient variant adds
    health-masked trigger stats/planning, forced fires on health
    transitions or stranded objects, and the ``validate_plan`` rollback
    guardrail.

    ``tel`` (an enabled ``obs.telemetry.TelemetryConfig``) appends a
    replicated :class:`~repro.obs.telemetry.TelemetryState` to the scan
    carry and records one StepRecord per step — every recorded quantity
    (loads, fire bit, sweeps, moved counts) is already replicated under
    the parity contract, so the ring stays replicated for free.
    ``tel=None`` follows the same static-elision rule as ``faults``."""
    from repro.sim import simulator as sim   # local: sim imports us lazily

    D = int(np.prod(mesh.devices.shape))
    ax = mesh.axis_names[0]
    do_lb_at_all = strategy != "none" and not trig.never
    resilient = faults is not None
    track = resilient or bool(guard)
    tkind = obs_telemetry.trigger_kind(trig) if tel else 0
    plan = None
    if do_lb_at_all:
        eng_params = dict(eng_params)
        plan = functools.partial(_plan_step_sharded, P=P, D=D, axis=ax,
                                 variant=eng_params.pop("variant"),
                                 **eng_params)

    def step(carry, t):
        if tel:
            problem, tstate, obs_state = carry
        else:
            problem, tstate = carry
        problem = evolve(problem, t)
        prev = problem.assignment
        rejected = jnp.float32(0.0)
        health_changed = jnp.float32(0.0)
        if do_lb_at_all:
            if resilient:
                alive_n, speed_n = faults.node_health(t, P, D)
                mx, av, tot = rt_triggers.load_stats_masked(
                    problem.loads, problem.assignment, P, alive_n,
                    speed_n)
            else:
                alive_n = speed_n = None
                mx, av, tot = rt_triggers.load_stats(
                    problem.loads, problem.assignment, problem.num_nodes)
            do, tstate = trig.decide(tstate, t, mx, av, tot)
            if resilient:
                # a health transition or an object stranded on a dead
                # node must fire a rebalance regardless of the policy
                stranded = (~jnp.take(
                    alive_n, jnp.clip(prev, 0, P - 1))).any()
                health_changed = faults.changed_at(
                    t, D).astype(jnp.float32)
                do = do | faults.changed_at(t, D) | stranded
                planned, stats = jax.lax.cond(
                    do,
                    lambda op: plan(op[0], alive=op[1], speed=op[2]),
                    lambda op: (op[0].assignment.astype(jnp.int32),
                                core_engine.zero_stats()),
                    (problem, alive_n, speed_n),
                )
            else:
                planned, stats = jax.lax.cond(
                    do,
                    plan,
                    lambda p: (p.assignment.astype(jnp.int32),
                               core_engine.zero_stats()),
                    problem,
                )
            if track:
                # guardrail: adopt only validated plans; otherwise keep
                # the last-good assignment (prev is valid by induction)
                ok = rt_resilience.validate_plan(
                    planned, problem.loads, num_nodes=P, alive=alive_n)
                adopt = do & ok
                rejected = (do & ~ok).astype(jnp.float32)
                new_assignment = jnp.where(adopt, planned, prev)
            else:
                adopt = do
                new_assignment = planned
            delta = new_assignment != prev
            moved = jnp.where(
                adopt, jnp.mean(delta.astype(jnp.float32)), 0.0)
            migrated_load = jnp.where(
                adopt,
                jnp.where(delta,
                          jnp.asarray(problem.loads, jnp.float32),
                          0.0).sum(),
                0.0)
            tstate = trig.observe(tstate, migrated_load, do)
            fired = do.astype(jnp.float32)
            sweeps = stats.diffusion_iters.astype(jnp.float32)
            moved_n = jnp.where(adopt, delta.sum().astype(jnp.float32),
                                0.0)
            problem = problem.with_assignment(new_assignment)
        else:
            moved = jnp.float32(0.0)
            migrated_load = jnp.float32(0.0)
            fired = jnp.float32(0.0)
            sweeps = jnp.float32(0.0)
            moved_n = jnp.float32(0.0)
        m = metrics.evaluate_device(problem)
        if threads_per_node:
            tma = sim._thread_max_avg(problem.loads, problem.assignment,
                                      problem.num_nodes, threads_per_node)
        else:
            tma = jnp.float32(0.0)
        ys = (m.max_avg_load, m.ext_int_comm, moved, tma, fired,
              m.max_load, migrated_load)
        if track:
            ys = ys + (rejected,)
        if tel:
            obs_state = obs_telemetry.record(
                obs_state, tel, t=t,
                node_loads=obs_telemetry.node_loads(
                    problem.loads, problem.assignment, P),
                fired=fired, trigger_kind=tkind, plan_rejected=rejected,
                sweeps=sweeps, moved_items=moved_n,
                moved_bytes=migrated_load,
                health_changed=health_changed)
            return (problem, tstate, obs_state), ys
        return (problem, tstate), ys

    return step, track


def _series_runner(mesh: Mesh, evolve, steps: int, strategy: str,
                   eng_params: Optional[Dict], trig,
                   threads_per_node: Optional[int], P: int,
                   has_coords: bool, faults=None, guard: bool = False,
                   tel=None):
    """Compile-once ``shard_map`` wrapping the whole series replay."""
    step, track = _make_series_step(mesh, evolve, strategy, eng_params,
                                    trig, threads_per_node, P, faults,
                                    guard, tel)
    nys = 8 if track else 7
    nobs = 3 if tel else 0   # TelemetryState leaves (count, records, loads)

    def body(loads, assignment, e_src, e_dst, e_bytes, coords):
        problem = comm_graph.LBProblem(
            loads=loads, assignment=assignment, edges_src=e_src,
            edges_dst=e_dst, edges_bytes=e_bytes, num_nodes=P,
            coords=coords if has_coords else None)
        init = (problem, trig.init_state())
        if tel:
            init = init + (obs_telemetry.init_state(tel, P),)
        carry, ys = jax.lax.scan(step, init, jnp.arange(steps))
        out = (carry[0].assignment.astype(jnp.int32),)
        if tel:
            out = out + tuple(carry[2])   # replicated ring — exits as-is
        return out + ys

    # the problem arrays enter replicated: per-shard state materializes
    # *inside* the step (dynamic_slice by axis index for the diffusion
    # rows), so the scan carry never re-gathers between steps
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P_(),) * 6,
        out_specs=(P_(),) * (1 + nobs + nys),
        check_vma=False)
    return jax.jit(fn)


def _series_chunk_runner(mesh: Mesh, evolve, chunk: int, strategy: str,
                         eng_params: Optional[Dict], trig,
                         threads_per_node: Optional[int], P: int,
                         has_coords: bool, faults=None,
                         guard: bool = False):
    """Chunked series runner: scan ``chunk`` steps from an explicit carry.

    The checkpoint/restart entry (``runtime.resilience.
    run_series_checkpointed``) drives the replay through this runner —
    same per-step program as :func:`_series_runner` (the step closure is
    shared), but the scan carry (problem arrays + trigger-state leaves)
    crosses the call boundary so the supervisor can snapshot and restore
    it.  Scanning ``t0 + arange(chunk)`` instead of ``arange(steps)``
    changes nothing numerically — chunked trajectories are bit-for-bit
    the one-shot scan."""
    step, track = _make_series_step(mesh, evolve, strategy, eng_params,
                                    trig, threads_per_node, P, faults,
                                    guard)
    nys = 8 if track else 7

    def body(loads, assignment, e_src, e_dst, e_bytes, coords, t0,
             last_lb, armed, history, hist_len, last_moved):
        problem = comm_graph.LBProblem(
            loads=loads, assignment=assignment, edges_src=e_src,
            edges_dst=e_dst, edges_bytes=e_bytes, num_nodes=P,
            coords=coords if has_coords else None)
        tstate = rt_triggers.TriggerState(last_lb, armed, history,
                                          hist_len, last_moved)
        (pfin, ts), ys = jax.lax.scan(
            step, (problem, tstate),
            jnp.asarray(t0, jnp.int32) + jnp.arange(chunk))
        carry_out = (pfin.loads, pfin.assignment.astype(jnp.int32),
                     pfin.edges_src, pfin.edges_dst, pfin.edges_bytes,
                     pfin.coords if has_coords else coords,
                     ts.last_lb, ts.armed, ts.history, ts.hist_len,
                     ts.last_moved)
        return carry_out + ys

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P_(),) * 12,
        out_specs=(P_(),) * (11 + nys),
        check_vma=False)
    return jax.jit(fn)


def run_series_sharded(
    initial: comm_graph.LBProblem,
    evolve,
    *,
    steps: int,
    lb_every: int,
    strategy: str = "diff-comm",
    strategy_kwargs: Optional[Dict] = None,
    trigger=None,
    mesh: Optional[Mesh] = None,
    num_shards: Optional[int] = None,
    threads_per_node: Optional[int] = None,
    faults=None,
    guard: Optional[bool] = None,
    telemetry=None,
):
    """Mesh-sharded ``run_series``: the whole replay in one ``shard_map``.

    The drop-in distributed twin of ``sim.simulator.run_series``'s
    scanned path: one compiled ``shard_map`` over the 1-D ``"lb"`` mesh
    contains the full ``lax.scan`` over ``steps`` — evolve, trigger
    decision (``runtime.triggers``, identical fire steps on every
    shard), ``lax.cond``-gated **sharded** three-stage planning (stage-2
    diffusion as ``ppermute`` ring halo exchanges over O(P/D) rows per
    shard), and the per-step metrics — with zero host transfers inside
    the loop and **bit-for-bit** the single-device scanned replay's
    ``SeriesResult`` (see the module docstring for the parity
    mechanism; ``tests/test_replay_shard.py`` asserts it on an
    8-virtual-device CPU mesh).

    Args mirror ``run_series`` (the strategy must be a jittable diff-*
    registration — its ``Strategy.variant`` configures the sharded
    planner; host baselines cannot be distributed).  ``mesh`` /
    ``num_shards`` pick the device mesh: the default uses the largest
    available device count dividing ``initial.num_nodes`` (shrinking to
    1 device degenerates to the single-device graph).  ``trigger``
    resolves exactly as in ``run_series`` (strategy-registered policy,
    then the fixed ``lb_every`` cadence).

    Resilience (``runtime.resilience``): ``faults`` takes a
    ``FaultSchedule`` whose die/slow/recover events are honored inside
    the scan — trigger stats and planning see the health mask, health
    transitions force a rebalance, and a dead shard's objects are
    re-homed onto alive communication partners.  ``guard`` (default: on
    whenever ``faults`` is set) runs every fired plan through
    ``validate_plan`` and rolls back to the last-good assignment on
    rejection; either flag adds the per-step ``plan_rejected`` series to
    the result.  An empty/None schedule with ``guard`` unset adds
    *nothing* to the trace — the bit-for-bit parity contract above is
    untouched.

    ``telemetry`` (an ``obs.telemetry.TelemetryConfig`` / level string)
    threads the scan-carried StepRecord ring through the shard_map —
    replicated, since every recorded quantity already is under the
    parity contract — and attaches the snapshot to the result.  Off /
    absent is bit-for-bit free, exactly as in ``run_series``.
    """
    from repro.sim import simulator as sim   # local: sim imports us lazily

    strategy_kwargs, trig, P, mesh, faults, guard, eng = _series_setup(
        initial, evolve, strategy, strategy_kwargs, trigger, lb_every,
        mesh, num_shards, faults, guard)
    tel = obs_telemetry.resolve(telemetry)
    tel = tel if tel.enabled else None

    key = (_mesh_key(mesh), evolve, int(steps), int(lb_every), strategy,
           tuple(sorted(strategy_kwargs.items())), trig,
           None if threads_per_node is None else int(threads_per_node),
           initial.coords is not None, P, faults, guard, tel)
    runner = _cached(
        _SERIES_CACHE, key,
        lambda: _series_runner(mesh, evolve, int(steps), strategy,
                               None if eng is None else dict(eng), trig,
                               threads_per_node, P,
                               initial.coords is not None, faults, guard,
                               tel))

    prob = sim._canonical(initial)
    coords = (prob.coords if prob.coords is not None
              else jnp.zeros((prob.num_objects, 1), jnp.float32))
    t_start = time.perf_counter()
    out = runner(prob.loads, prob.assignment, prob.edges_src,
                 prob.edges_dst, prob.edges_bytes, coords)
    if tel:
        obs_state = obs_telemetry.TelemetryState(*out[1:4])
        final_assignment, ys = out[0], out[4:]
    else:
        obs_state = None
        final_assignment, ys = out[0], out[1:]
    track = (faults is not None) or guard
    ys = jax.device_get(ys)
    if track:
        ma, ei, mig, tma, fired, mxl, migl, rej = ys
    else:
        ma, ei, mig, tma, fired, mxl, migl = ys
        rej = None
    final_assignment = np.asarray(jax.device_get(final_assignment),
                                  np.int32)
    wall = time.perf_counter() - t_start
    return sim.SeriesResult(
        np.asarray(ma, np.float64), np.asarray(ei, np.float64),
        np.asarray(mig, np.float64), wall, scanned=True, wall_seconds=wall,
        thread_max_avg=(np.asarray(tma, np.float64) if threads_per_node
                        else None),
        lb_fired=np.asarray(fired, np.float64),
        max_load=np.asarray(mxl, np.float64),
        migrated_load=np.asarray(migl, np.float64),
        final_assignment=final_assignment,
        plan_rejected=(None if rej is None
                       else np.asarray(rej, np.float64)),
        telemetry=(obs_telemetry.snapshot(obs_state, tel)
                   if tel else None))


class _PreparedSeries:
    """Chunk-driving handle over the sharded series replay.

    Built by :func:`prepare_series` and consumed by
    ``runtime.resilience.run_series_checkpointed``: the supervisor owns
    the scan carry between chunks (so it can snapshot/restore it) and
    calls :meth:`run_chunk` per chunk; :meth:`package` turns the final
    carry + concatenated per-step outputs into the same ``SeriesResult``
    ``run_series_sharded`` returns.  The per-step program is shared with
    the one-shot runner, so chunked trajectories are bit-for-bit the
    uninterrupted scan."""

    def __init__(self, *, mesh, evolve, lb_every, strategy,
                 strategy_kwargs, trig, threads_per_node, P, has_coords,
                 faults, guard, prob, coords):
        self.mesh = mesh
        self.evolve = evolve
        self.lb_every = int(lb_every)
        self.strategy = strategy
        self.strategy_kwargs = dict(strategy_kwargs)
        self.trig = trig
        self.threads_per_node = threads_per_node
        self.P = int(P)
        self.has_coords = bool(has_coords)
        self.faults = faults
        self.guard = bool(guard)
        self.track = (faults is not None) or bool(guard)
        self._prob = prob
        self._coords = coords
        strat = core_engine.get_strategy(strategy)
        self._eng = (dict(_engine_params(strat, self.strategy_kwargs),
                          variant=strat.variant)
                     if strategy != "none" else None)

    def initial_carry(self):
        """The scan carry at t=0: 6 problem arrays + 5 trigger leaves."""
        p = self._prob
        return (p.loads, p.assignment, p.edges_src, p.edges_dst,
                p.edges_bytes, self._coords) + tuple(self.trig.init_state())

    def _runner(self, chunk: int):
        key = ("chunk", _mesh_key(self.mesh), self.evolve, int(chunk),
               self.lb_every, self.strategy,
               tuple(sorted(self.strategy_kwargs.items())), self.trig,
               None if self.threads_per_node is None
               else int(self.threads_per_node),
               self.has_coords, self.P, self.faults, self.guard)
        return _cached(
            _SERIES_CACHE, key,
            lambda: _series_chunk_runner(
                self.mesh, self.evolve, int(chunk), self.strategy,
                None if self._eng is None else dict(self._eng), self.trig,
                self.threads_per_node, self.P, self.has_coords,
                self.faults, self.guard))

    def run_chunk(self, carry, t_start: int, chunk: int):
        """Advance ``chunk`` steps from ``carry``; returns
        ``(new_carry, per_step_outputs)``.  ``carry`` may be host
        snapshots (restored) or live device arrays."""
        carry = tuple(jnp.asarray(a) for a in carry)
        out = self._runner(int(chunk))(
            *carry[:6], jnp.asarray(int(t_start), jnp.int32), *carry[6:])
        return out[:11], out[11:]

    def package(self, carry, ys, *, wall_seconds: float):
        """Final carry + concatenated chunk outputs → ``SeriesResult``."""
        from repro.sim import simulator as sim

        if self.track:
            ma, ei, mig, tma, fired, mxl, migl, rej = ys
        else:
            ma, ei, mig, tma, fired, mxl, migl = ys
            rej = None
        final_assignment = np.asarray(jax.device_get(carry[1]), np.int32)
        return sim.SeriesResult(
            np.asarray(ma, np.float64), np.asarray(ei, np.float64),
            np.asarray(mig, np.float64), wall_seconds, scanned=True,
            wall_seconds=wall_seconds,
            thread_max_avg=(np.asarray(tma, np.float64)
                            if self.threads_per_node else None),
            lb_fired=np.asarray(fired, np.float64),
            max_load=np.asarray(mxl, np.float64),
            migrated_load=np.asarray(migl, np.float64),
            final_assignment=final_assignment,
            plan_rejected=(None if rej is None
                           else np.asarray(rej, np.float64)))


def prepare_series(
    initial: comm_graph.LBProblem,
    evolve,
    *,
    steps: int,
    lb_every: int,
    strategy: str = "diff-comm",
    strategy_kwargs: Optional[Dict] = None,
    trigger=None,
    mesh: Optional[Mesh] = None,
    num_shards: Optional[int] = None,
    threads_per_node: Optional[int] = None,
    faults=None,
    guard: Optional[bool] = None,
) -> _PreparedSeries:
    """Validate + stage a series replay for external chunk driving.

    Same arguments and validation as :func:`run_series_sharded` (the
    ``steps`` total is accepted for symmetry; the chunk driver decides
    the actual schedule), but instead of running the scan it returns a
    :class:`_PreparedSeries` whose ``initial_carry`` / ``run_chunk`` /
    ``package`` methods let a supervisor — in practice
    ``runtime.resilience.run_series_checkpointed`` — own the carry
    between chunks for checkpoint/restart."""
    from repro.sim import simulator as sim   # local: sim imports us lazily

    if int(steps) < 1:
        raise ValueError("steps must be >= 1")
    strategy_kwargs, trig, P, mesh, faults, guard, _eng = _series_setup(
        initial, evolve, strategy, strategy_kwargs, trigger, lb_every,
        mesh, num_shards, faults, guard)
    prob = sim._canonical(initial)
    coords = (prob.coords if prob.coords is not None
              else jnp.zeros((prob.num_objects, 1), jnp.float32))
    return _PreparedSeries(
        mesh=mesh, evolve=evolve, lb_every=lb_every, strategy=strategy,
        strategy_kwargs=strategy_kwargs, trig=trig,
        threads_per_node=threads_per_node, P=P,
        has_coords=initial.coords is not None, faults=faults, guard=guard,
        prob=prob, coords=coords)


# -------------------------------------------------------- PIC replay ----


def _pic_runner(mesh: Mesh, L: int, cx: int, cy: int, num_pes: int,
                k: int, vy0: float, lb_every: int, strategy: str,
                kw_items: tuple, bpp: float, use_kernel: Optional[bool],
                steps: int, capacity: int,
                threads_per_node: Optional[int], trig,
                faults=None, on_overflow: str = "strict", tel=None):
    """Compile-once ``shard_map`` wrapping the whole PIC replay.

    Per-shard carry: the (capacity,) particle payload slabs (x, y, vx,
    vy, q, chare id, particle id) with a live-prefix count, plus the
    replicated chare→PE assignment and trigger state.  Each step pushes
    the local slab, ``psum``-completes the handoff counts and the
    per-chare histogram (integer-valued — exact), decides the trigger on
    the replicated loads, plans (sharded over the PE rows when
    ``num_pes`` divides the mesh, else replicated — the chare problem is
    O(C) tiny either way), and executes a fired plan as the masked
    ``ring_exchange`` re-bucketing the slabs into PE-owned slot regions.

    Resilience: an active ``faults`` schedule masks trigger stats and
    planning with the node health at ``t``, forces a fire on every
    health transition or stranded chare, and gates plan adoption through
    ``validate_plan`` (strict mode additionally rejects plans whose
    per-shard inflow would overflow the static slabs — payload is never
    dropped).  ``on_overflow="spill"`` swaps the exchange for the
    admission-clamped spill ring: overflow particles stay on their
    source shard (their desired owner is preserved, so the next fired
    rebalance retries them) and the per-step ``deferred`` count is
    emitted.  ``faults=None`` + strict mode is the exact pre-resilience
    trace.
    """
    from repro.kernels.histogram.ops import histogram
    from repro.kernels.pic_push.ops import pic_push
    from repro.pic import chares as ch
    from repro.pic.grid import alternating_grid
    from repro.core import hierarchical

    D = int(np.prod(mesh.devices.shape))
    ax = mesh.axis_names[0]
    n_chares = cx * cy
    grid_q = jnp.asarray(alternating_grid(L))
    lb_on = strategy != "none" and not trig.never
    strat = core_engine.get_strategy(strategy) if lb_on else None
    resilient = faults is not None
    spill = on_overflow == "spill"
    track = resilient or spill
    tkind = obs_telemetry.trigger_kind(trig) if tel else 0
    # the chare-level plan: sharded over the PE rows when the mesh
    # divides them (plan → manifest → apply on ONE mesh), else the
    # replicated single-device graph — bit-for-bit either way
    plan_sharded = lb_on and strat.variant is not None and num_pes % D == 0
    if plan_sharded:
        eng = _engine_params(strat, dict(kw_items))
        plan = functools.partial(_plan_step_sharded, P=num_pes, D=D,
                                 axis=ax, variant=strat.variant, **eng)
    elif lb_on and resilient:
        # replicated health-masked planning: the engine method is the
        # single-device twin of the masked sharded plan
        plan = core_engine.get_engine(
            variant=strat.variant,
            **_engine_params(strat, dict(kw_items))).plan_health_fn
    elif lb_on:
        plan = strat.bind(**dict(kw_items))
    else:
        plan = None

    def step(carry, t):
        if tel:
            (x, y, vx, vy, q, chare_id, assignment, perm, count, tstate,
             obs_state) = carry
        else:
            (x, y, vx, vy, q, chare_id, assignment, perm, count,
             tstate) = carry
        xn, yn, vxn, vyn = pic_push(grid_q, x, y, vx, vy, q, L=L,
                                    use_kernel=use_kernel)
        new_chare = ch.chare_of_device(xn, yn, L, cx, cy)
        live = jnp.arange(capacity, dtype=jnp.int32) < count
        # particle handoffs: chare changed → bytes move; PE boundary →
        # ext.  Partial counts are integers — psum completion is exact,
        # so the f32 byte totals match the single-device path bitwise.
        moved = (new_chare != chare_id) & live
        src_pe = assignment[chare_id]
        dst_pe = assignment[new_chare]
        ext = jax.lax.psum(
            (moved & (src_pe != dst_pe)).sum(), ax).astype(jnp.float32) \
            * bpp
        intra = jax.lax.psum(
            (moved & (src_pe == dst_pe)).sum(), ax).astype(jnp.float32) \
            * bpp

        loads = jax.lax.psum(
            histogram(new_chare, live.astype(xn.dtype), C=n_chares,
                      use_kernel=use_kernel), ax)
        pe_loads = jax.ops.segment_sum(loads, assignment,
                                       num_segments=num_pes)
        pe_max = pe_loads.max()
        ma = pe_max / (pe_loads.mean() + 1e-30)
        rejected = jnp.float32(0.0)
        deferred_n = jnp.int32(0)
        health_changed = jnp.float32(0.0)
        sweeps = jnp.float32(0.0)
        moved_n = jnp.int32(0)

        if lb_on:
            if resilient:
                alive_n, speed_n = faults.node_health(t, num_pes, D)
                mx, av, tot = rt_triggers.load_stats_masked(
                    loads, assignment, num_pes, alive_n, speed_n)
            else:
                alive_n = speed_n = None
                mx, av, tot = rt_triggers.load_stats(loads, assignment,
                                                     num_pes)
            do, tstate = trig.decide(tstate, t, mx, av, tot)
            if resilient:
                # evacuate dead PEs now: fire on every health transition
                # and while any chare is still owned by a dead PE
                stranded = (~jnp.take(
                    alive_n, jnp.clip(assignment, 0, num_pes - 1))).any()
                health_changed = faults.changed_at(
                    t, D).astype(jnp.float32)
                do = do | faults.changed_at(t, D) | stranded

            def do_plan(args):
                loads_, assignment_ = args
                problem = ch.build_problem(
                    loads_, assignment_, L=L, cx=cx, cy=cy,
                    num_pes=num_pes, k=k, vy0=vy0, lb_period=lb_every,
                    bytes_per_particle=bpp)
                if resilient:
                    a2, stats = plan(problem, alive=alive_n,
                                     speed=speed_n)
                else:
                    a2, stats = plan(problem)
                return a2, stats.diffusion_iters.astype(jnp.float32)

            planned, sweeps = jax.lax.cond(
                do, do_plan,
                lambda a: (a[1].astype(jnp.int32), jnp.float32(0.0)),
                (loads, assignment))
            if resilient:
                # guardrail: only adopt validated plans — owners alive
                # and in range and, in strict mode, per-shard inflow
                # within the static slab budget (a plan that does not
                # fit would drop payload; spill clamps instead)
                ok = rt_resilience.validate_plan(
                    planned, loads, num_nodes=num_pes, alive=alive_n)
                if not spill:
                    pe_new = jax.ops.segment_sum(
                        loads, jnp.clip(planned, 0, num_pes - 1),
                        num_segments=num_pes)
                    per_shard = pe_new.reshape(D, num_pes // D).sum(1)
                    ok = ok & (per_shard <= capacity).all()
                adopt = do & ok
                rejected = (do & ~ok).astype(jnp.float32)
                new_assignment = jnp.where(adopt, planned, assignment)
            else:
                adopt = do
                new_assignment = planned
            delta = new_assignment != assignment
            migf = jnp.where(
                adopt, jnp.mean(delta.astype(jnp.float32)), 0.0)

            # execute the plan inside the scan: the masked ppermute ring
            # all-to-all re-buckets the live slab prefixes into PE-owned
            # slot regions — concatenated prefixes reproduce the
            # single-device bucketed layout bit-for-bit
            owner_old = jnp.take(assignment, new_chare)
            owner_new = jnp.take(new_assignment, new_chare)

            if spill:
                def do_move(args):
                    _owner, outs, count_me, dfr = rt_migrate.ring_exchange(
                        owner_new, args, num_nodes=num_pes, D=D,
                        capacity=capacity, axis=ax, count_loc=count,
                        mode="spill")
                    want = jax.lax.psum(
                        ((owner_old != owner_new) & live)
                        .astype(jnp.int32).sum(), ax)
                    return outs, count_me, want - dfr, dfr

                (xn, yn, vxn, vyn, q, new_chare, perm), count, moved_n, \
                    deferred_n = jax.lax.cond(
                        adopt, do_move,
                        lambda args: (args, count, jnp.int32(0),
                                      jnp.int32(0)),
                        (xn, yn, vxn, vyn, q, new_chare, perm))
            else:
                def do_move(args):
                    _owner, outs, count_me = rt_migrate.ring_exchange(
                        owner_new, args, num_nodes=num_pes, D=D,
                        capacity=capacity, axis=ax, count_loc=count)
                    moved_ct = jax.lax.psum(
                        ((owner_old != owner_new) & live)
                        .astype(jnp.int32).sum(), ax)
                    return outs, count_me, moved_ct

                (xn, yn, vxn, vyn, q, new_chare, perm), count, moved_n = \
                    jax.lax.cond(
                        adopt, do_move,
                        lambda args: (args, count, jnp.int32(0)),
                        (xn, yn, vxn, vyn, q, new_chare, perm))
            tstate = trig.observe(tstate, moved_n.astype(jnp.float32), do)
            migb = moved_n.astype(jnp.float32) * bpp
            fired = do.astype(jnp.float32)
            assignment = new_assignment
        else:
            migf = jnp.float32(0.0)
            migb = jnp.float32(0.0)
            fired = jnp.float32(0.0)

        if threads_per_node:
            thr = hierarchical.lpt_threads(
                loads, assignment, num_nodes=num_pes,
                threads_per_node=threads_per_node)
            tl = hierarchical.thread_loads(
                loads, assignment, thr, num_nodes=num_pes,
                threads_per_node=threads_per_node)
            tma = (tl.max() / (tl.mean() + 1e-30)).astype(jnp.float32)
        else:
            tma = jnp.float32(0.0)

        ys = (ma, pe_max, ext, intra, migf, migb, tma, fired,
              count[None])
        if track:
            ys = ys + (rejected, deferred_n.astype(jnp.float32))
        new_carry = (xn, yn, vxn, vyn, q, new_chare, assignment, perm,
                     count, tstate)
        if tel:
            obs_state = obs_telemetry.record(
                obs_state, tel, t=t,
                node_loads=jax.ops.segment_sum(loads, assignment,
                                               num_segments=num_pes),
                fired=fired, trigger_kind=tkind, plan_rejected=rejected,
                sweeps=sweeps,
                moved_items=moved_n.astype(jnp.float32), moved_bytes=migb,
                deferred=deferred_n.astype(jnp.float32),
                health_changed=health_changed)
            new_carry = new_carry + (obs_state,)
        return new_carry, ys

    def body(x, y, vx, vy, q, chare_id, perm, count0, assignment):
        carry = (x, y, vx, vy, q, chare_id, assignment, perm,
                 count0[0], trig.init_state())
        if tel:
            carry = carry + (obs_telemetry.init_state(tel, num_pes),)
        carry, ys = jax.lax.scan(step, carry, jnp.arange(steps))
        (x, y, perm, count) = (carry[0], carry[1], carry[7], carry[8])
        out = ys + (x, y, perm, count[None])
        if tel:
            out = out + tuple(carry[10])   # replicated ring — exits as-is
        return out

    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P_(ax),) * 8 + (P_(),),
        out_specs=((P_(),) * 8               # per-step replicated metrics
                   + (P_(None, ax),)         # per-step per-shard counts
                   + ((P_(),) * 2 if track else ())  # rejected, deferred
                   + (P_(ax),) * 4           # final slabs + counts
                   + ((P_(),) * 3 if tel else ())),  # TelemetryState
        check_vma=False)
    return jax.jit(fn)


def _pad_slabs(arrays, n: int, D: int, capacity: int):
    """Distribute (n,) buffers into (D*capacity,) per-shard slabs with
    n/D live items at each shard's prefix."""
    per = n // D
    out = []
    for a in arrays:
        a = np.asarray(a)
        slab = np.zeros((D, capacity), a.dtype)
        slab[:, :per] = a.reshape(D, per)
        out.append(jnp.asarray(slab.reshape(-1)))
    return out


def run_pic_sharded(cfg, cost) -> "PICResult":  # noqa: F821
    """Mesh-sharded scanned PIC driver (``PICConfig(sharded_replay=True)``).

    The whole run — push, handoff/byte accounting, trigger, planning and
    the executed particle exchange — is one compiled ``shard_map`` over
    the 1-D ``"lb"`` mesh with the particle slabs row-sharded; the only
    host contact is staging the initial slabs in and the final slabs +
    per-step metric series out.  Bit-for-bit the single-device scanned
    driver's ``PICResult`` (including ``final_x/final_y`` restored to
    particle-id order).  See the module docstring for the capacity rule;
    a ``replay_capacity`` below the largest per-shard bucket total
    raises ``ValueError`` after the run (payload is never dropped
    silently).

    ``PICConfig.faults`` injects a ``runtime.resilience.FaultSchedule``
    into the scan (health-masked trigger/planning, forced evacuation
    fires, guarded plan adoption) and ``PICConfig.on_overflow="spill"``
    swaps the exchange for the admission-clamped spill ring (overflow
    particles stay on their source shard and drain on later fires);
    either adds the ``plan_rejected`` / ``deferred`` per-step series to
    the result.  Defaults leave the trace bit-for-bit unchanged."""
    from repro.kernels.histogram.ops import histogram
    from repro.pic import chares as ch
    from repro.pic import driver as pic_driver
    from repro.pic.particles import initialize

    if cfg.strategy != "none":
        strat = core_engine.get_strategy(cfg.strategy)
        if not strat.jittable:
            raise ValueError(
                f"strategy {cfg.strategy!r} is not jittable; the sharded "
                "PIC replay needs a traceable plan_fn (diff-* / none)")
    # the exchange's ring ownership mapping needs num_pes % D == 0 (shard
    # d owns PEs [d*rpd, (d+1)*rpd)) and the particle slabs need n % D
    n = cfg.n_particles
    mesh = _resolve_mesh(None, cfg.replay_shards, (n, cfg.num_pes))
    D = int(np.prod(mesh.devices.shape))
    capacity = n if cfg.replay_capacity is None else int(cfg.replay_capacity)
    if capacity < n // D:
        raise ValueError(
            f"replay_capacity={capacity} cannot even hold the initial "
            f"even split of {n} particles over {D} shards "
            f"({n // D} per shard); raise replay_capacity "
            f"(n_particles={n} is always safe)")
    on_overflow = getattr(cfg, "on_overflow", "strict")
    if on_overflow not in ("strict", "spill"):
        raise ValueError(f"unknown on_overflow mode {on_overflow!r}")

    p = initialize(cfg.mode, cfg.L, n, k=cfg.k, vy0=cfg.vy0,
                   rho=cfg.rho, seed=cfg.seed)
    chare_id = np.asarray(ch.chare_of(p.x, p.y, cfg.L, cfg.cx, cfg.cy))
    assignment = jnp.asarray(
        ch.initial_mapping(cfg.cx, cfg.cy, cfg.num_pes, cfg.mapping),
        jnp.int32)
    n_chares = cfg.cx * cfg.cy

    kw_items = tuple(sorted((cfg.strategy_kwargs or {}).items()))
    trig = pic_driver._resolve_trigger(cfg)
    lb_on = cfg.strategy != "none" and not trig.never
    faults, _ = _resolve_resilience(getattr(cfg, "faults", None), None, D,
                                    cfg.strategy, trig)
    track = (faults is not None) or on_overflow == "spill"
    tel = obs_telemetry.resolve(getattr(cfg, "telemetry", None))
    tel = tel if tel.enabled else None

    # LB planning cost for the CostModel — measured once on the initial
    # snapshot, exactly as the single-device scanned path charges it
    lb_est = 0.0
    if lb_on:
        loads0 = histogram(jnp.asarray(chare_id), jnp.ones(n), C=n_chares,
                           use_kernel=cfg.use_kernel)
        problem0 = ch.build_problem(
            loads0, assignment, L=cfg.L, cx=cfg.cx, cy=cfg.cy,
            num_pes=cfg.num_pes, k=cfg.k, vy0=cfg.vy0,
            lb_period=cfg.lb_every,
            bytes_per_particle=cfg.bytes_per_particle)
        strat = core_engine.get_strategy(cfg.strategy)
        strat.run(problem0, **dict(kw_items))          # warm the compile
        lb_est = strat.run(problem0, **dict(kw_items)).info["plan_seconds"]

    runner = _cached(
        _PIC_CACHE,
        (_mesh_key(mesh), cfg.L, cfg.cx, cfg.cy, cfg.num_pes, cfg.k,
         cfg.vy0, cfg.lb_every, cfg.strategy, kw_items,
         cfg.bytes_per_particle, cfg.use_kernel, cfg.steps, capacity,
         cfg.threads_per_node, trig, faults, on_overflow, tel),
        lambda: _pic_runner(mesh, cfg.L, cfg.cx, cfg.cy, cfg.num_pes,
                            cfg.k, cfg.vy0, cfg.lb_every, cfg.strategy,
                            kw_items, cfg.bytes_per_particle,
                            cfg.use_kernel, cfg.steps, capacity,
                            cfg.threads_per_node, trig, faults,
                            on_overflow, tel))

    slabs = _pad_slabs(
        (p.x, p.y, p.vx, p.vy, p.q, chare_id,
         np.arange(n, dtype=np.int32)), n, D, capacity)
    count0 = jnp.full((D,), n // D, jnp.int32)

    t_start = time.perf_counter()
    out = runner(*slabs, count0, assignment)
    out = jax.device_get(out)
    wall = time.perf_counter() - t_start

    if tel:
        obs_state = obs_telemetry.TelemetryState(*out[-3:])
        out = out[:-3]
    else:
        obs_state = None
    if track:
        (ma, pe_max, ext_b, int_b, mig, mig_bytes, tma, fired, counts_ts,
         rej, deferred, x_out, y_out, perm_out, counts) = out
    else:
        (ma, pe_max, ext_b, int_b, mig, mig_bytes, tma, fired, counts_ts,
         x_out, y_out, perm_out, counts) = out
        rej = deferred = None
    counts_ts = np.asarray(counts_ts)              # (T, D) needed slots
    # spill mode clamps inflow inside the exchange (counts <= capacity
    # by construction, overflow surfaces as the deferred series); strict
    # mode keeps the fail-loud contract
    if on_overflow != "spill" and (counts_ts > capacity).any():
        raise ValueError(
            f"replay_capacity={capacity} overflowed (largest shard "
            f"needed {int(counts_ts.max())} slots at some step); the "
            "exchange would have dropped payload — raise replay_capacity "
            f"(n_particles={n} is always safe) or use "
            "on_overflow='spill'")

    ma, pe_max, ext_b, int_b, mig, mig_bytes, tma, fired = (
        np.asarray(a, np.float64)
        for a in (ma, pe_max, ext_b, int_b, mig, mig_bytes, tma, fired))
    lb_steps = fired > 0
    lb_s_t = np.where(lb_steps, lb_est, 0.0)
    step_s = (
        pe_max * cost.t_particle
        + (ext_b + mig_bytes) * cost.t_byte
        + np.array([cost.lb_seconds(s_, cfg.strategy, cfg.num_pes)
                    for s_ in lb_s_t]) / pic_driver._lb_amort(cfg, trig)
    )
    # concatenate the per-shard valid prefixes (the single-device slot
    # layout), then undo the executed exchanges back to particle-id order
    counts = np.asarray(counts).reshape(-1)
    xs = np.concatenate([np.asarray(x_out)[d * capacity:
                                           d * capacity + counts[d]]
                         for d in range(D)])
    ys_ = np.concatenate([np.asarray(y_out)[d * capacity:
                                            d * capacity + counts[d]]
                          for d in range(D)])
    perm = np.concatenate([np.asarray(perm_out)[d * capacity:
                                                d * capacity + counts[d]]
                           for d in range(D)])
    fx, fy = np.empty_like(xs), np.empty_like(ys_)
    fx[perm], fy[perm] = xs, ys_
    return pic_driver.PICResult(
        ma, ext_b, int_b, mig, mig_bytes,
        float(lb_est * lb_steps.sum()), step_s, fx, fy,
        scanned=True, wall_seconds=wall,
        thread_max_avg=(tma if cfg.threads_per_node else None),
        lb_steps=fired,
        plan_rejected=(None if rej is None
                       else np.asarray(rej, np.float64)),
        deferred=(None if deferred is None
                  else np.asarray(deferred, np.float64)),
        telemetry=(obs_telemetry.snapshot(obs_state, tel)
                   if tel else None))
