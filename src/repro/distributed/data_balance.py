"""DP-rank data balancing for variable-length batches (DESIGN.md §3.2).

Sequences of different lengths make per-rank step work uneven (attention is
O(len²), FFN O(len)).  Sequences-to-rank assignment is another instance of
the paper's problem: sequences in one document stream share prefix caches /
loader state (comm edges between consecutive shards), moving a shard has a
real prefetch-warmup cost, and loads (token/flop counts) persist across
steps within an epoch.

``pack_balanced`` is the per-batch greedy packer (length² cost LPT) used
inside one global batch; ``balance_shards`` in train/data.py is the
cross-step diffusion rebalancer this module re-exports.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np

from repro.train.data import balance_shards, rebalance_global, shard_problem


def seq_cost(lengths: np.ndarray, *, attn_weight: float = 1.0,
             ffn_weight: float = 1.0, seq_ref: int = 4096) -> np.ndarray:
    """Per-sequence step cost model: ffn·len + attn·len²/seq_ref."""
    ln = np.asarray(lengths, np.float64)
    return ffn_weight * ln + attn_weight * ln * ln / seq_ref


def pack_balanced(lengths: np.ndarray, num_ranks: int) -> np.ndarray:
    """LPT assignment of sequences → DP ranks for one batch.  Returns the
    (N,) rank index per sequence."""
    cost = seq_cost(lengths)
    order = np.argsort(-cost)
    load = np.zeros(num_ranks)
    out = np.zeros(len(lengths), np.int32)
    for i in order:
        r = int(np.argmin(load))
        out[i] = r
        load[r] += cost[i]
    return out


def pack_stats(lengths: np.ndarray, assignment: np.ndarray,
               num_ranks: int) -> Dict[str, float]:
    cost = seq_cost(lengths)
    load = np.bincount(assignment, weights=cost, minlength=num_ranks)
    return dict(max_avg=float(load.max() / (load.mean() + 1e-30)),
                max=float(load.max()), avg=float(load.mean()))


__all__ = ["balance_shards", "rebalance_global", "shard_problem",
           "seq_cost", "pack_balanced", "pack_stats"]
