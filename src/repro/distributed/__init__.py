"""Distributed runtime: sharding rules, the paper's balancer wired into MoE
expert placement / data sharding / serving, and gradient compression."""
