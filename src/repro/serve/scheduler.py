"""Cross-replica request scheduling with the paper's balancer
(DESIGN.md §3.3).

Serving replicas are nodes; *sessions* (multi-turn decode requests) are the
persistently interacting objects: a session's KV cache lives on its replica
(migration = cache transfer or re-prefill — expensive), sessions sharing a
prompt prefix form comm edges (prefix-cache hits are only possible when the
sharers are colocated), and session loads (active decode tokens/s) persist
over many scheduling periods.

``DiffusionScheduler.rebalance`` runs the three-stage balancer over the
current (session → replica) map; the greedy baseline re-places sessions by
load only, breaking prefix-sharing groups — the serving analogue of the
paper's GreedyRefine-vs-Diffusion comparison (measured in
benchmarks/serve_sched.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import api as core_api
from repro.core import comm_graph, metrics


@dataclasses.dataclass
class Session:
    uid: int
    replica: int
    tokens_per_s: float             # decode load (EMA)
    prefix_group: int = -1          # sessions sharing a prompt prefix
    kv_bytes: float = 1.0           # migration cost proxy


class DiffusionScheduler:
    def __init__(self, num_replicas: int, *, k: int = 4):
        self.num_replicas = num_replicas
        self.k = k
        self.sessions: Dict[int, Session] = {}

    def add(self, s: Session) -> None:
        self.sessions[s.uid] = s

    def remove(self, uid: int) -> None:
        self.sessions.pop(uid, None)

    def place_new(self, s: Session) -> int:
        """Admission: prefer the replica already holding s's prefix group
        (prefix-cache hit), else the least-loaded replica."""
        peers = [t for t in self.sessions.values()
                 if t.prefix_group == s.prefix_group and s.prefix_group >= 0]
        if peers:
            s.replica = peers[0].replica
        else:
            load = self.replica_loads()
            s.replica = int(np.argmin(load))
        self.add(s)
        return s.replica

    def replica_loads(self) -> np.ndarray:
        load = np.zeros(self.num_replicas)
        for s in self.sessions.values():
            load[s.replica] += s.tokens_per_s
        return load

    def _problem(self) -> Tuple[comm_graph.LBProblem, List[int]]:
        uids = sorted(self.sessions)
        idx = {u: i for i, u in enumerate(uids)}
        loads = np.array([self.sessions[u].tokens_per_s for u in uids])
        assign = np.array([self.sessions[u].replica for u in uids], np.int32)
        # comm edges: same prefix group ⇒ pairwise edges weighted by the
        # smaller session's load (shared-prefix reuse volume)
        groups: Dict[int, List[int]] = {}
        for u in uids:
            g = self.sessions[u].prefix_group
            if g >= 0:
                groups.setdefault(g, []).append(idx[u])
        edges, w = [], []
        for members in groups.values():
            for a in range(len(members)):
                for b in range(a + 1, len(members)):
                    i, j = members[a], members[b]
                    edges.append((i, j))
                    w.append(min(loads[i], loads[j]) + 1e-3)
        if not edges:
            n = len(uids)
            edges = [(i, (i + 1) % n) for i in range(n)]
            w = [1e-3] * n
        return comm_graph.make_problem(
            loads=np.maximum(loads, 1e-3),
            assignment=assign,
            edges=np.array(edges, np.int32),
            edge_bytes=np.array(w, np.float32),
            num_nodes=self.num_replicas,
        ), uids

    def rebalance(self, *, strategy: str = "diff-comm") -> Dict:
        if len(self.sessions) < 2:
            return dict(skipped=True)
        prob, uids = self._problem()
        if strategy == "greedy":
            new = _greedy(prob)
            info: Dict = dict(strategy="greedy")
        else:
            plan = core_api.diffusion_lb(
                prob, k=min(self.k, self.num_replicas - 1), variant="comm")
            new, info = plan.assignment, plan.info
        moved_kv = 0.0
        for u, r in zip(uids, new):
            if self.sessions[u].replica != int(r):
                moved_kv += self.sessions[u].kv_bytes
            self.sessions[u].replica = int(r)
        import jax.numpy as jnp
        info.update(metrics.evaluate(prob, jnp.asarray(np.asarray(new))))
        info["moved_kv_bytes"] = moved_kv
        return info


def _greedy(prob: comm_graph.LBProblem) -> np.ndarray:
    import numpy as np
    loads = np.asarray(prob.loads)
    order = np.argsort(-loads)
    rl = np.zeros(prob.num_nodes)
    out = np.zeros(len(loads), np.int32)
    for i in order:
        r = int(np.argmin(rl))
        out[i] = r
        rl[r] += loads[i]
    return out
