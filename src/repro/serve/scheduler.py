"""Cross-replica session scheduling on the device-resident runtime.

Serving replicas are nodes; *sessions* (multi-turn decode requests) are the
persistently interacting objects: a session's KV cache lives on its replica
(migration = cache transfer or re-prefill — expensive), sessions sharing a
prompt prefix form comm edges (prefix-cache hits are only possible when the
sharers are colocated), and session loads (active decode tokens/s) persist
over many scheduling periods.

The data plane is a :class:`SessionFleet` — fixed-shape ``(S,)`` device
arrays of load EMA, prefix-group id, replica owner and resident KV bytes —
and the prefix-sharing comm graph is built on device by
``core.comm_graph.prefix_group_edges`` (a segment-min leader election plus
per-member star edges: O(S) segment ops instead of the legacy O(n²) host
pair loop).  Planning goes through the Strategy registry
(``core.engine.get_strategy``), so the scheduler prices every registered
policy — diffusion variants, trigger-wrapped variants and the host
baselines — identically to the simulator and PIC replay layers.

A rebalance is **executed**, not modeled: the placement delta becomes a
real exchange through ``runtime.migrate`` — the fleet slabs are re-bucketed
into replica-contiguous slot order by the counting-scatter manifest, moved
KV bytes are read off ``Manifest.moved_sum`` (per-session sizes), and an
optional per-replica slot budget degrades gracefully through
``migrate.spill_owner`` (overflow sessions stay put and retry at the next
fire).  ``maybe_rebalance`` adds the control plane: a
``runtime.triggers`` policy (predictive by default) decides *when* a
rebalance amortizes the KV bytes the previous one actually moved.

The scan-compiled continuous-batching twin of this facade is
``serve/replay.py`` (``run_serve_replay``), and the fleet-scale policy
comparison lives in ``benchmarks/serve_bench.py`` (serve-bench/v1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm_graph, engine, metrics
from repro.runtime import migrate as rt_migrate
from repro.runtime import triggers as rt_triggers
from repro.runtime.cost import RuntimeCostModel

#: shared load floor: node loads *and* edge weights are priced from the
#: same clamped values (the legacy path clamped only the node loads)
LOAD_FLOOR = 1e-3


@dataclasses.dataclass
class Session:
    uid: int
    replica: int
    tokens_per_s: float             # decode load (EMA)
    prefix_group: int = -1          # sessions sharing a prompt prefix
    kv_bytes: float = 1.0           # resident KV cache size (exchange cost)


class SessionFleet(NamedTuple):
    """Device-resident session store: one fixed-shape slab per field.

    ``uid < 0`` marks a free slot.  ``group`` ids are canonical slot-range
    ids in ``[0, S)`` with ``-1`` for ungrouped — what
    ``comm_graph.prefix_group_edges`` needs for its segment ops."""

    uid: jax.Array        # (S,) i32 — session id, -1 = free slot
    load: jax.Array       # (S,) f32 — decode tokens/s EMA
    group: jax.Array      # (S,) i32 — canonical prefix-group id, -1 = none
    replica: jax.Array    # (S,) i32 — owning replica
    kv: jax.Array         # (S,) f32 — resident KV bytes

    @property
    def active(self) -> jax.Array:
        return self.uid >= 0


def fleet_loads(fleet: SessionFleet) -> jax.Array:
    """(S,) f32 clamped planning loads: live sessions floored at
    ``LOAD_FLOOR``; free slots carry exactly the floor (they must exist in
    the fixed-shape problem but should not attract the balancer)."""
    return jnp.where(fleet.active,
                     jnp.maximum(jnp.asarray(fleet.load, jnp.float32),
                                 jnp.float32(LOAD_FLOOR)),
                     jnp.float32(LOAD_FLOOR))


def fleet_problem(fleet: SessionFleet, num_replicas: int,
                  *, coords=None) -> comm_graph.LBProblem:
    """Device-side ``LBProblem`` over the fleet: N = S slots, P = replicas.

    Edge weights and node loads both come from :func:`fleet_loads` — the
    consistent-clamping contract — and the prefix-sharing graph is the
    star + connectivity-ring construction of
    ``comm_graph.prefix_group_edges``.  Pure jnp, so the serving replay
    rebuilds it every step inside its scan."""
    loads = fleet_loads(fleet)
    es, ed, ew = comm_graph.prefix_group_edges(
        fleet.group, loads, fleet.active, ring_eps=LOAD_FLOOR)
    return comm_graph.LBProblem(
        loads=loads,
        assignment=jnp.asarray(fleet.replica, jnp.int32),
        edges_src=es, edges_dst=ed, edges_bytes=ew,
        num_nodes=int(num_replicas), coords=coords)


def prefix_locality(fleet: SessionFleet, assignment=None) -> jax.Array:
    """f32 scalar in [0, 1]: fraction of prefix-sharing edge weight kept
    intra-replica — the prefix-cache-hit opportunity the placement
    preserves (1.0 when every group is colocated).  Uses only the star
    half of the edge construction (the connectivity ring is load-floor
    noise, not sharing)."""
    a = jnp.asarray(fleet.replica if assignment is None else assignment,
                    jnp.int32)
    S = int(a.shape[0])
    es, ed, ew = comm_graph.prefix_group_edges(
        fleet.group, fleet_loads(fleet), fleet.active, ring_eps=LOAD_FLOOR)
    es, ed, ew = es[:S], ed[:S], ew[:S]        # star edges only
    valid = es >= 0
    w = jnp.where(valid, ew, 0.0)
    intra = jnp.where(
        valid & (a[jnp.clip(es, 0, S - 1)] == a[jnp.clip(ed, 0, S - 1)]),
        ew, 0.0)
    return intra.sum() / jnp.maximum(w.sum(), jnp.float32(1e-30))


def _strategy_params(strat: engine.Strategy, num_replicas: int,
                     k: int) -> Dict:
    """Per-strategy planning params: diffusion variants get the clamped
    neighbor count; host baselines take no params."""
    if strat.variant is None:
        return {}
    return dict(k=max(1, min(int(k), int(num_replicas) - 1)))


class DiffusionScheduler:
    """Session → replica placement with executed KV migration.

    The legacy facade API is preserved (``add`` / ``remove`` /
    ``place_new`` / ``replica_loads`` / ``rebalance`` and the ``sessions``
    mapping view), but the store is a fixed-shape slot mirror of
    :class:`SessionFleet` (host numpy, auto-growing by doubling) and every
    plan + exchange runs on device."""

    def __init__(self, num_replicas: int, *, k: int = 4,
                 capacity: int = 64):
        self.num_replicas = int(num_replicas)
        self.k = int(k)
        S = max(8, int(capacity))
        self._uid = np.full(S, -1, np.int32)
        self._load = np.zeros(S, np.float32)
        self._group = np.full(S, -1, np.int64)   # raw (caller) group ids
        self._replica = np.zeros(S, np.int32)
        self._kv = np.zeros(S, np.float32)
        self._slot: Dict[int, int] = {}
        self._trig = None
        self._tstate = None
        self._tstep = 0

    # ------------------------------------------------------------ store --

    @property
    def capacity(self) -> int:
        return int(self._uid.shape[0])

    def __len__(self) -> int:
        return len(self._slot)

    @property
    def sessions(self) -> Dict[int, Session]:
        """Materialized ``{uid: Session}`` view of the fleet slabs."""
        return {
            int(self._uid[i]): Session(
                uid=int(self._uid[i]), replica=int(self._replica[i]),
                tokens_per_s=float(self._load[i]),
                prefix_group=int(self._group[i]),
                kv_bytes=float(self._kv[i]))
            for i in self._slot.values()
        }

    def _grow(self) -> None:
        S = self.capacity
        for name in ("_uid", "_load", "_group", "_replica", "_kv"):
            a = getattr(self, name)
            pad = np.full(S, -1 if name in ("_uid", "_group") else 0,
                          a.dtype)
            setattr(self, name, np.concatenate([a, pad]))

    def add(self, s: Session) -> None:
        if s.uid in self._slot:
            i = self._slot[s.uid]
        else:
            free = np.flatnonzero(self._uid < 0)
            if not len(free):
                self._grow()
                free = np.flatnonzero(self._uid < 0)
            i = int(free[0])
            self._slot[s.uid] = i
        self._uid[i] = s.uid
        self._load[i] = s.tokens_per_s
        self._group[i] = s.prefix_group
        self._replica[i] = s.replica
        self._kv[i] = s.kv_bytes

    def remove(self, uid: int) -> None:
        i = self._slot.pop(uid, None)
        if i is not None:
            self._uid[i] = -1
            self._load[i] = 0.0
            self._group[i] = -1
            self._kv[i] = 0.0

    def place_new(self, s: Session) -> int:
        """Admission: prefer the **least-loaded** replica among those
        already holding s's prefix group (prefix-cache hit without piling
        onto the hottest peer), else the least-loaded replica overall."""
        load = self.replica_loads()
        if s.prefix_group >= 0:
            peers = (self._uid >= 0) & (self._group == s.prefix_group)
            if peers.any():
                reps = np.unique(self._replica[peers])
                s.replica = int(reps[np.argmin(load[reps])])
                self.add(s)
                return s.replica
        s.replica = int(np.argmin(load))
        self.add(s)
        return s.replica

    def replica_loads(self) -> np.ndarray:
        act = self._uid >= 0
        return np.bincount(self._replica[act],
                           weights=self._load[act].astype(np.float64),
                           minlength=self.num_replicas)

    # ------------------------------------------------------------ fleet --

    def _canonical_groups(self) -> np.ndarray:
        """Raw group ids → canonical ids in [0, S) (slot-count bounded),
        -1 for ungrouped/free — the device edge builder's contract."""
        out = np.full(self.capacity, -1, np.int32)
        act = np.flatnonzero(self._uid >= 0)
        grouped = act[self._group[act] >= 0]
        if len(grouped):
            _, inv = np.unique(self._group[grouped], return_inverse=True)
            out[grouped] = inv.astype(np.int32)
        return out

    def fleet(self) -> SessionFleet:
        """Device snapshot of the session store."""
        return SessionFleet(
            uid=jnp.asarray(self._uid, jnp.int32),
            load=jnp.asarray(self._load, jnp.float32),
            group=jnp.asarray(self._canonical_groups(), jnp.int32),
            replica=jnp.asarray(self._replica, jnp.int32),
            kv=jnp.asarray(self._kv, jnp.float32))

    def problem(self) -> comm_graph.LBProblem:
        return fleet_problem(self.fleet(), self.num_replicas)

    # -------------------------------------------------------- rebalance --

    def rebalance(self, *, strategy: str = "diff-comm",
                  slot_capacity: Optional[int] = None) -> Dict:
        """Plan through the Strategy registry, then **execute** the
        placement delta as a slab exchange through ``runtime.migrate``.

        The fleet store is re-bucketed into replica-contiguous slot order
        by the counting-scatter manifest (free slots ride along at zero
        cost) and ``moved_kv_bytes`` is the executed per-session KV
        volume (``Manifest.moved_sum``).  ``slot_capacity`` bounds the
        per-replica slot count: moves that would overflow are deferred in
        place via ``migrate.spill_owner`` (``deferred_sessions`` in the
        info dict) rather than dropped."""
        if len(self._slot) < 2:
            return dict(skipped=True)
        fleet = self.fleet()
        prob = fleet_problem(fleet, self.num_replicas)
        strat = engine.get_strategy(strategy)
        plan = strat.run(
            prob, **_strategy_params(strat, self.num_replicas, self.k))
        info = dict(plan.info)
        owner_new = jnp.asarray(plan.assignment, jnp.int32)
        deferred = 0
        if slot_capacity is not None:
            # the budget bounds *live sessions* per replica: free slots are
            # parked on a virtual node with unbounded capacity so they
            # neither consume the budget nor block admissions
            # (spill_admissions broadcasts a per-group capacity vector)
            R, park = self.num_replicas, self.num_replicas
            act = fleet.active
            cap = jnp.full((R + 1,), int(slot_capacity), jnp.int32)
            cap = cap.at[park].set(self.capacity)
            eff, dmask = rt_migrate.spill_owner(
                jnp.where(act, fleet.replica, park),
                jnp.where(act, owner_new, park),
                num_nodes=R + 1, capacity=cap)
            owner_new = jnp.where(act, eff, owner_new)
            deferred = int(np.asarray((jnp.asarray(dmask) & act).sum()))
        (uid, load, group, kv, raw_group), man = rt_migrate.migrate(
            fleet.replica, owner_new,
            (fleet.uid, fleet.load, fleet.group, fleet.kv,
             jnp.asarray(self._group)),
            num_nodes=self.num_replicas)
        new_replica = jnp.take(owner_new, man.order)
        moved_kv = float(np.asarray(
            man.moved_sum(fleet.kv, where=fleet.active)))
        moved_n = int(np.asarray(
            jnp.where(man.moved & fleet.active, 1, 0).sum()))
        # refresh the host mirror from the relocated slabs (np.array:
        # jax buffers view as read-only, the mirror must stay mutable)
        self._uid = np.array(uid, np.int32)
        self._load = np.array(load, np.float32)
        self._group = np.array(raw_group)
        self._replica = np.array(new_replica, np.int32)
        self._kv = np.array(kv, np.float32)
        self._slot = {int(u): i for i, u in enumerate(self._uid) if u >= 0}
        info.update(metrics.evaluate(prob, jnp.asarray(plan.assignment)))
        info.update(moved_kv_bytes=moved_kv, moved_sessions=moved_n,
                    deferred_sessions=deferred,
                    prefix_local=float(np.asarray(
                        prefix_locality(self.fleet()))))
        return info

    # ---------------------------------------------------- control plane --

    def maybe_rebalance(self, *, strategy: str = "diff-comm+predictive",
                        trigger=None, lb_every: int = 10,
                        slot_capacity: Optional[int] = None,
                        cost: Optional[RuntimeCostModel] = None) -> Dict:
        """One control-plane tick: trigger decides, ``rebalance`` executes.

        The trigger (resolved through ``runtime.triggers`` — the
        strategy's registered policy by default) sees the clamped fleet
        load statistics; after a fire, the **executed** KV volume is fed
        back through ``Trigger.observe`` in load units
        (``moved_kv_bytes / cost.bytes_per_load``), so the predictive
        gate amortizes future fires against what migration actually
        cost — not the a-priori estimate."""
        trig = rt_triggers.resolve_for_strategy(
            trigger, lb_every=lb_every, strategy=strategy)
        if cost is None:
            cost = getattr(trig, "cost", None) or RuntimeCostModel()
        if trig is not self._trig:
            self._trig, self._tstate, self._tstep = trig, trig.init_state(), 0
        t = self._tstep
        self._tstep += 1
        fleet = self.fleet()
        mx, av, tot = rt_triggers.load_stats_jit(
            fleet_loads(fleet), fleet.replica, self.num_replicas)
        do, self._tstate = trig.decide(
            self._tstate, jnp.int32(t), mx, av, tot)
        if bool(do):
            info = self.rebalance(strategy=strategy,
                                  slot_capacity=slot_capacity)
            moved_load = jnp.float32(
                info.get("moved_kv_bytes", 0.0)
                / max(cost.bytes_per_load, 1e-30))
        else:
            info = dict(skipped=True)
            moved_load = jnp.float32(0.0)
        self._tstate = trig.observe(self._tstate, moved_load, do)
        info.update(fired=bool(do), t=t)
        return info
