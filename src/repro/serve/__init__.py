"""Serving layer: batched prefill/decode engine + diffusion request
scheduler across replicas."""
