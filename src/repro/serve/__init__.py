"""Serving layer: batched prefill/decode engine (serve/engine.py), the
device-resident session scheduler with executed KV migration
(serve/scheduler.py), and the scan-compiled continuous-batching replay
(serve/replay.py).  Submodules are imported directly — the engine pulls
the model stack, which the scheduler/replay paths do not need."""
