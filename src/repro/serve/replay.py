"""Scan-compiled continuous-batching serving replay with executed KV moves.

``run_serve_replay`` drives a fleet of ``S`` persistent multi-turn
sessions over ``R`` serving replicas for ``T`` engine ticks, with the
whole loop — workload evolution → trigger decision → device plan →
**executed** KV-slab exchange — compiled into one ``jax.lax.scan``.  It
mirrors ``sim.simulator.run_series``' host/scan parity contract: the host
path executes the same jnp expression graphs eagerly (trigger statistics
through ``runtime.triggers.load_stats``, planning through the same bound
Strategy closure, the exchange through the same
``runtime.migrate.build_and_apply``), so fire steps, placements and moved
KV bytes agree **bit-for-bit** across paths.

The carry is the session fleet as fixed-shape slabs — ``uid`` (which
session occupies each slot), ``replica`` (its owner) and ``kv`` (its
resident KV-cache bytes, growing with decode activity) — plus the trigger
state.  A fired rebalance re-buckets the slabs into replica-contiguous
order via the counting-scatter manifest (PR 6) and reads the executed
exchange volume off ``Manifest.moved_sum`` with *per-session* KV sizes;
that volume (in the trigger cost model's load units) feeds
``Trigger.observe``, so the predictive gate amortizes future fires against
what migration actually moved.  ``slot_capacity`` bounds live sessions per
replica through ``migrate.spill_owner`` — overflow moves defer in place
and retry at the next fire (graceful degradation, payload never dropped).

``num_shards > 1`` (or an explicit ``mesh``) runs the multi-replica-group
path: the same loop with the fired exchange executed as a ``ppermute``
ring all-to-all under ``shard_map`` (``migrate.migrate_sharded`` →
``migrate.ring_exchange``).  Strict mode's layout contract makes the
concatenated per-shard valid prefixes bit-for-bit the single-device
bucketed slabs, so the sharded replay reproduces the single-device
trajectory exactly.

Workloads:

  * :class:`ServeWorkload` — synthetic bursty multi-turn traffic: every
    session alternates decode turns and idle gaps (per-session random
    phase/rate), prefix-sharing groups of ``group_size`` sessions, and
    burst *waves* that periodically surge one cohort's load (the
    imbalance the balancer must chase).  Scales to 10⁵⁺ sessions — all
    tables are O(S) device arrays.
  * :class:`TraceWorkload` — trace-driven replay of a recorded ``(T, S)``
    load table (request logs, or a trace captured from any workload via
    :func:`record_trace`).

The ``serving-trace`` scenario in ``sim/scenarios.py`` adapts a recorded
trace to the simulator's scenario registry, so every existing bench and
parity suite (run_series, run_series_batch, run_series_sharded) consumes
the serving workload too.  The fleet-scale policy comparison is
``benchmarks/serve_bench.py`` (serve-bench/v1).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm_graph, engine
from repro.obs import telemetry as obs_telemetry
from repro.runtime import migrate as rt_migrate
from repro.runtime import triggers as rt_triggers
from repro.serve.scheduler import LOAD_FLOOR

# ------------------------------------------------------------- workloads --


@dataclasses.dataclass(frozen=True)
class ServeWorkload:
    """Synthetic bursty multi-turn session traffic (pure function of t).

    Session ``u``'s load at tick ``t`` is ``idle_load`` outside its
    decode turns and ``rate[u] * surge`` inside them, where turns open
    for ``turn_len`` of every ``turn_period`` ticks at a per-session
    random phase, and ``surge`` multiplies by ``1 + burst_amp`` whenever
    the session's burst *wave* is the active one (waves rotate every
    ``burst_period`` ticks — a moving cohort hotspot).  Prefix groups are
    ``uid // group_size``.  Hashable (frozen floats/ints only), so
    compiled replay runners cache across calls."""

    num_sessions: int = 4096
    num_replicas: int = 16
    group_size: int = 4
    turn_period: int = 12
    turn_len: int = 6
    burst_waves: int = 4
    burst_period: int = 25
    burst_amp: float = 3.0
    idle_load: float = 0.05
    rate_lo: float = 0.5
    rate_hi: float = 2.0
    kv0: float = 64.0
    kv_per_token: float = 1.0
    seed: int = 0

    def _tables(self):
        return _serve_tables(self)

    def loads_at(self, t, uid) -> jax.Array:
        """(S,) f32 decode load of the sessions in ``uid`` at tick t."""
        rate, phase, wave, _ = map(jnp.asarray, self._tables())
        t = jnp.asarray(t, jnp.int32)
        uid = jnp.asarray(uid, jnp.int32)
        in_turn = ((t + phase[uid]) % self.turn_period) < self.turn_len
        hot = wave[uid] == (t // self.burst_period) % self.burst_waves
        surge = 1.0 + self.burst_amp * hot.astype(jnp.float32)
        return jnp.where(
            in_turn, rate[uid] * surge,
            jnp.float32(self.idle_load)).astype(jnp.float32)

    def group_of(self, uid) -> jax.Array:
        return (jnp.asarray(uid, jnp.int32)
                // jnp.int32(max(1, self.group_size)))

    def kv0_of(self, uid) -> jax.Array:
        kv0 = jnp.asarray(self._tables()[3])
        return kv0[jnp.asarray(uid, jnp.int32)]


@functools.lru_cache(maxsize=64)
def _serve_tables(w: ServeWorkload):
    """Per-session random tables (rate, phase, wave, kv0).

    Cached as **numpy** and converted at the use site: a first call from
    inside a jit/vmap trace would otherwise cache traced constants that
    leak into later calls."""
    rng = np.random.default_rng(w.seed)
    S = w.num_sessions
    rate = rng.uniform(w.rate_lo, w.rate_hi, S).astype(np.float32)
    phase = rng.integers(0, max(1, w.turn_period), S).astype(np.int32)
    wave = rng.integers(0, max(1, w.burst_waves), S).astype(np.int32)
    kv0 = (w.kv0 * rng.uniform(0.5, 1.5, S)).astype(np.float32)
    return rate, phase, wave, kv0


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: jnp fields
class TraceWorkload:
    """Trace-driven workload: a recorded ``(T, S)`` load table.

    ``group`` ids must be canonical (``[0, S)``, -1 for ungrouped) and the
    table loops when replayed past its length.  Instances hash by
    identity, so reusing one instance reuses the compiled runner."""

    table: jax.Array              # (T, S) f32 per-tick session loads
    group: jax.Array              # (S,) i32 prefix groups
    kv0: jax.Array                # (S,) f32 initial KV bytes
    num_replicas: int = 16
    kv_per_token: float = 1.0

    @property
    def num_sessions(self) -> int:
        return int(self.table.shape[1])

    def loads_at(self, t, uid) -> jax.Array:
        row = self.table[jnp.mod(jnp.asarray(t, jnp.int32),
                                 self.table.shape[0])]
        return row[jnp.asarray(uid, jnp.int32)]

    def group_of(self, uid) -> jax.Array:
        return self.group[jnp.asarray(uid, jnp.int32)]

    def kv0_of(self, uid) -> jax.Array:
        return self.kv0[jnp.asarray(uid, jnp.int32)]


def record_trace(workload, *, steps: int) -> TraceWorkload:
    """Capture ``steps`` ticks of any workload into a
    :class:`TraceWorkload` (the trace-driven scenario's source)."""
    S = workload.num_sessions
    uid = jnp.arange(S, dtype=jnp.int32)
    rows = jax.jit(lambda ts: jax.vmap(
        lambda t: workload.loads_at(t, uid))(ts))(
            jnp.arange(steps, dtype=jnp.int32))
    return TraceWorkload(
        table=jnp.asarray(rows, jnp.float32),
        group=jnp.asarray(workload.group_of(uid), jnp.int32),
        kv0=jnp.asarray(workload.kv0_of(uid), jnp.float32),
        num_replicas=workload.num_replicas,
        kv_per_token=float(workload.kv_per_token))


# --------------------------------------------------------------- results --


@dataclasses.dataclass
class ServeReplayResult:
    """Per-tick records + final fleet state of one serving replay."""

    max_avg: np.ndarray           # (T,) post-LB replica load imbalance
    lb_fired: np.ndarray          # (T,) 0/1 trigger decisions
    moved_sessions: np.ndarray    # (T,) sessions exchanged at that tick
    moved_kv_bytes: np.ndarray    # (T,) executed KV transfer volume
    prefix_local: np.ndarray      # (T,) intra-replica prefix-edge fraction
    deferred: np.ndarray          # (T,) capacity-deferred moves (spill)
    occ_max: np.ndarray           # (T,) max live sessions on one replica
    final_uid: np.ndarray         # (S,) slot → session id
    final_replica: np.ndarray     # (S,) slot → replica
    final_kv: np.ndarray          # (S,) slot → resident KV bytes
    scanned: bool = False
    sharded: bool = False
    wall_seconds: float = 0.0
    # StepRecord ring snapshot when an enabled TelemetryConfig was passed
    telemetry: Optional[obs_telemetry.TelemetrySnapshot] = None

    @property
    def final_replica_by_uid(self) -> np.ndarray:
        """(S,) replica of each session id — slot-permutation invariant
        (the exchange re-buckets slots; identity lives in ``uid``)."""
        out = np.full(self.final_uid.shape, -1, np.int32)
        out[self.final_uid] = self.final_replica
        return out

    @property
    def total_moved_kv(self) -> float:
        return float(self.moved_kv_bytes.sum())


# ------------------------------------------------------------- step body --


def _locality(group, loads_c, replica) -> jax.Array:
    """Intra-replica fraction of prefix-sharing (star) edge weight."""
    S = int(group.shape[0])
    es, ed, ew = comm_graph.prefix_group_edges(
        group, loads_c, None, ring_eps=LOAD_FLOOR)
    es, ed, ew = es[:S], ed[:S], ew[:S]
    valid = es >= 0
    w = jnp.where(valid, ew, 0.0)
    intra = jnp.where(
        valid & (replica[jnp.clip(es, 0, S - 1)]
                 == replica[jnp.clip(ed, 0, S - 1)]), ew, 0.0)
    return intra.sum() / jnp.maximum(w.sum(), jnp.float32(1e-30))


def _make_parts(workload, trig, plan, slot_capacity, R: int, S: int,
                lb_on: bool, bytes_per_load: float):
    """The shared jnp step pieces — one source of truth for every path.

    ``pre``  advances the workload and decides; ``fire``/``nofire`` are
    the two exchange branches (identical signatures, so the scanned path
    puts them under ``lax.cond`` and the host path picks one after a
    device sync — same compiled graphs either way); ``post`` computes the
    post-exchange records."""

    def pre(uid, kv, replica, tstate, t):
        ld = workload.loads_at(t, uid)
        kv = kv + jnp.float32(workload.kv_per_token) * ld
        ldc = jnp.maximum(ld, jnp.float32(LOAD_FLOOR))
        if lb_on:
            mx, av, tot = rt_triggers.load_stats(ldc, replica, R)
            do, tstate = trig.decide(tstate, t, mx, av, tot)
        else:
            do = jnp.asarray(False)
        return kv, do, tstate

    def _problem(uid, ldc, replica):
        es, ed, ew = comm_graph.prefix_group_edges(
            workload.group_of(uid), ldc, None, ring_eps=LOAD_FLOOR)
        return comm_graph.LBProblem(
            loads=ldc, assignment=replica, edges_src=es, edges_dst=ed,
            edges_bytes=ew, num_nodes=R)

    def plan_owner(uid, kv, replica, t):
        """Effective post-spill target owners for a fired tick (plus the
        planner's executed diffusion sweeps, for telemetry)."""
        ldc = jnp.maximum(workload.loads_at(t, uid),
                          jnp.float32(LOAD_FLOOR))
        owner_new, stats = plan(_problem(uid, ldc, replica))
        owner_new = owner_new.astype(jnp.int32)
        sweeps = jnp.asarray(stats.diffusion_iters, jnp.float32)
        if slot_capacity is not None:
            owner_new, dmask = rt_migrate.spill_owner(
                replica, owner_new, num_nodes=R,
                capacity=int(slot_capacity))
            deferred = dmask.sum().astype(jnp.float32)
        else:
            deferred = jnp.float32(0.0)
        return owner_new, deferred, sweeps

    def fire(uid, kv, replica, t):
        owner_new, deferred, sweeps = plan_owner(uid, kv, replica, t)
        (uid2, kv2), man = rt_migrate.build_and_apply(
            replica, owner_new, (uid, kv), num_nodes=R)
        replica2 = jnp.take(owner_new, man.order)
        moved_n = man.moved_count.astype(jnp.float32)
        moved_kv = man.moved_sum(kv)
        return uid2, kv2, replica2, moved_n, moved_kv, deferred, sweeps

    def nofire(uid, kv, replica, t):
        return (uid, kv, replica, jnp.float32(0.0), jnp.float32(0.0),
                jnp.float32(0.0), jnp.float32(0.0))

    def post(uid, kv, replica, tstate, do, moved_kv, t):
        tstate = trig.observe(
            tstate, moved_kv / jnp.float32(bytes_per_load), do)
        ldc = jnp.maximum(workload.loads_at(t, uid),
                          jnp.float32(LOAD_FLOOR))
        mx, av, _ = rt_triggers.load_stats(ldc, replica, R)
        occ = jax.ops.segment_sum(
            jnp.ones((S,), jnp.int32), replica, num_segments=R)
        ploc = _locality(workload.group_of(uid), ldc, replica)
        return tstate, (mx / av, ploc, occ.max().astype(jnp.float32))

    return pre, plan_owner, fire, nofire, post


def _initial_state(workload):
    S = workload.num_sessions
    R = workload.num_replicas
    uid = jnp.arange(S, dtype=jnp.int32)
    replica = ((uid * R) // S).astype(jnp.int32)   # contiguous blocks
    kv = jnp.asarray(workload.kv0_of(uid), jnp.float32)
    return uid, kv, replica


def _resolve(workload, strategy, strategy_kwargs, trigger, lb_every):
    strat = engine.get_strategy(strategy)
    kw = dict(strategy_kwargs or {})
    if strat.variant is not None:
        kw.setdefault(
            "k", max(1, min(4, int(workload.num_replicas) - 1)))
    trig = rt_triggers.resolve_for_strategy(
        trigger, lb_every=lb_every, strategy=strategy)
    cost = getattr(trig, "cost", None)
    bpl = float(cost.bytes_per_load) if cost is not None else 1.0
    lb_on = strategy != "none" and not trig.never
    return strat, kw, trig, bpl, lb_on


# ---------------------------------------------------------- scanned path --


@functools.lru_cache(maxsize=64)
def _scanned_serve_runner(workload, steps: int, strategy: str,
                          kw_items: tuple, trig, lb_every: int,
                          slot_capacity: Optional[int], tel=None):
    strat = engine.get_strategy(strategy)
    plan = strat.bind(**dict(kw_items))
    S, R = workload.num_sessions, workload.num_replicas
    cost = getattr(trig, "cost", None)
    bpl = float(cost.bytes_per_load) if cost is not None else 1.0
    lb_on = strategy != "none" and not trig.never
    pre, _, fire, nofire, post = _make_parts(
        workload, trig, plan, slot_capacity, R, S, lb_on, bpl)
    tkind = obs_telemetry.trigger_kind(trig) if tel else 0

    def step(carry, t):
        if tel:
            uid, kv, replica, tstate, obs_state = carry
        else:
            uid, kv, replica, tstate = carry
        kv, do, tstate = pre(uid, kv, replica, tstate, t)
        uid, kv, replica, moved_n, moved_kv, deferred, sweeps = \
            jax.lax.cond(do, fire, nofire, uid, kv, replica, t)
        tstate, (ma, ploc, occ) = post(
            uid, kv, replica, tstate, do, moved_kv, t)
        ys = (ma, do.astype(jnp.float32), moved_n, moved_kv, ploc,
              deferred, occ)
        if tel:
            ldc = jnp.maximum(workload.loads_at(t, uid),
                              jnp.float32(LOAD_FLOOR))
            obs_state = obs_telemetry.record(
                obs_state, tel, t=t,
                node_loads=obs_telemetry.node_loads(ldc, replica, R),
                fired=do, trigger_kind=tkind, sweeps=sweeps,
                moved_items=moved_n, moved_bytes=moved_kv,
                deferred=deferred)
            return (uid, kv, replica, tstate, obs_state), ys
        return (uid, kv, replica, tstate), ys

    def run(uid, kv, replica):
        carry = (uid, kv, replica, trig.init_state())
        if tel:
            carry = carry + (obs_telemetry.init_state(tel, R),)
        return jax.lax.scan(step, carry, jnp.arange(steps))

    return jax.jit(run)


# ------------------------------------------------------------ host paths --


def _host_serve_loop(workload, steps, strategy, kw, trig, lb_every,
                     slot_capacity, *, mesh=None, tel=None):
    """Eager replay: the scanned step pieces executed one tick at a time.

    ``mesh`` switches the fired exchange to the multi-replica-group path:
    ``migrate.migrate_sharded`` (ring all-to-all under shard_map) in
    strict mode, whose layout contract reconstructs the single-device
    bucketed slabs bit-for-bit from the per-shard valid prefixes."""
    strat = engine.get_strategy(strategy)
    plan = strat.bind(**kw) if strat.jittable else None
    S, R = workload.num_sessions, workload.num_replicas
    cost = getattr(trig, "cost", None)
    bpl = float(cost.bytes_per_load) if cost is not None else 1.0
    lb_on = strategy != "none" and not trig.never
    pre, plan_owner, fire, nofire, post = _make_parts(
        workload, trig, plan, slot_capacity, R, S, lb_on, bpl)
    pre_j = jax.jit(pre)
    fire_j, nofire_j = jax.jit(fire), jax.jit(nofire)
    post_j = jax.jit(post)
    plan_owner_j = jax.jit(plan_owner) if strat.jittable else None

    def host_plan_owner(uid, kv, replica, t):
        """Host-baseline planning (greedy & co): eager Strategy.run on
        the same device-built problem, then the same spill clamp."""
        ldc = jnp.maximum(workload.loads_at(t, uid),
                          jnp.float32(LOAD_FLOOR))
        es, ed, ew = comm_graph.prefix_group_edges(
            workload.group_of(uid), ldc, None, ring_eps=LOAD_FLOOR)
        prob = comm_graph.LBProblem(
            loads=ldc, assignment=replica, edges_src=es, edges_dst=ed,
            edges_bytes=ew, num_nodes=R)
        owner_new = jnp.asarray(strat.run(prob, **kw).assignment,
                                jnp.int32)
        if slot_capacity is not None:
            owner_new, dmask = rt_migrate.spill_owner(
                replica, owner_new, num_nodes=R,
                capacity=int(slot_capacity))
            return owner_new, dmask.sum().astype(jnp.float32), \
                jnp.float32(0.0)
        return owner_new, jnp.float32(0.0), jnp.float32(0.0)

    uid, kv, replica = _initial_state(workload)
    tstate = trig.init_state()
    obs_state = (obs_telemetry.init_state(tel, R) if tel else None)
    tkind = obs_telemetry.trigger_kind(trig) if tel else 0
    recs = []
    for ti in range(steps):
        t = jnp.int32(ti)
        kv, do, tstate = pre_j(uid, kv, replica, tstate, t)
        fired = bool(do)
        sweeps = 0.0
        if not fired:
            uid, kv, replica, moved_n, moved_kv, deferred, sweeps = \
                nofire_j(uid, kv, replica, t)
        elif mesh is not None or plan_owner_j is None:
            getter = plan_owner_j or host_plan_owner
            owner_new, deferred, sweeps = getter(uid, kv, replica, t)
            moved = jnp.asarray(owner_new) != replica
            moved_n = moved.sum().astype(jnp.float32)
            moved_kv = jnp.where(moved, kv, 0.0).sum()
            if mesh is None:
                (uid, kv), man = rt_migrate.migrate(
                    replica, owner_new, (uid, kv), num_nodes=R)
                replica = jnp.take(owner_new, man.order)
            else:
                owner_out, (uid_p, kv_p), counts = rt_migrate.migrate_sharded(
                    owner_new, (uid, kv), num_nodes=R, mesh=mesh)
                # strict-mode layout contract: concatenated valid
                # prefixes == the single-device bucketed slabs
                D = int(np.prod(mesh.devices.shape))
                cap = int(np.asarray(owner_out).shape[0]) // D
                cnt = np.asarray(counts)
                keep = np.concatenate([
                    np.arange(d * cap, d * cap + cnt[d]) for d in range(D)])
                uid = jnp.asarray(np.asarray(uid_p)[keep], jnp.int32)
                kv = jnp.asarray(np.asarray(kv_p)[keep], jnp.float32)
                replica = jnp.asarray(np.asarray(owner_out)[keep],
                                      jnp.int32)
        else:
            uid, kv, replica, moved_n, moved_kv, deferred, sweeps = \
                fire_j(uid, kv, replica, t)
        tstate, (ma, ploc, occ) = post_j(
            uid, kv, replica, tstate, do, moved_kv, t)
        if tel:
            ldc = jnp.maximum(workload.loads_at(t, uid),
                              jnp.float32(LOAD_FLOOR))
            obs_state = obs_telemetry.record(
                obs_state, tel, t=t,
                node_loads=obs_telemetry.node_loads(ldc, replica, R),
                fired=fired, trigger_kind=tkind, sweeps=sweeps,
                moved_items=moved_n, moved_bytes=moved_kv,
                deferred=deferred)
        recs.append((float(ma), 1.0 if fired else 0.0, float(moved_n),
                     float(moved_kv), float(ploc), float(deferred),
                     float(occ)))
    return uid, kv, replica, recs, obs_state


# ------------------------------------------------------------- the entry --


def run_serve_replay(
    workload,
    *,
    steps: int,
    strategy: str = "diff-comm",
    strategy_kwargs: Optional[Dict] = None,
    trigger=None,
    lb_every: int = 10,
    slot_capacity: Optional[int] = None,
    scan: Optional[bool] = None,
    num_shards: Optional[int] = None,
    mesh=None,
    telemetry=None,
) -> ServeReplayResult:
    """Replay ``steps`` serving ticks with executed KV-cache migration.

    ``scan=None`` auto-selects the scanned path for jittable strategies
    (host baselines like ``"greedy"`` run the eager loop with the same
    executed exchange).  ``trigger`` resolves through
    ``runtime.triggers.resolve_for_strategy`` — the predictive policy
    amortizes fires against the **measured** KV bytes of the previous
    exchange.  ``num_shards`` / ``mesh`` run the fired exchanges as ring
    all-to-alls under ``shard_map`` (bit-for-bit the single-device
    trajectory via the strict layout contract); ``S`` and ``R`` must
    divide the shard count."""
    strat, kw, trig, _bpl, _lb_on = _resolve(
        workload, strategy, strategy_kwargs, trigger, lb_every)
    tel = obs_telemetry.resolve(telemetry)
    tel = tel if tel.enabled else None
    sharded = mesh is not None or num_shards is not None
    if sharded:
        if scan:
            raise ValueError(
                "the sharded serving replay is a host-driven loop; "
                "pass scan=False/None")
        from repro.distributed import replay_shard

        mesh = replay_shard.resolve_mesh(
            mesh, num_shards,
            (workload.num_sessions, workload.num_replicas))
        scan = False
    if scan is None:
        scan = strat.jittable
    if scan and not strat.jittable:
        raise ValueError(
            f"strategy {strategy!r} is not jittable; the scanned serving "
            "replay needs a traceable plan_fn (use scan=False or a "
            "diff-* / none strategy)")
    t0 = time.perf_counter()
    if scan:
        runner = _scanned_serve_runner(
            workload, int(steps), strategy, tuple(sorted(kw.items())),
            trig, int(lb_every),
            None if slot_capacity is None else int(slot_capacity), tel)
        final, ys = runner(*_initial_state(workload))
        uid, kv, replica = final[0], final[1], final[2]
        obs_state = final[4] if tel else None
        ma, fired, moved_n, moved_kv, ploc, deferred, occ = jax.device_get(ys)
        recs = np.stack([ma, fired, moved_n, moved_kv, ploc, deferred,
                         occ], axis=1)
    else:
        uid, kv, replica, rec_list, obs_state = _host_serve_loop(
            workload, int(steps), strategy, kw, trig, int(lb_every),
            None if slot_capacity is None else int(slot_capacity),
            mesh=mesh, tel=tel)
        recs = np.asarray(rec_list, np.float64).reshape(int(steps), 7)
    return ServeReplayResult(
        max_avg=np.asarray(recs[:, 0], np.float64),
        lb_fired=np.asarray(recs[:, 1], np.float64),
        moved_sessions=np.asarray(recs[:, 2], np.float64),
        moved_kv_bytes=np.asarray(recs[:, 3], np.float64),
        prefix_local=np.asarray(recs[:, 4], np.float64),
        deferred=np.asarray(recs[:, 5], np.float64),
        occ_max=np.asarray(recs[:, 6], np.float64),
        final_uid=np.asarray(uid, np.int32),
        final_replica=np.asarray(replica, np.int32),
        final_kv=np.asarray(kv, np.float32),
        scanned=bool(scan), sharded=bool(sharded),
        wall_seconds=time.perf_counter() - t0,
        telemetry=(obs_telemetry.snapshot(obs_state, tel)
                   if tel else None))
