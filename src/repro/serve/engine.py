"""Batched serving engine: continuous-batching prefill/decode over one
model replica.

``ServeEngine`` owns the jitted ``prefill``/``decode_step`` executables and
a slot-based KV cache: requests claim free batch slots, prefill writes their
prompt into the cache at their slot, and every engine tick advances all
active slots by one token.  Slots free on EOS/max-tokens (continuous
batching — new requests join between ticks without recompiling; shapes are
static in (num_slots, max_len)).

This is the per-replica data plane; cross-replica placement is
serve/scheduler.py.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (L,) token ids
    max_new_tokens: int = 16
    eos_id: int = -1                # -1 ⇒ never
    out: Optional[List[int]] = None


@dataclasses.dataclass
class ServeConfig:
    num_slots: int = 4
    max_len: int = 256
    dtype: str = "float32"


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = serve_cfg
        dt = jnp.dtype(serve_cfg.dtype)
        self.cache = transformer.init_cache(
            cfg, serve_cfg.num_slots, serve_cfg.max_len, dt)
        self.slot_req: List[Optional[Request]] = [None] * serve_cfg.num_slots
        self.slot_pos = np.zeros(serve_cfg.num_slots, np.int64)
        self.slot_tok = np.zeros(serve_cfg.num_slots, np.int32)
        self.queue: List[Request] = []
        self.done: List[Request] = []
        self.ticks = 0

        @functools.partial(jax.jit, static_argnames=("plen",), donate_argnums=(1,))
        def _prefill_slot(params, cache, tokens, slot, plen: int):
            """Write one request's prompt into `slot` of the cache."""
            # run the prompt as a batch-1 forward, then scatter its cache
            # rows into the engine cache at `slot`.
            one = transformer.init_cache(cfg, 1, serve_cfg.max_len, dt)
            pos = jnp.arange(plen, dtype=jnp.int32)[None]
            logits, one = transformer.prefill(
                params, cfg, dict(tokens=tokens[None, :plen], positions=pos),
                one)

            def put(c, o):
                return c.at[slot].set(o[0])

            def put_stacked(c, o):
                # scanned unit caches carry a leading group dim (G, B, S, ...)
                return c.at[:, slot].set(o[:, 0])

            cache = dict(
                unit=jax.tree.map(put_stacked, cache["unit"], one["unit"]),
                prefix=jax.tree.map(put, cache["prefix"], one["prefix"]),
                suffix=jax.tree.map(put, cache["suffix"], one["suffix"]),
            )
            return logits[:, -1], cache

        @jax.jit
        def _decode(params, cache, tokens, positions):
            """One decode tick for every slot.  tokens (S,1), positions (S,)."""
            B = tokens.shape[0]
            batch = dict(tokens=tokens,
                         positions=positions[:, None].astype(jnp.int32))
            h, cache, _ = transformer.forward(
                params, cfg, batch, cache=cache, decode=True)
            logits = transformer.logits_head(params, cfg, h)
            return logits[:, 0], cache

        self._prefill_slot = _prefill_slot
        self._decode = _decode

    # ------------------------------------------------------------- admin --

    def submit(self, req: Request) -> None:
        req.out = []
        self.queue.append(req)

    def _admit(self) -> None:
        for s in range(self.scfg.num_slots):
            while self.slot_req[s] is None and self.queue:
                req = self.queue.pop(0)
                plen = int(len(req.prompt))
                logits, self.cache = self._prefill_slot(
                    self.params, self.cache,
                    jnp.asarray(req.prompt, jnp.int32), s, plen=plen)
                tok = int(jnp.argmax(logits[0]))
                req.out.append(tok)
                # the prefill-produced first token can itself be terminal
                # (EOS, or max_new_tokens == 1): finish at admission and
                # keep the slot free for the next queued request instead
                # of burning a decode tick on a completed request
                if tok == req.eos_id or len(req.out) >= req.max_new_tokens:
                    self.done.append(req)
                    continue
                self.slot_req[s] = req
                self.slot_pos[s] = plen
                self.slot_tok[s] = tok

    # -------------------------------------------------------------- tick --

    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    def tick(self) -> None:
        """Admit waiting requests, advance all active slots one token."""
        self._admit()
        if self.active() == 0:
            return
        tokens = jnp.asarray(self.slot_tok[:, None])
        positions = jnp.asarray(self.slot_pos)
        logits, self.cache = self._decode(
            self.params, self.cache, tokens, positions)
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        self.ticks += 1
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            tok = int(nxt[s])
            req.out.append(tok)
            self.slot_pos[s] += 1
            self.slot_tok[s] = tok
            exhausted = len(req.out) >= req.max_new_tokens
            hit_eos = tok == req.eos_id
            full = self.slot_pos[s] >= self.scfg.max_len - 1
            if exhausted or hit_eos or full:
                self.done.append(req)
                self.slot_req[s] = None

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        while (self.queue or self.active()) and self.ticks < max_ticks:
            self.tick()
        return self.done
