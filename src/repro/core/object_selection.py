"""Stage 3 — object selection (paper §III.C).

Realizes the stage-2 virtual flows with actual objects.  Faithful rules:

  * per destination neighbor ``n``, objects leave in decreasing order of the
    bytes they exchange with ``n`` (communication variant) or increasing
    distance to ``n``'s centroid (coordinate variant §IV);
  * when an object moves, its peers' communication patterns update to point
    at the new residence — honored by recomputing the object→neighbor byte
    table between phases (and centroids, for the coordinate variant);
  * single-hop: an object migrates at most once per LB round.

Vectorization: one *phase* per neighbor slot (K phases, K small).  In each
phase every node works on its largest-remaining-budget neighbor; the
per-node "sort by metric, take while under budget" is a global lexsort +
segmented prefix sum — no data-dependent host loops, so the whole planner
jits and can run inside the training loop (distributed/ep_balance.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import comm_graph

NEG = jnp.float32(-1e30)


class SelectionResult(NamedTuple):
    assignment: jax.Array     # (N,) new object→node map
    moved: jax.Array          # (N,) bool
    realized: jax.Array       # (P, K) load actually shipped per neighbor slot
    residual: jax.Array       # (P, K) unrealized flow (wanted - shipped)


def _segmented_take_while(
    node: jax.Array,       # (N,) segment id per object (its current node)
    score: jax.Array,      # (N,) ordering metric, higher = leaves first
    loads: jax.Array,      # (N,) object loads
    eligible: jax.Array,   # (N,) bool — participates in this phase
    budget: jax.Array,     # (P,) per-node load budget
) -> jax.Array:
    """Per node: order eligible objects by score desc, select while the
    running load stays under budget (midpoint rule: an object is taken iff
    taking it lands closer to the budget than stopping)."""
    P = budget.shape[0]
    eff_score = jnp.where(eligible, score, NEG)
    order = jnp.lexsort((-eff_score, node))            # by node, then score
    node_s = node[order]
    load_s = jnp.where(eligible, loads, 0.0)[order]
    csum = jnp.cumsum(load_s)
    seg_tot = jax.ops.segment_sum(load_s, node_s, num_segments=P)
    before = jnp.concatenate([jnp.zeros(1), jnp.cumsum(seg_tot)[:-1]])
    within = csum - before[node_s]                     # inclusive in-node csum
    take_s = (within - 0.5 * load_s) <= budget[node_s]
    take_s &= eligible[order] & (load_s > 0)
    take = jnp.zeros_like(take_s).at[order].set(take_s)
    return take


@functools.partial(jax.jit, static_argnames=("metric", "score_psum_axis"))
def select_objects(
    problem: comm_graph.LBProblem,
    nbr_idx: jax.Array,
    nbr_mask: jax.Array,
    flows: jax.Array,
    *,
    metric: str = "comm",
    centroids: Optional[jax.Array] = None,
    score_psum_axis: Optional[str] = None,
) -> SelectionResult:
    """Pick objects realizing ``flows`` (stage-2 output, (P, K) net loads).

    ``score_psum_axis``: mesh axis name for the distributed planner
    (``distributed/lb_shard.py``) — the problem's edge arrays are then the
    *local shard* of an edge-sharded comm graph, and the per-phase comm
    scores are completed with a ``lax.psum`` over that axis (loads /
    assignment stay replicated).  ``None`` (default) is the single-device
    path, unchanged."""
    N = problem.num_objects
    P, K = nbr_idx.shape
    loads = problem.loads
    assignment = problem.assignment
    moved = jnp.zeros((N,), bool)
    send = jnp.where(nbr_mask, jnp.maximum(flows, 0.0), 0.0)   # (P, K)
    realized = jnp.zeros_like(send)
    obj_ids = jnp.arange(N)
    node_ids = jnp.arange(P)

    valid_e = problem.edges_src >= 0
    e_src = jnp.where(valid_e, problem.edges_src, 0)
    e_dst = jnp.where(valid_e, problem.edges_dst, 0)
    e_w = jnp.where(valid_e, problem.edges_bytes, 0.0)

    for _ in range(K):
        # Phase slot: each node's largest remaining budget neighbor.
        slot = jnp.argmax(send, axis=1)                         # (P,)
        budget = send[node_ids, slot]
        target = jnp.where(budget > 0, nbr_idx[node_ids, slot], -1)  # (P,)

        # Ordering metric, per the variant.
        if metric == "comm":
            # Bytes each object exchanges with its node's phase target —
            # the active column of comm_graph.object_node_bytes, computed
            # directly (one segment-sum over E per direction instead of
            # the full (N, K) table; the "peers update their patterns"
            # rule is preserved because this reruns on the phase's
            # current assignment).
            tgt_obj = target[assignment]                        # (N,)

            def dir_score(a, b):
                hit = (assignment[b] == tgt_obj[a]) & (tgt_obj[a] >= 0)
                return jax.ops.segment_sum(
                    jnp.where(hit, e_w, 0.0), a, num_segments=N)

            score = dir_score(e_src, e_dst) + dir_score(e_dst, e_src)
            if score_psum_axis is not None:
                score = jax.lax.psum(score, score_psum_axis)
        elif metric == "coord":
            assert problem.coords is not None, "coordinate variant needs coords"
            cent = _centroids(problem.coords, assignment, P)
            tgt = jnp.where(target >= 0, target, 0)[assignment]  # (N,)
            d2 = jnp.sum((problem.coords - cent[tgt]) ** 2, axis=-1)
            score = -d2                                          # closest first
        else:
            raise ValueError(f"unknown metric {metric!r}")

        eligible = ~moved & (target[assignment] >= 0)
        take = _segmented_take_while(assignment, score, loads, eligible, budget)

        shipped = jax.ops.segment_sum(
            jnp.where(take, loads, 0.0), assignment, num_segments=P
        )
        new_owner = jnp.where(target >= 0, target, 0)[assignment]
        assignment = jnp.where(take, new_owner, assignment)
        moved = moved | take
        realized = realized.at[node_ids, slot].add(shipped)
        send = send.at[node_ids, slot].set(0.0)  # slot done (shipped or not)

    residual = jnp.where(nbr_mask, jnp.maximum(flows, 0.0), 0.0) - realized
    return SelectionResult(assignment, moved, realized, residual)


def _centroids(coords: jax.Array, assignment: jax.Array, P: int) -> jax.Array:
    """(P, D) unweighted mean position of each node's objects (paper §IV)."""
    s = jax.ops.segment_sum(coords, assignment, num_segments=P)
    c = jax.ops.segment_sum(jnp.ones(coords.shape[0]), assignment,
                            num_segments=P)
    return s / jnp.maximum(c, 1.0)[:, None]


centroids = _centroids  # public alias
