"""Comparison strategies from the paper's evaluation (§II, §V.C):

  * ``greedy_refine`` — Charm++'s GreedyRefine: keep placement unless a node
    is overloaded; shed heaviest objects to the least-loaded nodes.  Best
    max/avg, worst communication locality (paper Table II).
  * ``greedy``        — Charm++'s GreedyLB: global re-map, sorted objects to
    least-loaded PE; ~100% migrations.
  * ``metis_like``    — from-scratch multilevel k-way partition of the object
    comm graph (heavy-edge matching → greedy graph growing → boundary FM).
    Good locality, near-total migration, like METIS in the paper.
  * ``parmetis_like`` — adaptive *re*-partition: boundary FM refinement from
    the current assignment with a migration-cost term (the ParMETIS
    ``itr``-style tradeoff knob).

All are centralized host planners (numpy), as they are in Charm++; the
paper's distributed contribution is the diffusion strategy in this package.
"""
from __future__ import annotations

import heapq
from typing import Dict, Tuple

import numpy as np

from repro.core import comm_graph


def _np(problem: comm_graph.LBProblem):
    loads = np.asarray(problem.loads, np.float64)
    a = np.asarray(problem.assignment, np.int64).copy()
    src = np.asarray(problem.edges_src, np.int64)
    dst = np.asarray(problem.edges_dst, np.int64)
    w = np.asarray(problem.edges_bytes, np.float64)
    valid = src >= 0
    return loads, a, src[valid], dst[valid], w[valid]


# ---------------------------------------------------------------- greedy ----


def greedy(problem: comm_graph.LBProblem) -> np.ndarray:
    loads, a, *_ = _np(problem)
    P = problem.num_nodes
    new = np.empty_like(a)
    heap = [(0.0, p) for p in range(P)]
    heapq.heapify(heap)
    for o in np.argsort(-loads):
        l, p = heapq.heappop(heap)
        new[o] = p
        heapq.heappush(heap, (l + loads[o], p))
    return new


def greedy_capped(problem: comm_graph.LBProblem,
                  cap: int = 0) -> np.ndarray:
    """GreedyLB under a rigid per-node object-count budget.

    Sorted objects go to the least-loaded node that still has slots —
    the indivisible-slot regime (MoE experts: exactly E/R experts fit a
    rank's weight buffers).  ``cap <= 0`` derives the tightest uniform
    budget ``ceil(N / P)``; like :func:`greedy` it ignores the current
    assignment and the comm graph entirely."""
    loads, a, *_ = _np(problem)
    P = problem.num_nodes
    N = len(loads)
    if cap <= 0:
        cap = -(-N // P)
    new = np.empty_like(a)
    node_load = np.zeros(P)
    node_cnt = np.zeros(P, np.int64)
    for o in np.argsort(-loads):
        open_ = np.nonzero(node_cnt < cap)[0]
        p = open_[np.argmin(node_load[open_])]
        new[o] = p
        node_load[p] += loads[o]
        node_cnt[p] += 1
    return new


def greedy_refine(
    problem: comm_graph.LBProblem, threshold: float = 1.003
) -> np.ndarray:
    """Shed load from nodes above ``threshold * avg`` to the least loaded."""
    loads, a, *_ = _np(problem)
    P = problem.num_nodes
    node_load = np.bincount(a, weights=loads, minlength=P).astype(np.float64)
    avg = node_load.mean()
    heap = [(node_load[p], p) for p in range(P)]
    heapq.heapify(heap)
    new = a.copy()
    objs_by_node = [list(np.nonzero(a == p)[0][np.argsort(loads[a == p])])
                    for p in range(P)]  # ascending; pop() = heaviest
    for p in np.argsort(-node_load):
        while node_load[p] > threshold * avg and objs_by_node[p]:
            o = objs_by_node[p].pop()
            # least-loaded target (lazy heap — skip stale entries)
            while True:
                l, q = heapq.heappop(heap)
                if abs(l - node_load[q]) < 1e-9:
                    break
            if q == p or node_load[q] + loads[o] > node_load[p]:
                heapq.heappush(heap, (node_load[q], q))
                break
            new[o] = q
            node_load[p] -= loads[o]
            node_load[q] += loads[o]
            heapq.heappush(heap, (node_load[q], q))
    return new


# ------------------------------------------------------------ partitioning --


def _csr(n: int, src, dst, w) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Symmetric CSR adjacency from an edge list (duplicates summed)."""
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    ww = np.concatenate([w, w])
    keep = s != d
    s, d, ww = s[keep], d[keep], ww[keep]
    order = np.lexsort((d, s))
    s, d, ww = s[order], d[order], ww[order]
    # merge duplicate (s, d)
    if s.size:
        uniq = np.ones(s.size, bool)
        uniq[1:] = (s[1:] != s[:-1]) | (d[1:] != d[:-1])
        idx = np.cumsum(uniq) - 1
        ms, md = s[uniq], d[uniq]
        mw = np.zeros(uniq.sum())
        np.add.at(mw, idx, ww)
        s, d, ww = ms, md, mw
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, s + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, d, ww


def _heavy_edge_matching(n, indptr, adj, w, vw):
    """Returns coarse ids (n,) — pairs matched by heaviest incident edge."""
    match = np.full(n, -1, np.int64)
    order = np.argsort(-vw)  # heavy vertices first
    for u in order:
        if match[u] >= 0:
            continue
        best, bw = -1, -1.0
        for e in range(indptr[u], indptr[u + 1]):
            v = adj[e]
            if match[v] < 0 and v != u and w[e] > bw:
                best, bw = v, w[e]
        if best >= 0:
            match[u], match[best] = best, u
        else:
            match[u] = u
    coarse = np.full(n, -1, np.int64)
    nxt = 0
    for u in range(n):
        if coarse[u] < 0:
            coarse[u] = coarse[match[u]] = nxt
            nxt += 1
    return coarse, nxt


def _contract(coarse, nc, indptr, adj, w, vw):
    n = vw.shape[0]
    cvw = np.zeros(nc)
    np.add.at(cvw, coarse, vw)
    src = np.repeat(np.arange(n), np.diff(indptr))
    cs, cd = coarse[src], coarse[adj]
    keep = cs != cd
    ip, a2, w2 = _csr(nc, cs[keep], cd[keep], w[keep] / 2.0)  # /2: symmetric dup
    return ip, a2, w2, cvw


def _grow_initial(nc, indptr, adj, w, vw, P, rng):
    """Recursive bisection (pmetis-style) on the coarse graph.

    Each bisection BFS-grows one side to the target weight fraction from a
    peripheral seed, then runs a 2-way boundary FM on the subgraph.  Far
    better k-way quality than one-shot graph growing when P is large
    relative to the coarse graph.
    """
    from collections import deque

    part = np.full(nc, -1, np.int64)

    def bfs_grow(verts, target_w):
        """Grow a region of ~target_w weight inside vertex set `verts`.

        Greedy graph growing (GGGP): from a pseudo-peripheral seed, extend
        by the frontier vertex with the highest connection weight into the
        region — keeps the growth front compact (low surface), unlike plain
        BFS which grows stringy onion shells.
        """
        inset = np.zeros(nc, bool)
        inset[verts] = True
        # peripheral seed: BFS from an arbitrary vertex, take the last reached
        start = verts[0]
        q, seen = deque([start]), {start}
        last = start
        while q:
            u = q.popleft()
            last = u
            for e in range(indptr[u], indptr[u + 1]):
                v = adj[e]
                if inset[v] and v not in seen:
                    seen.add(v)
                    q.append(v)
        side = np.zeros(nc, bool)
        gain = {}          # frontier vertex -> connection weight into region
        heap = [(-1.0, last)]
        gain[last] = 1.0
        acc = 0.0
        while heap and acc < target_w:
            g, u = heapq.heappop(heap)
            if side[u] or gain.get(u, None) != -g:
                continue   # stale heap entry
            side[u] = True
            acc += vw[u]
            for e in range(indptr[u], indptr[u + 1]):
                v = adj[e]
                if inset[v] and not side[v]:
                    gv = gain.get(v, 0.0) + w[e]
                    gain[v] = gv
                    heapq.heappush(heap, (-gv, v))
        # disconnected remainder: top up from any unreached in-set vertices
        if acc < target_w:
            for u in verts:
                if acc >= target_w:
                    break
                if not side[u]:
                    side[u] = True
                    acc += vw[u]
        return side

    def fm2(verts, side, n0_frac, passes=6):
        """2-way boundary FM on the subgraph induced by `verts`."""
        inset = np.zeros(nc, bool)
        inset[verts] = True
        tot = vw[verts].sum()
        cap0, cap1 = tot * n0_frac * 1.05, tot * (1 - n0_frac) * 1.05
        w0 = vw[verts][side[verts]].sum()
        for _ in range(passes):
            moved = False
            for u in verts:
                ext = int_ = 0.0
                for e in range(indptr[u], indptr[u + 1]):
                    v = adj[e]
                    if not inset[v]:
                        continue
                    if side[v] == side[u]:
                        int_ += w[e]
                    else:
                        ext += w[e]
                gain = ext - int_
                if gain <= 1e-12:
                    continue
                if side[u]:   # moving 0→1... side[u] True means in side-0 set
                    if w0 - vw[u] >= tot * n0_frac * 0.95:
                        side[u] = False
                        w0 -= vw[u]
                        moved = True
                else:
                    if w0 + vw[u] <= cap0:
                        side[u] = True
                        w0 += vw[u]
                        moved = True
            if not moved:
                break
        return side

    def bisect(verts, p0, p1):
        if p1 - p0 == 1 or verts.size == 0:
            part[verts] = p0
            return
        nl = (p1 - p0) // 2
        frac = nl / (p1 - p0)
        side = bfs_grow(verts, vw[verts].sum() * frac)
        side = fm2(verts, side, frac)
        left = verts[side[verts]]
        right = verts[~side[verts]]
        if left.size == 0 or right.size == 0:  # degenerate: split by weight
            order = verts[np.argsort(-vw[verts])]
            cw = np.cumsum(vw[order])
            cut = int(np.searchsorted(cw, cw[-1] * frac)) + 1
            left, right = order[:cut], order[cut:]
        bisect(left, p0, p0 + nl)
        bisect(right, p0 + nl, p1)

    bisect(np.arange(nc, dtype=np.int64), 0, P)
    return part


def _fm_refine(part, indptr, adj, w, vw, P, *, passes=8, imbalance=1.03,
               migration_penalty=0.0, original=None):
    """Boundary FM refinement.  gain = cut reduction − migration penalty."""
    node_load = np.zeros(P)
    np.add.at(node_load, part, vw)
    avg = node_load.mean()
    cap = avg * imbalance
    n = vw.shape[0]
    for _ in range(passes):
        improved = False
        # external weight of each vertex toward each adjacent part
        for u in range(n):
            pu = part[u]
            conn: Dict[int, float] = {}
            for e in range(indptr[u], indptr[u + 1]):
                conn[part[adj[e]]] = conn.get(part[adj[e]], 0.0) + w[e]
            internal = conn.get(pu, 0.0)
            best_gain, best_p = 0.0, -1
            for q, wq in conn.items():
                if q == pu:
                    continue
                gain = wq - internal
                if migration_penalty and original is not None:
                    if original[u] == pu:
                        gain -= migration_penalty
                    elif original[u] == q:
                        gain += migration_penalty
                # balance: allow if destination stays under cap, or if the
                # move strictly reduces the maximum of the two loads.
                ok = (node_load[q] + vw[u] <= cap) or (
                    node_load[q] + vw[u] < node_load[pu]
                )
                if ok and gain > best_gain + 1e-12:
                    best_gain, best_p = gain, q
            # Also move for pure balance when grossly overloaded.
            if best_p < 0 and node_load[pu] > cap and conn:
                cands = [q for q in conn if q != pu and
                         node_load[q] + vw[u] < node_load[pu]]
                if cands:
                    best_p = min(cands, key=lambda q: node_load[q])
            if best_p >= 0:
                node_load[pu] -= vw[u]
                node_load[best_p] += vw[u]
                part[u] = best_p
                improved = True
        if not improved:
            break
    return part


def _rcb(coords: np.ndarray, weights: np.ndarray, P: int) -> np.ndarray:
    """Recursive weighted coordinate bisection (geometric seeding)."""
    n = coords.shape[0]
    part = np.zeros(n, np.int64)

    def rec(idx, p0, p1):
        if p1 - p0 <= 1 or idx.size == 0:
            part[idx] = p0
            return
        nl = (p1 - p0) // 2
        axis = int(np.argmax(coords[idx].max(0) - coords[idx].min(0)))
        order = idx[np.argsort(coords[idx, axis], kind="stable")]
        cw = np.cumsum(weights[order])
        target = cw[-1] * nl / (p1 - p0)
        cut = int(np.searchsorted(cw, target)) + 1
        cut = min(max(cut, 1), idx.size - 1)
        rec(order[:cut], p0, p0 + nl)
        rec(order[cut:], p0 + nl, p1)

    rec(np.arange(n), 0, P)
    return part


def metis_like(problem: comm_graph.LBProblem, *, coarsen_to: int = 256,
               seed: int = 0, use_coords: bool = False) -> np.ndarray:
    """k-way partition from scratch (ignores current placement).

    Default is the pure graph path (multilevel heavy-edge matching → greedy
    graph growing → FM): real METIS sees only the graph, so part labels are
    arbitrary relative to the current placement — that is exactly why the
    paper measures ~87-99% migrations for it (Table II).  ``use_coords``
    switches to geometric seeding (RCB) + FM polish, which incidentally
    aligns labels with a tiled initial mapping (useful as an extra baseline,
    not as the METIS stand-in).
    """
    loads, a, src, dst, w = _np(problem)
    P = problem.num_nodes
    n = loads.shape[0]
    rng = np.random.default_rng(seed)

    if use_coords and problem.coords is not None:
        coords = np.asarray(problem.coords, np.float64)
        part = _rcb(coords, loads, P)
        indptr, adj, ew = _csr(n, src, dst, w)
        return _fm_refine(part, indptr, adj, ew, loads, P, passes=4)

    levels = []
    indptr, adj, ew = _csr(n, src, dst, w)
    vw = loads.copy()
    cur_n = n
    # Coarsen only when the graph is genuinely large; recursive bisection on
    # ≤ ~8k vertices is fast in full resolution and much higher quality.
    while cur_n > max(coarsen_to, 16 * P, 8192) and len(levels) < 12:
        coarse, nc = _heavy_edge_matching(cur_n, indptr, adj, ew, vw)
        if nc >= cur_n:  # no progress
            break
        levels.append(coarse)
        indptr, adj, ew, vw = _contract(coarse, nc, indptr, adj, ew, vw)
        cur_n = nc
    part = _grow_initial(cur_n, indptr, adj, ew, vw, P, rng)
    part = _fm_refine(part, indptr, adj, ew, vw, P)
    # Uncoarsen with refinement at each level.
    graphs = [(indptr, adj, ew, vw)]
    ip2, a2, w2 = _csr(n, src, dst, w)
    fine = [(ip2, a2, w2, loads)]
    # rebuild intermediate graphs for projection
    gi, ga, gw, gv = ip2, a2, w2, loads.copy()
    inter = [(gi, ga, gw, gv)]
    for coarse in levels:
        gi, ga, gw, gv = _contract(coarse, coarse.max() + 1, gi, ga, gw, gv)
        inter.append((gi, ga, gw, gv))
    for lvl in range(len(levels) - 1, -1, -1):
        part = part[levels[lvl]]
        gi, ga, gw, gv = inter[lvl]
        part = _fm_refine(part, gi, ga, gw, gv, P, passes=4)
    return part.astype(np.int64)


def parmetis_like(problem: comm_graph.LBProblem, *, itr: float = 1000.0,
                  passes: int = 8, imbalance: float = 1.05) -> np.ndarray:
    """Adaptive repartitioning from the current assignment.

    ``itr`` maps to ParMETIS's inter-processor-redistribution cost knob:
    higher ⇒ migrations are more expensive ⇒ fewer moves.  The paper notes
    (§V.C) this tradeoff is hard to tune; we expose it directly.
    """
    loads, a, src, dst, w = _np(problem)
    P = problem.num_nodes
    indptr, adj, ew = _csr(loads.shape[0], src, dst, w)
    scale = (ew.sum() / max(len(ew), 1)) * itr / 1000.0
    part = _fm_refine(a.copy(), indptr, adj, ew, loads, P, passes=passes,
                      imbalance=imbalance, migration_penalty=scale,
                      original=a)
    return part
