"""Core library: communication-aware diffusion load balancing (the paper's
contribution), its coordinate variant, baselines, and metrics."""
from repro.core.api import LBPlan, STRATEGIES, diffusion_lb, run_strategy
from repro.core.comm_graph import (
    LBProblem,
    make_problem,
    node_comm_matrix,
    node_loads,
    object_node_bytes,
)
from repro.core.metrics import evaluate

__all__ = [
    "LBPlan",
    "LBProblem",
    "STRATEGIES",
    "diffusion_lb",
    "evaluate",
    "make_problem",
    "node_comm_matrix",
    "node_loads",
    "object_node_bytes",
    "run_strategy",
]
