"""Within-node (across-thread) refinement — paper §III.D.

After the inter-node stages commit (via "proxy tokens" in Charm++; via the
final assignment array here), load is balanced across the ``T`` threads of
each node considering *load only*, no communication.  We use exact LPT
(longest-processing-time-first) per node — the planning set per node is
small, so a host loop is appropriate; this phase is not jitted in Charm++
either.
"""
from __future__ import annotations

import numpy as np


def within_node_lpt(
    loads: np.ndarray,
    assignment: np.ndarray,
    num_nodes: int,
    threads_per_node: int,
) -> np.ndarray:
    """Return (N,) thread index in [0, T) for every object.

    Global PE id of an object is then ``assignment * T + thread``.
    """
    loads = np.asarray(loads, np.float64)
    assignment = np.asarray(assignment)
    thread = np.zeros(assignment.shape[0], np.int32)
    for node in range(num_nodes):
        idx = np.nonzero(assignment == node)[0]
        if idx.size == 0:
            continue
        order = idx[np.argsort(-loads[idx])]
        tl = np.zeros(threads_per_node)
        for o in order:
            t = int(np.argmin(tl))
            tl[t] += loads[o]
            thread[o] = t
    return thread


def flatten_hierarchy(assignment, thread, threads_per_node: int):
    """Object→global-PE map from (node, thread)."""
    return np.asarray(assignment) * threads_per_node + np.asarray(thread)
