"""Within-node (across-thread) refinement — paper §III.D.

After the inter-node stages commit (via "proxy tokens" in Charm++; via the
final assignment array here), load is balanced across the ``T`` threads of
each node considering *load only*, no communication.  We use exact LPT
(longest-processing-time-first) per node.

:func:`lpt_threads` is the production implementation: a jittable,
vectorized LPT that runs on device, so the engine can emit two-level
(node, thread) placements inside ``jit`` / ``lax.scan`` / ``vmap``
(``LBEngine.plan_hier_fn``, the scanned replay layers).  The classic
sequential recurrence — "assign the next-heaviest object to the
least-loaded thread" — is reformulated rank-parallel: objects are sorted
once by ``(node, -load, index)`` (stable), giving every object a *rank*
within its node, and a ``lax.while_loop`` over ranks assigns **every
node's rank-r object in one step** (the per-node accumulator ``argmin``
is a vectorized (P, T) reduction).  Sequential depth is therefore the
largest per-node object count, not N.

:func:`within_node_lpt` is the host NumPy reference, kept as the oracle.
Both resolve ties identically — stable descending-load order (index
breaks load ties) and ``argmin`` taking the lowest thread index — and
both accumulate thread loads in float32 in the same order, so the two
implementations agree bit-for-bit (tests/test_hierarchical.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


@functools.partial(jax.jit, static_argnames=("num_nodes", "threads_per_node"))
def lpt_threads(
    loads: jax.Array,
    assignment: jax.Array,
    *,
    num_nodes: int,
    threads_per_node: int,
) -> jax.Array:
    """(N,) i32 thread index in [0, T) per object — exact per-node LPT.

    Pure and traceable with static ``(num_nodes, threads_per_node)``;
    safe under ``jit`` / ``lax.scan`` / ``lax.cond`` / ``vmap``.  Global
    PE id of an object is ``assignment * T + thread``
    (:func:`flatten_hierarchy`).
    """
    N = loads.shape[0]
    P, T = int(num_nodes), int(threads_per_node)
    loads = jnp.asarray(loads, jnp.float32)
    assignment = jnp.asarray(assignment, jnp.int32)

    # Stable (node asc, load desc, index asc) order: lexsort's last key is
    # primary and the sort is stable, so equal loads keep index order.
    order = jnp.lexsort((-loads, assignment))
    counts = jax.ops.segment_sum(
        jnp.ones(N, jnp.int32), assignment, num_segments=P)
    starts = jnp.cumsum(counts) - counts                       # (P,)
    max_rank = counts.max()

    def cond(carry):
        return carry[0] < max_rank

    def body(carry):
        r, acc, thread = carry
        pos = jnp.clip(starts + r, 0, max(N - 1, 0))
        obj = order[pos]                                       # (P,)
        valid = r < counts                                     # (P,)
        t = jnp.argmin(acc, axis=1).astype(jnp.int32)          # (P,)
        add = jnp.where(valid, loads[obj], 0.0)
        acc = acc.at[jnp.arange(P), t].add(add)
        # out-of-range scatter indices are dropped, so invalid lanes
        # (node exhausted; `obj` is a clipped duplicate) write nothing
        thread = thread.at[jnp.where(valid, obj, N)].set(t, mode="drop")
        return r + 1, acc, thread

    init = (jnp.int32(0), jnp.zeros((P, T), jnp.float32),
            jnp.zeros(N, jnp.int32))
    _, _, thread = jax.lax.while_loop(cond, body, init)
    return thread


def thread_loads(
    loads: jax.Array,
    assignment: jax.Array,
    thread: jax.Array,
    *,
    num_nodes: int,
    threads_per_node: int,
) -> jax.Array:
    """(P*T,) total load per global PE (traceable)."""
    pe = jnp.asarray(assignment) * threads_per_node + jnp.asarray(thread)
    return jax.ops.segment_sum(
        jnp.asarray(loads, jnp.float32), pe,
        num_segments=num_nodes * threads_per_node)


def within_node_lpt(
    loads: np.ndarray,
    assignment: np.ndarray,
    num_nodes: int,
    threads_per_node: int,
) -> np.ndarray:
    """Host NumPy LPT oracle — same ties, same f32 accumulation order as
    :func:`lpt_threads` (stable descending sort; argmin lowest index)."""
    loads = np.asarray(loads, np.float32)
    assignment = np.asarray(assignment)
    thread = np.zeros(assignment.shape[0], np.int32)
    for node in range(num_nodes):
        idx = np.nonzero(assignment == node)[0]
        if idx.size == 0:
            continue
        order = idx[np.argsort(-loads[idx], kind="stable")]
        tl = np.zeros(threads_per_node, np.float32)
        for o in order:
            t = int(np.argmin(tl))
            tl[t] += loads[o]
            thread[o] = t
    return thread


def flatten_hierarchy(assignment, thread, threads_per_node: int):
    """Object→global-PE map from (node, thread).  Works on both NumPy and
    JAX arrays (traceable)."""
    return assignment * threads_per_node + thread
