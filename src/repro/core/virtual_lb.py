"""Stage 2 — virtual load balancing (paper §III.B).

First-order diffusion (Cybenko [3], Hu-Blake [15]) restricted to the stage-1
neighbor graph.  Only load *magnitudes* are exchanged; the output is the
per-edge net transfer each node should realize with objects in stage 3.

Paper constraint — **single-hop migrations**: load received during the
iteration is frozen (it may not be re-sent), so every unit of transferred
load traverses exactly one edge from its originating node.  This is the
default; ``single_hop=False`` gives the classic unconstrained scheme.

Representation: padded neighbor lists ``nbr_idx/nbr_mask (P, K)``.  Because
the graph is symmetric, "receive" is also a gather: node i receives from
neighbor j exactly what j's row pushed toward i, located via the precomputed
reverse-slot table.  This keeps the sweep gather-only (no scatters), which is
what the Pallas kernel (kernels/diffusion) exploits.

The inner sweep is pluggable: ``step_fn=None`` uses the pure-jnp reference;
the production path passes ``kernels.diffusion.ops.diffusion_sweep``.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class VirtualLBResult(NamedTuple):
    target_loads: jax.Array  # (P,) converged virtual node loads
    flows: jax.Array         # (P, K) net load to send to each neighbor (+=send)
    iters: jax.Array         # scalar i32
    residual: jax.Array      # scalar f32 — final neighborhood imbalance


def reverse_slots(nbr_idx: jax.Array, nbr_mask: jax.Array) -> jax.Array:
    """(P, K) i32: rev[i, k] = slot of node i in the list of nbr_idx[i, k].

    Defined only where nbr_mask; padded slots get 0 (masked out by callers).
    """
    j = jnp.where(nbr_mask, nbr_idx, 0)                 # (P, K)
    their_lists = nbr_idx[j]                            # (P, K, K)
    me = jnp.arange(nbr_idx.shape[0])[:, None, None]
    hit = their_lists == me                             # (P, K, K)
    return jnp.where(nbr_mask, jnp.argmax(hit, axis=-1), 0).astype(jnp.int32)


def reference_sweep(x, own, nbr_idx, nbr_mask, rev, alpha, single_hop):
    """One diffusion sweep.  Returns (x_new, own_new, net_flow_step (P,K)).

    Pure-jnp oracle for the Pallas kernel (kernels/diffusion/ref.py re-exports
    this).  Gather-only; see module docstring.
    """
    safe_nbr = jnp.where(nbr_mask, nbr_idx, 0)
    xn = jnp.where(nbr_mask, x[safe_nbr], x[:, None])
    push = jnp.maximum(alpha * (x[:, None] - xn), 0.0) * nbr_mask
    if single_hop:
        tot = push.sum(axis=1)
        scale = jnp.where(tot > 0, jnp.minimum(1.0, own / (tot + 1e-30)), 1.0)
        push = push * scale[:, None]
    # recv[i, k]: what neighbor j = nbr_idx[i,k] pushed toward i this sweep.
    recv = jnp.where(nbr_mask, push[safe_nbr, rev], 0.0)
    x_new = x - push.sum(axis=1) + recv.sum(axis=1)
    own_new = own - push.sum(axis=1)
    return x_new, own_new, push - recv


def neighborhood_residual(x, nbr_idx, nbr_mask):
    """max over nodes of (max deviation in {i}∪N(i)) / global mean load."""
    safe_nbr = jnp.where(nbr_mask, nbr_idx, 0)
    xn = jnp.where(nbr_mask, x[safe_nbr], x[:, None])
    allx = jnp.concatenate([x[:, None], xn], axis=1)       # (P, K+1)
    m = jnp.concatenate([jnp.ones_like(x[:, None], bool), nbr_mask], axis=1)
    cnt = m.sum(axis=1)
    mean = jnp.where(cnt > 0, (allx * m).sum(axis=1) / cnt, x)
    dev = jnp.where(m, jnp.abs(allx - mean[:, None]), 0.0).max(axis=1)
    gmean = x.mean() + 1e-30
    return (dev / gmean).max()


@functools.partial(
    jax.jit,
    static_argnames=("max_iters", "single_hop", "step_fn"),
)
def virtual_balance(
    node_loads: jax.Array,
    nbr_idx: jax.Array,
    nbr_mask: jax.Array,
    *,
    alpha: Optional[float] = None,
    tol: float = 0.02,
    max_iters: int = 512,
    single_hop: bool = True,
    step_fn: Optional[Callable] = None,
) -> VirtualLBResult:
    """Iterate diffusion sweeps until every neighborhood is balanced.

    Args:
      node_loads: (P,) current per-node load.
      nbr_idx / nbr_mask: (P, K) stage-1 neighbor table.
      alpha: diffusion coefficient; default 1/(K+1) (stable first-order
        scheme for max degree K).
      tol: convergence threshold on max neighborhood deviation / mean load
        (the paper's "load variance in each neighborhood below a threshold").
      single_hop: freeze received load (paper default).
      step_fn: sweep implementation (defaults to :func:`reference_sweep`).
    """
    P, K = nbr_idx.shape
    if alpha is None:
        alpha = 1.0 / (K + 1.0)
    alpha = jnp.float32(alpha)
    sweep = step_fn or reference_sweep
    rev = reverse_slots(nbr_idx, nbr_mask)

    class S(NamedTuple):
        x: jax.Array
        own: jax.Array
        flows: jax.Array
        it: jax.Array
        res: jax.Array
        stall: jax.Array   # consecutive sweeps with negligible load movement

    def cond(s: S):
        # Stop on convergence, iteration cap, or stall: under the single-hop
        # constraint the scheme can freeze (all "own" load spent) while the
        # residual is still above tol — further sweeps are no-ops.
        return (s.it < max_iters) & (s.res > tol) & (s.stall < 3)

    def body(s: S):
        x, own, df = sweep(
            s.x, s.own, nbr_idx, nbr_mask, rev, alpha, single_hop
        )
        moved = jnp.abs(x - s.x).sum()
        stalled = moved <= 1e-6 * (jnp.abs(x).mean() + 1e-30)
        return S(x, own, s.flows + df, s.it + 1,
                 neighborhood_residual(x, nbr_idx, nbr_mask),
                 jnp.where(stalled, s.stall + 1, 0))

    x0 = node_loads.astype(jnp.float32)
    init = S(
        x0, x0, jnp.zeros_like(nbr_mask, jnp.float32), jnp.int32(0),
        neighborhood_residual(x0, nbr_idx, nbr_mask), jnp.int32(0),
    )
    s = jax.lax.while_loop(cond, body, init)
    return VirtualLBResult(s.x, s.flows, s.it, s.res)
