"""Stage 2 — virtual load balancing (paper §III.B).

First-order diffusion (Cybenko [3], Hu-Blake [15]) restricted to the stage-1
neighbor graph.  Only load *magnitudes* are exchanged; the output is the
per-edge net transfer each node should realize with objects in stage 3.

Paper constraint — **single-hop migrations**: load received during the
iteration is frozen (it may not be re-sent), so every unit of transferred
load traverses exactly one edge from its originating node.  This is the
default; ``single_hop=False`` gives the classic unconstrained scheme.

Representation: padded neighbor lists ``nbr_idx/nbr_mask (P, K)``.  Because
the graph is symmetric, "receive" is also a gather: node i receives from
neighbor j exactly what j's row pushed toward i, located via the precomputed
reverse-slot table.  This keeps the sweep gather-only (no scatters), which is
what the Pallas kernel (kernels/diffusion) exploits.

The inner sweep is pluggable: ``step_fn=None`` uses the pure-jnp reference;
the production path passes ``kernels.diffusion.ops.diffusion_sweep``.

The fixed-point loop runs in *chunks*: ``virtual_balance`` is a
``jax.lax.while_loop`` over ``sweep_chunk``-sweep blocks, each block
applying up to S masked sweeps with per-sweep early exit
(:func:`reference_nsweeps`).  Chunk granularity changes only how often the
host-visible loop condition is evaluated — the per-sweep activity mask
replicates the per-sweep ``while_loop`` decisions exactly, so results are
bit-for-bit independent of ``sweep_chunk``.  ``chunk_fn`` swaps in a fused
implementation of the whole S-sweep block (the production path passes
``kernels.diffusion.ops.diffusion_nsweeps``, which keeps the neighbor
tables VMEM-resident across the block on TPU).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class VirtualLBResult(NamedTuple):
    target_loads: jax.Array  # (P,) converged virtual node loads
    flows: jax.Array         # (P, K) net load to send to each neighbor (+=send)
    iters: jax.Array         # scalar i32
    residual: jax.Array      # scalar f32 — final neighborhood imbalance


def reverse_slots(nbr_idx: jax.Array, nbr_mask: jax.Array) -> jax.Array:
    """(P, K) i32: rev[i, k] = slot of node i in the list of nbr_idx[i, k].

    Defined only where nbr_mask; padded slots get 0 (masked out by callers).
    """
    j = jnp.where(nbr_mask, nbr_idx, 0)                 # (P, K)
    their_lists = nbr_idx[j]                            # (P, K, K)
    me = jnp.arange(nbr_idx.shape[0])[:, None, None]
    hit = their_lists == me                             # (P, K, K)
    return jnp.where(nbr_mask, jnp.argmax(hit, axis=-1), 0).astype(jnp.int32)


def reference_sweep(x, own, nbr_idx, nbr_mask, rev, alpha, single_hop):
    """One diffusion sweep.  Returns (x_new, own_new, net_flow_step (P,K)).

    Pure-jnp oracle for the Pallas kernel (kernels/diffusion/ref.py re-exports
    this).  Gather-only; see module docstring.
    """
    # gathers use the flattened jnp.take(..., mode="clip") forms the native
    # TPU kernels lower (see kernels/diffusion); a gather copies elements
    # exactly, so this is bit-identical to advanced indexing
    safe_nbr = jnp.where(nbr_mask, nbr_idx, 0)
    xn = jnp.where(nbr_mask, jnp.take(x, safe_nbr, axis=0, mode="clip"),
                   x[:, None])
    push = jnp.maximum(alpha * (x[:, None] - xn), 0.0) * nbr_mask
    if single_hop:
        tot = push.sum(axis=1)
        scale = jnp.where(tot > 0, jnp.minimum(1.0, own / (tot + 1e-30)), 1.0)
        push = push * scale[:, None]
    # recv[i, k]: what neighbor j = nbr_idx[i,k] pushed toward i this sweep.
    K = nbr_idx.shape[1]
    flat = safe_nbr * K + jnp.where(nbr_mask, rev, 0)
    recv = jnp.where(
        nbr_mask, jnp.take(push.reshape(-1), flat, mode="clip"), 0.0)
    x_new = x - push.sum(axis=1) + recv.sum(axis=1)
    own_new = own - push.sum(axis=1)
    return x_new, own_new, push - recv


def sweep_chunk_body(sweep, nbr_idx, nbr_mask, rev, alpha, single_hop,
                     tol, max_iters, *, residual_fn=None, sum_fn=None,
                     mean_abs_fn=None):
    """``(i, carry) -> carry`` applying one masked early-exit sweep.

    ``carry = (x, own, flow, it, res, stall)``.  The activity predicate is
    the same one the outer fixed-point loop checks, evaluated *before* the
    sweep — once it goes false mid-chunk the state passes through
    unchanged, so a chunk of S masked sweeps is bit-for-bit equal to S
    steps of the per-sweep ``while_loop``.  Shared by the pure-jnp chunk
    (:func:`reference_nsweeps`) and the fused Pallas kernel
    (``kernels/diffusion/kernel.py``), which keeps the two paths
    semantically identical by construction.

    The three reduction hooks default to the local (single-device) forms;
    the mesh-sharded planner (``distributed/lb_shard.py``) passes
    collective equivalents (``psum``/``pmax`` over the node shards) so the
    early-exit and stall decisions — the only global state in the loop —
    are made on the same quantities, keeping the sharded and single-device
    iteration counts identical by construction.
    """
    if residual_fn is None:
        residual_fn = lambda x2: neighborhood_residual(  # noqa: E731
            x2, nbr_idx, nbr_mask)
    if sum_fn is None:
        sum_fn = lambda v: v.sum()                       # noqa: E731
    if mean_abs_fn is None:
        mean_abs_fn = lambda x2: jnp.abs(x2).mean()      # noqa: E731

    def body(_, carry):
        x, own, flow, it, res, stall = carry
        active = (it < max_iters) & (res > tol) & (stall < 3)
        x2, own2, df = sweep(x, own, nbr_idx, nbr_mask, rev, alpha,
                             single_hop)
        moved = sum_fn(jnp.abs(x2 - x))
        stalled = moved <= 1e-6 * (mean_abs_fn(x2) + 1e-30)
        res2 = residual_fn(x2)

        def keep(new, old):
            return jnp.where(active, new, old)

        return (keep(x2, x), keep(own2, own), keep(flow + df, flow),
                keep(it + 1, it), keep(res2, res),
                keep(jnp.where(stalled, stall + 1, jnp.int32(0)), stall))

    return body


def reference_nsweeps(x, own, flow, it, res, stall, nbr_idx, nbr_mask, rev,
                      alpha, *, n_sweeps: int, single_hop: bool, tol,
                      max_iters, step_fn: Optional[Callable] = None):
    """Pure-jnp S-sweep chunk with per-sweep early exit.

    The CPU production path and the oracle for the fused Pallas kernel
    (``diffusion_nsweeps_pallas``).  Returns the updated
    ``(x, own, flow, it, res, stall)`` carry.
    """
    body = sweep_chunk_body(step_fn or reference_sweep, nbr_idx, nbr_mask,
                            rev, alpha, single_hop, tol, max_iters)
    return jax.lax.fori_loop(0, n_sweeps, body,
                             (x, own, flow, it, res, stall))


def neighborhood_deviation(x, xn, nbr_mask):
    """(P,) max |load - neighborhood mean| over {i}∪N(i), given the
    *pre-gathered* neighbor loads ``xn`` (P, K).

    The local core of :func:`neighborhood_residual`, shared with the
    mesh-sharded planner (``distributed/lb_shard.py``), whose ``xn``
    arrives via the ppermute halo ring — keeping the two residuals
    identical by construction."""
    allx = jnp.concatenate([x[:, None], xn], axis=1)       # (P, K+1)
    m = jnp.concatenate([jnp.ones_like(x[:, None], bool), nbr_mask], axis=1)
    cnt = m.sum(axis=1)
    mean = jnp.where(cnt > 0, (allx * m).sum(axis=1) / cnt, x)
    return jnp.where(m, jnp.abs(allx - mean[:, None]), 0.0).max(axis=1)


def neighborhood_residual(x, nbr_idx, nbr_mask):
    """max over nodes of (max deviation in {i}∪N(i)) / global mean load."""
    safe_nbr = jnp.where(nbr_mask, nbr_idx, 0)
    xn = jnp.where(nbr_mask, jnp.take(x, safe_nbr, axis=0, mode="clip"),
                   x[:, None])
    dev = neighborhood_deviation(x, xn, nbr_mask)
    gmean = x.mean() + 1e-30
    return (dev / gmean).max()


@functools.partial(
    jax.jit,
    static_argnames=("max_iters", "single_hop", "step_fn", "sweep_chunk",
                     "chunk_fn"),
)
def virtual_balance(
    node_loads: jax.Array,
    nbr_idx: jax.Array,
    nbr_mask: jax.Array,
    *,
    alpha: Optional[float] = None,
    tol: float = 0.02,
    max_iters: int = 512,
    single_hop: bool = True,
    step_fn: Optional[Callable] = None,
    sweep_chunk: int = 8,
    chunk_fn: Optional[Callable] = None,
) -> VirtualLBResult:
    """Iterate diffusion sweeps until every neighborhood is balanced.

    Args:
      node_loads: (P,) current per-node load.
      nbr_idx / nbr_mask: (P, K) stage-1 neighbor table.
      alpha: diffusion coefficient; default 1/(K+1) (stable first-order
        scheme for max degree K).
      tol: convergence threshold on max neighborhood deviation / mean load
        (the paper's "load variance in each neighborhood below a threshold").
      single_hop: freeze received load (paper default).
      step_fn: sweep implementation (defaults to :func:`reference_sweep`);
        used only when ``chunk_fn`` is None.
      sweep_chunk: sweeps per ``while_loop`` body (S).  Results are
        bit-for-bit independent of this value (per-sweep activity mask);
        larger chunks amortize the loop condition and, with a fused
        ``chunk_fn``, the HBM table traffic.
      chunk_fn: fused S-sweep block implementation with the
        :func:`reference_nsweeps` signature (minus ``step_fn``).  The
        production path passes ``kernels.diffusion.ops.diffusion_nsweeps``.
    """
    P, K = nbr_idx.shape
    if alpha is None:
        alpha = 1.0 / (K + 1.0)
    alpha = jnp.float32(alpha)
    rev = reverse_slots(nbr_idx, nbr_mask)
    n_sweeps = max(1, min(int(sweep_chunk), int(max_iters)))
    if chunk_fn is None:
        chunk_fn = functools.partial(reference_nsweeps, step_fn=step_fn)

    class S(NamedTuple):
        x: jax.Array
        own: jax.Array
        flows: jax.Array
        it: jax.Array
        res: jax.Array
        stall: jax.Array   # consecutive sweeps with negligible load movement

    def cond(s: S):
        # Stop on convergence, iteration cap, or stall: under the single-hop
        # constraint the scheme can freeze (all "own" load spent) while the
        # residual is still above tol — further sweeps are no-ops.
        return (s.it < max_iters) & (s.res > tol) & (s.stall < 3)

    def body(s: S):
        return S(*chunk_fn(
            s.x, s.own, s.flows, s.it, s.res, s.stall,
            nbr_idx, nbr_mask, rev, alpha,
            n_sweeps=n_sweeps, single_hop=single_hop, tol=tol,
            max_iters=max_iters,
        ))

    x0 = node_loads.astype(jnp.float32)
    init = S(
        x0, x0, jnp.zeros_like(nbr_mask, jnp.float32), jnp.int32(0),
        neighborhood_residual(x0, nbr_idx, nbr_mask), jnp.int32(0),
    )
    s = jax.lax.while_loop(cond, body, init)
    return VirtualLBResult(s.x, s.flows, s.it, s.res)
