"""Stage 1 — distributed K-neighbor selection (paper §III.A).

The paper's protocol is asynchronous message passing; on TPU we realize the
same fixed point as *synchronous vectorized rounds* (see DESIGN.md §2):

  round:
    1. every node with l = K - confirmed missing neighbors sends requests to
       its top ceil(l/2) untried candidates, ordered by decreasing
       communication volume (or any caller-provided preference score);
    2. request targets grant up to  K - confirmed - granted  incoming
       requests (the paper's `holds` bookkeeping), preferring high-comm
       requesters;
    3. requesters confirm grants up to their remaining budget
       K - confirmed - (grants they handed out this round) and send the final
       ack — only acked pairs become edges, un-acked grants release their
       hold, exactly as in the paper.

Rounds iterate until every node has min(K, #candidates) neighbors or
``max_rounds`` is hit.  The degree bound (≤ K) holds by construction at every
round — see tests/test_neighbor_selection.py property tests.

Representation: dense (P, P) preference/state matrices — this is the
simulator-scale path (the paper's simulator is also centralized).  The
distributed runtime shards rows; see core/distributed.py.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG = jnp.float32(-1e30)


class NeighborResult(NamedTuple):
    nbr_idx: jax.Array     # (P, K) neighbor node ids, -1 padded
    nbr_mask: jax.Array    # (P, K) bool
    degree: jax.Array      # (P,) confirmed neighbor count
    rounds: jax.Array      # scalar i32 — protocol rounds executed


def _topk_mask(score: jax.Array, k: jax.Array, k_max: int) -> jax.Array:
    """Row-wise boolean mask of the k(row) highest-scoring valid entries.

    ``score`` is (P, P) with invalid entries already set to NEG; ``k`` is a
    per-row (P,) count bounded by the static ``k_max``.  Uses
    ``lax.top_k`` (O(P·k_max) selection) rather than full row sorts —
    the sorts dominated planning time at simulator scale.  ``top_k``
    breaks ties toward the lower index, matching stable descending
    argsort, so the selected sets are identical to the sort-based
    formulation."""
    P = score.shape[0]
    kk = min(int(k_max), P)
    vals, idx = jax.lax.top_k(score, kk)                     # (P, kk)
    take = (vals > NEG / 2) & (jnp.arange(kk)[None, :] < k[:, None])
    rows = jnp.arange(P)[:, None]
    return jnp.zeros_like(score, bool).at[rows, idx].set(take)


@functools.partial(jax.jit, static_argnames=("k", "max_rounds"))
def select_neighbors(
    preference: jax.Array,
    *,
    k: int,
    max_rounds: int = 64,
) -> NeighborResult:
    """Run the handshake protocol.

    Args:
      preference: (P, P) symmetric-ish score matrix; entry [i, j] is how much
        node i wants node j as a neighbor (comm volume for the
        communication variant, negated centroid distance for the coordinate
        variant).  Non-candidates (zero comm) must be <= 0; the diagonal is
        ignored.
      k: desired degree K.
      max_rounds: protocol round bound (paper's "upper-bound number of
        iterations").
    """
    P = preference.shape[0]
    eye = jnp.eye(P, dtype=bool)
    candidate = (preference > 0) & ~eye
    pref = jnp.where(candidate, preference, NEG)
    # Number of neighbors a node can ever confirm.
    max_possible = jnp.minimum(candidate.sum(axis=1), k)

    class S(NamedTuple):
        edges: jax.Array   # (P, P) bool, symmetric confirmed pairs
        tried: jax.Array   # (P, P) bool, requests already issued by row node
        rounds: jax.Array
        stall: jax.Array   # consecutive rounds without a new confirmed pair

    def degree(edges):
        return edges.sum(axis=1)

    def cond(s: S):
        return (
            (s.rounds < max_rounds)
            & (s.stall < 4)  # give a couple of tried-reset retries, then stop
            & jnp.any(degree(s.edges) < max_possible)
        )

    def body(s: S) -> S:
        deg = degree(s.edges)
        need = jnp.maximum(k - deg, 0)
        # -- 1. requests: top ceil(need/2) untried, unconfirmed candidates.
        n_req = jnp.where(need > 0, (need + 1) // 2, 0)
        req_score = jnp.where(s.tried | s.edges, NEG, pref)
        req = _topk_mask(req_score, n_req, k)                    # req[i, j]: i→j
        # -- 1b. mutual requests pair directly (in the async protocol one
        # side's request arrives first and is simply granted; the symmetric
        # special case must not double-count both nodes' budgets).
        mutual = req & req.T
        mut_take = _topk_mask(jnp.where(mutual, pref, NEG), need, k)
        mut_edge = mut_take & mut_take.T
        edges = s.edges | mut_edge
        deg = degree(edges)
        req = req & ~mutual
        # -- 2. grants: target j takes top (K - deg_j) incoming requests.
        inc_score = jnp.where(req.T, pref, NEG)               # [j, i] view
        grant_budget = jnp.maximum(k - deg, 0)
        grant_t = _topk_mask(inc_score, grant_budget, k)         # [j, i]: j grants i
        grant = grant_t.T                                     # [i, j]
        granted_out = grant_t.sum(axis=1)                     # grants j handed out
        # -- 3. acks: requester i confirms top (K - deg_i - granted_i) grants.
        ack_budget = jnp.maximum(k - deg - granted_out, 0)
        ack_score = jnp.where(grant, pref, NEG)
        ack = _topk_mask(ack_score, ack_budget, k)               # [i, j] confirmed
        edges = edges | ack | ack.T
        # A node whose untried candidate list is exhausted but who is still
        # under-degree gets its tried set cleared (retry next round — the
        # rejections were due to transient `holds`).
        tried = s.tried | req
        untried_left = (jnp.where(tried | edges, NEG, pref) > NEG / 2).sum(axis=1)
        exhausted = (untried_left == 0) & (degree(edges) < max_possible)
        tried = jnp.where(exhausted[:, None], False, tried)
        progressed = edges.sum() > s.edges.sum()
        stall = jnp.where(progressed, 0, s.stall + 1)
        return S(edges, tried, s.rounds + 1, stall)

    init = S(jnp.zeros((P, P), bool), jnp.zeros((P, P), bool), jnp.int32(0),
             jnp.int32(0))
    final = jax.lax.while_loop(cond, body, init)

    deg = final.edges.sum(axis=1)
    # Extract padded (P, K) neighbor table, highest-preference first.
    nbr_score = jnp.where(final.edges, pref, NEG)
    _, order = jax.lax.top_k(nbr_score, min(k, P))            # (P, K)
    taken = jnp.take_along_axis(final.edges, order, axis=1)
    nbr_idx = jnp.where(taken, order, -1).astype(jnp.int32)
    return NeighborResult(nbr_idx, taken, deg.astype(jnp.int32), final.rounds)


def comm_preference(node_comm: jax.Array) -> jax.Array:
    """Preference matrix for the communication variant.

    Candidates are ordered by decreasing communication volume.  Nodes with
    zero communication remain *last-resort* candidates (tiny epsilon floor):
    the paper observes that under-filled nodes "may choose to migrate objects
    to a neighbor with which [they have] no communication in an attempt to
    distribute load" (§V.B) — that is what raises ext/int comm at high K in
    Table I.
    """
    P = node_comm.shape[0]
    eps = jnp.float32(1e-6) * (1.0 + node_comm.max())
    return jnp.where(jnp.eye(P, dtype=bool), 0.0, node_comm + eps)


def coordinate_preference(centroids: jax.Array) -> jax.Array:
    """Preference for the coordinate variant (§IV): inverse centroid distance.

    Note the paper's caveat: every node scores *all* others (O(P^2)), which is
    the variant's scalability limit; kept faithful here.
    """
    d2 = jnp.sum(
        (centroids[:, None, :] - centroids[None, :, :]) ** 2, axis=-1
    )
    return 1.0 / (d2 + 1e-9)
