"""Problem representation for the load balancer.

Mirrors the paper's simulator input (§V): per-object loads, optional logical
coordinates, a sparse weighted object-communication graph, and the current
object→node assignment.  Everything is a fixed-shape JAX array so the whole
planning pipeline is jit-able and usable inside the training framework.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LBProblem:
    """A load-balancing problem instance.

    Attributes:
      loads:       (N,) f32 — per-object computational load.
      assignment:  (N,) i32 — current object→node map, values in [0, P).
      edges_src:   (E,) i32 — object comm graph, directed half (symmetrized
                   on use).  Padded entries use src == dst == -1, bytes == 0.
      edges_dst:   (E,) i32
      edges_bytes: (E,) f32 — bytes exchanged per LB period on this edge.
      coords:      (N, D) f32 or None — logical positions (coordinate variant).
      num_nodes:   static int P.
    """

    loads: jax.Array
    assignment: jax.Array
    edges_src: jax.Array
    edges_dst: jax.Array
    edges_bytes: jax.Array
    num_nodes: int = dataclasses.field(metadata=dict(static=True))
    coords: Optional[jax.Array] = None

    @property
    def num_objects(self) -> int:
        return int(self.loads.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.edges_src.shape[0])

    def with_assignment(self, assignment: jax.Array) -> "LBProblem":
        return dataclasses.replace(self, assignment=assignment)

    def validate(self) -> None:
        """Host-side sanity checks (tests / debugging, not in jit paths)."""
        a = np.asarray(self.assignment)
        assert a.ndim == 1 and a.shape[0] == self.num_objects
        assert (a >= 0).all() and (a < self.num_nodes).all(), "bad assignment"
        s, d = np.asarray(self.edges_src), np.asarray(self.edges_dst)
        pad = s < 0
        assert (s[~pad] < self.num_objects).all()
        assert (d[~pad] < self.num_objects).all()
        assert (np.asarray(self.edges_bytes)[pad] == 0).all()


def node_loads(problem: LBProblem) -> jax.Array:
    """(P,) total load per node."""
    return jax.ops.segment_sum(
        problem.loads, problem.assignment, num_segments=problem.num_nodes
    )


def node_comm_matrix(problem: LBProblem) -> jax.Array:
    """(P, P) symmetric inter-node communication volume in bytes.

    Aggregates the object comm graph up to node granularity.  The diagonal
    holds *intra-node* bytes (used by the external/internal metric).  Dense
    P×P is the simulator-scale representation; the distributed runtime keeps
    only the local row block (see core/distributed.py).
    """
    P = problem.num_nodes
    valid = problem.edges_src >= 0
    src_n = jnp.where(valid, problem.assignment[problem.edges_src], 0)
    dst_n = jnp.where(valid, problem.assignment[problem.edges_dst], 0)
    w = jnp.where(valid, problem.edges_bytes, 0.0)
    flat = src_n * P + dst_n
    m = jax.ops.segment_sum(w, flat, num_segments=P * P).reshape(P, P)
    m = m + m.T  # symmetrize; diagonal counts both directions of intra edges
    return m


def object_node_bytes(
    problem: LBProblem,
    nbr_idx: jax.Array,
    assignment: Optional[jax.Array] = None,
) -> jax.Array:
    """(N, K) bytes each object exchanges with each of its node's neighbors.

    ``nbr_idx`` is the (P, K) neighbor table (padded with -1).  Entry
    ``[o, k]`` is the total bytes object ``o`` exchanges with objects that
    currently live on node ``nbr_idx[assignment[o], k]``.

    This is the paper's §III.C selection metric, including the "peers update
    their patterns when an object moves" rule: callers re-invoke this with the
    updated assignment between selection phases.
    """
    if assignment is None:
        assignment = problem.assignment
    N = problem.num_objects
    K = nbr_idx.shape[1]
    valid = problem.edges_src >= 0
    src = jnp.where(valid, problem.edges_src, 0)
    dst = jnp.where(valid, problem.edges_dst, 0)
    w = jnp.where(valid, problem.edges_bytes, 0.0)

    def one_direction(a, b):
        # For edge a->b: add bytes to a's slot for the neighbor that owns b.
        a_node = assignment[a]
        b_node = assignment[b]
        # (E, K) match of b_node against a's neighbor list.
        a_nbrs = nbr_idx[a_node]  # (E, K)
        match = (a_nbrs == b_node[:, None]) & (a_nbrs >= 0)
        # flat scatter-add into (N, K)
        flat_idx = a[:, None] * K + jnp.arange(K)[None, :]
        contrib = jnp.where(match, w[:, None], 0.0)
        return jax.ops.segment_sum(
            contrib.reshape(-1), flat_idx.reshape(-1), num_segments=N * K
        ).reshape(N, K)

    return one_direction(src, dst) + one_direction(dst, src)


def prefix_group_edges(group, loads, active=None, *,
                       ring_eps: float = 1e-3):
    """Device-side prefix-sharing comm edges for a session fleet.

    ``group`` is (S,) i32 — per-object group ids in ``[0, S)``, with
    ``-1`` marking ungrouped slots; ``active`` is an optional (S,) bool
    live mask (``None`` treats every slot as live); ``loads`` is (S,)
    f32 and must already carry the caller's load floor (the serving data
    plane clamps to ``1e-3``), so edge weights and node loads are priced
    from the **same** clamped values.

    Returns ``(edges_src, edges_dst, edges_bytes)`` of fixed shape
    ``(2*S,)``:

      * **star edges** — each live grouped slot connects to its group
        *leader* (the lowest live grouped slot index in the group,
        elected by a ``segment_min`` over group ids), weighted
        ``min(load_member, load_leader)`` — the shared-prefix reuse
        volume.  This collapses the legacy O(n²) pairwise-clique host
        loop to O(S) segment ops while preserving the invariant the
        balancer needs: every group member shares an edge with its
        group, so splitting a group always costs external bytes;
      * **ring edges** — live slot ``i ↔`` next live-neighbor candidate
        ``i+1 (mod S)`` at the tiny ``ring_eps`` weight (kept only when
        both endpoints are live) — the shape-static connectivity floor
        replacing the legacy "no edges ⇒ build a host ring" fallback, so
        a fleet of singleton groups still presents a connected comm
        graph to stage 1.

    Unused slots use the standard ``(-1, -1, 0.0)`` edge padding every
    consumer already masks on.  Pure jnp — safe under ``jit`` /
    ``lax.scan``, so the serving replay rebuilds the graph every fired
    step on device."""
    group = jnp.asarray(group, jnp.int32)
    loads = jnp.asarray(loads, jnp.float32)
    S = group.shape[0]
    idx = jnp.arange(S, dtype=jnp.int32)
    live = (jnp.ones((S,), bool) if active is None
            else jnp.asarray(active, bool))
    grouped = live & (group >= 0)
    # leader election: lowest live grouped slot index per group id
    # (other slots segment to the out-of-range bucket S)
    seg = jnp.where(grouped, group, S)
    leader_of_group = jax.ops.segment_min(
        jnp.where(grouped, idx, S), seg, num_segments=S + 1)[:S]
    leader = jnp.where(grouped,
                       leader_of_group[jnp.clip(group, 0, S - 1)], -1)
    is_member = grouped & (leader != idx)          # leaders carry no self-edge
    star_src = jnp.where(is_member, idx, -1)
    star_dst = jnp.where(is_member, leader, -1)
    star_w = jnp.where(
        is_member,
        jnp.minimum(loads, loads[jnp.clip(leader, 0, S - 1)]),
        0.0)
    ring_on = live & jnp.roll(live, -1)
    ring_src = jnp.where(ring_on, idx, -1)
    ring_dst = jnp.where(ring_on, (idx + 1) % S, -1)
    ring_w = jnp.where(ring_on, jnp.float32(ring_eps), 0.0)
    return (jnp.concatenate([star_src, ring_src]),
            jnp.concatenate([star_dst, ring_dst]),
            jnp.concatenate([star_w, ring_w]))


def stack_problems(problems) -> LBProblem:
    """Stack B same-shaped problems into one batched ``LBProblem``.

    Every array leaf gains a leading batch axis — the input to the vmapped
    planning paths (``engine.LBEngine.plan_batch`` and
    ``sim.simulator.run_series_batch``).  Requirements: identical
    ``num_nodes`` and object count; edge lists may differ in length and
    are padded to the longest with the standard (-1, -1, 0.0) padding
    (every consumer masks on ``edges_src >= 0``).  ``coords`` are kept
    only when every problem has them (the comm variant never reads them).
    """
    problems = list(problems)
    if not problems:
        raise ValueError("stack_problems needs at least one problem")
    P = problems[0].num_nodes
    N = problems[0].num_objects
    for p in problems:
        if p.num_nodes != P or p.num_objects != N:
            raise ValueError(
                "stack_problems needs a common (num_nodes, num_objects) "
                f"shape; got ({p.num_nodes}, {p.num_objects}) vs ({P}, {N})")
    E = max(p.num_edges for p in problems)

    def pad_edges(a, fill):
        a = jnp.asarray(a)
        return jnp.pad(a, (0, E - a.shape[0]), constant_values=fill)

    keep_coords = all(p.coords is not None for p in problems)
    return LBProblem(
        loads=jnp.stack([jnp.asarray(p.loads, jnp.float32)
                         for p in problems]),
        assignment=jnp.stack([jnp.asarray(p.assignment, jnp.int32)
                              for p in problems]),
        edges_src=jnp.stack([pad_edges(p.edges_src, -1).astype(jnp.int32)
                             for p in problems]),
        edges_dst=jnp.stack([pad_edges(p.edges_dst, -1).astype(jnp.int32)
                             for p in problems]),
        edges_bytes=jnp.stack(
            [pad_edges(p.edges_bytes, 0.0).astype(jnp.float32)
             for p in problems]),
        num_nodes=P,
        coords=jnp.stack([jnp.asarray(p.coords, jnp.float32)
                          for p in problems]) if keep_coords else None,
    )


def make_problem(
    loads,
    assignment,
    edges,  # (E, 2) int array of object pairs
    edge_bytes,
    num_nodes: int,
    coords=None,
) -> LBProblem:
    """Convenience constructor from host arrays."""
    edges = np.asarray(edges, dtype=np.int32).reshape(-1, 2)
    return LBProblem(
        loads=jnp.asarray(loads, jnp.float32),
        assignment=jnp.asarray(assignment, jnp.int32),
        edges_src=jnp.asarray(edges[:, 0], jnp.int32),
        edges_dst=jnp.asarray(edges[:, 1], jnp.int32),
        edges_bytes=jnp.asarray(edge_bytes, jnp.float32),
        num_nodes=int(num_nodes),
        coords=None if coords is None else jnp.asarray(coords, jnp.float32),
    )
