"""Problem representation for the load balancer.

Mirrors the paper's simulator input (§V): per-object loads, optional logical
coordinates, a sparse weighted object-communication graph, and the current
object→node assignment.  Everything is a fixed-shape JAX array so the whole
planning pipeline is jit-able and usable inside the training framework.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class LBProblem:
    """A load-balancing problem instance.

    Attributes:
      loads:       (N,) f32 — per-object computational load.
      assignment:  (N,) i32 — current object→node map, values in [0, P).
      edges_src:   (E,) i32 — object comm graph, directed half (symmetrized
                   on use).  Padded entries use src == dst == -1, bytes == 0.
      edges_dst:   (E,) i32
      edges_bytes: (E,) f32 — bytes exchanged per LB period on this edge.
      coords:      (N, D) f32 or None — logical positions (coordinate variant).
      num_nodes:   static int P.
    """

    loads: jax.Array
    assignment: jax.Array
    edges_src: jax.Array
    edges_dst: jax.Array
    edges_bytes: jax.Array
    num_nodes: int = dataclasses.field(metadata=dict(static=True))
    coords: Optional[jax.Array] = None

    @property
    def num_objects(self) -> int:
        return int(self.loads.shape[0])

    @property
    def num_edges(self) -> int:
        return int(self.edges_src.shape[0])

    def with_assignment(self, assignment: jax.Array) -> "LBProblem":
        return dataclasses.replace(self, assignment=assignment)

    def validate(self) -> None:
        """Host-side sanity checks (tests / debugging, not in jit paths)."""
        a = np.asarray(self.assignment)
        assert a.ndim == 1 and a.shape[0] == self.num_objects
        assert (a >= 0).all() and (a < self.num_nodes).all(), "bad assignment"
        s, d = np.asarray(self.edges_src), np.asarray(self.edges_dst)
        pad = s < 0
        assert (s[~pad] < self.num_objects).all()
        assert (d[~pad] < self.num_objects).all()
        assert (np.asarray(self.edges_bytes)[pad] == 0).all()


def node_loads(problem: LBProblem) -> jax.Array:
    """(P,) total load per node."""
    return jax.ops.segment_sum(
        problem.loads, problem.assignment, num_segments=problem.num_nodes
    )


def node_comm_matrix(problem: LBProblem) -> jax.Array:
    """(P, P) symmetric inter-node communication volume in bytes.

    Aggregates the object comm graph up to node granularity.  The diagonal
    holds *intra-node* bytes (used by the external/internal metric).  Dense
    P×P is the simulator-scale representation; the distributed runtime keeps
    only the local row block (see core/distributed.py).
    """
    P = problem.num_nodes
    valid = problem.edges_src >= 0
    src_n = jnp.where(valid, problem.assignment[problem.edges_src], 0)
    dst_n = jnp.where(valid, problem.assignment[problem.edges_dst], 0)
    w = jnp.where(valid, problem.edges_bytes, 0.0)
    flat = src_n * P + dst_n
    m = jax.ops.segment_sum(w, flat, num_segments=P * P).reshape(P, P)
    m = m + m.T  # symmetrize; diagonal counts both directions of intra edges
    return m


def object_node_bytes(
    problem: LBProblem,
    nbr_idx: jax.Array,
    assignment: Optional[jax.Array] = None,
) -> jax.Array:
    """(N, K) bytes each object exchanges with each of its node's neighbors.

    ``nbr_idx`` is the (P, K) neighbor table (padded with -1).  Entry
    ``[o, k]`` is the total bytes object ``o`` exchanges with objects that
    currently live on node ``nbr_idx[assignment[o], k]``.

    This is the paper's §III.C selection metric, including the "peers update
    their patterns when an object moves" rule: callers re-invoke this with the
    updated assignment between selection phases.
    """
    if assignment is None:
        assignment = problem.assignment
    N = problem.num_objects
    K = nbr_idx.shape[1]
    valid = problem.edges_src >= 0
    src = jnp.where(valid, problem.edges_src, 0)
    dst = jnp.where(valid, problem.edges_dst, 0)
    w = jnp.where(valid, problem.edges_bytes, 0.0)

    def one_direction(a, b):
        # For edge a->b: add bytes to a's slot for the neighbor that owns b.
        a_node = assignment[a]
        b_node = assignment[b]
        # (E, K) match of b_node against a's neighbor list.
        a_nbrs = nbr_idx[a_node]  # (E, K)
        match = (a_nbrs == b_node[:, None]) & (a_nbrs >= 0)
        # flat scatter-add into (N, K)
        flat_idx = a[:, None] * K + jnp.arange(K)[None, :]
        contrib = jnp.where(match, w[:, None], 0.0)
        return jax.ops.segment_sum(
            contrib.reshape(-1), flat_idx.reshape(-1), num_segments=N * K
        ).reshape(N, K)

    return one_direction(src, dst) + one_direction(dst, src)


def stack_problems(problems) -> LBProblem:
    """Stack B same-shaped problems into one batched ``LBProblem``.

    Every array leaf gains a leading batch axis — the input to the vmapped
    planning paths (``engine.LBEngine.plan_batch`` and
    ``sim.simulator.run_series_batch``).  Requirements: identical
    ``num_nodes`` and object count; edge lists may differ in length and
    are padded to the longest with the standard (-1, -1, 0.0) padding
    (every consumer masks on ``edges_src >= 0``).  ``coords`` are kept
    only when every problem has them (the comm variant never reads them).
    """
    problems = list(problems)
    if not problems:
        raise ValueError("stack_problems needs at least one problem")
    P = problems[0].num_nodes
    N = problems[0].num_objects
    for p in problems:
        if p.num_nodes != P or p.num_objects != N:
            raise ValueError(
                "stack_problems needs a common (num_nodes, num_objects) "
                f"shape; got ({p.num_nodes}, {p.num_objects}) vs ({P}, {N})")
    E = max(p.num_edges for p in problems)

    def pad_edges(a, fill):
        a = jnp.asarray(a)
        return jnp.pad(a, (0, E - a.shape[0]), constant_values=fill)

    keep_coords = all(p.coords is not None for p in problems)
    return LBProblem(
        loads=jnp.stack([jnp.asarray(p.loads, jnp.float32)
                         for p in problems]),
        assignment=jnp.stack([jnp.asarray(p.assignment, jnp.int32)
                              for p in problems]),
        edges_src=jnp.stack([pad_edges(p.edges_src, -1).astype(jnp.int32)
                             for p in problems]),
        edges_dst=jnp.stack([pad_edges(p.edges_dst, -1).astype(jnp.int32)
                             for p in problems]),
        edges_bytes=jnp.stack(
            [pad_edges(p.edges_bytes, 0.0).astype(jnp.float32)
             for p in problems]),
        num_nodes=P,
        coords=jnp.stack([jnp.asarray(p.coords, jnp.float32)
                          for p in problems]) if keep_coords else None,
    )


def make_problem(
    loads,
    assignment,
    edges,  # (E, 2) int array of object pairs
    edge_bytes,
    num_nodes: int,
    coords=None,
) -> LBProblem:
    """Convenience constructor from host arrays."""
    edges = np.asarray(edges, dtype=np.int32).reshape(-1, 2)
    return LBProblem(
        loads=jnp.asarray(loads, jnp.float32),
        assignment=jnp.asarray(assignment, jnp.int32),
        edges_src=jnp.asarray(edges[:, 0], jnp.int32),
        edges_dst=jnp.asarray(edges[:, 1], jnp.int32),
        edges_bytes=jnp.asarray(edge_bytes, jnp.float32),
        num_nodes=int(num_nodes),
        coords=None if coords is None else jnp.asarray(coords, jnp.float32),
    )
