"""Public API: the three-stage communication-aware diffusion balancer.

``diffusion_lb(problem)`` composes the stages of §III (plus the §IV
coordinate variant) and returns a new assignment with planning stats.
Planning itself lives in :mod:`repro.core.engine` — one fused, jitted,
scan-safe ``plan_fn`` per static configuration — and strategies are
``engine.Strategy`` records.  ``STRATEGIES`` remains as a thin mapping
view over the registry for existing callers.
"""
from __future__ import annotations

from collections.abc import Mapping
from typing import Callable, Dict, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import comm_graph, engine, metrics


class LBPlan(NamedTuple):
    assignment: np.ndarray
    info: Dict


def diffusion_lb(
    problem: comm_graph.LBProblem,
    *,
    k: int = 4,
    variant: str = "comm",          # "comm" (§III) | "coord" (§IV)
    tol: float = 0.02,
    max_iters: int = 512,
    max_rounds: int = 64,
    single_hop: bool = True,
    step_fn: Optional[Callable] = None,
) -> LBPlan:
    """Eager single-snapshot planning via the cached, compiled engine."""
    eng = engine.get_engine(
        variant=variant, k=k, tol=tol, max_iters=max_iters,
        max_rounds=max_rounds, single_hop=single_hop, step_fn=step_fn,
    )
    return eng.plan(problem)


# --------------------------------------------------------------- registry --


class _StrategyView(Mapping):
    """Back-compat dict view: name -> eager ``(problem, **kw) -> LBPlan``."""

    def __getitem__(self, name: str) -> Callable[..., LBPlan]:
        return engine.get_strategy(name).run

    def __iter__(self):
        return iter(engine.available())

    def __len__(self) -> int:
        return len(engine.available())


STRATEGIES: Mapping[str, Callable[..., LBPlan]] = _StrategyView()


def run_strategy(name: str, problem: comm_graph.LBProblem, **kw) -> LBPlan:
    plan = STRATEGIES[name](problem, **kw)
    plan.info.update(metrics.evaluate(problem, jnp.asarray(plan.assignment)))
    return plan
