"""Public API: the three-stage communication-aware diffusion balancer.

``diffusion_lb(problem)`` composes the stages of §III (plus the §IV
coordinate variant) and returns a new assignment with planning stats.
``STRATEGIES`` is the registry the simulator / benchmarks / framework
integrations use.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import baselines, comm_graph, metrics
from repro.core import neighbor_selection as ns
from repro.core import object_selection as osel
from repro.core import virtual_lb as vlb


class LBPlan(NamedTuple):
    assignment: np.ndarray
    info: Dict


def diffusion_lb(
    problem: comm_graph.LBProblem,
    *,
    k: int = 4,
    variant: str = "comm",          # "comm" (§III) | "coord" (§IV)
    tol: float = 0.02,
    max_iters: int = 512,
    max_rounds: int = 64,
    single_hop: bool = True,
    step_fn: Optional[Callable] = None,
) -> LBPlan:
    t0 = time.perf_counter()

    # -- stage 1: neighbor selection ------------------------------------
    if variant == "comm":
        node_comm = comm_graph.node_comm_matrix(problem)
        pref = ns.comm_preference(node_comm)
    elif variant == "coord":
        assert problem.coords is not None, "coordinate variant needs coords"
        cent = osel.centroids(
            problem.coords, problem.assignment, problem.num_nodes
        )
        pref = ns.coordinate_preference(cent)
    else:
        raise ValueError(f"unknown variant {variant!r}")
    nres = ns.select_neighbors(pref, k=k, max_rounds=max_rounds)

    # -- stage 2: virtual load balancing ---------------------------------
    nloads = comm_graph.node_loads(problem)
    vres = vlb.virtual_balance(
        nloads, nres.nbr_idx, nres.nbr_mask,
        tol=tol, max_iters=max_iters, single_hop=single_hop, step_fn=step_fn,
    )

    # -- stage 3: object selection ----------------------------------------
    sres = osel.select_objects(
        problem, nres.nbr_idx, nres.nbr_mask, vres.flows,
        metric="comm" if variant == "comm" else "coord",
    )

    info = dict(
        strategy=f"diff-{variant}",
        k=k,
        protocol_rounds=int(nres.rounds),
        mean_degree=float(np.mean(np.asarray(nres.degree))),
        diffusion_iters=int(vres.iters),
        diffusion_residual=float(vres.residual),
        unrealized_flow=float(np.abs(np.asarray(sres.residual)).sum()),
        plan_seconds=time.perf_counter() - t0,
    )
    return LBPlan(np.asarray(sres.assignment), info)


# --------------------------------------------------------------- registry --


def _wrap(fn):
    def run(problem: comm_graph.LBProblem, **kw) -> LBPlan:
        t0 = time.perf_counter()
        a = fn(problem, **kw)
        return LBPlan(np.asarray(a),
                      dict(strategy=fn.__name__,
                           plan_seconds=time.perf_counter() - t0))
    return run


def _none(problem: comm_graph.LBProblem) -> np.ndarray:
    return np.asarray(problem.assignment)


STRATEGIES: Dict[str, Callable[..., LBPlan]] = {
    "none": _wrap(_none),
    "diff-comm": lambda p, **kw: diffusion_lb(p, variant="comm", **kw),
    "diff-coord": lambda p, **kw: diffusion_lb(p, variant="coord", **kw),
    "greedy": _wrap(baselines.greedy),
    "greedy-refine": _wrap(baselines.greedy_refine),
    "metis": _wrap(baselines.metis_like),
    "parmetis": _wrap(baselines.parmetis_like),
}


def run_strategy(name: str, problem: comm_graph.LBProblem, **kw) -> LBPlan:
    plan = STRATEGIES[name](problem, **kw)
    plan.info.update(metrics.evaluate(problem, jnp.asarray(plan.assignment)))
    return plan
