"""Cost metrics from the paper's problem definition (§II).

  (1) load imbalance      — max / average node load;
  (2) communication cost  — external / internal bytes ratio;
  (3) migration cost      — fraction of objects that moved;
  (4) strategy cost       — wall time of computing the mapping (recorded by
      the simulator, not here).

``evaluate_device`` is the pure-jnp implementation (scan/jit safe — the
scanned replay layers accumulate it per step on device); ``evaluate`` is
the host dict view over the same math.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm_graph

#: ``ext_int_comm`` sentinel for an all-external mapping (zero internal
#: bytes).  The true ratio is unbounded; the previous ``ext / 1e-30``
#: spelling produced ~1e30 garbage that poisoned benchmark JSON and every
#: downstream mean.  1e6 is far above any physical ext/int ratio (paper
#: Tables I/II top out near 10) yet finite and f32-exact, so aggregates
#: stay meaningful and the condition remains detectable.
EXT_INT_ALL_EXTERNAL = 1.0e6


class StepMetrics(NamedTuple):
    """Per-snapshot cost metrics as device scalars (f32)."""

    max_avg_load: jax.Array
    ext_int_comm: jax.Array
    ext_bytes: jax.Array
    int_bytes: jax.Array
    pct_migrations: jax.Array
    node_load_std: jax.Array
    max_load: jax.Array
    avg_load: jax.Array


def evaluate_device(
    problem: comm_graph.LBProblem,
    assignment: Optional[jax.Array] = None,
) -> StepMetrics:
    """Traceable metric evaluation (usable inside jit / lax.scan)."""
    cur = jnp.asarray(problem.assignment)
    a = cur if assignment is None else jnp.asarray(assignment)
    nl = jax.ops.segment_sum(jnp.asarray(problem.loads), a,
                             num_segments=problem.num_nodes)
    avg = nl.mean() + 1e-30

    es = jnp.asarray(problem.edges_src)
    ed = jnp.asarray(problem.edges_dst)
    valid = es >= 0
    src_n = a[jnp.where(valid, es, 0)]
    dst_n = a[jnp.where(valid, ed, 0)]
    w = jnp.where(valid, jnp.asarray(problem.edges_bytes), 0.0)
    ext = jnp.where(src_n != dst_n, w, 0.0).sum()
    internal = jnp.where(src_n == dst_n, w, 0.0).sum()

    moved = jnp.mean((a != cur).astype(jnp.float32))
    # zero internal bytes: finite documented sentinel (0 when ext is also
    # zero — e.g. an edgeless problem — so "no comm at all" reads as 0)
    ext_int = jnp.where(
        internal > 0, ext / jnp.where(internal > 0, internal, 1.0),
        jnp.where(ext > 0, EXT_INT_ALL_EXTERNAL, 0.0))
    return StepMetrics(
        max_avg_load=(nl.max() / avg).astype(jnp.float32),
        ext_int_comm=ext_int.astype(jnp.float32),
        ext_bytes=ext.astype(jnp.float32),
        int_bytes=internal.astype(jnp.float32),
        pct_migrations=moved,
        node_load_std=(nl.std() / avg).astype(jnp.float32),
        max_load=nl.max().astype(jnp.float32),
        avg_load=avg.astype(jnp.float32),
    )


def evaluate(
    problem: comm_graph.LBProblem,
    assignment: Optional[jax.Array] = None,
) -> Dict[str, float]:
    """Host dict view of :func:`evaluate_device` (legacy interface).

    ``ext_int_comm`` is :data:`EXT_INT_ALL_EXTERNAL` when the mapping has
    external but no internal bytes (and 0.0 when it has neither); every
    value is guaranteed finite."""
    if assignment is not None:
        assignment = jnp.asarray(assignment)
    m = jax.device_get(evaluate_device(problem, assignment))  # one transfer
    out = {k: float(v) for k, v in m._asdict().items()}
    # guard: no non-finite value may escape into benchmark JSON
    for k, v in out.items():
        if not np.isfinite(v):
            out[k] = EXT_INT_ALL_EXTERNAL if v > 0 else 0.0
    return out
