"""Cost metrics from the paper's problem definition (§II).

  (1) load imbalance      — max / average node load;
  (2) communication cost  — external / internal bytes ratio;
  (3) migration cost      — fraction of objects that moved;
  (4) strategy cost       — wall time of computing the mapping (recorded by
      the simulator, not here).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm_graph


def evaluate(
    problem: comm_graph.LBProblem,
    assignment: Optional[jax.Array] = None,
) -> Dict[str, float]:
    a = problem.assignment if assignment is None else assignment
    nl = jax.ops.segment_sum(problem.loads, a, num_segments=problem.num_nodes)
    nl = np.asarray(nl)
    avg = nl.mean() + 1e-30

    valid = np.asarray(problem.edges_src) >= 0
    src_n = np.asarray(a)[np.asarray(problem.edges_src) * valid]
    dst_n = np.asarray(a)[np.asarray(problem.edges_dst) * valid]
    w = np.asarray(problem.edges_bytes) * valid
    ext = w[src_n != dst_n].sum()
    internal = w[src_n == dst_n].sum()

    moved = float(np.mean(np.asarray(a) != np.asarray(problem.assignment)))
    return dict(
        max_avg_load=float(nl.max() / avg),
        ext_int_comm=float(ext / (internal + 1e-30)),
        ext_bytes=float(ext),
        int_bytes=float(internal),
        pct_migrations=moved,
        node_load_std=float(nl.std() / avg),
        max_load=float(nl.max()),
        avg_load=float(avg),
    )
