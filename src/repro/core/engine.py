"""Device-resident LB engine: the three planning stages fused into one
shape-stable, jit/scannable ``plan`` function, plus the Strategy protocol.

The paper's balancer (§III) is three stages — neighbor selection, virtual
diffusion, object selection.  ``core/api.py``'s eager path composes them
through host Python with NumPy round-trips per call; that is fine for one
snapshot but dominates wall time when a time-evolving workload is replayed
(Fig 4/5) and makes the planner unusable inside ``jax.lax.scan``.

``LBEngine`` closes over the static configuration ``(variant, K, tol,
iteration caps)`` and exposes

  * ``plan_fn(problem) -> (assignment, PlanStats)`` — pure, traceable,
    shape-stable in the static ``(P, K, C)`` envelope (``P`` nodes, ``K``
    neighbor slots, ``C`` objects; all baked into array shapes), safe to
    call under ``jit`` / ``lax.scan`` / ``lax.cond``;
  * ``plan(problem) -> LBPlan`` — eager host convenience with timing and
    the legacy ``info`` dict;
  * ``plan_batch_fn`` / ``plan_batch`` — the vmapped batch path: B
    independent same-shaped problems (stacked via
    ``comm_graph.stack_problems``) planned in one compiled call, with the
    staged problem buffers donated to the executable on accelerators.

Stage 2 runs the chunked virtual-LB loop (``sweep_chunk`` sweeps per
``while_loop`` body) through ``kernels.diffusion.ops.diffusion_nsweeps``,
which picks the fused multi-sweep Pallas kernel / streaming kernel /
compiled reference per backend and VMEM budget.

``Strategy`` is the registry protocol replacing the dict-of-lambdas in
``core/api.py`` (a thin mapping view remains there for back-compat):
jittable strategies expose a traceable ``plan_fn(problem, **params)``;
host-only baselines (greedy, metis, ...) keep ``jittable=False`` and are
run eagerly by ``Strategy.run``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import baselines, comm_graph, hierarchical
from repro.core import neighbor_selection as ns
from repro.core import object_selection as osel
from repro.core import virtual_lb as vlb
from repro.kernels.diffusion import ops as diffusion_ops


class PlanStats(NamedTuple):
    """Planner statistics as device scalars (scan/cond friendly)."""

    protocol_rounds: jax.Array     # i32 — stage-1 handshake rounds
    mean_degree: jax.Array         # f32 — mean confirmed neighbor count
    diffusion_iters: jax.Array     # i32 — stage-2 sweeps executed
    diffusion_residual: jax.Array  # f32 — final neighborhood imbalance
    unrealized_flow: jax.Array     # f32 — |wanted - shipped| load (stage 3)


def zero_stats() -> PlanStats:
    """Neutral PlanStats — the no-LB branch of a ``lax.cond``."""
    return PlanStats(
        protocol_rounds=jnp.int32(0),
        mean_degree=jnp.float32(0.0),
        diffusion_iters=jnp.int32(0),
        diffusion_residual=jnp.float32(0.0),
        unrealized_flow=jnp.float32(0.0),
    )


class LBEngine:
    """Fused three-stage diffusion planner with static configuration.

    Construction is cheap; the first ``plan`` call per problem shape pays
    XLA compilation.  Instances are cached by :func:`get_engine`.
    """

    def __init__(
        self,
        *,
        variant: str = "comm",          # "comm" (§III) | "coord" (§IV)
        k: int = 4,
        tol: float = 0.02,
        max_iters: int = 512,
        max_rounds: int = 64,
        single_hop: bool = True,
        step_fn: Optional[Callable] = None,
        sweep_chunk: int = 8,
        threads_per_node: Optional[int] = None,
    ):
        if variant not in ("comm", "coord"):
            raise ValueError(f"unknown variant {variant!r}")
        self.variant = variant
        self.k = int(k)
        self.tol = float(tol)
        self.max_iters = int(max_iters)
        self.max_rounds = int(max_rounds)
        self.single_hop = bool(single_hop)
        self.step_fn = step_fn
        self.sweep_chunk = int(sweep_chunk)
        # optional stage 4 (paper §III.D): within-node LPT across T threads
        self.threads_per_node = (None if threads_per_node is None
                                 else int(threads_per_node))
        # production stage-2 path: the fused S-sweep chunk (auto-selected
        # fused/streaming/reference in kernels/diffusion/ops.py); an
        # explicit step_fn opts out and runs per-sweep inside the chunk.
        self.chunk_fn = (diffusion_ops.diffusion_nsweeps
                         if step_fn is None else None)
        self._jitted = jax.jit(self.plan_fn)
        self._jitted_batch = jax.jit(self.plan_batch_fn)
        self._jitted_hier = (jax.jit(self.plan_hier_fn)
                             if self.threads_per_node else None)
        # donating variant: only for batches plan_batch stages itself — a
        # caller-owned pre-stacked batch must survive the call.  CPU XLA
        # has no donation.
        self._jitted_batch_donate = jax.jit(
            self.plan_batch_fn,
            donate_argnums=(0,) if jax.default_backend() != "cpu" else (),
        )

    # ------------------------------------------------------- traced path --

    def plan_fn(
        self, problem: comm_graph.LBProblem
    ) -> Tuple[jax.Array, PlanStats]:
        """Neighbor selection → virtual balance → object selection, fused.

        Pure function of the problem arrays; every intermediate keeps the
        static (P, K) / (C,) padding, so the same trace serves every step
        of a scanned replay."""
        return self._plan_stages(problem, None)

    def plan_health_fn(
        self, problem: comm_graph.LBProblem, alive, speed=None
    ) -> Tuple[jax.Array, PlanStats]:
        """Health-masked :meth:`plan_fn` for a degraded mesh.

        ``alive`` is a (P,) bool node mask, ``speed`` an optional (P,)
        f32 per-node speed in (0, 1].  Dead nodes' objects are first
        re-homed onto their strongest alive communication partner
        (``runtime.resilience.rehome_dead``), slowed nodes' loads are
        scaled by the reciprocal speed, and the stage-1 preference
        rows/columns of dead nodes are zeroed — so the same three
        stages re-diffuse the displaced load over the surviving mesh
        and never target a dead node.  ``alive=None`` is exactly
        :meth:`plan_fn`.  Traceable like :meth:`plan_fn`; the resilient
        replay loops call it inside their scans."""
        if alive is None:
            return self._plan_stages(problem, None)
        from repro.runtime import resilience  # local: runtime imports core

        problem = resilience.degrade_problem(problem, alive, speed)
        return self._plan_stages(problem, jnp.asarray(alive, bool))

    def _plan_stages(
        self, problem: comm_graph.LBProblem, alive
    ) -> Tuple[jax.Array, PlanStats]:
        """Shared three-stage body; ``alive=None`` keeps the exact
        unmasked trace (the ``if`` is static, nothing is added).

        Each stage runs under a ``compat.named_scope`` so profiler
        traces and HLO dumps attribute planner time per stage."""
        from repro.distributed import compat

        # -- stage 1: neighbor selection --------------------------------
        with compat.named_scope("lb-plan/stage1-neighbors"):
            if self.variant == "comm":
                node_comm = comm_graph.node_comm_matrix(problem)
                pref = ns.comm_preference(node_comm)
            else:
                assert problem.coords is not None, \
                    "coordinate variant needs coords"
                cent = osel.centroids(
                    problem.coords, problem.assignment, problem.num_nodes
                )
                pref = ns.coordinate_preference(cent)
            if alive is not None:
                # zeroed rows/columns drop dead nodes from the candidate
                # set (select_neighbors candidates are ``preference > 0``)
                pref = jnp.where(alive[:, None] & alive[None, :], pref,
                                 0.0)
            nres = ns.select_neighbors(pref, k=self.k,
                                       max_rounds=self.max_rounds)

        # -- stage 2: virtual load balancing ----------------------------
        with compat.named_scope("lb-plan/stage2-diffusion"):
            nloads = comm_graph.node_loads(problem)
            vres = vlb.virtual_balance(
                nloads, nres.nbr_idx, nres.nbr_mask,
                tol=self.tol, max_iters=self.max_iters,
                single_hop=self.single_hop, step_fn=self.step_fn,
                sweep_chunk=self.sweep_chunk, chunk_fn=self.chunk_fn,
            )

        # -- stage 3: object selection ----------------------------------
        with compat.named_scope("lb-plan/stage3-objects"):
            sres = osel.select_objects(
                problem, nres.nbr_idx, nres.nbr_mask, vres.flows,
                metric="comm" if self.variant == "comm" else "coord",
            )

        stats = PlanStats(
            protocol_rounds=nres.rounds.astype(jnp.int32),
            mean_degree=jnp.mean(nres.degree.astype(jnp.float32)),
            diffusion_iters=vres.iters.astype(jnp.int32),
            diffusion_residual=vres.residual.astype(jnp.float32),
            unrealized_flow=jnp.abs(sres.residual).sum().astype(jnp.float32),
        )
        return sres.assignment.astype(jnp.int32), stats

    # ------------------------------------------------- hierarchical stage --

    def plan_hier_fn(
        self, problem: comm_graph.LBProblem
    ) -> Tuple[jax.Array, jax.Array, PlanStats]:
        """Two-level placement: :meth:`plan_fn` + within-node LPT (§III.D).

        Returns ``(assignment (N,), thread (N,), stats)`` where
        ``thread[o] ∈ [0, threads_per_node)`` and the global PE id is
        ``assignment * T + thread``.  Traceable like :meth:`plan_fn`
        (the LPT is a vectorized device loop — ``hierarchical.lpt_threads``),
        so the scanned replay layers can emit thread placements without
        leaving device.  Requires ``threads_per_node`` to be configured.
        """
        if not self.threads_per_node:
            raise ValueError(
                "plan_hier_fn needs threads_per_node set on the engine "
                "(get_engine(..., threads_per_node=T))")
        assignment, stats = self.plan_fn(problem)
        thread = hierarchical.lpt_threads(
            problem.loads, assignment,
            num_nodes=problem.num_nodes,
            threads_per_node=self.threads_per_node)
        return assignment, thread, stats

    def plan_hier_batch_fn(
        self, problems: comm_graph.LBProblem
    ) -> Tuple[jax.Array, jax.Array, PlanStats]:
        """Vmapped :meth:`plan_hier_fn` over a stacked problem batch."""
        return jax.vmap(self.plan_hier_fn)(problems)

    # ------------------------------------------------------ batched path --

    def plan_batch_fn(
        self, problems: comm_graph.LBProblem
    ) -> Tuple[jax.Array, PlanStats]:
        """Vmapped :meth:`plan_fn` over a stacked problem batch.

        ``problems`` is a batched ``LBProblem`` (every array leaf carries a
        leading B axis — see ``comm_graph.stack_problems``).  Returns
        ``(assignments (B, N), PlanStats of (B,) arrays)``.  One compiled
        call plans all B independent problems; traceable, so the batched
        replay layers scan over it."""
        return jax.vmap(self.plan_fn)(problems)

    def plan_batch(self, problems):
        """Eager batched planning: B problems in one compiled call.

        Accepts a sequence of same-shaped ``LBProblem``s (stacked here,
        with the staged buffers donated to the executable on accelerators)
        or an already-stacked batch (kept intact — no donation).  Returns
        a list of ``LBPlan``s."""
        from repro.core.api import LBPlan  # local import: api imports us

        t0 = time.perf_counter()
        if isinstance(problems, comm_graph.LBProblem):
            jitted = self._jitted_batch
        else:
            problems = comm_graph.stack_problems(problems)
            jitted = self._jitted_batch_donate
        assignments, stats = jitted(problems)
        assignments = np.asarray(jax.device_get(assignments))
        stats = jax.device_get(stats)
        dt = time.perf_counter() - t0
        plans = []
        for b in range(assignments.shape[0]):
            info = dict(
                strategy=f"diff-{self.variant}",
                k=self.k,
                batch_index=b,
                batch_size=assignments.shape[0],
                protocol_rounds=int(stats.protocol_rounds[b]),
                mean_degree=float(stats.mean_degree[b]),
                diffusion_iters=int(stats.diffusion_iters[b]),
                diffusion_residual=float(stats.diffusion_residual[b]),
                unrealized_flow=float(stats.unrealized_flow[b]),
                plan_seconds=dt,      # wall time of the whole batch
            )
            plans.append(LBPlan(assignments[b], info))
        return plans

    # -------------------------------------------------------- host path --

    def plan(self, problem: comm_graph.LBProblem):
        """Eager plan with wall-clock timing and the legacy info dict.

        With ``threads_per_node`` configured, the returned ``info`` also
        carries the two-level placement: ``thread`` ((N,) i32) and
        ``threads_per_node`` (the global PE id of object ``o`` is
        ``assignment[o] * T + thread[o]``)."""
        return eager_plan(self, problem, f"diff-{self.variant}")


def eager_plan(eng, problem, strategy_name: str,
               extra_info: Optional[Dict] = None):
    """Shared eager planning body (``LBEngine`` and the mesh-sharded
    ``distributed.lb_shard.ShardedLBEngine``): jitted dispatch — the
    two-level variant when ``threads_per_node`` is configured — one
    device transfer, wall-clock timing, and the legacy info dict."""
    from repro.core.api import LBPlan  # local import: api imports us

    t0 = time.perf_counter()
    thread = None
    if eng.threads_per_node:
        assignment, thread, stats = eng._jitted_hier(problem)
        thread = np.asarray(jax.device_get(thread))
    else:
        assignment, stats = eng._jitted(problem)
    assignment = np.asarray(jax.device_get(assignment))
    info = dict(
        strategy=strategy_name,
        k=eng.k,
        **(extra_info or {}),
        protocol_rounds=int(stats.protocol_rounds),
        mean_degree=float(stats.mean_degree),
        diffusion_iters=int(stats.diffusion_iters),
        diffusion_residual=float(stats.diffusion_residual),
        unrealized_flow=float(stats.unrealized_flow),
        plan_seconds=time.perf_counter() - t0,
    )
    if thread is not None:
        info.update(thread=thread, threads_per_node=eng.threads_per_node)
    return LBPlan(assignment, info)


_ENGINE_CACHE: Dict[tuple, LBEngine] = {}
_ENGINE_CACHE_MAX = 64


def _engine_key(cfg: Dict) -> tuple:
    """Canonical hashable cache key: values coerced exactly as
    ``LBEngine.__init__`` coerces them, so positional vs keyword spelling
    and int/float spelling of the same configuration share one entry.  An
    unhashable ``step_fn`` is keyed by identity (the cached engine holds a
    strong reference, so the id stays valid for the entry's lifetime)."""
    step_fn = cfg["step_fn"]
    try:
        hash(step_fn)
    except TypeError:
        step_fn = ("step_fn_id", id(step_fn))
    return (
        str(cfg["variant"]), int(cfg["k"]), float(cfg["tol"]),
        int(cfg["max_iters"]), int(cfg["max_rounds"]),
        bool(cfg["single_hop"]), step_fn, int(cfg["sweep_chunk"]),
        None if cfg["threads_per_node"] is None
        else int(cfg["threads_per_node"]),
    )


def get_engine(
    variant: str = "comm",
    k: int = 4,
    tol: float = 0.02,
    max_iters: int = 512,
    max_rounds: int = 64,
    single_hop: bool = True,
    step_fn: Optional[Callable] = None,
    sweep_chunk: int = 8,
    threads_per_node: Optional[int] = None,
) -> LBEngine:
    """Engine cache — one compiled planner per static configuration.

    Python's argument binding canonicalizes positional vs keyword
    spelling, and ``_engine_key`` canonicalizes the values, so — unlike
    the previous ``lru_cache`` — equivalent configurations share one
    entry regardless of call spelling, and an unhashable ``step_fn``
    does not raise."""
    cfg = dict(variant=variant, k=k, tol=tol, max_iters=max_iters,
               max_rounds=max_rounds, single_hop=single_hop,
               step_fn=step_fn, sweep_chunk=sweep_chunk,
               threads_per_node=threads_per_node)
    key = _engine_key(cfg)
    eng = _ENGINE_CACHE.get(key)
    if eng is None:
        eng = _ENGINE_CACHE[key] = LBEngine(**cfg)
        while len(_ENGINE_CACHE) > _ENGINE_CACHE_MAX:  # drop oldest entry
            _ENGINE_CACHE.pop(next(iter(_ENGINE_CACHE)))
    return eng


# ------------------------------------------------------ Strategy protocol --


@dataclasses.dataclass(frozen=True)
class Strategy:
    """A registered load-balancing strategy.

    ``plan_fn(problem, **params) -> (assignment, PlanStats)``.  When
    ``jittable`` the call is traceable for static ``params`` (usable under
    ``jit`` / ``scan`` / ``cond``); otherwise it runs host-side NumPy and
    may only be called eagerly.  ``defaults`` are merged under caller
    params by :meth:`run` and by the scanned replay layers.

    ``trigger`` names the strategy's default online rebalancing policy
    (``runtime.triggers`` — e.g. the ``diff-comm+threshold`` registration
    carries ``trigger="threshold"``).  The replay layers resolve it when
    the caller passes ``trigger=None``; a plain strategy (``trigger is
    None``) keeps the legacy fixed ``lb_every`` cadence.

    ``variant`` names the diffusion-planner variant (``"comm"`` /
    ``"coord"``) behind a diff-* strategy.  The sharded replay runtime
    (``distributed/replay_shard.py``) reads it to instantiate the
    mesh-sharded twin of the same planner configuration; ``None`` marks
    strategies with no diffusion engine behind them (baselines,
    ``"none"``), which the sharded replay cannot distribute.
    """

    name: str
    plan_fn: Callable[..., Tuple[jax.Array, PlanStats]]
    jittable: bool = False
    defaults: Mapping = dataclasses.field(default_factory=dict)
    trigger: Optional[str] = None
    variant: Optional[str] = None

    def params(self, **overrides) -> Dict:
        return {**self.defaults, **overrides}

    def bind(self, **overrides) -> Callable:
        """Traceable closure ``problem -> (assignment, PlanStats)``."""
        p = self.params(**overrides)
        return lambda problem: self.plan_fn(problem, **p)

    def run(self, problem: comm_graph.LBProblem, **overrides):
        """Eager execution returning the legacy ``LBPlan``."""
        from repro.core.api import LBPlan  # local import: api imports us

        t0 = time.perf_counter()
        params = self.params(**overrides)
        assignment, stats = self.plan_fn(problem, **params)
        assignment = np.asarray(jax.device_get(assignment))
        info = dict(strategy=self.name,
                    plan_seconds=time.perf_counter() - t0,
                    **{k: v for k, v in params.items()
                       if isinstance(v, (int, float, bool, str))})
        if self.name.startswith("diff"):  # incl. the sharded variants
            info.update(
                protocol_rounds=int(stats.protocol_rounds),
                mean_degree=float(stats.mean_degree),
                diffusion_iters=int(stats.diffusion_iters),
                diffusion_residual=float(stats.diffusion_residual),
                unrealized_flow=float(stats.unrealized_flow),
            )
        return LBPlan(assignment, info)


_REGISTRY: Dict[str, Strategy] = {}


def register(strategy: Strategy) -> Strategy:
    _REGISTRY[strategy.name] = strategy
    return strategy


def get_strategy(name: str) -> Strategy:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown strategy {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def available() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def registry() -> Mapping[str, Strategy]:
    return dict(_REGISTRY)


# ------------------------------------------------------ built-in strategies --


def _diffusion_plan_fn(variant: str):
    def plan_fn(problem, **params):
        # the jitted entry point: eager callers (Strategy.run / STRATEGIES)
        # get the cached compiled plan; traced callers (scan/cond) inline it
        return get_engine(variant=variant, **params)._jitted(problem)
    return plan_fn


def _none_plan_fn(problem):
    return problem.assignment.astype(jnp.int32), zero_stats()


def _host(fn):
    """Wrap a NumPy baseline as a Strategy plan_fn."""
    def plan_fn(problem, **params):
        return np.asarray(fn(problem, **params), np.int32), zero_stats()
    return plan_fn


register(Strategy("none", _none_plan_fn, jittable=True))
register(Strategy("diff-comm", _diffusion_plan_fn("comm"), jittable=True,
                  variant="comm"))
register(Strategy("diff-coord", _diffusion_plan_fn("coord"), jittable=True,
                  variant="coord"))
register(Strategy("greedy", _host(baselines.greedy)))
register(Strategy("ep-greedy", _host(baselines.greedy_capped),
                  defaults=dict(cap=0)))
register(Strategy("greedy-refine", _host(baselines.greedy_refine)))
register(Strategy("metis", _host(baselines.metis_like)))
register(Strategy("parmetis", _host(baselines.parmetis_like)))

# trigger-wrapped variants: same planner, adaptive rebalance policy — the
# replay layers pick the trigger up when called with ``trigger=None``
# (single snapshots via ``compare``/``run_strategy`` plan identically to
# the base strategy; the wrapping only matters over time)
for _variant in ("comm", "coord"):
    for _trig in ("threshold", "predictive"):
        register(Strategy(f"diff-{_variant}+{_trig}",
                          _diffusion_plan_fn(_variant), jittable=True,
                          trigger=_trig, variant=_variant))
del _variant, _trig
