"""Device-resident payload migration: the *apply* half of a rebalance.

Planning produces an old→new assignment pair; this module turns that
pair into per-node send/recv manifests and **executes** them, so the
replay layers stop merely counting migration and actually move payload
(the paper's §II migration-cost term, Demiralp et al.'s dominant
end-to-end cost).  Two execution paths:

  * **single device** — :func:`build_manifest` + :func:`apply_manifest`:
    a bucketed gather that reorders the payload arrays so each node's
    items occupy one contiguous slot region (stable order: by new owner,
    ties by previous position).  Pure and shape-stable, so it runs under
    ``jit`` / ``lax.scan`` / ``lax.cond`` — the scanned PIC driver
    executes it inside the replay scan.  :func:`build_and_apply` fuses
    build + apply in one traced expression (the scanned hot path);
    :func:`migrate` is the eager entry with the payload buffers donated
    to the executable on accelerators (double-buffered exchange: XLA may
    write the relocated arrays over the originals).

**The ``method`` knob** (:func:`build_manifest`, :func:`build_and_apply`,
:func:`migrate`): ``"sort"`` builds the permutation with the historical
stable ``argsort``; ``"scatter"`` builds it sort-free via the fused
counting-scatter kernel package (``kernels.migrate``: histogram →
exclusive-scan offsets → stable within-owner rank, O(n·P) MXU-friendly
work instead of the O(n log n) sort network); ``"auto"`` (default) picks
per backend and node count (:func:`kernels.migrate.preferred_method` —
scatter everywhere on TPU, scatter up to the measured C ≈ 64 crossover
on CPU).  **Bit-for-bit layout contract**: every method produces the
identical ``Manifest`` — ``order`` *is* ``argsort(owner_new,
stable=True)`` whichever way it was computed — so replay trajectories,
parity suites and the sharded exchange are method-independent.
  * **mesh-sharded** — :func:`migrate_sharded`: a ``ppermute`` ring
    all-to-all under ``shard_map`` on a 1-D device mesh.  Each shard
    owns a contiguous node range; the local payload block rotates D-1
    hops around the ring and every shard scatters the items it owns into
    its slot region as they pass.  Destination offsets are computed from
    an all-gathered (D, P) count matrix, so the concatenated per-shard
    regions are **bit-for-bit** the single-device bucketed layout.

Conservation is structural: both paths apply a permutation (plus
padding on the sharded path), so item count, total bytes, and every
per-item payload value are preserved exactly — tests/test_runtime.py
asserts all three on both paths.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P_

from repro.distributed import compat  # noqa: F401  (installs jax.shard_map)
from repro.kernels import migrate as mig_ops

AXIS = "mig"


class CapacityOverflowError(ValueError):
    """A migration would exceed a per-shard/per-node slot budget.

    Raised by the **eager** entries only (:func:`migrate_sharded` in its
    default ``on_overflow="strict"`` mode, and :func:`migrate` when a
    ``capacity`` bound is passed) — inside a compiled scan a Python
    exception is meaningless, which is exactly why the in-scan exchange
    offers the ``spill`` mode instead (overflow items stay on their
    source shard and retry at the next fired rebalance).

    Structured fields: ``capacity`` (the budget), ``counts`` (per-unit
    inflow item counts), ``offending`` (unit ids over budget), ``unit``
    (``"shard"`` or ``"node"``)."""

    def __init__(self, *, capacity: int, counts, unit: str = "shard"):
        self.capacity = int(capacity)
        self.counts = [int(c) for c in np.asarray(counts).ravel()]
        self.unit = str(unit)
        self.offending = [i for i, c in enumerate(self.counts)
                          if c > self.capacity]
        super().__init__(
            f"per-{self.unit} capacity={self.capacity} overflowed: inflow "
            f"counts per {self.unit} {self.counts} exceed the budget at "
            f"{self.unit} ids {self.offending}; the exchange would have "
            "dropped payload — raise capacity (n is always safe) or use "
            "on_overflow='spill'")


class Manifest(NamedTuple):
    """Executable exchange plan for one old→new ownership pair.

    ``order`` is the bucketed gather permutation (stable sort by new
    owner — identical whichever build method produced it); ``dest`` is
    its inverse (``dest[i]`` = item ``i``'s slot), populated only by the
    sort-free scatter build where it falls out for free; ``offsets[p]:
    offsets[p+1]`` is node ``p``'s slot region in the relocated layout;
    ``send_counts[s, d]`` counts items moving from node ``s`` to node
    ``d`` — the off-diagonal is the executed exchange, the diagonal
    stays put."""

    order: jax.Array        # (n,) i32 gather permutation
    offsets: jax.Array      # (P+1,) i32 slot-region boundaries
    send_counts: jax.Array  # (P, P) i32 per-node send/recv matrix
    moved: jax.Array        # (n,) bool — item changed owner
    dest: Optional[jax.Array] = None  # (n,) i32 scatter permutation

    @property
    def moved_count(self) -> jax.Array:
        """i32 scalar — items actually exchanged (equals the
        off-diagonal ``send_counts`` sum)."""
        return self.moved.sum().astype(jnp.int32)

    def moved_bytes(self, bytes_per_item) -> jax.Array:
        """f32 scalar — executed exchange volume (uniform item size)."""
        return self.moved_count.astype(jnp.float32) * bytes_per_item

    def moved_sum(self, weights, where=None) -> jax.Array:
        """f32 scalar — executed exchange volume with **per-item** sizes.

        ``weights`` is (n,) f32 — e.g. each session's resident KV-cache
        bytes in the serving data plane, where items are far from
        uniform; ``where`` optionally restricts the sum to a live-item
        mask (free fleet slots move for free).  The uniform-size
        :meth:`moved_bytes` is the special case ``weights = const``."""
        w = jnp.where(self.moved, jnp.asarray(weights, jnp.float32), 0.0)
        if where is not None:
            w = jnp.where(jnp.asarray(where, bool), w, 0.0)
        return w.sum()


def resolve_method(method: str, *, n: int, num_nodes: int) -> str:
    """Resolve the ``method`` knob to ``"sort"`` or ``"scatter"``.

    ``"auto"`` consults :func:`kernels.migrate.preferred_method` (backend
    + node-count crossover); explicit values pass through.  Shapes are
    static under tracing, so resolution happens at trace time."""
    if method == "auto":
        return mig_ops.preferred_method(int(n), int(num_nodes))
    if method not in ("sort", "scatter"):
        raise ValueError(f"unknown manifest method {method!r}")
    return method


def build_manifest(owner_old, owner_new, num_nodes: int,
                   method: str = "auto") -> Manifest:
    """Traceable manifest for relocating items between node slot regions.

    ``owner_old``/``owner_new`` are (n,) i32 per-item node ids (for PIC:
    ``assignment[chare_id]`` before/after the plan).  ``method`` selects
    how the bucketed permutation is built — ``"sort"`` (stable argsort),
    ``"scatter"`` (sort-free counting scatter, ``kernels.migrate``) or
    ``"auto"`` (:func:`resolve_method`).  The resulting ``Manifest`` is
    bit-for-bit identical either way; the scatter build additionally
    populates ``dest`` (the inverse permutation it derives the layout
    from)."""
    owner_old = jnp.asarray(owner_old, jnp.int32)
    owner_new = jnp.asarray(owner_new, jnp.int32)
    ones = jnp.ones(owner_new.shape, jnp.int32)
    n = int(owner_new.shape[0])
    if resolve_method(method, n=n, num_nodes=num_nodes) == "scatter":
        dest, counts, offsets = mig_ops.scatter_dest(owner_new, C=num_nodes)
        # one O(n) scatter materializes the gather permutation (dest is a
        # permutation here: every owner id is valid)
        order = (jnp.zeros((n,), jnp.int32)
                 .at[dest].set(jnp.arange(n, dtype=jnp.int32),
                               unique_indices=True, mode="drop"))
    else:
        dest = None
        order = jnp.argsort(owner_new, stable=True).astype(jnp.int32)
        counts = jax.ops.segment_sum(ones, owner_new,
                                     num_segments=num_nodes)
        offsets = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32),
             jnp.cumsum(counts).astype(jnp.int32)])
    pair = owner_old * num_nodes + owner_new
    send = jax.ops.segment_sum(
        ones, pair, num_segments=num_nodes * num_nodes
    ).reshape(num_nodes, num_nodes)
    return Manifest(order=order, offsets=offsets, send_counts=send,
                    moved=owner_old != owner_new, dest=dest)


def apply_manifest(manifest: Manifest, *arrays) -> Tuple[jax.Array, ...]:
    """Gather every payload array into the manifest's bucketed layout."""
    return tuple(jnp.take(jnp.asarray(a), manifest.order, axis=0)
                 for a in arrays)


def build_and_apply(owner_old, owner_new, arrays: Sequence, *,
                    num_nodes: int, method: str = "auto"):
    """Fused build + apply: ``(relocated_arrays, manifest)`` in one trace.

    The scanned replay loops call this inside their step ``jit`` so the
    whole pipeline — counts, offsets, destinations, permutation, payload
    gathers — compiles into a single XLA program with no executable
    boundary between the manifest build and the payload movement.  On
    the scatter path the permutation is materialized exactly once (one
    i32 scatter) and every payload array then moves by gather: per-array
    destination scatters were measured slower than scatter-once + gather
    on CPU XLA (scatters cost ~25× a gather there) and scatters
    serialize on TPU, so the gather form wins for any payload count.
    Layout is bit-for-bit the ``method="sort"`` result."""
    man = build_manifest(owner_old, owner_new, num_nodes, method=method)
    return apply_manifest(man, *arrays), man


def inverse_permutation(order) -> jax.Array:
    """Scatter permutation undoing :func:`apply_manifest`'s gather."""
    order = jnp.asarray(order, jnp.int32)
    return (jnp.zeros(order.shape, jnp.int32)
            .at[order].set(jnp.arange(order.shape[0], dtype=jnp.int32)))


@functools.lru_cache(maxsize=32)
def _migrate_exec(num_nodes: int, donate: bool, method: str):
    def fn(owner_old, owner_new, arrays):
        return build_and_apply(owner_old, owner_new, arrays,
                               num_nodes=num_nodes, method=method)

    return jax.jit(fn, donate_argnums=(2,) if donate else ())


def migrate(owner_old, owner_new, arrays: Sequence, *, num_nodes: int,
            donate: Optional[bool] = None, method: str = "auto",
            capacity: Optional[int] = None):
    """Eager single-device migration: ``(relocated_arrays, manifest)``.

    ``donate=None`` donates the payload buffers wherever the backend
    supports donation (not CPU XLA) — the executed exchange then
    double-buffers in place instead of allocating a second copy.
    ``method`` is the manifest-build knob (see :func:`build_manifest`);
    the relocated layout is identical for every setting.  ``capacity``,
    if given, bounds the per-**node** slot count of the relocated
    layout; exceeding it raises :class:`CapacityOverflowError` with the
    per-node inflow counts and offending node ids (the eager path stays
    strict — spill semantics belong to the in-scan exchanges)."""
    if donate is None:
        donate = jax.default_backend() != "cpu"
    with compat.named_scope("exchange/migrate"):
        out, man = _migrate_exec(int(num_nodes), bool(donate),
                                 str(method))(
            jnp.asarray(owner_old, jnp.int32),
            jnp.asarray(owner_new, jnp.int32), tuple(arrays))
    if capacity is not None:
        counts = np.diff(np.asarray(man.offsets))
        if (counts > int(capacity)).any():
            raise CapacityOverflowError(capacity=capacity, counts=counts,
                                        unit="node")
    return out, man


# ------------------------------------------------- spill (degradation) --


def spill_admissions(flow, occupancy, capacity) -> jax.Array:
    """Feasible admitted-flow matrix under a per-group slot budget.

    ``flow`` is the (G, G) i32 *wanted* move-count matrix between groups
    (nodes or shards; the diagonal — items staying put — is ignored),
    ``occupancy`` the (G,) current item count per group, ``capacity``
    the static slot budget every group must respect after the exchange.
    Returns ``A`` (G, G) with ``0 <= A <= off-diag(flow)`` such that
    every post-exchange count ``occupancy - A.sum(1) + A.sum(0)`` is
    ``<= capacity``, shrinking as little flow as possible per round and
    deferring from the **highest source index first** (a fixed
    deterministic rule, so replay trajectories are reproducible).

    A fixed point exists whenever ``occupancy <= capacity`` (``A = 0``
    is then feasible); each ``lax.while_loop`` round strictly reduces
    the admitted total, so termination is guaranteed.  Groups that are
    over budget *before* any exchange (only possible with a
    caller-violated precondition) exit with ``A = 0`` rather than loop
    forever.  Traceable and scan-safe — this is the solver behind both
    the per-node :func:`spill_owner` and the per-shard spill mode of
    :func:`ring_exchange`."""
    flow = jnp.asarray(flow, jnp.int32)
    G = flow.shape[0]
    occupancy = jnp.asarray(occupancy, jnp.int32)
    capacity = jnp.asarray(capacity, jnp.int32)
    eye = jnp.eye(G, dtype=bool)
    F = jnp.where(eye, 0, flow)

    def post(A):
        return occupancy - A.sum(axis=1) + A.sum(axis=0)

    def cond(A):
        return (post(A) > capacity).any() & (A.sum() > 0)

    def body(A):
        over = jnp.maximum(post(A) - capacity, 0)            # (G,)
        # per column: how much flow arrives from rows *below* each source
        # — cutting top-down means cut[s] covers whatever the rows after
        # it cannot absorb
        below = (jnp.cumsum(A[::-1], axis=0)[::-1] - A)      # (G, G)
        cut = jnp.clip(over[None, :] - below, 0, A)
        return A - cut

    return jax.lax.while_loop(cond, body, F)


def spill_owner(owner_old, owner_new, *, num_nodes: int, capacity):
    """Clamp a plan's per-node inflow to ``capacity`` by deferring moves.

    The single-device counterpart of :func:`ring_exchange`'s spill mode:
    items whose admission would push the destination node over the slot
    budget keep their **old** owner (they stay physically where they
    are) and simply retry at the next fired rebalance, when the next
    plan recomputes ``owner_new`` from the live assignment.  Within each
    (src, dst) flow the *first* items in slab order are admitted —
    deterministic, so replay trajectories are reproducible.

    Returns ``(owner_eff, deferred)``: the effective (n,) owner vector
    to hand to :func:`build_and_apply` / :func:`migrate`, and the (n,)
    bool mask of deferred items (``deferred.sum()`` is the per-step
    ``deferred_count``).  Requires every *current* per-node count to be
    ``<= capacity`` (always true when the previous exchange respected
    the same budget); payload is never dropped either way."""
    P = int(num_nodes)
    oo = jnp.asarray(owner_old, jnp.int32)
    on = jnp.asarray(owner_new, jnp.int32)
    move = on != oo
    ones = jnp.ones(oo.shape, jnp.int32)
    pair = oo * P + on
    F = jax.ops.segment_sum(
        jnp.where(move, 1, 0).astype(jnp.int32), pair,
        num_segments=P * P).reshape(P, P)
    occ = jax.ops.segment_sum(ones, oo, num_segments=P)
    A = spill_admissions(F, occ, capacity)
    # stable within-flow rank: admitted = first A[src, dst] movers of
    # each flow, in slab order (the same counting-scatter primitive the
    # manifest build uses; non-movers rank against the padding sentinel)
    rank, _ = mig_ops.bucket_ranks(jnp.where(move, pair, P * P), C=P * P)
    quota = jnp.take(A.reshape(-1), jnp.clip(pair, 0, P * P - 1))
    admitted = move & (rank < quota)
    deferred = move & ~admitted
    return jnp.where(deferred, oo, on), deferred


# ----------------------------------------------------- sharded exchange --


def ring_exchange(owner_loc, arr_loc: Tuple, *, num_nodes: int, D: int,
                  capacity: int, axis: str, count_loc=None,
                  mode: str = "strict"):
    """Per-shard ring all-to-all core (runs under ``shard_map``).

    Shard ``d`` owns nodes ``[d*rpd, (d+1)*rpd)``.  The local block
    rotates D-1 ``ppermute`` hops; at hop ``s`` shard ``me`` sees the
    block of shard ``(me+s) % D`` and scatters the items it owns into
    its (capacity,) output at exact global-bucket positions, computed
    from the all-gathered (D, P) count matrix plus the sort-free
    within-bucket rank (``kernels.migrate.bucket_ranks`` — the same
    counting-scatter primitive the single-device manifest build uses) —
    so the concatenated per-shard valid prefixes reproduce the
    single-device stable bucketed order bit-for-bit.

    ``count_loc`` (i32 scalar, optional) marks only the first
    ``count_loc`` slots of this shard's slab as live items; the rest are
    padding and are neither counted nor scattered.  ``None`` treats the
    whole slab as live (the :func:`migrate_sharded` entry).  The masked
    form is what lets the **sharded replay loop**
    (``distributed/replay_shard.py``) carry fixed-``capacity`` payload
    slabs through ``lax.scan`` and re-bucket them at every fired
    rebalance without a host trip.

    ``mode`` selects the overflow semantics.  ``"strict"`` (default)
    assumes the plan fits the slot budget — the caller is responsible
    for checking the returned counts (the layout contract above holds).
    ``"spill"`` is the graceful-degradation exchange: per-shard inflow
    is clamped to ``capacity`` by the :func:`spill_admissions` fixed
    point, overflow items **stay on their source shard** (their desired
    owner id is preserved in the owner slab so the next fired rebalance
    retries them), and the extra return value ``deferred`` (replicated
    i32 scalar) counts them.  Spill keeps every item exactly once —
    payload is never dropped — but gives up the bit-for-bit bucketed
    *layout* contract: kept items compact to the slab prefix in slab
    order, admitted inflow appends in (source shard, within-flow rank)
    order.

    Returns ``(out_owner, outs, count_me)`` — the (capacity,) relocated
    owner/payload slabs (valid prefix ``count_me``) for this shard —
    plus ``deferred`` in spill mode.
    """
    if mode not in ("strict", "spill"):
        raise ValueError(f"unknown ring_exchange mode {mode!r}")
    rpd = num_nodes // D
    me = jax.lax.axis_index(axis)
    slots = jnp.arange(owner_loc.shape[0], dtype=jnp.int32)
    live = (jnp.ones(owner_loc.shape, bool) if count_loc is None
            else slots < jnp.asarray(count_loc, jnp.int32))
    # padding slots carry stale owner ids: segment them out of range so
    # they contribute to no bucket
    owner_loc = jnp.where(live, owner_loc, num_nodes)
    cnt_loc = jax.ops.segment_sum(
        jnp.ones(owner_loc.shape, jnp.int32), owner_loc,
        num_segments=num_nodes)
    counts = jax.lax.all_gather(cnt_loc, axis)          # (D, P)
    if mode == "spill":
        return _ring_exchange_spill(
            owner_loc, arr_loc, live=live, counts=counts,
            num_nodes=num_nodes, D=D, capacity=capacity, axis=axis, me=me)
    with compat.named_scope("exchange/ring"):
        bucket = counts.sum(axis=0)                     # (P,) global sizes
        my_sizes = jax.lax.dynamic_slice(bucket, (me * rpd,), (rpd,))
        my_base = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32),
             jnp.cumsum(my_sizes).astype(jnp.int32)])[:rpd]  # (rpd,)

        # payload slabs relocate on the leading axis; trailing axes ride
        # along untouched (expert weight matrices are just bigger rows)
        outs = tuple(jnp.zeros((capacity,) + a.shape[1:], a.dtype)
                     for a in arr_loc)
        out_owner = jnp.zeros((capacity,), jnp.int32)
        buf = (owner_loc,) + tuple(arr_loc)
        for s in range(D):
            src = (me + s) % D
            pe = buf[0]
            accept = (pe // rpd) == me  # padding (pe == P) accepts nowhere
            # items from earlier source shards land first within each
            # bucket (source order == global index order: shards hold
            # contiguous global ranges), preserving the stable tie order
            before = (counts * (jnp.arange(D)[:, None] < src)).sum(0)
            # per-shard placement rides the shared sort-free counting-
            # scatter op: stable within-bucket rank of the accepted items
            # (rejected slots mask to the padding sentinel → rank −1)
            rank, _ = mig_ops.bucket_ranks(
                jnp.where(accept, pe, num_nodes), C=num_nodes)
            r = jnp.clip(pe - me * rpd, 0, rpd - 1)
            pos = jnp.where(
                accept,
                my_base[r] + jnp.take(before, pe, mode="clip") + rank,
                capacity)
            out_owner = out_owner.at[pos].set(pe, mode="drop")
            outs = tuple(o.at[pos].set(v, mode="drop")
                         for o, v in zip(outs, buf[1:]))
            if s + 1 < D:
                buf = tuple(
                    jax.lax.ppermute(
                        b, axis, [(d, (d - 1) % D) for d in range(D)])
                    for b in buf)
        count_me = my_sizes.sum().astype(jnp.int32)
        return out_owner, outs, count_me


def _ring_exchange_spill(owner_loc, arr_loc, *, live, counts,
                         num_nodes: int, D: int, capacity: int, axis: str,
                         me):
    """Spill-mode ring body (see :func:`ring_exchange` ``mode="spill"``).

    Admission is decided **on the source shard** from the replicated
    (D, D) shard-flow matrix, travels with the payload around the ring,
    and the destination scatters admitted items at
    ``kept_prefix + cumulative-admitted-before-source + within-flow
    rank`` — every position is < capacity by the admission fixed
    point's feasibility, so no ``mode="drop"`` scatter ever fires on a
    live item."""
    rpd = num_nodes // D
    # (D, D) wanted shard-level flow (diagonal = stays, solver ignores it)
    flow = counts.reshape(D, D, rpd).sum(-1)
    occ = counts.sum(axis=1)                             # (D,) live counts
    A = spill_admissions(flow, occ, capacity)            # (D, D) admitted
    dshard = jnp.minimum(owner_loc // rpd, D)            # padding → D
    fid = jnp.where(live & (dshard != me), dshard, D)
    # stable within-flow rank among this shard's movers to each dest
    rank, _ = mig_ops.bucket_ranks(fid, C=D)
    quota = jnp.take(A[me], jnp.clip(dshard, 0, D - 1))
    admitted = (fid < D) & (rank < quota)
    keep = live & ~admitted
    kept_me = keep.sum().astype(jnp.int32)
    # kept items (stays + deferred movers, desired owner id preserved)
    # compact to the slab prefix in slab order
    kpos = jnp.where(keep,
                     jnp.cumsum(keep.astype(jnp.int32)) - 1, capacity)
    out_owner = jnp.zeros((capacity,), jnp.int32).at[kpos].set(
        owner_loc, mode="drop")
    outs = tuple(
        jnp.zeros((capacity,) + a.shape[1:], a.dtype).at[kpos].set(
            a, mode="drop")
        for a in arr_loc)
    buf = (owner_loc, admitted.astype(jnp.int32), rank) + tuple(arr_loc)
    shift = [(d, (d - 1) % D) for d in range(D)]
    for s in range(1, D):
        buf = tuple(jax.lax.ppermute(b, axis, shift) for b in buf)
        src = (me + s) % D
        pe_b, adm_b, rank_b = buf[0], buf[1], buf[2]
        accept = (adm_b == 1) & (jnp.minimum(pe_b // rpd, D) == me)
        base = kept_me + (A[:, me] * (jnp.arange(D) < src)).sum()
        pos = jnp.where(accept, base + rank_b, capacity)
        out_owner = out_owner.at[pos].set(pe_b, mode="drop")
        outs = tuple(o.at[pos].set(v, mode="drop")
                     for o, v in zip(outs, buf[3:]))
    count_me = (kept_me + A[:, me].sum()).astype(jnp.int32)
    eye = jnp.eye(D, dtype=bool)
    deferred = (jnp.where(eye, 0, flow).sum() - A.sum()).astype(jnp.int32)
    return out_owner, outs, count_me, deferred


def _sharded_body(owner_loc, *arr_loc, num_nodes: int, D: int,
                  capacity: int, axis: str):
    """``shard_map`` adapter over :func:`ring_exchange` (whole slab live)."""
    out_owner, outs, count_me = ring_exchange(
        owner_loc, tuple(arr_loc), num_nodes=num_nodes, D=D,
        capacity=capacity, axis=axis)
    return (out_owner,) + outs + (count_me[None],)


def _sharded_body_spill(owner_loc, *arr_loc, num_nodes: int, D: int,
                        capacity: int, axis: str):
    """Spill-mode ``shard_map`` adapter (whole slab live)."""
    out_owner, outs, count_me, deferred = ring_exchange(
        owner_loc, tuple(arr_loc), num_nodes=num_nodes, D=D,
        capacity=capacity, axis=axis, mode="spill")
    return (out_owner,) + outs + (count_me[None], deferred[None])


def planned_capacity(owner_new, *, num_nodes: int, num_shards: int) -> int:
    """Static per-shard slot budget planned from an executed plan.

    The exchange's exact space requirement on shard ``d`` is the total
    bucket size of the nodes it owns — the **max inflow bound** the
    planner's flow budget realizes once stage 3 has assigned objects.
    This host-side helper computes that tight bound from ``owner_new``
    (one transfer; the eager :func:`migrate_sharded` entry already
    synchronizes on the result).  Callers that need a trace-time
    constant (the sharded replay loop, which sizes its ``lax.scan``
    payload slabs before any plan exists) must fall back to the
    worst-case ``n``."""
    counts = np.bincount(np.asarray(owner_new), minlength=num_nodes)
    per_shard = counts.reshape(num_shards, num_nodes // num_shards).sum(1)
    return max(1, int(per_shard.max()))


def migrate_sharded(owner_new, arrays: Sequence, *, num_nodes: int,
                    mesh: Optional[Mesh] = None,
                    capacity: Optional[int] = None,
                    on_overflow: str = "strict"):
    """Ring all-to-all payload exchange across a 1-D device mesh.

    ``owner_new`` / ``arrays`` are the *global* (n,) buffers, row-sharded
    over the mesh (n and ``num_nodes`` must divide the shard count; the
    caller pads if not).  ``capacity`` is the static per-shard slot
    budget; ``None`` (the default) derives the tight bound from the
    plan itself — :func:`planned_capacity`, the max per-shard inflow —
    so callers no longer have to pass the worst-case ``n``.  An explicit
    ``capacity`` overrides the planned bound (e.g. to keep one compiled
    executable across calls).

    ``on_overflow`` picks the degradation semantics when the plan wants
    more items on a shard than ``capacity`` allows.  ``"strict"`` (the
    default, and the eager contract) raises
    :class:`CapacityOverflowError` with the per-shard inflow counts and
    offending shard ids — payload is never lost silently.  ``"spill"``
    executes the admissible part of the exchange instead: inflow is
    clamped to ``capacity``, overflow items stay on their source shard
    (keeping their desired owner id, so a later call retries them), and
    a fourth return value ``deferred`` (int) counts them.  Spill gives
    up the bit-for-bit layout contract below (see
    :func:`ring_exchange`).

    Returns ``(owner_out, arrays_out, counts)`` where the outputs are
    (D*capacity,) padded global buffers (shard ``d``'s valid prefix is
    ``[d*capacity, d*capacity + counts[d])``) and ``counts`` is (D,) —
    plus ``deferred`` when ``on_overflow="spill"``.  In strict mode,
    concatenating the valid prefixes equals the single-device
    ``apply_manifest`` layout bit-for-bit."""
    if on_overflow not in ("strict", "spill"):
        raise ValueError(f"unknown on_overflow mode {on_overflow!r}")
    if mesh is None:
        mesh = Mesh(np.asarray(jax.devices()), (AXIS,))
    if len(mesh.axis_names) != 1:
        raise ValueError("migrate_sharded needs a 1-D mesh")
    ax = mesh.axis_names[0]
    D = int(np.prod(mesh.devices.shape))
    owner_new = jnp.asarray(owner_new, jnp.int32)
    n = owner_new.shape[0]
    if n % D or num_nodes % D:
        raise ValueError(
            f"n={n} and num_nodes={num_nodes} must divide the {D}-device "
            "mesh")
    spill = on_overflow == "spill"
    if capacity is None:
        capacity = planned_capacity(owner_new, num_nodes=num_nodes,
                                    num_shards=D)
        if spill:
            # the planned bound always fits; a spill caller wants a
            # *tighter* budget, but never below the current occupancy
            # (the admission fixed point needs occupancy <= capacity)
            capacity = max(capacity, n // D)
    if spill and int(capacity) < n // D:
        raise ValueError(
            f"spill capacity={int(capacity)} is below the per-shard "
            f"occupancy {n // D}; the current slabs must already fit")
    body = functools.partial(
        _sharded_body_spill if spill else _sharded_body,
        num_nodes=int(num_nodes), D=D, capacity=int(capacity), axis=ax)
    arrays = tuple(jnp.asarray(a) for a in arrays)
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(P_(ax),) * (1 + len(arrays)),
        out_specs=(P_(ax),) * ((3 if spill else 2) + len(arrays)),
        check_vma=False)
    out = fn(owner_new, *arrays)
    if spill:
        deferred = int(np.asarray(out[-1])[0])
        return out[0], out[1:-2], out[-2], deferred
    counts = np.asarray(out[-1])
    if (counts > capacity).any():
        raise CapacityOverflowError(capacity=capacity, counts=counts,
                                    unit="shard")
    return out[0], out[1:-1], out[-1]
