"""Migration / amortization cost model for the online rebalancing runtime.

The paper treats migration cost as a first-class term of the LB objective
(§II metric 3/4): a rebalance is only worth taking when the load-imbalance
time it recovers amortizes the bytes it moves plus the planning overhead.
This module is the single place where that trade-off is priced.  It
unifies

  * the PIC driver's :class:`repro.pic.driver.CostModel` per-term model
    (``t_particle``/``t_byte``/``lb_seconds``) — see :meth:`from_pic`;
  * the replay layers' bytes accounting (``StepMetrics`` ext/int bytes,
    ``PICResult.migrated_bytes``) — see :meth:`step_seconds` /
    :func:`series_modeled_seconds`.

Everything is a pure function of scalars/arrays (jnp-traceable), and the
model itself is a frozen dataclass of floats — hashable, so triggers that
embed one can key the replay layers' compiled-runner caches.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class RuntimeCostModel:
    """Per-term cost model in seconds, shared by triggers and benchmarks.

    Attributes:
      t_load:         seconds one unit of object load costs the critical
                      path per application step (PIC: seconds/particle —
                      ``CostModel.t_particle``).
      t_byte:         seconds per byte crossing a node boundary.
      bytes_per_load: migration payload bytes carried by one unit of load
                      (PIC: ``bytes_per_particle``; simulator workloads
                      default to 1 byte/load-unit).
      lb_overhead:    fixed seconds charged per executed rebalance —
                      planning + barrier + manifest exchange (the
                      amortized ``CostModel.lb_seconds`` term).
      moved_frac_est: a-priori estimate of the load fraction a rebalance
                      migrates, used by predictive triggers *before* the
                      plan exists (the paper's diffusion strategies move
                      ~15-19% — Table II).
    """

    t_load: float = 1.0
    t_byte: float = 1.0
    bytes_per_load: float = 1.0
    lb_overhead: float = 0.0
    moved_frac_est: float = 0.15

    # --------------------------------------------------------- pricing --

    def imbalance_seconds(self, max_load, avg_load):
        """Per-step time lost to imbalance: the excess of the slowest
        node over the average, priced at ``t_load`` (traceable)."""
        return jnp.maximum(max_load - avg_load, 0.0) * self.t_load

    def migration_seconds(self, moved_load):
        """Executed-exchange cost: payload bytes on the wire plus the
        fixed per-rebalance overhead (traceable)."""
        return (moved_load * self.bytes_per_load * self.t_byte
                + self.lb_overhead)

    def est_migration_seconds(self, total_load):
        """A-priori migration cost for a rebalance that has not been
        planned yet: ``moved_frac_est`` of the total load (traceable)."""
        return self.migration_seconds(self.moved_frac_est * total_load)

    def step_seconds(self, max_load, moved_load, lb_fired):
        """Modeled wall seconds of one application step: slowest-node
        compute + executed migration traffic + LB overhead when fired."""
        fired = jnp.asarray(lb_fired, jnp.float32)
        return (jnp.asarray(max_load, jnp.float32) * self.t_load
                + jnp.asarray(moved_load, jnp.float32)
                * self.bytes_per_load * self.t_byte
                + fired * self.lb_overhead)

    # --------------------------------------------------------- bridges --

    @classmethod
    def from_pic(cls, pic_cost, *, strategy: str, num_pes: int,
                 bytes_per_particle: float, plan_seconds: float = 0.0,
                 moved_frac_est: float = 0.15) -> "RuntimeCostModel":
        """Bridge from the PIC driver's :class:`CostModel`.

        ``plan_seconds`` is the measured planning wall time; it is
        amortized exactly as ``CostModel.lb_seconds`` amortizes it
        (diffusion is distributed — divided by ``num_pes``; centralized
        planners are charged in full)."""
        return cls(
            t_load=float(pic_cost.t_particle),
            t_byte=float(pic_cost.t_byte),
            bytes_per_load=float(bytes_per_particle),
            lb_overhead=float(
                pic_cost.lb_seconds(plan_seconds, strategy, num_pes)),
            moved_frac_est=float(moved_frac_est),
        )


def series_modeled_seconds(result, model: RuntimeCostModel) -> np.ndarray:
    """(T,) modeled seconds per step of a :class:`SeriesResult`.

    Requires the runtime-era per-step records (``max_load``,
    ``migrated_load``, ``lb_fired`` — populated by every
    ``sim.simulator.run_series`` path since the trigger runtime landed).
    """
    for field in ("max_load", "migrated_load", "lb_fired"):
        if getattr(result, field, None) is None:
            raise ValueError(
                f"SeriesResult.{field} missing — series_modeled_seconds "
                "needs a result from sim.simulator.run_series")
    return np.asarray(model.step_seconds(
        jnp.asarray(result.max_load, jnp.float32),
        jnp.asarray(result.migrated_load, jnp.float32),
        jnp.asarray(result.lb_fired, jnp.float32)))
