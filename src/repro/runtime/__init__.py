"""Online rebalancing runtime: the third pillar beside planning and
kernels.

``triggers`` decides *when* to rebalance (scan-safe adaptive policies),
``migrate`` executes the resulting exchange (device-resident payload
relocation, single-device and mesh-sharded), and ``cost`` prices the
trade-off (migration/amortization model shared by triggers and the
benchmarks).  Wired through ``sim/simulator.run_series`` (``trigger=``),
``pic/driver`` (executed particle migration) and
``distributed/lb_shard`` (sharded apply).
"""
from repro.runtime import cost, migrate, triggers  # noqa: F401
from repro.runtime.cost import RuntimeCostModel  # noqa: F401
from repro.runtime.triggers import (  # noqa: F401
    EveryTrigger, PredictiveTrigger, ThresholdTrigger, TriggerState,
)
