"""Resilience layer for the sharded replay runtime.

The replay loops (``distributed/replay_shard.py``) keep a persistently
communicating application balanced *while it runs*; this module makes
that claim survive an imperfect world.  Four pieces, all deterministic
and scan-safe (pure functions of the step index — nothing new rides in
the ``lax.scan`` carry):

* **Fault injection** — :class:`FaultSchedule`: a static list of
  ``(step, shard, kind)`` events (``die`` / ``slow`` / ``recover``)
  whose :meth:`~FaultSchedule.shard_health` projection is traceable in
  ``t``, so the same schedule replays bit-identically inside a scan, a
  chunked scan, or after a checkpoint restore.
* **Health-masked planning** — :func:`rehome_dead` moves a dead shard's
  objects onto the healthy node with the strongest communication
  affinity (falling back to the least-loaded alive node), and
  :func:`mask_preference` zeroes the stage-1 preference rows/columns of
  dead nodes, so the existing three-stage diffusion planner re-diffuses
  the displaced load over the surviving mesh with conservation intact.
  The motivation follows Boulmier et al. (anticipate the disruption,
  don't crash on it) and Demirel & Sbalzarini (diffusion remains
  correct under hard per-node constraints) — see PAPERS.md.
* **Plan guardrails** — :func:`validate_plan` checks a candidate
  assignment on-device (owners in range and alive, finite loads,
  optional per-node slot bound); the replay loops ``lax.cond`` the
  adoption on the verdict and roll back to the last-good assignment,
  surfacing a per-step ``plan_rejected`` flag.
* **Checkpointed replay** — :func:`run_series_checkpointed` drives the
  sharded sim replay in ``checkpoint_every``-step chunks under
  ``train.fault_tolerance.run_resilient``, snapshotting the scan carry
  at every chunk boundary and resuming bit-exact after an injected
  supervisor failure (chunking a scan changes nothing numerically —
  the per-step program is identical).

Graceful **capacity degradation** (the spill exchange) lives with the
exchange itself — ``runtime.migrate.spill_admissions`` /
``spill_owner`` / ``ring_exchange(mode="spill")``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm_graph

_KINDS = ("die", "slow", "recover")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """Deterministic shard-fault script for the replay runtime.

    ``events`` is a tuple of ``(step, shard, kind)`` with ``kind`` one
    of ``"die"`` (the shard's nodes stop hosting objects), ``"slow"``
    (the shard keeps running at ``slow_factor`` of full speed — its
    effective load is scaled by ``1/slow_factor`` in trigger stats and
    planning), or ``"recover"`` (full health restored).  An event takes
    effect *at* its step and persists until overridden by a later event
    for the same shard.  The schedule is hashable (it keys compiled
    runner caches) and its health projection is a pure traceable
    function of the step index — scan-safe by construction, nothing is
    carried.

    An empty schedule is inert: the replay entries normalize it to
    ``None`` and take the exact pre-resilience code path, keeping every
    trajectory bit-for-bit unchanged.
    """

    events: Tuple[Tuple[int, int, str], ...] = ()
    slow_factor: float = 0.5

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(
            (int(s), int(d), str(k)) for s, d, k in self.events))
        seen = set()
        for step, shard, kind in self.events:
            if kind not in _KINDS:
                raise ValueError(
                    f"unknown fault kind {kind!r} (one of {_KINDS})")
            if step < 0 or shard < 0:
                raise ValueError(
                    f"fault event ({step}, {shard}, {kind!r}) must have "
                    "non-negative step and shard")
            if (step, shard) in seen:
                raise ValueError(
                    f"duplicate fault event for shard {shard} at step "
                    f"{step} — one event per (step, shard)")
            seen.add((step, shard))
        if not (0.0 < float(self.slow_factor) <= 1.0):
            raise ValueError("slow_factor must be in (0, 1]")

    @property
    def empty(self) -> bool:
        return not self.events

    def max_shard(self) -> int:
        """Largest shard id referenced (−1 for an empty schedule)."""
        return max((d for _, d, _ in self.events), default=-1)

    def _tables(self):
        steps = np.asarray([e[0] for e in self.events], np.int32)
        shards = np.asarray([e[1] for e in self.events], np.int32)
        codes = np.asarray([_KINDS.index(e[2]) for e in self.events],
                           np.int32)
        return steps, shards, codes

    def shard_health(self, t, D: int):
        """``(alive, speed)`` per shard at step ``t`` (traceable).

        ``alive`` is (D,) bool, ``speed`` (D,) f32 in (0, 1].  A shard
        is dead iff its most recent ``die`` is more recent than its most
        recent ``recover``; it is slowed iff its most recent ``slow``
        postdates both.  Negative ``t`` reads as "before any event" —
        everything healthy."""
        if self.empty:
            return (jnp.ones((D,), bool), jnp.ones((D,), jnp.float32))
        steps, shards, codes = self._tables()
        steps = jnp.asarray(steps)
        shards = jnp.asarray(shards)
        codes = jnp.asarray(codes)
        t = jnp.asarray(t, jnp.int32)
        active = steps <= t

        def last(kind):
            stamped = jnp.where(active & (codes == kind), steps, -1)
            seg = jax.ops.segment_max(stamped, shards, num_segments=D)
            return jnp.maximum(seg, -1)   # shards with no events

        die, slow, rec = last(0), last(1), last(2)
        alive = die <= rec
        slowed = (slow > rec) & (slow > die)
        speed = jnp.where(alive & slowed,
                          jnp.float32(self.slow_factor), jnp.float32(1.0))
        return alive, speed

    def node_health(self, t, num_nodes: int, D: int):
        """Shard health broadcast to the planner's node granularity.

        Shard ``d`` owns the contiguous node rows
        ``[d*rpd, (d+1)*rpd)`` (the replay layers' ownership map), so
        node health is ``repeat(shard_health, num_nodes // D)``."""
        alive, speed = self.shard_health(t, D)
        rpd = num_nodes // D
        return jnp.repeat(alive, rpd), jnp.repeat(speed, rpd)

    def changed_at(self, t, D: int):
        """Traceable bool: did any shard's health change at step ``t``?

        The replay loops OR this into the trigger decision so a
        rebalance fires on every health transition (a dying shard must
        be evacuated *now*, not at the next cadence tick)."""
        if self.empty:
            return jnp.asarray(False)
        a0, s0 = self.shard_health(jnp.asarray(t, jnp.int32) - 1, D)
        a1, s1 = self.shard_health(t, D)
        return ((a0 != a1) | (s0 != s1)).any()


# ------------------------------------------------ health-masked planning --


def mask_preference(preference, alive):
    """Zero stage-1 preference rows/columns of dead nodes.

    ``select_neighbors`` treats ``preference > 0`` as the candidate
    edge set, so a zeroed row/column removes a dead node from every
    neighborhood: no flow is computed toward it, no object targets it.
    With an all-alive mask this is a value-preserving identity."""
    alive = jnp.asarray(alive, bool)
    return jnp.where(alive[:, None] & alive[None, :], preference, 0.0)


def rehome_dead(problem: comm_graph.LBProblem, alive) -> jax.Array:
    """Re-home objects owned by dead nodes onto healthy ones.

    Each displaced object moves to the **alive node it communicates
    with most** (its per-node byte total under the current assignment —
    the same comm-graph machinery stage 1 uses), falling back to the
    least-loaded alive node when it has no alive communication partner.
    Deterministic (argmax/argmin tie-break to the lowest node id) and
    conservation-preserving: every object keeps exactly one owner.  The
    result seeds the masked three-stage plan, which then diffuses the
    displaced load properly over the surviving mesh.

    If *no* node is alive the assignment is returned with dead owners
    intact — :func:`validate_plan` then rejects the plan and the replay
    loop keeps the last-good assignment (a fully dead mesh has no
    correct answer)."""
    P = problem.num_nodes
    a = jnp.asarray(problem.assignment, jnp.int32)
    alive = jnp.asarray(alive, bool)
    dead_obj = ~jnp.take(alive, jnp.clip(a, 0, P - 1))
    valid = problem.edges_src >= 0
    src = jnp.where(valid, problem.edges_src, 0)
    dst = jnp.where(valid, problem.edges_dst, 0)
    w = jnp.where(valid, problem.edges_bytes, 0.0).astype(jnp.float32)
    N = int(a.shape[0])
    # (N, P) per-object bytes toward each node under the current owners
    owners_dst = jnp.take(a, dst)
    owners_src = jnp.take(a, src)
    byts = (jax.ops.segment_sum(w, src * P + owners_dst,
                                num_segments=N * P)
            + jax.ops.segment_sum(w, dst * P + owners_src,
                                  num_segments=N * P)).reshape(N, P)
    score = jnp.where(alive[None, :], byts, jnp.float32(-1.0))
    best = jnp.argmax(score, axis=1).astype(jnp.int32)
    has_comm = jnp.max(score, axis=1) > 0.0
    nl = comm_graph.node_loads(problem)
    fallback = jnp.argmin(jnp.where(alive, nl, jnp.inf)).astype(jnp.int32)
    any_alive = alive.any()
    target = jnp.where(has_comm, best, fallback)
    return jnp.where(dead_obj & any_alive, target, a)


def degrade_problem(problem: comm_graph.LBProblem, alive,
                    speed=None) -> comm_graph.LBProblem:
    """Project a problem onto a degraded mesh before planning.

    Re-homes dead nodes' objects (:func:`rehome_dead`) and, when
    ``speed`` is given, scales each object's load by the reciprocal
    speed of its (post-rehome) owner — a slowed shard looks
    proportionally heavier to the diffusion sweep, so load drains off
    it.  The scaling is a planning-side approximation only; metrics
    and trigger accounting keep the true loads."""
    a = rehome_dead(problem, alive)
    problem = problem.with_assignment(a)
    if speed is not None:
        w = (jnp.float32(1.0)
             / jnp.maximum(jnp.asarray(speed, jnp.float32), 1e-6))
        loads = problem.loads * jnp.take(w, a)
        problem = dataclasses.replace(problem, loads=loads)
    return problem


# -------------------------------------------------------- plan guardrails --


def validate_plan(assignment, loads, *, num_nodes: int, alive=None,
                  node_capacity=None) -> jax.Array:
    """On-device plan guardrail: bool scalar, traceable and scan-safe.

    Accepts iff (a) every object has exactly one owner — structural,
    ``assignment`` is a dense (N,) vector — with the owner id in
    ``[0, num_nodes)``; (b) every load is finite; (c) every owner is
    alive, when an ``alive`` mask is given; (d) no node receives more
    than ``node_capacity`` objects, when a bound is given.  The replay
    loops ``lax.cond`` plan adoption on this verdict and roll back to
    the last-good assignment otherwise (surfaced per step as
    ``plan_rejected``), so one bad plan degrades a step instead of
    corrupting the whole trajectory."""
    a = jnp.asarray(assignment, jnp.int32)
    if a.ndim != 1:
        raise ValueError("assignment must be a dense (N,) owner vector")
    loads = jnp.asarray(loads)
    in_range = ((a >= 0) & (a < num_nodes)).all()
    ok = in_range & jnp.isfinite(loads).all()
    safe = jnp.clip(a, 0, num_nodes - 1)
    if alive is not None:
        ok = ok & jnp.take(jnp.asarray(alive, bool), safe).all()
    if node_capacity is not None:
        counts = jax.ops.segment_sum(
            jnp.ones(a.shape, jnp.int32), safe, num_segments=num_nodes)
        ok = ok & (counts <= jnp.asarray(node_capacity, jnp.int32)).all()
    return ok


def finite_or(value, fallback):
    """``value`` where finite, ``fallback`` elsewhere (shared guard)."""
    value = jnp.asarray(value)
    return jnp.where(jnp.isfinite(value), value, fallback)


# --------------------------------------------- checkpointed sharded replay --


def run_series_checkpointed(initial, evolve, *, steps: int,
                            checkpoint_every: int,
                            lb_every: int = 10,
                            strategy: str = "diff-comm",
                            strategy_kwargs: Optional[dict] = None,
                            trigger=None, mesh=None,
                            num_shards: Optional[int] = None,
                            threads_per_node: Optional[int] = None,
                            faults: Optional[FaultSchedule] = None,
                            guard: Optional[bool] = None,
                            fail_at=(), max_restarts: int = 8):
    """Checkpoint/restart-supervised sharded replay (bit-exact).

    Runs the same per-step program as
    ``distributed.replay_shard.run_series_sharded`` but in
    ``checkpoint_every``-step chunks: the scan carry (problem arrays +
    trigger state) is snapshotted to host memory at every chunk
    boundary, and the chunk loop is driven by
    ``train.fault_tolerance.run_resilient`` — the supervisor that
    restores the last snapshot and replays the interrupted chunk on a
    ``WorkerFailure``.  Chunking a ``lax.scan`` does not change its
    per-step numerics, so the result is **bit-for-bit** the uninterrupted
    ``run_series_sharded`` trajectory, with or without injected
    failures.

    ``fail_at`` is the test hook: an iterable of chunk indices at which
    one ``WorkerFailure`` is raised (once each) before the chunk runs.
    ``faults`` / ``guard`` compose — the supervisor restarts the
    *driver*, the fault schedule degrades the *mesh*; the two failure
    domains are independent.  Shorter ``checkpoint_every`` bounds the
    replayed work after a crash but pays more host synchronizations —
    the cadence trade-off documented in the README.

    Returns the same ``SeriesResult`` as ``run_series_sharded`` (wall
    fields reflect the chunked execution)."""
    import time

    from repro.distributed import replay_shard as rs
    from repro.train import fault_tolerance as ft

    if checkpoint_every < 1:
        raise ValueError("checkpoint_every must be >= 1")
    t0 = time.perf_counter()
    chunks = [min(checkpoint_every, steps - s)
              for s in range(0, steps, checkpoint_every)]
    prepared = rs.prepare_series(
        initial, evolve, steps=steps, lb_every=lb_every, strategy=strategy,
        strategy_kwargs=strategy_kwargs, trigger=trigger, mesh=mesh,
        num_shards=num_shards, threads_per_node=threads_per_node,
        faults=faults, guard=guard)
    carry = prepared.initial_carry()
    snapshots: Dict[int, tuple] = {0: jax.device_get(carry)}
    ys_chunks: Dict[int, tuple] = {}
    pending = set(int(c) for c in fail_at)
    state = {"carry": carry}

    def step_fn(ci):
        if ci in pending:
            pending.discard(ci)
            raise ft.WorkerFailure(f"injected failure before chunk {ci}")
        t_start = sum(chunks[:ci])
        new_carry, ys = prepared.run_chunk(state["carry"], t_start,
                                           chunks[ci])
        state["carry"] = new_carry
        ys_chunks[ci] = jax.device_get(ys)

    def save_fn(ci):
        snapshots[ci] = jax.device_get(state["carry"])

    def restore_fn():
        ci = max(snapshots)
        state["carry"] = tuple(jnp.asarray(a) for a in snapshots[ci])
        return ci

    ft.run_resilient(step_fn, start_step=0, num_steps=len(chunks),
                     save_every=1, save_fn=save_fn,
                     restore_fn=restore_fn, max_restarts=max_restarts)
    ys = tuple(np.concatenate([ys_chunks[ci][j]
                               for ci in range(len(chunks))])
               for j in range(len(ys_chunks[0])))
    return prepared.package(state["carry"], ys,
                            wall_seconds=time.perf_counter() - t0)
