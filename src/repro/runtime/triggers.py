"""Scan-safe LB triggers: *when* to rebalance, decided on device.

The replay layers historically rebalanced on a fixed cadence
(``lb_every``).  The paper's objective (§II) and the anticipation
literature (Boulmier et al., PAPERS.md) both say the decision should be
adaptive: rebalance when the imbalance-time a plan would recover
amortizes the migration it costs.  This module provides that decision as
a pure, ``lax.cond``-compatible function so the scanned replay paths can
keep the whole loop device-resident.

Every trigger is a frozen dataclass (hashable — it participates in the
compiled-runner cache keys of ``sim/simulator`` and ``pic/driver``) with

  * ``init_state() -> TriggerState`` — fixed-shape device carry;
  * ``decide(state, t, max_load, avg_load, total_load)
       -> (do: bool scalar, TriggerState)`` — traceable, called every
    step *before* planning with the pre-LB load statistics;
  * ``never`` — static Python bool; True means the trigger can be
    elided from the trace entirely (matching the legacy
    ``lb_every <= 0`` fast path).

Triggers:

  ``EveryTrigger``      — fixed period; ``decide`` reproduces the legacy
                          ``(t > 0) & (t % lb_every == 0)`` predicate
                          bit-for-bit.
  ``ThresholdTrigger``  — fires when max/avg exceeds ``hi``, with
                          hysteresis (re-arms when imbalance falls below
                          ``lo`` or after ``rearm_after`` steps) and a
                          ``min_interval`` refractory period.
  ``PredictiveTrigger`` — linear-trend anticipation: fits a least-squares
                          slope to the last ``window`` excess-load
                          samples and fires only when the predicted
                          imbalance-time over ``horizon`` steps exceeds
                          the modeled migration cost
                          (``RuntimeCostModel.est_migration_seconds``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.distributed import compat
from repro.runtime.cost import RuntimeCostModel


class TriggerState(NamedTuple):
    """Fixed-shape device carry shared by every trigger kind.

    ``history`` is a ring-free rolling window (newest sample last) sized
    by the trigger's static ``window``; simple triggers carry a length-1
    window they never read.  ``last_moved`` is the measured load volume
    of the most recent *executed* exchange (fed back by the replay
    layers via :meth:`PredictiveTrigger.observe`); negative means no
    exchange has been observed yet — the cold-start regime, where the
    predictive gate falls back to ``RuntimeCostModel.moved_frac_est``."""

    last_lb: jax.Array     # i32 — step index of the last fired rebalance
    armed: jax.Array       # bool — hysteresis arm flag
    history: jax.Array     # (W,) f32 — recent excess-load samples
    hist_len: jax.Array    # i32 — valid entries at the tail of history
    last_moved: jax.Array  # f32 — load moved by the last executed
    #                        exchange; < 0 until one has been observed


def _init_state(window: int) -> TriggerState:
    return TriggerState(
        last_lb=jnp.int32(-(1 << 30)),
        armed=jnp.asarray(True),
        history=jnp.zeros((max(1, int(window)),), jnp.float32),
        hist_len=jnp.int32(0),
        last_moved=jnp.float32(-1.0),
    )


def load_stats(loads, assignment, num_nodes: int):
    """(max, avg, total) node load as f32 device scalars — the trigger
    inputs, computed identically on the host and scanned paths (both
    route through this function, so threshold comparisons agree
    bitwise)."""
    nl = jax.ops.segment_sum(
        jnp.asarray(loads, jnp.float32),
        jnp.asarray(assignment, jnp.int32),
        num_segments=num_nodes)
    total = nl.sum()
    return nl.max(), total / num_nodes, total


#: jitted host-path entry (the scanned paths trace ``load_stats`` inline;
#: both execute the same expression graph)
load_stats_jit = jax.jit(load_stats, static_argnums=(2,))


def load_stats_masked(loads, assignment, num_nodes: int, alive, speed=None):
    """Health-masked trigger statistics for a degraded mesh.

    The resilient replay paths (``runtime/resilience.py``) feed the
    trigger *effective* load stats: per-node loads scaled by the
    reciprocal node ``speed`` (a slowed shard's work takes
    proportionally longer, so it reads as heavier), the max taken over
    **alive** nodes only, and the average over the alive count — a dead
    node must neither dilute the average nor dominate the max while its
    objects await re-homing.  ``total`` stays the true (unscaled) load
    sum, which the predictive trigger prices migrations against.  With
    an all-alive, full-speed mask this still differs from
    :func:`load_stats` only in the avg divisor's provenance (traced vs
    static — same value), so the resilient paths use it
    unconditionally."""
    nl = jax.ops.segment_sum(
        jnp.asarray(loads, jnp.float32),
        jnp.asarray(assignment, jnp.int32),
        num_segments=num_nodes)
    alive = jnp.asarray(alive, bool)
    eff = nl if speed is None else nl / jnp.maximum(
        jnp.asarray(speed, jnp.float32), 1e-6)
    eff = jnp.where(alive, eff, 0.0)
    cnt = jnp.maximum(alive.astype(jnp.float32).sum(), 1.0)
    return eff.max(), eff.sum() / cnt, nl.sum()


@dataclasses.dataclass(frozen=True)
class EveryTrigger:
    """Fixed-period trigger — the legacy ``lb_every`` behavior.

    ``decide`` emits the literal legacy predicate, so a replay with
    ``trigger="every"`` is bit-for-bit the pre-runtime replay."""

    every: int = 10

    @property
    def never(self) -> bool:
        return self.every <= 0

    def init_state(self) -> TriggerState:
        return _init_state(1)

    def decide(self, state: TriggerState, t, max_load, avg_load,
               total_load) -> Tuple[jax.Array, TriggerState]:
        if self.never:
            return jnp.asarray(False), state
        with compat.named_scope("trigger/every-decide"):
            do = (t > 0) & (t % self.every == 0)
            return do, state

    def observe(self, state: TriggerState, moved_load,
                fired) -> TriggerState:
        """Fixed cadence ignores execution feedback (no-op)."""
        return state


@dataclasses.dataclass(frozen=True)
class ThresholdTrigger:
    """Imbalance-threshold trigger with hysteresis.

    Fires when ``max/avg > hi`` while armed and at least ``min_interval``
    steps have passed since the last rebalance.  Firing disarms the
    trigger; it re-arms when the imbalance falls below ``lo`` (the
    rebalance worked — watch for the next spike) or ``rearm_after`` steps
    elapse (it didn't — retry rather than wedge).  The hysteresis band
    prevents rebalance thrash when the balancer cannot push the workload
    below ``hi``."""

    hi: float = 1.10
    lo: float = 1.05
    min_interval: int = 2
    rearm_after: int = 4

    @property
    def never(self) -> bool:
        return False

    def init_state(self) -> TriggerState:
        return _init_state(1)

    def decide(self, state: TriggerState, t, max_load, avg_load,
               total_load) -> Tuple[jax.Array, TriggerState]:
        with compat.named_scope("trigger/threshold-decide"):
            ma = max_load / jnp.maximum(avg_load, 1e-30)
            since = t - state.last_lb
            armed = (state.armed | (ma < self.lo)
                     | (since >= self.rearm_after))
            do = ((t > 0) & armed & (ma > self.hi)
                  & (since >= self.min_interval))
            return do, state._replace(
                last_lb=jnp.where(do, jnp.asarray(t, jnp.int32),
                                  state.last_lb),
                armed=jnp.where(do, False, armed),
            )

    def observe(self, state: TriggerState, moved_load,
                fired) -> TriggerState:
        """Hysteresis looks only at load stats (no-op)."""
        return state


@dataclasses.dataclass(frozen=True)
class PredictiveTrigger:
    """Linear-trend predictive trigger with cost amortization.

    Keeps the last ``window`` samples of the excess load
    ``max_load - avg_load``, fits a least-squares slope, and projects the
    imbalance-time that *not* rebalancing would cost over the next
    ``horizon`` steps: ``sum_h max(0, excess + slope*h) * t_load``.
    Fires when that projected loss (scaled by ``efficiency`` — the
    fraction a rebalance actually recovers) exceeds the migration cost
    it would pay, subject to the ``min_interval`` refractory period.

    The migration-cost gate is **measured when possible** (Boulmier et
    al.: anticipate against what rebalancing actually costs): once the
    replay layer has executed an exchange and fed its moved-load volume
    back through :meth:`observe`, the gate prices that *last executed*
    volume (``cost.migration_seconds(state.last_moved)``).  Before any
    exchange has been observed — the cold start — it falls back to the
    a-priori estimate ``cost.est_migration_seconds(total_load)``
    (``moved_frac_est`` of the total load).  ``measured_gate=False``
    pins the estimate-only legacy behavior."""

    window: int = 8
    horizon: int = 8
    min_interval: int = 2
    efficiency: float = 0.8
    cost: RuntimeCostModel = RuntimeCostModel()
    measured_gate: bool = True

    @property
    def never(self) -> bool:
        return False

    def init_state(self) -> TriggerState:
        return _init_state(self.window)

    def decide(self, state: TriggerState, t, max_load, avg_load,
               total_load) -> Tuple[jax.Array, TriggerState]:
        with compat.named_scope("trigger/predictive-decide"):
            return self._decide(state, t, max_load, avg_load, total_load)

    def _decide(self, state: TriggerState, t, max_load, avg_load,
                total_load) -> Tuple[jax.Array, TriggerState]:
        W = self.window
        excess = jnp.maximum(
            jnp.asarray(max_load, jnp.float32)
            - jnp.asarray(avg_load, jnp.float32), 0.0)
        hist = jnp.roll(state.history, -1).at[W - 1].set(excess)
        # a rebalance resets the trend: old samples describe the
        # pre-rebalance trajectory and would keep re-firing the trigger
        hist_len = jnp.minimum(
            jnp.where(state.last_lb == t - 1, 1, state.hist_len + 1), W)

        # masked least-squares slope over the valid tail of the window
        x = jnp.arange(W, dtype=jnp.float32)
        valid = (x >= W - hist_len).astype(jnp.float32)
        n = jnp.maximum(valid.sum(), 1.0)
        xm = (x * valid).sum() / n
        ym = (hist * valid).sum() / n
        var = (valid * (x - xm) ** 2).sum()
        slope = jnp.where(
            var > 0, (valid * (x - xm) * (hist - ym)).sum() / var, 0.0)

        h = jnp.arange(1, self.horizon + 1, dtype=jnp.float32)
        projected = jnp.maximum(excess + slope * h, 0.0).sum()
        loss = projected * self.cost.t_load * self.efficiency
        est = self.cost.est_migration_seconds(
            jnp.asarray(total_load, jnp.float32))
        if self.measured_gate:
            # amortize against the last *executed* exchange volume once
            # one exists; the modeled estimate is only the cold-start
            # prior (ROADMAP: measured, not estimated, predictive gate)
            gate = jnp.where(
                state.last_moved >= 0.0,
                self.cost.migration_seconds(state.last_moved), est)
        else:
            gate = est

        do = ((t > 0) & (hist_len >= 2) & (loss > gate)
              & (t - state.last_lb >= self.min_interval))
        return do, TriggerState(
            last_lb=jnp.where(do, jnp.asarray(t, jnp.int32),
                              state.last_lb),
            armed=state.armed,
            history=hist,
            hist_len=hist_len.astype(jnp.int32),
            last_moved=state.last_moved,
        )

    def observe(self, state: TriggerState, moved_load,
                fired) -> TriggerState:
        """Record the measured volume of an executed exchange.

        Called by every replay layer *after* a fired rebalance has been
        applied, with the load total the exchange actually moved (the
        same quantity ``SeriesResult.migrated_load`` /
        ``PICResult.migrated_bytes / bytes_per_load`` records).
        Traceable — safe inside the scanned and sharded replay loops."""
        fired = jnp.asarray(fired)
        return state._replace(last_moved=jnp.where(
            fired.astype(bool),
            jnp.asarray(moved_load, jnp.float32), state.last_moved))


Trigger = Union[EveryTrigger, ThresholdTrigger, PredictiveTrigger]

_BY_NAME = {
    "every": EveryTrigger,
    "threshold": ThresholdTrigger,
    "predictive": PredictiveTrigger,
}


@functools.lru_cache(maxsize=256)
def _named(name: str, lb_every: int) -> Trigger:
    if name == "every":
        return EveryTrigger(every=lb_every)
    return _BY_NAME[name]()


def resolve(spec, *, lb_every: int,
            strategy_trigger: Optional[str] = None) -> Trigger:
    """Canonical trigger from a user spec.

    ``spec`` may be ``None`` (fall back to the strategy's registered
    trigger policy, else the legacy fixed period), a name
    (``"every" | "threshold" | "predictive"``), or a trigger instance.
    Instances come back memoized-or-identical, so the compiled-runner
    caches keyed on the trigger hit across calls."""
    if spec is None:
        spec = strategy_trigger or "every"
    if isinstance(spec, str):
        if spec not in _BY_NAME:
            raise KeyError(
                f"unknown trigger {spec!r}; available: {sorted(_BY_NAME)}")
        return _named(spec, int(lb_every))
    if not all(hasattr(spec, a)
               for a in ("decide", "init_state", "never", "observe")):
        raise TypeError(
            f"trigger must be a name or a Trigger instance (decide / "
            f"init_state / never / observe), got {spec!r}")
    return spec


def resolve_for_strategy(spec, *, lb_every: int, strategy: str) -> Trigger:
    """:func:`resolve` with the strategy registry as the ``None``
    fallback — the one place the replay layers (sim and PIC) share the
    spec → registry-trigger → legacy-cadence resolution order."""
    from repro.core import engine  # local: keep runtime importable alone

    try:
        strategy_trigger = engine.get_strategy(strategy).trigger
    except KeyError:
        strategy_trigger = None
    return resolve(spec, lb_every=lb_every,
                   strategy_trigger=strategy_trigger)
