"""Live MoE expert rebalancing inside the train step (DESIGN.md §3.1).

The expert-placement runtime closes the loop that ``distributed/
ep_balance.py`` only planned: router statistics accumulate **on device**
inside the training scan, the runtime trigger machinery decides *when*
to replace the placement, the Strategy registry plans *where* every
expert goes, and the placement delta executes as an **expert-weight
exchange** whose measured bytes feed the predictive gate.  Three layers:

  * :func:`run_ep_replay` — the self-contained replay driver (mirrors
    ``serve/replay.py``): a :class:`RoutingWorkload` emits recorded
    top-k routing ids; one ``lax.scan`` carries the EMA token/
    co-activation statistics as fixed-shape arrays (updated from the ids
    via ``models.moe.pair_stats`` — one one-hot matmul, no host
    ``np.add.at`` loop), runs ``runtime.triggers`` on the expert-load
    skew, plans through the jitted ``LBEngine`` strategies followed by
    the jittable ``ep_balance.repair_capacity`` pass, and executes fired
    placements over the expert slabs with
    ``runtime.migrate.build_and_apply``.  The host path executes the
    same jnp expression graphs eagerly, so fire steps, placements and
    moved bytes agree **bit-for-bit** across paths; ``mesh``/
    ``num_shards`` runs the fired exchange as a ``ppermute`` ring
    all-to-all (``migrate.migrate_sharded``) whose strict layout
    contract reproduces the single-device trajectory exactly (capacity-
    exact placements make every shard prefix dense).
  * :func:`execute_placement` — the eager entry for **real** MoE
    parameters: relocates every per-slot weight tensor (``wi``/``wg``/
    ``wo`` on the expert axis, ``router`` on its column axis) by the
    manifest permutation, or — given a mesh — as the ring exchange on
    the "model" axis with the weight matrices flattened to slot-leading
    payload slabs.  Returns the executed moved-byte count.
  * :class:`EPRebalancer` — the train-loop driver ``launch/train.py``
    uses: consumes the ``router_counts``/``router_coact`` metrics the
    train step surfaces (``collect_router_stats=True``), converts
    physical-slot statistics to logical-expert statistics through the
    tracked ``slot_expert`` permutation, and fires
    plan → repair → :func:`execute_placement`, feeding the trigger the
    bytes the exchange actually moved (replacing ``ep_balance
    .migration_bytes``'s modeled estimate).

Object/load/edge mapping (the paper's persistently interacting objects):
objects = experts, loads = EMA routed tokens, edges = co-activation
counts, nodes = EP ranks, migration = expert-weight traffic.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import comm_graph, engine
from repro.distributed import ep_balance
from repro.models import moe as moe_mod
from repro.obs import telemetry as obs_telemetry
from repro.runtime import migrate as rt_migrate
from repro.runtime import triggers as rt_triggers

LOAD_FLOOR = 1e-3


# ------------------------------------------------------------- workloads --


@dataclasses.dataclass(frozen=True)
class RoutingWorkload:
    """Synthetic skewed top-k routing traffic (pure function of t).

    Expert popularity is Zipf-like (``(rank+1)^-alpha`` over a random
    expert order) with a rotating *hotspot*: every ``drift_period``
    steps the hot block of ``hot_frac·E`` experts advances, and hot
    experts' popularity multiplies by ``1 + hot_amp`` — the slow load
    drift the balancer must chase.  ``trace_len`` steps of (T, k) routed
    ids are drawn once per instance (cached numpy, seeded) and loop when
    replayed past the end.  Hashable (frozen scalars only), so compiled
    replay runners cache across calls."""

    num_experts: int = 64
    num_ranks: int = 8
    top_k: int = 4
    tokens_per_step: int = 2048
    alpha: float = 1.0
    hot_frac: float = 0.25
    hot_amp: float = 4.0
    drift_period: int = 16
    trace_len: int = 64
    weight_bytes: float = 2048.0   # per-expert weight size (exchange unit)
    seed: int = 0

    def ids_table(self) -> np.ndarray:
        """(trace_len, T, k) i32 routed expert ids."""
        return _routing_tables(self)

    def ids_at(self, t) -> jax.Array:
        tab = jnp.asarray(self.ids_table())
        return tab[jnp.mod(jnp.asarray(t, jnp.int32), tab.shape[0])]


@functools.lru_cache(maxsize=64)
def _routing_tables(w: RoutingWorkload) -> np.ndarray:
    """Draw the recorded routing trace (numpy, cached — see
    ``serve.replay._serve_tables`` for why numpy and not jnp)."""
    rng = np.random.default_rng(w.seed)
    E, T, k = w.num_experts, w.tokens_per_step, w.top_k
    base = (np.argsort(rng.permutation(E)) + 1.0) ** (-w.alpha)
    hot_n = max(1, int(round(w.hot_frac * E)))
    ids = np.empty((w.trace_len, T, k), np.int32)
    for t in range(w.trace_len):
        epoch = t // max(1, w.drift_period)
        hot = (np.arange(hot_n) + epoch * hot_n) % E
        p = base.copy()
        p[hot] *= 1.0 + w.hot_amp
        p /= p.sum()
        ids[t] = rng.choice(E, size=(T, k), p=p)
    return ids


@dataclasses.dataclass(frozen=True, eq=False)  # identity hash: jnp fields
class RoutingTrace:
    """Trace-driven routing workload: a recorded ``(L, T, k)`` id table.

    Instances hash by identity, so reusing one instance reuses the
    compiled runner (mirrors ``serve.replay.TraceWorkload``)."""

    table: jax.Array              # (L, T, k) i32 routed ids
    num_experts: int
    num_ranks: int = 8
    weight_bytes: float = 2048.0

    @property
    def top_k(self) -> int:
        return int(self.table.shape[2])

    @property
    def tokens_per_step(self) -> int:
        return int(self.table.shape[1])

    def ids_at(self, t) -> jax.Array:
        return self.table[jnp.mod(jnp.asarray(t, jnp.int32),
                                  self.table.shape[0])]


def record_routing(workload, *, steps: int) -> RoutingTrace:
    """Capture ``steps`` routing steps into a :class:`RoutingTrace`
    (the ``routing-skew`` scenario's source)."""
    rows = jax.jit(jax.vmap(workload.ids_at))(
        jnp.arange(steps, dtype=jnp.int32))
    return RoutingTrace(
        table=jnp.asarray(rows, jnp.int32),
        num_experts=int(workload.num_experts),
        num_ranks=int(workload.num_ranks),
        weight_bytes=float(workload.weight_bytes))


# --------------------------------------------------------------- results --


@dataclasses.dataclass
class EPReplayResult:
    """Per-step records + final placement of one rebalancing replay."""

    max_avg: np.ndarray           # (T,) post-LB expert-load imbalance
    lb_fired: np.ndarray          # (T,) 0/1 trigger decisions
    moved_experts: np.ndarray     # (T,) experts exchanged at that step
    moved_bytes: np.ndarray       # (T,) executed weight transfer volume
    final_placement: np.ndarray   # (E,) logical expert → rank
    final_slot_expert: np.ndarray  # (E,) physical slot → logical expert
    final_wsig: np.ndarray        # (E, d) relocated payload signature
    scanned: bool = False
    sharded: bool = False
    wall_seconds: float = 0.0
    # StepRecord ring snapshot when an enabled TelemetryConfig was passed
    telemetry: Optional[obs_telemetry.TelemetrySnapshot] = None

    @property
    def total_moved_bytes(self) -> float:
        return float(self.moved_bytes.sum())


# ------------------------------------------------------------- step body --


def _sig0(E: int, d: int = 4) -> jax.Array:
    """Deterministic (E, d) payload signature — a stand-in expert-weight
    slab that makes relocation observable (conservation tests check the
    exact row set survives every exchange)."""
    return (jnp.arange(E, dtype=jnp.float32)[:, None] * d
            + jnp.arange(d, dtype=jnp.float32)[None, :])


def _edge_template(E: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Static all-upper-tri edge list + ring-connectivity mask.

    The in-scan problem needs fixed shapes, so every expert pair is an
    edge; weights come from the live co-activation matrix with an eps
    floor on the ring pairs (i, i+1), (0, E-1) to keep the comm graph
    connected before any co-activation accumulates (the fixed-shape twin
    of ``ep_balance.build_problem``'s fallback)."""
    iu, ju = np.triu_indices(E, k=1)
    ring = (ju == iu + 1) | ((iu == 0) & (ju == E - 1))
    return iu.astype(np.int32), ju.astype(np.int32), ring


def _make_parts(workload, trig, plan, R: int, E: int, lb_on: bool,
                bytes_per_load: float, ema: float):
    """The shared jnp step pieces — one source of truth for every path.

    ``pre`` accumulates routing statistics and decides; ``fire``/
    ``nofire`` are the two exchange branches (identical signatures, so
    the scanned path puts them under ``lax.cond`` and the host path
    picks one after a device sync — same compiled graphs either way);
    ``post`` observes the measured moved bytes and records."""
    cap = E // R
    iu, ju, ring = _edge_template(E)
    iu_j, ju_j = jnp.asarray(iu), jnp.asarray(ju)
    ring_j = jnp.asarray(ring, jnp.float32)
    bpe = jnp.float32(workload.weight_bytes)

    def pre(slot_expert, wsig, placement, tokens, coact, tstate, t):
        st = moe_mod.pair_stats(workload.ids_at(t), E)
        tokens = ema * tokens + (1.0 - ema) * st.counts
        coact = ema * coact + (1.0 - ema) * st.coact
        if lb_on:
            mx, av, tot = rt_triggers.load_stats(
                jnp.maximum(tokens, LOAD_FLOOR), placement, R)
            do, tstate = trig.decide(tstate, t, mx, av, tot)
        else:
            do = jnp.asarray(False)
        return tokens, coact, do, tstate

    def _problem(placement, tokens, coact):
        ew = coact[iu_j, ju_j] + jnp.float32(LOAD_FLOOR) * ring_j
        return comm_graph.LBProblem(
            loads=jnp.maximum(tokens, LOAD_FLOOR).astype(jnp.float32),
            assignment=placement, edges_src=iu_j, edges_dst=ju_j,
            edges_bytes=ew.astype(jnp.float32), num_nodes=R)

    def plan_placement(placement, tokens, coact):
        """Capacity-exact new logical placement for a fired step (plus
        the planner's executed diffusion sweeps, for telemetry)."""
        new, stats = plan(_problem(placement, tokens, coact))
        return ep_balance.repair_capacity(
            new.astype(jnp.int32), tokens, num_ranks=R, cap=cap), \
            jnp.asarray(stats.diffusion_iters, jnp.float32)

    def fire(slot_expert, wsig, placement, tokens, coact, t):
        newp, sweeps = plan_placement(placement, tokens, coact)
        oo = jnp.take(placement, slot_expert)      # == slot // cap
        on = jnp.take(newp, slot_expert)
        (se2, ws2), man = rt_migrate.build_and_apply(
            oo, on, (slot_expert, wsig), num_nodes=R)
        moved_n = man.moved_count.astype(jnp.float32)
        return se2, ws2, newp, moved_n, man.moved_bytes(bpe), sweeps

    def nofire(slot_expert, wsig, placement, tokens, coact, t):
        return (slot_expert, wsig, placement, jnp.float32(0.0),
                jnp.float32(0.0), jnp.float32(0.0))

    def post(placement, tokens, tstate, do, moved_b, t):
        tstate = trig.observe(
            tstate, moved_b / jnp.float32(bytes_per_load), do)
        mx, av, _ = rt_triggers.load_stats(
            jnp.maximum(tokens, LOAD_FLOOR), placement, R)
        return tstate, mx / av

    return pre, plan_placement, fire, nofire, post


def _initial_state(workload, ema_unused=None):
    E = int(workload.num_experts)
    R = int(workload.num_ranks)
    cap = E // R
    slot_expert = jnp.arange(E, dtype=jnp.int32)
    placement = (slot_expert // cap).astype(jnp.int32)
    tokens = jnp.zeros((E,), jnp.float32)
    coact = jnp.zeros((E, E), jnp.float32)
    return slot_expert, _sig0(E), placement, tokens, coact


def _resolve(workload, strategy, strategy_kwargs, trigger, lb_every):
    strat = engine.get_strategy(
        ep_balance._ALIASES.get(strategy, strategy))
    kw = dict(strategy_kwargs or {})
    if strat.variant is not None:
        kw.setdefault("k", max(1, min(4, int(workload.num_ranks) - 1)))
    trig = rt_triggers.resolve_for_strategy(
        trigger, lb_every=lb_every, strategy=strategy)
    cost = getattr(trig, "cost", None)
    bpl = float(cost.bytes_per_load) if cost is not None else 1.0
    lb_on = strategy != "none" and not trig.never
    return strat, kw, trig, bpl, lb_on


# ---------------------------------------------------------- scanned path --


@functools.lru_cache(maxsize=64)
def _scanned_ep_runner(workload, steps: int, strategy: str,
                       kw_items: tuple, trig, lb_every: int, ema: float,
                       tel=None):
    strat = engine.get_strategy(
        ep_balance._ALIASES.get(strategy, strategy))
    plan = strat.bind(**dict(kw_items))
    E, R = int(workload.num_experts), int(workload.num_ranks)
    cost = getattr(trig, "cost", None)
    bpl = float(cost.bytes_per_load) if cost is not None else 1.0
    lb_on = strategy != "none" and not trig.never
    pre, _, fire, nofire, post = _make_parts(
        workload, trig, plan, R, E, lb_on, bpl, ema)
    tkind = obs_telemetry.trigger_kind(trig) if tel else 0

    def step(carry, t):
        if tel:
            se, ws, placement, tokens, coact, tstate, obs_state = carry
        else:
            se, ws, placement, tokens, coact, tstate = carry
        tokens, coact, do, tstate = pre(
            se, ws, placement, tokens, coact, tstate, t)
        se, ws, placement, moved_n, moved_b, sweeps = jax.lax.cond(
            do, fire, nofire, se, ws, placement, tokens, coact, t)
        tstate, ma = post(placement, tokens, tstate, do, moved_b, t)
        ys = (ma, do.astype(jnp.float32), moved_n, moved_b)
        if tel:
            obs_state = obs_telemetry.record(
                obs_state, tel, t=t,
                node_loads=obs_telemetry.node_loads(
                    jnp.maximum(tokens, LOAD_FLOOR), placement, R),
                fired=do, trigger_kind=tkind, sweeps=sweeps,
                moved_items=moved_n, moved_bytes=moved_b)
            return (se, ws, placement, tokens, coact, tstate,
                    obs_state), ys
        return (se, ws, placement, tokens, coact, tstate), ys

    def run(se, ws, placement, tokens, coact):
        carry = (se, ws, placement, tokens, coact, trig.init_state())
        if tel:
            carry = carry + (obs_telemetry.init_state(tel, R),)
        return jax.lax.scan(step, carry, jnp.arange(steps))

    return jax.jit(run)


# ------------------------------------------------------------ host paths --


def _host_ep_loop(workload, steps, strategy, kw, trig, ema, *, mesh=None,
                  tel=None):
    """Eager replay: the scanned step pieces executed one step at a time.

    ``mesh`` switches the fired exchange to ``migrate.migrate_sharded``
    (ring all-to-all under shard_map) in strict mode with the exact
    per-shard budget ``E // D`` — capacity-exact placements fill every
    shard's slab completely, so the strict layout contract makes the
    reassembled slabs bit-for-bit the single-device result with no
    prefix bookkeeping."""
    strat = engine.get_strategy(
        ep_balance._ALIASES.get(strategy, strategy))
    plan = strat.bind(**kw) if strat.jittable else None
    E, R = int(workload.num_experts), int(workload.num_ranks)
    cap = E // R
    cost = getattr(trig, "cost", None)
    bpl = float(cost.bytes_per_load) if cost is not None else 1.0
    lb_on = strategy != "none" and not trig.never
    pre, plan_placement, fire, nofire, post = _make_parts(
        workload, trig, plan, R, E, lb_on, bpl, ema)
    pre_j, post_j = jax.jit(pre), jax.jit(post)
    fire_j, nofire_j = jax.jit(fire), jax.jit(nofire)
    plan_j = jax.jit(plan_placement) if strat.jittable else None

    def host_plan(placement, tokens, coact):
        """Host-baseline planning (ep-greedy & co): eager Strategy.run
        on the same device-built stats, then the same jittable repair."""
        stats = ep_balance.ExpertStats(
            num_experts=E, ema=0.0,
            tokens=np.asarray(tokens, np.float64),
            coact=np.asarray(coact, np.float64))
        new, _ = ep_balance.plan_placement(
            stats, np.asarray(placement), R,
            strategy=strategy, **({"k": kw["k"]} if "k" in kw else {}))
        return jnp.asarray(new, jnp.int32), jnp.float32(0.0)

    se, ws, placement, tokens, coact = _initial_state(workload)
    tstate = trig.init_state()
    obs_state = (obs_telemetry.init_state(tel, R) if tel else None)
    tkind = obs_telemetry.trigger_kind(trig) if tel else 0
    recs = []
    for ti in range(steps):
        t = jnp.int32(ti)
        tokens, coact, do, tstate = pre_j(
            se, ws, placement, tokens, coact, tstate, t)
        fired = bool(do)
        sweeps = 0.0
        if not fired:
            se, ws, placement, moved_n, moved_b, sweeps = nofire_j(
                se, ws, placement, tokens, coact, t)
        elif mesh is not None or plan_j is None:
            getter = plan_j or host_plan
            newp, sweeps = getter(placement, tokens, coact)
            newp = jnp.asarray(newp, jnp.int32)
            oo = jnp.take(placement, se)
            on = jnp.take(newp, se)
            moved = on != oo
            moved_n = moved.sum().astype(jnp.float32)
            moved_b = moved_n * jnp.float32(workload.weight_bytes)
            if mesh is None:
                (se, ws), man = rt_migrate.migrate(
                    oo, on, (se, ws), num_nodes=R)
            else:
                D = int(np.prod(mesh.devices.shape))
                _, (se, ws), counts = rt_migrate.migrate_sharded(
                    on, (se, ws), num_nodes=R, mesh=mesh,
                    capacity=E // D)
                assert (np.asarray(counts) == E // D).all(), \
                    "capacity-exact placement must fill every shard"
                se = jnp.asarray(se, jnp.int32)
                ws = jnp.asarray(ws, jnp.float32)
            placement = newp
        else:
            se, ws, placement, moved_n, moved_b, sweeps = fire_j(
                se, ws, placement, tokens, coact, t)
        tstate, ma = post_j(placement, tokens, tstate, do, moved_b, t)
        if tel:
            obs_state = obs_telemetry.record(
                obs_state, tel, t=t,
                node_loads=obs_telemetry.node_loads(
                    jnp.maximum(tokens, LOAD_FLOOR), placement, R),
                fired=fired, trigger_kind=tkind, sweeps=sweeps,
                moved_items=moved_n, moved_bytes=moved_b)
        recs.append((float(ma), 1.0 if fired else 0.0, float(moved_n),
                     float(moved_b)))
    return se, ws, placement, recs, obs_state


# ------------------------------------------------------------- the entry --


def run_ep_replay(
    workload,
    *,
    steps: int,
    strategy: str = "diff-comm",
    strategy_kwargs: Optional[Dict] = None,
    trigger=None,
    lb_every: int = 10,
    ema: float = 0.9,
    scan: Optional[bool] = None,
    num_shards: Optional[int] = None,
    mesh=None,
    telemetry=None,
) -> EPReplayResult:
    """Replay ``steps`` training steps of live expert rebalancing.

    ``scan=None`` auto-selects the scanned path for jittable strategies
    (host baselines like ``"greedy"``/``"ep-greedy"`` run the eager loop
    with the same executed exchange).  ``trigger`` resolves through
    ``runtime.triggers.resolve_for_strategy`` — the predictive policy
    amortizes fires against the **measured** weight bytes of the
    previous exchange.  ``num_shards`` / ``mesh`` execute fired
    exchanges as ring all-to-alls under ``shard_map`` (bit-for-bit the
    single-device trajectory); ``E`` and ``num_ranks`` must divide the
    shard count."""
    strat, kw, trig, _bpl, _lb_on = _resolve(
        workload, strategy, strategy_kwargs, trigger, lb_every)
    tel = obs_telemetry.resolve(telemetry)
    tel = tel if tel.enabled else None
    E, R = int(workload.num_experts), int(workload.num_ranks)
    if E % R:
        raise ValueError(f"num_experts={E} must divide num_ranks={R}")
    sharded = mesh is not None or num_shards is not None
    if sharded:
        if scan:
            raise ValueError(
                "the sharded rebalancing replay is a host-driven loop; "
                "pass scan=False/None")
        from repro.distributed import replay_shard

        mesh = replay_shard.resolve_mesh(mesh, num_shards, (E, R))
        scan = False
    if scan is None:
        scan = strat.jittable
    if scan and not strat.jittable:
        raise ValueError(
            f"strategy {strategy!r} is not jittable; the scanned replay "
            "needs a traceable plan_fn (use scan=False or a diff-* "
            "strategy)")
    t0 = time.perf_counter()
    if scan:
        runner = _scanned_ep_runner(
            workload, int(steps), strategy, tuple(sorted(kw.items())),
            trig, int(lb_every), float(ema), tel)
        final, ys = runner(*_initial_state(workload))
        se, ws, placement = final[0], final[1], final[2]
        obs_state = final[6] if tel else None
        ma, fired, moved_n, moved_b = jax.device_get(ys)
        recs = np.stack([ma, fired, moved_n, moved_b], axis=1)
    else:
        se, ws, placement, rec_list, obs_state = _host_ep_loop(
            workload, int(steps), strategy, kw, trig, float(ema),
            mesh=mesh, tel=tel)
        recs = np.asarray(rec_list, np.float64).reshape(int(steps), 4)
    return EPReplayResult(
        max_avg=np.asarray(recs[:, 0], np.float64),
        lb_fired=np.asarray(recs[:, 1], np.float64),
        moved_experts=np.asarray(recs[:, 2], np.float64),
        moved_bytes=np.asarray(recs[:, 3], np.float64),
        final_placement=np.asarray(placement, np.int32),
        final_slot_expert=np.asarray(se, np.int32),
        final_wsig=np.asarray(ws, np.float32),
        scanned=bool(scan), sharded=bool(sharded),
        wall_seconds=time.perf_counter() - t0,
        telemetry=(obs_telemetry.snapshot(obs_state, tel)
                   if tel else None))


# ------------------------------------------- real-weight execution layer --


#: the per-expert-slot tensors of a MoE layer; everything else in the
#: param dict (shared-expert weights, biases) has no expert axis and
#: rides no exchange
EXPERT_KEYS = ("wi", "wg", "wo", "router")


def _expert_axis(key: str, ndim: int) -> int:
    """Expert axis of a per-expert MoE parameter, layout-agnostic.

    ``wi``/``wg``/``wo`` are (..., E, D, F)-shaped (a leading group axis
    may or may not be stacked on), the ``router`` is (..., D, E)."""
    return ndim - 1 if key == "router" else ndim - 3


def _expert_items(moe_params: Dict):
    for k in EXPERT_KEYS:
        if k in moe_params:
            yield k, jnp.asarray(moe_params[k])


def apply_order_to_moe(moe_params: Dict, order) -> Dict:
    """Gather every per-slot tensor of one MoE layer by the manifest
    permutation (slot ``p`` of the relocated layout holds old slot
    ``order[p]``); non-expert tensors pass through untouched."""
    order = jnp.asarray(order, jnp.int32)
    out = dict(moe_params)
    for k, v in _expert_items(moe_params):
        out[k] = jnp.take(v, order, axis=_expert_axis(k, v.ndim))
    return out


def expert_param_bytes(moe_layers: Sequence[Dict]) -> float:
    """Weight bytes resident per expert slot, summed over MoE layers —
    the exchange unit :func:`execute_placement` reports moved volume in."""
    total = 0.0
    for layer in moe_layers:
        for k, v in _expert_items(layer):
            E = v.shape[_expert_axis(k, v.ndim)]
            total += v.size * jnp.dtype(v.dtype).itemsize / float(E)
    return total


def execute_placement(moe_layers: Sequence[Dict], slot_expert,
                      new_placement, *, num_ranks: int, mesh=None):
    """Relocate real expert weights to a new logical placement.

    ``moe_layers`` is the sequence of MoE parameter dicts sharing one
    placement (the transformer accumulates router statistics across
    layers, so one plan serves all of them); ``slot_expert`` maps
    physical slot → logical expert and ``new_placement`` maps logical
    expert → rank (capacity-exact).  Single-device, the relocation is
    the manifest gather; with ``mesh`` it executes as the ``ppermute``
    ring all-to-all on the model axis (``migrate.migrate_sharded``) with
    each weight tensor flattened to a slot-leading payload slab — the
    strict layout contract plus capacity-exactness reassemble the
    single-device layout bit-for-bit.

    Returns ``(new_layers, new_slot_expert, moved_experts,
    moved_bytes)`` — the **measured** exchange volume (moved slots ×
    resident bytes per slot), the number the trigger's ``observe``
    feedback should see instead of ``ep_balance.migration_bytes``'s
    model."""
    slot_expert = jnp.asarray(slot_expert, jnp.int32)
    E = int(slot_expert.shape[0])
    R = int(num_ranks)
    cap = E // R
    oo = (jnp.arange(E, dtype=jnp.int32) // cap)
    on = jnp.take(jnp.asarray(new_placement, jnp.int32), slot_expert)
    bpe = expert_param_bytes(moe_layers)
    if mesh is None:
        man = rt_migrate.build_manifest(oo, on, R)
        new_layers = [apply_order_to_moe(layer, man.order)
                      for layer in moe_layers]
        se2 = jnp.take(slot_expert, man.order)
        moved = int(man.moved_count)
        return new_layers, se2, moved, moved * bpe
    D = int(np.prod(mesh.devices.shape))
    if E % D or R % D:
        raise ValueError(
            f"E={E} and num_ranks={R} must divide the {D}-device mesh")
    # flatten every per-expert tensor to a slot-leading (E, ...) slab;
    # trailing axes ride the exchange unchanged (the N-D ring payload
    # path); shared-expert tensors stay put
    keys = [[k for k, _ in _expert_items(layer)] for layer in moe_layers]
    slabs, shapes = [], []
    for layer, ks in zip(moe_layers, keys):
        for k in ks:
            v = jnp.asarray(layer[k])
            ax = _expert_axis(k, v.ndim)
            lead = jnp.moveaxis(v, ax, 0)
            slabs.append(lead.reshape(E, -1))
            shapes.append((ax, lead.shape, v.dtype))
    _, outs, counts = rt_migrate.migrate_sharded(
        on, (slot_expert,) + tuple(slabs), num_nodes=R, mesh=mesh,
        capacity=E // D)
    if not (np.asarray(counts) == E // D).all():
        raise ValueError(
            "capacity-exact placement must fill every shard slab")
    se2 = jnp.asarray(outs[0], jnp.int32)
    new_layers, i = [], 1
    for layer, ks in zip(moe_layers, keys):
        out = dict(layer)
        for k in ks:
            ax, lead_shape, dt = shapes[i - 1]
            out[k] = jnp.moveaxis(
                jnp.asarray(outs[i], dt).reshape(lead_shape), 0, ax)
            i += 1
        new_layers.append(out)
    moved = int(jnp.sum(on != oo))
    return new_layers, se2, moved, moved * bpe


class EPRebalancer:
    """Trigger-driven live rebalancer for the training loop.

    ``launch/train.py`` holds one of these and calls :meth:`step` after
    every train step with the ``router_counts``/``router_coact`` metrics
    the model accumulated on device (``collect_router_stats=True``).
    Those statistics are keyed by **physical slot** (the router's ids
    index the stacked weight arrays); the rebalancer converts them to
    logical-expert statistics through the tracked ``slot_expert``
    permutation, feeds the EMA :class:`ep_balance.ExpertStats`, runs the
    resolved trigger on the rank-load skew, and on fire plans through
    :func:`ep_balance.plan_placement` (Strategy registry + jittable
    capacity repair) and **executes** the delta with
    :func:`execute_placement` — observing the measured moved bytes, not
    a model."""

    def __init__(self, num_experts: int, num_ranks: int, *,
                 strategy: str = "diff-comm", trigger=None,
                 lb_every: int = 50, ema: float = 0.9):
        E, R = int(num_experts), int(num_ranks)
        assert E % R == 0
        self.num_experts, self.num_ranks = E, R
        self.strategy = strategy
        self.stats = ep_balance.ExpertStats(num_experts=E, ema=ema)
        self.trig = rt_triggers.resolve_for_strategy(
            trigger, lb_every=lb_every, strategy=strategy)
        cost = getattr(self.trig, "cost", None)
        self.bytes_per_load = (float(cost.bytes_per_load)
                               if cost is not None else 1.0)
        self.tstate = self.trig.init_state()
        self.slot_expert = np.arange(E, dtype=np.int32)
        self.history: list = []

    @property
    def placement(self) -> np.ndarray:
        """(E,) logical expert → rank, derived from ``slot_expert``."""
        cap = self.num_experts // self.num_ranks
        pos = np.empty(self.num_experts, np.int64)
        pos[self.slot_expert] = np.arange(self.num_experts)
        return (pos // cap).astype(np.int32)

    def _to_logical(self, counts, coact):
        """Physical-slot stats → logical-expert stats (scatter by the
        slot_expert permutation on both axes)."""
        se = self.slot_expert
        E = self.num_experts
        lc = np.zeros(E)
        lc[se] = np.asarray(counts, np.float64)
        co = np.zeros((E, E))
        co[np.ix_(se, se)] = np.asarray(coact, np.float64)
        return lc, co

    def step(self, t: int, counts, coact, moe_layers: Sequence[Dict],
             *, mesh=None):
        """One post-train-step tick.  Returns ``(moe_layers, info)`` —
        the (possibly relocated) MoE parameter dicts and a record with
        the trigger decision and measured exchange volume."""
        lc, co = self._to_logical(counts, coact)
        self.stats.update_from_counts(lc, co)
        placement = self.placement
        mx, av, tot = rt_triggers.load_stats(
            jnp.asarray(np.maximum(self.stats.tokens, LOAD_FLOOR),
                        jnp.float32),
            jnp.asarray(placement), self.num_ranks)
        do, self.tstate = self.trig.decide(
            self.tstate, jnp.int32(t), mx, av, tot)
        fired = bool(do)
        moved, moved_bytes = 0, 0.0
        info: Dict = dict(t=int(t), fired=fired,
                          max_avg=float(mx / av))
        if fired:
            new, plan_info = ep_balance.plan_placement(
                self.stats, placement, self.num_ranks,
                strategy=self.strategy)
            moe_layers, se2, moved, moved_bytes = execute_placement(
                moe_layers, self.slot_expert, new,
                num_ranks=self.num_ranks, mesh=mesh)
            self.slot_expert = np.asarray(se2, np.int32)
            info.update(moved_experts=int(moved),
                        moved_bytes=float(moved_bytes),
                        plan=plan_info)
        self.tstate = self.trig.observe(
            self.tstate,
            jnp.float32(moved_bytes / self.bytes_per_load), do)
        self.history.append(info)
        return moe_layers, info
