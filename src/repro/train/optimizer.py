"""AdamW with decoupled weight decay, global-norm clipping, and sharded
moments.

Optimizer state mirrors the parameter sharding (every moment tensor carries
its parameter's PartitionSpec), so FSDP-style "data"-axis parameter sharding
automatically gives ZeRO-sharded optimizer state — no separate partitioning
pass.  All update math is fp32 regardless of compute dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # True ⇒ params are stored in a low-precision dtype (bf16) and the
    # optimizer carries the fp32 master copy.  Halves every FSDP weight
    # all-gather and the resident param bytes (EXPERIMENTS.md §Perf).
    master_fp32: bool = False


class OptState(NamedTuple):
    step: jax.Array          # scalar i32
    mu: Any                  # first moments  (tree like params)
    nu: Any                  # second moments
    master: Any = None       # fp32 master weights when OptConfig.master_fp32


def init(params, *, master_fp32: bool = False) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    master = (jax.tree.map(lambda p: p.astype(jnp.float32), params)
              if master_fp32 else None)
    return OptState(jnp.int32(0), zeros,
                    jax.tree.map(jnp.copy, zeros), master)


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_frac·lr."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(cfg.warmup_steps, 1)
    t = (s - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def apply(
    cfg: OptConfig,
    params,
    grads,
    state: OptState,
    *,
    decay_mask=None,
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step.  ``decay_mask`` is a tree of bools (None ⇒ decay
    every tensor with ndim ≥ 2, the usual no-decay-for-norms/bias rule)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    if decay_mask is None:
        decay_mask = jax.tree.map(lambda p: p.ndim >= 2, params)

    def upd(p, g, m, v, dm, master):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        base = master if master is not None else p.astype(jnp.float32)
        if dm:
            delta = delta + cfg.weight_decay * base
        new_base = base - lr * delta
        return new_base.astype(p.dtype), m_new, v_new, (
            new_base if master is not None else None)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_d = jax.tree.leaves(decay_mask)
    flat_w = (jax.tree.leaves(state.master) if state.master is not None
              else [None] * len(flat_p))
    out = [upd(p, g, m, v, d, w) for p, g, m, v, d, w
           in zip(flat_p, flat_g, flat_m, flat_v, flat_d, flat_w)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_w = (jax.tree.unflatten(tdef, [o[3] for o in out])
             if state.master is not None else None)
    metrics = dict(grad_norm=gnorm, lr=lr)
    return new_p, OptState(step, new_m, new_v, new_w), metrics
