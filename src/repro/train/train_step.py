"""The jitted training step: loss → grads → clipped AdamW update.

``make_train_step`` closes over (cfg, opt_cfg, remat) and returns a pure
``(params, opt_state, batch) -> (params, opt_state, metrics)`` suitable for
``jax.jit`` with donated params/opt_state.  Sharding comes entirely from the
parameter PartitionSpecs and the activation constraints inside the model —
the step itself is layout-agnostic.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_mod


def make_loss(cfg: ModelConfig, remat: str = "none",
              collect_router_stats: bool = False) -> Callable:
    def loss(params, batch):
        return transformer.loss_fn(
            params, cfg, batch, remat=remat,
            collect_router_stats=collect_router_stats)
    return loss


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: opt_mod.OptConfig,
    *,
    remat: str = "none",
    grad_transform: Optional[Callable] = None,
    collect_router_stats: bool = False,
) -> Callable:
    """``grad_transform(grads) -> grads`` hooks gradient compression
    (distributed/grad_compress.py) between backward and update.

    ``collect_router_stats`` surfaces the MoE router's per-step
    statistics (``router_counts`` (E,), ``router_coact`` (E, E)) in the
    metrics dict — accumulated on device inside the model's layer scan,
    so the expert-placement runtime (``train/ep_runtime.py``) never
    replays routing on the host."""
    loss = make_loss(cfg, remat, collect_router_stats)

    def step(params, opt_state, batch):
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            params, batch)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, opt_metrics = opt_mod.apply(
            opt_cfg, params, grads, opt_state)
        out = dict(loss=l, **{k: v for k, v in metrics.items()},
                   **opt_metrics)
        return params, opt_state, out

    return step


def make_eval_step(cfg: ModelConfig) -> Callable:
    loss = make_loss(cfg)

    def step(params, batch):
        l, metrics = loss(params, batch)
        return dict(loss=l, **metrics)

    return step
