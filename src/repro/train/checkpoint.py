"""Fault-tolerant checkpointing: per-host sharded ``.npz`` + JSON manifest.

Design (DESIGN.md §6):
  * every leaf is saved under its tree path; the manifest records step,
    tree structure, dtypes/shapes, and data-pipeline state;
  * **elastic restore**: arrays are loaded as host numpy and re-placed with
    ``jax.device_put`` against whatever mesh/sharding the *restoring* job
    uses — a 512-chip checkpoint restores onto 256 chips (or 1 CPU) as long
    as the logical shapes match;
  * **double-buffered directories** (`ckpt_<step>` + `LATEST` pointer
    written last, atomically) — a crash mid-save never corrupts the
    restore point;
  * ``keep`` bounds disk usage (oldest checkpoints garbage-collected).

At real multi-pod scale each host writes only its addressable shards; in
this single-process container the "gather" is a no-op, and the layout on
disk is identical.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(
    directory: str,
    step: int,
    params,
    opt_state=None,
    data_state: Optional[Dict] = None,
    *,
    keep: int = 3,
) -> str:
    """Write ``ckpt_<step>`` then flip ``LATEST``.  Returns the ckpt path."""
    os.makedirs(directory, exist_ok=True)
    name = f"ckpt_{step:08d}"
    final = os.path.join(directory, name)
    tmp = tempfile.mkdtemp(dir=directory, prefix=".tmp_" + name)
    try:
        arrays = {f"params/{k}": np.asarray(v)
                  for k, v in _flatten(params).items()}
        if opt_state is not None:
            arrays.update({f"opt/{k}": np.asarray(v)
                           for k, v in _flatten(opt_state).items()})
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = dict(
            step=int(step),
            keys=sorted(arrays.keys()),
            data_state=None if data_state is None else {
                k: (v.tolist() if isinstance(v, np.ndarray) else v)
                for k, v in data_state.items()},
        )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = os.path.join(directory, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(name)
    os.replace(ptr_tmp, os.path.join(directory, "LATEST"))
    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(directory) if d.startswith("ckpt_"))
    for d in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    ptr = os.path.join(directory, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(directory, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(
    directory: str,
    params_template,
    opt_template=None,
    *,
    step: Optional[int] = None,
    shardings=None,
    opt_shardings=None,
) -> Tuple[Any, Any, int, Optional[Dict]]:
    """Load (params, opt_state, step, data_state).

    ``*_template`` give the tree structure (ShapeDtypeStructs or arrays).
    ``shardings`` (same tree shape) re-places leaves for the current mesh —
    the elastic-restore path; None keeps host/default placement.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))

    def load_tree(template, prefix, shard_tree):
        flat_t = _flatten(template)
        flat_s = _flatten(shard_tree) if shard_tree is not None else None
        leaves_by_key = {}
        for k, t in flat_t.items():
            a = z[f"{prefix}/{k}"]
            want = tuple(t.shape)
            if tuple(a.shape) != want:
                raise ValueError(
                    f"checkpoint leaf {k}: shape {a.shape} != {want}")
            if flat_s is not None:
                a = jax.device_put(a, flat_s[k])
            leaves_by_key[k] = a
        # unflatten in template order
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path) for path, _ in paths]
        return jax.tree_util.tree_unflatten(
            treedef, [leaves_by_key[k] for k in keys])

    params = load_tree(params_template, "params", shardings)
    opt = None
    if opt_template is not None:
        opt = load_tree(opt_template, "opt", opt_shardings)
    data_state = manifest.get("data_state")
    return params, opt, int(manifest["step"]), data_state
