"""Data pipeline: deterministic synthetic corpus + length-aware batching
with **diffusion-balanced shard assignment** (the paper's technique at the
data level — DESIGN.md §3.2).

Variable-length documents are persistent objects: a document shard stays on
its DP rank across epochs (its tokenizer cache / prefetch state is the
"migration cost"), consecutive shards exchange boundary documents (the comm
edges — a ring), and per-shard token counts are the loads.  When length
skew drifts the per-rank work apart, ``balance_shards`` runs the paper's
three-stage balancer on the (shard → rank) assignment instead of reshuffling
everything (the GreedyLB-style global remap baseline is ``rebalance_global``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import api as core_api
from repro.core import comm_graph


@dataclasses.dataclass
class DataConfig:
    vocab_size: int = 32_000
    seq_len: int = 1024
    global_batch: int = 8
    num_shards: int = 64            # document shards (objects)
    seed: int = 0
    len_alpha: float = 2.5          # Pareto tail for document lengths


class SyntheticCorpus:
    """Deterministic infinite token stream, shardable by (shard, index).

    Tokens are a fixed PRNG stream => any rank can regenerate any shard
    (this is what makes checkpoint-free data recovery possible: the data
    state is just (epoch, per-shard cursor))."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # per-shard document lengths: heavy-tailed => load imbalance
        self.doc_lens = [
            np.maximum(
                16,
                (rng.pareto(cfg.len_alpha, size=256) * cfg.seq_len / 4)
            ).astype(np.int64)
            for _ in range(cfg.num_shards)
        ]

    def shard_tokens(self, shard: int, epoch: int) -> np.ndarray:
        """Total token count of a shard (its load)."""
        return self.doc_lens[shard].sum()

    def sample_batch(self, shard: int, cursor: int, n_seqs: int,
                     epoch: int = 0) -> Tuple[np.ndarray, int]:
        """(n_seqs, seq_len) token block + new cursor (packed documents)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + shard * 7919 + epoch) % (2**31))
        out = rng.integers(1, cfg.vocab_size, size=(n_seqs, cfg.seq_len),
                           dtype=np.int32)
        return out, cursor + n_seqs


def shard_problem(
    token_counts: np.ndarray,     # (num_shards,) current shard loads
    assignment: np.ndarray,       # (num_shards,) shard → DP rank
    num_ranks: int,
) -> comm_graph.LBProblem:
    """LBProblem over data shards: ring comm graph between consecutive
    shards (documents straddle shard boundaries on disk)."""
    n = token_counts.shape[0]
    nxt = (np.arange(n) + 1) % n
    edges = np.stack([np.arange(n), nxt], axis=1)
    ebytes = np.full(n, float(np.mean(token_counts)) * 0.01 + 1.0,
                     np.float32)
    return comm_graph.make_problem(
        loads=token_counts.astype(np.float32),
        assignment=assignment,
        edges=edges,
        edge_bytes=ebytes,
        num_nodes=num_ranks,
        coords=np.arange(n, dtype=np.float32)[:, None],
    )


def balance_shards(token_counts, assignment, num_ranks, *, k: int = 2,
                   variant: str = "comm") -> Tuple[np.ndarray, Dict]:
    """Diffusion-rebalance the shard→rank map (paper technique)."""
    prob = shard_problem(np.asarray(token_counts), np.asarray(assignment),
                         num_ranks)
    plan = core_api.diffusion_lb(prob, k=min(k, num_ranks - 1),
                                 variant=variant)
    return plan.assignment.astype(np.int32), plan.info


def rebalance_global(token_counts, num_ranks) -> np.ndarray:
    """GreedyLB-style global remap baseline (max migration)."""
    order = np.argsort(-np.asarray(token_counts))
    loads = np.zeros(num_ranks)
    out = np.zeros(len(token_counts), np.int32)
    for s in order:
        r = int(np.argmin(loads))
        out[s] = r
        loads[r] += token_counts[s]
    return out


@dataclasses.dataclass
class PipelineState:
    epoch: int
    cursor: np.ndarray            # (num_shards,) per-shard position
    assignment: np.ndarray        # (num_shards,) shard → DP rank

    def to_dict(self):
        return dict(epoch=self.epoch, cursor=self.cursor,
                    assignment=self.assignment)

    @staticmethod
    def from_dict(d):
        return PipelineState(int(d["epoch"]), np.asarray(d["cursor"]),
                             np.asarray(d["assignment"]))


class DataPipeline:
    """Host-side batch producer.  ``next_batch`` returns a global batch
    (tokens, labels, positions) plus per-rank token-load stats the trainer
    feeds back into ``maybe_rebalance``."""

    def __init__(self, cfg: DataConfig, num_ranks: int,
                 state: Optional[PipelineState] = None):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg)
        self.num_ranks = num_ranks
        if state is None:
            state = PipelineState(
                epoch=0,
                cursor=np.zeros(cfg.num_shards, np.int64),
                assignment=(np.arange(cfg.num_shards) * num_ranks
                            // cfg.num_shards).astype(np.int32),
            )
        self.state = state

    def rank_loads(self) -> np.ndarray:
        counts = np.array([self.corpus.shard_tokens(s, self.state.epoch)
                           for s in range(self.cfg.num_shards)], np.float64)
        return np.bincount(self.state.assignment, weights=counts,
                           minlength=self.num_ranks)

    def maybe_rebalance(self, *, threshold: float = 1.1) -> Optional[Dict]:
        loads = self.rank_loads()
        if loads.max() / (loads.mean() + 1e-30) < threshold:
            return None
        counts = np.array([self.corpus.shard_tokens(s, self.state.epoch)
                           for s in range(self.cfg.num_shards)])
        new_assign, info = balance_shards(
            counts, self.state.assignment, self.num_ranks)
        info["moved_shards"] = int(
            (new_assign != self.state.assignment).sum())
        self.state.assignment = new_assign
        return info

    def next_batch(self, rng_epoch: int = 0) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        per_rank = cfg.global_batch // self.num_ranks
        toks = []
        for r in range(self.num_ranks):
            shards = np.nonzero(self.state.assignment == r)[0]
            s = int(shards[self.state.epoch % len(shards)]) if len(shards) \
                else int(r % cfg.num_shards)
            block, cur = self.corpus.sample_batch(
                s, int(self.state.cursor[s]), per_rank, self.state.epoch)
            self.state.cursor[s] = cur
            toks.append(block)
        tokens = np.concatenate(toks, axis=0)
        labels = np.concatenate(
            [tokens[:, 1:], np.full((tokens.shape[0], 1), -1, np.int32)],
            axis=1)
        positions = np.broadcast_to(
            np.arange(cfg.seq_len, dtype=np.int32)[None], tokens.shape)
        self.state.epoch += 1
        return dict(tokens=tokens, labels=labels,
                    positions=np.ascontiguousarray(positions))
