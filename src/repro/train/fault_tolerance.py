"""Fault tolerance & straggler mitigation for the training runtime.

Three mechanisms, all exercised by tests/test_fault_tolerance.py:

1. **Checkpoint/restart loop** (`run_resilient`): the driver runs the step
   function under a supervisor that catches worker failures (injected or
   real), restores from the last checkpoint, and continues.  Recovery is
   bounded by checkpoint cadence; the test kills the loop at random steps
   and asserts bit-exact continuation.

2. **Heartbeat / failure detection** (`HeartbeatMonitor`): at real scale
   each host posts a heartbeat after every step; the monitor flags hosts
   whose age exceeds ``timeout_steps``.  Here hosts are simulated
   participants — the detection logic (not the transport) is the unit under
   test.

3. **Straggler mitigation** (`StragglerBalancer`): per-host step times form
   the *load* of the paper's balancer; hosts that persistently exchange
   activations (DP ring / PP stages) are the comm graph.  Slow hosts shed
   data shards to fast neighbors via the diffusion planner — the paper's
   own technique applied to the runtime itself (DESIGN.md §3).  An EMA
   filters noise so only persistent stragglers trigger movement.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core import comm_graph
from repro.core import engine as core_engine
from repro.train import checkpoint as ckpt


# ------------------------------------------------------------ supervisor --


class WorkerFailure(RuntimeError):
    """Raised (or injected) when a worker dies mid-step."""


def run_resilient(
    step_fn: Callable[[int], None],
    *,
    start_step: int,
    num_steps: int,
    save_every: int,
    save_fn: Callable[[int], None],
    restore_fn: Callable[[], int],
    max_restarts: int = 8,
    on_failure: Optional[Callable[[int, Exception], None]] = None,
) -> Dict:
    """Supervised step loop.  ``step_fn(step)`` may raise WorkerFailure;
    the supervisor restores and resumes.  Returns run stats."""
    restarts = 0
    step = start_step
    while step < num_steps:
        try:
            step_fn(step)
            step += 1
            if step % save_every == 0:
                save_fn(step)
        except WorkerFailure as e:  # noqa: PERF203 — failure path is rare
            restarts += 1
            if on_failure is not None:
                on_failure(step, e)
            if restarts > max_restarts:
                raise
            step = restore_fn()
    return dict(final_step=step, restarts=restarts)


# ------------------------------------------------------------- heartbeat --


@dataclasses.dataclass
class HeartbeatMonitor:
    num_hosts: int
    timeout_steps: int = 3
    _last: Optional[np.ndarray] = None

    def __post_init__(self):
        self._last = np.zeros(self.num_hosts, np.int64)

    def beat(self, host: int, step: int) -> None:
        self._last[host] = step

    def dead_hosts(self, current_step: int) -> List[int]:
        age = current_step - self._last
        return list(np.nonzero(age > self.timeout_steps)[0])

    def healthy_mesh_size(self, current_step: int) -> int:
        """Elastic scaling hook: the largest power-of-two host count
        available after excluding dead hosts (re-mesh candidate)."""
        alive = self.num_hosts - len(self.dead_hosts(current_step))
        size = 1
        while size * 2 <= alive:
            size *= 2
        return size


# ------------------------------------------------------------ stragglers --


@dataclasses.dataclass
class StragglerBalancer:
    """Diffusion-based data re-sharding against persistent stragglers."""

    num_hosts: int
    shards_per_host: int = 8
    ema: float = 0.8
    trigger: float = 1.15          # max/avg EMA step time that triggers LB

    def __post_init__(self):
        self._ema_time = np.ones(self.num_hosts)
        n = self.num_hosts * self.shards_per_host
        self._shard_host = (np.arange(n) // self.shards_per_host).astype(
            np.int32)

    @property
    def shard_assignment(self) -> np.ndarray:
        return self._shard_host.copy()

    def host_share(self) -> np.ndarray:
        """(H,) fraction of data shards per host."""
        return np.bincount(self._shard_host,
                           minlength=self.num_hosts) / len(self._shard_host)

    def observe(self, step_times: np.ndarray) -> Optional[Dict]:
        """Feed per-host step times; returns LB info when triggered."""
        self._ema_time = (self.ema * self._ema_time
                          + (1 - self.ema) * np.asarray(step_times))
        ratio = self._ema_time.max() / (self._ema_time.mean() + 1e-30)
        if ratio < self.trigger:
            return None
        return self._rebalance()

    def _rebalance(self) -> Dict:
        n = len(self._shard_host)
        # shard load = host slowness (time per unit data) × shard size(=1)
        loads = self._ema_time[self._shard_host]
        nxt = (np.arange(n) + 1) % n
        edges = np.stack([np.arange(n), nxt], axis=1)
        prob = comm_graph.make_problem(
            loads=loads.astype(np.float32),
            assignment=self._shard_host,
            edges=edges,
            edge_bytes=np.ones(n, np.float32),
            num_nodes=self.num_hosts,
            coords=np.arange(n, dtype=np.float32)[:, None],
        )
        # route through the Strategy registry → jitted LBEngine.plan_fn:
        # straggler mitigation and the replay runtime share one compiled
        # planner code path (and one engine cache entry per configuration)
        plan = core_engine.get_strategy("diff-comm").run(
            prob, k=min(2, self.num_hosts - 1))
        moved = int((plan.assignment != self._shard_host).sum())
        self._shard_host = plan.assignment.astype(np.int32)
        return dict(moved_shards=moved, **plan.info)
