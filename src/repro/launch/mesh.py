"""Production mesh construction.

A *function*, not a module constant — importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax init; tests
see 1 device).
"""
from __future__ import annotations

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (data, model) single pod; 2×16×16 (pod, data, model) for two
    pods.  512 chips total in the multi-pod configuration."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    assert data * model <= n, f"need {data * model} devices, have {n}"
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=_auto(2))
