"""Training launcher: end-to-end driver wiring every subsystem together.

``python -m repro.launch.train --arch smollm-135m --steps 100 ...`` runs a
real (small-scale) training job on the available devices: data pipeline →
jitted train step (donated state) → periodic checkpointing → fault-tolerant
supervision → optional diffusion balancers (EP placement for MoE archs,
straggler-driven data re-sharding).

At production scale the same module is the per-host entry point: the mesh
comes from ``make_production_mesh`` and jax.distributed handles cross-host
init (not available in this container; the multi-pod configuration is
exercised by launch/dryrun.py instead).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.distributed import compat
from repro.models import transformer
from repro.models.params import init_params
from repro.obs import metrics as obs_metrics
from repro.train import checkpoint as ckpt
from repro.train import data as data_mod
from repro.train import ep_runtime
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod


@dataclasses.dataclass
class RunConfig:
    arch: str = "smollm-135m"
    reduced: bool = True            # full configs need real accelerators
    steps: int = 50
    seq_len: int = 128
    global_batch: int = 8
    lr: float = 3e-4
    warmup: int = 10
    save_every: int = 20
    ckpt_dir: Optional[str] = None
    resume: bool = True
    remat: str = "none"
    ep_balance_every: int = 0       # MoE expert rebalance cadence (0 = off)
    ep_strategy: str = "diff-comm"  # any registered strategy (+ "greedy")
    ep_trigger: Optional[str] = None  # None → strategy default / cadence
    ep_num_ranks: int = 0           # EP ranks (0 = min(4, E) at host scale)
    seed: int = 0
    log_every: int = 10
    profile_dir: Optional[str] = None  # jax.profiler.trace around the loop


def build(cfg: RunConfig):
    spec = get_arch(cfg.arch)
    mcfg = spec.reduced if cfg.reduced else spec.config
    specs = transformer.model_specs(mcfg)
    params = init_params(specs, cfg.seed)
    ocfg = opt_mod.OptConfig(lr=cfg.lr, warmup_steps=cfg.warmup,
                             total_steps=cfg.steps)
    opt_state = opt_mod.init(params)
    collect = bool(cfg.ep_balance_every) and mcfg.moe is not None
    step_fn = jax.jit(ts_mod.make_train_step(mcfg, ocfg, remat=cfg.remat,
                                             collect_router_stats=collect),
                      donate_argnums=(0, 1))
    dcfg = data_mod.DataConfig(vocab_size=mcfg.vocab_size,
                               seq_len=cfg.seq_len,
                               global_batch=cfg.global_batch,
                               seed=cfg.seed)
    pipe = data_mod.DataPipeline(dcfg, num_ranks=1)
    return mcfg, params, opt_state, step_fn, pipe


def train(cfg: RunConfig) -> Dict:
    mcfg, params, opt_state, step_fn, pipe = build(cfg)
    start = 0
    if cfg.ckpt_dir and cfg.resume and ckpt.latest_step(cfg.ckpt_dir) is not None:
        params, opt_state, start, ds = ckpt.restore(
            cfg.ckpt_dir, params, opt_state)
        if ds:
            pipe.state = data_mod.PipelineState.from_dict(ds)
        print(f"resumed from step {start}")

    rebalancer = None
    if cfg.ep_balance_every and mcfg.moe is not None:
        E = mcfg.moe.num_experts
        # EP ranks at host scale: a few virtual ranks (the planning logic
        # is rank-count agnostic; at production scale this is the
        # model-axis size).
        R = cfg.ep_num_ranks or min(4, E)
        rebalancer = ep_runtime.EPRebalancer(
            E, R, strategy=cfg.ep_strategy, trigger=cfg.ep_trigger,
            lb_every=cfg.ep_balance_every)

    hist = []
    t0 = time.time()
    with compat.profiler_trace(cfg.profile_dir):
        for step in range(start, cfg.steps):
            batch = {k: jnp.asarray(v)
                     for k, v in pipe.next_batch().items()}
            params, opt_state, m = step_fn(params, opt_state, batch)
            loss = float(m["loss"])
            hist.append(loss)
            # registry first, log lines from the snapshot — one source
            obs_metrics.counter("train/steps").inc()
            obs_metrics.gauge("train/loss").set(loss)
            obs_metrics.gauge("train/grad_norm").set(float(m["grad_norm"]))
            obs_metrics.gauge("train/lr").set(float(m["lr"]))
            obs_metrics.gauge("train/seconds").set(time.time() - t0)
            if cfg.log_every and step % cfg.log_every == 0:
                s = obs_metrics.snapshot()
                print(f"step {step:5d} loss {s['train/loss']:.4f} "
                      f"gnorm {s['train/grad_norm']:.3f} "
                      f"lr {s['train/lr']:.2e} "
                      f"({s['train/seconds']:.1f}s)", flush=True)
            if (cfg.ckpt_dir and cfg.save_every
                    and (step + 1) % cfg.save_every == 0):
                ckpt.save(cfg.ckpt_dir, step + 1, params, opt_state,
                          data_state=pipe.state.to_dict())
                obs_metrics.counter("train/checkpoints").inc()
            if rebalancer is not None:
                params, info = _rebalance_experts(params, rebalancer, m,
                                                  step)
                if info.get("fired"):
                    obs_metrics.counter("train/ep_fires").inc()
                    obs_metrics.counter("train/ep_moved_experts").inc(
                        int(info["moved_experts"]))
                    obs_metrics.counter("train/ep_moved_bytes").inc(
                        float(info["moved_bytes"]))
                    obs_metrics.gauge("train/ep_last_moved").set(
                        int(info["moved_experts"]))
                    obs_metrics.gauge("train/ep_last_bytes").set(
                        float(info["moved_bytes"]))
                    obs_metrics.gauge("train/ep_max_avg").set(
                        float(info["max_avg"]))
                    if cfg.log_every:
                        s = obs_metrics.snapshot()
                        print(f"  [ep-balance] moved "
                              f"{int(s['train/ep_last_moved'])} "
                              f"experts ({s['train/ep_last_bytes']:.0f} "
                              f"B), max/avg {s['train/ep_max_avg']:.3f}",
                              flush=True)
    if cfg.ckpt_dir:
        ckpt.save(cfg.ckpt_dir, cfg.steps, params, opt_state,
                  data_state=pipe.state.to_dict())
    return dict(losses=hist, final_loss=hist[-1] if hist else float("nan"),
                seconds=time.time() - t0, params=params,
                opt_state=opt_state)


def _moe_blocks(params) -> list:
    """(section, index) of every block param dict holding a MoE layer."""
    out = []
    for sect in ("unit", "prefix", "suffix"):
        for i, blk in enumerate(params.get(sect, ())):
            if isinstance(blk, dict) and "moe" in blk:
                out.append((sect, i))
    return out


def _rebalance_experts(params, rebalancer: "ep_runtime.EPRebalancer",
                       metrics: Dict, step: int):
    """One live-rebalancing tick on the real parameter tree.

    The train step already accumulated the router statistics on device
    (``router_counts``/``router_coact`` in its metrics); the rebalancer
    decides, plans, and — on fire — relocates every MoE layer's expert
    weights through the executed exchange, reporting the measured moved
    bytes back to its trigger."""
    where = _moe_blocks(params)
    layers = [params[s][i]["moe"] for s, i in where]
    layers, info = rebalancer.step(
        step, np.asarray(metrics["router_counts"]),
        np.asarray(metrics["router_coact"]), layers)
    if info.get("fired"):
        for (s, i), moe in zip(where, layers):
            params[s][i] = {**params[s][i], "moe": moe}
    return params, info


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--profile-dir", default=None,
                    help="wrap the train loop in jax.profiler.trace(DIR)")
    args = ap.parse_args()
    cfg = RunConfig(arch=args.arch, reduced=not args.full, steps=args.steps,
                    seq_len=args.seq_len, global_batch=args.batch,
                    lr=args.lr, ckpt_dir=args.ckpt_dir, remat=args.remat,
                    profile_dir=args.profile_dir)
    out = train(cfg)
    print(f"done: final loss {out['final_loss']:.4f} in {out['seconds']:.1f}s")


if __name__ == "__main__":
    main()
