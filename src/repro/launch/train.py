"""Training launcher: end-to-end driver wiring every subsystem together.

``python -m repro.launch.train --arch smollm-135m --steps 100 ...`` runs a
real (small-scale) training job on the available devices: data pipeline →
jitted train step (donated state) → periodic checkpointing → fault-tolerant
supervision → optional diffusion balancers (EP placement for MoE archs,
straggler-driven data re-sharding).

At production scale the same module is the per-host entry point: the mesh
comes from ``make_production_mesh`` and jax.distributed handles cross-host
init (not available in this container; the multi-pod configuration is
exercised by launch/dryrun.py instead).
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.distributed import ep_balance
from repro.models import transformer
from repro.models.params import init_params
from repro.train import checkpoint as ckpt
from repro.train import data as data_mod
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod


@dataclasses.dataclass
class RunConfig:
    arch: str = "smollm-135m"
    reduced: bool = True            # full configs need real accelerators
    steps: int = 50
    seq_len: int = 128
    global_batch: int = 8
    lr: float = 3e-4
    warmup: int = 10
    save_every: int = 20
    ckpt_dir: Optional[str] = None
    resume: bool = True
    remat: str = "none"
    ep_balance_every: int = 0       # MoE expert rebalance cadence (0 = off)
    seed: int = 0
    log_every: int = 10


def build(cfg: RunConfig):
    spec = get_arch(cfg.arch)
    mcfg = spec.reduced if cfg.reduced else spec.config
    specs = transformer.model_specs(mcfg)
    params = init_params(specs, cfg.seed)
    ocfg = opt_mod.OptConfig(lr=cfg.lr, warmup_steps=cfg.warmup,
                             total_steps=cfg.steps)
    opt_state = opt_mod.init(params)
    step_fn = jax.jit(ts_mod.make_train_step(mcfg, ocfg, remat=cfg.remat),
                      donate_argnums=(0, 1))
    dcfg = data_mod.DataConfig(vocab_size=mcfg.vocab_size,
                               seq_len=cfg.seq_len,
                               global_batch=cfg.global_batch,
                               seed=cfg.seed)
    pipe = data_mod.DataPipeline(dcfg, num_ranks=1)
    return mcfg, params, opt_state, step_fn, pipe


def train(cfg: RunConfig) -> Dict:
    mcfg, params, opt_state, step_fn, pipe = build(cfg)
    start = 0
    if cfg.ckpt_dir and cfg.resume and ckpt.latest_step(cfg.ckpt_dir) is not None:
        params, opt_state, start, ds = ckpt.restore(
            cfg.ckpt_dir, params, opt_state)
        if ds:
            pipe.state = data_mod.PipelineState.from_dict(ds)
        print(f"resumed from step {start}")

    estats = None
    if cfg.ep_balance_every and mcfg.moe is not None:
        estats = ep_balance.ExpertStats(mcfg.moe.num_experts)

    hist = []
    t0 = time.time()
    for step in range(start, cfg.steps):
        batch = {k: jnp.asarray(v) for k, v in pipe.next_batch().items()}
        params, opt_state, m = step_fn(params, opt_state, batch)
        loss = float(m["loss"])
        hist.append(loss)
        if cfg.log_every and step % cfg.log_every == 0:
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if cfg.ckpt_dir and cfg.save_every and (step + 1) % cfg.save_every == 0:
            ckpt.save(cfg.ckpt_dir, step + 1, params, opt_state,
                      data_state=pipe.state.to_dict())
        if (estats is not None and cfg.ep_balance_every
                and (step + 1) % cfg.ep_balance_every == 0):
            _rebalance_experts(mcfg, params, estats)
    if cfg.ckpt_dir:
        ckpt.save(cfg.ckpt_dir, cfg.steps, params, opt_state,
                  data_state=pipe.state.to_dict())
    return dict(losses=hist, final_loss=hist[-1] if hist else float("nan"),
                seconds=time.time() - t0, params=params,
                opt_state=opt_state)


def _rebalance_experts(mcfg, params, estats: ep_balance.ExpertStats):
    """Collect router stats from the last batch and re-place experts."""
    E = mcfg.moe.num_experts
    # EP ranks at host scale: pretend 4 ranks (the planning logic is rank-
    # count agnostic; at production scale this is the model-axis size).
    R = min(4, E)
    placement = (np.arange(E) * R // E).astype(np.int32)
    new, info = ep_balance.plan_placement(estats, placement, R)
    print(f"  [ep-balance] moved {info['moved_experts']} experts, "
          f"max/avg {info['max_avg_load']:.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--remat", default="none")
    args = ap.parse_args()
    cfg = RunConfig(arch=args.arch, reduced=not args.full, steps=args.steps,
                    seq_len=args.seq_len, global_batch=args.batch,
                    lr=args.lr, ckpt_dir=args.ckpt_dir, remat=args.remat)
    out = train(cfg)
    print(f"done: final loss {out['final_loss']:.4f} in {out['seconds']:.1f}s")


if __name__ == "__main__":
    main()
