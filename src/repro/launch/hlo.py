"""Loop-aware HLO analysis: flops, HBM traffic, collective bytes.

The compiled per-device module text is the dry-run's "profile".  XLA's
``cost_analysis()`` counts a ``while`` body **once**, so anything under
``lax.scan`` (layers, attention chunks, loss chunks) is under-counted by the
trip count.  This module parses the module into computations, reads each
``while`` op's static trip count (XLA records it as
``backend_config={"known_trip_count":{"n":...}}``; fallback: the constant in
the condition computation), and recursively weights body costs — nested
scans (attention chunks inside the layer scan) multiply out.

Per-op metrics (operand shapes resolved through a per-computation symbol
table — compiled HLO references operands by name only):

  * flops       — ``dot`` ops: 2 · prod(result dims) · prod(lhs contracting
                  dims).  Matmul-only by construction (element-wise flops
                  are negligible for these models).
  * traffic     — HBM bytes: operands + result of every *compute* op at
                  fusion boundaries (fusion interiors don't round-trip HBM,
                  so called fusion computations contribute flops but not
                  traffic).  A static over-approximation (assumes no cache
                  residency between ops); validated against
                  ``cost_analysis`` on scan-free modules in tests/test_hlo.py.
  * collectives — wire bytes per kind:
        all-gather         → result bytes (each device receives the gather)
        all-reduce         → 2× operand (ring reduce-scatter + all-gather)
        reduce-scatter / all-to-all / collective-permute → operand bytes
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(
    r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+fn?)?|pred)\[([0-9,]*)\]")

# ops whose boundary operand/result bytes count as HBM traffic
_TRAFFIC_SKIP = frozenset((
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "reshape",
))


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes_of(shapes: List[Tuple[str, List[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


# ------------------------------------------------------- module splitting --

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.*?\)?)\s*([\w\-]+)\((.*)$")
_WHILE_ATTR_RE = re.compile(
    r"condition=%?([\w.\-]+)\s*,\s*body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[":{]+n["\s:]+"?(\d+)')
_CALL_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def split_computations(hlo_text: str) -> Tuple[Dict[str, List[str]], str]:
    comps: Dict[str, List[str]] = {}
    entry = ""
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        if line.rstrip().endswith("{") and "=" not in line.split("(")[0]:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    if not entry and comps:
        entry = max(comps, key=lambda k: len(comps[k]))
    return comps, entry


class _Comp:
    """Parsed computation: op lines + result-shape symbol table."""

    def __init__(self, lines: List[str]):
        self.ops: List[Tuple[str, str, str, str]] = []   # name, result, op, rest
        self.shape: Dict[str, List[Tuple[str, List[int]]]] = {}
        for line in lines:
            m = _DEF_RE.match(line)
            if not m:
                continue
            name, result, op, rest = m.groups()
            self.ops.append((name, result, op, rest))
            self.shape[name] = _shapes_in(result)

    def operand_bytes(self, rest: str) -> int:
        """Bytes of the %name operands inside the call parens."""
        depth = 0
        end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    end = i
                    break
                depth -= 1
        names = _OPERAND_RE.findall(rest[:end])
        return sum(_shape_bytes_of(self.shape.get(n, [])) for n in names)

    def operand_shapes(self, rest: str) -> List[List[Tuple[str, List[int]]]]:
        end = rest.find(")")
        names = _OPERAND_RE.findall(rest[:end if end >= 0 else len(rest)])
        return [self.shape.get(n, []) for n in names]


def _trip_count_from_cond(comp: Optional[_Comp]) -> int:
    if comp is None:
        return 1
    consts = [1]
    for _, _, op, rest in comp.ops:
        if op == "constant":
            m = re.match(r"(\d+)\)", rest)
            if m:
                consts.append(int(m.group(1)))
    return max(consts)


def _dot_flops(comp: _Comp, result: str, rest: str, line: str) -> float:
    shapes = _shapes_in(result)
    if not shapes:
        return 0.0
    rn = 1
    for d in shapes[0][1]:
        rn *= d
    opshapes = comp.operand_shapes(rest)
    lhs_dims = opshapes[0][0][1] if opshapes and opshapes[0] else []
    cdim = 1
    cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
    if cm and cm.group(1):
        for i in cm.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                cdim *= lhs_dims[idx]
    return 2.0 * rn * cdim


def analyze(hlo_text: str) -> Dict[str, float]:
    """Loop-aware module metrics (see module docstring)."""
    raw, entry = split_computations(hlo_text)
    comps = {k: _Comp(v) for k, v in raw.items()}
    if entry not in comps:
        comps = {"__all__": _Comp(hlo_text.splitlines())}
        entry = "__all__"
    memo: Dict[Tuple[str, bool], Dict[str, float]] = {}

    def add(a, b, scale=1.0):
        for k, v in b.items():
            a[k] = a.get(k, 0.0) + scale * v

    def walk(name: str, fused: bool, depth: int = 0) -> Dict[str, float]:
        key = (name, fused)
        if key in memo:
            return memo[key]
        memo[key] = {}
        out: Dict[str, float] = {}
        comp = comps.get(name)
        if comp is None or depth > 48:
            return out
        for opname, result, op, rest in comp.ops:
            full = f"{result} {op}({rest}"
            base = op.replace("-start", "")
            if op.endswith("-done"):
                continue
            if op == "while":
                wm = _WHILE_ATTR_RE.search(rest)
                if wm:
                    cond, body = wm.groups()
                    tm = _TRIP_RE.search(rest)
                    trip = (int(tm.group(1)) if tm
                            else _trip_count_from_cond(comps.get(cond)))
                    add(out, walk(body, fused, depth + 1), trip)
                continue
            if base in COLLECTIVES:
                ob = comp.operand_bytes(rest)
                rb = _shape_bytes_of(_shapes_in(result))
                if base == "all-gather":
                    b = float(rb)
                elif base == "all-reduce":
                    b = 2.0 * ob
                else:
                    b = float(ob)
                out[f"coll:{base}:bytes"] = out.get(f"coll:{base}:bytes", 0.0) + b
                out[f"coll:{base}:count"] = out.get(f"coll:{base}:count", 0.0) + 1
                continue
            if op == "dot":
                out["flops"] = out.get("flops", 0.0) + _dot_flops(
                    comp, result, rest, full)
            if op in ("fusion", "call", "custom-call", "map", "reduce",
                      "sort", "scatter", "select-and-scatter", "conditional"):
                cm = _CALL_RE.search(rest)
                if cm:
                    # fused interiors: flops + collectives yes, traffic no
                    add(out, walk(cm.group(1), True, depth + 1))
            if not fused and op not in _TRAFFIC_SKIP:
                ob = comp.operand_bytes(rest)
                rb = _shape_bytes_of(_shapes_in(result))
                out["traffic"] = out.get("traffic", 0.0) + ob + rb
                key = f"traffic:{op}"
                out[key] = out.get(key, 0.0) + ob + rb
        memo[key] = out
        return out

    flat = walk(entry, False)
    flat.setdefault("flops", 0.0)
    flat.setdefault("traffic", 0.0)
    flat["collective_bytes"] = sum(
        v for k, v in flat.items()
        if k.startswith("coll:") and k.endswith(":bytes"))
    return flat


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    flat = analyze(hlo_text)
    return {k: dict(count=flat.get(f"coll:{k}:count", 0.0),
                    bytes=flat.get(f"coll:{k}:bytes", 0.0))
            for k in COLLECTIVES}


def total_collective_bytes(hlo_text: str) -> float:
    return analyze(hlo_text)["collective_bytes"]


def op_census(hlo_text: str, ops: Tuple[str, ...] = ("fusion", "dot",
                                                     "convolution", "copy",
                                                     "transpose")) -> Dict[str, int]:
    census = {o: 0 for o in ops}
    for line in hlo_text.splitlines():
        for o in ops:
            if re.search(rf"= .*\b{o}\(", line):
                census[o] += 1
    return census


def while_trip_counts(hlo_text: str) -> List[int]:
    raw, _ = split_computations(hlo_text)
    comps = {k: _Comp(v) for k, v in raw.items()}
    trips = []
    for comp in comps.values():
        for _, _, op, rest in comp.ops:
            if op == "while":
                tm = _TRIP_RE.search(rest)
                if tm:
                    trips.append(int(tm.group(1)))
                else:
                    wm = _WHILE_ATTR_RE.search(rest)
                    trips.append(_trip_count_from_cond(
                        comps.get(wm.group(1))) if wm else 1)
    return sorted(trips, reverse=True)


def format_stats(stats: Dict[str, Dict[str, float]]) -> str:
    rows = [f"{'collective':>20} {'count':>8} {'MiB':>12}"]
    for k, v in stats.items():
        if v["count"]:
            rows.append(
                f"{k:>20} {v['count']:>8.0f} {v['bytes']/2**20:>12.2f}")
    return "\n".join(rows)
