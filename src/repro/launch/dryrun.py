import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (device count locks at first init).  512
# placeholder host devices back both production meshes; dry-run only — tests
# and benchmarks see the real single device.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this driver builds the real jitted entry point (train_step /
prefill / decode_step) with production in/out shardings, runs
``.lower().compile()`` against ShapeDtypeStruct inputs (no allocation), and
records:

  * ``memory_analysis()``   — per-device bytes (proves the cell fits)
  * ``cost_analysis()``     — HLO FLOPs + HBM bytes (roofline terms 1-2)
  * collective bytes        — parsed from the compiled HLO (roofline term 3)
  * lower/compile wall time, HLO op census, model-FLOPs (6·N·D / 2·N·D)

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json``;
``benchmarks/roofline.py`` and EXPERIMENTS.md §Dry-run/§Roofline consume
them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m \
      --shape train_4k --mesh single            # one cell
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
  ... --set remat=full --set moe_impl=dense --tag myexp   # perf overrides
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, get_arch, input_specs, list_archs, shape_applicable
from repro.distributed import sharding as shard_rules
from repro.launch import hlo as hlo_mod
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.models.params import count_params, shape_dtype_tree
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                         "artifacts", "dryrun")

# TPU v5e hardware model (assignment §Roofline)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link


def active_params(cfg: ModelConfig) -> Dict[str, int]:
    """Total and per-token-active parameter counts (MoE-aware)."""
    specs = transformer.model_specs(cfg)
    total = count_params(specs)
    if cfg.moe is None:
        return dict(total=total, active=total)
    m = cfg.moe
    expert_p = 3 * cfg.d_model * m.d_expert
    n_moe = sum(1 for k in cfg.all_layers() if k.startswith("moe"))
    inactive = n_moe * (m.num_experts - m.top_k) * expert_p
    return dict(total=total, active=total - inactive)


def model_flops(cfg: ModelConfig, shape) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (inference)."""
    p = active_params(cfg)["active"]
    if shape.kind == "train":
        return 6.0 * p * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * p * shape.global_batch * shape.seq_len
    return 2.0 * p * shape.global_batch            # decode: 1 token/seq


def apply_overrides(cfg: ModelConfig, overrides: Dict[str, str]) -> ModelConfig:
    """--set key=value model-config overrides for perf experiments."""
    kw: Dict[str, Any] = {}
    for k, v in overrides.items():
        if k == "moe_impl":
            assert cfg.moe is not None
            kw["moe"] = dataclasses.replace(cfg.moe, impl=v)
        elif k in ("sliding_window", "vision_prefix"):
            kw[k] = int(v)
        elif k in ("compute_dtype", "param_dtype"):
            kw[k] = v
        elif k == "sharding":
            kw["sharding_profile"] = v
        elif k == "ep":
            kw["ep_axes"] = (("data", "model") if v == "wide"
                             else ("model",))
    return dataclasses.replace(cfg, **kw) if kw else cfg


def build_cell(cfg: ModelConfig, shape, mesh, *, remat: str = "none",
               seq_chunk: int = 512):
    """Returns (jitted_fn, example_args (SDS), n_static) for one cell."""
    specs = transformer.model_specs(cfg)
    params_sds = shape_dtype_tree(specs)
    prof = cfg.sharding_profile
    if prof == "dp" and shape.kind != "train":
        prof = "2d"            # cache paths need KV-length sharding
    pshard = shard_rules.param_shardings(specs, mesh, prof)
    ins = input_specs(cfg, shape)

    if shape.kind == "train":
        master = cfg.param_dtype != "float32"
        ocfg = opt_mod.OptConfig(master_fp32=master)
        opt_sds = jax.eval_shape(
            lambda p: opt_mod.init(p, master_fp32=master), params_sds)
        oshard = shard_rules.opt_shardings(pshard, mesh, master=master)
        bshard = shard_rules.data_shardings(ins["batch"], mesh, prof)
        step = ts_mod.make_train_step(cfg, ocfg, remat=remat)
        fn = jax.jit(
            step,
            in_shardings=(pshard, oshard, bshard),
            out_shardings=(pshard, oshard, None),
            donate_argnums=(0, 1),
        )
        return fn, (params_sds, opt_sds, ins["batch"])

    if shape.kind == "prefill":
        bshard = shard_rules.data_shardings(ins["batch"], mesh, prof)
        cache_sds = jax.eval_shape(
            lambda: transformer.init_cache(
                cfg, shape.global_batch, shape.seq_len, jnp.bfloat16))
        cshard = shard_rules.cache_shardings(cache_sds, mesh, prof)

        def prefill(params, batch, cache):
            return transformer.prefill(params, cfg, batch, cache)

        fn = jax.jit(
            prefill,
            in_shardings=(pshard, bshard, cshard),
            out_shardings=(None, cshard),
            donate_argnums=(2,),
        )
        return fn, (params_sds, ins["batch"], cache_sds)

    # decode
    cache_sds = ins["cache"]
    cshard = shard_rules.cache_shardings(cache_sds, mesh, prof)
    tok_shard = shard_rules.data_shardings(
        dict(tokens=ins["tokens"]), mesh, prof)["tokens"]

    def decode(params, tokens, index, cache):
        return transformer.decode_step(params, cfg, tokens, index, cache)

    fn = jax.jit(
        decode,
        in_shardings=(pshard, tok_shard, None, cshard),
        out_shardings=(None, cshard),
        donate_argnums=(3,),
    )
    return fn, (params_sds, ins["tokens"], ins["index"], cache_sds)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             remat: str = "auto", overrides: Optional[Dict] = None,
             tag: str = "", save: bool = True) -> Dict:
    spec = get_arch(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(arch, shape_name)
    if not ok:
        rec = dict(arch=arch, shape=shape_name, mesh=mesh_kind,
                   status="skip", reason=why)
        if save:
            _save(rec, arch, shape_name, mesh_kind, tag)
        return rec

    cfg = apply_overrides(spec.config, overrides or {})
    if remat == "auto":
        remat = "full" if shape.kind == "train" else "none"

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    rec: Dict[str, Any] = dict(
        arch=arch, shape=shape_name, mesh=mesh_kind, status="ok",
        chips=int(np.prod(mesh.devices.shape)), remat=remat,
        overrides=overrides or {}, params=active_params(cfg),
        model_flops=model_flops(cfg, shape),
    )
    try:
        with jax.sharding.set_mesh(mesh):
            fn, args = build_cell(cfg, shape, mesh, remat=remat)
            t0 = time.time()
            lowered = fn.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 2)
            t0 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t0, 2)

        ma = compiled.memory_analysis()
        rec["memory"] = dict(
            argument_bytes=int(ma.argument_size_in_bytes),
            output_bytes=int(ma.output_size_in_bytes),
            temp_bytes=int(ma.temp_size_in_bytes),
            alias_bytes=int(ma.alias_size_in_bytes),
            peak_device_bytes=int(ma.argument_size_in_bytes
                                  + ma.output_size_in_bytes
                                  + ma.temp_size_in_bytes
                                  - ma.alias_size_in_bytes),
        )
        ca = compiled.cost_analysis()
        # raw XLA numbers (scan bodies counted ONCE — recorded for
        # reference, not used for the roofline; see launch/hlo.py)
        rec["cost_raw"] = dict(
            flops=float(ca.get("flops", 0.0)),
            bytes_accessed=float(ca.get("bytes accessed", 0.0)),
        )
        txt = compiled.as_text()
        flat = hlo_mod.analyze(txt)
        rec["cost"] = dict(
            flops=flat["flops"],
            bytes_accessed=flat["traffic"],
        )
        rec["collectives"] = {
            k: dict(count=flat.get(f"coll:{k}:count", 0.0),
                    bytes=flat.get(f"coll:{k}:bytes", 0.0))
            for k in hlo_mod.COLLECTIVES}
        rec["collective_bytes"] = flat["collective_bytes"]
        rec["op_census"] = hlo_mod.op_census(txt)
        rec["trip_counts"] = hlo_mod.while_trip_counts(txt)[:12]
        rec["roofline"] = roofline_terms(rec)
    except Exception as e:  # record the failure — these are bugs to fix
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=20)
    if save:
        _save(rec, arch, shape_name, mesh_kind, tag)
    return rec


def roofline_terms(rec: Dict) -> Dict:
    """Three roofline terms in seconds (per device — cost_analysis and the
    compiled HLO are already the per-device SPMD module)."""
    t_compute = rec["cost"]["flops"] / PEAK_FLOPS
    t_memory = rec["cost"]["bytes_accessed"] / HBM_BW
    t_coll = rec["collective_bytes"] / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory),
        ("collective", t_coll), key=lambda kv: kv[1])[0]
    chips = rec["chips"]
    useful = rec["model_flops"] / chips
    return dict(
        t_compute=t_compute, t_memory=t_memory, t_collective=t_coll,
        dominant=dominant,
        model_flops_per_chip=useful,
        useful_flop_frac=(useful / rec["cost"]["flops"]
                          if rec["cost"]["flops"] else 0.0),
        # step-time lower bound if terms overlapped perfectly / not at all
        t_min=max(t_compute, t_memory, t_coll),
        t_sum=t_compute + t_memory + t_coll,
        # fraction of ideal (pure-compute of useful flops) achieved at t_min
        roofline_frac=(useful / PEAK_FLOPS) / max(
            max(t_compute, t_memory, t_coll), 1e-30),
    )


def _save(rec: Dict, arch: str, shape: str, mesh_kind: str, tag: str):
    os.makedirs(ARTIFACTS, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(
        ARTIFACTS, f"{arch}__{shape}__{mesh_kind}{suffix}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=float)
    return path


def summarize(rec: Dict) -> str:
    if rec["status"] == "skip":
        return f"{rec['arch']:>24} {rec['shape']:>12} {rec['mesh']:>7}  SKIP ({rec['reason'][:40]}...)"
    if rec["status"] == "fail":
        return f"{rec['arch']:>24} {rec['shape']:>12} {rec['mesh']:>7}  FAIL {rec['error'][:80]}"
    r = rec["roofline"]
    m = rec["memory"]["peak_device_bytes"] / 2**30
    return (f"{rec['arch']:>24} {rec['shape']:>12} {rec['mesh']:>7}  "
            f"mem/dev={m:6.2f}GiB flops={rec['cost']['flops']:.3e} "
            f"tc={r['t_compute']*1e3:8.2f}ms tm={r['t_memory']*1e3:8.2f}ms "
            f"tx={r['t_collective']*1e3:8.2f}ms dom={r['dominant']:>10} "
            f"roofline={r['roofline_frac']*100:5.1f}% "
            f"(lower {rec['lower_s']}s compile {rec['compile_s']}s)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--remat", default="auto")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    overrides = dict(kv.split("=", 1) for kv in args.set)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for a in list_archs():
            for s in SHAPES:
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    t0 = time.time()
    n_fail = 0
    for arch, shape in cells:
        for mk in meshes:
            rec = run_cell(arch, shape, mk, remat=args.remat,
                           overrides=overrides, tag=args.tag)
            print(summarize(rec), flush=True)
            n_fail += rec["status"] == "fail"
    print(f"done in {time.time() - t0:.0f}s, {n_fail} failures", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
