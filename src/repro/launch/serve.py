"""Serving launcher: batched requests against a small model.

``python -m repro.launch.serve --arch smollm-135m --requests 8`` spins up a
ServeEngine on the reduced config, feeds it a batch of prompts through the
diffusion scheduler (multi-replica placement simulated at host scale), and
reports throughput + scheduling metrics.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import get_arch
from repro.models import transformer
from repro.models.params import init_params
from repro.serve.engine import Request, ServeConfig, ServeEngine
from repro.serve.scheduler import DiffusionScheduler, Session


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=2)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.reduced
    params = init_params(transformer.model_specs(cfg), 0)

    sched = DiffusionScheduler(args.replicas)
    engines = [ServeEngine(cfg, params, ServeConfig(num_slots=args.slots))
               for _ in range(args.replicas)]

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(4, 12))
        sess = Session(uid=i, replica=0, tokens_per_s=1.0,
                       prefix_group=i % max(args.requests // 4, 1))
        r = sched.place_new(sess)
        engines[r].submit(Request(uid=i, prompt=prompt,
                                  max_new_tokens=args.max_new))
    info = sched.rebalance()
    done = []
    for e in engines:
        done += e.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print(f"scheduler: max/avg load {info.get('max_avg_load', 1):.3f}, "
          f"ext/int {info.get('ext_int_comm', 0):.3f}")
    for r in done[:4]:
        print(f"  req {r.uid}: {len(r.out)} tokens {r.out[:8]}...")


if __name__ == "__main__":
    main()
