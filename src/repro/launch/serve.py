"""Serving launcher: batched requests against a small model.

``python -m repro.launch.serve --arch smollm-135m --requests 8`` spins up a
ServeEngine on the reduced config, feeds it a batch of prompts through the
diffusion scheduler (multi-replica placement simulated at host scale), and
reports throughput + scheduling metrics.

``--fleet-replay N`` skips the model entirely and drives ``N`` synthetic
bursty multi-turn sessions through the scan-compiled serving replay
(``serve/replay.py`` — trigger decision and executed KV-slab exchange
inside one ``lax.scan``), reporting the balance/KV-traffic summary the
serving benchmark gates on.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def fleet_replay(args) -> None:
    from repro.serve import replay as sr

    w = sr.ServeWorkload(num_sessions=args.fleet_replay,
                         num_replicas=args.replicas)
    t0 = time.time()
    r = sr.run_serve_replay(w, steps=args.ticks, lb_every=10,
                            strategy=args.strategy)
    dt = time.time() - t0
    print(f"replayed {w.num_sessions} sessions x {args.ticks} ticks on "
          f"{w.num_replicas} replicas in {dt:.2f}s "
          f"({'scanned' if r.scanned else 'host'} path)")
    print(f"  rebalances {int(r.lb_fired.sum())}, moved KV "
          f"{r.total_moved_kv:.0f} bytes, p95 max/avg "
          f"{np.percentile(r.max_avg, 95):.3f}, prefix-local "
          f"{r.prefix_local.mean():.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--fleet-replay", type=int, default=0,
                    help="replay N synthetic sessions through "
                         "serve.replay instead of serving a model")
    ap.add_argument("--ticks", type=int, default=60)
    ap.add_argument("--strategy", default="diff-comm+predictive")
    args = ap.parse_args()

    if args.fleet_replay > 0:
        fleet_replay(args)
        return

    from repro.configs import get_arch
    from repro.models import transformer
    from repro.models.params import init_params
    from repro.serve.engine import Request, ServeConfig, ServeEngine
    from repro.serve.scheduler import DiffusionScheduler, Session

    spec = get_arch(args.arch)
    cfg = spec.reduced
    params = init_params(transformer.model_specs(cfg), 0)

    sched = DiffusionScheduler(args.replicas)
    engines = [ServeEngine(cfg, params, ServeConfig(num_slots=args.slots))
               for _ in range(args.replicas)]

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab_size, size=rng.integers(4, 12))
        sess = Session(uid=i, replica=0, tokens_per_s=1.0,
                       prefix_group=i % max(args.requests // 4, 1))
        r = sched.place_new(sess)
        engines[r].submit(Request(uid=i, prompt=prompt,
                                  max_new_tokens=args.max_new))
    info = sched.rebalance()
    done = []
    for e in engines:
        done += e.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s)")
    print(f"scheduler: max/avg load {info.get('max_avg_load', 1):.3f}, "
          f"ext/int {info.get('ext_int_comm', 0):.3f}, moved KV "
          f"{info.get('moved_kv_bytes', 0):.0f} bytes")
    for r in done[:4]:
        print(f"  req {r.uid}: {len(r.out)} tokens {r.out[:8]}...")


if __name__ == "__main__":
    main()
