"""Serving launcher: batched requests against a small model.

``python -m repro.launch.serve --arch smollm-135m --requests 8`` spins up a
ServeEngine on the reduced config, feeds it a batch of prompts through the
diffusion scheduler (multi-replica placement simulated at host scale), and
reports throughput + scheduling metrics.

``--fleet-replay N`` skips the model entirely and drives ``N`` synthetic
bursty multi-turn sessions through the scan-compiled serving replay
(``serve/replay.py`` — trigger decision and executed KV-slab exchange
inside one ``lax.scan``), reporting the balance/KV-traffic summary the
serving benchmark gates on.

Observability: every reported number flows through the
``repro.obs.metrics`` registry (``snapshot()`` is the single source the
log lines print from), ``--telemetry counters|full`` threads the
scan-carried StepRecord ring through the replay, ``--trace-out f.json``
exports the recorded run as a Chrome/Perfetto trace, and
``--profile-dir d`` wraps the run in ``jax.profiler.trace``.
"""
from __future__ import annotations

import argparse
import time

import numpy as np


def fleet_replay(args) -> None:
    from repro.distributed import compat
    from repro.obs import metrics, trace_export
    from repro.serve import replay as sr

    w = sr.ServeWorkload(num_sessions=args.fleet_replay,
                         num_replicas=args.replicas)
    t0 = time.time()
    with compat.profiler_trace(args.profile_dir):
        r = sr.run_serve_replay(w, steps=args.ticks, lb_every=10,
                                strategy=args.strategy,
                                telemetry=args.telemetry)
    metrics.gauge("serve/replay_seconds").set(time.time() - t0)
    metrics.counter("serve/sessions").inc(w.num_sessions)
    metrics.counter("serve/ticks").inc(args.ticks)
    metrics.counter("serve/rebalances").inc(int(r.lb_fired.sum()))
    metrics.counter("serve/moved_kv_bytes").inc(float(r.total_moved_kv))
    metrics.gauge("serve/p95_max_avg").set(
        float(np.percentile(r.max_avg, 95)))
    metrics.gauge("serve/prefix_local").set(float(r.prefix_local.mean()))
    s = metrics.snapshot()
    print(f"replayed {int(s['serve/sessions'])} sessions x "
          f"{int(s['serve/ticks'])} ticks on "
          f"{w.num_replicas} replicas in {s['serve/replay_seconds']:.2f}s "
          f"({'scanned' if r.scanned else 'host'} path)")
    print(f"  rebalances {int(s['serve/rebalances'])}, moved KV "
          f"{s['serve/moved_kv_bytes']:.0f} bytes, p95 max/avg "
          f"{s['serve/p95_max_avg']:.3f}, prefix-local "
          f"{s['serve/prefix_local']:.3f}")
    if r.telemetry is not None and args.trace_out:
        trace_export.export_chrome_trace(r.telemetry, path=args.trace_out,
                                         label="serve-replay")
        print(f"  wrote Chrome trace to {args.trace_out} "
              f"({len(r.telemetry.records)} steps recorded, "
              f"{r.telemetry.dropped} dropped)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--fleet-replay", type=int, default=0,
                    help="replay N synthetic sessions through "
                         "serve.replay instead of serving a model")
    ap.add_argument("--ticks", type=int, default=60)
    ap.add_argument("--strategy", default="diff-comm+predictive")
    ap.add_argument("--telemetry", default="off",
                    choices=("off", "counters", "full"),
                    help="scan-carried StepRecord telemetry level "
                         "(fleet replay)")
    ap.add_argument("--trace-out", default=None,
                    help="write the recorded run as a Chrome/Perfetto "
                         "trace JSON (needs --telemetry full)")
    ap.add_argument("--profile-dir", default=None,
                    help="wrap the run in jax.profiler.trace(DIR)")
    args = ap.parse_args()

    if args.fleet_replay > 0:
        fleet_replay(args)
        return

    from repro.configs import get_arch
    from repro.distributed import compat
    from repro.models import transformer
    from repro.models.params import init_params
    from repro.obs import metrics
    from repro.serve.engine import Request, ServeConfig, ServeEngine
    from repro.serve.scheduler import DiffusionScheduler, Session

    spec = get_arch(args.arch)
    cfg = spec.reduced
    params = init_params(transformer.model_specs(cfg), 0)

    sched = DiffusionScheduler(args.replicas)
    engines = [ServeEngine(cfg, params, ServeConfig(num_slots=args.slots))
               for _ in range(args.replicas)]

    rng = np.random.default_rng(0)
    t0 = time.time()
    with compat.profiler_trace(args.profile_dir):
        for i in range(args.requests):
            prompt = rng.integers(1, cfg.vocab_size,
                                  size=rng.integers(4, 12))
            sess = Session(uid=i, replica=0, tokens_per_s=1.0,
                           prefix_group=i % max(args.requests // 4, 1))
            r = sched.place_new(sess)
            engines[r].submit(Request(uid=i, prompt=prompt,
                                      max_new_tokens=args.max_new))
        info = sched.rebalance()
        done = []
        for e in engines:
            done += e.run_until_drained()
    metrics.gauge("serve/seconds").set(time.time() - t0)
    metrics.counter("serve/requests").inc(len(done))
    metrics.counter("serve/tokens").inc(sum(len(r.out) for r in done))
    metrics.gauge("serve/max_avg_load").set(info.get("max_avg_load", 1))
    metrics.gauge("serve/ext_int_comm").set(info.get("ext_int_comm", 0))
    metrics.counter("serve/moved_kv_bytes").inc(
        float(info.get("moved_kv_bytes", 0)))
    s = metrics.snapshot()
    dt, toks = s["serve/seconds"], s["serve/tokens"]
    print(f"served {int(s['serve/requests'])} requests, {int(toks)} "
          f"tokens in {dt:.2f}s ({toks/dt:.1f} tok/s)")
    print(f"scheduler: max/avg load {s['serve/max_avg_load']:.3f}, "
          f"ext/int {s['serve/ext_int_comm']:.3f}, moved KV "
          f"{s['serve/moved_kv_bytes']:.0f} bytes")
    for r in done[:4]:
        print(f"  req {r.uid}: {len(r.out)} tokens {r.out[:8]}...")


if __name__ == "__main__":
    main()
