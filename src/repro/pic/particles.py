"""PIC PRK particle initialization (paper §VI.A).

Distribution modes from the PRK benchmark [17]:
  GEOMETRIC   — column i holds ~A·ρ^i particles (exponential skew, the
                paper's evaluation mode), rows uniform;
  SINUSOIDAL  — density ∝ cos²(πi/L);
  LINEAR      — density a linear ramp along x;
  PATCH       — uniform inside a sub-rectangle.

Determinism construction: particles start at cell centers with zero
horizontal velocity; the particle charge
    q_p = (2k+1) · 2 · m / (GEOM_FACTOR · Q) · sign(column)
yields horizontal acceleration a = ±2(2k+1), so displacement alternates
a/2 = (2k+1) cells every step (odd ⇒ the column-parity force sign flips,
velocity returns to 0 every other step).  Vertical: constant speed
``vy0`` cells/step, no vertical force at cell centers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.pic.grid import GEOM_FACTOR


@dataclasses.dataclass
class Particles:
    x: np.ndarray
    y: np.ndarray
    vx: np.ndarray
    vy: np.ndarray
    q: np.ndarray

    @property
    def n(self) -> int:
        return self.x.shape[0]


def _cells_from_density(col_density: np.ndarray, L: int, n: int, rng):
    """Sample n (col, row) cells: columns ∝ density, rows uniform."""
    p = col_density / col_density.sum()
    cols = rng.choice(L, size=n, p=p)
    rows = rng.integers(0, L, size=n)
    return cols, rows


def initialize(
    mode: str,
    L: int,
    n: int,
    *,
    k: int = 1,
    vy0: float = 1.0,
    rho: float = 0.9,
    Q: float = 1.0,
    mass: float = 1.0,
    patch=(0.25, 0.25, 0.5, 0.5),
    seed: int = 0,
) -> Particles:
    rng = np.random.default_rng(seed)
    mode = mode.upper()
    i = np.arange(L)
    if mode == "GEOMETRIC":
        density = rho ** i
    elif mode == "SINUSOIDAL":
        density = np.cos(np.pi * i / L) ** 2 + 1e-9
    elif mode == "LINEAR":
        density = 1.0 - 0.9 * i / L
    elif mode == "PATCH":
        x0, y0, w, h = patch
        density = ((i >= x0 * L) & (i < (x0 + w) * L)).astype(float) + 1e-12
    else:
        raise ValueError(f"unknown distribution mode {mode!r}")

    cols, rows = _cells_from_density(density, L, n, rng)
    if mode == "PATCH":
        rows = rng.integers(int(patch[1] * L), int((patch[1] + patch[3]) * L),
                            size=n)
    x = cols + 0.5
    y = rows + 0.5
    sign = np.where(cols % 2 == 0, 1.0, -1.0)
    qp = (2 * k + 1) * 2.0 * mass / (GEOM_FACTOR * Q) * sign
    return Particles(
        x=x.astype(np.float32),
        y=y.astype(np.float32),
        vx=np.zeros(n, np.float32),
        vy=np.full(n, vy0, np.float32),
        q=qp.astype(np.float32),
    )
