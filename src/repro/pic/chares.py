"""Chare (object) decomposition of the PIC grid — paper §VI.

The L×L cell grid is tiled into cx×cy chares.  Initial chare→PE mappings:
  striped — column-major round robin (the paper's evaluation choice: worse
            locality, makes the column-wise imbalance pattern visible);
  quad    — contiguous 2D tiles of chares per PE (better locality).

The chare communication graph models PRK particle traffic: a chare sends its
particles east at (2k+1) cells/step and north at vy cells/step, so edge
weights are the expected particle-handoff bytes over one LB period.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import comm_graph


def chare_shape(L: int, cx: int, cy: int):
    """Cells per chare (fractional when the chare grid doesn't divide L —
    the paper's own setup is 12×12 chares on a 1000² grid, ~83×83 cells)."""
    return L / cx, L / cy


def chare_of(x, y, L: int, cx: int, cy: int):
    """Chare id (row-major over (cx, cy)) for particle positions."""
    w, h = chare_shape(L, cx, cy)
    ci = np.minimum(np.asarray(x, np.float64) // w, cx - 1).astype(np.int32)
    cj = np.minimum(np.asarray(y, np.float64) // h, cy - 1).astype(np.int32)
    return ci * cy + cj


def chare_of_device(x, y, L: int, cx: int, cy: int):
    """jnp ``chare_of`` — traceable, keeps particles device-resident."""
    w, h = chare_shape(L, cx, cy)
    ci = jnp.minimum(jnp.floor_divide(x, jnp.float32(w)),
                     cx - 1).astype(jnp.int32)
    cj = jnp.minimum(jnp.floor_divide(y, jnp.float32(h)),
                     cy - 1).astype(jnp.int32)
    return ci * cy + cj


def initial_mapping(cx: int, cy: int, num_pes: int, mode: str = "striped"):
    """(cx*cy,) chare→PE assignment."""
    n = cx * cy
    if mode == "striped":
        # column-major order over (ci, cj): all cj for ci=0, then ci=1, ...
        order = np.arange(n)  # chare id already row-major in (ci, cj)
        return (order * num_pes // n).astype(np.int32)
    if mode == "quad":
        px = int(np.sqrt(num_pes))
        while num_pes % px:
            px -= 1
        py = num_pes // px
        ci = np.arange(cx)[:, None] * px // cx
        cj = np.arange(cy)[None, :] * py // cy
        return (ci * py + cj).astype(np.int32).reshape(-1)
    raise ValueError(f"unknown mapping {mode!r}")


def chare_coords(cx: int, cy: int, L: int):
    """(cx*cy, 2) tile-center coordinates (for the coordinate variant)."""
    w, h = chare_shape(L, cx, cy)
    ci, cj = np.meshgrid(np.arange(cx), np.arange(cy), indexing="ij")
    return np.stack(
        [(ci.ravel() + 0.5) * w, (cj.ravel() + 0.5) * h], axis=1
    ).astype(np.float32)


def edge_structure(cx: int, cy: int) -> np.ndarray:
    """(2·cx·cy, 2) static east+north edge pairs of the chare torus."""
    n = cx * cy
    ci = np.arange(n) // cy
    cj = np.arange(n) % cy
    east = ((ci + 1) % cx) * cy + cj
    north = ci * cy + (cj + 1) % cy
    return np.concatenate(
        [np.stack([np.arange(n), east], 1), np.stack([np.arange(n), north], 1)]
    ).astype(np.int32)


def edge_bytes_device(
    chare_loads,                 # (cx*cy,) — np or traced jnp
    *,
    L: int, cx: int, cy: int, k: int, vy0: float, lb_period: int,
    bytes_per_particle: float = 48.0,
):
    """(2·cx·cy,) expected handoff bytes for :func:`edge_structure` order.

    Traceable: pure jnp in the loads; all geometry factors are static."""
    w, h = chare_shape(L, cx, cy)
    speed_x = 2 * k + 1
    frac_x = min(1.0, speed_x * lb_period / w)
    frac_y = min(1.0, abs(vy0) * lb_period / h)
    eps = 1e-3 * bytes_per_particle  # stencil adjacency floor
    loads = jnp.asarray(chare_loads, jnp.float32)
    we = loads * frac_x * bytes_per_particle + eps
    wn = loads * frac_y * bytes_per_particle + eps
    return jnp.concatenate([we, wn]).astype(jnp.float32)


def build_problem(
    chare_loads,                # (cx*cy,) particle counts (or measured cost)
    assignment,                 # (cx*cy,) chare→PE
    *,
    L: int, cx: int, cy: int, num_pes: int,
    k: int, vy0: float, lb_period: int,
    bytes_per_particle: float = 48.0,
) -> comm_graph.LBProblem:
    """LBProblem with chares as objects and particle-flux comm edges.

    Trace-safe: ``chare_loads`` / ``assignment`` may be traced jnp arrays
    (the scanned PIC driver rebuilds the problem on device every LB step);
    the edge structure and coordinates are static."""
    ebytes = edge_bytes_device(
        chare_loads, L=L, cx=cx, cy=cy, k=k, vy0=vy0, lb_period=lb_period,
        bytes_per_particle=bytes_per_particle)
    return comm_graph.make_problem(
        loads=jnp.maximum(jnp.asarray(chare_loads, jnp.float32), 1e-3),
        assignment=assignment,
        edges=edge_structure(cx, cy),
        edge_bytes=ebytes,
        num_nodes=num_pes,
        coords=chare_coords(cx, cy, L),
    )
