"""PIC PRK benchmark (paper §VI) in JAX."""
from repro.pic.chares import build_problem, chare_of, initial_mapping
from repro.pic.driver import CostModel, PICConfig, PICResult, run
from repro.pic.grid import alternating_grid
from repro.pic.particles import Particles, initialize

__all__ = [
    "CostModel", "PICConfig", "PICResult", "Particles",
    "alternating_grid", "build_problem", "chare_of", "initial_mapping",
    "initialize", "run",
]
