"""PIC PRK charge grid (paper §VI).

L×L grid points carry fixed electromagnetic charges that alternate sign by
column — the PRK construction that, combined with the particle-charge
formula in particles.py, makes every particle's horizontal displacement
exactly (2k+1) cells per time step.
"""
from __future__ import annotations

import numpy as np

# Geometry factor: a particle at a cell center feels a net horizontal
# Coulomb force of GEOM_FACTOR * q_p * Q from the four corners (two +Q·s,
# two -Q·s at distance sqrt(0.5); vertical components cancel).
GEOM_FACTOR = 4.0 * np.sqrt(2.0)


def alternating_grid(L: int, Q: float = 1.0) -> np.ndarray:
    """(L, L) charges: +Q in even columns, -Q in odd columns (PRK)."""
    cols = np.where(np.arange(L) % 2 == 0, Q, -Q).astype(np.float32)
    return np.broadcast_to(cols[:, None], (L, L)).copy()
