"""PIC PRK end-to-end driver with integrated load balancing (paper §VI).

Reproduces the paper's evaluation loop: particles advance each step (Pallas
push kernel), chare loads are measured (histogram kernel), and the
chare→PE assignment is rebalanced by any registered strategy whenever the
online trigger fires (``PICConfig.trigger`` — fixed ``lb_every`` cadence
by default, adaptive threshold/predictive policies via
``runtime.triggers``).  A fired rebalance is **executed**, not just
counted: particle payload is relocated between PE-owned slot regions
(``runtime.migrate`` bucketed gather, device-resident in the scanned
path) and the migration volume is measured from that exchange.  Records
the paper's metrics per step:

  * max/avg particles per PE            (Fig 3, Fig 4)
  * external/internal comm bytes        (particle handoffs crossing PEs)
  * migration volume at LB steps        (measured from the executed
    exchange; ``final_x/final_y`` are restored to particle-id order)
  * modeled step time (compute + comm + LB amortization) for the
    strong-scaling study (Fig 5/6) — see ``CostModel``; wall-clock
    multi-node timing needs real nodes, the model is calibrated per-term
    and reported as such in EXPERIMENTS.md.

Two execution paths:

  * **scanned** (default for jittable strategies) — particles, loads and
    the assignment stay device-resident for the whole run: one ``step_fn``
    is scanned in chunks of ``scan_chunk`` steps, per-step metrics
    accumulate in the scan outputs, and the host sees data only at chunk
    boundaries.  LB planning runs inside the scan via ``lax.cond`` on the
    step index (the fused ``core.engine`` planner).
  * **host loop** — the legacy eager path, used for NumPy-only baseline
    strategies (greedy, metis, ...) or when ``cfg.scan=False``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import api as core_api
from repro.core import engine as core_engine
from repro.core import hierarchical
from repro.obs import telemetry as obs_telemetry
from repro.kernels.histogram.ops import histogram
from repro.kernels.pic_push.ops import pic_push
from repro.pic import chares as ch
from repro.pic.grid import alternating_grid
from repro.pic.particles import initialize
from repro.runtime import migrate as rt_migrate
from repro.runtime import triggers as rt_triggers


@dataclasses.dataclass
class PICConfig:
    L: int = 1000
    n_particles: int = 100_000
    steps: int = 100
    k: int = 2
    rho: float = 0.9
    vy0: float = 1.0
    mode: str = "GEOMETRIC"
    cx: int = 12
    cy: int = 12
    num_pes: int = 4
    mapping: str = "striped"
    lb_every: int = 10
    strategy: str = "diff-comm"
    strategy_kwargs: Optional[Dict] = None
    # online rebalancing policy (runtime.triggers): None resolves to the
    # strategy's registered trigger and then to the legacy fixed
    # ``lb_every`` cadence (bit-for-bit the pre-runtime driver); "every" /
    # "threshold" / "predictive" or a Trigger instance select adaptive
    # policies, decided per step on device from the pre-LB PE loads.
    # Every LB step *executes* the plan: particle payload is relocated
    # between PE-owned slot regions (runtime.migrate) and
    # ``PICResult.migrated_bytes`` is measured from that exchange.
    trigger: Optional[object] = None
    # sweeps per fused diffusion block inside the planner (stage 2); None
    # keeps the engine default.  Plumbed into the diff-* strategies only —
    # the scanned path's lax.cond-gated planning then runs the chunked
    # virtual-LB loop (kernels/diffusion fused kernel on TPU).
    sweep_chunk: Optional[int] = None
    # two-level placement (paper §III.D): when set, every step also
    # records max/avg particles per *global PE* ((num_pes × T) threads,
    # chare→thread via the device-resident within-node LPT) in
    # PICResult.thread_max_avg — computed inside the scan, no host trip.
    threads_per_node: Optional[int] = None
    # mesh-sharded replay (distributed/replay_shard.py): the whole run —
    # push, trigger, planning, executed particle exchange — inside ONE
    # shard_map over the 1-D "lb" device mesh, particle slabs
    # row-sharded, bit-for-bit the single-device scanned path.  Needs a
    # jittable strategy; the mesh auto-sizes to the largest device count
    # dividing both n_particles and num_pes (replay_shards overrides).
    # replay_capacity is the static per-shard slot budget for the in-scan
    # ring all-to-all (None = worst-case n_particles, always safe; an
    # undersized budget raises ValueError after the run rather than
    # dropping payload).
    sharded_replay: bool = False
    replay_shards: Optional[int] = None
    replay_capacity: Optional[int] = None
    # resilience (sharded replay only; runtime/resilience.py): `faults`
    # injects a FaultSchedule of die/slow/recover shard events honored
    # inside the scan — health-masked trigger stats and planning, forced
    # evacuation fires, validate_plan-guarded adoption.  `on_overflow`
    # picks the exchange's degradation mode when a fired plan exceeds
    # replay_capacity: "strict" fails loud (the ValueError above),
    # "spill" clamps per-shard inflow, keeps overflow particles on their
    # source shard and retries them at the next fire (PICResult.deferred
    # records the backlog).  Defaults add nothing to the trace.
    faults: Optional[object] = None
    on_overflow: str = "strict"
    # scan-carried StepRecord telemetry (obs/telemetry.py): a
    # TelemetryConfig, a level string, or None.  Off/None adds nothing to
    # the traced program (bit-for-bit the untelemetered driver).
    telemetry: Optional[object] = None
    bytes_per_particle: float = 48.0
    seed: int = 0
    use_kernel: Optional[bool] = None  # None = auto (Pallas on TPU)
    scan: Optional[bool] = None        # None = auto (scan iff jittable)
    scan_chunk: int = 50               # steps per device-resident chunk


@dataclasses.dataclass
class CostModel:
    """Per-term model for simulated strong scaling (Fig 5).

    t_particle — seconds per particle push on one PE;
    t_byte     — seconds per byte crossing a node boundary;
    t_lb       — measured strategy planning time (filled by the driver).
      Diffusion planning is a *distributed* algorithm (O(K·iters) work per
      node); this container executes it serially, so its measured wall
      time is divided by num_pes.  Centralized planners (greedy*, metis*)
      are charged full wall time — matching their Charm++ deployments.
    """
    t_particle: float = 2.0e-8
    # calibrated so comm ≈ compute at the paper's 8-node operating point
    # (Fig 6 shows communication and computation time of the same
    # magnitude): ~50 MB/s effective per-PE boundary bandwidth (many small
    # particle messages on a shared NIC), not the wire peak.
    t_byte: float = 2.0e-8

    def lb_seconds(self, wall: float, strategy: str, num_pes: int) -> float:
        if strategy.startswith("diff"):
            return wall / max(num_pes, 1)
        return wall


@dataclasses.dataclass
class PICResult:
    max_avg: np.ndarray        # (T,) max/avg particles per PE
    ext_bytes: np.ndarray      # (T,) external comm bytes per step
    int_bytes: np.ndarray      # (T,)
    migrations: np.ndarray     # (T,) fraction of chares moved (LB steps)
    migrated_bytes: np.ndarray # (T,) particle bytes moved by LB
    lb_seconds: float
    step_seconds: np.ndarray   # (T,) modeled time per step
    final_x: np.ndarray
    final_y: np.ndarray
    scanned: bool = False
    wall_seconds: float = 0.0  # end-to-end wall time of the replay loop
    # (T,) max/avg load over global PEs under the two-level (node,
    # thread) placement; None unless PICConfig.threads_per_node was set
    thread_max_avg: Optional[np.ndarray] = None
    # (T,) 1.0 where the trigger fired and a rebalance was executed
    lb_steps: Optional[np.ndarray] = None
    # resilient sharded replay only (else None): (T,) 0/1 fired plans
    # rejected by the validate_plan guardrail, and (T,) particles the
    # spill exchange deferred on their source shard at each step
    plan_rejected: Optional[np.ndarray] = None
    deferred: Optional[np.ndarray] = None
    # StepRecord ring snapshot when PICConfig.telemetry was enabled
    telemetry: Optional[obs_telemetry.TelemetrySnapshot] = None

    def summary(self) -> Dict[str, float]:
        # mean ext/int ratio over steps with internal traffic; all-external
        # steps use the finite metrics sentinel, no-comm steps read 0
        from repro.core.metrics import EXT_INT_ALL_EXTERNAL

        ratio = np.where(
            self.int_bytes > 0,
            self.ext_bytes / np.where(self.int_bytes > 0,
                                      self.int_bytes, 1.0),
            np.where(self.ext_bytes > 0, EXT_INT_ALL_EXTERNAL, 0.0))
        return dict(
            mean_max_avg=float(self.max_avg.mean()),
            mean_ext_bytes=float(self.ext_bytes.mean()),
            mean_ext_int=float(ratio.mean()),
            total_migrated_bytes=float(self.migrated_bytes.sum()),
            lb_seconds=float(self.lb_seconds),
            modeled_time=float(self.step_seconds.sum()),
            wall_seconds=float(self.wall_seconds),
        )


def _lb_amort(cfg: PICConfig, trig) -> int:
    """Steps one plan's cost is amortized over in the modeled step time:
    the fixed cadence serves exactly ``lb_every`` steps per plan (the
    legacy accounting); an adaptive trigger's plan serves an interval
    known only after the fact, so its cost is charged where it fires."""
    if isinstance(trig, rt_triggers.EveryTrigger):
        return max(cfg.lb_every, 1)
    return 1


def _resolve_trigger(cfg: PICConfig):
    """Canonical trigger for a config (the strategy's registered policy
    backs ``cfg.trigger=None``; unknown strategies keep the legacy
    cadence)."""
    return rt_triggers.resolve_for_strategy(
        cfg.trigger, lb_every=cfg.lb_every, strategy=cfg.strategy)


def run(cfg: PICConfig, cost: CostModel = CostModel()) -> PICResult:
    if cfg.sweep_chunk is not None and cfg.strategy.startswith("diff"):
        cfg = dataclasses.replace(
            cfg, sweep_chunk=None,
            strategy_kwargs={**(cfg.strategy_kwargs or {}),
                             "sweep_chunk": cfg.sweep_chunk})
    if cfg.sharded_replay:
        if cfg.scan is False:
            raise ValueError(
                "sharded_replay is a scanned path; drop scan=False")
        from repro.distributed import replay_shard

        return replay_shard.run_pic_sharded(cfg, cost)
    if cfg.faults is not None and not getattr(cfg.faults, "empty", False):
        raise ValueError(
            "fault injection (PICConfig.faults) is a sharded-replay "
            "feature; set sharded_replay=True")
    if cfg.on_overflow != "strict":
        raise ValueError(
            "on_overflow='spill' degrades the sharded replay exchange; "
            "set sharded_replay=True (the single-device paths have no "
            "capacity to overflow)")
    use_scan = cfg.scan
    if use_scan and not core_engine.get_strategy(cfg.strategy).jittable:
        raise ValueError(
            f"strategy {cfg.strategy!r} is not jittable; the scanned PIC "
            "driver needs a traceable plan_fn (use scan=False/None or a "
            "diff-* / none strategy)")
    if use_scan is None:
        try:
            use_scan = core_engine.get_strategy(cfg.strategy).jittable
        except KeyError:
            use_scan = False
    tel = obs_telemetry.resolve(cfg.telemetry)
    tel = tel if tel.enabled else None
    if use_scan:
        return _run_scanned(cfg, cost, tel)
    return _run_host(cfg, cost, tel)


# ------------------------------------------------------------ scanned path --


@functools.lru_cache(maxsize=32)
def _chunk_runner(
    L: int, cx: int, cy: int, num_pes: int, k: int, vy0: float,
    lb_every: int, strategy: str, kw_items: tuple, bpp: float,
    use_kernel: Optional[bool], chunk_len: int,
    threads_per_node: Optional[int] = None,
    trig=None, tel=None,
):
    """Compiled ``lax.scan`` over ``chunk_len`` device-resident PIC steps."""
    n_chares = cx * cy
    grid_q = jnp.asarray(alternating_grid(L))
    trig = trig or rt_triggers.resolve(None, lb_every=lb_every)
    lb_on = strategy != "none" and not trig.never
    plan = (core_engine.get_strategy(strategy).bind(**dict(kw_items))
            if lb_on else None)
    tkind = obs_telemetry.trigger_kind(trig) if tel else 0

    def step(carry, t):
        if tel:
            x, y, vx, vy, q, chare_id, assignment, perm, tstate, \
                obs_state = carry
        else:
            x, y, vx, vy, q, chare_id, assignment, perm, tstate = carry
        xn, yn, vxn, vyn = pic_push(grid_q, x, y, vx, vy, q, L=L,
                                    use_kernel=use_kernel)
        new_chare = ch.chare_of_device(xn, yn, L, cx, cy)
        # particle handoffs: chare changed → bytes move; PE boundary → ext
        moved = new_chare != chare_id
        src_pe = assignment[chare_id]
        dst_pe = assignment[new_chare]
        ext = (moved & (src_pe != dst_pe)).sum().astype(jnp.float32) * bpp
        intra = (moved & (src_pe == dst_pe)).sum().astype(jnp.float32) * bpp

        loads = histogram(new_chare, jnp.ones_like(xn), C=n_chares,
                          use_kernel=use_kernel)
        pe_loads = jax.ops.segment_sum(loads, assignment,
                                       num_segments=num_pes)
        pe_max = pe_loads.max()
        ma = pe_max / (pe_loads.mean() + 1e-30)

        if lb_on:
            mx, av, tot = rt_triggers.load_stats(loads, assignment,
                                                 num_pes)
            do, tstate = trig.decide(tstate, t, mx, av, tot)

            def do_plan(args):
                loads_, assignment_ = args
                problem = ch.build_problem(
                    loads_, assignment_, L=L, cx=cx, cy=cy,
                    num_pes=num_pes, k=k, vy0=vy0, lb_period=lb_every,
                    bytes_per_particle=bpp)
                a2, stats = plan(problem)
                return a2, jnp.asarray(stats.diffusion_iters, jnp.float32)

            new_assignment, sweeps = jax.lax.cond(
                do, do_plan,
                lambda a: (a[1].astype(jnp.int32), jnp.float32(0.0)),
                (loads, assignment))
            delta = new_assignment != assignment
            migf = jnp.where(
                do, jnp.mean(delta.astype(jnp.float32)), 0.0)

            # execute the plan: relocate particle payload between the
            # PE-owned slot regions (bucketed gather — runtime.migrate);
            # migrated_bytes is measured from this exchange, not modeled
            owner_old = jnp.take(assignment, new_chare)
            owner_new = jnp.take(new_assignment, new_chare)

            def do_move(args):
                outs, man = rt_migrate.build_and_apply(
                    owner_old, owner_new, args, num_nodes=num_pes)
                return outs, man.moved_count

            (xn, yn, vxn, vyn, q, new_chare, perm), moved_n = jax.lax.cond(
                do, do_move, lambda args: (args, jnp.int32(0)),
                (xn, yn, vxn, vyn, q, new_chare, perm))
            # feed the executed exchange back (measured predictive gate):
            # load units are particles, matching the trigger's load stats
            tstate = trig.observe(tstate, moved_n.astype(jnp.float32), do)
            migb = moved_n.astype(jnp.float32) * bpp
            fired = do.astype(jnp.float32)
            assignment = new_assignment
        else:
            migf = jnp.float32(0.0)
            migb = jnp.float32(0.0)
            fired = jnp.float32(0.0)
            sweeps = jnp.float32(0.0)

        if threads_per_node:
            thr = hierarchical.lpt_threads(
                loads, assignment, num_nodes=num_pes,
                threads_per_node=threads_per_node)
            tl = hierarchical.thread_loads(
                loads, assignment, thr, num_nodes=num_pes,
                threads_per_node=threads_per_node)
            tma = (tl.max() / (tl.mean() + 1e-30)).astype(jnp.float32)
        else:
            tma = jnp.float32(0.0)

        ys = (ma, pe_max, ext, intra, migf, migb, tma, fired)
        if tel:
            obs_state = obs_telemetry.record(
                obs_state, tel, t=t,
                node_loads=jax.ops.segment_sum(loads, assignment,
                                               num_segments=num_pes),
                fired=fired, trigger_kind=tkind, sweeps=sweeps,
                moved_items=migb / bpp, moved_bytes=migb)
            return (xn, yn, vxn, vyn, q, new_chare, assignment, perm,
                    tstate, obs_state), ys
        return (xn, yn, vxn, vyn, q, new_chare, assignment, perm,
                tstate), ys

    def run_chunk(carry, ts):
        return jax.lax.scan(step, carry, ts)

    return jax.jit(run_chunk)


def _run_scanned(cfg: PICConfig, cost: CostModel, tel=None) -> PICResult:
    p = initialize(cfg.mode, cfg.L, cfg.n_particles, k=cfg.k, vy0=cfg.vy0,
                   rho=cfg.rho, seed=cfg.seed)
    x, y = jnp.asarray(p.x), jnp.asarray(p.y)
    vx, vy = jnp.asarray(p.vx), jnp.asarray(p.vy)
    q = jnp.asarray(p.q)
    assignment = jnp.asarray(
        ch.initial_mapping(cfg.cx, cfg.cy, cfg.num_pes, cfg.mapping),
        jnp.int32)
    chare_id = ch.chare_of_device(x, y, cfg.L, cfg.cx, cfg.cy)
    n_chares = cfg.cx * cfg.cy

    kw_items = tuple(sorted((cfg.strategy_kwargs or {}).items()))
    trig = _resolve_trigger(cfg)
    lb_on = cfg.strategy != "none" and not trig.never

    # LB planning cost for the CostModel: the scanned path fuses planning
    # into the step executable, so per-call wall time is measured once on
    # the initial snapshot (post-compile) and charged at every LB step —
    # matching the legacy host path's per-call perf_counter semantics.
    lb_est = 0.0
    if lb_on:
        loads0 = histogram(chare_id, jnp.ones_like(x), C=n_chares,
                           use_kernel=cfg.use_kernel)
        problem0 = ch.build_problem(
            loads0, assignment, L=cfg.L, cx=cfg.cx, cy=cfg.cy,
            num_pes=cfg.num_pes, k=cfg.k, vy0=cfg.vy0,
            lb_period=cfg.lb_every,
            bytes_per_particle=cfg.bytes_per_particle)
        strat = core_engine.get_strategy(cfg.strategy)
        strat.run(problem0, **dict(kw_items))          # warm the compile
        lb_est = strat.run(problem0, **dict(kw_items)).info["plan_seconds"]

    T = cfg.steps
    chunk = max(1, min(cfg.scan_chunk, T))
    carry = (x, y, vx, vy, q, chare_id, assignment,
             jnp.arange(cfg.n_particles, dtype=jnp.int32),
             trig.init_state())
    if tel:
        carry = carry + (obs_telemetry.init_state(tel, cfg.num_pes),)
    ys_host = []
    t_start = time.perf_counter()
    for s in range(0, T, chunk):
        n = min(chunk, T - s)
        runner = _chunk_runner(
            cfg.L, cfg.cx, cfg.cy, cfg.num_pes, cfg.k, cfg.vy0,
            cfg.lb_every, cfg.strategy, kw_items, cfg.bytes_per_particle,
            cfg.use_kernel, n, cfg.threads_per_node, trig, tel)
        carry, ys = runner(carry, jnp.arange(s, s + n))
        ys_host.append(jax.device_get(ys))   # host transfer per chunk only
    wall = time.perf_counter() - t_start

    ma, pe_max, ext_b, int_b, mig, mig_bytes, tma, fired = (
        np.concatenate([np.asarray(c[i], np.float64) for c in ys_host])
        for i in range(8))

    lb_steps = fired > 0
    lb_s_t = np.where(lb_steps, lb_est, 0.0)
    step_s = (
        pe_max * cost.t_particle
        + (ext_b + mig_bytes) * cost.t_byte
        + np.array([cost.lb_seconds(s_, cfg.strategy, cfg.num_pes)
                    for s_ in lb_s_t]) / _lb_amort(cfg, trig)
    )
    # the carry holds slot-ordered particles (bucketed by owning PE);
    # report them in original particle-id order, undoing the exchanges
    perm = np.asarray(carry[7])
    xs, ys_ = np.asarray(carry[0]), np.asarray(carry[1])
    fx, fy = np.empty_like(xs), np.empty_like(ys_)
    fx[perm], fy[perm] = xs, ys_
    return PICResult(ma, ext_b, int_b, mig, mig_bytes,
                     float(lb_est * lb_steps.sum()), step_s, fx, fy,
                     scanned=True, wall_seconds=wall,
                     thread_max_avg=(tma if cfg.threads_per_node else None),
                     lb_steps=fired,
                     telemetry=(obs_telemetry.snapshot(carry[9], tel)
                                if tel else None))


# --------------------------------------------------------------- host loop --


def _run_host(cfg: PICConfig, cost: CostModel, tel=None) -> PICResult:
    grid_q = jnp.asarray(alternating_grid(cfg.L))
    p = initialize(cfg.mode, cfg.L, cfg.n_particles, k=cfg.k, vy0=cfg.vy0,
                   rho=cfg.rho, seed=cfg.seed)
    x, y = jnp.asarray(p.x), jnp.asarray(p.y)
    vx, vy = jnp.asarray(p.vx), jnp.asarray(p.vy)
    q = jnp.asarray(p.q)

    n_chares = cfg.cx * cfg.cy
    assignment = ch.initial_mapping(cfg.cx, cfg.cy, cfg.num_pes, cfg.mapping)
    chare_id = np.asarray(ch.chare_of(p.x, p.y, cfg.L, cfg.cx, cfg.cy))
    perm = np.arange(cfg.n_particles, dtype=np.int32)

    trig = _resolve_trigger(cfg)
    lb_on = cfg.strategy != "none" and not trig.never
    tstate = trig.init_state()

    T = cfg.steps
    ma = np.zeros(T)
    ext_b = np.zeros(T)
    int_b = np.zeros(T)
    mig = np.zeros(T)
    mig_bytes = np.zeros(T)
    tma = np.zeros(T)
    step_s = np.zeros(T)
    fired = np.zeros(T)
    lb_seconds = 0.0
    obs_state = (obs_telemetry.init_state(tel, cfg.num_pes)
                 if tel else None)
    tkind = obs_telemetry.trigger_kind(trig) if tel else 0

    t_start = time.perf_counter()
    for t in range(T):
        xn, yn, vx, vy = pic_push(grid_q, x, y, vx, vy, q, L=cfg.L,
                                  use_kernel=cfg.use_kernel)
        new_chare = np.asarray(
            ch.chare_of(np.asarray(xn), np.asarray(yn), cfg.L, cfg.cx, cfg.cy)
        )
        # particle handoffs: chare changed → bytes move; PE boundary → external
        moved = new_chare != chare_id
        src_pe = assignment[chare_id[moved]]
        dst_pe = assignment[new_chare[moved]]
        ext = float((src_pe != dst_pe).sum()) * cfg.bytes_per_particle
        intra = float((src_pe == dst_pe).sum()) * cfg.bytes_per_particle
        x, y, chare_id = xn, yn, new_chare

        loads = np.asarray(
            histogram(jnp.asarray(chare_id), jnp.ones(cfg.n_particles),
                      C=n_chares, use_kernel=cfg.use_kernel)
        )
        pe_loads = np.bincount(assignment, weights=loads,
                               minlength=cfg.num_pes)
        ma[t] = pe_loads.max() / (pe_loads.mean() + 1e-30)
        ext_b[t], int_b[t] = ext, intra

        lb_s = 0.0
        do = False
        if lb_on:
            if isinstance(trig, rt_triggers.EveryTrigger):
                # fixed cadence ignores the stats: legacy predicate,
                # no per-step device trip
                do = t > 0 and t % trig.every == 0
            else:
                # identical expression graph to the scanned path (f32
                # stats + jnp decide), so adaptive triggers fire on the
                # same steps
                mx, av, tot = rt_triggers.load_stats_jit(
                    jnp.asarray(loads, jnp.float32),
                    jnp.asarray(assignment, jnp.int32), cfg.num_pes)
                d, tstate = trig.decide(tstate, jnp.int32(t), mx, av, tot)
                do = bool(d)
        if do:
            problem = ch.build_problem(
                loads, assignment, L=cfg.L, cx=cfg.cx, cy=cfg.cy,
                num_pes=cfg.num_pes, k=cfg.k, vy0=cfg.vy0,
                lb_period=cfg.lb_every,
                bytes_per_particle=cfg.bytes_per_particle,
            )
            t0 = time.perf_counter()
            plan = core_api.STRATEGIES[cfg.strategy](
                problem, **(cfg.strategy_kwargs or {})
            )
            lb_s = time.perf_counter() - t0
            lb_seconds += lb_s
            new_assignment = np.asarray(plan.assignment)
            moved_chares = new_assignment != assignment
            mig[t] = float(moved_chares.mean())
            fired[t] = 1.0

            # execute the plan: bucket particles into PE-owned slot
            # regions; migrated bytes measured from the exchange
            # shared manifest path (runtime.migrate) — the identical
            # permutation code the scanned driver runs, so host and
            # scanned replay share one parity surface
            owner_old = assignment[chare_id]
            owner_new = new_assignment[chare_id].astype(np.int32)
            (x, y, vx, vy, q, ch_j, pm_j), man = rt_migrate.migrate(
                owner_old, owner_new,
                (x, y, vx, vy, q, jnp.asarray(chare_id, jnp.int32),
                 jnp.asarray(perm, jnp.int32)),
                num_nodes=cfg.num_pes)
            moved_n = int(man.moved_count)
            mig_bytes[t] = float(moved_n * cfg.bytes_per_particle)
            chare_id = np.asarray(ch_j)
            perm = np.asarray(pm_j)
            assignment = new_assignment.astype(np.int32)
        if lb_on and not isinstance(trig, rt_triggers.EveryTrigger):
            # measured predictive gate: same f32 particle count the
            # scanned path observes (moved_n for fired steps, else 0)
            tstate = trig.observe(
                tstate,
                jnp.float32(mig_bytes[t] / cfg.bytes_per_particle),
                jnp.asarray(bool(do)))

        if cfg.threads_per_node:
            # same device-resident LPT as the scanned path (f32 parity)
            thr = hierarchical.lpt_threads(
                jnp.asarray(loads, jnp.float32),
                jnp.asarray(assignment, jnp.int32),
                num_nodes=cfg.num_pes,
                threads_per_node=cfg.threads_per_node)
            tl = hierarchical.thread_loads(
                jnp.asarray(loads, jnp.float32),
                jnp.asarray(assignment, jnp.int32), thr,
                num_nodes=cfg.num_pes,
                threads_per_node=cfg.threads_per_node)
            tma[t] = float(tl.max() / (tl.mean() + 1e-30))

        if tel:
            obs_state = obs_telemetry.record(
                obs_state, tel, t=t,
                node_loads=np.bincount(assignment, weights=loads,
                                       minlength=cfg.num_pes),
                fired=fired[t], trigger_kind=tkind,
                moved_items=mig_bytes[t] / cfg.bytes_per_particle,
                moved_bytes=mig_bytes[t])

        # modeled step time: slowest PE compute + boundary traffic + LB
        step_s[t] = (
            pe_loads.max() * cost.t_particle
            + (ext + mig_bytes[t]) * cost.t_byte
            + cost.lb_seconds(lb_s, cfg.strategy, cfg.num_pes)
            / _lb_amort(cfg, trig)
        )

    xs, ys_ = np.asarray(x), np.asarray(y)
    fx, fy = np.empty_like(xs), np.empty_like(ys_)
    fx[perm], fy[perm] = xs, ys_     # undo the executed exchanges
    return PICResult(ma, ext_b, int_b, mig, mig_bytes, lb_seconds, step_s,
                     fx, fy, scanned=False,
                     wall_seconds=time.perf_counter() - t_start,
                     thread_max_avg=(tma if cfg.threads_per_node else None),
                     lb_steps=fired,
                     telemetry=(obs_telemetry.snapshot(obs_state, tel)
                                if tel else None))
