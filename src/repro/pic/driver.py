"""PIC PRK end-to-end driver with integrated load balancing (paper §VI).

Reproduces the paper's evaluation loop: particles advance each step (Pallas
push kernel), chare loads are measured (histogram kernel), and every
``lb_every`` steps the chare→PE assignment is rebalanced by any registered
strategy.  Records the paper's metrics per step:

  * max/avg particles per PE            (Fig 3, Fig 4)
  * external/internal comm bytes        (particle handoffs crossing PEs)
  * migration volume at LB steps
  * modeled step time (compute + comm + LB amortization) for the
    strong-scaling study (Fig 5/6) — see ``CostModel``; wall-clock
    multi-node timing needs real nodes, the model is calibrated per-term
    and reported as such in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np
import jax.numpy as jnp

from repro.core import api as core_api
from repro.kernels.histogram.ops import histogram
from repro.kernels.pic_push.ops import pic_push
from repro.pic import chares as ch
from repro.pic.grid import alternating_grid
from repro.pic.particles import initialize


@dataclasses.dataclass
class PICConfig:
    L: int = 1000
    n_particles: int = 100_000
    steps: int = 100
    k: int = 2
    rho: float = 0.9
    vy0: float = 1.0
    mode: str = "GEOMETRIC"
    cx: int = 12
    cy: int = 12
    num_pes: int = 4
    mapping: str = "striped"
    lb_every: int = 10
    strategy: str = "diff-comm"
    strategy_kwargs: Optional[Dict] = None
    bytes_per_particle: float = 48.0
    seed: int = 0
    use_kernel: Optional[bool] = None  # None = auto (Pallas on TPU)


@dataclasses.dataclass
class CostModel:
    """Per-term model for simulated strong scaling (Fig 5).

    t_particle — seconds per particle push on one PE;
    t_byte     — seconds per byte crossing a node boundary;
    t_lb       — measured strategy planning time (filled by the driver).
      Diffusion planning is a *distributed* algorithm (O(K·iters) work per
      node); this container executes it serially, so its measured wall
      time is divided by num_pes.  Centralized planners (greedy*, metis*)
      are charged full wall time — matching their Charm++ deployments.
    """
    t_particle: float = 2.0e-8
    # calibrated so comm ≈ compute at the paper's 8-node operating point
    # (Fig 6 shows communication and computation time of the same
    # magnitude): ~50 MB/s effective per-PE boundary bandwidth (many small
    # particle messages on a shared NIC), not the wire peak.
    t_byte: float = 2.0e-8

    def lb_seconds(self, wall: float, strategy: str, num_pes: int) -> float:
        if strategy.startswith("diff"):
            return wall / max(num_pes, 1)
        return wall


@dataclasses.dataclass
class PICResult:
    max_avg: np.ndarray        # (T,) max/avg particles per PE
    ext_bytes: np.ndarray      # (T,) external comm bytes per step
    int_bytes: np.ndarray      # (T,)
    migrations: np.ndarray     # (T,) fraction of chares moved (LB steps)
    migrated_bytes: np.ndarray # (T,) particle bytes moved by LB
    lb_seconds: float
    step_seconds: np.ndarray   # (T,) modeled time per step
    final_x: np.ndarray
    final_y: np.ndarray

    def summary(self) -> Dict[str, float]:
        return dict(
            mean_max_avg=float(self.max_avg.mean()),
            mean_ext_bytes=float(self.ext_bytes.mean()),
            total_migrated_bytes=float(self.migrated_bytes.sum()),
            lb_seconds=float(self.lb_seconds),
            modeled_time=float(self.step_seconds.sum()),
        )


def run(cfg: PICConfig, cost: CostModel = CostModel()) -> PICResult:
    grid_q = jnp.asarray(alternating_grid(cfg.L))
    p = initialize(cfg.mode, cfg.L, cfg.n_particles, k=cfg.k, vy0=cfg.vy0,
                   rho=cfg.rho, seed=cfg.seed)
    x, y = jnp.asarray(p.x), jnp.asarray(p.y)
    vx, vy = jnp.asarray(p.vx), jnp.asarray(p.vy)
    q = jnp.asarray(p.q)

    n_chares = cfg.cx * cfg.cy
    assignment = ch.initial_mapping(cfg.cx, cfg.cy, cfg.num_pes, cfg.mapping)
    chare_id = np.asarray(ch.chare_of(p.x, p.y, cfg.L, cfg.cx, cfg.cy))

    T = cfg.steps
    ma = np.zeros(T)
    ext_b = np.zeros(T)
    int_b = np.zeros(T)
    mig = np.zeros(T)
    mig_bytes = np.zeros(T)
    step_s = np.zeros(T)
    lb_seconds = 0.0

    for t in range(T):
        xn, yn, vx, vy = pic_push(grid_q, x, y, vx, vy, q, L=cfg.L,
                                  use_kernel=cfg.use_kernel)
        new_chare = np.asarray(
            ch.chare_of(np.asarray(xn), np.asarray(yn), cfg.L, cfg.cx, cfg.cy)
        )
        # particle handoffs: chare changed → bytes move; PE boundary → external
        moved = new_chare != chare_id
        src_pe = assignment[chare_id[moved]]
        dst_pe = assignment[new_chare[moved]]
        ext = float((src_pe != dst_pe).sum()) * cfg.bytes_per_particle
        intra = float((src_pe == dst_pe).sum()) * cfg.bytes_per_particle
        x, y, chare_id = xn, yn, new_chare

        loads = np.asarray(
            histogram(jnp.asarray(chare_id), jnp.ones(cfg.n_particles),
                      C=n_chares, use_kernel=cfg.use_kernel)
        )
        pe_loads = np.bincount(assignment, weights=loads,
                               minlength=cfg.num_pes)
        ma[t] = pe_loads.max() / (pe_loads.mean() + 1e-30)
        ext_b[t], int_b[t] = ext, intra

        lb_s = 0.0
        if (cfg.strategy != "none" and cfg.lb_every > 0
                and t > 0 and t % cfg.lb_every == 0):
            problem = ch.build_problem(
                loads, assignment, L=cfg.L, cx=cfg.cx, cy=cfg.cy,
                num_pes=cfg.num_pes, k=cfg.k, vy0=cfg.vy0,
                lb_period=cfg.lb_every,
                bytes_per_particle=cfg.bytes_per_particle,
            )
            t0 = time.perf_counter()
            plan = core_api.STRATEGIES[cfg.strategy](
                problem, **(cfg.strategy_kwargs or {})
            )
            lb_s = time.perf_counter() - t0
            lb_seconds += lb_s
            new_assignment = np.asarray(plan.assignment)
            moved_chares = new_assignment != assignment
            mig[t] = float(moved_chares.mean())
            mig_bytes[t] = float(
                loads[moved_chares].sum() * cfg.bytes_per_particle
            )
            assignment = new_assignment.astype(np.int32)

        # modeled step time: slowest PE compute + boundary traffic + LB
        step_s[t] = (
            pe_loads.max() * cost.t_particle
            + (ext + mig_bytes[t]) * cost.t_byte
            + cost.lb_seconds(lb_s, cfg.strategy, cfg.num_pes)
            / max(cfg.lb_every, 1)
        )

    return PICResult(ma, ext_b, int_b, mig, mig_bytes, lb_seconds, step_s,
                     np.asarray(x), np.asarray(y))
