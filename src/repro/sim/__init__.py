"""Load-balancing simulation infrastructure (paper §V)."""
from repro.sim.simulator import CompareRow, SeriesResult, compare, format_table, run_series
from repro.sim.stencil import stencil_2d, stencil_3d
from repro.sim.synthetic import hotspot, mod7, random_pm

__all__ = [
    "CompareRow",
    "SeriesResult",
    "compare",
    "format_table",
    "hotspot",
    "mod7",
    "random_pm",
    "run_series",
    "stencil_2d",
    "stencil_3d",
]
