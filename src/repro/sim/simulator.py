"""Load-balancing simulation infrastructure (paper §V).

The paper's simulator takes (loads, coords, comm edges) snapshots from any
Charm++ application and replays strategies at any scale on one process; ours
does the same for ``LBProblem`` instances.  ``compare`` runs a set of
strategies on one snapshot; ``run_series`` replays a time-evolving workload
with periodic rebalancing (used by the PIC driver and Fig 4/5 benchmarks).

``run_series`` has two execution paths:

  * **scanned** — when the strategy is jittable (``engine.Strategy``) and
    ``evolve`` is scan-safe (scenarios from sim/scenarios.py mark theirs
    with ``evolve.jittable = True``), the whole replay compiles to a single
    ``jax.lax.scan``: evolve + ``lax.cond``-gated planning + device-side
    metrics per step, with exactly one host transfer at the end.  Compiled
    runners are cached, so repeated replays (parameter sweeps, many
    scenarios) pay tracing once.
  * **host loop** — the legacy eager path, kept for the NumPy baselines
    (greedy, metis, ...) and for host-side ``evolve`` callables.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api, comm_graph, engine, metrics


@dataclasses.dataclass
class CompareRow:
    strategy: str
    before: Dict[str, float]
    after: Dict[str, float]
    info: Dict


def compare(
    problem: comm_graph.LBProblem,
    strategies: Sequence[str],
    strategy_kwargs: Optional[Dict[str, Dict]] = None,
) -> List[CompareRow]:
    strategy_kwargs = strategy_kwargs or {}
    before = metrics.evaluate(problem)
    rows = []
    for name in strategies:
        plan = api.run_strategy(name, problem, **strategy_kwargs.get(name, {}))
        after = metrics.evaluate(problem, jnp.asarray(plan.assignment))
        rows.append(CompareRow(name, before, after, plan.info))
    return rows


def format_table(rows: List[CompareRow]) -> str:
    """Paper-Table-II-style text table."""
    cols = ["strategy", "max/avg", "ext/int", "%migr", "plan_s"]
    out = ["  ".join(f"{c:>12}" for c in cols)]
    if rows:
        b = rows[0].before
        out.append("  ".join([
            f"{'(initial)':>12}", f"{b['max_avg_load']:>12.3f}",
            f"{b['ext_int_comm']:>12.3f}", f"{'-':>12}", f"{'-':>12}",
        ]))
    for r in rows:
        out.append("  ".join([
            f"{r.strategy:>12}",
            f"{r.after['max_avg_load']:>12.3f}",
            f"{r.after['ext_int_comm']:>12.3f}",
            f"{100*r.after['pct_migrations']:>11.1f}%",
            f"{r.info.get('plan_seconds', float('nan')):>12.3f}",
        ]))
    return "\n".join(out)


@dataclasses.dataclass
class SeriesResult:
    max_avg: np.ndarray        # (T,) per step
    ext_int: np.ndarray        # (T,)
    migrations: np.ndarray     # (T,) fraction moved at that step (0 if no LB)
    plan_seconds: float        # host path: cumulative planning wall time;
                               # scanned path: wall time of the whole replay
    scanned: bool = False
    wall_seconds: float = 0.0  # total replay wall time (both paths)


def run_series(
    initial: comm_graph.LBProblem,
    evolve: Callable[[comm_graph.LBProblem, int], comm_graph.LBProblem],
    *,
    steps: int,
    lb_every: int,
    strategy: str = "diff-comm",
    strategy_kwargs: Optional[Dict] = None,
    scan: Optional[bool] = None,
) -> SeriesResult:
    """Replay ``steps`` of a workload, rebalancing every ``lb_every`` steps.

    ``evolve(problem, t)`` advances loads/comm one application step while
    preserving the current assignment (the simulator's stand-in for the
    application's own dynamics).  ``scan=None`` auto-selects the scanned
    path when both the strategy and ``evolve`` are jit-traceable."""
    strategy_kwargs = strategy_kwargs or {}
    if scan:
        strat = engine.get_strategy(strategy)
        if not strat.jittable:
            raise ValueError(
                f"strategy {strategy!r} is not jittable; the scanned replay "
                "needs a traceable plan_fn (use scan=False or a diff-* / "
                "none strategy)")
    if scan is None:
        try:
            jittable = engine.get_strategy(strategy).jittable
        except KeyError:
            jittable = False
        scan = jittable and getattr(evolve, "jittable", False)
    if scan:
        return _run_series_scanned(
            initial, evolve, steps=steps, lb_every=lb_every,
            strategy=strategy, strategy_kwargs=strategy_kwargs)
    return _run_series_host(
        initial, evolve, steps=steps, lb_every=lb_every,
        strategy=strategy, strategy_kwargs=strategy_kwargs)


# ------------------------------------------------------------- host loop --


def _run_series_host(initial, evolve, *, steps, lb_every, strategy,
                     strategy_kwargs) -> SeriesResult:
    t_start = time.perf_counter()
    problem = initial
    ma, ei, mig = [], [], []
    plan_s = 0.0
    for t in range(steps):
        problem = evolve(problem, t)
        if strategy != "none" and lb_every > 0 and t % lb_every == 0 and t > 0:
            plan = api.run_strategy(strategy, problem, **strategy_kwargs)
            moved = float(
                np.mean(plan.assignment != np.asarray(problem.assignment))
            )
            problem = problem.with_assignment(jnp.asarray(plan.assignment))
            plan_s += plan.info.get("plan_seconds", 0.0)
            mig.append(moved)
        else:
            mig.append(0.0)
        m = metrics.evaluate(problem)
        ma.append(m["max_avg_load"])
        ei.append(m["ext_int_comm"])
    return SeriesResult(np.array(ma), np.array(ei), np.array(mig), plan_s,
                        scanned=False,
                        wall_seconds=time.perf_counter() - t_start)


# ---------------------------------------------------------- scanned path --


@functools.lru_cache(maxsize=64)
def _scanned_runner(evolve, steps: int, lb_every: int, strategy: str,
                    kw_items: tuple):
    """Compile-once scan over the whole replay.

    Cache key: the evolve closure (identity), the static replay shape, and
    the strategy binding — re-running the same scenario/strategy reuses
    the compiled executable."""
    strat = engine.get_strategy(strategy)
    plan = strat.bind(**dict(kw_items))
    do_lb_at_all = strategy != "none" and lb_every > 0

    def step(problem, t):
        problem = evolve(problem, t)
        prev = problem.assignment
        if do_lb_at_all:
            do = (t > 0) & (t % lb_every == 0)
            new_assignment, _stats = jax.lax.cond(
                do,
                plan,
                lambda p: (p.assignment.astype(jnp.int32),
                           engine.zero_stats()),
                problem,
            )
            moved = jnp.where(
                do, jnp.mean((new_assignment != prev).astype(jnp.float32)),
                0.0)
            problem = problem.with_assignment(new_assignment)
        else:
            moved = jnp.float32(0.0)
        m = metrics.evaluate_device(problem)
        return problem, (m.max_avg_load, m.ext_int_comm, moved)

    def run(problem):
        return jax.lax.scan(step, problem, jnp.arange(steps))

    return jax.jit(run)


def _canonical(problem: comm_graph.LBProblem) -> comm_graph.LBProblem:
    """Device arrays with the carry dtypes the scan expects."""
    return dataclasses.replace(
        problem,
        loads=jnp.asarray(problem.loads, jnp.float32),
        assignment=jnp.asarray(problem.assignment, jnp.int32),
        edges_src=jnp.asarray(problem.edges_src, jnp.int32),
        edges_dst=jnp.asarray(problem.edges_dst, jnp.int32),
        edges_bytes=jnp.asarray(problem.edges_bytes, jnp.float32),
        coords=None if problem.coords is None
        else jnp.asarray(problem.coords, jnp.float32),
    )


def _run_series_scanned(initial, evolve, *, steps, lb_every, strategy,
                        strategy_kwargs) -> SeriesResult:
    runner = _scanned_runner(
        evolve, steps, lb_every, strategy,
        tuple(sorted(strategy_kwargs.items())))
    t_start = time.perf_counter()
    try:
        _final, (ma, ei, mig) = runner(_canonical(initial))
    except jax.errors.TracerArrayConversionError as e:
        # scan=True forced with a host-NumPy evolve: surface the cause
        # instead of the opaque tracer leak from inside lax.scan
        raise ValueError(
            "the evolve callable is not jit-traceable (it converts traced "
            "arrays to NumPy); use scan=False or a pure-jnp evolve — "
            "scenarios from sim/scenarios.py are scan-safe") from e
    ma, ei, mig = jax.device_get((ma, ei, mig))
    wall = time.perf_counter() - t_start
    return SeriesResult(np.asarray(ma, np.float64), np.asarray(ei, np.float64),
                        np.asarray(mig, np.float64), wall, scanned=True,
                        wall_seconds=wall)
