"""Load-balancing simulation infrastructure (paper §V).

The paper's simulator takes (loads, coords, comm edges) snapshots from any
Charm++ application and replays strategies at any scale on one process; ours
does the same for ``LBProblem`` instances.  ``compare`` runs a set of
strategies on one snapshot; ``run_series`` replays a time-evolving workload
with periodic rebalancing (used by the PIC driver and Fig 4/5 benchmarks).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core import api, comm_graph, metrics


@dataclasses.dataclass
class CompareRow:
    strategy: str
    before: Dict[str, float]
    after: Dict[str, float]
    info: Dict


def compare(
    problem: comm_graph.LBProblem,
    strategies: Sequence[str],
    strategy_kwargs: Optional[Dict[str, Dict]] = None,
) -> List[CompareRow]:
    strategy_kwargs = strategy_kwargs or {}
    before = metrics.evaluate(problem)
    rows = []
    for name in strategies:
        plan = api.run_strategy(name, problem, **strategy_kwargs.get(name, {}))
        import jax.numpy as jnp
        after = metrics.evaluate(problem, jnp.asarray(plan.assignment))
        rows.append(CompareRow(name, before, after, plan.info))
    return rows


def format_table(rows: List[CompareRow]) -> str:
    """Paper-Table-II-style text table."""
    cols = ["strategy", "max/avg", "ext/int", "%migr", "plan_s"]
    out = ["  ".join(f"{c:>12}" for c in cols)]
    if rows:
        b = rows[0].before
        out.append("  ".join([
            f"{'(initial)':>12}", f"{b['max_avg_load']:>12.3f}",
            f"{b['ext_int_comm']:>12.3f}", f"{'-':>12}", f"{'-':>12}",
        ]))
    for r in rows:
        out.append("  ".join([
            f"{r.strategy:>12}",
            f"{r.after['max_avg_load']:>12.3f}",
            f"{r.after['ext_int_comm']:>12.3f}",
            f"{100*r.after['pct_migrations']:>11.1f}%",
            f"{r.info.get('plan_seconds', float('nan')):>12.3f}",
        ]))
    return "\n".join(out)


@dataclasses.dataclass
class SeriesResult:
    max_avg: np.ndarray        # (T,) per step
    ext_int: np.ndarray        # (T,)
    migrations: np.ndarray     # (T,) fraction moved at that step (0 if no LB)
    plan_seconds: float


def run_series(
    initial: comm_graph.LBProblem,
    evolve: Callable[[comm_graph.LBProblem, int], comm_graph.LBProblem],
    *,
    steps: int,
    lb_every: int,
    strategy: str = "diff-comm",
    strategy_kwargs: Optional[Dict] = None,
) -> SeriesResult:
    """Replay ``steps`` of a workload, rebalancing every ``lb_every`` steps.

    ``evolve(problem, t)`` advances loads/comm one application step while
    preserving the current assignment (the simulator's stand-in for the
    application's own dynamics).
    """
    strategy_kwargs = strategy_kwargs or {}
    problem = initial
    ma, ei, mig = [], [], []
    plan_s = 0.0
    for t in range(steps):
        problem = evolve(problem, t)
        if strategy != "none" and lb_every > 0 and t % lb_every == 0 and t > 0:
            plan = api.run_strategy(strategy, problem, **strategy_kwargs)
            moved = float(
                np.mean(plan.assignment != np.asarray(problem.assignment))
            )
            problem = problem.with_assignment(plan.assignment)
            plan_s += plan.info.get("plan_seconds", 0.0)
            mig.append(moved)
        else:
            mig.append(0.0)
        m = metrics.evaluate(problem)
        ma.append(m["max_avg_load"])
        ei.append(m["ext_int_comm"])
    return SeriesResult(np.array(ma), np.array(ei), np.array(mig), plan_s)
