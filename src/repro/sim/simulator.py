"""Load-balancing simulation infrastructure (paper §V).

The paper's simulator takes (loads, coords, comm edges) snapshots from any
Charm++ application and replays strategies at any scale on one process; ours
does the same for ``LBProblem`` instances.  ``compare`` runs a set of
strategies on one snapshot; ``run_series`` replays a time-evolving workload
with periodic rebalancing (used by the PIC driver and Fig 4/5 benchmarks).

``run_series`` has two execution paths:

  * **scanned** — when the strategy is jittable (``engine.Strategy``) and
    ``evolve`` is scan-safe (scenarios from sim/scenarios.py mark theirs
    with ``evolve.jittable = True``), the whole replay compiles to a single
    ``jax.lax.scan``: evolve + ``lax.cond``-gated planning + device-side
    metrics per step, with exactly one host transfer at the end.  Compiled
    runners are cached, so repeated replays (parameter sweeps, many
    scenarios) pay tracing once.
  * **host loop** — the legacy eager path, kept for the NumPy baselines
    (greedy, metis, ...) and for host-side ``evolve`` callables.

``run_series_batch`` is the third path: B independent workloads at a
common shape (e.g. every registered scenario from ``sim/scenarios.py``,
via ``scenarios.batch_instances``) replayed in **one** vmapped scan — a
single compiled call plans and evolves all B lanes per step instead of a
Python loop over scenarios, with the stacked problem buffers donated to
the executable on accelerators.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import api, comm_graph, engine, hierarchical, metrics
from repro.obs import telemetry as obs_telemetry
from repro.runtime import migrate as rt_migrate
from repro.runtime import triggers as rt_triggers


@dataclasses.dataclass
class CompareRow:
    strategy: str
    before: Dict[str, float]
    after: Dict[str, float]
    info: Dict


def compare(
    problem: comm_graph.LBProblem,
    strategies: Sequence[str],
    strategy_kwargs: Optional[Dict[str, Dict]] = None,
) -> List[CompareRow]:
    strategy_kwargs = strategy_kwargs or {}
    before = metrics.evaluate(problem)
    rows = []
    for name in strategies:
        plan = api.run_strategy(name, problem, **strategy_kwargs.get(name, {}))
        after = metrics.evaluate(problem, jnp.asarray(plan.assignment))
        # load volume the plan would migrate — the honest §II metric-3
        # numerator (benchmarks price it via RuntimeCostModel)
        moved = np.asarray(plan.assignment) != np.asarray(problem.assignment)
        plan.info["migrated_load"] = float(
            np.where(moved, np.asarray(problem.loads, np.float32),
                     np.float32(0)).sum())
        rows.append(CompareRow(name, before, after, plan.info))
    return rows


def format_table(rows: List[CompareRow]) -> str:
    """Paper-Table-II-style text table."""
    cols = ["strategy", "max/avg", "ext/int", "%migr", "plan_s"]
    out = ["  ".join(f"{c:>12}" for c in cols)]
    if rows:
        b = rows[0].before
        out.append("  ".join([
            f"{'(initial)':>12}", f"{b['max_avg_load']:>12.3f}",
            f"{b['ext_int_comm']:>12.3f}", f"{'-':>12}", f"{'-':>12}",
        ]))
    for r in rows:
        out.append("  ".join([
            f"{r.strategy:>12}",
            f"{r.after['max_avg_load']:>12.3f}",
            f"{r.after['ext_int_comm']:>12.3f}",
            f"{100*r.after['pct_migrations']:>11.1f}%",
            f"{r.info.get('plan_seconds', float('nan')):>12.3f}",
        ]))
    return "\n".join(out)


@dataclasses.dataclass
class SeriesResult:
    max_avg: np.ndarray        # (T,) per step
    ext_int: np.ndarray        # (T,)
    migrations: np.ndarray     # (T,) fraction moved at that step (0 if no LB)
    plan_seconds: float        # host path: cumulative planning wall time;
                               # scanned path: wall time of the whole replay
    scanned: bool = False
    wall_seconds: float = 0.0  # total replay wall time (both paths)
    # (T,) per-step max/avg load across all P*T global PEs under the
    # two-level (node, thread) placement — only when ``threads_per_node``
    # was requested (None otherwise)
    thread_max_avg: Optional[np.ndarray] = None
    # runtime-era per-step records (None on the batched path): whether the
    # trigger fired, the pre-metrics max node load, and the total load of
    # the objects the rebalance moved — the inputs to
    # ``runtime.cost.series_modeled_seconds``
    lb_fired: Optional[np.ndarray] = None      # (T,) 0/1
    max_load: Optional[np.ndarray] = None      # (T,)
    migrated_load: Optional[np.ndarray] = None  # (T,)
    # (N,) final object→node assignment after the last step (None on the
    # batched path) — the sharded-replay parity contract asserts it
    final_assignment: Optional[np.ndarray] = None
    # (T,) 0/1 — fired plans the validate_plan guardrail rejected (and
    # rolled back); only recorded by the resilient sharded replay paths
    # (``faults`` / ``guard``), None everywhere else
    plan_rejected: Optional[np.ndarray] = None
    # scan-carried StepRecord ring (obs/telemetry.py) — only when the
    # replay was passed an enabled TelemetryConfig, None otherwise
    telemetry: Optional[obs_telemetry.TelemetrySnapshot] = None


def run_series(
    initial: comm_graph.LBProblem,
    evolve: Callable[[comm_graph.LBProblem, int], comm_graph.LBProblem],
    *,
    steps: int,
    lb_every: int,
    strategy: str = "diff-comm",
    strategy_kwargs: Optional[Dict] = None,
    scan: Optional[bool] = None,
    threads_per_node: Optional[int] = None,
    trigger=None,
    telemetry=None,
) -> SeriesResult:
    """Replay ``steps`` of a workload with trigger-policed rebalancing.

    ``evolve(problem, t)`` advances loads/comm one application step while
    preserving the current assignment (the simulator's stand-in for the
    application's own dynamics).  ``scan=None`` auto-selects the scanned
    path when both the strategy and ``evolve`` are jit-traceable.

    ``trigger`` selects the online rebalancing policy
    (``runtime.triggers``): ``None`` falls back to the strategy's
    registered trigger (e.g. ``"diff-comm+threshold"``) and then to the
    legacy fixed period — ``trigger="every"`` (or ``None`` on a plain
    strategy) reproduces the pre-runtime ``lb_every`` replay
    **bit-for-bit** on both paths.  ``"threshold"`` / ``"predictive"``
    (or a configured ``Trigger`` instance) decide per step from the
    pre-LB load statistics, identically on the host and scanned paths.
    Per-step ``lb_fired`` / ``max_load`` / ``migrated_load`` records feed
    ``runtime.cost.series_modeled_seconds``.

    ``threads_per_node`` enables the two-level (node, thread) view (paper
    §III.D): each step additionally records the max/avg load across all
    ``P * T`` global PEs under the within-node LPT placement
    (``hierarchical.lpt_threads`` — computed on device in the scanned
    path) in ``SeriesResult.thread_max_avg``.  The batched replay
    (``run_series_batch``) takes neither knob.

    :func:`run_series_sharded` is the mesh-sharded sibling: the same
    scanned loop (same knobs, bit-for-bit the same ``SeriesResult``)
    executed inside one ``shard_map`` over the 1-D ``"lb"`` device mesh
    with the planner's diffusion stage running as ring halo exchanges.

    ``telemetry`` (a :class:`repro.obs.telemetry.TelemetryConfig`, a level
    string, or ``None``) opts the replay into the scan-carried StepRecord
    ring; ``level="off"`` / ``None`` adds nothing to the traced program
    and is bit-for-bit identical to the pre-telemetry replay."""
    strategy_kwargs = strategy_kwargs or {}
    tel = obs_telemetry.resolve(telemetry)
    tel = tel if tel.enabled else None
    trig = rt_triggers.resolve_for_strategy(trigger, lb_every=lb_every,
                                            strategy=strategy)
    if scan:
        strat = engine.get_strategy(strategy)
        if not strat.jittable:
            raise ValueError(
                f"strategy {strategy!r} is not jittable; the scanned replay "
                "needs a traceable plan_fn (use scan=False or a diff-* / "
                "none strategy)")
    if scan is None:
        try:
            jittable = engine.get_strategy(strategy).jittable
        except KeyError:
            jittable = False
        scan = jittable and getattr(evolve, "jittable", False)
    if scan:
        return _run_series_scanned(
            initial, evolve, steps=steps, lb_every=lb_every,
            strategy=strategy, strategy_kwargs=strategy_kwargs,
            threads_per_node=threads_per_node, trig=trig, tel=tel)
    return _run_series_host(
        initial, evolve, steps=steps, lb_every=lb_every,
        strategy=strategy, strategy_kwargs=strategy_kwargs,
        threads_per_node=threads_per_node, trig=trig, tel=tel)


def run_series_sharded(initial, evolve, **kwargs):
    """Mesh-sharded ``run_series``: the whole replay (evolve → trigger →
    sharded plan → assignment update) inside one ``shard_map`` over the
    1-D ``"lb"`` device mesh, bit-for-bit the scanned single-device
    path.  Thin forwarder to
    :func:`repro.distributed.replay_shard.run_series_sharded` (kept
    lazy so ``sim`` stays importable without the distributed stack)."""
    from repro.distributed import replay_shard

    return replay_shard.run_series_sharded(initial, evolve, **kwargs)


# ------------------------------------------------------------- host loop --


def _run_series_host(initial, evolve, *, steps, lb_every, strategy,
                     strategy_kwargs, threads_per_node=None,
                     trig=None, tel=None) -> SeriesResult:
    trig = trig or rt_triggers.resolve(None, lb_every=lb_every)
    t_start = time.perf_counter()
    problem = initial
    ma, ei, mig, tma = [], [], [], []
    fired, mxl, migl = [], [], []
    plan_s = 0.0
    obs_state = (obs_telemetry.init_state(tel, initial.num_nodes)
                 if tel else None)
    tkind = obs_telemetry.trigger_kind(trig) if tel else 0
    lb_on = strategy != "none" and not trig.never
    # the fixed cadence ignores the load stats: keep the legacy pure-
    # Python predicate (bit-identical) instead of a per-step device trip
    is_every = isinstance(trig, rt_triggers.EveryTrigger)
    tstate = trig.init_state()
    for t in range(steps):
        problem = evolve(problem, t)
        do = False
        if lb_on:
            if is_every:
                do = t > 0 and t % trig.every == 0
            else:
                # same jnp expression graph as the scanned path, so
                # adaptive threshold comparisons agree bitwise across
                # paths
                mx, av, tot = rt_triggers.load_stats_jit(
                    jnp.asarray(problem.loads, jnp.float32),
                    jnp.asarray(problem.assignment, jnp.int32),
                    problem.num_nodes)
                d, tstate = trig.decide(tstate, jnp.int32(t), mx, av, tot)
                do = bool(d)
        moved_n = 0.0
        sweeps = 0.0
        if do:
            plan = api.run_strategy(strategy, problem, **strategy_kwargs)
            delta = plan.assignment != np.asarray(problem.assignment)
            moved = float(np.mean(delta))
            moved_n = float(np.sum(delta))
            sweeps = float(plan.info.get("diffusion_iters", 0.0))
            migl.append(float(jnp.where(
                jnp.asarray(delta),
                jnp.asarray(problem.loads, jnp.float32), 0.0).sum()))
            problem = problem.with_assignment(jnp.asarray(plan.assignment))
            plan_s += plan.info.get("plan_seconds", 0.0)
            mig.append(moved)
        else:
            mig.append(0.0)
            migl.append(0.0)
        if lb_on and not is_every:
            # feed the executed exchange volume back (measured predictive
            # gate) — same f32 value the scanned path observes, so the
            # two paths keep firing on identical steps
            tstate = trig.observe(tstate, jnp.float32(migl[-1]),
                                  jnp.asarray(do))
        fired.append(1.0 if do else 0.0)
        m = metrics.evaluate(problem)
        ma.append(m["max_avg_load"])
        ei.append(m["ext_int_comm"])
        mxl.append(m["max_load"])
        if threads_per_node:
            tma.append(float(_thread_max_avg(
                problem.loads, problem.assignment,
                problem.num_nodes, threads_per_node)))
        if tel:
            obs_state = obs_telemetry.record(
                obs_state, tel, t=t,
                node_loads=obs_telemetry.node_loads(
                    problem.loads, problem.assignment, problem.num_nodes),
                fired=fired[-1], trigger_kind=tkind, sweeps=sweeps,
                moved_items=moved_n, moved_bytes=migl[-1])
    return SeriesResult(np.array(ma), np.array(ei), np.array(mig), plan_s,
                        scanned=False,
                        wall_seconds=time.perf_counter() - t_start,
                        thread_max_avg=(np.array(tma) if threads_per_node
                                        else None),
                        lb_fired=np.array(fired), max_load=np.array(mxl),
                        migrated_load=np.array(migl),
                        final_assignment=np.asarray(problem.assignment,
                                                    np.int32),
                        telemetry=(obs_telemetry.snapshot(obs_state, tel)
                                   if tel else None))


# ---------------------------------------------------------- scanned path --


def _thread_max_avg(loads, assignment, num_nodes: int,
                    threads_per_node: int):
    """Traceable max/avg PE load under the two-level LPT placement."""
    thr = hierarchical.lpt_threads(
        jnp.asarray(loads, jnp.float32),
        jnp.asarray(assignment, jnp.int32),
        num_nodes=num_nodes, threads_per_node=threads_per_node)
    tl = hierarchical.thread_loads(
        loads, assignment, thr, num_nodes=num_nodes,
        threads_per_node=threads_per_node)
    return (tl.max() / (tl.mean() + 1e-30)).astype(jnp.float32)


@functools.lru_cache(maxsize=64)
def _scanned_runner(evolve, steps: int, lb_every: int, strategy: str,
                    kw_items: tuple, threads_per_node: Optional[int] = None,
                    trig=None, tel=None):
    """Compile-once scan over the whole replay.

    Cache key: the evolve closure (identity), the static replay shape,
    the strategy binding, the trigger policy and the telemetry config
    (all frozen dataclasses) — re-running the same scenario/strategy/
    trigger reuses the compiled executable.  ``tel=None`` (telemetry off)
    adds nothing to the trace: the carry and every expression below are
    identical to the pre-telemetry runner."""
    strat = engine.get_strategy(strategy)
    plan = strat.bind(**dict(kw_items))
    trig = trig or rt_triggers.resolve(None, lb_every=lb_every)
    do_lb_at_all = strategy != "none" and not trig.never
    tkind = obs_telemetry.trigger_kind(trig) if tel else 0

    def step(carry, t):
        if tel:
            problem, tstate, obs_state = carry
        else:
            problem, tstate = carry
        problem = evolve(problem, t)
        prev = problem.assignment
        sweeps = jnp.float32(0.0)
        moved_n = jnp.float32(0.0)
        if do_lb_at_all:
            mx, av, tot = rt_triggers.load_stats(
                problem.loads, problem.assignment, problem.num_nodes)
            do, tstate = trig.decide(tstate, t, mx, av, tot)
            new_assignment, stats = jax.lax.cond(
                do,
                plan,
                lambda p: (p.assignment.astype(jnp.int32),
                           engine.zero_stats()),
                problem,
            )
            delta = new_assignment != prev
            moved = jnp.where(
                do, jnp.mean(delta.astype(jnp.float32)), 0.0)
            migrated_load = jnp.where(
                do,
                jnp.where(delta,
                          jnp.asarray(problem.loads, jnp.float32),
                          0.0).sum(),
                0.0)
            # executed-exchange feedback for the measured predictive gate
            tstate = trig.observe(tstate, migrated_load, do)
            fired = do.astype(jnp.float32)
            problem = problem.with_assignment(new_assignment)
            if tel:
                sweeps = jnp.asarray(stats.diffusion_iters, jnp.float32)
                moved_n = delta.sum().astype(jnp.float32)
        else:
            moved = jnp.float32(0.0)
            migrated_load = jnp.float32(0.0)
            fired = jnp.float32(0.0)
        m = metrics.evaluate_device(problem)
        if threads_per_node:
            tma = _thread_max_avg(problem.loads, problem.assignment,
                                  problem.num_nodes, threads_per_node)
        else:
            tma = jnp.float32(0.0)
        ys = (m.max_avg_load, m.ext_int_comm, moved,
              tma, fired, m.max_load, migrated_load)
        if tel:
            obs_state = obs_telemetry.record(
                obs_state, tel, t=t,
                node_loads=obs_telemetry.node_loads(
                    problem.loads, problem.assignment, problem.num_nodes),
                fired=fired, trigger_kind=tkind, sweeps=sweeps,
                moved_items=moved_n, moved_bytes=migrated_load)
            return (problem, tstate, obs_state), ys
        return (problem, tstate), ys

    def run(problem):
        carry = (problem, trig.init_state())
        if tel:
            carry = carry + (obs_telemetry.init_state(
                tel, problem.num_nodes),)
        return jax.lax.scan(step, carry, jnp.arange(steps))

    return jax.jit(run)


def _canonical(problem: comm_graph.LBProblem) -> comm_graph.LBProblem:
    """Device arrays with the carry dtypes the scan expects."""
    return dataclasses.replace(
        problem,
        loads=jnp.asarray(problem.loads, jnp.float32),
        assignment=jnp.asarray(problem.assignment, jnp.int32),
        edges_src=jnp.asarray(problem.edges_src, jnp.int32),
        edges_dst=jnp.asarray(problem.edges_dst, jnp.int32),
        edges_bytes=jnp.asarray(problem.edges_bytes, jnp.float32),
        coords=None if problem.coords is None
        else jnp.asarray(problem.coords, jnp.float32),
    )


# ---------------------------------------------------------- batched path --


@dataclasses.dataclass
class BatchSeriesResult:
    """One vmapped replay of B workloads: per-lane series + batch wall."""

    series: List[SeriesResult]   # one per input instance, in order
    wall_seconds: float          # wall time of the whole batched replay
    steps: int

    @property
    def batch(self) -> int:
        return len(self.series)

    @property
    def lane_steps_per_sec(self) -> float:
        """Aggregate throughput: (B × T) scenario-steps per second."""
        return self.batch * self.steps / max(self.wall_seconds, 1e-12)


def _shape_preserving(evolve):
    """Wrap ``evolve`` to keep the batch's padded edge envelope.

    Inside the batched scan each lane's problem carries edge lists padded
    to the batch-wide maximum; an evolve that rebuilds ``edges_bytes`` at
    its native length (the PIC proxy) would otherwise shrink the carry.
    Re-pads with the standard (-1, -1, 0.0) edge padding."""

    def ev(p, t):
        q = evolve(p, t)
        fixes = {}
        for field, fill in (("edges_src", -1), ("edges_dst", -1),
                            ("edges_bytes", 0.0)):
            old, new = getattr(p, field), getattr(q, field)
            if new.shape != old.shape:
                fixes[field] = jnp.pad(
                    jnp.asarray(new, old.dtype),
                    (0, old.shape[0] - new.shape[0]), constant_values=fill)
        return dataclasses.replace(q, **fixes) if fixes else q

    return ev


@functools.lru_cache(maxsize=16)
def _batched_runner(evolves: tuple, lane_branch: tuple, steps: int,
                    lb_every: int, strategy: str, kw_items: tuple):
    """Compile-once vmapped scan over B lanes × ``steps`` steps.

    ``evolves`` are the distinct evolve closures (``lax.switch`` branches);
    ``lane_branch[b]`` maps lane b to its branch.  Cached on the closure
    identities + replay shape, so re-running the same batch reuses the
    executable."""
    strat = engine.get_strategy(strategy)
    plan = strat.bind(**dict(kw_items))
    do_lb_at_all = strategy != "none" and lb_every > 0
    branches = [_shape_preserving(ev) for ev in evolves]

    # lane→evolve is static, so lanes are grouped per distinct evolve and
    # each group vmapped over its slice — a lax.switch on a vmapped index
    # would instead run *every* branch for *every* lane (O(B²) evolve work)
    groups = sorted(
        (b, tuple(l for l, lb in enumerate(lane_branch) if lb == b))
        for b in set(lane_branch))
    order = [l for _, lanes in groups for l in lanes]
    # device-resident O(B) inverse (shared with the migration manifests)
    # instead of a host argsort
    inv_order = rt_migrate.inverse_permutation(
        np.asarray(order, np.int32))
    single = len(groups) == 1

    def evolve_all(ps, t):
        if single:
            return jax.vmap(lambda p: branches[0](p, t))(ps)
        parts = [
            jax.vmap(lambda p, b=b: branches[b](p, t))(
                jax.tree_util.tree_map(
                    lambda a, lanes=lanes: a[jnp.asarray(lanes)], ps))
            for b, lanes in groups
        ]
        merged = jax.tree_util.tree_map(
            lambda *xs: jnp.concatenate(xs, axis=0), *parts)
        return jax.tree_util.tree_map(lambda a: a[inv_order], merged)

    def run(problems):
        def step(ps, t):
            ps = evolve_all(ps, t)
            if do_lb_at_all:
                # the LB-period predicate is uniform across lanes, so the
                # cond stays *outside* the vmap — a per-lane cond would
                # batch into a select that runs the planner every step
                do = (t > 0) & (t % lb_every == 0)
                prev = ps.assignment                       # (B, N)
                new_assignment = jax.lax.cond(
                    do,
                    lambda ps: jax.vmap(plan)(ps)[0].astype(jnp.int32),
                    lambda ps: ps.assignment.astype(jnp.int32),
                    ps,
                )
                moved = jnp.where(
                    do,
                    jnp.mean((new_assignment != prev).astype(jnp.float32),
                             axis=1),
                    jnp.zeros(prev.shape[0], jnp.float32))
                ps = ps.with_assignment(new_assignment)
            else:
                moved = jnp.zeros(ps.assignment.shape[0], jnp.float32)
            m = jax.vmap(metrics.evaluate_device)(ps)
            return ps, (m.max_avg_load, m.ext_int_comm, moved)

        return jax.lax.scan(step, problems, jnp.arange(steps))

    # the stacked carry is staged by run_series_batch and never reused —
    # donate it where the backend supports donation (not CPU XLA)
    donate = (0,) if jax.default_backend() != "cpu" else ()
    return jax.jit(run, donate_argnums=donate)


def run_series_batch(
    instances: Sequence,
    *,
    steps: int,
    lb_every: int,
    strategy: str = "diff-comm",
    strategy_kwargs: Optional[Dict] = None,
) -> BatchSeriesResult:
    """Replay B workloads in one vmapped scan (one compiled call).

    ``instances`` is a sequence of ``(problem, evolve)`` pairs — or
    ``(name, problem, evolve)`` triples as produced by
    ``scenarios.batch_instances`` — at a common ``(num_nodes, num_objects)``
    shape (edge lists are padded to the longest).  Every ``evolve`` must be
    scan-safe and the strategy jittable; distinct evolves become
    ``lax.switch`` branches selected per lane."""
    strategy_kwargs = strategy_kwargs or {}
    strat = engine.get_strategy(strategy)
    if not strat.jittable:
        raise ValueError(
            f"strategy {strategy!r} is not jittable; the batched replay "
            "needs a traceable plan_fn (diff-* / none)")
    if strat.trigger is not None:
        # refuse rather than silently downgrade the wrapped strategy's
        # adaptive policy to the fixed cadence (per-lane trigger state in
        # the vmapped carry is a ROADMAP item)
        raise ValueError(
            f"strategy {strategy!r} carries an adaptive trigger; the "
            "batched replay only supports the fixed lb_every cadence — "
            f"use run_series or the base strategy")
    pairs = [inst[-2:] for inst in instances]
    for _, ev in pairs:
        if not getattr(ev, "jittable", False):
            raise ValueError(
                "every evolve in a batched replay must be scan-safe "
                "(scenarios from sim/scenarios.py are)")
    uniq: List = []
    lane_branch = []
    for _, ev in pairs:
        if ev not in uniq:
            uniq.append(ev)
        lane_branch.append(uniq.index(ev))
    runner = _batched_runner(
        tuple(uniq), tuple(lane_branch), steps, lb_every, strategy,
        tuple(sorted(strategy_kwargs.items())))
    stacked = comm_graph.stack_problems(
        [_canonical(p) for p, _ in pairs])
    t_start = time.perf_counter()
    _final, (ma, ei, mig) = runner(stacked)
    ma, ei, mig = jax.device_get((ma, ei, mig))   # (T, B) each
    wall = time.perf_counter() - t_start
    series = [
        SeriesResult(np.asarray(ma[:, b], np.float64),
                     np.asarray(ei[:, b], np.float64),
                     np.asarray(mig[:, b], np.float64),
                     wall, scanned=True, wall_seconds=wall)
        for b in range(len(pairs))
    ]
    return BatchSeriesResult(series, wall, steps)


def _run_series_scanned(initial, evolve, *, steps, lb_every, strategy,
                        strategy_kwargs, threads_per_node=None,
                        trig=None, tel=None) -> SeriesResult:
    runner = _scanned_runner(
        evolve, steps, lb_every, strategy,
        tuple(sorted(strategy_kwargs.items())), threads_per_node, trig, tel)
    t_start = time.perf_counter()
    try:
        final, ys = runner(_canonical(initial))
    except jax.errors.TracerArrayConversionError as e:
        # scan=True forced with a host-NumPy evolve: surface the cause
        # instead of the opaque tracer leak from inside lax.scan
        raise ValueError(
            "the evolve callable is not jit-traceable (it converts traced "
            "arrays to NumPy); use scan=False or a pure-jnp evolve — "
            "scenarios from sim/scenarios.py are scan-safe") from e
    ma, ei, mig, tma, fired, mxl, migl = jax.device_get(ys)
    wall = time.perf_counter() - t_start
    return SeriesResult(np.asarray(ma, np.float64), np.asarray(ei, np.float64),
                        np.asarray(mig, np.float64), wall, scanned=True,
                        wall_seconds=wall,
                        thread_max_avg=(np.asarray(tma, np.float64)
                                        if threads_per_node else None),
                        lb_fired=np.asarray(fired, np.float64),
                        max_load=np.asarray(mxl, np.float64),
                        migrated_load=np.asarray(migl, np.float64),
                        final_assignment=np.asarray(final[0].assignment,
                                                    np.int32),
                        telemetry=(obs_telemetry.snapshot(final[2], tel)
                                   if tel else None))
