"""Synthetic stencil problem generators (paper §I, §V).

2D 5-point and 3D 7-point stencils with periodic boundaries, decomposed into
one object per grid point (the paper's intuition benchmark) or into tiles,
with ``tiled`` (contiguous blocks — good initial locality) or ``striped``
(column-major round robin) object→node mappings.
"""
from __future__ import annotations

import numpy as np

from repro.core import comm_graph


def _factor2(p: int):
    a = int(np.sqrt(p))
    while p % a:
        a -= 1
    return a, p // a


def _factor3(p: int):
    best = (1, 1, p)
    for a in range(1, int(round(p ** (1 / 3))) + 2):
        if p % a:
            continue
        q = p // a
        b = int(np.sqrt(q))
        while q % b:
            b -= 1
        cand = tuple(sorted((a, b, q // b)))
        if max(cand) - min(cand) < max(best) - min(best):
            best = cand
    return best


def stencil_2d(
    nx: int,
    ny: int,
    num_nodes: int,
    *,
    mapping: str = "tiled",
    periodic: bool = True,
    bytes_per_edge: float = 1.0,
    base_load: float = 1.0,
    seed: int = 0,
) -> comm_graph.LBProblem:
    """One object per grid point, 5-point neighbor edges.

    ``seed`` drives the ``"random"`` mapping only (other mappings are
    deterministic); the default 0 reproduces the legacy behavior."""
    N = nx * ny
    ii, jj = np.meshgrid(np.arange(nx), np.arange(ny), indexing="ij")
    ii, jj = ii.ravel(), jj.ravel()
    coords = np.stack([ii, jj], axis=1).astype(np.float32)

    edges = []
    for di, dj in ((1, 0), (0, 1)):
        ni, nj = ii + di, jj + dj
        if periodic:
            ni, nj = ni % nx, nj % ny
            keep = np.ones(N, bool)
        else:
            keep = (ni < nx) & (nj < ny)
            ni, nj = np.minimum(ni, nx - 1), np.minimum(nj, ny - 1)
        src = (ii * ny + jj)[keep]
        dst = (ni * ny + nj)[keep]
        edges.append(np.stack([src, dst], axis=1))
    edges = np.concatenate(edges)

    assignment = _map_2d(ii, jj, nx, ny, num_nodes, mapping, seed)
    return comm_graph.make_problem(
        loads=np.full(N, base_load, np.float32),
        assignment=assignment,
        edges=edges,
        edge_bytes=np.full(edges.shape[0], bytes_per_edge, np.float32),
        num_nodes=num_nodes,
        coords=coords,
    )


def _map_2d(ii, jj, nx, ny, P, mapping, seed=0):
    if mapping == "tiled":
        px, py = _factor2(P)
        tx = (ii * px // nx).clip(0, px - 1)
        ty = (jj * py // ny).clip(0, py - 1)
        return (tx * py + ty).astype(np.int32)
    if mapping == "striped":
        # column-major stripes: contiguous column bands per node
        return (jj * P // ny).clip(0, P - 1).astype(np.int32)
    if mapping == "ring":
        # 1D ring of nodes along x (Table I setting)
        return (ii * P // nx).clip(0, P - 1).astype(np.int32)
    if mapping == "random":
        rng = np.random.default_rng(seed)
        return rng.integers(0, P, ii.shape[0]).astype(np.int32)
    raise ValueError(f"unknown mapping {mapping!r}")


def stencil_3d(
    nx: int,
    ny: int,
    nz: int,
    num_nodes: int,
    *,
    mapping: str = "tiled",
    periodic: bool = True,
    bytes_per_edge: float = 1.0,
    base_load: float = 1.0,
    seed: int = 0,
) -> comm_graph.LBProblem:
    """7-point 3D stencil (Table II benchmarks).

    ``mapping``: "tiled" (contiguous 3D blocks — near-optimal locality),
    "striped" (x-slabs: contiguous along x only — the poor-locality initial
    placement under which partitioners show their locality edge, cf. the
    paper's striped PIC mapping §VI), or "random" (seeded by ``seed``;
    default 0 reproduces the legacy behavior)."""
    N = nx * ny * nz
    ii, jj, kk = np.meshgrid(
        np.arange(nx), np.arange(ny), np.arange(nz), indexing="ij"
    )
    ii, jj, kk = ii.ravel(), jj.ravel(), kk.ravel()
    coords = np.stack([ii, jj, kk], axis=1).astype(np.float32)

    def lin(a, b, c):
        return (a * ny + b) * nz + c

    edges = []
    for d in ((1, 0, 0), (0, 1, 0), (0, 0, 1)):
        na, nb, nc = ii + d[0], jj + d[1], kk + d[2]
        if periodic:
            na, nb, nc = na % nx, nb % ny, nc % nz
            keep = np.ones(N, bool)
        else:
            keep = (na < nx) & (nb < ny) & (nc < nz)
            na, nb, nc = (np.minimum(na, nx - 1), np.minimum(nb, ny - 1),
                          np.minimum(nc, nz - 1))
        edges.append(np.stack([lin(ii, jj, kk)[keep],
                               lin(na, nb, nc)[keep]], axis=1))
    edges = np.concatenate(edges)

    if mapping == "tiled":
        px, py, pz = _factor3(num_nodes)
        tx = (ii * px // nx).clip(0, px - 1)
        ty = (jj * py // ny).clip(0, py - 1)
        tz = (kk * pz // nz).clip(0, pz - 1)
        assignment = ((tx * py + ty) * pz + tz).astype(np.int32)
    elif mapping == "striped":
        # contiguous ranges of the x-major linearized order (slab-like,
        # works for any P vs nx): much more surface than tiled blocks.
        lin_id = lin(ii, jj, kk).astype(np.int64)
        assignment = (lin_id * num_nodes // N).clip(
            0, num_nodes - 1).astype(np.int32)
    elif mapping == "random":
        rng = np.random.default_rng(seed)
        assignment = rng.integers(0, num_nodes, N).astype(np.int32)
    else:
        raise ValueError(f"unknown mapping {mapping!r}")

    return comm_graph.make_problem(
        loads=np.full(N, base_load, np.float32),
        assignment=assignment,
        edges=edges,
        edge_bytes=np.full(edges.shape[0], bytes_per_edge, np.float32),
        num_nodes=num_nodes,
        coords=coords,
    )
