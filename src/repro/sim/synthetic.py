"""Synthetic load-imbalance injectors used by the paper's experiments."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import comm_graph


def random_pm(
    problem: comm_graph.LBProblem, frac: float = 0.4, seed: int = 0
) -> comm_graph.LBProblem:
    """Fig 2 setting: every object's load randomly ±``frac``."""
    rng = np.random.default_rng(seed)
    loads = np.asarray(problem.loads)
    factor = 1.0 + rng.uniform(-frac, frac, loads.shape[0])
    return dataclasses.replace(
        problem, loads=np.maximum(loads * factor, 1e-6).astype(np.float32)
    )


def mod7(
    problem: comm_graph.LBProblem,
    over: float = 1.5,
    under: float = 0.7,
) -> comm_graph.LBProblem:
    """Table II setting: every 1st and 2nd PE mod 7 overloaded, every 3rd
    mod 7 underloaded (applied multiplicatively to that PE's objects)."""
    a = np.asarray(problem.assignment)
    loads = np.asarray(problem.loads).copy()
    m = a % 7
    loads[(m == 1) | (m == 2)] *= over
    loads[m == 3] *= under
    return dataclasses.replace(problem, loads=loads.astype(np.float32))


def hotspot(
    problem: comm_graph.LBProblem, node: int = 0, factor: float = 10.0
) -> comm_graph.LBProblem:
    """Table I setting: a single node overloaded by ``factor``."""
    a = np.asarray(problem.assignment)
    loads = np.asarray(problem.loads).copy()
    loads[a == node] *= factor
    return dataclasses.replace(problem, loads=loads.astype(np.float32))
