"""ASCII ownership-map visualizations (paper Figs 1-2 equivalents)."""
from __future__ import annotations

import numpy as np

_GLYPHS = "0123456789abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ"


def ownership_map(assignment, nx: int, ny: int) -> str:
    """Render a (nx*ny,) assignment of a 2D grid as an ASCII block map."""
    a = np.asarray(assignment).reshape(nx, ny)
    rows = []
    for i in range(nx):
        rows.append("".join(_GLYPHS[int(p) % len(_GLYPHS)] for p in a[i]))
    return "\n".join(rows)


def locality_summary(assignment, nx: int, ny: int) -> float:
    """Fraction of 4-neighbor grid links that stay within one node — a quick
    scalar for 'contiguous blocks of color' (Fig 1 intuition)."""
    a = np.asarray(assignment).reshape(nx, ny)
    same = 0
    total = 0
    same += (a == np.roll(a, 1, axis=0)).sum()
    same += (a == np.roll(a, 1, axis=1)).sum()
    total += 2 * a.size
    return float(same) / total
