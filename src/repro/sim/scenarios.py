"""Scenario registry: named time-evolving workloads for the replay layers.

A scenario bundles an initial :class:`LBProblem` with a *jit-traceable*
``evolve(problem, t) -> problem`` so the whole replay can run as one
``jax.lax.scan`` (sim/simulator.py).  Every evolve here is a pure function
of the step index with static shapes — loads (and edge bytes, where they
track loads) are recomputed, never accumulated, so a scanned replay and a
host-loop replay see bit-identical workloads.

Registered workloads:

  stencil-wave      — load hotspot orbiting a 2D stencil (the paper's §V
                      simulator setting; examples/stencil_lb_demo.py);
  pic-geometric     — chare-level PIC PRK proxy: the geometric particle
                      column profile advects east at (2k+1) cells/step,
                      edge bytes follow the loads (paper §VI);
  adversarial-hotspot — a hotspot that *teleports* across the domain every
                      ``dwell`` steps: worst case for a diffusive balancer,
                      which can only move load one neighbor hop per round;
  bimodal-churn     — bimodal object loads (few heavy, many light) whose
                      heavy-set membership churns over time (Boulmier et
                      al.'s unpredictable-imbalance regime);
  serving-trace     — trace-driven serving replay: a recorded table of
                      bursty multi-turn session loads (serve/replay.py's
                      synthetic workload captured via ``record_trace``)
                      with prefix-sharing star+ring comm edges — sessions
                      are the persistently interacting objects, replicas
                      the nodes;
  routing-skew      — recorded MoE expert-routing trace
                      (train/ep_runtime.py's skewed top-k workload):
                      experts are the objects, EP ranks the nodes, loads
                      are EMA routed tokens and edges the strongest
                      co-activation pairs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import comm_graph
from repro.pic import chares
from repro.sim import stencil

EvolveFn = Callable[[comm_graph.LBProblem, object], comm_graph.LBProblem]


def finite_loads(loads, floor: float = 1e-3) -> jnp.ndarray:
    """Shared finite-guard for evolved load vectors.

    Every registered evolve routes its loads through this: non-finite
    entries (a NaN/Inf from a degenerate parameterization would
    otherwise poison trigger statistics, diffusion sweeps and the
    resilience guardrails downstream) are replaced by ``floor`` and
    finite entries are clamped to at least ``floor``.  For the finite
    loads every registered scenario actually produces (all >= ``floor``)
    this is a bitwise identity, so adding the guard changed no replay
    trajectory."""
    loads = jnp.asarray(loads, jnp.float32)
    return jnp.where(jnp.isfinite(loads),
                     jnp.maximum(loads, jnp.float32(floor)),
                     jnp.float32(floor))


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named workload: ``factory(**kw) -> (problem, evolve)``."""

    name: str
    description: str
    factory: Callable[..., Tuple[comm_graph.LBProblem, EvolveFn]]
    defaults: Mapping = dataclasses.field(default_factory=dict)
    # PICConfig field overrides for the particle-level driver benches
    # (fig4/fig5); None for purely simulator-level scenarios.
    pic_config: Optional[Mapping] = None

    def instantiate(self, **overrides):
        """(problem, evolve) for this workload.

        Memoized on the parameter set: re-instantiating the same scenario
        returns the *same* evolve object, so the replay layers' compiled-
        runner caches (keyed on evolve identity) hit across calls —
        parameter sweeps pay tracing once per distinct configuration."""
        kw = {**self.defaults, **overrides}
        try:
            key = (self.name, tuple(sorted(kw.items())))
            hash(key)
        except TypeError:
            key = None  # unhashable override: fall through uncached
        if key is not None and key in _INSTANCE_MEMO:
            return _INSTANCE_MEMO[key]
        problem, evolve = self.factory(**kw)
        evolve.jittable = True  # every registered evolve is scan-safe
        if key is not None:
            _INSTANCE_MEMO[key] = (problem, evolve)
        return problem, evolve


_INSTANCE_MEMO: Dict = {}


SCENARIOS: Dict[str, Scenario] = {}


def register(s: Scenario) -> Scenario:
    SCENARIOS[s.name] = s
    return s


def get(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: {sorted(SCENARIOS)}"
        ) from None


def available() -> Tuple[str, ...]:
    return tuple(sorted(SCENARIOS))


def batch_instances(batch: int = 16, *, grid: int = 16, num_nodes: int = 16):
    """B ``(name, problem, evolve)`` instances at one common shape.

    Feeds the batched replay layers (``simulator.run_series_batch``): every
    registered scenario is instantiated at the same ``(N, P)`` envelope —
    the stencil family at ``grid²`` objects / ``num_nodes`` nodes, the PIC
    proxy at a ``grid×grid`` chare array over ``num_nodes`` PEs — and
    replicas beyond one-per-scenario vary workload parameters (period,
    dwell, churn seed, density) so the B lanes are genuinely independent
    problems, not copies.  Edge-list lengths may still differ; the batch
    stacker pads them.

    Raises for a registered scenario without a common-shape variant entry
    below: the batched benchmarks claim full-registry coverage, so a new
    scenario must be taught its shape here rather than silently dropped.
    """
    variants = {
        "stencil-wave": lambda v: dict(
            grid=grid, num_nodes=num_nodes, period=40 + 10 * v,
            amp=6.0 + 2.0 * v),
        "adversarial-hotspot": lambda v: dict(
            grid=grid, num_nodes=num_nodes, dwell=6 + 2 * v, seed=v),
        "bimodal-churn": lambda v: dict(
            grid=grid, num_nodes=num_nodes, churn_every=4 + v, seed=v),
        "pic-geometric": lambda v: dict(
            cx=grid, cy=grid, num_pes=num_nodes, rho=0.85 + 0.03 * v,
            n_particles=20_000.0),
        "serving-trace": lambda v: dict(
            num_sessions=grid * grid, num_replicas=num_nodes,
            burst_period=20 + 5 * v, seed=v),
        "routing-skew": lambda v: dict(
            num_experts=grid * grid, num_ranks=num_nodes,
            drift_period=12 + 4 * v, seed=v),
    }
    missing = sorted(set(SCENARIOS) - set(variants))
    if missing:
        raise ValueError(
            f"scenarios {missing} have no common-shape variant entry in "
            "batch_instances; add one so the batched sweeps keep covering "
            "the whole registry")
    names = sorted(SCENARIOS)
    out = []
    for i in range(batch):
        name = names[i % len(names)]
        problem, evolve = SCENARIOS[name].instantiate(
            **variants[name](i // len(names)))
        out.append((name, problem, evolve))
    return out


# ------------------------------------------------------------ stencil wave --


def _stencil_wave(*, grid: int = 32, num_nodes: int = 16,
                  mapping: str = "tiled", period: int = 60,
                  amp: float = 8.0, seed: int = 0):
    problem = stencil.stencil_2d(grid, grid, num_nodes, mapping=mapping,
                                 seed=seed)
    coords = jnp.asarray(problem.coords)
    base = jnp.ones(grid * grid, jnp.float32)
    sigma2 = jnp.float32(2.0 * (grid / 8.0) ** 2)

    def evolve(p: comm_graph.LBProblem, t) -> comm_graph.LBProblem:
        angle = 2.0 * jnp.pi * t / period
        cx = grid / 2.0 + grid / 3.0 * jnp.cos(angle)
        cy = grid / 2.0 + grid / 3.0 * jnp.sin(angle)
        d2 = (coords[:, 0] - cx) ** 2 + (coords[:, 1] - cy) ** 2
        loads = base * (1.0 + amp * jnp.exp(-d2 / sigma2))
        return dataclasses.replace(p, loads=finite_loads(loads))

    return problem, evolve


register(Scenario(
    "stencil-wave",
    "load hotspot orbiting a 2D stencil grid (paper §V)",
    _stencil_wave,
    defaults=dict(grid=32, num_nodes=16, mapping="tiled", period=60,
                  amp=8.0, seed=0),
))


# ----------------------------------------------------------- PIC geometric --


def _pic_geometric(*, L: int = 1000, cx: int = 12, cy: int = 12,
                   num_pes: int = 4, k: int = 2, vy0: float = 1.0,
                   rho: float = 0.9, lb_period: int = 10,
                   n_particles: float = 100_000.0,
                   bytes_per_particle: float = 48.0,
                   mapping: str = "striped"):
    n = cx * cy
    w = L / cx
    # chare-column center cell, one per chare (loads are uniform along y)
    col = (jnp.arange(n, dtype=jnp.float32) // cy + 0.5) * w
    speed = jnp.float32(2 * k + 1)
    assignment = jnp.asarray(chares.initial_mapping(cx, cy, num_pes, mapping))

    def loads_at(t):
        # geometric column density, advected east with wraparound
        shifted = jnp.mod(col - speed * t, L)
        dens = jnp.power(jnp.float32(rho), shifted)
        return (dens / dens.sum() * n_particles).astype(jnp.float32)

    def evolve(p: comm_graph.LBProblem, t) -> comm_graph.LBProblem:
        loads = loads_at(t)
        eb = chares.edge_bytes_device(
            loads, L=L, cx=cx, cy=cy, k=k, vy0=vy0, lb_period=lb_period,
            bytes_per_particle=bytes_per_particle)
        return dataclasses.replace(
            p, loads=finite_loads(loads), edges_bytes=eb)

    problem = chares.build_problem(
        np.asarray(loads_at(0)), np.asarray(assignment), L=L, cx=cx, cy=cy,
        num_pes=num_pes, k=k, vy0=vy0, lb_period=lb_period,
        bytes_per_particle=bytes_per_particle)
    return problem, evolve


register(Scenario(
    "pic-geometric",
    "chare-level PIC PRK proxy: geometric column profile drifting east "
    "(paper §VI)",
    _pic_geometric,
    defaults=dict(L=1000, cx=12, cy=12, num_pes=4, k=2, vy0=1.0, rho=0.9,
                  lb_period=10, n_particles=100_000.0, mapping="striped"),
    pic_config=dict(mode="GEOMETRIC", L=1000, cx=12, cy=12, num_pes=4,
                    k=2, rho=0.9, mapping="striped", lb_every=10),
))


# ---------------------------------------------------- adversarial hotspot --


def _adversarial_hotspot(*, grid: int = 32, num_nodes: int = 16,
                         mapping: str = "tiled", dwell: int = 8,
                         amp: float = 12.0, n_sites: int = 16,
                         seed: int = 0):
    # seed drives both the teleport sites and a "random" initial mapping
    problem = stencil.stencil_2d(grid, grid, num_nodes, mapping=mapping,
                                 seed=seed)
    coords = jnp.asarray(problem.coords)
    rng = np.random.default_rng(seed)
    # teleport sites sampled once: far-apart corners-and-interior points
    sites = jnp.asarray(
        rng.uniform(0, grid, size=(n_sites, 2)).astype(np.float32))
    sigma2 = jnp.float32(2.0 * (grid / 10.0) ** 2)

    def evolve(p: comm_graph.LBProblem, t) -> comm_graph.LBProblem:
        idx = jnp.mod(t // dwell, n_sites)
        c = sites[idx]
        d2 = ((coords - c[None, :]) ** 2).sum(axis=1)
        loads = 1.0 + amp * jnp.exp(-d2 / sigma2)
        return dataclasses.replace(p, loads=finite_loads(loads))

    return problem, evolve


register(Scenario(
    "adversarial-hotspot",
    "hotspot teleporting across the domain every `dwell` steps — worst "
    "case for one-hop diffusive migration",
    _adversarial_hotspot,
    defaults=dict(grid=32, num_nodes=16, mapping="tiled", dwell=8,
                  amp=12.0, n_sites=16, seed=0),
))


# --------------------------------------------------------- bimodal churn --


def _bimodal_churn(*, grid: int = 32, num_nodes: int = 16,
                   mapping: str = "tiled", heavy_frac: float = 0.1,
                   heavy_load: float = 20.0, churn_every: int = 5,
                   stride: int = 7919, seed: int = 0):
    # seed drives both the churn permutation and a "random" initial mapping
    problem = stencil.stencil_2d(grid, grid, num_nodes, mapping=mapping,
                                 seed=seed)
    N = grid * grid
    rng = np.random.default_rng(seed)
    perm = jnp.asarray(rng.permutation(N).astype(np.int32))
    heavy_count = jnp.int32(max(1, int(heavy_frac * N)))

    def evolve(p: comm_graph.LBProblem, t) -> comm_graph.LBProblem:
        phase = (jnp.asarray(t) // churn_every).astype(jnp.int32)
        # deterministic churn: rotate the permutation ranks each phase
        rank = jnp.mod(perm + phase * stride, N)
        heavy = rank < heavy_count
        loads = jnp.where(heavy, heavy_load, 1.0)
        return dataclasses.replace(p, loads=finite_loads(loads))

    return problem, evolve


register(Scenario(
    "bimodal-churn",
    "bimodal loads whose heavy-set membership churns every few steps "
    "(unpredictable imbalance)",
    _bimodal_churn,
    defaults=dict(grid=32, num_nodes=16, mapping="tiled", heavy_frac=0.1,
                  heavy_load=20.0, churn_every=5, stride=7919, seed=0),
))


# --------------------------------------------------------- serving trace --


def _serving_trace(*, num_sessions: int = 256, num_replicas: int = 16,
                   group_size: int = 4, trace_len: int = 64,
                   turn_period: int = 12, turn_len: int = 6,
                   burst_waves: int = 4, burst_period: int = 25,
                   burst_amp: float = 3.0, seed: int = 0):
    """Recorded serving trace as a registry workload.

    Captures ``trace_len`` ticks of ``serve.replay.ServeWorkload``'s
    bursty multi-turn traffic into a ``(T, S)`` table and replays it
    through the scenario interface: sessions are the objects (identity
    fixed to slot index here — the simulator path never migrates
    payload), replicas the nodes, and the prefix-sharing comm graph is
    the device-built star+ring construction
    (``comm_graph.prefix_group_edges``), with edge weights re-priced from
    the clamped loads every step.  The table loops past its length, so
    any replay horizon works."""
    from repro.serve import replay as serve_replay  # local: serve uses core

    w = serve_replay.ServeWorkload(
        num_sessions=num_sessions, num_replicas=num_replicas,
        group_size=group_size, turn_period=turn_period, turn_len=turn_len,
        burst_waves=burst_waves, burst_period=burst_period,
        burst_amp=burst_amp, seed=seed)
    trace = serve_replay.record_trace(w, steps=trace_len)
    table, group = trace.table, trace.group
    S, T = num_sessions, trace_len
    uid = jnp.arange(S, dtype=jnp.int32)
    assignment = ((uid * num_replicas) // S).astype(jnp.int32)

    def edges(loads):
        return comm_graph.prefix_group_edges(group, loads, None)

    loads0 = finite_loads(table[0])
    es, ed, ew = edges(loads0)
    problem = comm_graph.LBProblem(
        loads=loads0, assignment=assignment, edges_src=es, edges_dst=ed,
        edges_bytes=ew, num_nodes=num_replicas)

    def evolve(p: comm_graph.LBProblem, t) -> comm_graph.LBProblem:
        loads = finite_loads(
            table[jnp.mod(jnp.asarray(t, jnp.int32), T)])
        _, _, ew = edges(loads)
        return dataclasses.replace(p, loads=loads, edges_bytes=ew)

    return problem, evolve


register(Scenario(
    "serving-trace",
    "trace-driven serving replay: recorded bursty multi-turn session "
    "loads with prefix-sharing comm edges (serve/replay.py)",
    _serving_trace,
    defaults=dict(num_sessions=256, num_replicas=16, group_size=4,
                  trace_len=64, turn_period=12, turn_len=6, burst_waves=4,
                  burst_period=25, burst_amp=3.0, seed=0),
))


# ---------------------------------------------------------- routing skew --


def _routing_skew(*, num_experts: int = 64, num_ranks: int = 8,
                  top_k: int = 4, tokens_per_step: int = 1024,
                  trace_len: int = 48, alpha: float = 1.0,
                  hot_frac: float = 0.25, hot_amp: float = 4.0,
                  drift_period: int = 16, edges_per_expert: int = 4,
                  ema: float = 0.9, seed: int = 0):
    """Recorded MoE expert-routing trace as a registry workload.

    Captures ``trace_len`` steps of ``train.ep_runtime.RoutingWorkload``'s
    skewed drifting top-k traffic and replays the **EMA** routing
    statistics through the scenario interface: experts are the objects,
    EP ranks the nodes, loads are the EMA tokens-per-expert and the comm
    graph is the static set of strongest co-activation pairs (top
    ``edges_per_expert·E`` by total EMA co-activation over the trace,
    plus a ring floor for connectivity) with weights re-read from the
    recorded per-step EMA co-activation each step.  The table loops past
    its length, so any replay horizon works."""
    from repro.distributed import ep_balance  # local: heavier deps
    from repro.train import ep_runtime

    E = num_experts
    w = ep_runtime.RoutingWorkload(
        num_experts=E, num_ranks=num_ranks, top_k=top_k,
        tokens_per_step=tokens_per_step, alpha=alpha, hot_frac=hot_frac,
        hot_amp=hot_amp, drift_period=drift_period, trace_len=trace_len,
        seed=seed)
    ids = w.ids_table()                              # (L, T, k)
    L = trace_len
    counts = np.zeros((L, E), np.float32)
    coact = np.zeros((L, E, E), np.float32)
    run_c = np.zeros(E)
    run_x = np.zeros((E, E))
    for t in range(L):
        c, x = ep_balance.pair_stats_np(ids[t], E)
        run_c = ema * run_c + (1.0 - ema) * c
        run_x = ema * run_x + (1.0 - ema) * x
        counts[t] = run_c
        coact[t] = run_x
    # static edge set: strongest persistent co-activation pairs + ring
    iu, ju = np.triu_indices(E, k=1)
    tot = coact.sum(axis=0)[iu, ju]
    M = min(len(iu), edges_per_expert * E)
    top = np.sort(np.argpartition(-tot, M - 1)[:M])
    ring = {(i, (i + 1) % E) for i in range(E)}
    ring |= {(j, i) for i, j in ring if i > j}
    pairs = sorted({(int(iu[m]), int(ju[m])) for m in top}
                   | {(min(a, b), max(a, b)) for a, b in ring})
    es = np.asarray([a for a, _ in pairs], np.int32)
    ed = np.asarray([b for _, b in pairs], np.int32)
    ew_table = jnp.asarray(coact[:, es, ed] + 1e-3)  # (L, M') weights
    counts_t = jnp.asarray(counts)
    cap = E // num_ranks
    assignment = (jnp.arange(E, dtype=jnp.int32) // cap).astype(jnp.int32)

    problem = comm_graph.LBProblem(
        loads=finite_loads(counts_t[0]), assignment=assignment,
        edges_src=jnp.asarray(es), edges_dst=jnp.asarray(ed),
        edges_bytes=ew_table[0], num_nodes=num_ranks)

    def evolve(p: comm_graph.LBProblem, t) -> comm_graph.LBProblem:
        row = jnp.mod(jnp.asarray(t, jnp.int32), L)
        return dataclasses.replace(
            p, loads=finite_loads(counts_t[row]),
            edges_bytes=ew_table[row])

    return problem, evolve


register(Scenario(
    "routing-skew",
    "recorded MoE expert-routing trace: EMA tokens-per-expert loads and "
    "co-activation comm edges over EP ranks (train/ep_runtime.py)",
    _routing_skew,
    defaults=dict(num_experts=64, num_ranks=8, top_k=4,
                  tokens_per_step=1024, trace_len=48, alpha=1.0,
                  hot_frac=0.25, hot_amp=4.0, drift_period=16,
                  edges_per_expert=4, ema=0.9, seed=0),
))
