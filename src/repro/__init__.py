"""Reproduction of "Communication-Aware Diffusion Load Balancing for
Persistently Interacting Objects" grown toward a production-scale JAX
system.  Importing the package installs version shims for newer
``jax.sharding`` APIs on the pinned jax (see distributed/compat.py).
"""
from repro.distributed import compat as _compat

_compat.install()
