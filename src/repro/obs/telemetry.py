"""Scan-safe LB telemetry: a fixed-shape StepRecord ring buffer.

Every replay path (sim host/scan/sharded, PIC, serve, EP-train) accepts a
:class:`TelemetryConfig` and, when enabled, threads a
:class:`TelemetryState` through its step loop — a ``(ring, F)`` f32 record
buffer plus (at ``level="full"``) a ``(ring, P)`` per-node load buffer,
written in place with ``dynamic_update_slice`` so the carry shape is fixed
and the whole thing stays ``lax.scan``-compatible.

The contract that makes ``off`` free: a disabled config adds **nothing** to
the traced program.  Call sites guard every telemetry expression behind a
static Python ``if tel.enabled:`` (the same elision pattern as
``faults=None`` in the sharded replay), so ``level="off"`` — and passing no
config at all — is bit-for-bit identical to the pre-telemetry paths.  The
parity suite in ``tests/test_obs.py`` asserts exactly that.

Record fields (one f32 row per step, fixed order — see :data:`FIELDS`):
step index, max/avg/p95 node load, trigger fired + which trigger kind,
plan_rejected, diffusion sweeps actually executed, moved items, moved
bytes (load units where the path has no byte notion), spill/deferred
backlog, and health-mask transitions.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

LEVELS = ("off", "counters", "full")

#: StepRecord column order.  Append-only: downstream consumers
#: (trace_export, tests, notebooks) address columns by name.
FIELDS = (
    "t",              # step index
    "max_load",       # max node load after the step
    "avg_load",       # mean node load
    "p95_load",       # 95th-percentile node load
    "fired",          # 0/1 — the trigger fired this step
    "trigger_kind",   # static trigger id (see TRIGGER_KINDS)
    "plan_rejected",  # 0/1 — a fired plan failed validate_plan
    "sweeps",         # diffusion sweeps actually executed (PlanStats)
    "moved_items",    # objects/particles/sessions/experts relocated
    "moved_bytes",    # executed exchange volume (load units if byteless)
    "deferred",       # spill/deferred backlog after the step
    "health_changed", # nodes whose alive mask flipped this step
)
NF = len(FIELDS)

TRIGGER_KINDS = {"every": 0, "threshold": 1, "predictive": 2, "other": 3}


def trigger_kind(trig) -> int:
    """Static integer id of a trigger policy (constant per run)."""
    from repro.runtime import triggers as rt

    if isinstance(trig, rt.EveryTrigger):
        return TRIGGER_KINDS["every"]
    if isinstance(trig, rt.ThresholdTrigger):
        return TRIGGER_KINDS["threshold"]
    if isinstance(trig, rt.PredictiveTrigger):
        return TRIGGER_KINDS["predictive"]
    return TRIGGER_KINDS["other"]


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Telemetry knob.  Frozen + hashable so it can join the cache key of
    every compiled replay runner.

    ``level="off"`` (default): no state, no carry, bit-for-bit identical
    to an absent config.  ``"counters"``: the (ring, F) StepRecord buffer.
    ``"full"``: additionally per-node loads per step — what the Chrome
    trace's per-node lanes and migration flow events are built from.
    """

    level: str = "off"
    ring: int = 256

    def __post_init__(self):
        if self.level not in LEVELS:
            raise ValueError(f"telemetry level {self.level!r} not in {LEVELS}")
        if self.ring < 1:
            raise ValueError("telemetry ring must hold at least one record")

    @property
    def enabled(self) -> bool:
        return self.level != "off"

    @property
    def full(self) -> bool:
        return self.level == "full"


def resolve(cfg: Optional[TelemetryConfig]) -> TelemetryConfig:
    """``None`` → the default (off) config; strings allowed for CLIs."""
    if cfg is None:
        return TelemetryConfig()
    if isinstance(cfg, str):
        return TelemetryConfig(level=cfg)
    return cfg


class TelemetryState(NamedTuple):
    """Scan-carried ring state: total records written + the two buffers."""

    count: jax.Array    # i32 scalar — total records ever written
    records: jax.Array  # (ring, NF) f32
    loads: jax.Array    # (ring, P) f32 — P == 0 below level="full"


def init_state(cfg: TelemetryConfig, num_nodes: int) -> TelemetryState:
    """Fresh ring for a run over ``num_nodes`` nodes/shards/replicas."""
    P = int(num_nodes) if cfg.full else 0
    return TelemetryState(
        count=jnp.int32(0),
        records=jnp.zeros((cfg.ring, NF), jnp.float32),
        loads=jnp.zeros((cfg.ring, P), jnp.float32),
    )


def node_loads(loads, assignment, num_nodes: int) -> jax.Array:
    """Per-node load vector (traceable) — the full-level lane source."""
    return jax.ops.segment_sum(
        jnp.asarray(loads, jnp.float32),
        jnp.asarray(assignment, jnp.int32), num_segments=num_nodes)


def record(
    state: TelemetryState,
    cfg: TelemetryConfig,
    *,
    t,
    node_loads,
    fired,
    trigger_kind: int = TRIGGER_KINDS["other"],
    plan_rejected=0.0,
    sweeps=0.0,
    moved_items=0.0,
    moved_bytes=0.0,
    deferred=0.0,
    health_changed=0.0,
) -> TelemetryState:
    """Write one StepRecord at ``count % ring`` (traceable, fixed shape).

    ``node_loads`` is the per-node load vector after the step; max/avg/p95
    derive from it here so every path records the same statistics.  Call
    sites must guard the call behind ``if cfg.enabled:`` — this function
    assumes an enabled config.
    """
    nl = jnp.asarray(node_loads, jnp.float32)
    row = jnp.stack([
        jnp.asarray(t, jnp.float32),
        nl.max(),
        nl.mean(),
        jnp.quantile(nl, 0.95).astype(jnp.float32),
        jnp.asarray(fired, jnp.float32),
        jnp.float32(trigger_kind),
        jnp.asarray(plan_rejected, jnp.float32),
        jnp.asarray(sweeps, jnp.float32),
        jnp.asarray(moved_items, jnp.float32),
        jnp.asarray(moved_bytes, jnp.float32),
        jnp.asarray(deferred, jnp.float32),
        jnp.asarray(health_changed, jnp.float32),
    ])
    slot = (state.count % cfg.ring).astype(jnp.int32)
    records = jax.lax.dynamic_update_slice(
        state.records, row[None, :], (slot, jnp.int32(0)))
    loads = state.loads
    if cfg.full:
        loads = jax.lax.dynamic_update_slice(
            loads, nl[None, :], (slot, jnp.int32(0)))
    return TelemetryState(state.count + jnp.int32(1), records, loads)


@dataclasses.dataclass
class TelemetrySnapshot:
    """Host-side, chronological view of a recorded run."""

    config: TelemetryConfig
    records: np.ndarray                 # (N, NF) — oldest → newest
    node_loads: Optional[np.ndarray]    # (N, P) at level="full", else None
    steps_total: int                    # records ever written (incl dropped)

    @property
    def dropped(self) -> int:
        """Records overwritten by ring wraparound."""
        return max(0, self.steps_total - len(self.records))

    def column(self, name: str) -> np.ndarray:
        """One StepRecord field over time, addressed by name."""
        return self.records[:, FIELDS.index(name)]


def snapshot(state: TelemetryState, cfg: TelemetryConfig) -> TelemetrySnapshot:
    """One host transfer: unroll the ring into chronological order."""
    count = int(state.count)
    ring = cfg.ring
    recs = np.asarray(jax.device_get(state.records), np.float32)
    loads = np.asarray(jax.device_get(state.loads), np.float32)
    if count >= ring:
        order = (np.arange(ring) + count % ring) % ring
        recs, loads = recs[order], loads[order]
    else:
        recs, loads = recs[:count], loads[:count]
    return TelemetrySnapshot(
        config=cfg, records=recs,
        node_loads=loads if cfg.full else None, steps_total=count)
