"""Chrome-trace / Perfetto export of a recorded replay.

``export_chrome_trace`` turns a :class:`~repro.obs.telemetry.TelemetrySnapshot`
into a Chrome Trace Event JSON object (openable at ``ui.perfetto.dev`` or
``chrome://tracing``):

  * one counter lane per node/shard with its load over time (full level;
    at ``counters`` level the max/avg/p95 aggregate lanes stand in),
  * LB fires, plan rejections and fault injections as instant events,
  * executed migrations as flow events between the sender and receiver
    node lanes (derived from the per-node load deltas at fired steps),
  * one duration slice per replay step on a dedicated "steps" lane.

``validate_chrome_trace`` is the format checker the CI step and the test
suite share: required keys per event phase, non-decreasing timestamps,
and matched flow-event ids.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

import numpy as np

from repro.obs import telemetry

#: Wall-time scale of the synthetic timeline: one replay step = 1 ms.
US_PER_STEP = 1000

_PID = 0
_TID_STEPS = 0      # per-step duration slices
_TID_EVENTS = 1     # fires / rejections / faults
_TID_NODE0 = 10     # node lanes start here (tid = _TID_NODE0 + node)


def _meta(name: str, pid: int, tid: Optional[int], value: str) -> Dict:
    ev = {"name": name, "ph": "M", "pid": pid, "ts": 0,
          "args": {"name": value}}
    if tid is not None:
        ev["tid"] = tid
    return ev


def export_chrome_trace(
    snap: telemetry.TelemetrySnapshot,
    *,
    path: Optional[str] = None,
    label: str = "lb-replay",
    us_per_step: int = US_PER_STEP,
) -> Dict:
    """Build (and optionally write) the Chrome Trace Event JSON object."""
    t_col = snap.column("t").astype(np.int64)
    fired = snap.column("fired") > 0.5
    rejected = snap.column("plan_rejected") > 0.5
    faults = snap.column("health_changed") > 0.5
    moved_items = snap.column("moved_items")
    moved_bytes = snap.column("moved_bytes")
    nl = snap.node_loads   # (N, P) or None

    events: List[Dict] = [_meta("process_name", _PID, None, label),
                          _meta("thread_name", _PID, _TID_STEPS, "steps"),
                          _meta("thread_name", _PID, _TID_EVENTS, "lb-events")]
    if nl is not None:
        for p in range(nl.shape[1]):
            events.append(_meta("thread_name", _PID, _TID_NODE0 + p,
                                f"node/{p:03d}"))

    flow_id = 0
    body: List[Dict] = []
    for i, t in enumerate(t_col):
        ts = int(t) * us_per_step
        body.append({"name": f"step {int(t)}", "ph": "X", "pid": _PID,
                     "tid": _TID_STEPS, "ts": ts, "dur": us_per_step,
                     "args": {"fired": bool(fired[i]),
                              "sweeps": float(snap.records[i][
                                  telemetry.FIELDS.index("sweeps")])}})
        # load lanes: per node at level="full", aggregates otherwise
        if nl is not None:
            for p in range(nl.shape[1]):
                body.append({"name": f"node/{p:03d} load", "ph": "C",
                             "pid": _PID, "tid": _TID_NODE0 + p, "ts": ts,
                             "args": {"load": float(nl[i, p])}})
        for field in ("max_load", "avg_load", "p95_load"):
            body.append({"name": field, "ph": "C", "pid": _PID,
                         "tid": _TID_EVENTS, "ts": ts,
                         "args": {field: float(snap.column(field)[i])}})
        if fired[i]:
            body.append({"name": "lb-fire", "ph": "i", "s": "p",
                         "pid": _PID, "tid": _TID_EVENTS, "ts": ts,
                         "args": {"moved_items": float(moved_items[i]),
                                  "moved_bytes": float(moved_bytes[i])}})
        if rejected[i]:
            body.append({"name": "plan-rejected", "ph": "i", "s": "p",
                         "pid": _PID, "tid": _TID_EVENTS, "ts": ts,
                         "args": {}})
        if faults[i]:
            body.append({"name": "fault-injection", "ph": "i", "s": "p",
                         "pid": _PID, "tid": _TID_EVENTS, "ts": ts,
                         "args": {"transitions": float(
                             snap.column("health_changed")[i])}})
        # executed migrations as flows between node lanes: at a fired
        # step, load leaving one lane and arriving at another is the
        # migration the exchange executed
        if nl is not None and fired[i] and i > 0:
            delta = nl[i] - nl[i - 1]
            eps = 1e-6 * max(1.0, float(np.abs(nl[i]).max()))
            senders = np.where(delta < -eps)[0]
            receivers = np.where(delta > eps)[0]
            if len(senders) and len(receivers):
                top_rx = int(receivers[np.argmax(delta[receivers])])
                half = max(1, us_per_step // 2)
                for s in senders:
                    # anchor slices on both lanes so the flow arrows have
                    # something to bind to in Perfetto
                    body.append({"name": "migrate-out", "ph": "X",
                                 "pid": _PID, "tid": _TID_NODE0 + int(s),
                                 "ts": ts, "dur": half,
                                 "args": {"load_delta": float(delta[s])}})
                    body.append({"name": "migrate-in", "ph": "X",
                                 "pid": _PID, "tid": _TID_NODE0 + top_rx,
                                 "ts": ts + half, "dur": half,
                                 "args": {"load_delta": float(
                                     delta[top_rx])}})
                    body.append({"name": "migration", "ph": "s",
                                 "id": flow_id, "pid": _PID,
                                 "tid": _TID_NODE0 + int(s), "ts": ts,
                                 "args": {}})
                    body.append({"name": "migration", "ph": "f",
                                 "bp": "e", "id": flow_id, "pid": _PID,
                                 "tid": _TID_NODE0 + top_rx,
                                 "ts": ts + half, "args": {}})
                    flow_id += 1

    body.sort(key=lambda e: (e["ts"], 0 if e["ph"] != "f" else 1))
    trace = {
        "traceEvents": events + body,
        "displayTimeUnit": "ms",
        "otherData": {
            "telemetry_level": snap.config.level,
            "steps_recorded": int(len(snap.records)),
            "steps_total": int(snap.steps_total),
            "dropped": int(snap.dropped),
        },
    }
    if path:
        with open(path, "w") as f:
            json.dump(trace, f, indent=None, separators=(",", ":"))
            f.write("\n")
    return trace


def validate_chrome_trace(trace: Dict) -> List[str]:
    """Check a trace object against the Chrome Trace Event format.

    Returns a list of human-readable violations (empty == valid):
    required keys per event, non-decreasing timestamps over the
    non-metadata stream, and flow ids appearing as exactly one matched
    ``s``/``f`` pair with start ≤ finish.
    """
    errors: List[str] = []
    if not isinstance(trace, dict) or "traceEvents" not in trace:
        return ["trace must be a dict with a 'traceEvents' list"]
    events = trace["traceEvents"]
    if not isinstance(events, list) or not events:
        return ["'traceEvents' must be a non-empty list"]

    last_ts = None
    flows: Dict[int, Dict[str, List[int]]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i} is not an object")
            continue
        for key in ("name", "ph", "pid", "ts"):
            if key not in ev:
                errors.append(f"event {i} ({ev.get('name')!r}) missing {key!r}")
        ph = ev.get("ph")
        if ph == "M":
            continue
        if "tid" not in ev:
            errors.append(f"event {i} ({ev.get('name')!r}) missing 'tid'")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {i} has bad ts {ts!r}")
            continue
        if last_ts is not None and ts < last_ts:
            errors.append(
                f"event {i} ts {ts} decreases (previous {last_ts})")
        last_ts = ts
        if ph == "C" and not isinstance(ev.get("args"), dict):
            errors.append(f"counter event {i} missing args dict")
        if ph == "X" and ev.get("dur", -1) < 0:
            errors.append(f"slice event {i} missing non-negative dur")
        if ph == "i" and ev.get("s") not in ("g", "p", "t"):
            errors.append(f"instant event {i} has bad scope {ev.get('s')!r}")
        if ph in ("s", "f"):
            if "id" not in ev:
                errors.append(f"flow event {i} missing id")
            else:
                flows.setdefault(ev["id"], {"s": [], "f": []}).setdefault(
                    ph, []).append(int(ts))

    for fid, ends in sorted(flows.items()):
        if len(ends["s"]) != 1 or len(ends["f"]) != 1:
            errors.append(
                f"flow id {fid} has {len(ends['s'])} starts / "
                f"{len(ends['f'])} finishes (want exactly 1 of each)")
        elif ends["s"][0] > ends["f"][0]:
            errors.append(
                f"flow id {fid} finishes (ts {ends['f'][0]}) before it "
                f"starts (ts {ends['s'][0]})")
    return errors
