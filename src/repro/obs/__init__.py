"""Unified LB observability: scan-carried telemetry, trace export, metrics.

Three legs (ISSUE 10):

  * :mod:`repro.obs.telemetry` — a fixed-shape ``StepRecord`` ring buffer
    carried through the ``lax.scan`` of every replay path, behind a
    ``TelemetryConfig(level=off|counters|full)`` knob where ``off`` (the
    default) provably changes nothing.
  * :mod:`repro.obs.trace_export` — converts a recorded run into
    Chrome-trace / Perfetto JSON (load lanes per node, LB fires and fault
    injections as instant events, executed migrations as flow events).
  * :mod:`repro.obs.metrics` — a tiny counters/gauges registry with a
    ``snapshot()`` API used by the launchers instead of ad-hoc prints.
"""
from repro.obs.telemetry import (  # noqa: F401
    FIELDS,
    TelemetryConfig,
    TelemetrySnapshot,
    TelemetryState,
    init_state,
    node_loads,
    record,
    snapshot,
    trigger_kind,
)
from repro.obs import metrics  # noqa: F401
from repro.obs.trace_export import (  # noqa: F401
    export_chrome_trace,
    validate_chrome_trace,
)
