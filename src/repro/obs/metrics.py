"""Tiny counters/gauges registry for launcher + host-loop logging.

Replaces the ad-hoc ``print`` bookkeeping in ``launch/serve.py`` and
``launch/train.py``: hot loops bump named counters/gauges, and callers
pull a consistent ``snapshot()`` dict to log, assert on, or ship to a
bench JSON.  Counters are monotone by construction (negative increments
raise) — the hypothesis suite leans on that invariant.

Host-side only by design: device-resident per-step series belong to
:mod:`repro.obs.telemetry`; this registry is for the eager control plane
(steps/s, fires, checkpoint counts, moved bytes totals).
"""
from __future__ import annotations

import threading
from typing import Dict, Union

Number = Union[int, float]


class Counter:
    """Monotonically non-decreasing named value."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def inc(self, amount: Number = 1) -> float:
        if amount < 0:
            raise ValueError(
                f"counter {self.name!r} is monotone; cannot inc({amount})")
        self._value += float(amount)
        return self._value

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-write-wins named value."""

    __slots__ = ("name", "_value")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0

    def set(self, value: Number) -> float:
        self._value = float(value)
        return self._value

    @property
    def value(self) -> float:
        return self._value


class MetricsRegistry:
    """Name → Counter/Gauge map with an atomic ``snapshot()``."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            if name in self._gauges:
                raise ValueError(f"{name!r} is already a gauge")
            return self._counters.setdefault(name, Counter(name))

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            if name in self._counters:
                raise ValueError(f"{name!r} is already a counter")
            return self._gauges.setdefault(name, Gauge(name))

    def snapshot(self) -> Dict[str, float]:
        """Flat name → value dict (counters and gauges together)."""
        with self._lock:
            out = {n: c.value for n, c in self._counters.items()}
            out.update({n: g.value for n, g in self._gauges.items()})
            return dict(sorted(out.items()))

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()


#: Process-wide default registry (what the launchers use).
_default = MetricsRegistry()


def counter(name: str) -> Counter:
    return _default.counter(name)


def gauge(name: str) -> Gauge:
    return _default.gauge(name)


def snapshot() -> Dict[str, float]:
    return _default.snapshot()


def reset() -> None:
    _default.reset()
