"""Shared benchmark utilities: timing, table formatting, result capture."""
from __future__ import annotations

import json
import os
import subprocess
import time
from typing import Callable, Dict, List, Optional

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "bench")


def save_result(name: str, payload: Dict) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def provenance() -> Dict:
    """Where/what produced a bench file: device kind + count, backend,
    jax version, git sha.  Stamped into every ``BENCH_*.json`` by
    :func:`write_bench_json` so perf trajectories across commits carry
    their own context (the CI artifact and the committed file agree on
    the schema; consumers treat missing git metadata as ``None``)."""
    import jax

    devs = jax.devices()
    sha = None
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        if proc.returncode == 0:
            sha = proc.stdout.strip() or None
    except Exception:
        sha = None
    return dict(
        device_kind=devs[0].device_kind,
        device_count=len(devs),
        backend=jax.default_backend(),
        jax_version=jax.__version__,
        git_sha=sha,
    )


def write_bench_json(path: str, *, schema: str, generated_by: str,
                     repeats: Optional[int] = None, **out) -> str:
    """The one writer behind every repo-root ``BENCH_*.json``.

    Stable shape: ``schema`` / ``generated_by`` / ``provenance`` (see
    :func:`provenance`) / optional ``repeats`` + the bench's own keys,
    serialized sorted with a trailing newline so diffs across commits
    stay minimal."""
    payload = dict(
        schema=schema,
        generated_by=generated_by,
        provenance=provenance(),
        **out,
    )
    if repeats is not None:
        payload["repeats"] = repeats
    path = os.path.abspath(path)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float, sort_keys=True)
        f.write("\n")
    return path


def _timeit(fn: Callable, args, kw, repeat: int):
    """([wall_seconds...], result-from-last-run)."""
    walls = []
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        walls.append(time.perf_counter() - t0)
    return walls, out


def timeit(fn: Callable, *args, repeat: int = 3, **kw):
    """(result, best_seconds) — best-of-N wall time."""
    walls, out = _timeit(fn, args, kw, repeat)
    return out, min(walls)


def timeit_median(fn: Callable, *args, repeat: int = 3, **kw):
    """(result, median_seconds) — median-of-N wall time.

    The gating statistic for perf assertions: robust to one slow outlier
    (CI noise) without rewarding a lucky fastest run the way best-of-N
    does.  ``result`` is from the last run."""
    walls, out = _timeit(fn, args, kw, repeat)
    return out, sorted(walls)[len(walls) // 2]


def table(headers: List[str], rows: List[List]) -> str:
    w = [max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
         for i, h in enumerate(headers)]
    out = ["  ".join(str(h).rjust(w[i]) for i, h in enumerate(headers))]
    out.append("  ".join("-" * w[i] for i in range(len(headers))))
    for r in rows:
        out.append("  ".join(str(c).rjust(w[i]) for i, c in enumerate(r)))
    return "\n".join(out)
